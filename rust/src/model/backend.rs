//! The `Backend` trait: one masked-model step engine per execution
//! substrate.
//!
//! * [`crate::runtime::executor`]-backed `XlaBackend` (in `crate::fl::xla_backend`)
//!   runs the AOT-lowered JAX/Pallas graphs through PJRT — the production
//!   path.
//! * [`crate::native`]'s `NativeBackend` is a pure-rust mirror of the same
//!   math, used to cross-check the XLA numerics and to run huge sweeps
//!   where the miniature models make XLA dispatch overhead dominate.

use super::{ArchConfig, MaskState};

/// Frozen backbone + (LP-trainable) head. `head_version` bumps whenever the
/// head changes so device-resident caches can invalidate.
#[derive(Clone, Debug)]
pub struct ModelParams {
    pub cfg: ArchConfig,
    pub w_blocks: Vec<f32>, // L·F·F
    pub head_w: Vec<f32>,   // C·F
    pub head_b: Vec<f32>,   // C
    pub head_version: u64,
}

/// Fine-tuning baseline state: its own weight copy + Adam moments.
#[derive(Clone, Debug)]
pub struct FtState {
    pub w_blocks: Vec<f32>,
    pub head_w: Vec<f32>,
    pub head_b: Vec<f32>,
    pub m_wb: Vec<f32>,
    pub v_wb: Vec<f32>,
    pub m_hw: Vec<f32>,
    pub v_hw: Vec<f32>,
    pub m_hb: Vec<f32>,
    pub v_hb: Vec<f32>,
    pub step: u64,
}

impl FtState {
    pub fn from_params(p: &ModelParams) -> Self {
        Self {
            w_blocks: p.w_blocks.clone(),
            head_w: p.head_w.clone(),
            head_b: p.head_b.clone(),
            m_wb: vec![0.0; p.w_blocks.len()],
            v_wb: vec![0.0; p.w_blocks.len()],
            m_hw: vec![0.0; p.head_w.len()],
            v_hw: vec![0.0; p.head_w.len()],
            m_hb: vec![0.0; p.head_b.len()],
            v_hb: vec![0.0; p.head_b.len()],
            step: 0,
        }
    }
}

/// Linear-probe state: head + Adam moments (backbone untouched).
#[derive(Clone, Debug)]
pub struct LpState {
    pub head_w: Vec<f32>,
    pub head_b: Vec<f32>,
    pub m_hw: Vec<f32>,
    pub v_hw: Vec<f32>,
    pub m_hb: Vec<f32>,
    pub v_hb: Vec<f32>,
    pub step: u64,
}

impl LpState {
    pub fn from_params(p: &ModelParams) -> Self {
        Self {
            head_w: p.head_w.clone(),
            head_b: p.head_b.clone(),
            m_hw: vec![0.0; p.head_w.len()],
            v_hw: vec![0.0; p.head_w.len()],
            m_hb: vec![0.0; p.head_b.len()],
            v_hb: vec![0.0; p.head_b.len()],
            step: 0,
        }
    }
}

/// One masked-model execution engine. Batch tensors are row-major host
/// slices sized exactly (B·F), (B·C); callers pad partial batches.
pub trait Backend: Send + Sync {
    /// One stochastic-mask Adam step; returns the batch loss.
    fn train_step(
        &self,
        params: &ModelParams,
        state: &mut MaskState,
        x: &[f32],
        y_onehot: &[f32],
        u: &[f32],
    ) -> anyhow::Result<f32>;

    /// Logits (B·C) under an explicit mask.
    fn eval_logits(
        &self,
        params: &ModelParams,
        mask: &[f32],
        x: &[f32],
    ) -> anyhow::Result<Vec<f32>>;

    /// One linear-probing Adam step on the head; returns the loss.
    fn lp_step(
        &self,
        params: &ModelParams,
        state: &mut LpState,
        x: &[f32],
        y_onehot: &[f32],
    ) -> anyhow::Result<f32>;

    /// One fine-tuning Adam step on blocks + head; returns the loss.
    fn ft_step(
        &self,
        params: &ModelParams,
        state: &mut FtState,
        x: &[f32],
        y_onehot: &[f32],
    ) -> anyhow::Result<f32>;

    /// Logits for the fine-tuning baseline's own weights.
    fn ft_eval_logits(
        &self,
        params: &ModelParams,
        state: &FtState,
        x: &[f32],
    ) -> anyhow::Result<Vec<f32>>;

    fn name(&self) -> &'static str;
}

/// Adam hyper-parameters shared by both backends (and the L2 graphs).
pub mod adam {
    pub const MASK_LR: f32 = 0.1; // paper App. C.1
    pub const LP_LR: f32 = 0.01;
    pub const FT_LR: f32 = 3e-3;
    pub const B1: f32 = 0.9;
    pub const B2: f32 = 0.999;
    pub const EPS: f32 = 1e-8;

    /// In-place Adam update matching `model.adam_update` in L2.
    pub fn update(p: &mut [f32], g: &[f32], mt: &mut [f32], vt: &mut [f32], t: u64, lr: f32) {
        let t = t as f32;
        let bc1 = 1.0 - B1.powf(t);
        let bc2 = 1.0 - B2.powf(t);
        for i in 0..p.len() {
            mt[i] = B1 * mt[i] + (1.0 - B1) * g[i];
            vt[i] = B2 * vt[i] + (1.0 - B2) * g[i] * g[i];
            let mhat = mt[i] / bc1;
            let vhat = vt[i] / bc2;
            p[i] -= lr * mhat / (vhat.sqrt() + EPS);
        }
    }
}
