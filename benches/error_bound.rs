//! **Eq. 6 / Appendix B** — empirical verification of the distributed
//! mean-estimation error bound E‖θ̄ − θ̂‖² ≤ d/4K, including the filter
//! "bit-flip" noise at 8/16/32 bits-per-entry fingerprints.
//!
//!     cargo bench --bench error_bound [-- --trials 50]

use deltamask::bench::Table;
use deltamask::compress::{DecodeCtx, DeltaMaskCodec, EncodeCtx, FilterKind, Update, UpdateCodec};
use deltamask::model::sample_mask_seeded;
use deltamask::util::cli::Args;
use deltamask::util::rng::Xoshiro256pp;

/// Monte-Carlo MSE of θ̂ = (1/K)Σ m̂_k against θ̄ = (1/K)Σ θ_k, with masks
/// reconstructed through the DeltaMask pipeline at the given filter width.
fn mse_with_filter(
    d: usize,
    k: usize,
    trials: usize,
    filter: Option<FilterKind>,
    rng: &mut Xoshiro256pp,
) -> f64 {
    let thetas: Vec<Vec<f32>> = (0..k)
        .map(|_| (0..d).map(|_| rng.next_f32()).collect())
        .collect();
    let mut theta_bar = vec![0.0f64; d];
    for t in &thetas {
        for i in 0..d {
            theta_bar[i] += t[i] as f64 / k as f64;
        }
    }
    let theta_g: Vec<f32> = vec![0.5; d];
    let mut mse = 0.0f64;
    for trial in 0..trials {
        let round_seed = rng.next_u64();
        let mut mask_g = Vec::new();
        sample_mask_seeded(&theta_g, round_seed, &mut mask_g);
        let mut est = vec![0.0f64; d];
        for (ci, t) in thetas.iter().enumerate() {
            // Independent per-client sampling: Eq. 6's setting.
            let mut mask_k = Vec::new();
            sample_mask_seeded(t, round_seed ^ (ci as u64 + 1) ^ (trial as u64) << 20, &mut mask_k);
            let recon: Vec<f32> = match filter {
                None => mask_k.clone(),
                Some(kind) => {
                    let codec = DeltaMaskCodec {
                        filter: kind,
                        ..Default::default()
                    };
                    let ctx = EncodeCtx {
                        d,
                        theta_k: t,
                        theta_g: &theta_g,
                        mask_k: &mask_k,
                        mask_g: &mask_g,
                        s_k: &[],
                        s_g: &[],
                        kappa: 1.0,
                        seed: round_seed,
                    };
                    let enc = codec.encode(&ctx).unwrap();
                    let dctx = DecodeCtx {
                        d,
                        mask_g: &mask_g,
                        s_g: &[],
                        seed: round_seed,
                    };
                    match codec.decode(&enc.bytes, &dctx).unwrap() {
                        Update::Mask(m) => m,
                        _ => unreachable!(),
                    }
                }
            };
            for i in 0..d {
                est[i] += recon[i] as f64 / k as f64;
            }
        }
        mse += (0..d)
            .map(|i| (est[i] - theta_bar[i]).powi(2))
            .sum::<f64>()
            / trials as f64;
    }
    mse
}

fn main() {
    let args = Args::from_env();
    let trials = args.usize("trials", 20);
    let d = args.usize("d", 4096);
    let mut rng = Xoshiro256pp::new(5);

    let mut table = Table::new(
        "Eq. 6: E||θ̄ − θ̂||² vs bound d/4K",
        &["K", "reconstruction", "measured MSE", "bound d/4K", "ratio"],
    );
    for k in [1usize, 5, 10, 30] {
        let bound = d as f64 / (4.0 * k as f64);
        for (label, filt) in [
            ("exact masks", None),
            ("BFuse8", Some(FilterKind::BFuse8)),
            ("BFuse16", Some(FilterKind::BFuse16)),
            ("BFuse32", Some(FilterKind::BFuse32)),
        ] {
            let mse = mse_with_filter(d, k, trials, filt, &mut rng);
            eprintln!("  K={k} {label}: mse={mse:.2} bound={bound:.2}");
            table.row(vec![
                format!("{k}"),
                label.to_string(),
                format!("{:.2}", mse),
                format!("{:.2}", bound),
                format!("{:.3}", mse / bound),
            ]);
            assert!(
                mse <= bound * 1.05,
                "Eq. 6 violated: K={k} {label} mse={mse} bound={bound}"
            );
        }
    }
    table.print();
    table.save("error_bound");
    println!("\nall configurations satisfy E||θ̄ − θ̂||² ≤ d/4K (Appendix B).");
}
