//! Edge-device profile (App. C.4 scenario): measure per-entry CPU cost of
//! filter construction + membership query at the paper's 10M-entry scale
//! (scale down with --entries for a quick run), for every filter variant in
//! Table 4.
//!
//!     cargo run --release --example edge_profile -- [--entries 1000000]
//!
//! The paper measured Jetson Nano / RPi 4 / Coral boards with a power HAT;
//! this machine reports its own CPU timings — the algorithmic claims
//! (BFuse ≻ XOR; mild bpe scaling) are device-independent.

use deltamask::bench::{summarize, time_fn, Table};
use deltamask::filters::{BinaryFuse, MembershipFilter, XorFilter};
use deltamask::util::cli::Args;
use deltamask::util::rng::Xoshiro256pp;

fn main() {
    let args = Args::from_env();
    let n = args.usize("entries", 1_000_000);
    let mut rng = Xoshiro256pp::new(3);
    let keys: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
    let probes: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();

    println!("filter profile over {n} entries (paper Table 4 uses 10M)");
    let mut table = Table::new(
        "edge filter profile",
        &["filter", "bpe", "construct ns/entry", "query ns/entry"],
    );

    macro_rules! profile {
        ($label:expr, $ty:ty) => {{
            let reps = if n > 2_000_000 { 1 } else { 3 };
            let c = summarize(&time_fn(0, reps, || <$ty>::build(&keys).unwrap()));
            let f = <$ty>::build(&keys).unwrap();
            let q = summarize(&time_fn(1, reps, || {
                probes.iter().filter(|&&k| f.contains(k)).count()
            }));
            table.row(vec![
                $label.to_string(),
                format!("{:.2}", f.bits_per_entry()),
                format!("{:.1}", c.mean / n as f64 * 1e9),
                format!("{:.1}", q.mean / n as f64 * 1e9),
            ]);
        }};
    }

    profile!("Xor8", XorFilter<u8>);
    profile!("Xor16", XorFilter<u16>);
    profile!("Xor32", XorFilter<u32>);
    profile!("BFuse8", BinaryFuse<u8, 4>);
    profile!("BFuse16", BinaryFuse<u16, 4>);
    profile!("BFuse32", BinaryFuse<u32, 4>);
    table.print();
    println!(
        "\npaper Table 4 shape check: BFuse* should construct+query faster than Xor* \
         and bpe growth 8→32 should cost only mildly more time."
    );
}
