//! Minimal dense f32 matmul kernels for the native backend. Cache-friendly
//! loop orders (ikj for NN/AT, row-dot for BT) — no external BLAS in the
//! offline vendor set, and the simulated-FM sizes (≤ 64×384×384) stay well
//! inside L2 cache.
//!
//! The inner loops are blocked/unrolled so the autovectorizer gets straight
//! multi-lane arithmetic: NN/AT unroll the contiguous `j` axis 8-wide
//! (element-wise, so the per-element accumulation order — and therefore the
//! f32 result — is bit-identical to the scalar loops, which the tests keep
//! as oracles), and BT processes 4 output columns per pass with 4
//! independent dot accumulators (each dot still sums in `k` order, so it
//! too matches the scalar kernel bitwise while quadrupling ILP and reusing
//! the streamed A row).

/// One ikj rank-update row: `crow += av · brow`, 8-wide.
#[inline(always)]
fn axpy8(crow: &mut [f32], brow: &[f32], av: f32) {
    debug_assert_eq!(crow.len(), brow.len());
    let mut ci = crow.chunks_exact_mut(8);
    let mut bi = brow.chunks_exact(8);
    for (cb, bb) in (&mut ci).zip(&mut bi) {
        cb[0] += av * bb[0];
        cb[1] += av * bb[1];
        cb[2] += av * bb[2];
        cb[3] += av * bb[3];
        cb[4] += av * bb[4];
        cb[5] += av * bb[5];
        cb[6] += av * bb[6];
        cb[7] += av * bb[7];
    }
    for (c, b) in ci.into_remainder().iter_mut().zip(bi.remainder()) {
        *c += av * b;
    }
}

/// C = A @ B with A:(m,k), B:(k,n), C:(m,n). (ikj order: streams B rows.)
pub fn matmul_nn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            axpy8(crow, &b[kk * n..(kk + 1) * n], av);
        }
    }
}

/// C = A @ Bᵀ with A:(m,k), B:(n,k), C:(m,n). Four output columns per pass:
/// the A row streams once through four independent dot accumulators.
pub fn matmul_bt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let mut j = 0usize;
        while j + 4 <= n {
            let b0 = &b[j * k..(j + 1) * k];
            let b1 = &b[(j + 1) * k..(j + 2) * k];
            let b2 = &b[(j + 2) * k..(j + 3) * k];
            let b3 = &b[(j + 3) * k..(j + 4) * k];
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for (kk, &av) in arow.iter().enumerate() {
                s0 += av * b0[kk];
                s1 += av * b1[kk];
                s2 += av * b2[kk];
                s3 += av * b3[kk];
            }
            c[i * n + j] = s0;
            c[i * n + j + 1] = s1;
            c[i * n + j + 2] = s2;
            c[i * n + j + 3] = s3;
            j += 4;
        }
        while j < n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += arow[kk] * brow[kk];
            }
            c[i * n + j] = acc;
            j += 1;
        }
    }
}

/// C = Aᵀ @ B with A:(k,m), B:(k,n), C:(m,n). (Accumulates rank-1 updates;
/// ikj-style inner streaming.)
pub fn matmul_at(a: &[f32], b: &[f32], c: &mut [f32], k: usize, m: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for kk in 0..k {
        let arow = &a[kk * m..(kk + 1) * m];
        let brow = &b[kk * n..(kk + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            axpy8(&mut c[i * n..(i + 1) * n], brow, av);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn naive_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for kk in 0..k {
                    acc += a[i * k + kk] * b[kk * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    /// Scalar ikj kernel (the seed's matmul_nn) — the bitwise oracle for
    /// the 8-wide unrolled version: same per-element accumulation order.
    fn scalar_ikj_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        }
        c
    }

    /// Scalar row-dot kernel (the seed's matmul_bt) — bitwise oracle for
    /// the 4-column blocked version: each dot sums in the same k order.
    fn scalar_dot_bt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &b[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += arow[kk] * brow[kk];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn all_variants_match_naive() {
        let mut rng = Xoshiro256pp::new(1);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 7), (8, 32, 16), (17, 9, 23)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.next_f32() - 0.5).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.next_f32() - 0.5).collect();
            let want = naive_nn(&a, &b, m, k, n);

            let mut c = vec![0.0f32; m * n];
            matmul_nn(&a, &b, &mut c, m, k, n);
            assert_close(&c, &want);

            // A @ Bᵀ: feed B transposed.
            let mut bt = vec![0.0f32; n * k];
            for kk in 0..k {
                for j in 0..n {
                    bt[j * k + kk] = b[kk * n + j];
                }
            }
            matmul_bt(&a, &bt, &mut c, m, k, n);
            assert_close(&c, &want);

            // Aᵀ @ B: feed A transposed.
            let mut at = vec![0.0f32; k * m];
            for i in 0..m {
                for kk in 0..k {
                    at[kk * m + i] = a[i * k + kk];
                }
            }
            matmul_at(&at, &b, &mut c, k, m, n);
            assert_close(&c, &want);
        }
    }

    #[test]
    fn blocked_kernels_bitwise_match_scalar_oracles() {
        // The unrolled kernels preserve the exact f32 accumulation order of
        // the scalar loops — so training trajectories are unchanged, not
        // just approximately equal. Sizes cover remainder lanes (n % 8 ≠ 0,
        // n % 4 ≠ 0) and zero-skip rows.
        let mut rng = Xoshiro256pp::new(99);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (5, 13, 11), (16, 64, 64), (7, 31, 29)] {
            let mut a: Vec<f32> = (0..m * k).map(|_| rng.next_f32() - 0.5).collect();
            // Exercise the av == 0.0 skip path.
            for x in a.iter_mut().step_by(5) {
                *x = 0.0;
            }
            let b: Vec<f32> = (0..k * n).map(|_| rng.next_f32() - 0.5).collect();
            let mut c = vec![0.0f32; m * n];
            matmul_nn(&a, &b, &mut c, m, k, n);
            assert_eq!(c, scalar_ikj_nn(&a, &b, m, k, n), "nn {m}x{k}x{n}");

            let bt: Vec<f32> = (0..n * k).map(|_| rng.next_f32() - 0.5).collect();
            matmul_bt(&a, &bt, &mut c, m, k, n);
            assert_eq!(c, scalar_dot_bt(&a, &bt, m, k, n), "bt {m}x{k}x{n}");
        }
    }

    fn assert_close(got: &[f32], want: &[f32]) {
        for (g, w) in got.iter().zip(want) {
            assert!((g - w).abs() < 1e-4, "{g} vs {w}");
        }
    }
}
