//! End-to-end federated integration tests: DeltaMask training improves
//! accuracy at sub-1 bpp, baselines behave per the paper's ordering, and
//! both execution backends drive the same coordinator.

use deltamask::compress::{self, Update};
use deltamask::coordinator::PipelineMode;
use deltamask::fl::server::MaskServer;
use deltamask::fl::{run_experiment, BackendKind, ExperimentConfig, HeadInit, ServerTuning};
use deltamask::model::sample_mask_seeded;
use deltamask::util::rng::Xoshiro256pp;

fn base_cfg() -> ExperimentConfig {
    ExperimentConfig {
        dataset: "cifar10".into(),
        arch: "test".into(),
        // "deltamask" unless the CI knob matrix overrides it (its codec-9
        // entry sets DELTAMASK_METHOD=deltamask-pco, and the sibling-codec
        // entries set =maskrn / =sparse-rsn, so each new wire path runs
        // end-to-end under the full scaling stack). Tests that pin a
        // specific method still assign `cfg.method` explicitly.
        method: deltamask::fl::method_from_env(),
        n_clients: 6,
        rounds: 12,
        rho: 1.0,
        local_epochs: 1,
        samples_per_client: 48,
        test_samples: 200,
        dirichlet_alpha: 10.0,
        kappa0: 0.8,
        kappa_floor: 0.25,
        seed: 7,
        eval_every: 3,
        backend: BackendKind::Native,
        head_init: HeadInit::Lp,
        lp_rounds: 1,
        theta0: 0.85,
        arch_override: None,
        tuning: ServerTuning {
            pipeline: PipelineMode::Streaming,
            // CI's knob-matrix job re-runs this suite with
            // DELTAMASK_DECODE_WORKERS / DELTAMASK_AGG_SHARDS /
            // DELTAMASK_PERSISTENT_PIPELINE combinations, so every
            // end-to-end test also exercises the sharded decode path, the
            // dimension-sharded aggregation path and the round-resident
            // pipeline.
            decode_workers: deltamask::fl::decode_workers_from_env(),
            agg_shards: deltamask::fl::agg_shards_from_env(),
            // The remote-shards knob-matrix entry sets
            // DELTAMASK_SHARD_PLACE to a mixed local/remote spec, draining
            // every sharded run through standing shard-worker processes.
            shard_place: deltamask::fl::shard_place_from_env(),
            persistent_pipeline: deltamask::fl::persistent_pipeline_from_env(),
            // The churn knob-matrix entry additionally sets DELTAMASK_CHAOS
            // + DELTAMASK_QUORUM, so the whole suite runs under seeded
            // faults with degraded completion allowed.
            quorum: deltamask::fl::quorum_from_env(),
            round_deadline_ms: deltamask::fl::round_deadline_ms_from_env(),
            on_decode_error: deltamask::fl::on_decode_error_from_env(),
        },
        chaos: deltamask::fl::chaos_from_env(),
        // The uds-transport knob-matrix entry sets DELTAMASK_TRANSPORT=uds,
        // rerouting every update in this suite through the length-prefixed
        // framed socket transport over a loopback Unix socket.
        transport: deltamask::fl::transport_from_env(),
    }
}

#[test]
fn deltamask_trains_at_sub_one_bpp_native() {
    let cfg = base_cfg();
    let res = run_experiment(&cfg).expect("experiment failed");
    let acc = res.final_accuracy();
    // The sibling codecs trade per-round progress for their own properties
    // (maskrn only flips noise-dictionary coordinates, sparse-rsn prunes
    // low-posterior entries), so when CI points the suite at them the
    // miniature-scale accuracy bar is the "clear learning" one.
    let sibling = matches!(cfg.method.as_str(), "maskrn" | "sparse-rsn");
    let floor = if sibling { 0.35 } else { 0.5 };
    assert!(acc > floor, "{}: final accuracy {acc} too low", cfg.method);
    let bpp = res.avg_bpp();
    assert!(bpp < 1.0, "avg bpp {bpp} should be < 1 (paper headline)");
    assert!(bpp > 0.0);
    // bpp decays as updates sparsify: late rounds cheaper than round 0.
    // sparse-rsn is exempt: its record cost tracks supermask polarization
    // (min(|A|, d−|A|)), not update sparsity, so monotone decay is not
    // part of its contract.
    if cfg.method != "sparse-rsn" {
        let first = res.rounds.first().unwrap().mean_bpp;
        let last = res.rounds.last().unwrap().mean_bpp;
        assert!(last < first, "bpp should decay: first={first} last={last}");
    }
}

#[test]
fn deltamask_matches_fedpm_accuracy_with_lower_bpp() {
    let mut cfg = base_cfg();
    // This test is about the paper's Fig. 3 DeltaMask-vs-FedPM claim; when
    // CI points the suite at a sibling codec (covered by its own e2e test
    // below), keep the comparison on the DeltaMask side it is about.
    if matches!(cfg.method.as_str(), "maskrn" | "sparse-rsn") {
        cfg.method = "deltamask".into();
    }
    cfg.rounds = 10;
    let dm = run_experiment(&cfg).unwrap();
    cfg.method = "fedpm".into();
    let pm = run_experiment(&cfg).unwrap();
    // Paper Fig. 3: DeltaMask ≈ FedPM accuracy at a fraction of the bitrate.
    assert!(
        dm.final_accuracy() > pm.final_accuracy() - 0.1,
        "deltamask {} vs fedpm {}",
        dm.final_accuracy(),
        pm.final_accuracy()
    );
    assert!(
        dm.avg_bpp() < pm.avg_bpp() * 0.6,
        "deltamask bpp {} should be well under fedpm {}",
        dm.avg_bpp(),
        pm.avg_bpp()
    );
}

#[test]
fn all_methods_run_and_report_metrics() {
    // Every registered codec (the registry is the roster — a new codec
    // lands in this test by registry growth alone) plus the two
    // non-codec reference methods.
    let methods: Vec<&str> = compress::all_names()
        .iter()
        .copied()
        .chain(["linear_probing", "fine_tuning"])
        .collect();
    for method in methods {
        let mut cfg = base_cfg();
        cfg.method = method.into();
        cfg.rounds = 3;
        cfg.eval_every = 3;
        let res = run_experiment(&cfg)
            .unwrap_or_else(|e| panic!("method {method} failed: {e}"));
        assert_eq!(res.rounds.len(), 3, "{method}");
        assert!(res.final_accuracy() > 0.0, "{method}");
        assert!(res.avg_bpp() > 0.0, "{method}");
    }
}

#[test]
fn noniid_split_still_learns() {
    let mut cfg = base_cfg();
    cfg.dirichlet_alpha = 0.1;
    cfg.rho = 0.5;
    cfg.rounds = 24;
    cfg.eval_every = 6;
    let res = run_experiment(&cfg).unwrap();
    // Non-IID at partial participation converges slowly (the paper runs 300
    // rounds); at this miniature scale we only require clear learning —
    // and a touch less of it from the gated/regularized sibling codecs.
    let floor = if matches!(cfg.method.as_str(), "maskrn" | "sparse-rsn") {
        0.2
    } else {
        0.25
    };
    assert!(
        res.final_accuracy() > floor,
        "{}: non-IID accuracy {}",
        cfg.method,
        res.final_accuracy()
    );
}

/// Satellite property test: for every codec in the roster (both update
/// families), decoding a round's realistic payloads and feeding them to the
/// streaming `begin_round` / `absorb` / `finish_round` path — in an
/// adversarial arrival order — must be *bitwise* identical to the seed's
/// batch `aggregate` over the same updates.
#[test]
fn streaming_absorb_bitwise_matches_batch_aggregate_across_codecs() {
    let d = 4096usize;
    let n_clients = 5usize;
    for (trial, name) in compress::all_names().iter().enumerate() {
        let codec = compress::by_name(name).unwrap();
        let mut rng = Xoshiro256pp::new(0xBEEF ^ trial as u64);

        // A plausible round state: global probabilities, drifted per-client
        // posteriors, shared-seed masks.
        let theta_g: Vec<f32> = (0..d).map(|_| 0.05 + 0.9 * rng.next_f32()).collect();
        let s_g: Vec<f32> = theta_g.iter().map(|&p| (p / (1.0 - p)).ln()).collect();
        let round_seed = 77u64.wrapping_mul(trial as u64 + 1);
        let mut mask_g = Vec::new();
        sample_mask_seeded(&theta_g, round_seed, &mut mask_g);

        let mut updates: Vec<Update> = Vec::new();
        for k in 0..n_clients {
            let theta_k: Vec<f32> = theta_g
                .iter()
                .map(|&p| (p + 0.3 * (rng.next_f32() - 0.5)).clamp(0.01, 0.99))
                .collect();
            let s_k: Vec<f32> = theta_k.iter().map(|&p| (p / (1.0 - p)).ln()).collect();
            let mut mask_k = Vec::new();
            sample_mask_seeded(&theta_k, round_seed, &mut mask_k);
            let ectx = compress::EncodeCtx {
                d,
                theta_k: &theta_k,
                theta_g: &theta_g,
                mask_k: &mask_k,
                mask_g: &mask_g,
                s_k: &s_k,
                s_g: &s_g,
                kappa: 0.8,
                seed: round_seed ^ k as u64,
            };
            let enc = codec.encode(&ectx).unwrap_or_else(|e| panic!("{name}: {e}"));
            let dctx = compress::DecodeCtx {
                d,
                mask_g: &mask_g,
                s_g: &s_g,
                seed: round_seed ^ k as u64,
            };
            updates.push(codec.decode(&enc.bytes, &dctx).unwrap());
        }

        let mut batch = MaskServer::with_theta0(d, 1.0, 0.85);
        batch.aggregate(&updates);

        // Adversarial arrival order: reversed, with a mid-list swap.
        let mut order: Vec<usize> = (0..n_clients).rev().collect();
        order.swap(1, 3);
        let mut stream = MaskServer::with_theta0(d, 1.0, 0.85);
        stream.begin_round(updates.len());
        for &slot in &order {
            stream.absorb(slot, updates[slot].clone());
        }
        stream.finish_round();

        assert_eq!(
            batch.theta_g, stream.theta_g,
            "{name} ({:?}): theta_g diverged",
            updates[0].family()
        );
        assert_eq!(batch.s_g, stream.s_g, "{name}: s_g diverged");
    }
}

/// Acceptance check for the coordinator refactor: a full experiment run
/// under the streaming pipeline is trajectory-identical (losses, wire bits,
/// κ and every evaluated accuracy) to the batch-barrier reference, for one
/// mask-family and one delta-family codec.
#[test]
fn streaming_and_batch_pipelines_produce_identical_trajectories() {
    for method in ["deltamask", "eden"] {
        let mut cfg = base_cfg();
        cfg.method = method.into();
        cfg.rounds = 6;
        cfg.eval_every = 2;
        cfg.tuning.pipeline = PipelineMode::Batch;
        let batch = run_experiment(&cfg).unwrap();
        cfg.tuning.pipeline = PipelineMode::Streaming;
        let streaming = run_experiment(&cfg).unwrap();

        assert_eq!(batch.rounds.len(), streaming.rounds.len(), "{method}");
        for (b, s) in batch.rounds.iter().zip(&streaming.rounds) {
            assert_eq!(b.round, s.round, "{method}");
            assert_eq!(b.kappa, s.kappa, "{method} round {}", b.round);
            assert_eq!(b.mean_bits, s.mean_bits, "{method} round {}", b.round);
            assert_eq!(b.train_loss, s.train_loss, "{method} round {}", b.round);
            assert_eq!(b.accuracy, s.accuracy, "{method} round {}", b.round);
            assert_eq!(b.pipeline, "batch");
            assert_eq!(s.pipeline, "streaming");
        }
        assert_eq!(
            batch.final_accuracy(),
            streaming.final_accuracy(),
            "{method}"
        );
    }
}

/// Round-resident acceptance check: a full experiment through the
/// persistent pipeline (resident decode workers + resident shard lanes +
/// persistent pools) is trajectory-identical — losses, wire bits, κ and
/// every evaluated accuracy — to the per-round-spawn path, for one
/// mask-family and one delta-family codec, and its RoundMetrics expose
/// the pool hit/miss counters.
#[test]
fn persistent_pipeline_trajectories_match_per_round_spawn() {
    for method in ["deltamask", "eden"] {
        let mut cfg = base_cfg();
        cfg.method = method.into();
        cfg.rounds = 6;
        cfg.eval_every = 2;
        cfg.tuning.decode_workers = 3;
        cfg.tuning.agg_shards = 2;
        cfg.tuning.persistent_pipeline = false;
        let spawned = run_experiment(&cfg).unwrap();
        cfg.tuning.persistent_pipeline = true;
        let resident = run_experiment(&cfg).unwrap();

        assert_eq!(spawned.rounds.len(), resident.rounds.len(), "{method}");
        for (a, b) in spawned.rounds.iter().zip(&resident.rounds) {
            assert_eq!(a.round, b.round, "{method}");
            assert_eq!(a.kappa, b.kappa, "{method} round {}", a.round);
            assert_eq!(a.mean_bits, b.mean_bits, "{method} round {}", a.round);
            assert_eq!(a.train_loss, b.train_loss, "{method} round {}", a.round);
            assert_eq!(a.accuracy, b.accuracy, "{method} round {}", a.round);
            assert_eq!(a.agg_shards, 2, "{method}");
            assert_eq!(b.agg_shards, 2, "{method}");
        }
        assert_eq!(
            spawned.final_accuracy(),
            resident.final_accuracy(),
            "{method}"
        );
        // The pool counters are wired through: every round accounts its
        // leases (hits + misses covers at least the shard-lane splits).
        assert!(
            resident.rounds.iter().all(|r| r.pool_hits + r.pool_misses > 0),
            "{method}: pool accounting missing from RoundMetrics"
        );
    }
}

/// Strip wall-clock and scheduling-dependent fields (timings, per-worker
/// millisecond arrays, pool hit/miss splits, transit/backpressure counters)
/// from an experiment's JSON so the remainder is the deterministic record:
/// config, per-round κ / wire bits / loss / accuracy / fault counters.
fn scrub_nondeterministic(j: &mut deltamask::util::json::Json) {
    use deltamask::util::json::Json;
    const DROP: &[&str] = &[
        "wall_secs",
        "mean_enc_ms",
        "mean_dec_ms",
        "dec_kernel_ms",
        "dec_worker_ms",
        "shard_absorb_ms",
        "pool_hits",
        "pool_misses",
        "transit_secs",
        "backpressure_stalls",
    ];
    match j {
        Json::Obj(m) => {
            for key in DROP {
                m.remove(*key);
            }
            for v in m.values_mut() {
                scrub_nondeterministic(v);
            }
        }
        Json::Arr(v) => {
            for item in v.iter_mut() {
                scrub_nondeterministic(item);
            }
        }
        _ => {}
    }
}

/// The acceptance criterion for codecs 10–11: each sibling codec runs end
/// to end through the real experiment loop, its serial / worker-sharded /
/// dimension-sharded / round-resident trajectories are bitwise identical,
/// and a replay with the same seed reproduces the identical JSON metrics
/// (modulo wall-clock fields) under the full scaling stack.
#[test]
fn sibling_codecs_run_e2e_with_deterministic_trajectories() {
    for method in ["maskrn", "sparse-rsn"] {
        let mut cfg = base_cfg();
        cfg.method = method.into();
        cfg.rounds = 6;
        cfg.eval_every = 2;
        cfg.tuning.decode_workers = 1;
        cfg.tuning.agg_shards = 1;
        cfg.tuning.persistent_pipeline = false;
        let serial = run_experiment(&cfg).unwrap();
        cfg.tuning.decode_workers = 3;
        cfg.tuning.agg_shards = 2;
        let sharded = run_experiment(&cfg).unwrap();
        cfg.tuning.persistent_pipeline = true;
        let resident = run_experiment(&cfg).unwrap();

        for (label, other) in [("sharded", &sharded), ("resident", &resident)] {
            assert_eq!(serial.rounds.len(), other.rounds.len(), "{method} {label}");
            for (a, b) in serial.rounds.iter().zip(&other.rounds) {
                assert_eq!(a.round, b.round, "{method} {label}");
                assert_eq!(a.kappa, b.kappa, "{method} {label} round {}", a.round);
                assert_eq!(
                    a.mean_bits, b.mean_bits,
                    "{method} {label} round {}",
                    a.round
                );
                assert_eq!(
                    a.train_loss, b.train_loss,
                    "{method} {label} round {}",
                    a.round
                );
                assert_eq!(a.accuracy, b.accuracy, "{method} {label} round {}", a.round);
            }
            assert_eq!(
                serial.final_accuracy(),
                other.final_accuracy(),
                "{method} {label}"
            );
        }

        // Same seed ⇒ identical JSON metrics, scaling stack fully engaged.
        let replay = run_experiment(&cfg).unwrap();
        let mut want = resident.to_json();
        let mut got = replay.to_json();
        scrub_nondeterministic(&mut want);
        scrub_nondeterministic(&mut got);
        assert_eq!(
            got.to_string_compact(),
            want.to_string_compact(),
            "{method}: replay diverged"
        );

        // The run itself must be a real experiment: learning at sub-1 bpp.
        let acc = serial.final_accuracy();
        assert!(acc > 0.25, "{method}: accuracy {acc} shows no learning");
        let bpp = serial.avg_bpp();
        assert!(bpp > 0.0 && bpp < 1.0, "{method}: avg bpp {bpp}");
    }
}

#[cfg(feature = "xla")]
#[test]
fn xla_backend_end_to_end() {
    // The production path: AOT Pallas/JAX graphs through PJRT.
    let mut cfg = base_cfg();
    cfg.backend = BackendKind::Xla;
    cfg.rounds = 4;
    cfg.eval_every = 2;
    cfg.n_clients = 3;
    let res = run_experiment(&cfg).expect("run `make artifacts` first");
    assert!(res.final_accuracy() > 0.3, "acc {}", res.final_accuracy());
    assert!(res.avg_bpp() < 1.5);
}

#[cfg(feature = "xla")]
#[test]
fn xla_and_native_agree_on_trained_accuracy() {
    let mut cfg = base_cfg();
    cfg.rounds = 5;
    cfg.eval_every = 5;
    cfg.n_clients = 3;
    cfg.samples_per_client = 24;
    let native = run_experiment(&cfg).unwrap();
    cfg.backend = BackendKind::Xla;
    let xla = run_experiment(&cfg).unwrap();
    // Same seeds, same math (mod f32 associativity): accuracies land close.
    assert!(
        (native.final_accuracy() - xla.final_accuracy()).abs() < 0.15,
        "native {} vs xla {}",
        native.final_accuracy(),
        xla.final_accuracy()
    );
}

#[test]
fn head_init_variants_ordering() {
    // Table 5: LP ≥ FiT ≥ He.
    let mut accs = std::collections::HashMap::new();
    for (name, init) in [("lp", HeadInit::Lp), ("fit", HeadInit::Fit), ("he", HeadInit::He)] {
        let mut cfg = base_cfg();
        cfg.head_init = init;
        cfg.rounds = 10;
        cfg.eval_every = 5;
        let res = run_experiment(&cfg).unwrap();
        accs.insert(name, res.final_accuracy());
    }
    assert!(
        accs["lp"] >= accs["he"] - 0.05,
        "LP {} should beat He {}",
        accs["lp"],
        accs["he"]
    );
}
