//! The federated round loop (Alg. 1): client sampling, shared-seed mask
//! broadcast, parallel local training, update encode/decode with timing,
//! Bayesian/FedAvg aggregation and periodic global evaluation.

use super::client::ClientSession;
use super::data::{self, FederatedData};
use super::metrics::{ExperimentResult, RoundMetrics};
use super::server::MaskServer;
use super::ExperimentConfig;
use crate::compress::{DecodeCtx, EncodeCtx, UpdateCodec};
use crate::model::backend::{Backend, FtState, LpState, ModelParams};
use crate::model::{accuracy, init_params, kappa_schedule, sample_mask_seeded};
use crate::util::rng::Xoshiro256pp;
use crate::util::timer::Stopwatch;
use anyhow::{anyhow, Result};

/// Everything produced by one client in one round.
struct ClientRoundOutput {
    bytes: Vec<u8>,
    enc_secs: f64,
    loss: f32,
}

pub struct Runner<'a> {
    pub cfg: &'a ExperimentConfig,
    pub backend: &'a dyn Backend,
    pub params: ModelParams,
    pub data: FederatedData,
    pub sessions: Vec<ClientSession>,
    pub server: MaskServer,
    rng: Xoshiro256pp,
}

impl<'a> Runner<'a> {
    pub fn new(cfg: &'a ExperimentConfig, backend: &'a dyn Backend) -> Result<Self> {
        let arch = cfg.arch_config();
        let profile = data::profile(&cfg.dataset)
            .ok_or_else(|| anyhow!("unknown dataset {}", cfg.dataset))?;
        let data = data::generate(
            &profile,
            arch,
            cfg.n_clients,
            cfg.samples_per_client,
            cfg.test_samples,
            cfg.dirichlet_alpha,
            cfg.seed,
        );
        let params = init_params(arch, cfg.seed ^ 0x11_22);
        let sessions = (0..cfg.n_clients)
            .map(|id| ClientSession::new(id, arch.d(), cfg.seed))
            .collect();
        Ok(Self {
            cfg,
            backend,
            params,
            data,
            sessions,
            server: MaskServer::with_theta0(arch.d(), cfg.rho, cfg.theta0),
            rng: Xoshiro256pp::new(cfg.seed ^ 0x5e_1e_c7),
        })
    }

    /// §3.3 head initialization: `lp_rounds` federated rounds of linear
    /// probing (or He/FiT alternatives, Table 5). Returns the uplink bits
    /// this cost per client (counted into the stream like any update).
    pub fn init_head(&mut self) -> Result<f64> {
        let arch = self.params.cfg;
        match self.cfg.head_init {
            super::HeadInit::He => Ok(0.0),
            super::HeadInit::Lp => {
                let mut global = LpState::from_params(&self.params);
                let mut bits = 0.0;
                for round in 0..self.cfg.lp_rounds {
                    let mut deltas: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
                    for k in 0..self.cfg.n_clients {
                        // Enough local epochs that the paper's single LP
                        // round actually converges the head (good frozen
                        // features converge a linear probe quickly).
                        let (new_state, _) = self.sessions[k].local_probe(
                            self.backend,
                            &self.params,
                            &self.data.clients[k],
                            &global,
                            20,
                            round,
                        )?;
                        let dw: Vec<f32> = new_state
                            .head_w
                            .iter()
                            .zip(&global.head_w)
                            .map(|(a, b)| a - b)
                            .collect();
                        let db: Vec<f32> = new_state
                            .head_b
                            .iter()
                            .zip(&global.head_b)
                            .map(|(a, b)| a - b)
                            .collect();
                        deltas.push((dw, db));
                    }
                    let kf = deltas.len() as f32;
                    for (dw, db) in &deltas {
                        for (g, d) in global.head_w.iter_mut().zip(dw) {
                            *g += d / kf;
                        }
                        for (g, d) in global.head_b.iter_mut().zip(db) {
                            *g += d / kf;
                        }
                    }
                    bits += 32.0 * (arch.c * arch.f + arch.c) as f64;
                }
                self.params.head_w = global.head_w;
                self.params.head_b = global.head_b;
                self.params.head_version += 1;
                Ok(bits)
            }
            super::HeadInit::Fit => {
                // FiT-LDA (Shysheya et al. 2022): Gaussian-LDA head from
                // client class statistics. Clients send per-class feature
                // sums + counts (counted below); the server forms
                // w_c = μ_c/σ², b_c = −‖μ_c‖²/(2σ²) + log π_c.
                let f = arch.f;
                let c = arch.c;
                let ones = vec![1.0f32; arch.d()];
                let mut sums = vec![0.0f64; c * f];
                let mut counts = vec![0.0f64; c];
                let mut sq_sum = 0.0f64;
                let mut n_total = 0.0f64;
                for k in 0..self.cfg.n_clients {
                    let cd = &self.data.clients[k];
                    // Feature = backbone output h_L (mask ≡ 1). Obtained via
                    // eval-forward against a zero head? The eval graph
                    // returns logits, so use the native forward here — the
                    // frozen weights are identical across backends.
                    let feats = native_features(&self.params, cd, &ones)?;
                    for (i, &y) in cd.y.iter().enumerate() {
                        counts[y as usize] += 1.0;
                        n_total += 1.0;
                        for j in 0..f {
                            let v = feats[i * f + j] as f64;
                            sums[y as usize * f + j] += v;
                            sq_sum += v * v;
                        }
                    }
                }
                let mut mean_norm_sq = 0.0f64;
                for cls in 0..c {
                    let n = counts[cls].max(1.0);
                    for j in 0..f {
                        sums[cls * f + j] /= n;
                    }
                }
                // Shared isotropic variance estimate.
                let mut within = sq_sum / (n_total * f as f64).max(1.0);
                for cls in 0..c {
                    let mut ns = 0.0;
                    for j in 0..f {
                        ns += sums[cls * f + j] * sums[cls * f + j];
                    }
                    mean_norm_sq += ns / c as f64;
                }
                within = (within - mean_norm_sq / f as f64).max(1e-3);
                for cls in 0..c {
                    let prior = ((counts[cls].max(0.5)) / n_total.max(1.0)).ln();
                    let mut nsq = 0.0f64;
                    for j in 0..f {
                        let mu = sums[cls * f + j];
                        nsq += mu * mu;
                        self.params.head_w[cls * f + j] = (mu / within) as f32;
                    }
                    self.params.head_b[cls] = (-(nsq) / (2.0 * within) + prior) as f32;
                }
                self.params.head_version += 1;
                // Uplink: per-class sums (C·F floats) + counts (C).
                Ok(32.0 * (c * f + c) as f64)
            }
        }
    }

    /// Run the full federated experiment with the given codec.
    pub fn run_codec(&mut self, codec: &dyn UpdateCodec) -> Result<ExperimentResult> {
        let arch = self.params.cfg;
        let d = arch.d();
        let sw = Stopwatch::new();
        let head_bits = self.init_head()?;
        let mut rounds = Vec::with_capacity(self.cfg.rounds);

        for round in 0..self.cfg.rounds {
            self.server.begin_round();
            let kappa = kappa_schedule(self.cfg.kappa0, round, self.cfg.rounds, self.cfg.kappa_floor);
            let round_seed = self.cfg.seed ^ (round as u64).wrapping_mul(0xa076_1d64_78bd_642f);

            // Shared-seed global binary mask (identical on all parties).
            let mut mask_g = Vec::new();
            sample_mask_seeded(&self.server.theta_g, round_seed, &mut mask_g);

            // Participant sampling.
            let k = ((self.cfg.rho * self.cfg.n_clients as f64).round() as usize)
                .clamp(1, self.cfg.n_clients);
            let participants = self.rng.choose(self.cfg.n_clients, k);

            // Local training + encode (parallel over participants).
            let theta_g = self.server.theta_g.clone();
            let s_g = self.server.s_g.clone();
            let outputs = self.run_clients_parallel(
                &participants,
                codec,
                &theta_g,
                &s_g,
                &mask_g,
                kappa,
                round,
                round_seed,
            )?;

            // Server-side decode + aggregate (timed).
            let mut updates = Vec::with_capacity(outputs.len());
            let mut dec_secs = 0.0;
            let mut enc_secs = 0.0;
            let mut bits = 0.0;
            let mut loss = 0.0;
            for (i, out) in outputs.iter().enumerate() {
                let dctx = DecodeCtx {
                    d,
                    mask_g: &mask_g,
                    s_g: &self.server.s_g,
                    seed: round_seed ^ participants[i] as u64,
                };
                let t = Stopwatch::new();
                updates.push(codec.decode(&out.bytes, &dctx)?);
                dec_secs += t.elapsed_secs();
                enc_secs += out.enc_secs;
                bits += out.bytes.len() as f64 * 8.0;
                loss += out.loss as f64;
            }
            let kf = outputs.len() as f64;
            self.server.aggregate(&updates);

            // Periodic evaluation of the global model.
            let acc = if (round + 1) % self.cfg.eval_every == 0
                || round + 1 == self.cfg.rounds
            {
                Some(self.eval_global(round_seed)?)
            } else {
                None
            };
            rounds.push(RoundMetrics {
                round,
                kappa,
                mean_bits: bits / kf,
                mean_bpp: (bits / kf) / d as f64,
                enc_ms_mean: enc_secs / kf * 1e3,
                dec_ms_mean: dec_secs / kf * 1e3,
                train_loss: loss / kf,
                accuracy: acc,
            });
        }
        Ok(self.result_with_head(rounds, head_bits, sw.elapsed_secs()))
    }

    #[allow(clippy::too_many_arguments)]
    fn run_clients_parallel(
        &mut self,
        participants: &[usize],
        codec: &dyn UpdateCodec,
        theta_g: &[f32],
        s_g: &[f32],
        mask_g: &[f32],
        kappa: f64,
        round: usize,
        round_seed: u64,
    ) -> Result<Vec<ClientRoundOutput>> {
        let cfg = self.cfg;
        let backend = self.backend;
        let params = &self.params;
        let data = &self.data;
        let d = params.cfg.d();

        // Move the participating sessions out so threads own them.
        let mut picked: Vec<(usize, ClientSession)> = Vec::with_capacity(participants.len());
        for &id in participants {
            let placeholder = ClientSession::new(id, 0, 0);
            let sess = std::mem::replace(&mut self.sessions[id], placeholder);
            picked.push((id, sess));
        }

        let n_threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(picked.len())
            .max(1);

        let results: Vec<(usize, ClientSession, Result<ClientRoundOutput>)> =
            std::thread::scope(|scope| {
                let mut handles = Vec::new();
                let chunks: Vec<Vec<(usize, ClientSession)>> = {
                    let mut cs: Vec<Vec<(usize, ClientSession)>> =
                        (0..n_threads).map(|_| Vec::new()).collect();
                    for (i, item) in picked.into_iter().enumerate() {
                        cs[i % n_threads].push(item);
                    }
                    cs
                };
                for chunk in chunks {
                    handles.push(scope.spawn(move || {
                        let mut out = Vec::new();
                        for (id, mut sess) in chunk {
                            let res = (|| {
                                let (theta_k, loss) = sess.local_train_opts(
                                    backend,
                                    params,
                                    &data.clients[id],
                                    theta_g,
                                    cfg.local_epochs,
                                    round,
                                    codec.resync_scores(),
                                )?;
                                // Common-random-numbers sampling: m^{k,t}
                                // uses the SAME public per-round uniforms as
                                // m^{g,t-1}, so Δ only contains coordinates
                                // whose probability moved across u_i — the
                                // "inherent sparsity in consecutive mask
                                // updates" (§3.2) that DeltaMask exploits.
                                let mut mask_k = Vec::new();
                                crate::model::sample_mask_seeded(
                                    &theta_k, round_seed, &mut mask_k,
                                );
                                let ctx = EncodeCtx {
                                    d,
                                    theta_k: &theta_k,
                                    theta_g,
                                    mask_k: &mask_k,
                                    mask_g,
                                    s_k: &sess.mask_state.s,
                                    s_g,
                                    kappa,
                                    seed: round_seed ^ id as u64,
                                };
                                let t = Stopwatch::new();
                                let enc = codec.encode(&ctx)?;
                                Ok(ClientRoundOutput {
                                    bytes: enc.bytes,
                                    enc_secs: t.elapsed_secs(),
                                    loss,
                                })
                            })();
                            out.push((id, sess, res));
                        }
                        out
                    }));
                }
                handles
                    .into_iter()
                    .flat_map(|h| h.join().expect("client thread panicked"))
                    .collect()
            });

        // Restore sessions in participant order and collect outputs.
        let mut by_id: std::collections::BTreeMap<usize, ClientRoundOutput> =
            std::collections::BTreeMap::new();
        for (id, sess, res) in results {
            self.sessions[id] = sess;
            by_id.insert(id, res?);
        }
        Ok(participants
            .iter()
            .map(|id| by_id.remove(id).expect("missing client output"))
            .collect())
    }

    /// Evaluate the global model with the posterior-mean (expected) mask
    /// θ^{g} — the deterministic Bayesian point estimate (sampled-mask
    /// evaluation is available via [`eval_sampled`]).
    pub fn eval_global(&self, _round_seed: u64) -> Result<f64> {
        self.eval_mask(&self.server.theta_g.clone())
    }

    /// Stochastic-mask evaluation m ~ Bern(θ^{g}) (FedPM-style).
    pub fn eval_sampled(&self, seed: u64) -> Result<f64> {
        let mut mask = Vec::new();
        sample_mask_seeded(&self.server.theta_g, seed ^ 0xe0a1, &mut mask);
        self.eval_mask(&mask)
    }

    pub fn eval_mask(&self, mask: &[f32]) -> Result<f64> {
        let arch = self.params.cfg;
        let test = &self.data.test;
        let n = test.len();
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut pos = 0usize;
        let mut xbuf = vec![0.0f32; arch.b * arch.f];
        while pos < n {
            let take = (n - pos).min(arch.b);
            for row in 0..arch.b {
                let src = pos + (row % take);
                xbuf[row * arch.f..(row + 1) * arch.f]
                    .copy_from_slice(&test.x[src * arch.f..(src + 1) * arch.f]);
            }
            let logits = self.backend.eval_logits(&self.params, mask, &xbuf)?;
            let labels: Vec<u32> = (0..take).map(|r| test.y[pos + r]).collect();
            let (c, t) = accuracy(&logits, &labels, arch.c, take);
            correct += c;
            total += t;
            pos += take;
        }
        Ok(correct as f64 / total.max(1) as f64)
    }

    fn result(&self, rounds: Vec<RoundMetrics>, wall: f64) -> ExperimentResult {
        self.result_with_head(rounds, 0.0, wall)
    }

    fn result_with_head(
        &self,
        rounds: Vec<RoundMetrics>,
        head_init_bits: f64,
        wall: f64,
    ) -> ExperimentResult {
        ExperimentResult {
            method: self.cfg.method.clone(),
            dataset: self.cfg.dataset.clone(),
            arch: self.cfg.arch.clone(),
            n_clients: self.cfg.n_clients,
            rho: self.cfg.rho,
            dirichlet_alpha: self.cfg.dirichlet_alpha,
            d: self.params.cfg.d(),
            rounds,
            head_init_bits,
            wall_secs: wall,
        }
    }

    // -----------------------------------------------------------------
    // Weight-space baselines (Tables 2/3 "Fine-tuning" / "Linear Probing")
    // -----------------------------------------------------------------

    /// Federated fine-tuning at 32 bpp: clients send raw weight deltas.
    pub fn run_finetuning(&mut self) -> Result<ExperimentResult> {
        let arch = self.params.cfg;
        let d = arch.d();
        let sw = Stopwatch::new();
        let mut global = FtState::from_params(&self.params);
        let mut rounds = Vec::new();
        let head_len = arch.c * arch.f + arch.c;
        for round in 0..self.cfg.rounds {
            let k = ((self.cfg.rho * self.cfg.n_clients as f64).round() as usize)
                .clamp(1, self.cfg.n_clients);
            let participants = self.rng.choose(self.cfg.n_clients, k);
            let mut sum_wb = vec![0.0f32; global.w_blocks.len()];
            let mut sum_hw = vec![0.0f32; global.head_w.len()];
            let mut sum_hb = vec![0.0f32; global.head_b.len()];
            let mut loss = 0.0f64;
            for &id in &participants {
                let mut sess = std::mem::replace(
                    &mut self.sessions[id],
                    ClientSession::new(id, 0, 0),
                );
                let (state, l) = sess.local_finetune(
                    self.backend,
                    &self.params,
                    &self.data.clients[id],
                    &global,
                    self.cfg.local_epochs,
                    round,
                )?;
                for i in 0..sum_wb.len() {
                    sum_wb[i] += state.w_blocks[i] - global.w_blocks[i];
                }
                for i in 0..sum_hw.len() {
                    sum_hw[i] += state.head_w[i] - global.head_w[i];
                }
                for i in 0..sum_hb.len() {
                    sum_hb[i] += state.head_b[i] - global.head_b[i];
                }
                loss += l as f64;
                self.sessions[id] = sess;
            }
            let kf = participants.len() as f32;
            for i in 0..sum_wb.len() {
                global.w_blocks[i] += sum_wb[i] / kf;
            }
            for i in 0..sum_hw.len() {
                global.head_w[i] += sum_hw[i] / kf;
            }
            for i in 0..sum_hb.len() {
                global.head_b[i] += sum_hb[i] / kf;
            }
            let acc = if (round + 1) % self.cfg.eval_every == 0
                || round + 1 == self.cfg.rounds
            {
                Some(self.eval_ft(&global)?)
            } else {
                None
            };
            let bits = 32.0 * (d + head_len) as f64;
            rounds.push(RoundMetrics {
                round,
                kappa: 0.0,
                mean_bits: bits,
                mean_bpp: bits / d as f64,
                enc_ms_mean: 0.0,
                dec_ms_mean: 0.0,
                train_loss: loss / participants.len() as f64,
                accuracy: acc,
            });
        }
        Ok(self.result(rounds, sw.elapsed_secs()))
    }

    fn eval_ft(&self, global: &FtState) -> Result<f64> {
        let arch = self.params.cfg;
        let test = &self.data.test;
        let n = test.len();
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut pos = 0usize;
        let mut xbuf = vec![0.0f32; arch.b * arch.f];
        while pos < n {
            let take = (n - pos).min(arch.b);
            for row in 0..arch.b {
                let src = pos + (row % take);
                xbuf[row * arch.f..(row + 1) * arch.f]
                    .copy_from_slice(&test.x[src * arch.f..(src + 1) * arch.f]);
            }
            let logits = self.backend.ft_eval_logits(&self.params, global, &xbuf)?;
            let labels: Vec<u32> = (0..take).map(|r| test.y[pos + r]).collect();
            let (c, t) = accuracy(&logits, &labels, arch.c, take);
            correct += c;
            total += t;
            pos += take;
        }
        Ok(correct as f64 / total.max(1) as f64)
    }

    /// Federated linear probing: head-only training, mask ≡ 1.
    pub fn run_linear_probing(&mut self) -> Result<ExperimentResult> {
        let arch = self.params.cfg;
        let d = arch.d();
        let sw = Stopwatch::new();
        let mut global = LpState::from_params(&self.params);
        let head_len = arch.c * arch.f + arch.c;
        let mut rounds = Vec::new();
        for round in 0..self.cfg.rounds {
            let k = ((self.cfg.rho * self.cfg.n_clients as f64).round() as usize)
                .clamp(1, self.cfg.n_clients);
            let participants = self.rng.choose(self.cfg.n_clients, k);
            let mut sum_hw = vec![0.0f32; global.head_w.len()];
            let mut sum_hb = vec![0.0f32; global.head_b.len()];
            let mut loss = 0.0f64;
            for &id in &participants {
                let mut sess = std::mem::replace(
                    &mut self.sessions[id],
                    ClientSession::new(id, 0, 0),
                );
                let (state, l) = sess.local_probe(
                    self.backend,
                    &self.params,
                    &self.data.clients[id],
                    &global,
                    self.cfg.local_epochs,
                    round,
                )?;
                for i in 0..sum_hw.len() {
                    sum_hw[i] += state.head_w[i] - global.head_w[i];
                }
                for i in 0..sum_hb.len() {
                    sum_hb[i] += state.head_b[i] - global.head_b[i];
                }
                loss += l as f64;
                self.sessions[id] = sess;
            }
            let kf = participants.len() as f32;
            for i in 0..sum_hw.len() {
                global.head_w[i] += sum_hw[i] / kf;
            }
            for i in 0..sum_hb.len() {
                global.head_b[i] += sum_hb[i] / kf;
            }
            let acc = if (round + 1) % self.cfg.eval_every == 0
                || round + 1 == self.cfg.rounds
            {
                let mut p = self.params.clone();
                p.head_w = global.head_w.clone();
                p.head_b = global.head_b.clone();
                p.head_version += round as u64 + 1;
                let ones = vec![1.0f32; d];
                Some(eval_with_params(self.backend, &p, &self.data, &ones)?)
            } else {
                None
            };
            let bits = 32.0 * head_len as f64;
            rounds.push(RoundMetrics {
                round,
                kappa: 0.0,
                mean_bits: bits,
                mean_bpp: bits / d as f64,
                enc_ms_mean: 0.0,
                dec_ms_mean: 0.0,
                train_loss: loss / participants.len() as f64,
                accuracy: acc,
            });
        }
        Ok(self.result(rounds, sw.elapsed_secs()))
    }
}

/// Evaluate arbitrary params (used by the LP baseline with a swapped head).
fn eval_with_params(
    backend: &dyn Backend,
    params: &ModelParams,
    data: &FederatedData,
    mask: &[f32],
) -> Result<f64> {
    let arch = params.cfg;
    let test = &data.test;
    let n = test.len();
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut pos = 0usize;
    let mut xbuf = vec![0.0f32; arch.b * arch.f];
    while pos < n {
        let take = (n - pos).min(arch.b);
        for row in 0..arch.b {
            let src = pos + (row % take);
            xbuf[row * arch.f..(row + 1) * arch.f]
                .copy_from_slice(&test.x[src * arch.f..(src + 1) * arch.f]);
        }
        let logits = backend.eval_logits(params, mask, &xbuf)?;
        let labels: Vec<u32> = (0..take).map(|r| test.y[pos + r]).collect();
        let (c, t) = accuracy(&logits, &labels, arch.c, take);
        correct += c;
        total += t;
        pos += take;
    }
    Ok(correct as f64 / total.max(1) as f64)
}

/// Native forward to the last hidden layer (FiT-LDA statistics).
fn native_features(
    params: &ModelParams,
    data: &super::data::ClientData,
    mask: &[f32],
) -> Result<Vec<f32>> {
    use crate::native::linalg::matmul_bt;
    let cfg = params.cfg;
    let f = cfg.f;
    let n = data.len();
    let mut h = data.x.clone();
    let mut mw = vec![0.0f32; f * f];
    let mut z = vec![0.0f32; n * f];
    for l in 0..cfg.l {
        let w = &params.w_blocks[l * f * f..(l + 1) * f * f];
        let m = &mask[l * f * f..(l + 1) * f * f];
        for i in 0..f * f {
            mw[i] = w[i] * m[i];
        }
        matmul_bt(&h, &mw, &mut z, n, f, f);
        for i in 0..n * f {
            h[i] += z[i].max(0.0);
        }
    }
    Ok(h)
}
