//! Quickstart: fine-tune a simulated foundation model federatedly with
//! DeltaMask in under a minute on CPU, end-to-end through the production
//! path — AOT-compiled Pallas/JAX graphs executed from rust via PJRT.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! What happens:
//!   1. loads `artifacts/manifest.json` + the miniature `test` combo HLO,
//!   2. builds a federated CIFAR-10-like dataset (6 clients, IID),
//!   3. one linear-probing round initializes the head (§3.3),
//!   4. 12 DeltaMask rounds: stochastic mask training → KL-ranked top-κ
//!      deltas → binary fuse filter → grayscale PNG → Bayesian aggregation,
//!   5. prints accuracy and measured bits-per-parameter per round.

use deltamask::coordinator::PipelineMode;
use deltamask::fl::{run_experiment, BackendKind, ExperimentConfig, HeadInit, ServerTuning};

fn main() -> anyhow::Result<()> {
    let cfg = ExperimentConfig {
        dataset: "cifar10".into(),
        arch: "test".into(),
        method: "deltamask".into(),
        n_clients: 6,
        rounds: 12,
        rho: 1.0,
        local_epochs: 1,
        samples_per_client: 48,
        test_samples: 400,
        dirichlet_alpha: 10.0,
        kappa0: 0.8,
        kappa_floor: 0.25,
        seed: 7,
        eval_every: 3,
        backend: BackendKind::Xla, // the AOT Pallas/JAX path
        head_init: HeadInit::Lp,
        lp_rounds: 1,
        theta0: 0.85,
        arch_override: None,
        tuning: ServerTuning {
            pipeline: PipelineMode::Streaming, // decode→absorb per arrival
            decode_workers: 2,                 // shard the server decode sweep
            agg_shards: 2,                     // shard aggregation by dimension
            shard_place: String::new(),        // absorb lanes in-process (no remote workers)
            persistent_pipeline: true,         // spawn workers/lanes once, park between rounds
            quorum: 1.0,                       // strict: every planned client must report
            round_deadline_ms: 0,              // no drain deadline
            on_decode_error: Default::default(), // abort on undecodable records
        },
        chaos: String::new(),          // clean transport
        transport: Default::default(), // in-process channel uplink
    };

    println!(
        "DeltaMask quickstart: {} clients, {} rounds, d = {} mask params, backend = XLA/PJRT",
        cfg.n_clients,
        cfg.rounds,
        cfg.arch_config().d()
    );
    let res = run_experiment(&cfg)?;
    for r in &res.rounds {
        print!(
            "round {:2}  loss {:.3}  bpp {:5.2}  enc {:5.2} ms  dec {:5.2} ms",
            r.round, r.train_loss, r.mean_bpp, r.enc_ms_mean, r.dec_ms_mean
        );
        match r.accuracy {
            Some(acc) => println!("  acc {:.3}", acc),
            None => println!(),
        }
    }
    println!(
        "\nfinal accuracy {:.3} at avg {:.3} bits-per-parameter ({:.2} MiB total uplink/client)",
        res.final_accuracy(),
        res.avg_bpp(),
        res.total_uplink_mib()
    );
    println!("paper context: DeltaMask targets ≈0.1–0.25 bpp vs 1 bpp for FedPM-class methods.");
    Ok(())
}
