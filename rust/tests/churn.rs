//! Fault-tolerant round completion suite: quorum/deadline drain policies,
//! admission hardening (replays, duplicates, bad slots, in-band failures)
//! and the deterministic [`ChaosTransport`] — exercised across spawn and
//! round-resident drains, worker/shard shapes and both update families.
//!
//! The two load-bearing properties, asserted throughout:
//!
//!  * **Dormancy** — with chaos off, a relaxed policy (`quorum < 1`,
//!    deadline set) is bitwise-invisible: identical aggregator state and
//!    all-zero fault counters versus the strict reference.
//!  * **Degradation correctness** — a faulted round that meets quorum
//!    finishes bitwise-identical to a clean round over exactly the
//!    surviving cohort, and the same chaos seed reproduces the same
//!    fault counters on every run (what makes churn scenarios CI-able).
//!
//! Seeds for the chaos scenarios are *searched* (first seed under 10k
//! whose fate mix matches the scenario) rather than hand-picked, so the
//! tests state their own preconditions instead of depending on hash
//! accidents staying stable.

use deltamask::compress::{self, Encoded, ScratchPool, UpdateCodec};
use deltamask::coordinator::{
    drain_round, ChannelTransport, ChaosTransport, DrainConfig, DrainPipeline, DrainPolicy,
    DrainReport, FaultCounters, FaultPlan, FaultVerdict, OnDecodeError, Payload, PipelineMode,
    RoundEngine, RoundPlan, Transport, TransportKind, WireMessage,
};
use deltamask::fl::server::MaskServer;
use deltamask::fl::{run_experiment, BackendKind, ExperimentConfig, HeadInit, ServerTuning};
use deltamask::model::sample_mask_seeded;
use deltamask::util::rng::Xoshiro256pp;
use std::sync::Arc;

fn logit(p: f32) -> f32 {
    let p = p.clamp(1e-6, 1.0 - 1e-6);
    (p / (1.0 - p)).ln()
}

/// A plausible round for `codec`: drifted posteriors, shared-seed masks,
/// score mirrors — the same recipe as `agg_shards.rs` / `decode_workers.rs`.
fn encode_round(name: &str, plan: &RoundPlan, rng: &mut Xoshiro256pp) -> Vec<Encoded> {
    let codec = compress::by_name(name).unwrap();
    let mut encs = Vec::new();
    for slot in 0..plan.expected() {
        let theta_k: Vec<f32> = plan
            .theta_g
            .iter()
            .map(|&p| (p + 0.3 * (rng.next_f32() - 0.5)).clamp(0.01, 0.99))
            .collect();
        let s_k: Vec<f32> = theta_k.iter().map(|&p| logit(p)).collect();
        let mut mask_k = Vec::new();
        sample_mask_seeded(&theta_k, plan.seed, &mut mask_k);
        let ectx = plan.encode_ctx(slot, &theta_k, &mask_k, &s_k);
        encs.push(codec.encode(&ectx).unwrap_or_else(|e| panic!("{name}: {e}")));
    }
    encs
}

fn round_fixture(name: &str, d: usize, k: usize, trial: u64) -> (Arc<RoundPlan>, Vec<Encoded>) {
    let mut rng = Xoshiro256pp::new(0xC4A0 ^ trial.wrapping_mul(0x9e37_79b9));
    let theta_g: Vec<f32> = (0..d).map(|_| 0.05 + 0.9 * rng.next_f32()).collect();
    let s_g: Vec<f32> = theta_g.iter().map(|&p| logit(p)).collect();
    let mut engine = RoundEngine::new(trial, k, 1.0, 0.8, 0.25, 3);
    let plan = engine.plan(0, &theta_g, &s_g);
    let encs = encode_round(name, &plan, &mut rng);
    (Arc::new(plan), encs)
}

/// Well-formed update messages for the given slots, in the given order.
fn updates(plan: &RoundPlan, encs: &[Encoded], slots: &[usize]) -> Vec<WireMessage> {
    slots
        .iter()
        .map(|&slot| WireMessage {
            round: plan.round,
            client_id: plan.participants[slot],
            slot,
            payload: Payload::Update(encs[slot].clone()),
            enc_secs: 0.125 * (slot as f64 + 1.0),
            loss: 0.5 + slot as f32,
        })
        .collect()
}

/// A pre-filled, already-closed uplink carrying exactly `msgs`.
fn send_msgs(msgs: Vec<WireMessage>) -> ChannelTransport {
    let (channel, sender) = ChannelTransport::new();
    for m in msgs {
        sender.send(m).unwrap();
    }
    drop(sender);
    channel
}

fn policy(quorum: f64, deadline_ms: u64) -> DrainPolicy {
    DrainPolicy {
        quorum,
        deadline_ms,
        on_decode_error: OnDecodeError::Abort,
    }
}

/// First seed under 10k whose fault plan satisfies the scenario predicate.
fn find_plan(build: impl Fn(u64) -> FaultPlan, ok: impl Fn(&FaultPlan) -> bool) -> FaultPlan {
    for seed in 0..10_000 {
        let plan = build(seed);
        if ok(&plan) {
            return plan;
        }
    }
    panic!("no chaos seed under 10_000 produces the required fate mix");
}

fn slots_with(plan: &RoundPlan, fault: &FaultPlan, want: FaultVerdict) -> Vec<usize> {
    (0..plan.expected())
        .filter(|&s| fault.verdict(plan.round, plan.participants[s]) == want)
        .collect()
}

/// Slots whose record is eventually absorbed under an infinite-patience
/// drain: delivered on time or straggling in after the uplink closes.
fn surviving_slots(plan: &RoundPlan, fault: &FaultPlan) -> Vec<usize> {
    (0..plan.expected())
        .filter(|&s| {
            matches!(
                fault.verdict(plan.round, plan.participants[s]),
                FaultVerdict::Deliver | FaultVerdict::Straggle
            )
        })
        .collect()
}

/// Drain one round into a fresh server via the per-round-spawn path
/// (`shards > 1` goes through a sharded view, stitched back on success).
fn drain_into(
    name: &str,
    plan: &RoundPlan,
    transport: &mut dyn Transport,
    mode: PipelineMode,
    workers: usize,
    shards: usize,
    policy: DrainPolicy,
) -> anyhow::Result<(MaskServer, DrainReport)> {
    let codec = compress::by_name(name).unwrap();
    let mut server = MaskServer::with_theta0(plan.d(), 1.0, 0.85);
    let pool = ScratchPool::new();
    if shards <= 1 {
        let report = drain_round(
            transport,
            plan,
            codec.as_ref(),
            &mut server,
            DrainConfig::new(mode, workers).with_policy(policy),
            &pool,
        )?;
        Ok((server, report))
    } else {
        let mut view = server.shard_view(shards);
        let report = drain_round(
            transport,
            plan,
            codec.as_ref(),
            &mut view,
            DrainConfig::sharded(mode, workers, shards).with_policy(policy),
            &pool,
        )?;
        server.adopt_shards(view);
        Ok((server, report))
    }
}

/// Same round through a round-resident [`DrainPipeline`].
fn drain_resident(
    name: &str,
    plan: &Arc<RoundPlan>,
    transport: &mut dyn Transport,
    workers: usize,
    shards: usize,
    policy: DrainPolicy,
) -> anyhow::Result<(MaskServer, DrainReport)> {
    let codec: Arc<dyn UpdateCodec> = Arc::from(compress::by_name(name).unwrap());
    let pipeline = DrainPipeline::new(
        DrainConfig::sharded(PipelineMode::Streaming, workers, shards).with_policy(policy),
    );
    let mut server = MaskServer::with_theta0(plan.d(), 1.0, 0.85);
    if shards <= 1 {
        let report = pipeline.drain_round(transport, plan, &codec, &mut server)?;
        Ok((server, report))
    } else {
        let mut view = server.shard_view(shards);
        let report = pipeline.drain_round(transport, plan, &codec, &mut view)?;
        server.adopt_shards(view);
        Ok((server, report))
    }
}

/// With chaos off, a relaxed completion policy (quorum 0.5, 60s deadline)
/// must be bitwise-invisible: same aggregator state as the strict
/// reference and clean fault counters — for all 11 codecs, both pipeline
/// modes, and both drain shapes.
#[test]
fn relaxed_policy_is_dormant_on_clean_rounds() {
    let d = 512;
    for (trial, name) in compress::all_names().iter().enumerate() {
        let k = 3 + trial % 3;
        let (plan, encs) = round_fixture(name, d, k, trial as u64 + 1);
        let slots: Vec<usize> = (0..k).rev().collect();
        for mode in [PipelineMode::Batch, PipelineMode::Streaming] {
            for (workers, shards) in [(1usize, 1usize), (3, 2)] {
                let tag = format!("{name} {mode:?} workers={workers} shards={shards}");
                let mut strict_ch = send_msgs(updates(&plan, &encs, &slots));
                let (strict, s_rep) = drain_into(
                    name,
                    &plan,
                    &mut strict_ch,
                    mode,
                    workers,
                    shards,
                    DrainPolicy::strict(),
                )
                .unwrap_or_else(|e| panic!("{tag}: {e}"));
                let mut relaxed_ch = send_msgs(updates(&plan, &encs, &slots));
                let (relaxed, r_rep) = drain_into(
                    name,
                    &plan,
                    &mut relaxed_ch,
                    mode,
                    workers,
                    shards,
                    policy(0.5, 60_000),
                )
                .unwrap_or_else(|e| panic!("{tag}: {e}"));
                assert_eq!(strict.theta_g, relaxed.theta_g, "{tag}: theta_g diverged");
                assert_eq!(strict.s_g, relaxed.s_g, "{tag}: s_g diverged");
                for rep in [&s_rep, &r_rep] {
                    assert_eq!(
                        rep.faults,
                        FaultCounters {
                            received: k as u64,
                            accepted: k as u64,
                            ..FaultCounters::default()
                        },
                        "{tag}"
                    );
                    assert!(rep.quorum_met && !rep.degraded, "{tag}");
                }
            }
        }
    }
}

/// Degradation correctness: a chaos round (drops + mid-round deaths) that
/// still meets quorum finishes bitwise-identical to a clean round in which
/// the non-survivors simply never report — for every codec (both update
/// families), spawn worker/shard shapes, and the resident pipeline.
#[test]
fn degraded_round_matches_clean_drain_over_the_surviving_cohort() {
    let d = 512;
    let k = 5;
    for (trial, name) in compress::all_names().iter().enumerate() {
        let (plan, encs) = round_fixture(name, d, k, 31 + trial as u64);
        let fault = find_plan(
            |seed| FaultPlan::parse(&format!("seed={seed},drop=0.35,die=0.25")).unwrap(),
            |f| {
                surviving_slots(&plan, f).len() >= 2
                    && !slots_with(&plan, f, FaultVerdict::Die).is_empty()
                    && !slots_with(&plan, f, FaultVerdict::Drop).is_empty()
            },
        );
        let dies = slots_with(&plan, &fault, FaultVerdict::Die).len() as u64;
        let alive = surviving_slots(&plan, &fault);
        let relaxed = policy(0.25, 0);
        let all: Vec<usize> = (0..k).collect();

        // Oracle: same plan, clean uplink, only the survivors report.
        let mut oracle_ch = send_msgs(updates(&plan, &encs, &alive));
        let (oracle, o_rep) = drain_into(
            name,
            &plan,
            &mut oracle_ch,
            PipelineMode::Streaming,
            1,
            1,
            relaxed,
        )
        .unwrap();
        assert_eq!(o_rep.faults.missing, (k - alive.len()) as u64, "{name} oracle");

        for (workers, shards) in [(1usize, 1usize), (3, 1), (1, 3), (3, 4)] {
            let tag = format!("{name} workers={workers} shards={shards}");
            let mut chaos = ChaosTransport::new(send_msgs(updates(&plan, &encs, &all)), fault);
            let (faulted, rep) = drain_into(
                name,
                &plan,
                &mut chaos,
                PipelineMode::Streaming,
                workers,
                shards,
                relaxed,
            )
            .unwrap_or_else(|e| panic!("{tag}: {e}"));
            assert_eq!(oracle.theta_g, faulted.theta_g, "{tag}: theta_g diverged");
            assert_eq!(oracle.s_g, faulted.s_g, "{tag}: s_g diverged");
            assert!(rep.degraded && rep.quorum_met, "{tag}");
            assert_eq!(rep.faults.missing, (k - alive.len()) as u64, "{tag}");
            assert_eq!(rep.faults.failed, dies, "{tag}");
            assert_eq!(rep.faults.accepted, alive.len() as u64, "{tag}");
        }

        // Round-resident shape, one codec per update family.
        if ["deltamask", "fedpm"].contains(name) {
            let mut chaos = ChaosTransport::new(send_msgs(updates(&plan, &encs, &all)), fault);
            let (resident, rep) =
                drain_resident(name, &plan, &mut chaos, 2, 2, relaxed).unwrap();
            assert_eq!(oracle.theta_g, resident.theta_g, "{name} resident");
            assert_eq!(oracle.s_g, resident.s_g, "{name} resident");
            assert!(rep.degraded && rep.quorum_met, "{name} resident");
        }
    }
}

/// Reproducibility, exactly: every fault class firing at once (duplicates
/// on everything, reorder, drops, stragglers, corruption under the skip
/// policy, deaths) produces fault counters that (a) match the counts
/// predicted from the fault plan's verdicts, (b) are identical across two
/// runs of the same seed, and (c) still leave the aggregator bitwise
/// equal to the clean drain over the absorbed cohort.
#[test]
fn chaos_fault_counters_are_reproducible_and_exact() {
    let d = 384;
    let k = 10;
    let (plan, encs) = round_fixture("deltamask", d, k, 57);
    let fault = find_plan(
        |seed| {
            FaultPlan::parse(&format!(
                "seed={seed},dup=1.0,reorder=0.4,drop=0.2,straggle=0.2,corrupt=0.25,die=0.15"
            ))
            .unwrap()
        },
        |f| {
            [
                FaultVerdict::Deliver,
                FaultVerdict::Drop,
                FaultVerdict::Straggle,
                FaultVerdict::Corrupt,
                FaultVerdict::Die,
            ]
            .iter()
            .all(|&v| !slots_with(&plan, f, v).is_empty())
        },
    );
    let deliver = slots_with(&plan, &fault, FaultVerdict::Deliver).len() as u64;
    let straggle = slots_with(&plan, &fault, FaultVerdict::Straggle).len() as u64;
    let corrupt = slots_with(&plan, &fault, FaultVerdict::Corrupt).len() as u64;
    let die = slots_with(&plan, &fault, FaultVerdict::Die).len() as u64;
    // Stragglers bypass the duplicate stage (they are withheld whole), so
    // dup=1.0 doubles exactly the on-time deliveries: second copies of
    // updates count as duplicates, second copies of failure reports as
    // failures. Corrupt records are admitted (first copy) then skipped at
    // decode, so they count in `accepted` + `corrupt` but stay missing.
    let expect = FaultCounters {
        received: 2 * (deliver + corrupt + die) + straggle,
        accepted: deliver + straggle + corrupt,
        duplicates: deliver + corrupt,
        stale: 0,
        bad_slot: 0,
        failed: 2 * die,
        corrupt,
        late: 0,
        missing: k as u64 - deliver - straggle,
    };
    let skip = DrainPolicy {
        quorum: 0.1,
        deadline_ms: 0,
        on_decode_error: OnDecodeError::Skip,
    };
    let all: Vec<usize> = (0..k).collect();
    let run = || {
        let mut chaos = ChaosTransport::new(send_msgs(updates(&plan, &encs, &all)), fault);
        drain_into(
            "deltamask",
            &plan,
            &mut chaos,
            PipelineMode::Streaming,
            1,
            1,
            skip,
        )
        .unwrap()
    };
    let (server_a, rep_a) = run();
    let (server_b, rep_b) = run();
    assert_eq!(rep_a.faults, expect);
    assert_eq!(
        rep_a.faults, rep_b.faults,
        "same chaos seed must produce identical fault counters"
    );
    assert_eq!(server_a.theta_g, server_b.theta_g);
    assert_eq!(server_a.s_g, server_b.s_g);
    assert!(rep_a.degraded && rep_a.quorum_met);

    let alive = surviving_slots(&plan, &fault);
    let mut oracle_ch = send_msgs(updates(&plan, &encs, &alive));
    let (oracle, _) = drain_into(
        "deltamask",
        &plan,
        &mut oracle_ch,
        PipelineMode::Streaming,
        1,
        1,
        skip,
    )
    .unwrap();
    assert_eq!(oracle.theta_g, server_a.theta_g);
    assert_eq!(oracle.s_g, server_a.s_g);
}

/// An in-band `Payload::Failed` report degrades the round under a
/// satisfiable quorum (bitwise-identical to the survivors-only clean
/// drain, across serial / worker / sharded / resident shapes) and aborts
/// it under the strict policy with the client's root cause in the error.
#[test]
fn in_band_client_failure_degrades_or_aborts_by_policy() {
    let d = 512;
    let k = 4;
    for name in ["deltamask", "fedpm"] {
        let (plan, encs) = round_fixture(name, d, k, 7);
        let good = [0usize, 1, 3];
        let dead_id = plan.participants[2];
        let mut msgs = updates(&plan, &encs, &good);
        msgs.insert(
            2,
            WireMessage {
                round: plan.round,
                client_id: dead_id,
                slot: 2,
                payload: Payload::Failed("client oom".into()),
                enc_secs: 0.0,
                loss: 0.0,
            },
        );
        let relaxed = policy(0.75, 0);
        let mut oracle_ch = send_msgs(updates(&plan, &encs, &good));
        let (oracle, _) = drain_into(
            name,
            &plan,
            &mut oracle_ch,
            PipelineMode::Streaming,
            1,
            1,
            relaxed,
        )
        .unwrap();

        for (workers, shards) in [(1usize, 1usize), (3, 1), (1, 3), (3, 4)] {
            let tag = format!("{name} workers={workers} shards={shards}");
            let mut ch = send_msgs(msgs.clone());
            let (server, rep) = drain_into(
                name,
                &plan,
                &mut ch,
                PipelineMode::Streaming,
                workers,
                shards,
                relaxed,
            )
            .unwrap_or_else(|e| panic!("{tag}: {e}"));
            assert_eq!(oracle.theta_g, server.theta_g, "{tag}: theta_g diverged");
            assert_eq!(oracle.s_g, server.s_g, "{tag}: s_g diverged");
            assert!(rep.degraded && rep.quorum_met, "{tag}");
            assert_eq!(rep.faults.failed, 1, "{tag}");
            assert_eq!(rep.faults.missing, 1, "{tag}");
        }

        let mut ch = send_msgs(msgs.clone());
        let (resident, rep) = drain_resident(name, &plan, &mut ch, 2, 2, relaxed).unwrap();
        assert_eq!(oracle.theta_g, resident.theta_g, "{name} resident");
        assert_eq!(rep.faults.failed, 1, "{name} resident");

        // Strict policy: the shortfall error names the failed client.
        let mut ch = send_msgs(msgs);
        let err = drain_into(
            name,
            &plan,
            &mut ch,
            PipelineMode::Streaming,
            1,
            1,
            DrainPolicy::strict(),
        )
        .unwrap_err();
        let text = err.to_string();
        assert!(text.contains("uplink closed after 3/4"), "{name}: {text}");
        assert!(
            text.contains(&format!("client {dead_id} failed: client oom")),
            "{name}: {text}"
        );
    }
}

/// Admission hardening end-to-end: a duplicate delivery, a stale-round
/// replay and an out-of-range slot are each counted and dropped — the
/// strict round still completes (first record per slot wins) and the
/// aggregator is bitwise-identical to the garbage-free drain.
#[test]
fn replays_duplicates_and_bad_slots_are_counted_and_rejected() {
    let d = 512;
    let k = 3;
    let (plan, encs) = round_fixture("deltamask", d, k, 13);
    let all: Vec<usize> = (0..k).collect();
    let mut msgs = updates(&plan, &encs, &[0]);
    msgs.push(msgs[0].clone()); // duplicate delivery of slot 0
    let mut stale = msgs[0].clone();
    stale.round = plan.round + 7; // replay from another round
    msgs.push(stale);
    let mut rogue = msgs[0].clone();
    rogue.slot = 99; // out-of-range slot index
    msgs.push(rogue);
    msgs.extend(updates(&plan, &encs, &[1, 2]));

    let mut oracle_ch = send_msgs(updates(&plan, &encs, &all));
    let (oracle, _) = drain_into(
        "deltamask",
        &plan,
        &mut oracle_ch,
        PipelineMode::Streaming,
        1,
        1,
        DrainPolicy::strict(),
    )
    .unwrap();

    for (workers, shards) in [(1usize, 1usize), (3, 4)] {
        let tag = format!("workers={workers} shards={shards}");
        let mut ch = send_msgs(msgs.clone());
        let (server, rep) = drain_into(
            "deltamask",
            &plan,
            &mut ch,
            PipelineMode::Streaming,
            workers,
            shards,
            DrainPolicy::strict(),
        )
        .unwrap_or_else(|e| panic!("{tag}: {e}"));
        assert_eq!(oracle.theta_g, server.theta_g, "{tag}: theta_g diverged");
        assert_eq!(oracle.s_g, server.s_g, "{tag}: s_g diverged");
        assert!(rep.quorum_met && !rep.degraded, "{tag}");
        assert_eq!(
            rep.faults,
            FaultCounters {
                received: 6,
                accepted: 3,
                duplicates: 1,
                stale: 1,
                bad_slot: 1,
                ..FaultCounters::default()
            },
            "{tag}"
        );
    }
}

/// Deadline semantics without sleeping: stragglers withheld past the
/// uplink close surface as a timeout, the late sweep counts them (they
/// are never absorbed), and the round completes degraded over the
/// on-time cohort — bitwise-identical to a clean on-time-only drain.
#[test]
fn deadline_sweeps_stragglers_as_late_without_sleeping() {
    let d = 256;
    let k = 5;
    let (plan, encs) = round_fixture("deltamask", d, k, 91);
    let fault = find_plan(
        |seed| FaultPlan::parse(&format!("seed={seed},straggle=0.4")).unwrap(),
        |f| {
            let s = slots_with(&plan, f, FaultVerdict::Straggle).len();
            s >= 1 && k - s >= 2
        },
    );
    let ontime = slots_with(&plan, &fault, FaultVerdict::Deliver);
    let stragglers = (k - ontime.len()) as u64;
    let all: Vec<usize> = (0..k).collect();

    let start = std::time::Instant::now();
    let mut chaos = ChaosTransport::new(send_msgs(updates(&plan, &encs, &all)), fault);
    let (faulted, rep) = drain_into(
        "deltamask",
        &plan,
        &mut chaos,
        PipelineMode::Streaming,
        1,
        1,
        policy(0.2, 60_000),
    )
    .unwrap();
    assert!(
        start.elapsed() < std::time::Duration::from_secs(30),
        "the deadline drain must not sleep out its 60s budget"
    );
    assert_eq!(rep.faults.late, stragglers);
    assert_eq!(rep.faults.missing, stragglers);
    assert_eq!(rep.faults.accepted, ontime.len() as u64);
    assert!(rep.degraded && rep.quorum_met);

    let mut oracle_ch = send_msgs(updates(&plan, &encs, &ontime));
    let (oracle, _) = drain_into(
        "deltamask",
        &plan,
        &mut oracle_ch,
        PipelineMode::Streaming,
        1,
        1,
        policy(0.2, 0),
    )
    .unwrap();
    assert_eq!(oracle.theta_g, faulted.theta_g);
    assert_eq!(oracle.s_g, faulted.s_g);
}

/// A quorum shortfall mid-trajectory aborts that round cleanly and leaves
/// the SAME resident pipeline + shard view reusable: the following good
/// rounds drain through the same parked workers/lanes, bitwise-identical
/// to a serial replay of the good rounds only.
#[test]
fn aborted_shortfall_leaves_resident_pipeline_and_view_reusable() {
    let d = 512;
    let name = "deltamask";
    let codec: Arc<dyn UpdateCodec> = Arc::from(compress::by_name(name).unwrap());
    let pipeline = DrainPipeline::new(DrainConfig::sharded(PipelineMode::Streaming, 3, 4));
    let mut server = MaskServer::with_theta0(d, 1.0, 0.85);
    let mut view = server.shard_view(4);
    let mut oracle = MaskServer::with_theta0(d, 1.0, 0.85);
    let oracle_pool = ScratchPool::new();
    let serial_codec = compress::by_name(name).unwrap();
    let mut engine = RoundEngine::new(17, 4, 1.0, 0.8, 0.25, 3);
    let mut engine_o = RoundEngine::new(17, 4, 1.0, 0.8, 0.25, 3);
    for round in 0..3 {
        let plan = Arc::new(engine.plan(round, &server.theta_g, &server.s_g));
        let plan_o = engine_o.plan(round, &oracle.theta_g, &oracle.s_g);
        let mut rng = Xoshiro256pp::new(0xBEEF ^ round as u64);
        let encs = encode_round(name, &plan, &mut rng);
        let all: Vec<usize> = (0..plan.expected()).collect();
        if round == 1 {
            // Only one of four clients reports: the strict quorum aborts
            // the round...
            let mut ch = send_msgs(updates(&plan, &encs, &[0]));
            let err = pipeline
                .drain_round(&mut ch, &plan, &codec, &mut view)
                .unwrap_err();
            let text = err.to_string();
            assert!(text.contains("uplink closed after 1/4"), "{text}");
            // ...and the oracle skips it entirely (its engine still
            // consumed the round's sampling draw above).
            continue;
        }
        let mut ch = send_msgs(updates(&plan, &encs, &all));
        pipeline
            .drain_round(&mut ch, &plan, &codec, &mut view)
            .unwrap_or_else(|e| panic!("round {round}: {e}"));
        server.sync_from_shards(&view);

        let mut ch = send_msgs(updates(&plan_o, &encs, &all));
        drain_round(
            &mut ch,
            &plan_o,
            serial_codec.as_ref(),
            &mut oracle,
            DrainConfig::serial(PipelineMode::Streaming),
            &oracle_pool,
        )
        .unwrap_or_else(|e| panic!("oracle round {round}: {e}"));
        assert_eq!(server.theta_g, oracle.theta_g, "round {round}");
        assert_eq!(server.s_g, oracle.s_g, "round {round}");
    }
    server.adopt_shards(view);
    assert_eq!(server.theta_g, oracle.theta_g, "after stitch");
    assert_eq!(server.s_g, oracle.s_g, "after stitch");
}

// ---------------------------------------------------------------------
// End-to-end: the runner under churn
// ---------------------------------------------------------------------

fn mini_cfg(method: &str) -> ExperimentConfig {
    ExperimentConfig {
        dataset: "cifar10".into(),
        arch: "test".into(),
        method: method.into(),
        n_clients: 5,
        rounds: 3,
        rho: 1.0,
        local_epochs: 1,
        samples_per_client: 24,
        test_samples: 100,
        dirichlet_alpha: 10.0,
        kappa0: 0.8,
        kappa_floor: 0.25,
        seed: 42,
        eval_every: 3,
        backend: BackendKind::Native,
        head_init: HeadInit::He,
        lp_rounds: 1,
        theta0: 0.85,
        arch_override: None,
        tuning: ServerTuning {
            pipeline: PipelineMode::Streaming,
            decode_workers: 1,
            agg_shards: 1,
            // The CI remote-shards knob-matrix entry sets
            // DELTAMASK_SHARD_PLACE to a mixed local/remote spec, so every
            // runner-driven sharded experiment in this suite drains through
            // standing `deltamask shard-worker` processes over UDS (the
            // runner resolves the spec to each run's lane count).
            shard_place: deltamask::fl::shard_place_from_env(),
            persistent_pipeline: false,
            quorum: 1.0,
            round_deadline_ms: 0,
            on_decode_error: OnDecodeError::Abort,
        },
        chaos: String::new(),
        // The CI uds-transport knob-matrix entry sets
        // DELTAMASK_TRANSPORT=uds, re-running this whole suite — chaos,
        // quorum, retry and all — over the loopback framed socket.
        transport: deltamask::fl::transport_from_env(),
    }
}

/// A full experiment under seeded chaos completes degraded rounds with
/// identical per-round fault counters, losses, bitrates and accuracy
/// across the serial, worker-sharded and round-resident drain shapes —
/// and a replay of the same seed reproduces everything exactly. Per-round
/// counters are cross-checked against the fault plan's own verdicts
/// (ρ = 1 ⇒ every client participates, so fates are computable without
/// re-deriving the engine's participant sampling).
#[test]
fn experiment_under_chaos_is_reproducible_across_drain_shapes() {
    let n = 5;
    let rounds = 3;
    let fault = find_plan(
        |seed| FaultPlan::parse(&format!("seed={seed},drop=0.25,die=0.2")).unwrap(),
        |f| {
            let lost = |r: usize| {
                (0..n)
                    .filter(|&c| f.verdict(r, c) != FaultVerdict::Deliver)
                    .count()
            };
            // Quorum 0.6 of 5 ⇒ 3 survivors needed every round; at least
            // one faulted client overall so the run actually degrades.
            (0..rounds).all(|r| n - lost(r) >= 3) && (0..rounds).map(lost).sum::<usize>() >= 1
        },
    );
    let mut base = mini_cfg("deltamask");
    base.tuning.quorum = 0.6;
    base.chaos = format!("seed={},drop=0.25,die=0.2", fault.seed);

    let serial = run_experiment(&base).unwrap();
    let replay = run_experiment(&base).unwrap();
    let mut sharded_cfg = base.clone();
    sharded_cfg.tuning.decode_workers = 2;
    sharded_cfg.tuning.agg_shards = 2;
    let sharded = run_experiment(&sharded_cfg).unwrap();
    let mut resident_cfg = sharded_cfg.clone();
    resident_cfg.tuning.persistent_pipeline = true;
    let resident = run_experiment(&resident_cfg).unwrap();

    assert_eq!(serial.rounds.len(), rounds);
    let mut any_degraded = false;
    for (r, m) in serial.rounds.iter().enumerate() {
        assert_eq!(m.round, r);
        let dies = (0..n)
            .filter(|&c| fault.verdict(r, c) == FaultVerdict::Die)
            .count() as u64;
        let drops = (0..n)
            .filter(|&c| fault.verdict(r, c) == FaultVerdict::Drop)
            .count() as u64;
        assert_eq!(m.faults.failed, dies, "round {r}");
        assert_eq!(m.faults.missing, dies + drops, "round {r}");
        assert_eq!(m.degraded, dies + drops > 0, "round {r}");
        assert!(m.quorum_met, "round {r}");
        any_degraded |= m.degraded;
        for (label, other) in [
            ("replay", &replay),
            ("sharded", &sharded),
            ("resident", &resident),
        ] {
            let o = &other.rounds[r];
            assert_eq!(m.faults, o.faults, "{label} round {r}: fault counters");
            assert_eq!(m.degraded, o.degraded, "{label} round {r}");
            assert_eq!(m.train_loss, o.train_loss, "{label} round {r}: loss");
            assert_eq!(m.mean_bpp, o.mean_bpp, "{label} round {r}: bpp");
            assert_eq!(m.accuracy, o.accuracy, "{label} round {r}: accuracy");
        }
    }
    assert!(
        any_degraded,
        "the searched fault plan must actually degrade a round"
    );
}

/// Bounded retry on the client send path: transient send failures below
/// the retry budget are invisible (bitwise-identical to the clean run,
/// zero fault counters), while a client whose sends exhaust every attempt
/// escalates in-band and the strict round aborts on the shortfall.
#[test]
fn transient_send_failures_are_retried_to_a_clean_round() {
    let clean = run_experiment(&mini_cfg("deltamask")).unwrap();
    let mut cfg = mini_cfg("deltamask");
    // Every (round, client) pair is flaky, but fails fewer times than the
    // runner's retry budget: the backoff path absorbs all of it.
    cfg.chaos = "seed=3,flaky=1.0,flaky_sends=2".into();
    let flaky = run_experiment(&cfg).unwrap();
    assert_eq!(clean.rounds.len(), flaky.rounds.len());
    for (c, f) in clean.rounds.iter().zip(&flaky.rounds) {
        assert_eq!(c.train_loss, f.train_loss, "round {}: loss", c.round);
        assert_eq!(c.mean_bpp, f.mean_bpp, "round {}: bpp", c.round);
        assert_eq!(c.accuracy, f.accuracy, "round {}: accuracy", c.round);
        assert_eq!(
            f.faults,
            FaultCounters {
                received: 5,
                accepted: 5,
                ..FaultCounters::default()
            },
            "round {}",
            c.round
        );
        assert!(f.quorum_met && !f.degraded, "round {}", c.round);
    }

    // Exhausted retries: every send attempt (including the in-band
    // escalation) fails, so nothing reaches the server and the strict
    // quorum aborts the run at the first round.
    let mut dead = mini_cfg("deltamask");
    dead.chaos = "seed=3,flaky=1.0,flaky_sends=9".into();
    let err = run_experiment(&dead).unwrap_err().to_string();
    assert!(err.contains("uplink closed after 0/5"), "{err}");
}

/// The CI knob-matrix `churn` entries drive this smoke through the env
/// surface (`DELTAMASK_METHOD` / `DELTAMASK_CHAOS` / `DELTAMASK_QUORUM`
/// plus the scaling knobs — the uds-churn-maskrn entry points it at a
/// sibling codec over the framed socket): whatever scenario the env
/// describes, two runs of it must agree exactly — same per-round fault
/// counters and accuracy on success, or the very same error if the
/// scenario cannot meet its quorum. With no env set this degenerates to a
/// clean determinism check.
#[test]
fn ci_env_knob_scenario_is_deterministic() {
    let mut cfg = mini_cfg(&deltamask::fl::method_from_env());
    cfg.tuning.quorum = deltamask::fl::quorum_from_env();
    cfg.chaos = deltamask::fl::chaos_from_env();
    cfg.tuning.decode_workers = deltamask::fl::decode_workers_from_env();
    cfg.tuning.agg_shards = deltamask::fl::agg_shards_from_env();
    cfg.tuning.persistent_pipeline = deltamask::fl::persistent_pipeline_from_env();
    let a = run_experiment(&cfg);
    let b = run_experiment(&cfg);
    match (a, b) {
        (Ok(a), Ok(b)) => {
            assert_eq!(a.rounds.len(), b.rounds.len());
            for (x, y) in a.rounds.iter().zip(&b.rounds) {
                assert_eq!(x.faults, y.faults, "round {}: fault counters", x.round);
                assert_eq!(x.degraded, y.degraded, "round {}", x.round);
                assert_eq!(x.accuracy, y.accuracy, "round {}: accuracy", x.round);
            }
        }
        (Err(a), Err(b)) => assert_eq!(a.to_string(), b.to_string()),
        (a, b) => panic!(
            "env scenario diverged across runs: ok={} vs ok={}",
            a.is_ok(),
            b.is_ok()
        ),
    }
}

/// With chaos off, the relaxed policy knobs are dormant end-to-end: a
/// `--quorum 0.6 --round-deadline-ms 60000` run is bitwise-identical to
/// the strict default, with clean fault counters on every round.
#[test]
fn relaxed_policy_without_chaos_is_bitwise_dormant_end_to_end() {
    let strict = run_experiment(&mini_cfg("deltamask")).unwrap();
    let mut cfg = mini_cfg("deltamask");
    cfg.tuning.quorum = 0.6;
    cfg.tuning.round_deadline_ms = 60_000;
    let relaxed = run_experiment(&cfg).unwrap();
    assert_eq!(strict.rounds.len(), relaxed.rounds.len());
    for (s, r) in strict.rounds.iter().zip(&relaxed.rounds) {
        assert_eq!(s.train_loss, r.train_loss, "round {}: loss", s.round);
        assert_eq!(s.mean_bpp, r.mean_bpp, "round {}: bpp", s.round);
        assert_eq!(s.accuracy, r.accuracy, "round {}: accuracy", s.round);
        for m in [s, r] {
            assert_eq!(
                m.faults,
                FaultCounters {
                    received: 5,
                    accepted: 5,
                    ..FaultCounters::default()
                },
                "round {}",
                m.round
            );
            assert!(m.quorum_met && !m.degraded, "round {}", m.round);
            assert_eq!(m.wire.sent_messages, 5, "round {}", m.round);
        }
    }
}

// ---------------------------------------------------------------------
// End-to-end: the wire (loopback socket) vs the in-process channel
// ---------------------------------------------------------------------

/// The per-round facts that must be transport-invariant: the model
/// trajectory (loss / bits / accuracy), the fault accounting and
/// completion verdicts, and the send-time wire counters. Timing fields
/// and the socket-only frame/backpressure counters are excluded — those
/// are allowed (expected, even) to differ across transports.
fn assert_transport_invariant(
    label: &str,
    a: &deltamask::fl::ExperimentResult,
    b: &deltamask::fl::ExperimentResult,
) {
    assert_eq!(a.rounds.len(), b.rounds.len(), "{label}: round count");
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        let r = x.round;
        assert_eq!(x.train_loss, y.train_loss, "{label} round {r}: loss");
        assert_eq!(x.mean_bits, y.mean_bits, "{label} round {r}: bits");
        assert_eq!(x.mean_bpp, y.mean_bpp, "{label} round {r}: bpp");
        assert_eq!(x.accuracy, y.accuracy, "{label} round {r}: accuracy");
        assert_eq!(x.faults, y.faults, "{label} round {r}: fault counters");
        assert_eq!(x.quorum_met, y.quorum_met, "{label} round {r}: quorum");
        assert_eq!(x.degraded, y.degraded, "{label} round {r}: degraded");
        assert_eq!(
            x.wire.sent_messages, y.wire.sent_messages,
            "{label} round {r}: sent messages"
        );
        assert_eq!(
            x.wire.sent_payload_bytes, y.wire.sent_payload_bytes,
            "{label} round {r}: sent payload bytes"
        );
    }
    assert_eq!(
        a.final_accuracy(),
        b.final_accuracy(),
        "{label}: final accuracy"
    );
}

/// Pointing the experiment at a real socket changes nothing but the wire:
/// for both TCP and Unix-domain loopback, a clean run is
/// trajectory-identical to the in-process channel — and the socket run
/// demonstrably framed its traffic (the channel reports zero frames).
#[test]
fn clean_socket_trajectories_match_the_channel() {
    let mut base = mini_cfg("deltamask");
    base.transport = TransportKind::Channel;
    let channel = run_experiment(&base).unwrap();
    for kind in [TransportKind::Tcp, TransportKind::Uds] {
        let mut cfg = mini_cfg("deltamask");
        cfg.transport = kind;
        let socket = run_experiment(&cfg).unwrap();
        assert_transport_invariant(kind.as_str(), &channel, &socket);
        for m in &channel.rounds {
            assert_eq!(m.wire.wire_frames, 0, "channel framed round {}", m.round);
        }
        for m in &socket.rounds {
            // Every accepted message crossed the wire as a frame, and the
            // 16-byte headers make the wire strictly fatter than the
            // payloads. (Both counters are settled by the time a strict
            // round completes: the drain saw all five updates.)
            assert!(
                m.wire.wire_frames >= m.wire.sent_messages,
                "{} round {}: {} frames < {} messages",
                kind.as_str(),
                m.round,
                m.wire.wire_frames,
                m.wire.sent_messages
            );
            assert!(
                m.wire.wire_bytes > m.wire.sent_payload_bytes,
                "{} round {}: framing overhead missing",
                kind.as_str(),
                m.round
            );
        }
    }
}

/// The PR 7 fault model composes onto the socket for free: the same
/// seeded chaos spec over uds loopback reproduces the channel run's
/// fault counters, degraded verdicts, losses and accuracy exactly — and a
/// socket replay of the same seed reproduces the socket run.
#[test]
fn chaos_over_the_socket_reproduces_the_channel_fault_trajectory() {
    let n = 5;
    let rounds = 3;
    // Same scenario search as the drain-shape test: every round keeps
    // quorum (3 of 5), at least one round actually degrades; flaky sends
    // additionally exercise the socket sender's retry path.
    let fault = find_plan(
        |seed| {
            FaultPlan::parse(&format!("seed={seed},drop=0.25,die=0.2,flaky=0.5")).unwrap()
        },
        |f| {
            let lost = |r: usize| {
                (0..n)
                    .filter(|&c| f.verdict(r, c) != FaultVerdict::Deliver)
                    .count()
            };
            (0..rounds).all(|r| n - lost(r) >= 3) && (0..rounds).map(lost).sum::<usize>() >= 1
        },
    );
    let mut base = mini_cfg("deltamask");
    base.tuning.quorum = 0.6;
    base.chaos = format!("seed={},drop=0.25,die=0.2,flaky=0.5", fault.seed);

    base.transport = TransportKind::Channel;
    let channel = run_experiment(&base).unwrap();
    base.transport = TransportKind::Uds;
    let socket = run_experiment(&base).unwrap();
    let socket_replay = run_experiment(&base).unwrap();

    assert_transport_invariant("uds-chaos", &channel, &socket);
    assert_transport_invariant("uds-replay", &socket, &socket_replay);
    assert!(
        socket.rounds.iter().any(|m| m.degraded),
        "the searched fault plan must actually degrade a round"
    );
}
