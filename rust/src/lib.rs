//! # DeltaMask
//!
//! Reproduction of *"Federated Fine-Tuning of Foundation Models via
//! Probabilistic Masking"* (Tsouvalas, Asano, Saeed — 2023) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the federated system, split into two layers:
//!   the [`coordinator`] subsystem (transport-agnostic round engine:
//!   `RoundPlan`/`RoundEngine` for sampling, κ scheduling and shared-seed
//!   mask derivation; a `Transport` carrying encoded updates with wire
//!   accounting; a work-stealing `ClientPool`; and the batch-vs-streaming
//!   `PipelineMode`), and the [`fl`] experiment layer on top of it
//!   (state ownership, the streaming Bayesian [`fl::server::MaskServer`],
//!   baselines, metrics). Updates are decoded and absorbed per-arrival —
//!   the server never materializes a round's O(K·d) update set — plus the
//!   DeltaMask codec (binary fuse filters → grayscale PNG) and every
//!   baseline codec the paper compares against, under [`compress`].
//! * **L2 (`python/compile/model.py`)** — the masked-model compute graph
//!   (fwd/bwd + Adam on mask scores), AOT-lowered once to HLO text.
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels for the masked
//!   matmul hot-spot, lowered into the same HLO.
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! pre-compiled artifacts through the PJRT C API and executes them natively
//! (behind the `xla` cargo feature; without it a stub reports the missing
//! integration and the pure-rust [`native`] backend drives everything).
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index mapping every table/figure of the paper to a bench target.
//!
//! ## Hot-path performance tracking (`BENCH_hotpaths.json`)
//!
//! The encode→wire→decode hot path runs on **batched monomorphic kernels**
//! (blocked filter membership via `MembershipFilter::{contains_batch,
//! decode_mask_into}`, word-at-a-time bit I/O, fused-pair literal emission,
//! unrolled matmuls) with **reusable scratch** (`compress::EncodeScratch`
//! per client session, a `compress::ScratchPool` of decode buffers cycling
//! through `coordinator::drain_round` ↔ `Aggregator::reclaim_buffer`), so
//! steady-state rounds allocate nothing on the wire path. Every batched
//! kernel is parity-locked to a retained scalar oracle — it changes *how*
//! membership is queried, never what is encoded; all 8 codecs stay
//! bitwise-identical on the wire.
//!
//! `benches/hotpaths.rs` times each kernel against its scalar oracle and
//! writes `BENCH_hotpaths.json` at the repo root. Regenerate with:
//!
//! ```text
//! cargo bench --bench hotpaths            # full sweep, d ∈ {1e5, 1e6, 1e7}
//! cargo bench --bench hotpaths -- --smoke # CI scale (the bench-smoke job)
//! ```
//!
//! Schema (`deltamask-hotpaths-v1`):
//!
//! ```text
//! { "schema":  "deltamask-hotpaths-v1",
//!   "provenance": <how this file was produced>,
//!   "smoke":   <bool>, "iters": <n>, "warmup": <n>,
//!   "kernels": [ { "name": <kernel id, e.g. "bfuse8_decode_d1000000">,
//!                  "scalar_secs":  <min over iters, scalar oracle>,
//!                  "batched_secs": <min over iters, batched kernel>,
//!                  "speedup":      <scalar_secs / batched_secs>,
//!                  "parity":       <bitwise agreement, asserted> } ],
//!   "tracked": [ { "name": <png/deflate throughput id>, "secs": <min> } ] }
//! ```
//!
//! PR-over-PR regression checks diff `kernels[*].batched_secs` (and the
//! `tracked` throughputs) between runs on the same machine; `parity` must
//! always be `true` — the bench exits non-zero otherwise.

pub mod bench;
pub mod codec;
pub mod compress;
pub mod coordinator;
pub mod filters;
pub mod fl;
pub mod hash;
pub mod model;
pub mod native;
pub mod runtime;
pub mod util;
