//! The **round-resident drain pipeline**: decode workers, shard lanes and
//! scratch pools that live for a whole experiment instead of one round.
//!
//! [`drain_round`](super::drain_round) (the per-round-spawn path) rebuilds
//! its worker crew every round: N thread spawns, a fresh results channel,
//! and — when the caller also rebuilds its sharded view — S lane spawns
//! plus cold buffer pools. That is O(rounds) setup cost and it forfeits
//! the cross-round zero-allocation steady state the shared
//! [`ScratchPool`] otherwise provides. A [`DrainPipeline`] makes all of
//! that O(1) per experiment:
//!
//! * **Spawn once** — [`DrainPipeline::new`] spawns the resolved number of
//!   decode workers immediately; they park on an **epoch barrier** (a
//!   `Mutex` + `Condvar` generation counter). [`DrainPipeline::drain_round`]
//!   publishes a round package (plan snapshot, codec, job queue, results
//!   queue, optional [`ShardRouter`]) and bumps the epoch; workers wake,
//!   stream the round, and park again. No thread is spawned or joined
//!   anywhere in the per-round path.
//! * **Pools persist** — the pipeline owns the decode-output
//!   [`ScratchPool`]; round t+1's decodes reuse the buffers round t spent.
//!   With a resident [`ShardedAggregator`](super::ShardedAggregator)
//!   (whose lane threads and per-lane pools are resident too), steady-state
//!   rounds allocate **zero** decode buffers — observable via
//!   [`DrainReport::pool`] and `ShardedAggregator::lane_pool_stats`, not
//!   just asserted.
//! * **Abort and reuse** — a malformed record (or early uplink close)
//!   aborts the round exactly like the per-round-spawn path: pending jobs
//!   dropped, the results queue unblocked and drained, every worker
//!   *parked* (not joined), the aggregator's lanes quiesced via
//!   [`Aggregator::abort_round`]. The pipeline is immediately reusable for
//!   the next round. Dropping the pipeline signals shutdown and joins the
//!   workers.
//!
//! Bitwise identity with the per-round-spawn drain is part of the
//! contract: the pipeline runs the same validation, the same
//! decode kernels (including the range-restricted per-shard sweep) and
//! drives the same [`Aggregator`] interface — property-tested across all
//! all 11 registered codecs × both pipeline modes × worker/shard combinations × multi-round
//! trajectories in `rust/tests/agg_shards.rs`.
//!
//! ```
//! use std::sync::Arc;
//! use deltamask::compress::{self, UpdateCodec};
//! use deltamask::coordinator::{
//!     ChannelTransport, DrainConfig, DrainPipeline, Payload, PipelineMode, RoundEngine,
//!     WireMessage,
//! };
//! use deltamask::fl::server::MaskServer;
//! use deltamask::model::sample_mask_seeded;
//!
//! let d = 64;
//! let theta = vec![0.5f32; d];
//! let s = vec![0.0f32; d];
//! let codec: Arc<dyn UpdateCodec> = Arc::from(compress::by_name("fedpm").unwrap());
//! let pipeline = DrainPipeline::new(DrainConfig::new(PipelineMode::Streaming, 2));
//! let mut engine = RoundEngine::new(7, 2, 1.0, 0.8, 0.25, 2);
//! let mut server = MaskServer::with_theta0(d, 1.0, 0.5);
//!
//! // Two rounds through the SAME resident workers and pool.
//! for round in 0..2 {
//!     let plan = Arc::new(engine.plan(round, &server.theta_g, &server.s_g));
//!     let (mut transport, sender) = ChannelTransport::new();
//!     for slot in 0..plan.expected() {
//!         let mut mask_k = Vec::new();
//!         sample_mask_seeded(&plan.theta_g, plan.client_seed(slot), &mut mask_k);
//!         let enc = codec
//!             .encode(&plan.encode_ctx(slot, &plan.theta_g, &mask_k, &[]))
//!             .unwrap();
//!         sender
//!             .send(WireMessage {
//!                 round,
//!                 client_id: plan.participants[slot],
//!                 slot,
//!                 payload: Payload::Update(enc),
//!                 enc_secs: 0.0,
//!                 loss: 0.5,
//!             })
//!             .unwrap();
//!     }
//!     drop(sender);
//!     let report = pipeline
//!         .drain_round(&mut transport, &plan, &codec, &mut server)
//!         .unwrap();
//!     assert_eq!(report.dec_by_worker.len(), 2);
//! }
//! ```

use super::aggregate::{
    decode_and_route, drain_round, Aggregator, DecodeQueue, DrainConfig, DrainReport, RoundGate,
};
use super::round::RoundPlan;
use super::shard::ShardRouter;
use super::transport::Transport;
use super::PipelineMode;
use crate::compress::{Encoded, ScratchPool, Update, UpdateCodec};
use crate::util::timer::Stopwatch;
use anyhow::{anyhow, bail, Result};
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A long-lived decode→absorb pipeline: resident decode workers parked on
/// an epoch barrier between rounds, plus the experiment-lifetime decode
/// buffer pool. Owned by `fl::Runner` when `--persistent-pipeline` is on;
/// usable directly by any coordinator driver. See the module docs for the
/// lifecycle (spawn-once → per-round activate → park → drop-joins).
pub struct DrainPipeline {
    /// The drain configuration, with `workers`/`shards` pre-resolved in
    /// [`DrainPipeline::new`] (so `cfg.workers` ≥ 1; 1 means no resident
    /// threads — the serial/inline path needs none).
    cfg: DrainConfig,
    pool: Arc<ScratchPool>,
    crew: Option<Crew>,
}

/// The resident worker crew (present iff `workers > 1`).
struct Crew {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

/// The epoch barrier the workers park on between rounds.
struct Shared {
    state: Mutex<EpochState>,
    wake: Condvar,
}

struct EpochState {
    /// Round generation. Bumped by `drain_round`; a worker that has
    /// already served this epoch parks until it changes. The current
    /// round package is replaced (never cleared), so a worker waking
    /// late always converges on the latest epoch's work.
    epoch: u64,
    round: Option<Arc<RoundWork>>,
    shutdown: bool,
}

impl Shared {
    /// Park until a new epoch (returning its round package) or shutdown
    /// (returning `None`).
    fn next_round(&self, seen_epoch: &mut u64) -> Option<Arc<RoundWork>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.shutdown {
                return None;
            }
            if st.epoch != *seen_epoch {
                *seen_epoch = st.epoch;
                return Some(Arc::clone(st.round.as_ref().expect("epoch implies round")));
            }
            st = self.wake.wait(st).unwrap();
        }
    }
}

/// Everything one round's workers need, bundled so a single `Arc` travels
/// through the epoch barrier.
struct RoundWork {
    plan: Arc<RoundPlan>,
    codec: Arc<dyn UpdateCodec>,
    /// The master router token for dimension-sharded rounds. Workers clone
    /// it once when they pick the round up; `drain_round` takes it out
    /// after the workers quiesce so the absorb lanes can observe
    /// disconnect on abort (a clone parked inside this struct would keep
    /// them alive forever).
    router: Mutex<Option<ShardRouter>>,
    queue: DecodeQueue,
    results: ResultsQueue<WorkerRecord>,
    pool: Arc<ScratchPool>,
}

impl RoundWork {
    /// Unblock every worker touching this round: drop pending jobs and
    /// release producers blocked on the bounded results queue. Idempotent;
    /// harmless after a completed round (both queues are already drained).
    fn abort(&self) {
        self.queue.abort();
        self.results.abort();
    }

    /// Release the master router token (no-op if already taken). Without
    /// this the absorb lanes can never observe disconnect — the round
    /// package stays published on the epoch barrier until the next epoch
    /// replaces it.
    fn release_router(&self) {
        let mut slot = self
            .router
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        slot.take();
    }
}

/// One worker's outcome for one record. `Ok(Some(update))` = decoded, to
/// be absorbed on the draining thread; `Ok(None)` = already routed to the
/// shard lanes by the worker itself.
struct WorkerRecord {
    slot: usize,
    worker: usize,
    dec_secs: f64,
    outcome: Result<Option<Update>>,
}

/// Aborts the round's queues — and releases the master router token — when
/// dropped, so workers never stay blocked and shard lanes can always reach
/// their disconnect after an unwinding drain (e.g. an aggregator panic on
/// the absorb stage: the resident view's own `Drop` then waits for its
/// lanes, which requires every round sender gone). Runs on every exit
/// path; see [`RoundWork::abort`] / [`RoundWork::release_router`].
struct RoundQuiesceGuard<'a>(&'a RoundWork);

impl Drop for RoundQuiesceGuard<'_> {
    fn drop(&mut self) {
        self.0.abort();
        self.0.release_router();
    }
}

impl DrainPipeline {
    /// Spawn the resident crew for `cfg` (resolving `workers == 0` /
    /// `shards == 0` to the core count once, so every round of the
    /// experiment uses the same shape). `workers == 1` spawns nothing —
    /// the per-round path is the inline/serial drain, but the pipeline
    /// still owns the experiment-lifetime decode pool.
    pub fn new(cfg: DrainConfig) -> Self {
        let resolved = DrainConfig::sharded(cfg.mode, cfg.resolved_workers(), cfg.resolved_shards())
            .with_policy(cfg.policy);
        let workers = resolved.workers;
        let crew = (workers > 1).then(|| {
            let shared = Arc::new(Shared {
                state: Mutex::new(EpochState {
                    epoch: 0,
                    round: None,
                    shutdown: false,
                }),
                wake: Condvar::new(),
            });
            let handles = (0..workers)
                .map(|worker| {
                    let shared = Arc::clone(&shared);
                    std::thread::spawn(move || worker_loop(&shared, worker))
                })
                .collect();
            Crew { shared, handles }
        });
        Self {
            cfg: resolved,
            pool: Arc::new(ScratchPool::new()),
            crew,
        }
    }

    /// The drain configuration every round of this pipeline runs under
    /// (workers/shards pre-resolved).
    pub fn config(&self) -> DrainConfig {
        self.cfg
    }

    /// Resolved decode worker count.
    pub fn workers(&self) -> usize {
        self.cfg.workers
    }

    /// The experiment-lifetime decode buffer pool (its
    /// [`stats`](ScratchPool::stats) expose the cross-round zero-alloc
    /// property).
    pub fn pool(&self) -> &Arc<ScratchPool> {
        &self.pool
    }

    /// Drain one round through the resident crew — the pipeline-owned
    /// equivalent of [`drain_round`](super::drain_round), with identical
    /// semantics, identical error classification and bitwise-identical
    /// aggregator state. With `shards > 1` the aggregator must expose a
    /// [`ShardRouter`] (i.e. be a
    /// [`ShardedAggregator`](super::ShardedAggregator)); callers keeping
    /// one resident view across rounds get the full spawn-free,
    /// allocation-free steady state.
    ///
    /// On error the round aborts cleanly — pending jobs dropped, workers
    /// parked (not joined), lanes quiesced via
    /// [`Aggregator::abort_round`] — and the pipeline is immediately
    /// reusable for the next round.
    pub fn drain_round(
        &self,
        transport: &mut dyn Transport,
        plan: &Arc<RoundPlan>,
        codec: &Arc<dyn UpdateCodec>,
        agg: &mut dyn Aggregator,
    ) -> Result<DrainReport> {
        match &self.crew {
            // No resident threads: the serial/inline drain is already
            // spawn-free; the pipeline contributes the persistent pool.
            None => drain_round(transport, plan, codec.as_ref(), agg, self.cfg, &self.pool),
            Some(crew) => self.drain_resident(crew, transport, plan, codec, agg),
        }
    }

    fn drain_resident(
        &self,
        crew: &Crew,
        transport: &mut dyn Transport,
        plan: &Arc<RoundPlan>,
        codec: &Arc<dyn UpdateCodec>,
        agg: &mut dyn Aggregator,
    ) -> Result<DrainReport> {
        let expected = plan.expected();
        let mode = self.cfg.mode;
        let shards = self.cfg.shards;
        let workers = self.cfg.workers;
        let pool_before = self.pool.stats();
        let mut report = DrainReport::new(expected, workers);
        let mut gate = RoundGate::new(plan, &self.cfg.policy);

        // Batch mode: the full-round barrier comes first, before the crew
        // is activated — a barrier failure has nothing to quiesce.
        let mut buffered: Vec<Option<Encoded>> = Vec::new();
        if mode == PipelineMode::Batch {
            buffered = vec![None; expected];
            while let Some((slot, enc)) = gate.next_record(transport, &mut report)? {
                buffered[slot] = Some(enc);
            }
        }

        agg.begin_round(expected);
        let router = if shards > 1 {
            match agg.shard_router() {
                Some(router) => Some(router),
                None => {
                    agg.abort_round();
                    bail!(
                        "DrainConfig::shards > 1 requires a dimension-sharded aggregator \
                         (coordinator::ShardedAggregator)"
                    );
                }
            }
        } else {
            None
        };

        let work = Arc::new(RoundWork {
            plan: Arc::clone(plan),
            codec: Arc::clone(codec),
            router: Mutex::new(router),
            queue: DecodeQueue::new(),
            results: ResultsQueue::new(workers * 2, workers),
            pool: Arc::clone(&self.pool),
        });
        crew.activate(&work);
        let _quiesce_on_unwind = RoundQuiesceGuard(&work);

        let mut absorbed = 0usize;
        let mut run = || -> Result<()> {
            // Settled = absorbed + skipped-as-corrupt: every job pushed to
            // the workers must come back before the round can finish.
            let mut settled = 0usize;
            match mode {
                PipelineMode::Streaming => {
                    while let Some((slot, enc)) = gate.next_record(transport, &mut report)? {
                        work.queue.push(slot, enc);
                        // Opportunistically absorb finished decodes between
                        // arrivals (overlaps aggregation with transport
                        // waits, keeps the in-flight set small).
                        while let Some(rec) = work.results.try_pop() {
                            if settle(rec, &mut report, agg, &self.pool, &mut gate)? {
                                absorbed += 1;
                            }
                            settled += 1;
                        }
                    }
                }
                PipelineMode::Batch => {
                    // Barrier already passed: fan out in slot order,
                    // skipping slots that never arrived.
                    for (slot, enc) in std::mem::take(&mut buffered).into_iter().enumerate() {
                        if let Some(enc) = enc {
                            work.queue.push(slot, enc);
                        }
                    }
                }
            }
            work.queue.close();
            while settled < gate.accepted() {
                let rec = work
                    .results
                    .pop()
                    .ok_or_else(|| anyhow!("decode workers exited early"))?;
                if settle(rec, &mut report, agg, &self.pool, &mut gate)? {
                    absorbed += 1;
                }
                settled += 1;
            }
            Ok(())
        };
        let out = run();

        if out.is_err() {
            // Clean abort: drop pending jobs, unblock producers, then wait
            // until every worker has finished the round (pop() returns
            // `None` only once all producers are done) — after which no
            // worker holds a router clone and the lanes can be quiesced.
            work.abort();
            while work.results.pop().is_some() {}
        }
        // Release the master router token; without this the lanes would
        // never observe disconnect on an aborted round (the round package
        // stays published on the barrier until the next epoch replaces it).
        work.release_router();

        let settled = out
            .and_then(|()| super::aggregate::bail_on_lane_fault(agg))
            .and_then(|()| gate.settle(absorbed, &mut report));
        match settled {
            Ok(partial) => {
                if partial {
                    agg.finish_round_partial();
                } else {
                    agg.finish_round();
                }
                super::aggregate::bail_on_lane_fault(agg)?;
                report.pool = self.pool.stats().delta_since(pool_before);
                Ok(report)
            }
            Err(e) => {
                agg.abort_round();
                Err(e)
            }
        }
    }
}

impl Drop for DrainPipeline {
    /// Signal shutdown on the epoch barrier and join the resident workers.
    /// `drain_round` always leaves the crew parked (success or error), so
    /// this never blocks on an in-flight round.
    fn drop(&mut self) {
        if let Some(crew) = self.crew.take() {
            {
                let mut st = crew.shared.state.lock().unwrap();
                st.shutdown = true;
                crew.shared.wake.notify_all();
            }
            for handle in crew.handles {
                let _ = handle.join();
            }
        }
    }
}

impl Crew {
    /// Publish a round package and bump the epoch; every parked worker
    /// wakes and streams this round.
    fn activate(&self, work: &Arc<RoundWork>) {
        let mut st = self.shared.state.lock().unwrap();
        st.epoch += 1;
        st.round = Some(Arc::clone(work));
        drop(st);
        self.shared.wake.notify_all();
    }
}

/// Reports a producer as done when dropped — on the normal path and on a
/// worker panic alike, so the draining thread's `pop()` can always reach
/// its disconnect signal ("decode workers exited early") instead of
/// waiting forever on a producer that died.
struct ProducerDoneGuard<'a>(&'a ResultsQueue<WorkerRecord>);

impl Drop for ProducerDoneGuard<'_> {
    fn drop(&mut self) {
        self.0.producer_done();
    }
}

/// Resident worker body: park on the barrier, stream a round, park again —
/// until shutdown. The per-record action is the same decode (or
/// decode-and-route) the per-round-spawn workers perform.
fn worker_loop(shared: &Shared, worker: usize) {
    let mut seen_epoch = 0u64;
    while let Some(work) = shared.next_round(&mut seen_epoch) {
        // Declared before the router so drop order (reverse) releases the
        // router clone first: "all producers done" implies no live
        // worker-held lane senders.
        let _done = ProducerDoneGuard(&work.results);
        let router = work.router.lock().unwrap().clone();
        while let Some((slot, enc)) = work.queue.next() {
            // The clock covers only this thread's decode compute (the
            // record timing lives inside `decode_record`); pushing into
            // the bounded results queue — backpressure — is off-clock.
            let (dec_secs, outcome) = match decode_record(&work, router.as_ref(), slot, &enc) {
                Ok((secs, payload)) => (secs, Ok(payload)),
                Err(e) => (0.0, Err(e)),
            };
            let rec = WorkerRecord {
                slot,
                worker,
                dec_secs,
                outcome,
            };
            work.results.push(rec);
        }
    }
}

/// Decode one record, returning `(decode compute seconds on this thread,
/// payload)` — `None` payload when the record was routed to the shard
/// lanes (whose per-range sweep time lands in their absorb timings).
fn decode_record(
    work: &RoundWork,
    router: Option<&ShardRouter>,
    slot: usize,
    enc: &Encoded,
) -> Result<(f64, Option<Update>)> {
    match router {
        Some(router) => {
            let secs =
                decode_and_route(work.codec.as_ref(), &work.plan, slot, enc, &work.pool, router)?;
            Ok((secs, None))
        }
        None => {
            let t = Stopwatch::new();
            let update =
                work.codec
                    .decode_pooled(&enc.bytes, &work.plan.decode_ctx(slot), &work.pool)?;
            Ok((t.elapsed_secs(), Some(update)))
        }
    }
}

/// Fold one worker record into the report (and the aggregator, for
/// non-routed records), recycling spent buffers. Returns whether the
/// record was absorbed (`false` = decode failure skipped under the
/// gate's skip policy; an aborting failure is `Err`).
fn settle(
    rec: WorkerRecord,
    report: &mut DrainReport,
    agg: &mut dyn Aggregator,
    pool: &ScratchPool,
    gate: &mut RoundGate,
) -> Result<bool> {
    let payload = match rec.outcome {
        Ok(payload) => payload,
        Err(e) => {
            gate.decode_failed(rec.slot, e)?;
            return Ok(false);
        }
    };
    report.dec_secs += rec.dec_secs;
    report.dec_by_worker[rec.worker] += rec.dec_secs;
    if let Some(update) = payload {
        agg.absorb(rec.slot, update);
        while let Some(buf) = agg.reclaim_buffer() {
            pool.put(buf);
        }
    }
    Ok(true)
}

/// Bounded MPSC results queue with explicit producer accounting — the
/// resident replacement for the per-round `mpsc::sync_channel`: `pop`
/// returns `None` exactly when every producer has finished the round and
/// the queue is empty (the disconnect signal a per-round channel gets for
/// free), and `abort` unblocks producers by discarding their records.
struct ResultsQueue<T> {
    state: Mutex<ResultsState<T>>,
    /// Consumer-side signal: an item arrived or a producer finished.
    ready: Condvar,
    /// Producer-side signal: space freed (or abort).
    space: Condvar,
}

struct ResultsState<T> {
    items: VecDeque<T>,
    cap: usize,
    producers: usize,
    aborted: bool,
}

impl<T> ResultsQueue<T> {
    fn new(cap: usize, producers: usize) -> Self {
        Self {
            state: Mutex::new(ResultsState {
                items: VecDeque::with_capacity(cap),
                cap: cap.max(1),
                producers,
                aborted: false,
            }),
            ready: Condvar::new(),
            space: Condvar::new(),
        }
    }

    /// Enqueue, blocking while full. After `abort` the item is discarded —
    /// the producer returns immediately instead of deadlocking against a
    /// consumer that already bailed.
    fn push(&self, item: T) {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.aborted {
                return;
            }
            if st.items.len() < st.cap {
                st.items.push_back(item);
                drop(st);
                self.ready.notify_one();
                return;
            }
            st = self.space.wait(st).unwrap();
        }
    }

    /// Non-blocking pop.
    fn try_pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        let item = st.items.pop_front();
        if item.is_some() {
            drop(st);
            self.space.notify_one();
        }
        item
    }

    /// Blocking pop; `None` once every producer is done and the queue is
    /// empty.
    fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.space.notify_one();
                return Some(item);
            }
            if st.producers == 0 {
                return None;
            }
            st = self.ready.wait(st).unwrap();
        }
    }

    /// A producer finished its round share.
    fn producer_done(&self) {
        let mut st = self.state.lock().unwrap();
        st.producers = st.producers.saturating_sub(1);
        drop(st);
        self.ready.notify_all();
    }

    /// Discard queued items and unblock every producer; subsequent pushes
    /// are dropped. Idempotent.
    fn abort(&self) {
        let mut st = self.state.lock().unwrap();
        st.aborted = true;
        st.items.clear();
        drop(st);
        self.space.notify_all();
        self.ready.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress;
    use crate::coordinator::transport::{ChannelTransport, Payload, WireMessage};
    use crate::coordinator::RoundEngine;
    use crate::fl::server::MaskServer;
    use crate::model::sample_mask_seeded;

    fn plan_of(n: usize, round: usize) -> Arc<RoundPlan> {
        let theta = vec![0.5f32; 32];
        let s = vec![0.0f32; 32];
        Arc::new(RoundEngine::new(1 + round as u64, n, 1.0, 0.8, 0.25, 3).plan(round, &theta, &s))
    }

    fn fedpm_codec() -> Arc<dyn UpdateCodec> {
        Arc::from(compress::by_name("fedpm").unwrap())
    }

    fn send_round(
        plan: &RoundPlan,
        codec: &dyn UpdateCodec,
        skip: Option<usize>,
    ) -> ChannelTransport {
        let (transport, sender) = ChannelTransport::new();
        for slot in 0..plan.expected() {
            if Some(slot) == skip {
                continue;
            }
            let mut mask_k = Vec::new();
            sample_mask_seeded(&plan.theta_g, plan.client_seed(slot), &mut mask_k);
            let enc = codec
                .encode(&plan.encode_ctx(slot, &plan.theta_g, &mask_k, &[]))
                .unwrap();
            sender
                .send(WireMessage {
                    round: plan.round,
                    client_id: plan.participants[slot],
                    slot,
                    payload: Payload::Update(enc),
                    enc_secs: 0.0,
                    loss: 0.5,
                })
                .unwrap();
        }
        drop(sender);
        transport
    }

    #[test]
    fn resident_rounds_match_per_round_spawn_bitwise() {
        let codec = fedpm_codec();
        for mode in [PipelineMode::Batch, PipelineMode::Streaming] {
            let pipeline = DrainPipeline::new(DrainConfig::new(mode, 3));
            let mut resident = MaskServer::with_theta0(32, 1.0, 0.5);
            let mut oracle = resident.clone();
            for round in 0..3 {
                let plan = plan_of(4, round);
                let mut t1 = send_round(&plan, codec.as_ref(), None);
                pipeline
                    .drain_round(&mut t1, &plan, &codec, &mut resident)
                    .unwrap();
                let mut t2 = send_round(&plan, codec.as_ref(), None);
                drain_round(
                    &mut t2,
                    &plan,
                    codec.as_ref(),
                    &mut oracle,
                    DrainConfig::serial(mode),
                    &ScratchPool::new(),
                )
                .unwrap();
                assert_eq!(resident.theta_g, oracle.theta_g, "{mode:?} round {round}");
                assert_eq!(resident.s_g, oracle.s_g, "{mode:?} round {round}");
            }
        }
    }

    #[test]
    fn failed_round_leaves_the_pipeline_reusable() {
        let codec = fedpm_codec();
        let pipeline = DrainPipeline::new(DrainConfig::new(PipelineMode::Streaming, 2));
        let mut server = MaskServer::with_theta0(32, 1.0, 0.5);

        // Round 0: slot 1 never reports — early uplink close.
        let plan = plan_of(3, 0);
        let mut t = send_round(&plan, codec.as_ref(), Some(1));
        let err = pipeline
            .drain_round(&mut t, &plan, &codec, &mut server)
            .unwrap_err();
        assert!(err.to_string().contains("2/3"), "{err}");

        // Round 1: a corrupt record fails decode on a resident worker.
        let plan = plan_of(3, 1);
        let (mut t, sender) = ChannelTransport::new();
        for slot in 0..3 {
            sender
                .send(WireMessage {
                    round: 1,
                    client_id: plan.participants[slot],
                    slot,
                    payload: Payload::Update(Encoded { bytes: vec![0; 3] }),
                    enc_secs: 0.0,
                    loss: 0.0,
                })
                .unwrap();
        }
        drop(sender);
        let err = pipeline
            .drain_round(&mut t, &plan, &codec, &mut server)
            .unwrap_err();
        assert!(err.to_string().contains("decode failed for slot"), "{err}");

        // Round 2: same pipeline, same workers — a clean round succeeds and
        // matches the serial oracle.
        let plan = plan_of(3, 2);
        let mut t = send_round(&plan, codec.as_ref(), None);
        pipeline
            .drain_round(&mut t, &plan, &codec, &mut server)
            .unwrap();
        let mut oracle = MaskServer::with_theta0(32, 1.0, 0.5);
        let mut t = send_round(&plan, codec.as_ref(), None);
        drain_round(
            &mut t,
            &plan,
            codec.as_ref(),
            &mut oracle,
            DrainConfig::serial(PipelineMode::Streaming),
            &ScratchPool::new(),
        )
        .unwrap();
        assert_eq!(server.theta_g, oracle.theta_g);
    }

    #[test]
    fn resident_degraded_round_matches_serial_over_the_surviving_cohort() {
        use crate::coordinator::DrainPolicy;
        let codec = fedpm_codec();
        let relaxed = DrainPolicy {
            quorum: 0.5,
            ..DrainPolicy::default()
        };
        for mode in [PipelineMode::Batch, PipelineMode::Streaming] {
            let pipeline =
                DrainPipeline::new(DrainConfig::new(mode, 2).with_policy(relaxed));
            let mut resident = MaskServer::with_theta0(32, 1.0, 0.5);
            let mut oracle = resident.clone();

            // Round 0: slot 1 never reports; both paths finish degraded.
            let plan = plan_of(3, 0);
            let mut t1 = send_round(&plan, codec.as_ref(), Some(1));
            let report = pipeline
                .drain_round(&mut t1, &plan, &codec, &mut resident)
                .unwrap();
            assert!(report.degraded && report.quorum_met, "{mode:?}");
            assert_eq!(report.faults.missing, 1, "{mode:?}");
            let mut t2 = send_round(&plan, codec.as_ref(), Some(1));
            drain_round(
                &mut t2,
                &plan,
                codec.as_ref(),
                &mut oracle,
                DrainConfig::serial(mode).with_policy(relaxed),
                &ScratchPool::new(),
            )
            .unwrap();
            assert_eq!(resident.theta_g, oracle.theta_g, "{mode:?}");
            assert_eq!(resident.s_g, oracle.s_g, "{mode:?}");

            // Round 1: the same pipeline runs a full round cleanly after
            // the degraded one — and stays bitwise-locked to the oracle.
            let plan = plan_of(3, 1);
            let mut t1 = send_round(&plan, codec.as_ref(), None);
            let report = pipeline
                .drain_round(&mut t1, &plan, &codec, &mut resident)
                .unwrap();
            assert!(!report.degraded, "{mode:?}");
            let mut t2 = send_round(&plan, codec.as_ref(), None);
            drain_round(
                &mut t2,
                &plan,
                codec.as_ref(),
                &mut oracle,
                DrainConfig::serial(mode).with_policy(relaxed),
                &ScratchPool::new(),
            )
            .unwrap();
            assert_eq!(resident.theta_g, oracle.theta_g, "{mode:?}");
        }
    }

    #[test]
    fn sharded_resident_drain_requires_a_sharded_aggregator() {
        let codec = fedpm_codec();
        let pipeline = DrainPipeline::new(DrainConfig::sharded(PipelineMode::Streaming, 2, 3));
        let mut server = MaskServer::with_theta0(32, 1.0, 0.5);
        let plan = plan_of(2, 0);
        let mut t = send_round(&plan, codec.as_ref(), None);
        let err = pipeline
            .drain_round(&mut t, &plan, &codec, &mut server)
            .unwrap_err();
        assert!(err.to_string().contains("dimension-sharded"), "{err}");
    }

    #[test]
    fn results_queue_disconnect_and_abort_semantics() {
        let q: ResultsQueue<u32> = ResultsQueue::new(2, 1);
        q.push(7);
        assert_eq!(q.try_pop(), Some(7));
        assert_eq!(q.try_pop(), None);
        q.producer_done();
        assert_eq!(q.pop(), None, "empty + no producers = disconnect");

        let q: ResultsQueue<u32> = ResultsQueue::new(1, 1);
        q.push(1);
        q.abort();
        q.push(2); // discarded, does not block even though cap is 1
        q.producer_done();
        assert_eq!(q.pop(), None, "aborted queue drains to disconnect");
    }
}
