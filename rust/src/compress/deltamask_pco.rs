//! **DeltaMask-pco** (codec 9) — DeltaMask's Δ′ selection with a numeric
//! latent payload instead of a probabilistic filter + PNG.
//!
//! The filter pipeline fingerprints Δ′ into a near-uniform byte array
//! (≈ 9 bits/key for BFuse8, incompressible by construction) and pays an
//! O(d) membership sweep plus a ≈ 2^-8 false-positive mask-noise floor at
//! decode. This codec instead transmits the **sorted Δ′ index set
//! directly** as a [`crate::codec::pco`] stream: delta coding turns the
//! sorted indexes into small gaps, and the quantile-bin adaptive packing
//! codes them near the gap entropy ≈ log2(d/|Δ′|) + 1.44 bits/key — for the
//! paper's sparse late-training regimes that is 20–35% below the filter,
//! with **exact** reconstruction (no false positives) and an O(|Δ′|) decode
//! in place of the O(d) sweep.
//!
//! Wire format (record tag 7, one past the v1 filter-tag space 0..=6, so a
//! v1 decoder rejects these records cleanly instead of misreading them):
//!
//! ```text
//! tag(1)=7  version(1)=1  payload_len(4)  payload = pco stream of sorted Δ′
//! ```
//!
//! Decode totality: the pco stream decoder is total, decoded indexes are
//! validated strictly increasing and `< d`, and `d` bounds the decoded
//! count — corrupt records yield `Err`, never a panic or a wild write.

use super::deltamask::DeltaMaskCodec;
use super::{
    wire, DecodeCtx, EncodeCtx, EncodeScratch, Encoded, Family, Ranking, ScratchPool, Update,
    UpdateCodec,
};
use crate::codec::pco;
use anyhow::{ensure, Result};

/// Record tag: one past the filter-tag space (0..=6) of the v1 wire format.
pub const RECORD_TAG: u8 = 7;
/// Record format version.
pub const RECORD_VERSION: u8 = 1;

#[derive(Clone, Debug)]
pub struct DeltaMaskPcoCodec {
    pub ranking: Ranking,
}

impl Default for DeltaMaskPcoCodec {
    fn default() -> Self {
        Self {
            ranking: Ranking::Kl,
        }
    }
}

impl DeltaMaskPcoCodec {
    /// Parse + validate a record into the sorted Δ′ index set. Shared by
    /// every decode path, so malformed-record rejection is uniform.
    fn parse_indexes(&self, bytes: &[u8], ctx: &DecodeCtx) -> Result<Vec<u32>> {
        ensure!(bytes.len() >= 6, "deltamask-pco record too short");
        ensure!(
            bytes[0] == RECORD_TAG,
            "not a deltamask-pco record (tag {})",
            bytes[0]
        );
        ensure!(
            bytes[1] == RECORD_VERSION,
            "unknown deltamask-pco record version {}",
            bytes[1]
        );
        let mut r = wire::Reader::new(&bytes[2..]);
        let payload_len = r.u32()? as usize;
        let rest = &bytes[2 + r.pos..];
        ensure!(rest.len() == payload_len, "payload length mismatch");
        let idx =
            pco::decompress_u32s(rest, ctx.d).map_err(|e| anyhow::anyhow!("pco: {e}"))?;
        let mut prev = None;
        for &i in &idx {
            ensure!((i as usize) < ctx.d, "index {i} out of range (d={})", ctx.d);
            if let Some(p) = prev {
                ensure!(i > p, "indexes not strictly increasing");
            }
            prev = Some(i);
        }
        Ok(idx)
    }
}

/// A parsed record is its own range decoder: flips within a range are found
/// by two binary searches over the sorted index set — O(log n + hits) per
/// range, with no per-index sweep at all.
struct SortedIndexFlips {
    idx: Vec<u32>,
}

impl super::MaskRangeDecoder for SortedIndexFlips {
    fn decode_range(&self, range: std::ops::Range<usize>, mask: &mut [f32]) {
        debug_assert_eq!(mask.len(), range.len());
        let lo = self.idx.partition_point(|&i| (i as usize) < range.start);
        let hi = self.idx.partition_point(|&i| (i as usize) < range.end);
        for &i in &self.idx[lo..hi] {
            let j = i as usize - range.start;
            mask[j] = 1.0 - mask[j];
        }
    }
}

impl UpdateCodec for DeltaMaskPcoCodec {
    fn name(&self) -> &'static str {
        "deltamask-pco"
    }

    fn family(&self) -> Family {
        Family::Mask
    }

    fn encode(&self, ctx: &EncodeCtx) -> Result<Encoded> {
        self.encode_with(ctx, &mut EncodeScratch::default())
    }

    /// Encode reusing the caller's scratch: Δ′ selection is DeltaMask's own
    /// fused single-pass kernel (same ranking, same truncation — the two
    /// codecs select identical update sets), and the quickselect index
    /// buffer is recycled as the u32 sort buffer afterwards, so the
    /// steady-state encode allocates only the output bytes.
    fn encode_with(&self, ctx: &EncodeCtx, scratch: &mut EncodeScratch) -> Result<Encoded> {
        let selector = DeltaMaskCodec {
            ranking: self.ranking,
            ..Default::default()
        };
        selector.select_updates_into(ctx, scratch);
        scratch.rank.clear();
        scratch.rank.extend(scratch.keys.iter().map(|&k| k as u32));
        scratch.rank.sort_unstable();
        let payload = pco::compress_u32s(&scratch.rank);

        let mut bytes = Vec::with_capacity(payload.len() + 6);
        bytes.push(RECORD_TAG);
        bytes.push(RECORD_VERSION);
        wire::put_u32(&mut bytes, payload.len() as u32);
        bytes.extend_from_slice(&payload);
        Ok(Encoded { bytes })
    }

    fn decode(&self, bytes: &[u8], ctx: &DecodeCtx) -> Result<Update> {
        let idx = self.parse_indexes(bytes, ctx)?;
        let mut mask = ctx.mask_g.to_vec();
        for &i in &idx {
            mask[i as usize] = 1.0 - mask[i as usize];
        }
        Ok(Update::Mask(mask))
    }

    fn decode_pooled(&self, bytes: &[u8], ctx: &DecodeCtx, pool: &ScratchPool) -> Result<Update> {
        // Parse before leasing, so malformed records never touch the pool.
        let idx = self.parse_indexes(bytes, ctx)?;
        let mut mask = pool.take_copy(ctx.mask_g);
        for &i in &idx {
            mask[i as usize] = 1.0 - mask[i as usize];
        }
        Ok(Update::Mask(mask))
    }

    fn range_decoder(
        &self,
        bytes: &[u8],
        ctx: &DecodeCtx,
    ) -> Result<Option<Box<dyn super::MaskRangeDecoder>>> {
        let idx = self.parse_indexes(bytes, ctx)?;
        Ok(Some(Box::new(SortedIndexFlips { idx })))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::sample_mask_seeded;
    use crate::util::rng::Xoshiro256pp;

    fn make_ctx<'a>(
        d: usize,
        theta_k: &'a [f32],
        theta_g: &'a [f32],
        mask_k: &'a [f32],
        mask_g: &'a [f32],
        kappa: f64,
    ) -> EncodeCtx<'a> {
        EncodeCtx {
            d,
            theta_k,
            theta_g,
            mask_k,
            mask_g,
            s_k: &[],
            s_g: &[],
            kappa,
            seed: 99,
        }
    }

    fn setup(d: usize, drift: f32, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Xoshiro256pp::new(seed);
        let theta_g: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
        let theta_k: Vec<f32> = theta_g
            .iter()
            .map(|&p| (p + drift * (rng.next_f32() - 0.5)).clamp(0.01, 0.99))
            .collect();
        let mut mask_g = Vec::new();
        sample_mask_seeded(&theta_g, 7, &mut mask_g);
        let mut mask_k = Vec::new();
        sample_mask_seeded(&theta_k, 8, &mut mask_k);
        (theta_k, theta_g, mask_k, mask_g)
    }

    #[test]
    fn roundtrip_is_exact_not_probabilistic() {
        // The filter paths carry a 2^-bpe false-positive noise floor; the
        // pco index stream must reconstruct the selected update exactly.
        let d = 100_000;
        let (tk, tg, mk, mg) = setup(d, 0.1, 41);
        let codec = DeltaMaskPcoCodec::default();
        let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 1.0);
        let enc = codec.encode(&ctx).unwrap();
        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mg,
            s_g: &[],
            seed: 99,
        };
        let Update::Mask(m) = codec.decode(&enc.bytes, &dec_ctx).unwrap() else {
            panic!()
        };
        assert_eq!(m, mk, "κ=1 pco decode must equal the client mask exactly");
    }

    #[test]
    fn kappa_truncation_flips_exactly_the_selected_set() {
        let d = 50_000;
        let (tk, tg, mk, mg) = setup(d, 0.2, 42);
        let codec = DeltaMaskPcoCodec::default();
        let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 0.6);
        let selected = DeltaMaskCodec::default().select_updates(&ctx);
        let enc = codec.encode(&ctx).unwrap();
        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mg,
            s_g: &[],
            seed: 99,
        };
        let Update::Mask(m) = codec.decode(&enc.bytes, &dec_ctx).unwrap() else {
            panic!()
        };
        let mut expect = mg.clone();
        for &i in &selected {
            expect[i as usize] = 1.0 - expect[i as usize];
        }
        assert_eq!(m, expect);
    }

    #[test]
    fn scratch_pooled_and_range_paths_are_identical() {
        let d = 30_000;
        let (tk, tg, mk, mg) = setup(d, 0.1, 43);
        let codec = DeltaMaskPcoCodec::default();
        let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 0.8);
        let plain = codec.encode(&ctx).unwrap();
        let mut scratch = EncodeScratch::default();
        let scratched = codec.encode_with(&ctx, &mut scratch).unwrap();
        assert_eq!(plain.bytes, scratched.bytes);
        let again = codec.encode_with(&ctx, &mut scratch).unwrap();
        assert_eq!(plain.bytes, again.bytes);

        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mg,
            s_g: &[],
            seed: 99,
        };
        let Update::Mask(want) = codec.decode(&plain.bytes, &dec_ctx).unwrap() else {
            panic!()
        };
        let pool = ScratchPool::new();
        let Update::Mask(got) = codec.decode_pooled(&plain.bytes, &dec_ctx, &pool).unwrap()
        else {
            panic!()
        };
        assert_eq!(got, want);
        pool.put(got);
        let Update::Mask(got2) = codec.decode_pooled(&plain.bytes, &dec_ctx, &pool).unwrap()
        else {
            panic!()
        };
        assert_eq!(got2, want);
        assert_eq!(pool.spares(), 0, "pooled decode must draw from the pool");

        // Range tiling reproduces the full decode bitwise.
        let rd = codec
            .range_decoder(&plain.bytes, &dec_ctx)
            .unwrap()
            .expect("pco records support range decoding");
        let mut tiled = mg.clone();
        let cuts = [0usize, 1, 2, 2, d / 3, d / 2 + 7, d];
        for w in cuts.windows(2) {
            let (lo, hi) = (w[0], w[1]);
            rd.decode_range(lo..hi, &mut tiled[lo..hi]);
        }
        assert_eq!(tiled, want);
    }

    #[test]
    fn beats_the_png_deflate_payload_on_sparse_updates() {
        // Late-training 2% drift at d=327680 — the hardest (sparsest) shape:
        // the gap entropy alone is ~7.4 bits/key, so the pco stream sits within
        // ~1 bit of the entropy floor while BFuse8+PNG pays ~10 bits/key. We
        // pin a 10% floor here; the ISSUE's ≥ 20% target is asserted on the
        // tracked dense fixture (second half of this test), where the margin
        // exceeds 50%.
        let d = 327_680;
        let mut rng = Xoshiro256pp::new(4);
        let theta_g: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
        let mut mask_g = Vec::new();
        sample_mask_seeded(&theta_g, 5, &mut mask_g);
        let mut mask_k = mask_g.clone();
        let mut flipped = 0;
        while flipped < d / 50 {
            let i = rng.below(d as u64) as usize;
            mask_k[i] = 1.0 - mask_k[i];
            flipped += 1;
        }
        let ctx = make_ctx(d, &theta_g, &theta_g, &mask_k, &mask_g, 0.8);
        let png_bytes = DeltaMaskCodec::default().encode(&ctx).unwrap().bytes.len();
        let pco_bytes = DeltaMaskPcoCodec::default()
            .encode(&ctx)
            .unwrap()
            .bytes
            .len();
        assert!(
            pco_bytes * 10 <= png_bytes * 9,
            "sparse: pco={pco_bytes} png={png_bytes}: needs ≥ 10% reduction"
        );

        // Dense fixture — the shape the tracked hotpaths / ablation cases
        // measure (independently drawn masks, ~50% coordinate disagreement):
        // here the ISSUE's ≥ 20% bytes-on-wire target must hold outright.
        let d = 100_000;
        let mut rng = Xoshiro256pp::new(7);
        let theta_g: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
        let mut mask_g = Vec::new();
        sample_mask_seeded(&theta_g, 11, &mut mask_g);
        let mut mask_k = Vec::new();
        sample_mask_seeded(&theta_g, 12, &mut mask_k);
        let ctx = make_ctx(d, &theta_g, &theta_g, &mask_k, &mask_g, 0.8);
        let png_bytes = DeltaMaskCodec::default().encode(&ctx).unwrap().bytes.len();
        let pco_bytes = DeltaMaskPcoCodec::default()
            .encode(&ctx)
            .unwrap()
            .bytes
            .len();
        assert!(
            pco_bytes * 10 <= png_bytes * 8,
            "dense: pco={pco_bytes} png={png_bytes}: needs ≥ 20% reduction"
        );
    }

    #[test]
    fn empty_delta_roundtrip() {
        let d = 1000;
        let theta = vec![0.5f32; d];
        let mut mask = Vec::new();
        sample_mask_seeded(&theta, 1, &mut mask);
        let codec = DeltaMaskPcoCodec::default();
        let ctx = make_ctx(d, &theta, &theta, &mask, &mask, 0.8);
        let enc = codec.encode(&ctx).unwrap();
        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mask,
            s_g: &[],
            seed: 99,
        };
        let Update::Mask(m) = codec.decode(&enc.bytes, &dec_ctx).unwrap() else {
            panic!()
        };
        assert_eq!(m, mask);
    }

    #[test]
    fn malformed_records_error_instead_of_panicking() {
        let d = 10_000;
        let (tk, tg, mk, mg) = setup(d, 0.1, 44);
        let codec = DeltaMaskPcoCodec::default();
        let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 1.0);
        let enc = codec.encode(&ctx).unwrap();
        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mg,
            s_g: &[],
            seed: 99,
        };
        // Wrong record tag (a v1 filter record) and wrong version.
        let mut bad = enc.bytes.clone();
        bad[0] = 0;
        assert!(codec.decode(&bad, &dec_ctx).is_err());
        let mut bad = enc.bytes.clone();
        bad[1] = RECORD_VERSION + 1;
        assert!(codec.decode(&bad, &dec_ctx).is_err());
        // Truncations.
        for cut in [0, 3, 6, enc.bytes.len() - 1] {
            assert!(codec.decode(&enc.bytes[..cut], &dec_ctx).is_err(), "cut={cut}");
        }
        // A v1 decoder must reject tag-7 records rather than misread them.
        assert!(DeltaMaskCodec::default().decode(&enc.bytes, &dec_ctx).is_err());
        // And d bounds the index range: decoding against a smaller model
        // dimension rejects out-of-range indexes.
        let small_mg = vec![0.0f32; 4];
        let small_ctx = DecodeCtx {
            d: 4,
            mask_g: &small_mg,
            s_g: &[],
            seed: 99,
        };
        assert!(codec.decode(&enc.bytes, &small_ctx).is_err());
    }
}
