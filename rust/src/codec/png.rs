//! Minimal PNG (ISO/IEC 15948) encoder/decoder for **8-bit grayscale**
//! images — the `A_{k,t}` carrier of DeltaMask (§3.2): the binary fuse
//! fingerprint array is reshaped into a near-square grayscale image and
//! compressed losslessly (PNG = scanline filtering + DEFLATE/zlib).
//!
//! The five standard scanline filters (None/Sub/Up/Average/Paeth) are
//! implemented with the minimum-sum-of-absolute-differences heuristic, which
//! is what lets PNG exploit "non-uniform distributions of entries across the
//! fingerprint locations" beyond raw DEFLATE.

use super::crc::crc32;
use super::deflate::{zlib_compress, zlib_compress_fast, zlib_decompress};

const PNG_SIG: [u8; 8] = [0x89, b'P', b'N', b'G', b'\r', b'\n', 0x1a, b'\n'];

/// An 8-bit grayscale image.
#[derive(Clone, Debug, PartialEq)]
pub struct GrayImage {
    pub width: u32,
    pub height: u32,
    pub pixels: Vec<u8>, // row-major, width*height
}

impl GrayImage {
    pub fn new(width: u32, height: u32, pixels: Vec<u8>) -> Self {
        assert_eq!(pixels.len(), (width * height) as usize);
        Self {
            width,
            height,
            pixels,
        }
    }

    /// Pack an arbitrary byte payload into a near-square image, padding the
    /// tail with zeros. The true byte length travels in the DeltaMask record
    /// header, not the image.
    pub fn from_payload(payload: &[u8]) -> Self {
        let n = payload.len().max(1);
        let width = (n as f64).sqrt().ceil() as u32;
        let height = (n as u32).div_ceil(width).max(1);
        let mut pixels = vec![0u8; (width * height) as usize];
        pixels[..payload.len()].copy_from_slice(payload);
        Self {
            width,
            height,
            pixels,
        }
    }

    pub fn payload(&self, len: usize) -> &[u8] {
        &self.pixels[..len]
    }
}

fn paeth(a: i32, b: i32, c: i32) -> u8 {
    let p = a + b - c;
    let pa = (p - a).abs();
    let pb = (p - b).abs();
    let pc = (p - c).abs();
    if pa <= pb && pa <= pc {
        a as u8
    } else if pb <= pc {
        b as u8
    } else {
        c as u8
    }
}

/// Apply filter `ft` to `row` given `prev` row; returns filtered bytes.
fn filter_row(ft: u8, row: &[u8], prev: &[u8]) -> Vec<u8> {
    let w = row.len();
    let mut out = Vec::with_capacity(w);
    for i in 0..w {
        let x = row[i] as i32;
        let a = if i > 0 { row[i - 1] as i32 } else { 0 };
        let b = prev[i] as i32;
        let c = if i > 0 { prev[i - 1] as i32 } else { 0 };
        let f = match ft {
            0 => x,
            1 => x - a,
            2 => x - b,
            3 => x - (a + b) / 2,
            4 => x - paeth(a, b, c) as i32,
            _ => unreachable!(),
        };
        out.push(f as u8);
    }
    out
}

fn unfilter_row(ft: u8, row: &mut [u8], prev: &[u8]) -> Result<(), String> {
    let w = row.len();
    for i in 0..w {
        let a = if i > 0 { row[i - 1] as i32 } else { 0 };
        let b = prev[i] as i32;
        let c = if i > 0 { prev[i - 1] as i32 } else { 0 };
        let f = row[i] as i32;
        row[i] = match ft {
            0 => f as u8,
            1 => (f + a) as u8,
            2 => (f + b) as u8,
            3 => (f + (a + b) / 2) as u8,
            4 => (f + paeth(a, b, c) as i32) as u8,
            _ => return Err(format!("bad filter type {ft}")),
        };
    }
    Ok(())
}

fn chunk(out: &mut Vec<u8>, tag: &[u8; 4], data: &[u8]) {
    out.extend_from_slice(&(data.len() as u32).to_be_bytes());
    out.extend_from_slice(tag);
    out.extend_from_slice(data);
    let mut crc_input = Vec::with_capacity(4 + data.len());
    crc_input.extend_from_slice(tag);
    crc_input.extend_from_slice(data);
    out.extend_from_slice(&crc32(&crc_input).to_be_bytes());
}

/// Filtered scanline stream (filter-type byte + filtered row per scanline)
/// with the MSAD per-row filter choice — shared by both encoders.
fn filtered_scanlines(img: &GrayImage) -> Vec<u8> {
    let w = img.width as usize;
    let mut raw = Vec::with_capacity((w + 1) * img.height as usize);
    let zero_row = vec![0u8; w];
    for y in 0..img.height as usize {
        let row = &img.pixels[y * w..(y + 1) * w];
        let prev = if y == 0 {
            &zero_row[..]
        } else {
            &img.pixels[(y - 1) * w..y * w]
        };
        // MSAD heuristic: pick the filter minimizing sum of |signed residual|.
        let mut best_ft = 0u8;
        let mut best_cost = u64::MAX;
        let mut best_row: Vec<u8> = Vec::new();
        for ft in 0..=4u8 {
            let cand = filter_row(ft, row, prev);
            let cost: u64 = cand.iter().map(|&b| (b as i8).unsigned_abs() as u64).sum();
            if cost < best_cost {
                best_cost = cost;
                best_ft = ft;
                best_row = cand;
            }
        }
        raw.push(best_ft);
        raw.extend_from_slice(&best_row);
    }
    raw
}

fn assemble(img: &GrayImage, idat: Vec<u8>) -> Vec<u8> {
    let mut out = PNG_SIG.to_vec();
    let mut ihdr = Vec::with_capacity(13);
    ihdr.extend_from_slice(&img.width.to_be_bytes());
    ihdr.extend_from_slice(&img.height.to_be_bytes());
    ihdr.extend_from_slice(&[8, 0, 0, 0, 0]); // depth 8, gray, deflate, adaptive, no interlace
    chunk(&mut out, b"IHDR", &ihdr);
    chunk(&mut out, b"IDAT", &idat);
    chunk(&mut out, b"IEND", &[]);
    out
}

/// Encode to a PNG byte stream (color type 0, bit depth 8, no interlace).
pub fn encode(img: &GrayImage) -> Vec<u8> {
    assemble(img, zlib_compress(&filtered_scanlines(img)))
}

/// Like [`encode`] but compresses the IDAT with the fast DEFLATE match
/// finder ([`zlib_compress_fast`]). The output is a standard PNG any
/// decoder (including [`decode`]) reads; only the IDAT bytes differ, so
/// callers must gate it behind a wire version tag.
pub fn encode_fast(img: &GrayImage) -> Vec<u8> {
    assemble(img, zlib_compress_fast(&filtered_scanlines(img)))
}

/// Decode a grayscale-8 PNG produced by [`encode`] (also accepts any
/// single-IDAT or multi-IDAT gray8 non-interlaced PNG).
pub fn decode(data: &[u8]) -> Result<GrayImage, String> {
    if data.len() < 8 || data[..8] != PNG_SIG {
        return Err("not a PNG".into());
    }
    let mut pos = 8usize;
    let mut width = 0u32;
    let mut height = 0u32;
    let mut idat: Vec<u8> = Vec::new();
    let mut seen_ihdr = false;
    while pos + 8 <= data.len() {
        let len = u32::from_be_bytes(data[pos..pos + 4].try_into().unwrap()) as usize;
        let tag = &data[pos + 4..pos + 8];
        if pos + 8 + len + 4 > data.len() {
            return Err("truncated chunk".into());
        }
        let body = &data[pos + 8..pos + 8 + len];
        let crc_expect =
            u32::from_be_bytes(data[pos + 8 + len..pos + 12 + len].try_into().unwrap());
        let mut crc_input = Vec::with_capacity(4 + len);
        crc_input.extend_from_slice(tag);
        crc_input.extend_from_slice(body);
        if crc32(&crc_input) != crc_expect {
            return Err("chunk CRC mismatch".into());
        }
        match tag {
            b"IHDR" => {
                if len != 13 {
                    return Err("bad IHDR".into());
                }
                width = u32::from_be_bytes(body[0..4].try_into().unwrap());
                height = u32::from_be_bytes(body[4..8].try_into().unwrap());
                if body[8] != 8 || body[9] != 0 {
                    return Err("only gray8 supported".into());
                }
                if body[12] != 0 {
                    return Err("interlace unsupported".into());
                }
                seen_ihdr = true;
            }
            b"IDAT" => idat.extend_from_slice(body),
            b"IEND" => break,
            _ => {} // ancillary chunks ignored
        }
        pos += 12 + len;
    }
    if !seen_ihdr {
        return Err("missing IHDR".into());
    }
    let raw = zlib_decompress(&idat)?;
    let w = width as usize;
    if raw.len() != (w + 1) * height as usize {
        return Err("scanline data size mismatch".into());
    }
    let mut pixels = vec![0u8; w * height as usize];
    let zero_row = vec![0u8; w];
    for y in 0..height as usize {
        let ft = raw[y * (w + 1)];
        let src = &raw[y * (w + 1) + 1..(y + 1) * (w + 1)];
        // Copy then unfilter in place, referencing the already-unfiltered
        // previous row.
        let (done, cur) = pixels.split_at_mut(y * w);
        let prev = if y == 0 {
            &zero_row[..]
        } else {
            &done[(y - 1) * w..]
        };
        let row = &mut cur[..w];
        row.copy_from_slice(src);
        unfilter_row(ft, row, prev)?;
    }
    Ok(GrayImage {
        width,
        height,
        pixels,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn images() -> Vec<GrayImage> {
        let mut rng = Xoshiro256pp::new(3);
        let mut out = vec![
            GrayImage::new(1, 1, vec![0]),
            GrayImage::new(1, 1, vec![255]),
            GrayImage::new(7, 3, (0..21).collect()),
            GrayImage::new(64, 64, vec![128; 4096]),
        ];
        // Gradient (Sub/Up filters should win).
        let grad: Vec<u8> = (0..128 * 32).map(|i| (i % 256) as u8).collect();
        out.push(GrayImage::new(128, 32, grad));
        // Random noise.
        let noise: Vec<u8> = (0..100 * 100).map(|_| rng.next_u64() as u8).collect();
        out.push(GrayImage::new(100, 100, noise));
        out
    }

    #[test]
    fn roundtrip() {
        for img in images() {
            let png = encode(&img);
            let back = decode(&png).unwrap();
            assert_eq!(back, img);
        }
    }

    #[test]
    fn encode_fast_roundtrips_through_same_decoder() {
        for img in images() {
            let png = encode_fast(&img);
            let back = decode(&png).unwrap();
            assert_eq!(back, img);
        }
    }

    #[test]
    fn payload_packing_roundtrip() {
        let mut rng = Xoshiro256pp::new(5);
        for n in [0usize, 1, 100, 1000, 40_007] {
            let payload: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
            let img = GrayImage::from_payload(&payload);
            assert!(img.width as u64 * img.height as u64 >= n as u64);
            let png = encode(&img);
            let back = decode(&png).unwrap();
            assert_eq!(back.payload(n), &payload[..]);
        }
    }

    #[test]
    fn structured_image_compresses() {
        let img = GrayImage::new(256, 256, vec![7; 65536]);
        let png = encode(&img);
        assert!(png.len() < 2048, "constant image should be tiny, got {}", png.len());
    }

    #[test]
    fn signature_and_garbage_rejected() {
        assert!(decode(b"not a png at all").is_err());
        let mut png = encode(&GrayImage::new(4, 4, vec![1; 16]));
        png[20] ^= 0xff; // corrupt IHDR body -> CRC fails
        assert!(decode(&png).is_err());
    }

    #[test]
    fn filter_unfilter_inverse_property() {
        let mut rng = Xoshiro256pp::new(8);
        for _ in 0..50 {
            let w = 1 + (rng.next_u64() % 40) as usize;
            let row: Vec<u8> = (0..w).map(|_| rng.next_u64() as u8).collect();
            let prev: Vec<u8> = (0..w).map(|_| rng.next_u64() as u8).collect();
            for ft in 0..=4u8 {
                let mut filtered = filter_row(ft, &row, &prev);
                unfilter_row(ft, &mut filtered, &prev).unwrap();
                assert_eq!(filtered, row, "filter {ft}");
            }
        }
    }
}
