//! Deterministic pseudo-random generators and samplers.
//!
//! The federated simulation must be bit-reproducible across runs and across
//! the server/client boundary (the paper's shared-seed deterministic mask
//! sampling, §3.2), so every stochastic decision in the system flows through
//! these seeded generators — never through `std` hash randomness or OS
//! entropy.

/// SplitMix64 — tiny, fast, passes BigCrush when used as a stream; also the
/// canonical seeding sequence for xoshiro.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the workhorse generator for all simulation randomness.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent child stream; used to give every (client, round)
    /// pair its own generator without coordination.
    pub fn fork(&mut self, tag: u64) -> Self {
        let mix = self.next_u64() ^ tag.wrapping_mul(0xd1342543de82ef95);
        Self::new(mix)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1) with 24 bits of mantissa (f32-exact).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53 bits of mantissa.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Unbiased integer in [0, n) (Lemire's multiply-shift with rejection).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    pub fn fill_f32_uniform(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.next_f32();
        }
    }

    /// Standard normal via Box–Muller (pairwise, cache-free for simplicity —
    /// data-gen is not on the hot path).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn fill_gaussian_f32(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = mean + std * self.next_gaussian() as f32;
        }
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang, with the shape<1 boost.
    pub fn next_gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0);
        if shape < 1.0 {
            // Gamma(a) = Gamma(a+1) * U^{1/a}
            let g = self.next_gamma(shape + 1.0);
            let u = self.next_f64().max(1e-300);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.next_gaussian();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x * x * x * x {
                return d * v;
            }
            if u > 1e-300 && u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * 1_k): the paper's Dir(a) label-split sampler.
    pub fn next_dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.next_gamma(alpha)).collect();
        let sum: f64 = g.iter().sum();
        if sum <= 0.0 {
            // Degenerate draw (can happen for very small alpha): one-hot.
            let hot = self.below(k as u64) as usize;
            let mut out = vec![0.0; k];
            out[hot] = 1.0;
            return out;
        }
        for v in g.iter_mut() {
            *v /= sum;
        }
        g
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indexes from [0, n) (partial Fisher–Yates).
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference sequence for seed 1234567 (from the published C code).
        let mut sm = SplitMix64::new(1234567);
        let v: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        // Self-consistency + determinism across calls.
        let mut sm2 = SplitMix64::new(1234567);
        let v2: Vec<u64> = (0..3).map(|_| sm2.next_u64()).collect();
        assert_eq!(v, v2);
        assert_ne!(v[0], v[1]);
    }

    #[test]
    fn xoshiro_uniform_mean() {
        let mut rng = Xoshiro256pp::new(42);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_small_range() {
        let mut rng = Xoshiro256pp::new(5);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Xoshiro256pp::new(9);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut rng = Xoshiro256pp::new(11);
        for shape in [0.1, 0.5, 1.0, 3.0, 10.0] {
            let n = 50_000;
            let mean: f64 = (0..n).map(|_| rng.next_gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() < 0.1 * shape.max(0.5),
                "shape={shape} mean={mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one_and_concentration_behaves() {
        let mut rng = Xoshiro256pp::new(13);
        // Large alpha -> near-uniform; small alpha -> spiky. Average the
        // max-coordinate over draws so the check is statistical, not
        // seed-dependent.
        let trials = 200;
        let mut max_flat = 0.0;
        let mut max_spiky = 0.0;
        for _ in 0..trials {
            let p_flat = rng.next_dirichlet(100.0, 10);
            let p_spiky = rng.next_dirichlet(0.05, 10);
            assert!((p_flat.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!((p_spiky.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            max_flat += p_flat.iter().cloned().fold(0.0, f64::max) / trials as f64;
            max_spiky += p_spiky.iter().cloned().fold(0.0, f64::max) / trials as f64;
        }
        assert!(max_flat < 0.25, "avg max (flat) = {max_flat}");
        assert!(max_spiky > 0.6, "avg max (spiky) = {max_spiky}");
    }

    #[test]
    fn choose_distinct() {
        let mut rng = Xoshiro256pp::new(17);
        for _ in 0..100 {
            let mut c = rng.choose(30, 6);
            c.sort_unstable();
            c.dedup();
            assert_eq!(c.len(), 6);
            assert!(c.iter().all(|&i| i < 30));
        }
    }

    #[test]
    fn fork_streams_decorrelated() {
        let mut base = Xoshiro256pp::new(21);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..1000).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
