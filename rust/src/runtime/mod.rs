//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json`) and executes them from the L3 hot path.
//!
//! Python never runs here — the HLO text was produced once by
//! `python/compile/aot.py`; this module parses it with
//! `HloModuleProto::from_text_file`, compiles it on the PJRT CPU client and
//! executes with concrete buffers. One compiled executable per (combo,
//! graph), cached for the whole process lifetime.

pub mod executor;
pub mod manifest;
pub mod xla_backend;

pub use executor::{Executor, GraphHandle};
pub use manifest::{ComboSpec, GraphSpec, Manifest, TensorSpec};
pub use xla_backend::XlaBackend;

/// Locate the artifacts directory: `$DELTAMASK_ARTIFACTS`, else walk up
/// from the current directory looking for `artifacts/manifest.json` (so
/// `cargo test` / `cargo bench` work from any cwd).
pub fn artifacts_dir() -> Option<std::path::PathBuf> {
    if let Ok(p) = std::env::var("DELTAMASK_ARTIFACTS") {
        let p = std::path::PathBuf::from(p);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    let mut cur = std::env::current_dir().ok()?;
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return Some(cand);
        }
        if !cur.pop() {
            return None;
        }
    }
}
