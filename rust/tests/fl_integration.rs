//! End-to-end federated integration tests: DeltaMask training improves
//! accuracy at sub-1 bpp, baselines behave per the paper's ordering, and
//! both execution backends drive the same coordinator.

use deltamask::fl::{run_experiment, BackendKind, ExperimentConfig, HeadInit};

fn base_cfg() -> ExperimentConfig {
    ExperimentConfig {
        dataset: "cifar10".into(),
        arch: "test".into(),
        method: "deltamask".into(),
        n_clients: 6,
        rounds: 12,
        rho: 1.0,
        local_epochs: 1,
        samples_per_client: 48,
        test_samples: 200,
        dirichlet_alpha: 10.0,
        kappa0: 0.8,
        kappa_floor: 0.25,
        seed: 7,
        eval_every: 3,
        backend: BackendKind::Native,
        head_init: HeadInit::Lp,
        lp_rounds: 1,
        theta0: 0.85,
        arch_override: None,
    }
}

#[test]
fn deltamask_trains_at_sub_one_bpp_native() {
    let cfg = base_cfg();
    let res = run_experiment(&cfg).expect("experiment failed");
    let acc = res.final_accuracy();
    assert!(acc > 0.5, "final accuracy {acc} too low");
    let bpp = res.avg_bpp();
    assert!(bpp < 1.0, "avg bpp {bpp} should be < 1 (paper headline)");
    assert!(bpp > 0.0);
    // bpp decays as updates sparsify: late rounds cheaper than round 0.
    let first = res.rounds.first().unwrap().mean_bpp;
    let last = res.rounds.last().unwrap().mean_bpp;
    assert!(last < first, "bpp should decay: first={first} last={last}");
}

#[test]
fn deltamask_matches_fedpm_accuracy_with_lower_bpp() {
    let mut cfg = base_cfg();
    cfg.rounds = 10;
    let dm = run_experiment(&cfg).unwrap();
    cfg.method = "fedpm".into();
    let pm = run_experiment(&cfg).unwrap();
    // Paper Fig. 3: DeltaMask ≈ FedPM accuracy at a fraction of the bitrate.
    assert!(
        dm.final_accuracy() > pm.final_accuracy() - 0.1,
        "deltamask {} vs fedpm {}",
        dm.final_accuracy(),
        pm.final_accuracy()
    );
    assert!(
        dm.avg_bpp() < pm.avg_bpp() * 0.6,
        "deltamask bpp {} should be well under fedpm {}",
        dm.avg_bpp(),
        pm.avg_bpp()
    );
}

#[test]
fn all_methods_run_and_report_metrics() {
    for method in [
        "deltamask", "fedpm", "fedmask", "deepreduce", "eden", "drive", "qsgd", "fedcode",
        "linear_probing", "fine_tuning",
    ] {
        let mut cfg = base_cfg();
        cfg.method = method.into();
        cfg.rounds = 3;
        cfg.eval_every = 3;
        let res = run_experiment(&cfg)
            .unwrap_or_else(|e| panic!("method {method} failed: {e}"));
        assert_eq!(res.rounds.len(), 3, "{method}");
        assert!(res.final_accuracy() > 0.0, "{method}");
        assert!(res.avg_bpp() > 0.0, "{method}");
    }
}

#[test]
fn noniid_split_still_learns() {
    let mut cfg = base_cfg();
    cfg.dirichlet_alpha = 0.1;
    cfg.rho = 0.5;
    cfg.rounds = 24;
    cfg.eval_every = 6;
    let res = run_experiment(&cfg).unwrap();
    // Non-IID at partial participation converges slowly (the paper runs 300
    // rounds); at this miniature scale we only require clear learning.
    assert!(
        res.final_accuracy() > 0.25,
        "non-IID accuracy {}",
        res.final_accuracy()
    );
}

#[test]
fn xla_backend_end_to_end() {
    // The production path: AOT Pallas/JAX graphs through PJRT.
    let mut cfg = base_cfg();
    cfg.backend = BackendKind::Xla;
    cfg.rounds = 4;
    cfg.eval_every = 2;
    cfg.n_clients = 3;
    let res = run_experiment(&cfg).expect("run `make artifacts` first");
    assert!(res.final_accuracy() > 0.3, "acc {}", res.final_accuracy());
    assert!(res.avg_bpp() < 1.5);
}

#[test]
fn xla_and_native_agree_on_trained_accuracy() {
    let mut cfg = base_cfg();
    cfg.rounds = 5;
    cfg.eval_every = 5;
    cfg.n_clients = 3;
    cfg.samples_per_client = 24;
    let native = run_experiment(&cfg).unwrap();
    cfg.backend = BackendKind::Xla;
    let xla = run_experiment(&cfg).unwrap();
    // Same seeds, same math (mod f32 associativity): accuracies land close.
    assert!(
        (native.final_accuracy() - xla.final_accuracy()).abs() < 0.15,
        "native {} vs xla {}",
        native.final_accuracy(),
        xla.final_accuracy()
    );
}

#[test]
fn head_init_variants_ordering() {
    // Table 5: LP ≥ FiT ≥ He.
    let mut accs = std::collections::HashMap::new();
    for (name, init) in [("lp", HeadInit::Lp), ("fit", HeadInit::Fit), ("he", HeadInit::He)] {
        let mut cfg = base_cfg();
        cfg.head_init = init;
        cfg.rounds = 10;
        cfg.eval_every = 5;
        let res = run_experiment(&cfg).unwrap();
        accs.insert(name, res.final_accuracy());
    }
    assert!(
        accs["lp"] >= accs["he"] - 0.05,
        "LP {} should beat He {}",
        accs["lp"],
        accs["he"]
    );
}
