//! Networked uplink: a length-prefixed framed transport over TCP and
//! Unix-domain sockets implementing [`Transport`]/[`TransportSender`].
//!
//! ## Frame format
//!
//! Every frame is a 16-byte little-endian header followed by `len` payload
//! bytes:
//!
//! ```text
//! offset  size  field
//!      0     4  magic  "DMW1"
//!      4     1  version (1)
//!      5     1  kind    (1=Update 2=Failed 3=Hello 4=Plan 5=EndOfRound 6=Shutdown
//!                        7=ShardHello 8=ShardBegin 9=ShardSplit 10=ShardFinish
//!                        11=ShardAbort 12=ShardSlice)
//!      6     2  reserved, must be zero
//!      8     4  session — logical client id for data frames; this is what
//!               lets M OS connections carry K ≫ M multiplexed clients
//!     12     4  len     — payload bytes, ≤ the configured max frame size
//! ```
//!
//! Decode is *total*: [`parse_header`]/[`parse_frame`] are bounds-checked
//! pure functions over byte slices that return errors, never panic, for
//! any input. A frame whose header is valid but whose payload is garbage
//! is skipped (the length keeps the stream in sync) and counted; a frame
//! whose header is invalid kills the connection (a length-prefixed stream
//! cannot resync after a bogus length), surfacing as missing senders in
//! the drain. Garbage *codec* bytes inside a structurally-valid `Update`
//! flow through to the round gate, where they fail the codecs'
//! bounds-checked decode and count as `FaultCounters.corrupt` — exactly
//! like chaos-injected corruption.
//!
//! ## Backpressure
//!
//! Each connection gets a dedicated reader thread feeding one bounded
//! inbound queue. Admission enforces a global byte budget plus a
//! per-connection byte budget; a reader whose frame does not fit *blocks*
//! (counted in [`TransportStats::backpressure_stalls`]) instead of
//! buffering, so the kernel socket buffer fills and flow control
//! propagates to the client's `send` — a slow coordinator slows the fleet
//! down rather than OOMing. One frame per connection always makes
//! progress even when it alone exceeds a budget, so oversized-but-legal
//! frames cannot deadlock admission.
//!
//! ## Lifecycles
//!
//! Two wirings share all of the above:
//!
//! * **Loopback** ([`SocketHub`]): one experiment binds once, each round
//!   connects a fresh set of M connections. Dropping the round's last
//!   sender closes the sockets, the readers see EOF and the transport
//!   reports `Closed` — the exact semantics of the per-round
//!   [`ChannelTransport`], which is what makes channel↔socket trajectory
//!   identity hold by construction.
//! * **Two-process** ([`FleetServer`]/[`FleetLink`]): connections persist
//!   across rounds, so closure is protocol-level instead: the fleet marks
//!   each connection with an `EndOfRound` frame after its round's sends,
//!   and the server's between-rounds [`FleetServer::end_round`] waits for
//!   those marks while discarding whatever the drain left unread —
//!   the per-round accounting a dropped channel would have produced.
//!
//! [`ChannelTransport`]: super::ChannelTransport

use super::super::aggregate::Aggregator;
use super::super::shard::WireSlice;
use super::{Counters, Payload, RecvOutcome, Transport, TransportSender, TransportStats, WireMessage};
use crate::compress::{Encoded, Update};
use crate::coordinator::round::RoundPlan;
use crate::util::timer::Stopwatch;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::ops::Range;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Which uplink implementation an experiment runs on.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process mpsc channel (the simulation default).
    #[default]
    Channel,
    /// Framed TCP socket (loopback in-process, or `serve`/`client-fleet`).
    Tcp,
    /// Framed Unix-domain socket.
    Uds,
}

impl TransportKind {
    /// Parse `channel` / `tcp` / `uds` (alias `unix`). `None` on anything
    /// else so config layers can fail loudly with their own message.
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim() {
            "channel" => Some(Self::Channel),
            "tcp" => Some(Self::Tcp),
            "uds" | "unix" => Some(Self::Uds),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Channel => "channel",
            Self::Tcp => "tcp",
            Self::Uds => "uds",
        }
    }
}

/// Admission budgets and the frame-size cap.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SocketConfig {
    /// Hard per-frame payload cap; a header announcing more is treated as
    /// stream corruption (connection-fatal).
    pub max_frame: usize,
    /// Global bound on queued inbound bytes across all connections.
    pub inbound_budget: usize,
    /// Per-connection bound on queued inbound bytes.
    pub conn_budget: usize,
}

impl Default for SocketConfig {
    fn default() -> Self {
        Self {
            max_frame: 64 << 20,
            inbound_budget: 8 << 20,
            conn_budget: 2 << 20,
        }
    }
}

impl SocketConfig {
    /// Read `DELTAMASK_MAX_FRAME_BYTES` / `DELTAMASK_INBOUND_BUDGET_BYTES`
    /// / `DELTAMASK_CONN_BUDGET_BYTES`. Empty or unset keeps the default;
    /// malformed values panic loudly rather than silently running a
    /// different configuration than asked.
    pub fn from_env() -> Self {
        fn knob(name: &str, default: usize) -> usize {
            match std::env::var(name) {
                Ok(v) if v.is_empty() => default,
                Ok(v) => v
                    .parse()
                    .unwrap_or_else(|_| panic!("{name} must be a byte count, got `{v}`")),
                Err(_) => default,
            }
        }
        let d = Self::default();
        Self {
            max_frame: knob("DELTAMASK_MAX_FRAME_BYTES", d.max_frame),
            inbound_budget: knob("DELTAMASK_INBOUND_BUDGET_BYTES", d.inbound_budget),
            conn_budget: knob("DELTAMASK_CONN_BUDGET_BYTES", d.conn_budget),
        }
    }
}

// ---------------------------------------------------------------------------
// Frame codec — total, bounds-checked, pure.
// ---------------------------------------------------------------------------

pub const MAGIC: [u8; 4] = *b"DMW1";
pub const VERSION: u8 = 1;
pub const HEADER_LEN: usize = 16;

const K_UPDATE: u8 = 1;
const K_FAILED: u8 = 2;
const K_HELLO: u8 = 3;
const K_PLAN: u8 = 4;
const K_EOR: u8 = 5;
const K_SHUTDOWN: u8 = 6;
// Shard-fabric frames: a coordinator's remote absorb lane talking to a
// `deltamask shard-worker` process (see `coordinator::shard`).
const K_SHARD_HELLO: u8 = 7;
const K_SHARD_BEGIN: u8 = 8;
const K_SHARD_SPLIT: u8 = 9;
const K_SHARD_FINISH: u8 = 10;
const K_SHARD_ABORT: u8 = 11;
const K_SHARD_SLICE: u8 = 12;

/// A validated frame header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameHeader {
    pub kind: u8,
    pub session: u32,
    pub len: usize,
}

/// Decoded frame payload.
#[derive(Clone, Debug)]
pub enum FrameBody {
    /// An uplink data record (`Update` or in-band `Failed`).
    Msg(WireMessage),
    /// Fleet handshake: connection identity plus a config fingerprint.
    Hello(Hello),
    /// Downlink round broadcast (raw; the mask is re-derived locally).
    Plan(PlanWire),
    /// The sending side has no more data frames for `round`.
    EndOfRound(u64),
    /// The experiment is over; the fleet should exit cleanly.
    Shutdown,
    /// Shard-lane handshake: config fingerprint, shard bounds and the
    /// encoded slice state seeding the worker (empty in the echo).
    ShardHello(ShardHello),
    /// Open one shard round; `seq` is strictly monotone per connection so
    /// a replayed round is rejected instead of double-counted.
    ShardBegin { seq: u64, expected: u64 },
    /// One routed sub-update for the in-flight shard round.
    ShardSplit(ShardSplit),
    /// Close the in-flight shard round (`partial` = degraded quorum); the
    /// worker finishes its slice and answers with a `ShardSlice`.
    ShardFinish { partial: bool },
    /// Abandon the in-flight shard round; the worker answers with the
    /// *unfinished* post-absorb slice (mirroring a parked local lane).
    ShardAbort,
    /// Worker → coordinator: the slice state after a finish or abort,
    /// plus the absorb compute seconds the worker spent this round.
    ShardSlice { absorb_secs: f64, state: Vec<u8> },
}

/// Fleet handshake record. The fingerprint catches the deadliest two-process
/// operator error — `serve` and `client-fleet` launched with different
/// experiment configs — before a single round runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Hello {
    pub conn_index: u32,
    pub conns_total: u32,
    pub fingerprint: ConfigFingerprint,
}

/// The config facts both processes must agree on for lockstep trajectories.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConfigFingerprint {
    pub seed: u64,
    pub n_clients: u64,
    pub rounds: u64,
    pub d: u64,
}

/// Shard-lane handshake record: the same fingerprint check the fleet
/// handshake runs, plus the dimension range this lane owns and the slice
/// state that seeds the worker (the coordinator's parked mirror, so a
/// reconnect resumes exactly where the lane left off).
#[derive(Clone, Debug, PartialEq)]
pub struct ShardHello {
    pub fingerprint: ConfigFingerprint,
    pub range_start: u64,
    pub range_end: u64,
    /// `WireSlice`-encoded slice state; empty in the worker's echo.
    pub state: Vec<u8>,
}

/// One routed sub-update: the record's slot, its update family and this
/// shard's contiguous sub-range of the decoded coefficients.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardSplit {
    pub slot: u32,
    /// 0 = mask family, 1 = score-delta family.
    pub family: u8,
    pub data: Vec<f32>,
}

/// Raw `Plan` frame contents. `mask_g` is never transmitted: it is a pure
/// function of `(theta_g, seed)` (§3.2 common random numbers), so the
/// fleet re-derives it via [`RoundPlan`]'s sampling path.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanWire {
    pub round: u64,
    pub seed: u64,
    pub kappa: f64,
    pub participants: Vec<u64>,
    pub theta_g: Vec<f32>,
    pub s_g: Vec<f32>,
}

impl PlanWire {
    pub fn from_plan(plan: &RoundPlan) -> Self {
        Self {
            round: plan.round as u64,
            seed: plan.seed,
            kappa: plan.kappa,
            participants: plan.participants.iter().map(|&p| p as u64).collect(),
            theta_g: plan.theta_g.clone(),
            s_g: plan.s_g.clone(),
        }
    }

    /// Rebuild the full broadcast plan, re-deriving the shared-seed global
    /// mask locally.
    pub fn into_round_plan(self) -> RoundPlan {
        let mut mask_g = Vec::new();
        crate::model::sample_mask_seeded(&self.theta_g, self.seed, &mut mask_g);
        RoundPlan {
            round: self.round as usize,
            seed: self.seed,
            kappa: self.kappa,
            participants: self.participants.iter().map(|&p| p as usize).collect(),
            mask_g,
            theta_g: self.theta_g,
            s_g: self.s_g,
        }
    }
}

/// Bounds-checked little-endian cursor; every read is fallible.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| anyhow!("frame truncated: need {n} bytes at offset {}", self.pos))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(n.checked_mul(4).ok_or_else(|| anyhow!("f32 run overflows"))?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn rest(&mut self) -> &'a [u8] {
        let s = &self.buf[self.pos..];
        self.pos = self.buf.len();
        s
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("frame has {} trailing bytes", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

fn header_bytes(kind: u8, session: u32, len: usize) -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[0..4].copy_from_slice(&MAGIC);
    h[4] = VERSION;
    h[5] = kind;
    // bytes 6..8 reserved, zero.
    h[8..12].copy_from_slice(&session.to_le_bytes());
    h[12..16].copy_from_slice(&(len as u32).to_le_bytes());
    h
}

/// Validate a 16-byte header. Rejects bad magic/version/kind, non-zero
/// reserved bytes, and any announced length above `max_frame` — the only
/// defense a length-prefixed stream has against a corrupted length.
pub fn parse_header(buf: &[u8; HEADER_LEN], max_frame: usize) -> Result<FrameHeader> {
    if buf[0..4] != MAGIC {
        bail!("bad frame magic {:02x?}", &buf[0..4]);
    }
    if buf[4] != VERSION {
        bail!("unsupported frame version {}", buf[4]);
    }
    let kind = buf[5];
    if !(K_UPDATE..=K_SHARD_SLICE).contains(&kind) {
        bail!("unknown frame kind {kind}");
    }
    if buf[6] != 0 || buf[7] != 0 {
        bail!("reserved header bytes are non-zero");
    }
    let session = u32::from_le_bytes(buf[8..12].try_into().unwrap());
    let len = u32::from_le_bytes(buf[12..16].try_into().unwrap()) as usize;
    if len > max_frame {
        bail!("frame length {len} exceeds max frame size {max_frame}");
    }
    Ok(FrameHeader { kind, session, len })
}

/// Decode a frame payload for a validated header. Total: any byte string
/// yields `Ok` or `Err`, never a panic.
pub fn parse_frame(header: FrameHeader, payload: &[u8]) -> Result<FrameBody> {
    if payload.len() != header.len {
        bail!(
            "payload length {} does not match header length {}",
            payload.len(),
            header.len
        );
    }
    let mut c = Cur::new(payload);
    match header.kind {
        K_UPDATE | K_FAILED => {
            let round = c.u64()? as usize;
            let client_id = c.u64()? as usize;
            let slot = c.u64()? as usize;
            let enc_secs = c.f64()?;
            let loss = c.f32()?;
            if header.session != client_id as u32 {
                bail!(
                    "session {} disagrees with client id {client_id}",
                    header.session
                );
            }
            let payload = if header.kind == K_UPDATE {
                Payload::Update(Encoded {
                    bytes: c.rest().to_vec(),
                })
            } else {
                Payload::Failed(
                    std::str::from_utf8(c.rest())
                        .context("Failed frame message is not UTF-8")?
                        .to_string(),
                )
            };
            Ok(FrameBody::Msg(WireMessage {
                round,
                client_id,
                slot,
                payload,
                enc_secs,
                loss,
            }))
        }
        K_HELLO => {
            let hello = Hello {
                conn_index: c.u32()?,
                conns_total: c.u32()?,
                fingerprint: ConfigFingerprint {
                    seed: c.u64()?,
                    n_clients: c.u64()?,
                    rounds: c.u64()?,
                    d: c.u64()?,
                },
            };
            c.done()?;
            if hello.conns_total == 0 || hello.conn_index >= hello.conns_total {
                bail!(
                    "hello connection {}/{} out of range",
                    hello.conn_index,
                    hello.conns_total
                );
            }
            Ok(FrameBody::Hello(hello))
        }
        K_PLAN => {
            let round = c.u64()?;
            let seed = c.u64()?;
            let kappa = c.f64()?;
            let n = c.u64()? as usize;
            let mut participants = Vec::new();
            for _ in 0..n {
                participants.push(c.u64()?);
            }
            let d = c.u64()? as usize;
            let theta_g = c.f32s(d)?;
            let s_g = c.f32s(d)?;
            c.done()?;
            Ok(FrameBody::Plan(PlanWire {
                round,
                seed,
                kappa,
                participants,
                theta_g,
                s_g,
            }))
        }
        K_EOR => {
            let round = c.u64()?;
            c.done()?;
            Ok(FrameBody::EndOfRound(round))
        }
        K_SHUTDOWN => {
            c.done()?;
            Ok(FrameBody::Shutdown)
        }
        K_SHARD_HELLO => {
            let fingerprint = ConfigFingerprint {
                seed: c.u64()?,
                n_clients: c.u64()?,
                rounds: c.u64()?,
                d: c.u64()?,
            };
            let range_start = c.u64()?;
            let range_end = c.u64()?;
            let state = c.rest().to_vec();
            if range_start >= range_end {
                bail!("shard hello range {range_start}..{range_end} is empty or inverted");
            }
            if range_end > fingerprint.d {
                bail!(
                    "shard hello range end {range_end} exceeds dimensionality {}",
                    fingerprint.d
                );
            }
            Ok(FrameBody::ShardHello(ShardHello {
                fingerprint,
                range_start,
                range_end,
                state,
            }))
        }
        K_SHARD_BEGIN => {
            let seq = c.u64()?;
            let expected = c.u64()?;
            c.done()?;
            Ok(FrameBody::ShardBegin { seq, expected })
        }
        K_SHARD_SPLIT => {
            let slot = c.u32()?;
            let family = c.take(1)?[0];
            if family > 1 {
                bail!("shard split family byte {family} is not mask (0) or score-delta (1)");
            }
            let raw = c.rest();
            if raw.len() % 4 != 0 {
                bail!("shard split data length {} is not a multiple of 4", raw.len());
            }
            let data = raw
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            Ok(FrameBody::ShardSplit(ShardSplit { slot, family, data }))
        }
        K_SHARD_FINISH => {
            let flag = c.take(1)?[0];
            c.done()?;
            if flag > 1 {
                bail!("shard finish flag byte {flag} is not 0/1");
            }
            Ok(FrameBody::ShardFinish { partial: flag == 1 })
        }
        K_SHARD_ABORT => {
            c.done()?;
            Ok(FrameBody::ShardAbort)
        }
        K_SHARD_SLICE => {
            let absorb_secs = c.f64()?;
            let state = c.rest().to_vec();
            Ok(FrameBody::ShardSlice { absorb_secs, state })
        }
        _ => unreachable!("parse_header validated the kind"),
    }
}

fn frame(kind: u8, session: u32, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&header_bytes(kind, session, payload.len()));
    out.extend_from_slice(payload);
    out
}

/// Encode one uplink record as a full frame (header + payload).
pub fn encode_message(msg: &WireMessage) -> Vec<u8> {
    let (kind, body): (u8, &[u8]) = match &msg.payload {
        Payload::Update(enc) => (K_UPDATE, &enc.bytes),
        Payload::Failed(e) => (K_FAILED, e.as_bytes()),
    };
    let mut payload = Vec::with_capacity(36 + body.len());
    payload.extend_from_slice(&(msg.round as u64).to_le_bytes());
    payload.extend_from_slice(&(msg.client_id as u64).to_le_bytes());
    payload.extend_from_slice(&(msg.slot as u64).to_le_bytes());
    payload.extend_from_slice(&msg.enc_secs.to_le_bytes());
    payload.extend_from_slice(&msg.loss.to_le_bytes());
    payload.extend_from_slice(body);
    frame(kind, msg.client_id as u32, &payload)
}

pub fn encode_hello(hello: &Hello) -> Vec<u8> {
    let mut p = Vec::with_capacity(40);
    p.extend_from_slice(&hello.conn_index.to_le_bytes());
    p.extend_from_slice(&hello.conns_total.to_le_bytes());
    p.extend_from_slice(&hello.fingerprint.seed.to_le_bytes());
    p.extend_from_slice(&hello.fingerprint.n_clients.to_le_bytes());
    p.extend_from_slice(&hello.fingerprint.rounds.to_le_bytes());
    p.extend_from_slice(&hello.fingerprint.d.to_le_bytes());
    frame(K_HELLO, hello.conn_index, &p)
}

pub fn encode_plan(plan: &RoundPlan) -> Vec<u8> {
    let w = PlanWire::from_plan(plan);
    let mut p =
        Vec::with_capacity(40 + 8 * w.participants.len() + 4 * (w.theta_g.len() + w.s_g.len()));
    p.extend_from_slice(&w.round.to_le_bytes());
    p.extend_from_slice(&w.seed.to_le_bytes());
    p.extend_from_slice(&w.kappa.to_le_bytes());
    p.extend_from_slice(&(w.participants.len() as u64).to_le_bytes());
    for id in &w.participants {
        p.extend_from_slice(&id.to_le_bytes());
    }
    p.extend_from_slice(&(w.theta_g.len() as u64).to_le_bytes());
    for v in &w.theta_g {
        p.extend_from_slice(&v.to_le_bytes());
    }
    for v in &w.s_g {
        p.extend_from_slice(&v.to_le_bytes());
    }
    frame(K_PLAN, 0, &p)
}

pub fn encode_eor(round: u64) -> Vec<u8> {
    frame(K_EOR, 0, &round.to_le_bytes())
}

pub fn encode_shutdown() -> Vec<u8> {
    frame(K_SHUTDOWN, 0, &[])
}

/// Encode a shard-lane handshake. `shard` rides in the session field for
/// debuggability (the worker identifies the lane by its connection).
pub fn encode_shard_hello(shard: u32, hello: &ShardHello) -> Vec<u8> {
    let mut p = Vec::with_capacity(48 + hello.state.len());
    p.extend_from_slice(&hello.fingerprint.seed.to_le_bytes());
    p.extend_from_slice(&hello.fingerprint.n_clients.to_le_bytes());
    p.extend_from_slice(&hello.fingerprint.rounds.to_le_bytes());
    p.extend_from_slice(&hello.fingerprint.d.to_le_bytes());
    p.extend_from_slice(&hello.range_start.to_le_bytes());
    p.extend_from_slice(&hello.range_end.to_le_bytes());
    p.extend_from_slice(&hello.state);
    frame(K_SHARD_HELLO, shard, &p)
}

pub fn encode_shard_begin(shard: u32, seq: u64, expected: u64) -> Vec<u8> {
    let mut p = Vec::with_capacity(16);
    p.extend_from_slice(&seq.to_le_bytes());
    p.extend_from_slice(&expected.to_le_bytes());
    frame(K_SHARD_BEGIN, shard, &p)
}

pub fn encode_shard_split(shard: u32, slot: u32, family: u8, data: &[f32]) -> Vec<u8> {
    let mut p = Vec::with_capacity(5 + 4 * data.len());
    p.extend_from_slice(&slot.to_le_bytes());
    p.push(family);
    for v in data {
        p.extend_from_slice(&v.to_le_bytes());
    }
    frame(K_SHARD_SPLIT, shard, &p)
}

pub fn encode_shard_finish(shard: u32, partial: bool) -> Vec<u8> {
    frame(K_SHARD_FINISH, shard, &[u8::from(partial)])
}

pub fn encode_shard_abort(shard: u32) -> Vec<u8> {
    frame(K_SHARD_ABORT, shard, &[])
}

pub fn encode_shard_slice(shard: u32, absorb_secs: f64, state: &[u8]) -> Vec<u8> {
    let mut p = Vec::with_capacity(8 + state.len());
    p.extend_from_slice(&absorb_secs.to_le_bytes());
    p.extend_from_slice(state);
    frame(K_SHARD_SLICE, shard, &p)
}

// ---------------------------------------------------------------------------
// Streams and listeners (TCP + UDS behind one enum).
// ---------------------------------------------------------------------------

/// Where a socket endpoint lives.
#[derive(Clone, Debug)]
pub enum SocketAddrSpec {
    Tcp(String),
    Uds(PathBuf),
}

impl SocketAddrSpec {
    /// Interpret a CLI address for the given transport kind. `Channel`
    /// has no address and is rejected here.
    pub fn parse(kind: TransportKind, addr: &str) -> Result<Self> {
        match kind {
            TransportKind::Tcp => Ok(Self::Tcp(addr.to_string())),
            TransportKind::Uds => Ok(Self::Uds(PathBuf::from(addr))),
            TransportKind::Channel => {
                bail!("the in-process channel transport has no socket address")
            }
        }
    }
}

impl std::fmt::Display for SocketAddrSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Tcp(a) => write!(f, "tcp://{a}"),
            Self::Uds(p) => write!(f, "uds://{}", p.display()),
        }
    }
}

/// One accepted or connected socket (either family).
#[derive(Debug)]
pub enum Stream {
    Tcp(TcpStream),
    Uds(UnixStream),
}

impl Stream {
    pub fn connect(spec: &SocketAddrSpec) -> Result<Self> {
        match spec {
            SocketAddrSpec::Tcp(addr) => {
                let s = TcpStream::connect(addr).with_context(|| format!("connect {spec}"))?;
                s.set_nodelay(true)?;
                Ok(Self::Tcp(s))
            }
            SocketAddrSpec::Uds(path) => Ok(Self::Uds(
                UnixStream::connect(path).with_context(|| format!("connect {spec}"))?,
            )),
        }
    }

    pub fn try_clone(&self) -> io::Result<Self> {
        match self {
            Self::Tcp(s) => s.try_clone().map(Self::Tcp),
            Self::Uds(s) => s.try_clone().map(Self::Uds),
        }
    }

    /// Tear down both directions; unblocks any thread parked in `read`.
    pub fn shutdown_both(&self) {
        let _ = match self {
            Self::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
            Self::Uds(s) => s.shutdown(std::net::Shutdown::Both),
        };
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Self::Tcp(s) => s.read(buf),
            Self::Uds(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Self::Tcp(s) => s.write(buf),
            Self::Uds(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Self::Tcp(s) => s.flush(),
            Self::Uds(s) => s.flush(),
        }
    }
}

/// A bound accept socket (either family).
#[derive(Debug)]
pub enum Listener {
    Tcp(TcpListener),
    Uds(UnixListener),
}

impl Listener {
    pub fn bind(spec: &SocketAddrSpec) -> Result<Self> {
        match spec {
            SocketAddrSpec::Tcp(addr) => Ok(Self::Tcp(
                TcpListener::bind(addr).with_context(|| format!("bind {spec}"))?,
            )),
            SocketAddrSpec::Uds(path) => {
                match UnixListener::bind(path) {
                    Ok(l) => Ok(Self::Uds(l)),
                    Err(e) if e.kind() == io::ErrorKind::AddrInUse => {
                        // A socket file left behind by a dead process: safe
                        // to reclaim iff nothing answers on it.
                        if UnixStream::connect(path).is_err() {
                            std::fs::remove_file(path)?;
                            Ok(Self::Uds(UnixListener::bind(path)?))
                        } else {
                            bail!("{spec} already has a live listener");
                        }
                    }
                    Err(e) => Err(e).with_context(|| format!("bind {spec}")),
                }
            }
        }
    }

    /// The resolved address peers should connect to (TCP `:0` binds get
    /// their assigned port back).
    pub fn local_spec(&self) -> Result<SocketAddrSpec> {
        match self {
            Self::Tcp(l) => Ok(SocketAddrSpec::Tcp(l.local_addr()?.to_string())),
            Self::Uds(l) => {
                let addr = l.local_addr()?;
                let path = addr
                    .as_pathname()
                    .ok_or_else(|| anyhow!("unnamed unix listener"))?;
                Ok(SocketAddrSpec::Uds(path.to_path_buf()))
            }
        }
    }

    pub fn accept(&self) -> Result<Stream> {
        match self {
            Self::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nodelay(true)?;
                Ok(Stream::Tcp(s))
            }
            Self::Uds(l) => {
                let (s, _) = l.accept()?;
                Ok(Stream::Uds(s))
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Inbound queue with bounded admission.
// ---------------------------------------------------------------------------

struct Queued {
    msg: WireMessage,
    conn: usize,
    cost: usize,
    at: Instant,
}

struct InboundState {
    queue: VecDeque<Queued>,
    queued_bytes: usize,
    peak_queued_bytes: usize,
    conn_bytes: Vec<usize>,
    conn_alive: Vec<bool>,
    /// Highest `EndOfRound` mark seen per connection (two-process mode).
    conn_eor: Vec<Option<u64>>,
    live_conns: usize,
    current_round: u64,
    closing: bool,
    // Accounting (see `TransportStats` for which side reads what).
    arrived_messages: u64,
    arrived_payload_bytes: u64,
    received: u64,
    transit_secs: f64,
    frames: u64,
    frame_bytes: u64,
    stalls: u64,
    corrupt_frames: u64,
}

impl InboundState {
    fn new(conns: usize) -> Self {
        Self {
            queue: VecDeque::new(),
            queued_bytes: 0,
            peak_queued_bytes: 0,
            conn_bytes: vec![0; conns],
            conn_alive: vec![true; conns],
            conn_eor: vec![None; conns],
            live_conns: conns,
            current_round: 0,
            closing: false,
            arrived_messages: 0,
            arrived_payload_bytes: 0,
            received: 0,
            transit_secs: 0.0,
            frames: 0,
            frame_bytes: 0,
            stalls: 0,
            corrupt_frames: 0,
        }
    }

    /// Nothing queued and nothing can arrive for the current round: every
    /// connection is gone, or every surviving one has marked end-of-round.
    fn closed(&self) -> bool {
        self.queue.is_empty()
            && (self.live_conns == 0
                || self
                    .conn_alive
                    .iter()
                    .zip(&self.conn_eor)
                    .filter(|(alive, _)| **alive)
                    .all(|(_, eor)| eor.is_some_and(|r| r >= self.current_round)))
    }

    fn release(&mut self, q: &Queued) {
        self.queued_bytes -= q.cost;
        self.conn_bytes[q.conn] -= q.cost;
    }
}

struct Inbound {
    state: Mutex<InboundState>,
    /// Consumers (and end-of-round waiters) park here.
    readable: Condvar,
    /// Backpressured readers park here.
    writable: Condvar,
}

impl Inbound {
    fn pop(&self, st: &mut MutexGuard<'_, InboundState>) -> Option<WireMessage> {
        st.queue.pop_front().map(|q| {
            st.release(&q);
            st.received += 1;
            st.transit_secs += q.at.elapsed().as_secs_f64();
            self.writable.notify_all();
            q.msg
        })
    }

    fn conn_down(&self, conn: usize) {
        let mut st = self.state.lock().unwrap();
        if st.conn_alive[conn] {
            st.conn_alive[conn] = false;
            st.live_conns -= 1;
        }
        drop(st);
        self.readable.notify_all();
        self.writable.notify_all();
    }
}

/// Read exactly `buf.len()` bytes. `Ok(false)` on a clean EOF at offset 0;
/// an error on EOF mid-buffer (a torn frame).
fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> io::Result<bool> {
    let mut off = 0;
    while off < buf.len() {
        match r.read(&mut buf[off..]) {
            Ok(0) => {
                return if off == 0 {
                    Ok(false)
                } else {
                    Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "connection closed mid-frame",
                    ))
                }
            }
            Ok(n) => off += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

fn reader_loop(conn: usize, mut stream: Stream, inbound: Arc<Inbound>, cfg: SocketConfig) {
    let mut header = [0u8; HEADER_LEN];
    loop {
        match read_exact_or_eof(&mut stream, &mut header) {
            Ok(true) => {}
            // Clean EOF at a frame boundary: the peer is done.
            Ok(false) => break,
            // Torn frame or transport error: dirty close.
            Err(_) => {
                inbound.state.lock().unwrap().corrupt_frames += 1;
                break;
            }
        }
        let h = match parse_header(&header, cfg.max_frame) {
            Ok(h) => h,
            // A corrupt header (including a bogus length) desynchronizes a
            // length-prefixed stream beyond recovery — connection-fatal.
            // The round drain sees the dead connection as missing senders.
            Err(_) => {
                inbound.state.lock().unwrap().corrupt_frames += 1;
                break;
            }
        };
        let mut payload = vec![0u8; h.len];
        if !matches!(read_exact_or_eof(&mut stream, &mut payload), Ok(true)) {
            inbound.state.lock().unwrap().corrupt_frames += 1;
            break;
        }
        let cost = HEADER_LEN + h.len;
        let body = parse_frame(h, &payload);
        let mut st = inbound.state.lock().unwrap();
        st.frames += 1;
        st.frame_bytes += cost as u64;
        match body {
            Ok(FrameBody::Msg(msg)) => {
                // Bounded admission: block (stall) while this frame would
                // overflow either budget, unless the connection's queue is
                // empty — one in-flight frame per connection always makes
                // progress, so a single oversized frame can't deadlock.
                let mut stalled = false;
                while !st.closing
                    && ((st.queued_bytes > 0 && st.queued_bytes + cost > cfg.inbound_budget)
                        || (st.conn_bytes[conn] > 0
                            && st.conn_bytes[conn] + cost > cfg.conn_budget))
                {
                    if !stalled {
                        stalled = true;
                        st.stalls += 1;
                    }
                    st = inbound.writable.wait(st).unwrap();
                }
                if st.closing {
                    break;
                }
                st.queued_bytes += cost;
                st.conn_bytes[conn] += cost;
                st.peak_queued_bytes = st.peak_queued_bytes.max(st.queued_bytes);
                st.arrived_messages += 1;
                st.arrived_payload_bytes += msg.payload_bytes() as u64;
                st.queue.push_back(Queued {
                    msg,
                    conn,
                    cost,
                    at: Instant::now(),
                });
                drop(st);
                inbound.readable.notify_all();
            }
            Ok(FrameBody::EndOfRound(round)) => {
                let mark = st.conn_eor[conn].map_or(round, |prev| prev.max(round));
                st.conn_eor[conn] = Some(mark);
                drop(st);
                inbound.readable.notify_all();
            }
            // Data direction never carries Hello/Plan/Shutdown past the
            // handshake; a structurally-broken payload lands here too. The
            // length kept the stream in sync, so skip and count.
            Ok(_) | Err(_) => {
                st.corrupt_frames += 1;
                if st.closing {
                    break;
                }
            }
        }
    }
    inbound.conn_down(conn);
}

/// Where `TransportStats::sent_*` come from: the loopback hub shares the
/// sender's counters (send-time accounting, exactly like the channel); a
/// standalone server only sees what arrived at its readers.
enum SentAccounting {
    Local(Arc<Counters>),
    Intake,
}

/// Server end of a framed socket uplink: one reader thread per connection
/// feeding a bounded inbound queue. See the module docs for the
/// backpressure and closure rules.
pub struct SocketTransport {
    inbound: Arc<Inbound>,
    /// Clones kept only to shutdown blocked readers on drop.
    streams: Vec<Stream>,
    readers: Vec<std::thread::JoinHandle<()>>,
    sent: SentAccounting,
}

impl SocketTransport {
    fn start(streams: Vec<Stream>, cfg: SocketConfig, sent: SentAccounting) -> Result<Self> {
        let inbound = Arc::new(Inbound {
            state: Mutex::new(InboundState::new(streams.len())),
            readable: Condvar::new(),
            writable: Condvar::new(),
        });
        let mut shutdown_handles = Vec::with_capacity(streams.len());
        let mut readers = Vec::with_capacity(streams.len());
        for (conn, stream) in streams.into_iter().enumerate() {
            shutdown_handles.push(stream.try_clone()?);
            let inbound = inbound.clone();
            readers.push(
                std::thread::Builder::new()
                    .name(format!("dm-sock-reader-{conn}"))
                    .spawn(move || reader_loop(conn, stream, inbound, cfg))?,
            );
        }
        Ok(Self {
            inbound,
            streams: shutdown_handles,
            readers,
            sent,
        })
    }

    /// High-water mark of queued inbound bytes — the backpressure tests'
    /// bounded-memory witness.
    pub fn peak_inbound_bytes(&self) -> usize {
        self.inbound.state.lock().unwrap().peak_queued_bytes
    }

    /// Structurally-corrupt frames skipped or connection-fatal so far.
    pub fn frame_corruptions(&self) -> u64 {
        self.inbound.state.lock().unwrap().corrupt_frames
    }
}

impl Transport for SocketTransport {
    fn recv(&mut self) -> Option<WireMessage> {
        let mut st = self.inbound.state.lock().unwrap();
        loop {
            if let Some(m) = self.inbound.pop(&mut st) {
                return Some(m);
            }
            if st.closed() {
                return None;
            }
            st = self.inbound.readable.wait(st).unwrap();
        }
    }

    fn recv_deadline(&mut self, deadline: Instant) -> RecvOutcome {
        let mut st = self.inbound.state.lock().unwrap();
        loop {
            // Trait contract: Msg > Closed > TimedOut, so a message racing
            // the deadline still lands and a dead wire never reads as
            // "maybe still in flight".
            if let Some(m) = self.inbound.pop(&mut st) {
                return RecvOutcome::Msg(m);
            }
            if st.closed() {
                return RecvOutcome::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return RecvOutcome::TimedOut;
            }
            let (guard, _) = self
                .inbound
                .readable
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = guard;
        }
    }

    fn try_recv(&mut self) -> Option<WireMessage> {
        let mut st = self.inbound.state.lock().unwrap();
        self.inbound.pop(&mut st)
    }

    fn discard_inflight(&mut self) {
        let mut st = self.inbound.state.lock().unwrap();
        while let Some(q) = st.queue.pop_front() {
            st.release(&q);
        }
        drop(st);
        self.inbound.writable.notify_all();
    }

    fn stats(&self) -> TransportStats {
        let st = self.inbound.state.lock().unwrap();
        let (sent_messages, sent_payload_bytes) = match &self.sent {
            SentAccounting::Local(c) => (
                c.messages.load(Ordering::Relaxed),
                c.payload_bytes.load(Ordering::Relaxed),
            ),
            SentAccounting::Intake => (st.arrived_messages, st.arrived_payload_bytes),
        };
        TransportStats {
            sent_messages,
            sent_payload_bytes,
            received_messages: st.received,
            transit_secs: st.transit_secs,
            wire_frames: st.frames,
            wire_bytes: st.frame_bytes,
            backpressure_stalls: st.stalls,
        }
    }
}

impl Drop for SocketTransport {
    fn drop(&mut self) {
        {
            self.inbound.state.lock().unwrap().closing = true;
        }
        self.inbound.readable.notify_all();
        self.inbound.writable.notify_all();
        for s in &self.streams {
            s.shutdown_both();
        }
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Client-side frame writer: M shared connections carrying any number of
/// logical clients, routed by `client_id % M` with the client id in the
/// frame's session field. Cheap to clone (all clones share the
/// connections); dropping the last clone closes the write side, which is
/// how loopback rounds signal completion.
pub struct SocketSender {
    conns: Arc<Vec<Mutex<Stream>>>,
    counters: Arc<Counters>,
}

impl TransportSender for SocketSender {
    fn send(&self, msg: WireMessage) -> Result<()> {
        // Count before writing, mirroring the channel sender: a send the
        // server never reads (it aborted) is still a send.
        self.counters
            .payload_bytes
            .fetch_add(msg.payload_bytes() as u64, Ordering::Relaxed);
        self.counters.messages.fetch_add(1, Ordering::Relaxed);
        let frame = encode_message(&msg);
        let idx = msg.client_id % self.conns.len();
        let mut conn = self.conns[idx]
            .lock()
            .map_err(|_| anyhow!("socket sender lock poisoned"))?;
        conn.write_all(&frame)
            .and_then(|()| conn.flush())
            .with_context(|| format!("uplink send for client {}", msg.client_id))
    }

    fn clone_sender(&self) -> Box<dyn TransportSender> {
        Box::new(Self {
            conns: self.conns.clone(),
            counters: self.counters.clone(),
        })
    }
}

// ---------------------------------------------------------------------------
// Loopback hub: in-process experiments over a real socket.
// ---------------------------------------------------------------------------

static HUB_SEQ: AtomicU64 = AtomicU64::new(0);

/// Per-experiment loopback endpoint: binds once, then wires a fresh
/// (transport, sender) pair per round — preserving the per-round channel
/// lifecycle (close-on-drop) over a real socket.
pub struct SocketHub {
    listener: Listener,
    target: SocketAddrSpec,
    cfg: SocketConfig,
    conns: usize,
    uds_path: Option<PathBuf>,
}

impl SocketHub {
    /// Bind an ephemeral loopback endpoint: TCP on `127.0.0.1:0`, or a
    /// unique socket file under the system temp dir.
    pub fn bind_loopback(kind: TransportKind, cfg: SocketConfig, conns: usize) -> Result<Self> {
        let spec = match kind {
            TransportKind::Tcp => SocketAddrSpec::Tcp("127.0.0.1:0".into()),
            TransportKind::Uds => {
                let seq = HUB_SEQ.fetch_add(1, Ordering::Relaxed);
                SocketAddrSpec::Uds(std::env::temp_dir().join(format!(
                    "deltamask-{}-{seq}.sock",
                    std::process::id()
                )))
            }
            TransportKind::Channel => bail!("channel transport needs no socket hub"),
        };
        let listener = Listener::bind(&spec)?;
        let target = listener.local_spec()?;
        let uds_path = match &target {
            SocketAddrSpec::Uds(p) => Some(p.clone()),
            SocketAddrSpec::Tcp(_) => None,
        };
        Ok(Self {
            listener,
            target,
            cfg,
            conns: conns.max(1),
            uds_path,
        })
    }

    pub fn config(&self) -> SocketConfig {
        self.cfg
    }

    /// Fresh per-round link: M connections (capped at the expected sender
    /// count), a reader-backed transport, and the multiplexing sender.
    /// The listener backlog absorbs the connects, so no handshake thread
    /// is needed.
    pub fn round_link(&self, expected: usize) -> Result<(SocketTransport, Box<dyn TransportSender>)> {
        let n = self.conns.min(expected.max(1));
        let mut client_ends = Vec::with_capacity(n);
        let mut server_ends = Vec::with_capacity(n);
        for _ in 0..n {
            client_ends.push(Stream::connect(&self.target)?);
        }
        for _ in 0..n {
            server_ends.push(self.listener.accept()?);
        }
        let counters = Arc::new(Counters::default());
        let transport =
            SocketTransport::start(server_ends, self.cfg, SentAccounting::Local(counters.clone()))?;
        let sender = SocketSender {
            conns: Arc::new(client_ends.into_iter().map(Mutex::new).collect()),
            counters,
        };
        Ok((transport, Box::new(sender)))
    }
}

impl Drop for SocketHub {
    fn drop(&mut self) {
        if let Some(p) = &self.uds_path {
            let _ = std::fs::remove_file(p);
        }
    }
}

// ---------------------------------------------------------------------------
// Two-process mode: FleetServer (coordinator) and FleetLink (client fleet).
// ---------------------------------------------------------------------------

/// Coordinator side of a `serve` / `client-fleet` pair: accepted fleet
/// connections, their reader-backed transport, and the downlink for plan
/// broadcast and round bookkeeping.
pub struct FleetServer {
    transport: Option<SocketTransport>,
    inbound: Arc<Inbound>,
    /// Write handles to every connection whose fleet-side `conn_index` is
    /// 0 — the only connection each fleet reads control frames from.
    control: Vec<Stream>,
}

impl FleetServer {
    /// Accept one fleet: the first Hello announces how many connections
    /// the fleet opens; every Hello must agree on that count and on the
    /// config fingerprint, or the handshake fails loudly before round 0.
    pub fn accept_fleet(
        listener: &Listener,
        cfg: SocketConfig,
        expect: ConfigFingerprint,
    ) -> Result<Self> {
        let mut streams: Vec<Stream> = Vec::new();
        let mut hellos: Vec<Hello> = Vec::new();
        loop {
            let mut stream = listener.accept()?;
            let hello = read_hello(&mut stream, cfg)?;
            if hello.fingerprint != expect {
                bail!(
                    "fleet config fingerprint {:?} does not match serve config {:?} — \
                     serve and client-fleet must run identical experiment settings",
                    hello.fingerprint,
                    expect
                );
            }
            if let Some(first) = hellos.first() {
                if hello.conns_total != first.conns_total {
                    bail!(
                        "fleet connections disagree on their count ({} vs {})",
                        hello.conns_total,
                        first.conns_total
                    );
                }
            }
            if hellos.iter().any(|h| h.conn_index == hello.conn_index) {
                bail!("duplicate fleet connection index {}", hello.conn_index);
            }
            let total = hello.conns_total as usize;
            streams.push(stream);
            hellos.push(hello);
            if streams.len() == total {
                break;
            }
        }
        let mut control = Vec::new();
        for (stream, hello) in streams.iter().zip(&hellos) {
            if hello.conn_index == 0 {
                control.push(stream.try_clone()?);
            }
        }
        let transport = SocketTransport::start(streams, cfg, SentAccounting::Intake)?;
        let inbound = transport.inbound.clone();
        Ok(Self {
            transport: Some(transport),
            inbound,
            control,
        })
    }

    /// The uplink transport, to be owned (and optionally chaos-wrapped) by
    /// the drain loop. Callable once.
    pub fn take_transport(&mut self) -> SocketTransport {
        self.transport
            .take()
            .expect("FleetServer transport already taken")
    }

    /// Mark the round open and broadcast its plan to the fleet.
    pub fn broadcast_plan(&mut self, plan: &RoundPlan) -> Result<()> {
        {
            self.inbound.state.lock().unwrap().current_round = plan.round as u64;
        }
        let frame = encode_plan(plan);
        for conn in &mut self.control {
            conn.write_all(&frame)?;
            conn.flush()?;
        }
        Ok(())
    }

    /// Between-rounds barrier: wait for every surviving connection's
    /// `EndOfRound(round)` mark, discarding (uncounted) any data frames
    /// the drain left unread — leftover duplicates must not leak into the
    /// next round as `stale`, matching the dropped per-round channel.
    /// Keeps draining while it waits so a backpressured fleet can always
    /// finish flushing.
    pub fn end_round(&self, round: usize) {
        let mut st = self.inbound.state.lock().unwrap();
        loop {
            while let Some(q) = st.queue.pop_front() {
                st.release(&q);
            }
            self.inbound.writable.notify_all();
            let done = st.live_conns == 0
                || st
                    .conn_alive
                    .iter()
                    .zip(&st.conn_eor)
                    .filter(|(alive, _)| **alive)
                    .all(|(_, eor)| eor.is_some_and(|r| r >= round as u64));
            if done {
                return;
            }
            st = self.inbound.readable.wait(st).unwrap();
        }
    }

    /// Tell the fleet the experiment is over.
    pub fn shutdown(&mut self) -> Result<()> {
        let frame = encode_shutdown();
        for conn in &mut self.control {
            conn.write_all(&frame)?;
            conn.flush()?;
        }
        Ok(())
    }
}

fn read_hello(stream: &mut Stream, cfg: SocketConfig) -> Result<Hello> {
    match read_frame(stream, cfg)? {
        FrameBody::Hello(h) => Ok(h),
        other => bail!("expected Hello handshake frame, got {other:?}"),
    }
}

/// Blocking read of one whole frame (handshake / control paths).
fn read_frame(stream: &mut Stream, cfg: SocketConfig) -> Result<FrameBody> {
    read_frame_or_eof(stream, cfg)?.ok_or_else(|| anyhow!("connection closed"))
}

/// Blocking read of one whole frame; `None` on a clean EOF at a frame
/// boundary (the peer hung up between frames).
fn read_frame_or_eof(stream: &mut Stream, cfg: SocketConfig) -> Result<Option<FrameBody>> {
    let mut header = [0u8; HEADER_LEN];
    if !read_exact_or_eof(stream, &mut header)? {
        return Ok(None);
    }
    let h = parse_header(&header, cfg.max_frame)?;
    let mut payload = vec![0u8; h.len];
    if !read_exact_or_eof(stream, &mut payload)? {
        bail!("connection closed mid-frame");
    }
    parse_frame(h, &payload).map(Some)
}

/// Downlink control messages a fleet reacts to.
#[derive(Clone, Debug)]
pub enum ControlMsg {
    Plan(PlanWire),
    Shutdown,
}

/// Client-fleet side of a `serve` / `client-fleet` pair: M persistent
/// connections multiplexing all local clients, with control frames read
/// from connection 0.
pub struct FleetLink {
    control: Stream,
    conns: Arc<Vec<Mutex<Stream>>>,
    counters: Arc<Counters>,
    cfg: SocketConfig,
}

impl FleetLink {
    /// Connect `conns` streams and complete the Hello handshake. Retries
    /// the first connection until `timeout` so the fleet can start before
    /// the server finishes binding.
    pub fn connect(
        spec: &SocketAddrSpec,
        conns: usize,
        fingerprint: ConfigFingerprint,
        cfg: SocketConfig,
        timeout: Duration,
    ) -> Result<Self> {
        let conns = conns.max(1);
        let deadline = Instant::now() + timeout;
        let first = loop {
            match Stream::connect(spec) {
                Ok(s) => break s,
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e.context(format!("fleet connect to {spec} timed out")));
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        };
        let mut streams = vec![first];
        for _ in 1..conns {
            streams.push(Stream::connect(spec)?);
        }
        for (i, s) in streams.iter_mut().enumerate() {
            let hello = Hello {
                conn_index: i as u32,
                conns_total: conns as u32,
                fingerprint,
            };
            s.write_all(&encode_hello(&hello))?;
            s.flush()?;
        }
        let control = streams[0].try_clone()?;
        Ok(Self {
            control,
            conns: Arc::new(streams.into_iter().map(Mutex::new).collect()),
            counters: Arc::new(Counters::default()),
            cfg,
        })
    }

    /// A multiplexing sender over the fleet's connections. Clones share
    /// the connections; the link keeps its own handles, so round senders
    /// dropping never closes the wire.
    pub fn sender(&self) -> Box<dyn TransportSender> {
        Box::new(SocketSender {
            conns: self.conns.clone(),
            counters: self.counters.clone(),
        })
    }

    /// Block until the server's next control frame.
    pub fn recv_control(&mut self) -> Result<ControlMsg> {
        match read_frame(&mut self.control, self.cfg)? {
            FrameBody::Plan(p) => Ok(ControlMsg::Plan(p)),
            FrameBody::Shutdown => Ok(ControlMsg::Shutdown),
            other => bail!("unexpected control frame {other:?}"),
        }
    }

    /// Mark every connection quiescent for `round`.
    pub fn send_eor(&self, round: usize) -> Result<()> {
        let frame = encode_eor(round as u64);
        for conn in self.conns.iter() {
            let mut c = conn.lock().map_err(|_| anyhow!("fleet conn lock poisoned"))?;
            c.write_all(&frame)?;
            c.flush()?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Shard fabric: remote absorb lanes (ShardLink) and the worker serve loop.
// ---------------------------------------------------------------------------

/// Coordinator-side client for one remote shard lane: a persistent framed
/// connection to a `deltamask shard-worker`, carrying the lane's round
/// traffic as `K_SHARD_*` frames. All writes are blocking `write_all`s on
/// a connection the worker reads one frame at a time, so the kernel's
/// socket window (bounded by [`SocketConfig::max_frame`] per frame) is
/// the backpressure: a slow shard host stalls the lane's bounded job
/// queue, which stalls the router, which stalls decode — never unbounded
/// buffering.
pub struct ShardLink {
    stream: Stream,
    cfg: SocketConfig,
    shard: u32,
}

impl ShardLink {
    /// Connect (retrying until `timeout`, so workers may be racing their
    /// bind), send the shard hello carrying the config fingerprint, the
    /// lane's bounds and the slice state seeding the worker, and wait for
    /// the worker's echo. A worker that rejects the hello closes the
    /// connection, which surfaces here before any round starts.
    pub fn connect(
        spec: &SocketAddrSpec,
        cfg: SocketConfig,
        shard: u32,
        fingerprint: ConfigFingerprint,
        range: Range<usize>,
        state: &[u8],
        timeout: Duration,
    ) -> Result<Self> {
        let deadline = Instant::now() + timeout;
        let stream = loop {
            match Stream::connect(spec) {
                Ok(s) => break s,
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e.context(format!("shard lane connect to {spec} timed out")));
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        };
        let mut link = Self { stream, cfg, shard };
        let hello = ShardHello {
            fingerprint,
            range_start: range.start as u64,
            range_end: range.end as u64,
            state: state.to_vec(),
        };
        link.stream.write_all(&encode_shard_hello(shard, &hello))?;
        link.stream.flush()?;
        match read_frame(&mut link.stream, link.cfg).with_context(|| {
            format!("shard worker at {spec} rejected the hello (fingerprint or bounds mismatch?)")
        })? {
            FrameBody::ShardHello(echo) => {
                if echo.fingerprint != fingerprint {
                    bail!(
                        "shard worker at {spec} echoed fingerprint {:?}, expected {:?}",
                        echo.fingerprint,
                        fingerprint
                    );
                }
                Ok(link)
            }
            other => bail!("expected shard hello echo from {spec}, got {other:?}"),
        }
    }

    /// Open one shard round. `seq` must be strictly monotone per link.
    pub fn begin(&mut self, seq: u64, expected: usize) -> Result<()> {
        self.stream
            .write_all(&encode_shard_begin(self.shard, seq, expected as u64))?;
        self.stream.flush()?;
        Ok(())
    }

    /// Ship one routed sub-update (family 0 = mask, 1 = score-delta).
    pub fn split(&mut self, slot: usize, family: u8, data: &[f32]) -> Result<()> {
        self.stream
            .write_all(&encode_shard_split(self.shard, slot as u32, family, data))?;
        self.stream.flush()?;
        Ok(())
    }

    /// Finish the round on the worker; returns its absorb seconds and the
    /// post-finish slice state.
    pub fn finish(&mut self, partial: bool) -> Result<(f64, Vec<u8>)> {
        self.stream
            .write_all(&encode_shard_finish(self.shard, partial))?;
        self.stream.flush()?;
        self.read_slice()
    }

    /// Abandon the round on the worker; returns its absorb seconds and the
    /// *unfinished* post-absorb slice state (a parked local lane's exact
    /// equivalent, which is what keeps aborted rounds bitwise-coherent).
    pub fn abort(&mut self) -> Result<(f64, Vec<u8>)> {
        self.stream.write_all(&encode_shard_abort(self.shard))?;
        self.stream.flush()?;
        self.read_slice()
    }

    /// Best-effort experiment-over signal (non-lingering workers exit).
    pub fn send_shutdown(&mut self) {
        let _ = self
            .stream
            .write_all(&encode_shutdown())
            .and_then(|()| self.stream.flush());
    }

    fn read_slice(&mut self) -> Result<(f64, Vec<u8>)> {
        match read_frame(&mut self.stream, self.cfg)? {
            FrameBody::ShardSlice { absorb_secs, state } => Ok((absorb_secs, state)),
            other => bail!("expected shard slice return, got {other:?}"),
        }
    }
}

/// Serve one shard worker: accept shard-lane connections sequentially
/// (one lane per worker) and drive a slice sink per session from
/// `K_SHARD_*` frames. Generic over the sink so tests can serve spy
/// aggregators; production serves `fl::server::MaskServer` via the
/// `deltamask shard-worker` subcommand.
///
/// Every wire value is validated *before* it reaches the sink's
/// panicking contract methods — a malformed, replayed or incoherent
/// frame kills the connection (the coordinator's lane sees the dead link
/// as a fault), never the worker process. A clean EOF re-enters accept,
/// which is what lets a faulted coordinator lane reconnect and re-seed
/// the worker from its parked mirror. With `linger` the worker also
/// ignores shutdown frames and re-accepts forever (CI keeps standing
/// workers across test suites); without it the first shutdown frame
/// returns cleanly.
pub fn serve_shard_worker<A: Aggregator + WireSlice>(
    listener: &Listener,
    cfg: SocketConfig,
    expect: ConfigFingerprint,
    linger: bool,
) -> Result<()> {
    loop {
        let mut stream = listener.accept()?;
        match serve_shard_session::<A>(&mut stream, cfg, expect) {
            Ok(true) if !linger => return Ok(()),
            Ok(_) => {}
            Err(e) => eprintln!("deltamask shard-worker: session ended: {e:#}"),
        }
    }
}

/// One accepted shard-lane connection. `Ok(true)` on a shutdown frame,
/// `Ok(false)` on a clean EOF (lane dropped or reconnecting).
fn serve_shard_session<A: Aggregator + WireSlice>(
    stream: &mut Stream,
    cfg: SocketConfig,
    expect: ConfigFingerprint,
) -> Result<bool> {
    let hello = match read_frame(stream, cfg).context("shard hello read")? {
        FrameBody::ShardHello(h) => h,
        FrameBody::Shutdown => return Ok(true),
        other => bail!("expected shard hello, got {other:?}"),
    };
    if hello.fingerprint != expect {
        bail!(
            "shard hello fingerprint {:?} does not match this worker's config {:?} — \
             shard-worker must run identical experiment settings",
            hello.fingerprint,
            expect
        );
    }
    // parse_frame guaranteed a non-empty range within the fingerprint's d;
    // what is left to check is agreement with *this* worker's config and
    // with the state that came along.
    let range = hello.range_start as usize..hello.range_end as usize;
    let mut sink = A::decode_slice(&hello.state).context("shard hello slice state")?;
    if sink.slice_dim() != range.len() {
        bail!(
            "shard hello state dimensionality {} does not match bounds {range:?}",
            sink.slice_dim()
        );
    }
    let echo = ShardHello {
        state: Vec::new(),
        ..hello
    };
    stream.write_all(&encode_shard_hello(0, &echo))?;
    stream.flush()?;

    let mut last_seq = 0u64;
    loop {
        // Parked between rounds: wait for the next begin (or shutdown/EOF).
        let (seq, expected) = match read_frame_or_eof(stream, cfg)? {
            None => return Ok(false),
            Some(FrameBody::ShardBegin { seq, expected }) => (seq, expected),
            Some(FrameBody::Shutdown) => return Ok(true),
            Some(other) => bail!("expected shard begin, got {other:?}"),
        };
        if seq <= last_seq {
            bail!("replayed shard round seq {seq} (last was {last_seq})");
        }
        if expected > expect.n_clients {
            bail!(
                "shard round expects {expected} updates from a {}-client experiment",
                expect.n_clients
            );
        }
        last_seq = seq;
        let expected = expected as usize;
        sink.begin_round(expected);
        let mut absorb_secs = 0.0f64;
        let mut seen = vec![false; expected];
        let mut absorbed = 0usize;
        let mut family: Option<u8> = None;
        loop {
            match read_frame_or_eof(stream, cfg)? {
                // EOF mid-round: the lane died or aborted hard; the
                // mid-round state dies with the connection (the
                // coordinator still holds the authoritative mirror).
                None => return Ok(false),
                Some(FrameBody::ShardSplit(split)) => {
                    let slot = split.slot as usize;
                    if slot >= expected {
                        bail!("shard split slot {slot} out of range 0..{expected}");
                    }
                    if seen[slot] {
                        bail!("duplicate shard split for slot {slot}");
                    }
                    if split.data.len() != range.len() {
                        bail!(
                            "shard split length {} does not match bounds {range:?}",
                            split.data.len()
                        );
                    }
                    if family.is_some_and(|f| f != split.family) {
                        bail!("mixed update families within one shard round");
                    }
                    family = Some(split.family);
                    seen[slot] = true;
                    absorbed += 1;
                    let update = if split.family == 0 {
                        Update::Mask(split.data)
                    } else {
                        Update::ScoreDelta(split.data)
                    };
                    let t = Stopwatch::new();
                    sink.absorb(slot, update);
                    while sink.reclaim_buffer().is_some() {}
                    absorb_secs += t.elapsed_secs();
                }
                Some(FrameBody::ShardFinish { partial }) => {
                    if !partial && absorbed != expected {
                        bail!("strict shard finish with {absorbed}/{expected} splits absorbed");
                    }
                    let t = Stopwatch::new();
                    if partial {
                        sink.finish_round_partial();
                    } else {
                        sink.finish_round();
                    }
                    absorb_secs += t.elapsed_secs();
                    stream.write_all(&encode_shard_slice(0, absorb_secs, &sink.encode_slice()))?;
                    stream.flush()?;
                    break;
                }
                Some(FrameBody::ShardAbort) => {
                    // Hand back the mid-round (unfinished) state so the
                    // coordinator's mirror matches what a parked local
                    // lane sink would hold after the same abort. The next
                    // begin supersedes this round, exactly like a lane.
                    stream.write_all(&encode_shard_slice(0, absorb_secs, &sink.encode_slice()))?;
                    stream.flush()?;
                    break;
                }
                Some(FrameBody::Shutdown) => return Ok(true),
                Some(other) => bail!("unexpected mid-round shard frame {other:?}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn update(round: usize, client: usize, slot: usize, n: usize) -> WireMessage {
        WireMessage {
            round,
            client_id: client,
            slot,
            payload: Payload::Update(Encoded {
                bytes: (0..n).map(|i| (i * 7 + client) as u8).collect(),
            }),
            enc_secs: 0.0625,
            loss: 1.5,
        }
    }

    fn decode_all(frame_bytes: &[u8], max_frame: usize) -> Result<FrameBody> {
        let header: [u8; HEADER_LEN] = frame_bytes[..HEADER_LEN].try_into().unwrap();
        let h = parse_header(&header, max_frame)?;
        parse_frame(h, &frame_bytes[HEADER_LEN..])
    }

    #[test]
    fn frames_round_trip() {
        let msg = update(3, 41, 5, 100);
        let body = decode_all(&encode_message(&msg), 1 << 20).unwrap();
        match body {
            FrameBody::Msg(m) => {
                assert_eq!(m.round, 3);
                assert_eq!(m.client_id, 41);
                assert_eq!(m.slot, 5);
                assert_eq!(m.enc_secs, 0.0625);
                assert_eq!(m.loss, 1.5);
                assert_eq!(m.payload_bytes(), 100);
            }
            other => panic!("wrong body {other:?}"),
        }

        let failed = WireMessage {
            payload: Payload::Failed("oom while training".into()),
            ..update(1, 2, 0, 0)
        };
        match decode_all(&encode_message(&failed), 1 << 20).unwrap() {
            FrameBody::Msg(m) => {
                assert!(matches!(m.payload, Payload::Failed(ref e) if e == "oom while training"))
            }
            other => panic!("wrong body {other:?}"),
        }

        let hello = Hello {
            conn_index: 2,
            conns_total: 4,
            fingerprint: ConfigFingerprint {
                seed: 42,
                n_clients: 10,
                rounds: 3,
                d: 1000,
            },
        };
        match decode_all(&encode_hello(&hello), 1 << 20).unwrap() {
            FrameBody::Hello(h) => assert_eq!(h, hello),
            other => panic!("wrong body {other:?}"),
        }

        match decode_all(&encode_eor(7), 1 << 20).unwrap() {
            FrameBody::EndOfRound(r) => assert_eq!(r, 7),
            other => panic!("wrong body {other:?}"),
        }
        assert!(matches!(
            decode_all(&encode_shutdown(), 1 << 20).unwrap(),
            FrameBody::Shutdown
        ));
    }

    #[test]
    fn plan_frames_round_trip_and_rederive_the_mask() {
        use crate::coordinator::round::RoundEngine;
        let theta: Vec<f32> = (0..64).map(|i| 0.2 + (i as f32) * 0.01).collect();
        let s: Vec<f32> = (0..64).map(|i| -1.0 + (i as f32) * 0.02).collect();
        let plan = RoundEngine::new(42, 10, 0.5, 0.8, 0.25, 10).plan(2, &theta, &s);
        match decode_all(&encode_plan(&plan), 1 << 20).unwrap() {
            FrameBody::Plan(w) => {
                let rebuilt = w.into_round_plan();
                assert_eq!(rebuilt.round, plan.round);
                assert_eq!(rebuilt.seed, plan.seed);
                assert_eq!(rebuilt.kappa, plan.kappa);
                assert_eq!(rebuilt.participants, plan.participants);
                assert_eq!(rebuilt.theta_g, plan.theta_g);
                assert_eq!(rebuilt.s_g, plan.s_g);
                assert_eq!(rebuilt.mask_g, plan.mask_g, "CRN mask re-derived bitwise");
            }
            other => panic!("wrong body {other:?}"),
        }
    }

    #[test]
    fn header_rejections_are_errors_not_panics() {
        let good = header_bytes(K_UPDATE, 9, 32);
        assert!(parse_header(&good, 1 << 20).is_ok());

        let mut bad = good;
        bad[0] = b'X';
        assert!(parse_header(&bad, 1 << 20).is_err(), "magic");
        let mut bad = good;
        bad[4] = 9;
        assert!(parse_header(&bad, 1 << 20).is_err(), "version");
        let mut bad = good;
        bad[5] = 0;
        assert!(parse_header(&bad, 1 << 20).is_err(), "kind 0");
        let mut bad = good;
        bad[5] = 200;
        assert!(parse_header(&bad, 1 << 20).is_err(), "kind out of range");
        let mut bad = good;
        bad[6] = 1;
        assert!(parse_header(&bad, 1 << 20).is_err(), "reserved");
        let oversized = header_bytes(K_UPDATE, 9, (1 << 20) + 1);
        assert!(
            parse_header(&oversized, 1 << 20).is_err(),
            "length above the cap"
        );
    }

    #[test]
    fn session_must_match_the_client_id() {
        let mut f = encode_message(&update(0, 300, 1, 8));
        // Flip a session byte: the integrity cross-check fires.
        f[8] ^= 0xFF;
        // Keep header length consistent so the payload parse is reached.
        assert!(decode_all(&f, 1 << 20).is_err());
    }

    #[test]
    fn loopback_hub_delivers_over_a_real_socket() {
        for kind in [TransportKind::Uds, TransportKind::Tcp] {
            let hub = SocketHub::bind_loopback(kind, SocketConfig::default(), 3).unwrap();
            let (mut transport, sender) = hub.round_link(8).unwrap();
            for c in 0..8 {
                sender.send(update(0, c, c, 64)).unwrap();
            }
            drop(sender);
            let mut slots: Vec<usize> =
                std::iter::from_fn(|| transport.recv()).map(|m| m.slot).collect();
            slots.sort_unstable();
            assert_eq!(slots, (0..8).collect::<Vec<_>>(), "{kind:?}");
            let st = transport.stats();
            assert_eq!(st.sent_messages, 8);
            assert_eq!(st.sent_payload_bytes, 8 * 64);
            assert_eq!(st.received_messages, 8);
            assert_eq!(st.wire_frames, 8);
            assert_eq!(st.wire_bytes, 8 * (HEADER_LEN + 36 + 64) as u64);
        }
    }

    #[test]
    fn shard_frames_round_trip_and_reject_structural_garbage() {
        let fp = ConfigFingerprint {
            seed: 7,
            n_clients: 12,
            rounds: 4,
            d: 100,
        };
        let hello = ShardHello {
            fingerprint: fp,
            range_start: 25,
            range_end: 75,
            state: vec![1, 2, 3, 4],
        };
        match decode_all(&encode_shard_hello(1, &hello), 1 << 20).unwrap() {
            FrameBody::ShardHello(h) => assert_eq!(h, hello),
            other => panic!("wrong body {other:?}"),
        }
        // Inverted or out-of-dimension bounds are rejected at parse.
        let inverted = ShardHello {
            range_start: 75,
            range_end: 25,
            ..hello.clone()
        };
        assert!(decode_all(&encode_shard_hello(1, &inverted), 1 << 20).is_err());
        let oversized = ShardHello {
            range_end: 101,
            ..hello.clone()
        };
        assert!(decode_all(&encode_shard_hello(1, &oversized), 1 << 20).is_err());

        match decode_all(&encode_shard_begin(2, 9, 8), 1 << 20).unwrap() {
            FrameBody::ShardBegin { seq, expected } => {
                assert_eq!((seq, expected), (9, 8));
            }
            other => panic!("wrong body {other:?}"),
        }

        let data = vec![0.0f32, 1.0, 0.5, -2.25];
        match decode_all(&encode_shard_split(0, 3, 1, &data), 1 << 20).unwrap() {
            FrameBody::ShardSplit(s) => {
                assert_eq!(s.slot, 3);
                assert_eq!(s.family, 1);
                assert_eq!(s.data, data);
            }
            other => panic!("wrong body {other:?}"),
        }
        // Unknown family bytes and torn f32 runs are parse errors.
        assert!(decode_all(&encode_shard_split(0, 3, 2, &data), 1 << 20).is_err());
        let mut torn = encode_shard_split(0, 3, 0, &data);
        torn.truncate(torn.len() - 2);
        let torn_len = torn.len() - HEADER_LEN;
        torn[12..16].copy_from_slice(&(torn_len as u32).to_le_bytes());
        assert!(decode_all(&torn, 1 << 20).is_err());

        for partial in [false, true] {
            match decode_all(&encode_shard_finish(0, partial), 1 << 20).unwrap() {
                FrameBody::ShardFinish { partial: p } => assert_eq!(p, partial),
                other => panic!("wrong body {other:?}"),
            }
        }
        assert!(matches!(
            decode_all(&encode_shard_abort(0), 1 << 20).unwrap(),
            FrameBody::ShardAbort
        ));
        match decode_all(&encode_shard_slice(0, 0.125, &[9, 9]), 1 << 20).unwrap() {
            FrameBody::ShardSlice { absorb_secs, state } => {
                assert_eq!(absorb_secs, 0.125);
                assert_eq!(state, vec![9, 9]);
            }
            other => panic!("wrong body {other:?}"),
        }
    }

    #[test]
    fn transport_kind_parses() {
        assert_eq!(TransportKind::parse("channel"), Some(TransportKind::Channel));
        assert_eq!(TransportKind::parse("tcp"), Some(TransportKind::Tcp));
        assert_eq!(TransportKind::parse("uds"), Some(TransportKind::Uds));
        assert_eq!(TransportKind::parse("unix"), Some(TransportKind::Uds));
        assert_eq!(TransportKind::parse("smoke-signals"), None);
    }
}
