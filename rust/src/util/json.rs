//! Minimal JSON: a writer for result emission and a recursive-descent parser
//! for `artifacts/manifest.json`. No external crates (offline vendor set has
//! no serde).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. `BTreeMap` keeps emission deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Self {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("set() on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn from_f64(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn from_str_(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    Json::Str(k.clone()).write(out, indent + 1, pretty);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    val.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (full spec minus `\u` surrogate pairs, which we
    /// map through directly — the manifest is ASCII).
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing data at byte {pos}"));
        }
        Ok(v)
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end".into());
    }
    match b[*pos] {
        b'{' => {
            *pos += 1;
            let mut m = BTreeMap::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(m));
            }
            loop {
                skip_ws(b, pos);
                let key = match parse_value(b, pos)? {
                    Json::Str(s) => s,
                    _ => return Err("object key must be string".into()),
                };
                skip_ws(b, pos);
                if *pos >= b.len() || b[*pos] != b':' {
                    return Err(format!("expected ':' at {pos}"));
                }
                *pos += 1;
                let val = parse_value(b, pos)?;
                m.insert(key, val);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(m));
                    }
                    _ => return Err(format!("expected ',' or '}}' at {pos}")),
                }
            }
        }
        b'[' => {
            *pos += 1;
            let mut v = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(v));
            }
            loop {
                v.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(v));
                    }
                    _ => return Err(format!("expected ',' or ']' at {pos}")),
                }
            }
        }
        b'"' => {
            *pos += 1;
            let mut s = String::new();
            while *pos < b.len() {
                match b[*pos] {
                    b'"' => {
                        *pos += 1;
                        return Ok(Json::Str(s));
                    }
                    b'\\' => {
                        *pos += 1;
                        match b.get(*pos) {
                            Some(b'"') => s.push('"'),
                            Some(b'\\') => s.push('\\'),
                            Some(b'/') => s.push('/'),
                            Some(b'n') => s.push('\n'),
                            Some(b't') => s.push('\t'),
                            Some(b'r') => s.push('\r'),
                            Some(b'b') => s.push('\u{8}'),
                            Some(b'f') => s.push('\u{c}'),
                            Some(b'u') => {
                                let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                                    .map_err(|_| "bad \\u escape")?;
                                let code = u32::from_str_radix(hex, 16)
                                    .map_err(|_| "bad \\u escape")?;
                                s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                                *pos += 4;
                            }
                            _ => return Err("bad escape".into()),
                        }
                        *pos += 1;
                    }
                    c => {
                        // UTF-8 passthrough.
                        let len = utf8_len(c);
                        s.push_str(
                            std::str::from_utf8(&b[*pos..*pos + len])
                                .map_err(|_| "bad utf-8")?,
                        );
                        *pos += len;
                    }
                }
            }
            Err("unterminated string".into())
        }
        b't' => {
            expect(b, pos, "true")?;
            Ok(Json::Bool(true))
        }
        b'f' => {
            expect(b, pos, "false")?;
            Ok(Json::Bool(false))
        }
        b'n' => {
            expect(b, pos, "null")?;
            Ok(Json::Null)
        }
        _ => {
            let start = *pos;
            while *pos < b.len()
                && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            {
                *pos += 1;
            }
            let s = std::str::from_utf8(&b[start..*pos]).map_err(|_| "bad number")?;
            s.parse::<f64>()
                .map(Json::Num)
                .map_err(|_| format!("bad number '{s}'"))
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

fn expect(b: &[u8], pos: &mut usize, lit: &str) -> Result<(), String> {
    if b.len() >= *pos + lit.len() && &b[*pos..*pos + lit.len()] == lit.as_bytes() {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("expected '{lit}' at {pos}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut j = Json::obj();
        j.set("name", Json::from_str_("delta\"mask\n"))
            .set("d", Json::Num(327680.0))
            .set("bpp", Json::Num(0.151))
            .set("ok", Json::Bool(true))
            .set("none", Json::Null)
            .set("arr", Json::arr_f64(&[1.0, 2.5, -3.0]));
        for text in [j.to_string_pretty(), j.to_string_compact()] {
            let back = Json::parse(&text).unwrap();
            assert_eq!(back, j, "text={text}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[{"b":1e-3},[],{}],"c":"Ax"}"#).unwrap();
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[0]
                .get("b")
                .unwrap()
                .as_f64()
                .unwrap(),
            1e-3
        );
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "Ax");
    }

    #[test]
    fn parse_errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn integers_emitted_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }
}
