//! Federated experiment layer: configuration, state ownership and the
//! paper's Alg. 1 round loop, built **on top of the
//! [`coordinator`](crate::coordinator) subsystem**.
//!
//! Division of labour after the refactor:
//! * `coordinator::RoundEngine` plans each round (participant sampling,
//!   κ schedule, per-round seeds, the shared-seed mask m^{g,t-1});
//! * `coordinator::ClientPool` trains + encodes participants with
//!   work-stealing scheduling;
//! * `coordinator::Transport` carries the encoded updates with byte and
//!   latency accounting;
//! * [`server::MaskServer`] absorbs updates as they arrive
//!   (`begin_round` / `absorb` / `finish_round`), Bayesian for the mask
//!   family, FedAvg-on-scores for the delta family;
//! * [`runner::Runner`] (this layer) owns model/data/session state, wires
//!   the pieces together per [`ExperimentConfig`], and runs the
//!   weight-space baselines.
//!
//! Operator knobs live in ONE declarative table ([`knobs`]): each entry
//! pairs a CLI flag with its `DELTAMASK_*` environment spelling and the
//! `ExperimentConfig` field it writes, so the flag/env/field triplication
//! the CLI, tests and CI matrix share cannot drift. The server-side
//! subset (pipeline/workers/shards/placement/quorum/deadline/decode-error)
//! is grouped into the nested [`ServerTuning`] struct, which assembles the
//! coordinator's `DrainConfig`/`DrainPolicy`/`ShardPlacement` directly.

pub mod client;
pub mod data;
pub mod knobs;
pub mod metrics;
pub mod remote;
pub mod runner;
pub mod server;

pub use metrics::{ExperimentResult, RoundMetrics};
pub use runner::Runner;

use crate::model::ArchConfig;
use anyhow::{anyhow, Result};

/// Head-initialization strategy (§3.3 / Table 5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HeadInit {
    /// One (or more) federated linear-probing rounds — the paper's default.
    Lp,
    /// Kaiming-style random head, frozen (DeltaMask_He).
    He,
    /// FiT-LDA data-driven head (DeltaMask_FiT).
    Fit,
}

/// Execution backend selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT XLA graphs through PJRT (L1 Pallas + L2 JAX) — production path.
    Xla,
    /// Pure-rust mirror — cross-check + fast miniature sweeps.
    Native,
}

/// The server-side scaling and completion knobs, grouped: how a round's
/// drain schedules decode/absorb work and when it declares the round done.
/// Every knob here is scheduling/fault policy only — **bitwise identical
/// trajectories at any setting** (the drains guarantee it; the quorum
/// knobs change outcomes only when faults actually remove records).
/// Assembled from the CLI/env by the [`knobs`] table; turned into the
/// coordinator's types via [`ServerTuning::to_drain_config`] /
/// [`ServerTuning::to_drain_policy`] / [`ServerTuning::shard_placement`].
#[derive(Clone, Debug)]
pub struct ServerTuning {
    /// Server-side decode→aggregate scheduling: streaming (per-arrival,
    /// O(d) server memory — the default) or batch (the old full-round
    /// barrier, kept for A/B comparisons); see `coordinator::PipelineMode`.
    pub pipeline: crate::coordinator::PipelineMode,
    /// Server decode worker threads (`--decode-workers N`): 1 decodes
    /// inline on the draining thread (the serial reference path), N > 1
    /// shards the Eq. 5 decode sweep across N scoped workers, 0 uses one
    /// worker per available core; see `coordinator::DrainConfig`.
    pub decode_workers: usize,
    /// Server aggregation shards (`--agg-shards N`): 1 keeps the single
    /// absorb lane (the reference path), N > 1 partitions the parameter
    /// space into N contiguous dimension shards — each with its own
    /// pseudo-count slice, participation counters and scratch pool —
    /// absorbed on N parallel lanes (`coordinator::ShardedAggregator`),
    /// 0 uses one shard per available core. The knob surface is
    /// documented in `docs/SCALING.md`.
    pub agg_shards: usize,
    /// Per-shard lane placement (`--shard-place SPEC`, env
    /// `DELTAMASK_SHARD_PLACE`): a comma-separated site per shard —
    /// `local` (in-process thread lane), `uds:<path>` or
    /// `tcp:<host:port>` (a `deltamask shard-worker` process reached
    /// over the DMW1 wire). Empty (the default) runs every shard local.
    /// Parsed by `coordinator::ShardPlacement`; remote lanes are
    /// trajectory-identical to local ones.
    pub shard_place: String,
    /// Round-resident drain pipeline (`--persistent-pipeline`, env
    /// `DELTAMASK_PERSISTENT_PIPELINE=1`): spawn the decode workers and
    /// the dimension-shard absorb lanes **once per experiment** and park
    /// them between rounds (`coordinator::DrainPipeline`).
    pub persistent_pipeline: bool,
    /// Round-completion quorum (`--quorum Q`, env `DELTAMASK_QUORUM`) as a
    /// fraction of the planned cohort in (0, 1]. The drain never exits
    /// early on quorum — it waits for the full cohort, the uplink closing
    /// or the deadline — but once the round ends, `ceil(Q·K)` absorbed
    /// updates suffice to finish **degraded** over the survivors instead
    /// of aborting. 1.0 (the default) is the strict all-K behaviour.
    pub quorum: f64,
    /// Per-round drain deadline in milliseconds (`--round-deadline-ms`,
    /// env `DELTAMASK_ROUND_DEADLINE_MS`); 0 (the default) waits forever.
    /// On expiry the round finishes if quorum is met, errors otherwise —
    /// see `coordinator::DrainPolicy`.
    pub round_deadline_ms: u64,
    /// What an undecodable record does to the round
    /// (`--on-decode-error {abort,skip}`, env `DELTAMASK_ON_DECODE_ERROR`):
    /// `abort` (the default) fails the round on the first decode error;
    /// `skip` counts the record as corrupt and lets it fall against quorum.
    pub on_decode_error: crate::coordinator::OnDecodeError,
}

impl Default for ServerTuning {
    fn default() -> Self {
        Self {
            pipeline: crate::coordinator::PipelineMode::default(),
            decode_workers: 1,
            agg_shards: 1,
            shard_place: String::new(),
            persistent_pipeline: false,
            quorum: 1.0,
            round_deadline_ms: 0,
            on_decode_error: crate::coordinator::OnDecodeError::default(),
        }
    }
}

impl ServerTuning {
    /// The round-completion policy the drain runs under, assembled from
    /// the three fault-tolerance knobs.
    pub fn to_drain_policy(&self) -> crate::coordinator::DrainPolicy {
        crate::coordinator::DrainPolicy {
            quorum: self.quorum,
            deadline_ms: self.round_deadline_ms,
            on_decode_error: self.on_decode_error,
        }
    }

    /// The full drain configuration (mode × decode workers × aggregation
    /// shards, with the completion policy attached) — the single value the
    /// runner hands to `coordinator::drain_round` / `DrainPipeline`.
    pub fn to_drain_config(&self) -> crate::coordinator::DrainConfig {
        crate::coordinator::DrainConfig::sharded(
            self.pipeline,
            self.decode_workers,
            self.agg_shards,
        )
        .with_policy(self.to_drain_policy())
    }

    /// The parsed per-shard lane placement. An empty spec is the
    /// all-local default; a malformed one is a config error (the knob
    /// table validates eagerly, so this only fails for specs assembled
    /// programmatically).
    pub fn shard_placement(&self) -> Result<crate::coordinator::ShardPlacement> {
        crate::coordinator::ShardPlacement::parse(&self.shard_place)
    }
}

/// Full experiment configuration (defaults follow the paper App. C.1).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub dataset: String,
    pub arch: String,
    pub method: String,
    pub n_clients: usize,
    pub rounds: usize,
    pub rho: f64,
    pub local_epochs: usize,
    pub samples_per_client: usize,
    pub test_samples: usize,
    pub dirichlet_alpha: f64,
    pub kappa0: f64,
    /// Cosine-schedule floor as a fraction of κ₀ (1.0 ⇒ constant κ, used by
    /// the Fig. 8 ablation).
    pub kappa_floor: f64,
    pub seed: u64,
    pub eval_every: usize,
    pub backend: BackendKind,
    pub head_init: HeadInit,
    pub lp_rounds: usize,
    /// Initial mask probability θ₀. For fine-tuning a *pre-trained* model
    /// the mask starts near "keep everything" (Piggyback-style); 0.5 would
    /// emulate the random-init FedPM regime instead.
    pub theta0: f32,
    /// Override the architecture geometry (the benches shrink F to keep the
    /// CPU sweeps tractable; bpp math is scale-relative).
    pub arch_override: Option<ArchConfig>,
    /// The server-side scaling/completion knob group — see [`ServerTuning`].
    pub tuning: ServerTuning,
    /// Deterministic chaos-injection spec (`--chaos SPEC`, env
    /// `DELTAMASK_CHAOS`), e.g. `"seed=7,drop=0.1,straggle=0.2"` — parsed
    /// by `coordinator::FaultPlan::parse`. Empty (the default) runs the
    /// clean transport; a non-empty spec wraps the uplink in
    /// `coordinator::ChaosTransport`, with every fault a pure function of
    /// (seed, round, client), so a faulted run is reproducible in CI.
    pub chaos: String,
    /// Which uplink the experiment runs over (`--transport
    /// {channel,tcp,uds}`, env `DELTAMASK_TRANSPORT`). `Channel` (the
    /// default) is the in-process mpsc path; `Tcp`/`Uds` route every
    /// update through the length-prefixed framed socket transport —
    /// loopback inside `run_experiment` (one fresh socket link per round,
    /// trajectory-identical to the channel), or across OS processes via
    /// `deltamask serve` / `deltamask client-fleet`
    /// ([`remote::serve_experiment`] / [`remote::run_client_fleet`]).
    pub transport: crate::coordinator::TransportKind,
}

/// Default decode-worker count: `$DELTAMASK_DECODE_WORKERS` when set (CI's
/// tier-1 job re-runs the `fl_integration` suite with `=4` to exercise the
/// sharded server path end-to-end), else 1 (serial).
///
/// Panics if the variable is set but not a non-negative integer — a
/// malformed value silently falling back to the serial path would let the
/// CI sharded re-run pass while exercising nothing. (Parsing and panic
/// message live in the [`knobs`] table; this is a convenience reader for
/// tests and examples that assemble configs field-by-field.)
pub fn decode_workers_from_env() -> usize {
    knobs::env_only("DELTAMASK_DECODE_WORKERS").tuning.decode_workers
}

/// Default aggregation-shard count: `$DELTAMASK_AGG_SHARDS` when set
/// (CI's tier-1 job re-runs the `fl_integration` suite with `=4` so the
/// dimension-sharded absorb path is exercised end-to-end), else 1 (one
/// absorb lane). Same parse-or-panic policy as
/// [`decode_workers_from_env`], via the [`knobs`] table.
pub fn agg_shards_from_env() -> usize {
    knobs::env_only("DELTAMASK_AGG_SHARDS").tuning.agg_shards
}

/// Default shard-lane placement: `$DELTAMASK_SHARD_PLACE` when set (CI's
/// knob-matrix `remote-shards` entry points the suites at standing
/// `deltamask shard-worker` processes over UDS), else empty (every lane
/// in-process). A set-but-malformed spec panics via the [`knobs`] table —
/// the same fail-loudly policy as the other CI-gating knobs.
pub fn shard_place_from_env() -> String {
    knobs::env_only("DELTAMASK_SHARD_PLACE").tuning.shard_place
}

/// Default update-codec method: `$DELTAMASK_METHOD` when set and
/// non-empty (CI's knob-matrix job runs the `fl_integration` suite with
/// `=deltamask-pco` so the codec-9 numeric-latent wire path is exercised
/// under the full scaling stack), else `"deltamask"`.
///
/// No validation here: an unknown name fails loudly downstream, because
/// [`run_experiment`] bails on any method `compress::by_name` doesn't
/// resolve — the same can't-silently-exercise-nothing policy as the
/// integer knobs.
pub fn method_from_env() -> String {
    knobs::env_only("DELTAMASK_METHOD").method
}

/// Default for the round-resident drain pipeline:
/// `$DELTAMASK_PERSISTENT_PIPELINE` when set (CI's knob-matrix job runs
/// the `fl_integration` suite with `=1` combined with the sharding knobs,
/// so the resident path is exercised end-to-end), else off.
///
/// Panics (via the [`knobs`] table) if the variable is set but not one of
/// `0/1/true/false` — the same fail-loudly policy as the other CI-gating
/// knobs.
pub fn persistent_pipeline_from_env() -> bool {
    knobs::env_only("DELTAMASK_PERSISTENT_PIPELINE").tuning.persistent_pipeline
}

/// Default round-completion quorum: `$DELTAMASK_QUORUM` when set (CI's
/// knob-matrix `churn` entry runs the suite with `<1.0` plus a seeded
/// `DELTAMASK_CHAOS` spec so degraded completion is exercised end-to-end),
/// else 1.0 (strict all-K). Empty means unset (the CI matrix sets every
/// knob key for every entry, with "" for the knobs an entry doesn't
/// exercise); a set-but-malformed or out-of-(0, 1] value panics via the
/// [`knobs`] table.
pub fn quorum_from_env() -> f64 {
    knobs::env_only("DELTAMASK_QUORUM").tuning.quorum
}

/// Default per-round drain deadline: `$DELTAMASK_ROUND_DEADLINE_MS` when
/// set, else 0 (wait forever). Panics on a set-but-malformed value via
/// the [`knobs`] table.
pub fn round_deadline_ms_from_env() -> u64 {
    knobs::env_only("DELTAMASK_ROUND_DEADLINE_MS").tuning.round_deadline_ms
}

/// Default decode-error policy: `$DELTAMASK_ON_DECODE_ERROR` when set
/// (`abort` or `skip`), else abort. Panics on anything else via the
/// [`knobs`] table.
pub fn on_decode_error_from_env() -> crate::coordinator::OnDecodeError {
    knobs::env_only("DELTAMASK_ON_DECODE_ERROR").tuning.on_decode_error
}

/// Default uplink transport: `$DELTAMASK_TRANSPORT` when set (CI's
/// knob-matrix `uds-transport` entry runs the `fl_integration` and
/// `churn` suites with `=uds` so every update crosses a real socket),
/// else the in-process channel. Empty means unset; anything that is not
/// `channel`/`tcp`/`uds` panics via the [`knobs`] table.
pub fn transport_from_env() -> crate::coordinator::TransportKind {
    knobs::env_only("DELTAMASK_TRANSPORT").transport
}

/// Default chaos spec: `$DELTAMASK_CHAOS` when set (CI's knob-matrix
/// `churn` entry injects a seeded fault plan under the full scaling
/// stack), else empty (clean transport). Validated eagerly via
/// `FaultPlan::parse` in the [`knobs`] table so a typo'd spec fails at
/// startup, not as a mysteriously-clean run.
pub fn chaos_from_env() -> String {
    knobs::env_only("DELTAMASK_CHAOS").chaos
}

impl Default for ExperimentConfig {
    /// Paper defaults with every `DELTAMASK_*` env knob applied (the CI
    /// matrix steers the test suites through the env spellings). The
    /// knob resolution order is: hard default → env → CLI (the CLI layer
    /// applies `knobs::apply_cli` on top of this).
    fn default() -> Self {
        let mut cfg = Self::base();
        knobs::apply_env(&mut cfg);
        cfg
    }
}

impl ExperimentConfig {
    /// The paper's App. C.1 defaults with **no** environment applied —
    /// the fixed point the knob table resolves env/CLI spellings against.
    pub(crate) fn base() -> Self {
        Self {
            dataset: "cifar100".into(),
            arch: "vitb32".into(),
            method: "deltamask".into(),
            n_clients: 10,
            rounds: 30,
            rho: 1.0,
            local_epochs: 1, // paper: E=1
            samples_per_client: 64,
            test_samples: 512,
            dirichlet_alpha: 10.0, // IID
            kappa0: 0.8, // paper §4
            kappa_floor: 0.25,
            seed: 42,
            eval_every: 5,
            backend: BackendKind::Native,
            head_init: HeadInit::Lp,
            lp_rounds: 1,
            theta0: 0.85,
            arch_override: None,
            tuning: ServerTuning::default(),
            chaos: String::new(),
            transport: crate::coordinator::TransportKind::default(),
        }
    }
}

/// Architecture widths (mirrors `aot.py`'s ARCHS). Returns (F, B).
pub fn arch_width(arch: &str) -> Option<(usize, usize)> {
    Some(match arch {
        "vitb32" => (256, 64),
        "vitl14" => (384, 64),
        "dinov2b" => (320, 64),
        "dinov2s" => (160, 64),
        "convmixer" => (288, 64),
        "test" => (32, 8),
        _ => return None,
    })
}

impl ExperimentConfig {
    pub fn arch_config(&self) -> ArchConfig {
        if let Some(a) = self.arch_override {
            return a;
        }
        let (f, b) = arch_width(&self.arch).unwrap_or((256, 64));
        let classes = data::profile(&self.dataset).map(|p| p.classes).unwrap_or(100);
        ArchConfig::new(f, classes, b, 5)
    }

    /// Miniature geometry for fast sweeps: same class structure, narrow
    /// blocks. bpp is measured relative to the miniature d.
    pub fn miniaturize(mut self, f: usize, b: usize) -> Self {
        let classes = data::profile(&self.dataset).map(|p| p.classes).unwrap_or(100);
        self.arch_override = Some(ArchConfig::new(f, classes, b, 5));
        self
    }

    /// The config facts two cooperating processes (serve / client-fleet /
    /// shard-worker) must agree on for lockstep trajectories, checked in
    /// every socket handshake. Everything else diverges loudly later via
    /// the plan/update/split frames themselves.
    pub fn fingerprint(&self) -> crate::coordinator::ConfigFingerprint {
        crate::coordinator::ConfigFingerprint {
            seed: self.seed,
            n_clients: self.n_clients as u64,
            rounds: self.rounds as u64,
            d: self.arch_config().d() as u64,
        }
    }

    /// The parsed chaos plan, or `None` when the spec is empty / inert
    /// (all rates zero) — callers skip the `ChaosTransport` wrapper
    /// entirely in that case so the default path stays byte-for-byte the
    /// clean transport.
    pub fn fault_plan(&self) -> Result<Option<crate::coordinator::FaultPlan>> {
        if self.chaos.is_empty() {
            return Ok(None);
        }
        let plan = crate::coordinator::FaultPlan::parse(&self.chaos)?;
        Ok(if plan.is_active() { Some(plan) } else { None })
    }
}

/// Run one experiment end-to-end with the configured method/backend.
/// This is the single entry point the CLI, the examples and every bench use.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<ExperimentResult> {
    with_backend(cfg, |backend| {
        let mut runner = Runner::new(cfg, backend)?;
        match cfg.method.as_str() {
            "fine_tuning" => runner.run_finetuning(),
            "linear_probing" => runner.run_linear_probing(),
            name => {
                // Arc because the round-resident pipeline's decode workers
                // hold the codec across rounds.
                let codec: std::sync::Arc<dyn crate::compress::UpdateCodec> =
                    std::sync::Arc::from(
                        crate::compress::by_name(name)
                            .ok_or_else(|| anyhow!("unknown method '{name}'"))?,
                    );
                runner.run_codec(codec)
            }
        }
    })
}

/// Construct the configured backend and hand it to `f` — the shared
/// backend-selection path for [`run_experiment`] and the two-process
/// entry points in [`remote`].
pub(crate) fn with_backend<R>(
    cfg: &ExperimentConfig,
    f: impl FnOnce(&dyn crate::model::Backend) -> Result<R>,
) -> Result<R> {
    let holder: BackendHolder = match cfg.backend {
        BackendKind::Native => BackendHolder::Native(crate::native::NativeBackend),
        BackendKind::Xla => {
            let exec = std::sync::Arc::new(crate::runtime::Executor::from_artifacts()?);
            let arch = cfg.arch_config();
            BackendHolder::Xla(crate::runtime::XlaBackend::new(exec, &cfg.arch, arch.c)?)
        }
    };
    let backend: &dyn crate::model::Backend = match &holder {
        BackendHolder::Native(b) => b,
        BackendHolder::Xla(b) => b,
    };
    f(backend)
}

enum BackendHolder {
    Native(crate::native::NativeBackend),
    Xla(crate::runtime::XlaBackend),
}
