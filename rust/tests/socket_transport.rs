//! Framed socket transport suite: frame-codec totality under mutation,
//! bounded-admission backpressure, multiplexed-session integrity, torn-frame
//! connection death, the `recv_deadline` outcome ordering over a real wire —
//! and the two-process `serve` / `client-fleet` end-to-end, asserted
//! trajectory-identical to the in-process channel run.
//!
//! The loopback tests build directly on the socket module's public surface
//! (`SocketHub`, `FleetServer`, the frame codec); the end-to-end test drives
//! the installed binary through `CARGO_BIN_EXE_deltamask`, so the whole CLI
//! path — config parsing, handshake fingerprint, plan broadcast, EOR
//! barrier, shutdown — is under test, not just the library.

use deltamask::compress::Encoded;
use deltamask::coordinator::transport::socket::{
    encode_eor, encode_hello, encode_message, encode_plan, encode_shutdown, parse_frame,
    parse_header, Hello, Listener, Stream, HEADER_LEN, MAGIC, VERSION,
};
use deltamask::coordinator::{
    ConfigFingerprint, FleetServer, Payload, RecvOutcome, RoundEngine, SocketAddrSpec,
    SocketConfig, SocketHub, Transport, TransportKind, TransportSender, WireMessage,
};
use deltamask::util::json::Json;
use deltamask::util::rng::Xoshiro256pp;
use std::io::Write as _;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

/// Deterministic per-client payload bytes, so receivers can verify that a
/// frame's content belongs to the client its session field claims.
fn pattern(client: usize, n: usize) -> Vec<u8> {
    (0..n)
        .map(|i| (i.wrapping_mul(31) ^ client.wrapping_mul(7)) as u8)
        .collect()
}

fn update(round: usize, client: usize, slot: usize, n: usize) -> WireMessage {
    WireMessage {
        round,
        client_id: client,
        slot,
        payload: Payload::Update(Encoded {
            bytes: pattern(client, n),
        }),
        enc_secs: 0.25,
        loss: 2.0,
    }
}

fn fingerprint() -> ConfigFingerprint {
    ConfigFingerprint {
        seed: 5,
        n_clients: 4,
        rounds: 2,
        d: 64,
    }
}

// ---------------------------------------------------------------------
// Frame codec totality
// ---------------------------------------------------------------------

/// Hand-rolled header bytes (magic | version | kind | reserved | session |
/// len), for probing the parser with inputs the encoders would never emit.
fn raw_header(kind: u8, session: u32, len: u32) -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[..4].copy_from_slice(&MAGIC);
    h[4] = VERSION;
    h[5] = kind;
    // h[6..8] reserved, zero
    h[8..12].copy_from_slice(&session.to_le_bytes());
    h[12..16].copy_from_slice(&len.to_le_bytes());
    h
}

/// Every well-formed frame the encoders can produce, one of each kind.
fn corpus() -> Vec<Vec<u8>> {
    let d = 48;
    let theta: Vec<f32> = (0..d).map(|i| 0.1 + 0.8 * (i as f32) / d as f32).collect();
    let s: Vec<f32> = theta.iter().map(|&p| (p / (1.0 - p)).ln()).collect();
    let plan = RoundEngine::new(7, 6, 1.0, 0.8, 0.25, 3).plan(0, &theta, &s);
    vec![
        encode_message(&update(2, 11, 3, 96)),
        encode_message(&update(0, 0, 0, 0)),
        encode_message(&WireMessage {
            payload: Payload::Failed("client oom".into()),
            ..update(1, 5, 2, 0)
        }),
        encode_hello(&Hello {
            conn_index: 1,
            conns_total: 3,
            fingerprint: fingerprint(),
        }),
        encode_plan(&plan),
        encode_eor(9),
        encode_shutdown(),
    ]
}

fn split(frame: &[u8]) -> ([u8; HEADER_LEN], &[u8]) {
    let header: [u8; HEADER_LEN] = frame[..HEADER_LEN].try_into().unwrap();
    (header, &frame[HEADER_LEN..])
}

/// The decoder is total: random bit flips in headers and payloads, truncated
/// and extended payloads, and outright random bytes all come back as
/// `Ok`/`Err` — never a panic, never an out-of-bounds read. Untouched frames
/// keep round-tripping throughout.
#[test]
fn frame_decoding_is_total_under_mutation() {
    const MAX: usize = 1 << 20;
    let mut rng = Xoshiro256pp::new(0x50C4E7);
    let frames = corpus();

    for frame in &frames {
        let (header, payload) = split(frame);
        let h = parse_header(&header, MAX).expect("encoder output must parse");
        parse_frame(h, payload).expect("encoder output must decode");

        for _ in 0..500 {
            // Header mutation: up to 3 flipped bits. If the header still
            // parses, the (unmodified) payload is decoded against it — a
            // changed length or kind must surface as an error, not a panic.
            let mut mh = header;
            for _ in 0..1 + rng.below(3) {
                let bit = rng.below((HEADER_LEN * 8) as u64) as usize;
                mh[bit / 8] ^= 1 << (bit % 8);
            }
            if let Ok(h) = parse_header(&mh, MAX) {
                let _ = parse_frame(h, payload);
            }

            // Payload mutation: flipped bits under an intact header.
            if !payload.is_empty() {
                let mut mp = payload.to_vec();
                for _ in 0..1 + rng.below(4) {
                    let bit = rng.below((mp.len() * 8) as u64) as usize;
                    mp[bit / 8] ^= 1 << (bit % 8);
                }
                let _ = parse_frame(h, &mp);
            }
        }

        // Truncations and extensions: the length cross-check rejects every
        // payload that does not match the header exactly.
        for cut in [0, 1, payload.len().saturating_sub(1)] {
            if cut < payload.len() {
                assert!(parse_frame(h, &payload[..cut]).is_err(), "truncated to {cut}");
            }
        }
        let mut extended = payload.to_vec();
        extended.push(0xAA);
        assert!(parse_frame(h, &extended).is_err(), "extended payload");
    }

    // Fully random headers.
    for _ in 0..2_000 {
        let mut h = [0u8; HEADER_LEN];
        for b in h.iter_mut() {
            *b = rng.below(256) as u8;
        }
        let _ = parse_header(&h, MAX);
    }

    // Valid headers of every kind over random payload bytes of the declared
    // length — this drives the body decoders (including the Plan vector
    // counts) through arbitrary garbage.
    for _ in 0..2_000 {
        let kind = 1 + rng.below(6) as u8;
        let len = rng.below(512) as usize;
        let session = rng.next_u32();
        let h = parse_header(&raw_header(kind, session, len as u32), MAX)
            .expect("well-formed header");
        let mut body = vec![0u8; len];
        for b in body.iter_mut() {
            *b = rng.below(256) as u8;
        }
        let _ = parse_frame(h, &body);
    }

    // A header announcing more than the cap is rejected before any
    // allocation happens.
    assert!(parse_header(&raw_header(1, 0, (MAX + 1) as u32), MAX).is_err());
}

// ---------------------------------------------------------------------
// Backpressure
// ---------------------------------------------------------------------

/// A slow consumer bounds the coordinator's queue memory without losing or
/// reordering anything: the reader parks once the byte budget is hit (the
/// stall counter proves it), the high-water mark never exceeds the budget,
/// and every frame still arrives exactly once, in order.
#[test]
fn backpressure_bounds_queue_memory_and_loses_nothing() {
    let cfg = SocketConfig {
        max_frame: 1 << 20,
        inbound_budget: 4096,
        conn_budget: 4096,
    };
    let hub = SocketHub::bind_loopback(TransportKind::Tcp, cfg, 1).unwrap();
    let (mut transport, sender) = hub.round_link(1).unwrap();
    let total = 300usize;
    let payload = 256usize; // frame cost 308 bytes — ~13 fit in the budget

    let tx = std::thread::spawn(move || {
        for slot in 0..total {
            sender.send(update(0, 0, slot, payload)).unwrap();
        }
        // Dropping the only sender closes the write side: the round ends.
    });

    let mut got = Vec::with_capacity(total);
    while let Some(m) = transport.recv() {
        if got.len() < 150 {
            // Slow consumer for the first half: the sender outruns us and
            // must hit the admission gate.
            std::thread::sleep(Duration::from_millis(1));
        }
        got.push(m.slot);
    }
    tx.join().unwrap();

    assert_eq!(got, (0..total).collect::<Vec<_>>(), "lossless and in order");
    assert!(
        transport.peak_inbound_bytes() <= 4096,
        "queue grew past the budget: {} bytes",
        transport.peak_inbound_bytes()
    );
    let st = transport.stats();
    assert_eq!(st.sent_messages, total as u64);
    assert_eq!(st.received_messages, total as u64);
    assert!(
        st.backpressure_stalls > 0,
        "the slow consumer never backpressured the reader"
    );
    assert_eq!(transport.frame_corruptions(), 0);
}

// ---------------------------------------------------------------------
// Session multiplexing
// ---------------------------------------------------------------------

/// Many logical clients over few connections, written from concurrent
/// threads: every message arrives exactly once with its own client's
/// payload bytes — frames from different sessions sharing a connection
/// never bleed into each other.
#[test]
fn multiplexed_sessions_interleave_without_crosstalk() {
    let clients = 32usize;
    let writers = 4usize;
    let hub = SocketHub::bind_loopback(TransportKind::Uds, SocketConfig::default(), writers).unwrap();
    let (mut transport, sender) = hub.round_link(clients).unwrap();

    let threads: Vec<_> = (0..writers)
        .map(|w| {
            let s = sender.clone_sender();
            std::thread::spawn(move || {
                for c in (w..clients).step_by(writers) {
                    s.send(update(1, c, c, 64 + c)).unwrap();
                }
            })
        })
        .collect();
    drop(sender);
    for t in threads {
        t.join().unwrap();
    }

    let mut seen = vec![false; clients];
    let mut wire_bytes = 0u64;
    while let Some(m) = transport.recv() {
        assert_eq!(m.round, 1);
        assert_eq!(m.slot, m.client_id);
        assert!(!seen[m.client_id], "client {} delivered twice", m.client_id);
        seen[m.client_id] = true;
        match &m.payload {
            Payload::Update(enc) => assert_eq!(
                enc.bytes,
                pattern(m.client_id, 64 + m.client_id),
                "crosstalk: client {} carries foreign bytes",
                m.client_id
            ),
            Payload::Failed(e) => panic!("unexpected failure payload: {e}"),
        }
        wire_bytes += (HEADER_LEN + 36 + 64 + m.client_id) as u64;
    }
    assert!(seen.iter().all(|&s| s), "a session went missing");

    let st = transport.stats();
    assert_eq!(st.sent_messages, clients as u64);
    assert_eq!(st.received_messages, clients as u64);
    assert_eq!(st.wire_frames, clients as u64);
    assert_eq!(st.wire_bytes, wire_bytes);
    assert_eq!(transport.frame_corruptions(), 0);
}

// ---------------------------------------------------------------------
// Handshake and connection death
// ---------------------------------------------------------------------

/// `serve` and `client-fleet` launched with different experiment configs is
/// the deadliest two-process operator error: the Hello fingerprint check
/// fails the handshake before a single round runs.
#[test]
fn fleet_handshake_rejects_a_config_mismatch() {
    let listener = Listener::bind(&SocketAddrSpec::Tcp("127.0.0.1:0".into())).unwrap();
    let spec = listener.local_spec().unwrap();
    let client = std::thread::spawn(move || {
        let mut s = Stream::connect(&spec).unwrap();
        let wrong = Hello {
            conn_index: 0,
            conns_total: 1,
            fingerprint: ConfigFingerprint {
                seed: 999, // everything else agrees; the seed does not
                ..fingerprint()
            },
        };
        s.write_all(&encode_hello(&wrong)).unwrap();
        s.flush().unwrap();
        s // keep the connection alive until the server has judged it
    });
    let err = FleetServer::accept_fleet(&listener, SocketConfig::default(), fingerprint())
        .unwrap_err()
        .to_string();
    assert!(err.contains("fingerprint"), "unexpected error: {err}");
    drop(client.join().unwrap());
}

/// The `recv_deadline` outcome ordering (Msg > Closed > TimedOut), pinned
/// over a real wire — plus torn-frame semantics: a connection dying
/// mid-frame is counted as a corruption and drops out of the round's
/// closure condition, so the drain sees `Closed`, never a hang.
#[test]
fn torn_frames_kill_the_connection_and_close_the_round() {
    let listener = Listener::bind(&SocketAddrSpec::Tcp("127.0.0.1:0".into())).unwrap();
    let spec = listener.local_spec().unwrap();
    let fp = fingerprint();
    let fleet_side = std::thread::spawn(move || {
        let mut a = Stream::connect(&spec).unwrap();
        let mut b = Stream::connect(&spec).unwrap();
        for (i, s) in [&mut a, &mut b].into_iter().enumerate() {
            s.write_all(&encode_hello(&Hello {
                conn_index: i as u32,
                conns_total: 2,
                fingerprint: fp,
            }))
            .unwrap();
            s.flush().unwrap();
        }
        (a, b)
    });
    let mut fleet = FleetServer::accept_fleet(&listener, SocketConfig::default(), fp).unwrap();
    let (mut a, mut b) = fleet_side.join().unwrap();
    let mut transport = fleet.take_transport();

    // Msg beats an already-expired deadline: once the frame lands, a
    // deadline in the past still yields the message, not TimedOut.
    a.write_all(&encode_message(&update(0, 0, 0, 40))).unwrap();
    a.flush().unwrap();
    let msg = loop {
        match transport.recv_deadline(Instant::now()) {
            RecvOutcome::Msg(m) => break m,
            RecvOutcome::TimedOut => std::thread::sleep(Duration::from_millis(1)),
            RecvOutcome::Closed => panic!("live connections must not read as closed"),
        }
    };
    assert_eq!(msg.slot, 0);

    // Live-but-idle wire: a short deadline is a timeout, not a closure.
    match transport.recv_deadline(Instant::now() + Duration::from_millis(20)) {
        RecvOutcome::TimedOut => {}
        other => panic!("expected TimedOut on an idle live wire, got {other:?}"),
    }

    // Connection 0 dies seven bytes into a header; connection 1 finishes
    // the round politely.
    let torn = encode_message(&update(0, 1, 1, 40));
    a.write_all(&torn[..7]).unwrap();
    a.flush().unwrap();
    drop(a);
    b.write_all(&encode_eor(0)).unwrap();
    b.flush().unwrap();

    // One dead connection + one EOR mark = the round is closed, well before
    // any deadline. Closed must win over TimedOut.
    let deadline = Instant::now() + Duration::from_secs(30);
    match transport.recv_deadline(deadline) {
        RecvOutcome::Closed => {}
        other => panic!("expected Closed after death + EOR, got {other:?}"),
    }
    assert!(
        Instant::now() < deadline,
        "closure must not sleep out the deadline"
    );
    assert_eq!(transport.frame_corruptions(), 1, "the torn frame is counted");
    assert_eq!(transport.stats().received_messages, 1);
    drop(b);
}

// ---------------------------------------------------------------------
// Two-process end-to-end
// ---------------------------------------------------------------------

/// The experiment flags shared by all three processes. Small enough for a
/// debug-profile CI run, identical to the churn suite's mini config.
const EXPERIMENT_FLAGS: &[&str] = &[
    "--method", "deltamask", "--dataset", "cifar10", "--arch", "test",
    "--backend", "native", "--head-init", "he", "--clients", "5",
    "--rounds", "3", "--samples", "24", "--test-samples", "100",
    "--alpha", "10", "--seed", "42", "--eval-every", "3", "--epochs", "1",
];

/// A `deltamask` subcommand invocation with the ambient `DELTAMASK_*` knob
/// environment scrubbed, so the test pins its own transport regardless of
/// what the CI matrix exports.
fn deltamask_cmd(sub: &str) -> Command {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_deltamask"));
    for (k, _) in std::env::vars() {
        if k.starts_with("DELTAMASK_") {
            cmd.env_remove(k);
        }
    }
    cmd.arg(sub).args(EXPERIMENT_FLAGS).stdout(Stdio::null());
    cmd
}

fn wait_or_kill(child: &mut Child, label: &str) -> std::process::ExitStatus {
    let deadline = Instant::now() + Duration::from_secs(240);
    loop {
        if let Some(status) = child.try_wait().unwrap() {
            return status;
        }
        if Instant::now() > deadline {
            let _ = child.kill();
            panic!("{label} did not finish within 240s");
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

fn load_json(path: &std::path::Path) -> Json {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    Json::parse(&text).unwrap_or_else(|e| panic!("parsing {}: {e}", path.display()))
}

fn field<'j>(j: &'j Json, key: &str) -> &'j Json {
    j.get(key).unwrap_or_else(|| panic!("missing key {key}"))
}

/// Coordinator and fleet as separate OS processes over a Unix-domain
/// socket, via the real CLI: the run must complete cleanly and its JSON
/// result must match an in-process channel run of the identical config on
/// every transport-invariant fact — losses, bitrates, accuracy, fault
/// counters, completion verdicts and send-time wire counts. The socket
/// frame counters additionally prove the traffic really crossed the wire.
#[test]
fn two_process_uds_run_matches_the_in_process_channel_run() {
    let tmp = std::env::temp_dir();
    let pid = std::process::id();
    let sock = tmp.join(format!("dm-e2e-{pid}.sock"));
    let ref_out = tmp.join(format!("dm-e2e-{pid}-channel.json"));
    let two_out = tmp.join(format!("dm-e2e-{pid}-uds.json"));
    let _ = std::fs::remove_file(&sock);

    // Reference: one process, in-process channel transport.
    let status = deltamask_cmd("train")
        .args(["--transport", "channel", "--out"])
        .arg(&ref_out)
        .status()
        .unwrap();
    assert!(status.success(), "channel reference run failed");

    // Two processes: `serve` owns the coordinator, `client-fleet` trains.
    let mut serve = deltamask_cmd("serve")
        .args(["--transport", "uds", "--listen"])
        .arg(&sock)
        .arg("--out")
        .arg(&two_out)
        .spawn()
        .unwrap();
    let mut fleet = deltamask_cmd("client-fleet")
        .args(["--transport", "uds", "--connections", "3", "--connect"])
        .arg(&sock)
        .spawn()
        .unwrap();
    let serve_status = wait_or_kill(&mut serve, "serve");
    let fleet_status = wait_or_kill(&mut fleet, "client-fleet");
    assert!(serve_status.success(), "serve exited with {serve_status}");
    assert!(fleet_status.success(), "client-fleet exited with {fleet_status}");

    let a = load_json(&ref_out);
    let b = load_json(&two_out);
    for key in ["final_accuracy", "peak_accuracy", "avg_bpp", "total_uplink_mib", "d"] {
        assert_eq!(field(&a, key), field(&b, key), "top-level {key} diverged");
    }
    let ra = field(&a, "rounds").as_arr().unwrap();
    let rb = field(&b, "rounds").as_arr().unwrap();
    assert_eq!(ra.len(), rb.len(), "round count");
    assert_eq!(ra.len(), 3);
    for (x, y) in ra.iter().zip(rb) {
        let r = field(x, "round").as_usize().unwrap();
        for key in ["round", "loss", "bpp", "acc", "quorum_met", "degraded", "faults"] {
            assert_eq!(field(x, key), field(y, key), "round {r}: {key} diverged");
        }
        for key in ["sent_messages", "sent_payload_bytes"] {
            assert_eq!(
                field(field(x, "wire"), key),
                field(field(y, "wire"), key),
                "round {r}: wire.{key} diverged"
            );
        }
        // The channel run never framed anything; the socket run framed at
        // least one frame per message (EOR marks add more).
        let sent = field(field(x, "wire"), "sent_messages").as_f64().unwrap();
        let chan_frames = field(field(x, "wire"), "wire_frames").as_f64().unwrap();
        let sock_frames = field(field(y, "wire"), "wire_frames").as_f64().unwrap();
        assert_eq!(chan_frames, 0.0, "round {r}: channel run framed traffic");
        assert!(
            sock_frames >= sent,
            "round {r}: {sock_frames} frames < {sent} messages over the socket"
        );
    }

    let _ = std::fs::remove_file(&ref_out);
    let _ = std::fs::remove_file(&two_out);
    let _ = std::fs::remove_file(&sock);
}

// ---------------------------------------------------------------------
// Scale
// ---------------------------------------------------------------------

/// Ten thousand logical clients multiplexed over eight connections, written
/// from eight concurrent threads against the default budgets: exactly-once
/// delivery, zero corruption, send-time counters intact.
#[test]
fn ten_thousand_sessions_multiplex_over_a_loopback_socket() {
    let k = 10_000usize;
    let writers = 8usize;
    let payload = 24usize;
    let hub = SocketHub::bind_loopback(TransportKind::Uds, SocketConfig::default(), writers).unwrap();
    let (mut transport, sender) = hub.round_link(k).unwrap();

    let threads: Vec<_> = (0..writers)
        .map(|w| {
            let s = sender.clone_sender();
            std::thread::spawn(move || {
                for c in (w..k).step_by(writers) {
                    s.send(update(0, c, c, payload)).unwrap();
                }
            })
        })
        .collect();
    drop(sender);

    // Drain concurrently with the writers — at this volume the queue and
    // the OS socket buffers are both smaller than the traffic.
    let mut seen = vec![false; k];
    let mut n = 0usize;
    while let Some(m) = transport.recv() {
        assert!(!seen[m.slot], "slot {} delivered twice", m.slot);
        seen[m.slot] = true;
        n += 1;
    }
    for t in threads {
        t.join().unwrap();
    }
    assert_eq!(n, k, "every session's frame arrived exactly once");

    let st = transport.stats();
    assert_eq!(st.sent_messages, k as u64);
    assert_eq!(st.received_messages, k as u64);
    assert_eq!(st.sent_payload_bytes, (k * payload) as u64);
    assert_eq!(transport.frame_corruptions(), 0);
}

/// The acceptance-scale witness: a full multi-round experiment with 10^4
/// multiplexed clients over the UDS loopback, trajectory-identical to the
/// in-process channel run. Ignored by default — minutes of debug-profile
/// training — run with `cargo test --test socket_transport -- --ignored`.
#[test]
#[ignore = "10^4-client experiment: minutes in a debug profile"]
fn ten_thousand_client_experiment_is_transport_invariant() {
    use deltamask::coordinator::{OnDecodeError, PipelineMode};
    use deltamask::fl::{run_experiment, BackendKind, ExperimentConfig, HeadInit};
    let base = ExperimentConfig {
        dataset: "cifar10".into(),
        arch: "test".into(),
        method: "deltamask".into(),
        n_clients: 10_000,
        rounds: 2,
        rho: 1.0,
        local_epochs: 1,
        samples_per_client: 8,
        test_samples: 50,
        dirichlet_alpha: 10.0,
        kappa0: 0.8,
        kappa_floor: 0.25,
        seed: 42,
        eval_every: 2,
        backend: BackendKind::Native,
        head_init: HeadInit::He,
        lp_rounds: 1,
        theta0: 0.85,
        arch_override: None,
        pipeline: PipelineMode::Streaming,
        decode_workers: 2,
        agg_shards: 2,
        persistent_pipeline: true,
        quorum: 1.0,
        round_deadline_ms: 0,
        on_decode_error: OnDecodeError::Abort,
        chaos: String::new(),
        transport: TransportKind::Channel,
    };
    let channel = run_experiment(&base).unwrap();
    let mut cfg = base;
    cfg.transport = TransportKind::Uds;
    let socket = run_experiment(&cfg).unwrap();
    assert_eq!(channel.rounds.len(), socket.rounds.len());
    for (x, y) in channel.rounds.iter().zip(&socket.rounds) {
        let r = x.round;
        assert_eq!(x.train_loss, y.train_loss, "round {r}: loss");
        assert_eq!(x.mean_bpp, y.mean_bpp, "round {r}: bpp");
        assert_eq!(x.accuracy, y.accuracy, "round {r}: accuracy");
        assert_eq!(x.faults, y.faults, "round {r}: fault counters");
        assert_eq!(x.wire.sent_messages, y.wire.sent_messages, "round {r}");
        assert_eq!(
            x.wire.sent_payload_bytes, y.wire.sent_payload_bytes,
            "round {r}"
        );
    }
    assert_eq!(channel.final_accuracy(), socket.final_accuracy());
}
