"""AOT pipeline: the test combo lowers to parseable HLO text and the
manifest matches the graph specs."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M


def test_lower_test_combo(tmp_path):
    entry = aot.lower_combo("test", 10, str(tmp_path), verbose=False)
    assert entry["d"] == 5 * 32 * 32
    assert set(entry["graphs"]) == {"train", "eval", "lp", "ft"}
    for graph, g in entry["graphs"].items():
        path = tmp_path / g["file"]
        text = path.read_text()
        assert text.startswith("HloModule"), f"{graph}: not HLO text"
        assert "ENTRY" in text
        # Input arity recorded in the manifest matches the spec.
        spec = M.graph_specs(M.ModelConfig("test", F=32, C=10, B=8))[graph]
        assert len(g["inputs"]) == len(spec["inputs"])
        assert len(g["outputs"]) == len(spec["outputs"])


def test_hlo_text_parses_back(tmp_path):
    """The text form must be self-contained: parseable by the HLO-text
    parser with the full parameter signature intact. (Numeric equivalence
    of the text round-trip is asserted on the rust side, in
    rust/tests/runtime_integration.rs, against these same artifacts.)"""
    from jax._src.lib import xla_client as xc

    cfg = M.ModelConfig("test", F=32, C=10, B=8)
    spec = M.graph_specs(cfg)["eval"]
    args_spec = [M.f32(shape) for _, shape in spec["inputs"]]
    lowered = jax.jit(spec["fn"]).lower(*args_spec)
    text = aot.to_hlo_text(lowered)

    parsed = xc._xla.hlo_module_from_text(text)
    assert parsed is not None
    # All eval inputs survive as entry parameters in the text.
    assert text.count("parameter(") >= len(spec["inputs"])


def test_manifest_covers_paper_experiments():
    combos = aot.default_combos()
    # All 8 dataset class-counts on vitb32.
    vitb32 = {c for a, c in combos if a == "vitb32"}
    assert vitb32 == set(aot.DATASETS.values())
    # Table 1 archs at C=100.
    t1 = {a for a, c in combos if c == 100}
    assert {"vitb32", "vitl14", "dinov2b", "dinov2s", "convmixer"} <= t1
    # Miniature test combo present.
    assert ("test", 10) in combos
