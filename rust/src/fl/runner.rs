//! The federated experiment driver: owns model/data/session state and runs
//! Alg. 1 **on top of the `coordinator` subsystem** — `RoundEngine` plans
//! each round (sampling, κ, shared-seed mask), a `ClientPool` trains and
//! encodes participants with work stealing, updates travel through a
//! `Transport`, and the server absorbs them as they arrive
//! (`MaskServer::{begin_round, absorb, finish_round}`) or behind the old
//! barrier, depending on `PipelineMode`. The runner itself no longer
//! decodes or aggregates inline.

use super::client::ClientSession;
use super::data::{self, ClientData, FederatedData};
use super::metrics::{ExperimentResult, RoundMetrics};
use super::server::MaskServer;
use super::ExperimentConfig;
use crate::compress::UpdateCodec;
use crate::coordinator::{
    drain_round, send_with_retry, shard_bounds, ChannelTransport, ChaosTransport, ClientPool,
    ControlMsg, DrainConfig, DrainPipeline, DrainReport, FaultCounters, FaultPlan, FleetLink,
    FleetServer, Payload, PoolStats, RoundEngine, RoundPlan, ScratchPool, ShardedAggregator,
    SocketConfig, SocketHub, Transport, TransportKind, TransportSender, TransportStats,
    WireMessage,
};
use crate::model::backend::{Backend, FtState, LpState, ModelParams};
use crate::model::{accuracy, init_params, sample_mask_seeded};
use crate::util::timer::Stopwatch;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Client-side uplink send attempts before escalating to an in-band
/// `Payload::Failed` report. One more than `FaultPlan`'s default
/// `flaky_sends`, so default-flaky chaos recovers under retry while
/// `flaky_sends>=3` exercises the escalation path.
const SEND_ATTEMPTS: u32 = 3;

/// OS connections a loopback socket experiment (`--transport tcp|uds`
/// without `serve`/`client-fleet`) multiplexes its clients over, capped at
/// the round's cohort size. Deliberately small: the design point is
/// M connections ≪ K logical clients, and a low M exercises the
/// session-multiplexing path on every round.
const LOOPBACK_CONNS: usize = 4;

/// Per-round accounting produced by the server-side drain loop.
#[derive(Clone, Debug, Default)]
struct RoundTally {
    bits: f64,
    enc_secs: f64,
    dec_secs: f64,
    /// Decode compute seconds attributed to each decode worker
    /// (`coordinator::DrainReport::dec_by_worker`).
    dec_by_worker: Vec<f64>,
    /// Aggregation shards the round drained through (1 = single lane).
    agg_shards: usize,
    /// Absorb compute seconds attributed to each dimension shard
    /// (`ShardedAggregator::absorb_secs_by_shard`; empty when unsharded).
    absorb_by_shard: Vec<f64>,
    /// Decode/absorb buffer-pool leases this round, drain pool + shard
    /// lane pools combined (`PoolStats`): free-list reuses vs fresh
    /// allocations. Under the round-resident pipeline, `pool_misses`
    /// drops to zero once the pools are warm.
    pool_hits: u64,
    pool_misses: u64,
    loss: f64,
    /// Admission/fault accounting from the drain
    /// (`DrainReport::faults`); all zeros on a clean round.
    faults: FaultCounters,
    /// Quorum verdict and degraded-completion flag from the drain.
    quorum_met: bool,
    degraded: bool,
    /// Uplink transport accounting for the round.
    wire: TransportStats,
}

pub struct Runner<'a> {
    pub cfg: &'a ExperimentConfig,
    pub backend: &'a dyn Backend,
    pub params: ModelParams,
    pub data: FederatedData,
    /// Client sessions; a slot is `None` only while that client is in
    /// flight on the pool (no placeholder sessions, ever).
    pub sessions: Vec<Option<ClientSession>>,
    pub server: MaskServer,
    engine: RoundEngine,
    /// Decode-buffer pool shared across rounds: round t+1's decodes reuse
    /// the buffers round t's aggregation spent, so the steady-state
    /// decode→absorb cycle allocates nothing.
    scratch: ScratchPool,
}

impl<'a> Runner<'a> {
    pub fn new(cfg: &'a ExperimentConfig, backend: &'a dyn Backend) -> Result<Self> {
        let arch = cfg.arch_config();
        let profile = data::profile(&cfg.dataset)
            .ok_or_else(|| anyhow!("unknown dataset {}", cfg.dataset))?;
        let data = data::generate(
            &profile,
            arch,
            cfg.n_clients,
            cfg.samples_per_client,
            cfg.test_samples,
            cfg.dirichlet_alpha,
            cfg.seed,
        );
        let params = init_params(arch, cfg.seed ^ 0x11_22);
        let sessions = (0..cfg.n_clients)
            .map(|id| Some(ClientSession::new(id, arch.d(), cfg.seed)))
            .collect();
        Ok(Self {
            cfg,
            backend,
            params,
            data,
            sessions,
            server: MaskServer::with_theta0(arch.d(), cfg.rho, cfg.theta0),
            engine: RoundEngine::new(
                cfg.seed,
                cfg.n_clients,
                cfg.rho,
                cfg.kappa0,
                cfg.kappa_floor,
                cfg.rounds,
            ),
            scratch: ScratchPool::new(),
        })
    }

    /// §3.3 head initialization: `lp_rounds` federated rounds of linear
    /// probing (or He/FiT alternatives, Table 5). Returns the uplink bits
    /// this cost per client (counted into the stream like any update).
    pub fn init_head(&mut self) -> Result<f64> {
        let arch = self.params.cfg;
        match self.cfg.head_init {
            super::HeadInit::He => Ok(0.0),
            super::HeadInit::Lp => {
                let mut global = LpState::from_params(&self.params);
                let mut bits = 0.0;
                for round in 0..self.cfg.lp_rounds {
                    let mut deltas: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
                    for k in 0..self.cfg.n_clients {
                        // Enough local epochs that the paper's single LP
                        // round actually converges the head (good frozen
                        // features converge a linear probe quickly).
                        let sess = self.sessions[k]
                            .as_mut()
                            .ok_or_else(|| anyhow!("client {k} session in flight"))?;
                        let (new_state, _) = sess.local_probe(
                            self.backend,
                            &self.params,
                            &self.data.clients[k],
                            &global,
                            20,
                            round,
                        )?;
                        let dw: Vec<f32> = new_state
                            .head_w
                            .iter()
                            .zip(&global.head_w)
                            .map(|(a, b)| a - b)
                            .collect();
                        let db: Vec<f32> = new_state
                            .head_b
                            .iter()
                            .zip(&global.head_b)
                            .map(|(a, b)| a - b)
                            .collect();
                        deltas.push((dw, db));
                    }
                    let kf = deltas.len() as f32;
                    for (dw, db) in &deltas {
                        for (g, d) in global.head_w.iter_mut().zip(dw) {
                            *g += d / kf;
                        }
                        for (g, d) in global.head_b.iter_mut().zip(db) {
                            *g += d / kf;
                        }
                    }
                    bits += 32.0 * (arch.c * arch.f + arch.c) as f64;
                }
                self.params.head_w = global.head_w;
                self.params.head_b = global.head_b;
                self.params.head_version += 1;
                Ok(bits)
            }
            super::HeadInit::Fit => {
                // FiT-LDA (Shysheya et al. 2022): Gaussian-LDA head from
                // client class statistics. Clients send per-class feature
                // sums + counts (counted below); the server forms
                // w_c = μ_c/σ², b_c = −‖μ_c‖²/(2σ²) + log π_c.
                let f = arch.f;
                let c = arch.c;
                let ones = vec![1.0f32; arch.d()];
                let mut sums = vec![0.0f64; c * f];
                let mut counts = vec![0.0f64; c];
                let mut sq_sum = 0.0f64;
                let mut n_total = 0.0f64;
                for k in 0..self.cfg.n_clients {
                    let cd = &self.data.clients[k];
                    // Feature = backbone output h_L (mask ≡ 1). Obtained via
                    // eval-forward against a zero head? The eval graph
                    // returns logits, so use the native forward here — the
                    // frozen weights are identical across backends.
                    let feats = native_features(&self.params, cd, &ones)?;
                    for (i, &y) in cd.y.iter().enumerate() {
                        counts[y as usize] += 1.0;
                        n_total += 1.0;
                        for j in 0..f {
                            let v = feats[i * f + j] as f64;
                            sums[y as usize * f + j] += v;
                            sq_sum += v * v;
                        }
                    }
                }
                let mut mean_norm_sq = 0.0f64;
                for cls in 0..c {
                    let n = counts[cls].max(1.0);
                    for j in 0..f {
                        sums[cls * f + j] /= n;
                    }
                }
                // Shared isotropic variance estimate.
                let mut within = sq_sum / (n_total * f as f64).max(1.0);
                for cls in 0..c {
                    let mut ns = 0.0;
                    for j in 0..f {
                        ns += sums[cls * f + j] * sums[cls * f + j];
                    }
                    mean_norm_sq += ns / c as f64;
                }
                within = (within - mean_norm_sq / f as f64).max(1e-3);
                for cls in 0..c {
                    let prior = ((counts[cls].max(0.5)) / n_total.max(1.0)).ln();
                    let mut nsq = 0.0f64;
                    for j in 0..f {
                        let mu = sums[cls * f + j];
                        nsq += mu * mu;
                        self.params.head_w[cls * f + j] = (mu / within) as f32;
                    }
                    self.params.head_b[cls] = (-(nsq) / (2.0 * within) + prior) as f32;
                }
                self.params.head_version += 1;
                // Uplink: per-class sums (C·F floats) + counts (C).
                Ok(32.0 * (c * f + c) as f64)
            }
        }
    }

    /// Run the full federated experiment with the given codec. Each round
    /// is planned by the [`RoundEngine`]; decoding and aggregation flow
    /// through the transport into the streaming server (or the batch
    /// barrier when `cfg.tuning.pipeline` asks for the A/B reference
    /// path).
    ///
    /// With `cfg.tuning.persistent_pipeline` the decode workers, the
    /// dimension-shard absorb lanes and every buffer pool are **round
    /// resident**: spawned once here, parked between rounds, reused for
    /// the whole trajectory (`coordinator::DrainPipeline` + one resident
    /// `MaskServer::shard_view`), and stitched back at the end — bitwise
    /// identical to the per-round-spawn path.
    pub fn run_codec(&mut self, codec: Arc<dyn UpdateCodec>) -> Result<ExperimentResult> {
        let d = self.params.cfg.d();
        let sw = Stopwatch::new();
        let head_bits = self.init_head()?;
        let mut rounds = Vec::with_capacity(self.cfg.rounds);

        let drain_cfg = self.cfg.tuning.to_drain_config();
        // Parsed once; `None` (the default) keeps the clean transport with
        // zero wrapping, so chaos-off runs are byte-for-byte the old path.
        let fault_plan = self.cfg.fault_plan()?;
        // Loopback socket mode (`--transport tcp|uds`): bind one hub for
        // the whole experiment; every round dials a fresh framed link so
        // the channel's close-on-drop round lifecycle is preserved over a
        // real socket and the two trajectories stay bitwise identical.
        let hub = match self.cfg.transport {
            TransportKind::Channel => None,
            kind => Some(SocketHub::bind_loopback(
                kind,
                SocketConfig::from_env(),
                LOOPBACK_CONNS,
            )?),
        };
        let pipeline = self
            .cfg
            .tuning
            .persistent_pipeline
            .then(|| DrainPipeline::new(drain_cfg));
        // The resident dimension-sharded view: lanes, lane pools and
        // pseudo-count slices live here across rounds; θ_g/s_g sync back
        // to `self.server` after every round for planning and evaluation.
        // Lanes run in-process or on remote shard workers per
        // `cfg.tuning.shard_place`.
        let mut resident_view: Option<ShardedAggregator<MaskServer>> = match &pipeline {
            Some(pipe) if pipe.config().shards > 1 => {
                Some(shard_view_for(&self.server, self.cfg, pipe.config().shards)?)
            }
            _ => None,
        };

        for round in 0..self.cfg.rounds {
            let plan = Arc::new(
                self.engine
                    .plan(round, &self.server.theta_g, &self.server.s_g),
            );
            let tally = self.run_round(
                &plan,
                &codec,
                drain_cfg,
                fault_plan,
                hub.as_ref(),
                pipeline.as_ref(),
                &mut resident_view,
            )?;

            // Periodic evaluation of the global model.
            let acc = if (round + 1) % self.cfg.eval_every == 0 || round + 1 == self.cfg.rounds
            {
                Some(self.eval_global(plan.seed)?)
            } else {
                None
            };
            rounds.push(self.metrics_for_round(&plan, tally, acc, d));
        }
        // Retire the resident view: the full stitch (incl. pseudo-counts)
        // brings `self.server` back to the exact unsharded state.
        if let Some(view) = resident_view.take() {
            self.server.adopt_shards(view);
        }
        Ok(self.result_with_head(rounds, head_bits, sw.elapsed_secs()))
    }

    /// One federated round: fan participants out on the work-stealing pool,
    /// drain their encoded updates off the transport on this thread, and
    /// aggregate per the configured pipeline mode — through the resident
    /// `pipeline`/`resident_view` pair when the experiment is persistent,
    /// through per-round spawns otherwise.
    #[allow(clippy::too_many_arguments)]
    fn run_round(
        &mut self,
        plan: &Arc<RoundPlan>,
        codec: &Arc<dyn UpdateCodec>,
        drain_cfg: DrainConfig,
        fault_plan: Option<FaultPlan>,
        hub: Option<&SocketHub>,
        pipeline: Option<&DrainPipeline>,
        resident_view: &mut Option<ShardedAggregator<MaskServer>>,
    ) -> Result<RoundTally> {
        let cfg = self.cfg;
        let backend = self.backend;
        let params = &self.params;
        let data = &self.data;
        let expected = plan.expected();
        let resync = codec.resync_scores();
        let plan_ref: &RoundPlan = plan.as_ref();
        let codec_ref: &dyn UpdateCodec = codec.as_ref();

        // Hand the participating sessions to the pool; their slots stay
        // visibly empty until the round returns them.
        let mut items: Vec<(usize, ClientSession)> = Vec::with_capacity(expected);
        for &id in &plan.participants {
            let sess = self.sessions[id]
                .take()
                .ok_or_else(|| anyhow!("client {id} session already in flight"))?;
            items.push((id, sess));
        }

        // The uplink: an in-process channel, or a fresh loopback socket
        // link dialed through the hub. Both have identical round
        // lifecycles (senders dropping closes the transport) and identical
        // send-time `sent_*` accounting, so the trajectories match.
        let (bare_transport, bare_sender): (Box<dyn Transport>, Box<dyn TransportSender>) =
            match hub {
                Some(hub) => {
                    let (sock, sender) = hub.round_link(expected)?;
                    (Box::new(sock), sender)
                }
                None => {
                    let (channel, sender) = ChannelTransport::new();
                    (Box::new(channel), sender)
                }
            };
        // Chaos injection wraps both ends when a plan is active: the
        // sender so flaky pairs exercise the retry path, the receiver so
        // drop/duplicate/reorder/corrupt/straggle/die fire on delivery.
        // With no plan both ends are exactly the clean transport.
        let sender = match fault_plan {
            Some(p) => p.wrap_sender(bare_sender),
            None => bare_sender,
        };
        let mut transport: Box<dyn Transport> = match fault_plan {
            Some(p) => Box::new(ChaosTransport::new(bare_transport, p)),
            None => bare_transport,
        };
        let job = move |slot: usize, id: usize, sess: &mut ClientSession| -> Result<()> {
            run_client_slot(
                backend,
                params,
                &data.clients[id],
                plan_ref,
                cfg.local_epochs,
                resync,
                codec_ref,
                sender.as_ref(),
                slot,
                id,
                sess,
            )
        };

        let server = &mut self.server;
        let dec_pool = &self.scratch;
        let server_loop = move || -> Result<RoundTally> {
            // All decoding + aggregation happens inside the coordinator's
            // drain loop (`drain_dispatch`); the runner only reduces the
            // report into the round tally.
            let out = drain_dispatch(
                &mut *transport,
                plan,
                codec,
                cfg,
                drain_cfg,
                pipeline,
                resident_view,
                server,
                dec_pool,
            )?;
            let wire = transport.stats();
            Ok(tally_from(out, wire))
        };

        let pool = ClientPool::sized_for(expected);
        let (finished, tally) = pool.run_with_server(items, job, server_loop);

        // Return sessions to their slots. Error priority: when the drain
        // itself failed, a genuine client failure (the root cause behind a
        // server-side shortfall) wins over the drain's own error. When the
        // drain *succeeded* — a relaxed quorum absorbed the loss — client
        // errors are not fatal: they are already accounted in the round's
        // fault counters (`failed`/`missing`), which is the whole point of
        // degraded completion.
        let mut client_err: Option<anyhow::Error> = None;
        for (id, sess, out) in finished {
            if let Some(sess) = sess {
                self.sessions[id] = Some(sess);
            }
            if let Err(e) = out {
                if client_err.is_none() {
                    client_err = Some(e);
                }
            }
        }
        match (tally, client_err) {
            (Err(_), Some(e)) => Err(e),
            (other, _) => other,
        }
    }

    /// Assemble one round's metrics record from the drain tally.
    fn metrics_for_round(
        &self,
        plan: &RoundPlan,
        tally: RoundTally,
        acc: Option<f64>,
        d: usize,
    ) -> RoundMetrics {
        let kf = plan.expected() as f64;
        let dec_worker_ms: Vec<f64> = tally.dec_by_worker.iter().map(|s| s * 1e3).collect();
        let shard_absorb_ms: Vec<f64> = tally.absorb_by_shard.iter().map(|s| s * 1e3).collect();
        RoundMetrics {
            round: plan.round,
            kappa: plan.kappa,
            mean_bits: tally.bits / kf,
            mean_bpp: (tally.bits / kf) / d as f64,
            enc_ms_mean: tally.enc_secs / kf * 1e3,
            dec_ms_mean: tally.dec_secs / kf * 1e3,
            dec_kernel_ms: tally.dec_secs * 1e3,
            decode_workers: dec_worker_ms.len().max(1),
            dec_worker_ms,
            agg_shards: tally.agg_shards.max(1),
            shard_absorb_ms,
            pool_hits: tally.pool_hits,
            pool_misses: tally.pool_misses,
            train_loss: tally.loss / kf,
            accuracy: acc,
            pipeline: self.cfg.tuning.pipeline.as_str(),
            faults: tally.faults,
            quorum_met: tally.quorum_met,
            degraded: tally.degraded,
            wire: tally.wire,
        }
    }

    /// Serve the experiment to a remote client fleet (`deltamask serve`):
    /// the same round loop as [`Runner::run_codec`] — identical planning,
    /// drain dispatch, metrics and final stitch — except each plan is
    /// broadcast over the fleet's control connections and the encoded
    /// updates drain off the fleet's socket transport instead of an
    /// in-process pool. Training happens in the fleet process; this
    /// runner's sessions only mirror head initialization so both sides
    /// start from identical parameters.
    pub fn serve_codec(
        &mut self,
        codec: Arc<dyn UpdateCodec>,
        fleet: &mut FleetServer,
    ) -> Result<ExperimentResult> {
        let d = self.params.cfg.d();
        let sw = Stopwatch::new();
        let head_bits = self.init_head()?;
        let mut rounds = Vec::with_capacity(self.cfg.rounds);

        let drain_cfg = self.cfg.tuning.to_drain_config();
        let fault_plan = self.cfg.fault_plan()?;
        let pipeline = self
            .cfg
            .tuning
            .persistent_pipeline
            .then(|| DrainPipeline::new(drain_cfg));
        let mut resident_view: Option<ShardedAggregator<MaskServer>> = match &pipeline {
            Some(pipe) if pipe.config().shards > 1 => {
                Some(shard_view_for(&self.server, self.cfg, pipe.config().shards)?)
            }
            _ => None,
        };

        // One socket transport for the whole experiment. Chaos wraps it
        // once: verdicts are pure in (seed, round, client), so a resident
        // wrapper delivers the same fault schedule as the loopback path's
        // per-round wrappers.
        let mut transport: Box<dyn Transport> = {
            let sock = fleet.take_transport();
            match fault_plan {
                Some(p) => Box::new(ChaosTransport::new(sock, p)),
                None => Box::new(sock),
            }
        };

        for round in 0..self.cfg.rounds {
            let plan = Arc::new(
                self.engine
                    .plan(round, &self.server.theta_g, &self.server.s_g),
            );
            fleet.broadcast_plan(&plan)?;
            let before = transport.stats();
            let out = drain_dispatch(
                &mut *transport,
                &plan,
                &codec,
                self.cfg,
                drain_cfg,
                pipeline.as_ref(),
                &mut resident_view,
                &mut self.server,
                &self.scratch,
            )?;
            // Quarantine straggler traffic still in flight (uncounted, and
            // clears any chaos hold buffers), then wait for every live
            // connection to pass the round's end-of-round barrier so the
            // next round starts from a quiet wire.
            transport.discard_inflight();
            fleet.end_round(round);
            let wire = transport.stats().delta_since(&before);
            let tally = tally_from(out, wire);
            let acc = if (round + 1) % self.cfg.eval_every == 0 || round + 1 == self.cfg.rounds
            {
                Some(self.eval_global(plan.seed)?)
            } else {
                None
            };
            rounds.push(self.metrics_for_round(&plan, tally, acc, d));
        }
        if let Some(view) = resident_view.take() {
            self.server.adopt_shards(view);
        }
        fleet.shutdown()?;
        Ok(self.result_with_head(rounds, head_bits, sw.elapsed_secs()))
    }

    /// The client-fleet side of a two-process experiment
    /// (`deltamask client-fleet`): follow the coordinator's control
    /// stream, training and uploading every planned round until the
    /// shutdown frame arrives. Head initialization runs locally first —
    /// both processes derive it from the same seed, keeping parameters in
    /// lockstep without ever shipping weights.
    pub fn fleet_loop(
        &mut self,
        codec: Arc<dyn UpdateCodec>,
        link: &mut FleetLink,
    ) -> Result<()> {
        self.init_head()?;
        let fault_plan = self.cfg.fault_plan()?;
        loop {
            match link.recv_control()? {
                ControlMsg::Plan(pw) => {
                    let plan = Arc::new(pw.into_round_plan());
                    let round = plan.round;
                    self.fleet_round(&plan, &codec, fault_plan, link)?;
                    // The barrier mark: tells the coordinator this process
                    // has nothing more in flight for `round`.
                    link.send_eor(round)?;
                }
                ControlMsg::Shutdown => return Ok(()),
            }
        }
    }

    /// One fleet-side round: identical client work to [`Runner::run_round`]
    /// (same pool, same retry/escalation policy, same chaos sender wrap),
    /// with the coordinator's socket as the uplink. Client errors are
    /// reported in-band and logged, never fatal here — the coordinator's
    /// drain policy owns the abort/degrade verdict.
    fn fleet_round(
        &mut self,
        plan: &Arc<RoundPlan>,
        codec: &Arc<dyn UpdateCodec>,
        fault_plan: Option<FaultPlan>,
        link: &FleetLink,
    ) -> Result<()> {
        let cfg = self.cfg;
        let backend = self.backend;
        let params = &self.params;
        let data = &self.data;
        let expected = plan.expected();
        let resync = codec.resync_scores();
        let plan_ref: &RoundPlan = plan.as_ref();
        let codec_ref: &dyn UpdateCodec = codec.as_ref();

        let mut items: Vec<(usize, ClientSession)> = Vec::with_capacity(expected);
        for &id in &plan.participants {
            let sess = self.sessions[id]
                .take()
                .ok_or_else(|| anyhow!("client {id} session already in flight"))?;
            items.push((id, sess));
        }

        let sender = match fault_plan {
            Some(p) => p.wrap_sender(link.sender()),
            None => link.sender(),
        };
        let job = move |slot: usize, id: usize, sess: &mut ClientSession| -> Result<()> {
            run_client_slot(
                backend,
                params,
                &data.clients[id],
                plan_ref,
                cfg.local_epochs,
                resync,
                codec_ref,
                sender.as_ref(),
                slot,
                id,
                sess,
            )
        };
        let pool = ClientPool::sized_for(expected);
        let finished = pool.run(items, job);
        for (id, sess, out) in finished {
            if let Some(sess) = sess {
                self.sessions[id] = Some(sess);
            }
            if let Err(e) = out {
                eprintln!("[fleet] client {id} failed in round {}: {e:#}", plan.round);
            }
        }
        Ok(())
    }

    /// Evaluate the global model with the posterior-mean (expected) mask
    /// θ^{g} — the deterministic Bayesian point estimate (sampled-mask
    /// evaluation is available via [`Runner::eval_sampled`]).
    pub fn eval_global(&self, _round_seed: u64) -> Result<f64> {
        self.eval_mask(&self.server.theta_g.clone())
    }

    /// Stochastic-mask evaluation m ~ Bern(θ^{g}) (FedPM-style).
    pub fn eval_sampled(&self, seed: u64) -> Result<f64> {
        let mut mask = Vec::new();
        sample_mask_seeded(&self.server.theta_g, seed ^ 0xe0a1, &mut mask);
        self.eval_mask(&mask)
    }

    pub fn eval_mask(&self, mask: &[f32]) -> Result<f64> {
        let arch = self.params.cfg;
        let test = &self.data.test;
        let n = test.len();
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut pos = 0usize;
        let mut xbuf = vec![0.0f32; arch.b * arch.f];
        while pos < n {
            let take = (n - pos).min(arch.b);
            for row in 0..arch.b {
                let src = pos + (row % take);
                xbuf[row * arch.f..(row + 1) * arch.f]
                    .copy_from_slice(&test.x[src * arch.f..(src + 1) * arch.f]);
            }
            let logits = self.backend.eval_logits(&self.params, mask, &xbuf)?;
            let labels: Vec<u32> = (0..take).map(|r| test.y[pos + r]).collect();
            let (c, t) = accuracy(&logits, &labels, arch.c, take);
            correct += c;
            total += t;
            pos += take;
        }
        Ok(correct as f64 / total.max(1) as f64)
    }

    fn result(&self, rounds: Vec<RoundMetrics>, wall: f64) -> ExperimentResult {
        self.result_with_head(rounds, 0.0, wall)
    }

    fn result_with_head(
        &self,
        rounds: Vec<RoundMetrics>,
        head_init_bits: f64,
        wall: f64,
    ) -> ExperimentResult {
        ExperimentResult {
            method: self.cfg.method.clone(),
            dataset: self.cfg.dataset.clone(),
            arch: self.cfg.arch.clone(),
            n_clients: self.cfg.n_clients,
            rho: self.cfg.rho,
            dirichlet_alpha: self.cfg.dirichlet_alpha,
            d: self.params.cfg.d(),
            rounds,
            head_init_bits,
            wall_secs: wall,
        }
    }

    // -----------------------------------------------------------------
    // Weight-space baselines (Tables 2/3 "Fine-tuning" / "Linear Probing")
    // -----------------------------------------------------------------

    /// Federated fine-tuning at 32 bpp: clients send raw weight deltas.
    pub fn run_finetuning(&mut self) -> Result<ExperimentResult> {
        let arch = self.params.cfg;
        let d = arch.d();
        let sw = Stopwatch::new();
        let mut global = FtState::from_params(&self.params);
        let mut rounds = Vec::new();
        let head_len = arch.c * arch.f + arch.c;
        for round in 0..self.cfg.rounds {
            let participants = self.engine.sample_participants();
            let mut sum_wb = vec![0.0f32; global.w_blocks.len()];
            let mut sum_hw = vec![0.0f32; global.head_w.len()];
            let mut sum_hb = vec![0.0f32; global.head_b.len()];
            let mut loss = 0.0f64;
            for &id in &participants {
                let sess = self.sessions[id]
                    .as_mut()
                    .ok_or_else(|| anyhow!("client {id} session in flight"))?;
                let (state, l) = sess.local_finetune(
                    self.backend,
                    &self.params,
                    &self.data.clients[id],
                    &global,
                    self.cfg.local_epochs,
                    round,
                )?;
                for i in 0..sum_wb.len() {
                    sum_wb[i] += state.w_blocks[i] - global.w_blocks[i];
                }
                for i in 0..sum_hw.len() {
                    sum_hw[i] += state.head_w[i] - global.head_w[i];
                }
                for i in 0..sum_hb.len() {
                    sum_hb[i] += state.head_b[i] - global.head_b[i];
                }
                loss += l as f64;
            }
            let kf = participants.len() as f32;
            for i in 0..sum_wb.len() {
                global.w_blocks[i] += sum_wb[i] / kf;
            }
            for i in 0..sum_hw.len() {
                global.head_w[i] += sum_hw[i] / kf;
            }
            for i in 0..sum_hb.len() {
                global.head_b[i] += sum_hb[i] / kf;
            }
            let acc = if (round + 1) % self.cfg.eval_every == 0 || round + 1 == self.cfg.rounds
            {
                Some(self.eval_ft(&global)?)
            } else {
                None
            };
            let bits = 32.0 * (d + head_len) as f64;
            rounds.push(RoundMetrics {
                round,
                kappa: 0.0,
                mean_bits: bits,
                mean_bpp: bits / d as f64,
                enc_ms_mean: 0.0,
                dec_ms_mean: 0.0,
                dec_kernel_ms: 0.0,
                decode_workers: 1,
                dec_worker_ms: Vec::new(),
                agg_shards: 1,
                shard_absorb_ms: Vec::new(),
                pool_hits: 0,
                pool_misses: 0,
                train_loss: loss / participants.len() as f64,
                accuracy: acc,
                pipeline: self.cfg.tuning.pipeline.as_str(),
                faults: FaultCounters::default(),
                quorum_met: true,
                degraded: false,
                wire: TransportStats::default(),
            });
        }
        Ok(self.result(rounds, sw.elapsed_secs()))
    }

    fn eval_ft(&self, global: &FtState) -> Result<f64> {
        let arch = self.params.cfg;
        let test = &self.data.test;
        let n = test.len();
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut pos = 0usize;
        let mut xbuf = vec![0.0f32; arch.b * arch.f];
        while pos < n {
            let take = (n - pos).min(arch.b);
            for row in 0..arch.b {
                let src = pos + (row % take);
                xbuf[row * arch.f..(row + 1) * arch.f]
                    .copy_from_slice(&test.x[src * arch.f..(src + 1) * arch.f]);
            }
            let logits = self.backend.ft_eval_logits(&self.params, global, &xbuf)?;
            let labels: Vec<u32> = (0..take).map(|r| test.y[pos + r]).collect();
            let (c, t) = accuracy(&logits, &labels, arch.c, take);
            correct += c;
            total += t;
            pos += take;
        }
        Ok(correct as f64 / total.max(1) as f64)
    }

    /// Federated linear probing: head-only training, mask ≡ 1.
    pub fn run_linear_probing(&mut self) -> Result<ExperimentResult> {
        let arch = self.params.cfg;
        let d = arch.d();
        let sw = Stopwatch::new();
        let mut global = LpState::from_params(&self.params);
        let head_len = arch.c * arch.f + arch.c;
        let mut rounds = Vec::new();
        for round in 0..self.cfg.rounds {
            let participants = self.engine.sample_participants();
            let mut sum_hw = vec![0.0f32; global.head_w.len()];
            let mut sum_hb = vec![0.0f32; global.head_b.len()];
            let mut loss = 0.0f64;
            for &id in &participants {
                let sess = self.sessions[id]
                    .as_mut()
                    .ok_or_else(|| anyhow!("client {id} session in flight"))?;
                let (state, l) = sess.local_probe(
                    self.backend,
                    &self.params,
                    &self.data.clients[id],
                    &global,
                    self.cfg.local_epochs,
                    round,
                )?;
                for i in 0..sum_hw.len() {
                    sum_hw[i] += state.head_w[i] - global.head_w[i];
                }
                for i in 0..sum_hb.len() {
                    sum_hb[i] += state.head_b[i] - global.head_b[i];
                }
                loss += l as f64;
            }
            let kf = participants.len() as f32;
            for i in 0..sum_hw.len() {
                global.head_w[i] += sum_hw[i] / kf;
            }
            for i in 0..sum_hb.len() {
                global.head_b[i] += sum_hb[i] / kf;
            }
            let acc = if (round + 1) % self.cfg.eval_every == 0 || round + 1 == self.cfg.rounds
            {
                let mut p = self.params.clone();
                p.head_w = global.head_w.clone();
                p.head_b = global.head_b.clone();
                p.head_version += round as u64 + 1;
                let ones = vec![1.0f32; d];
                Some(eval_with_params(self.backend, &p, &self.data, &ones)?)
            } else {
                None
            };
            let bits = 32.0 * head_len as f64;
            rounds.push(RoundMetrics {
                round,
                kappa: 0.0,
                mean_bits: bits,
                mean_bpp: bits / d as f64,
                enc_ms_mean: 0.0,
                dec_ms_mean: 0.0,
                dec_kernel_ms: 0.0,
                decode_workers: 1,
                dec_worker_ms: Vec::new(),
                agg_shards: 1,
                shard_absorb_ms: Vec::new(),
                pool_hits: 0,
                pool_misses: 0,
                train_loss: loss / participants.len() as f64,
                accuracy: acc,
                pipeline: self.cfg.tuning.pipeline.as_str(),
                faults: FaultCounters::default(),
                quorum_met: true,
                degraded: false,
                wire: TransportStats::default(),
            });
        }
        Ok(self.result(rounds, sw.elapsed_secs()))
    }
}

/// Per-round accounting produced by the server-side drain dispatch,
/// before the transport's wire stats are folded in.
struct DrainOutcome {
    report: DrainReport,
    agg_shards: usize,
    absorb_by_shard: Vec<f64>,
    lane_pool: PoolStats,
}

/// Build the dimension-sharded server view per the configured shard
/// placement: the default all-local placement keeps the zero-handshake
/// thread-lane path; otherwise each shard's lane runs on the
/// `cfg.tuning.shard_place` site it was pinned to, remote ones shipping
/// their slices to `deltamask shard-worker` processes over the DMW1 wire.
/// The spec is resolved to the view's actual lane count first (missing
/// sites pad with `local`, extras are dropped), so one ambient
/// `DELTAMASK_SHARD_PLACE` composes with every `--agg-shards` setting.
fn shard_view_for(
    server: &MaskServer,
    cfg: &ExperimentConfig,
    shards: usize,
) -> Result<ShardedAggregator<MaskServer>> {
    let lanes = shard_bounds(server.theta_g.len(), shards).len();
    let placement = cfg.tuning.shard_placement()?.resolved(lanes);
    if placement.is_all_local() {
        Ok(server.shard_view(shards))
    } else {
        server.shard_view_placed(shards, &placement, cfg.fingerprint(), SocketConfig::from_env())
    }
}

/// The four-way drain dispatch shared by the in-process round loop and the
/// two-process serve loop. With `agg_shards > 1` the round drains into a
/// dimension-sharded view of the server — the resident one (synced back,
/// kept) under the persistent pipeline, a per-round one (stitched back,
/// dropped) otherwise; a failed drain leaves the view's absorb lanes
/// joined/parked without touching the server.
#[allow(clippy::too_many_arguments)]
fn drain_dispatch(
    transport: &mut dyn Transport,
    plan: &Arc<RoundPlan>,
    codec: &Arc<dyn UpdateCodec>,
    cfg: &ExperimentConfig,
    drain_cfg: DrainConfig,
    pipeline: Option<&DrainPipeline>,
    resident_view: &mut Option<ShardedAggregator<MaskServer>>,
    server: &mut MaskServer,
    dec_pool: &ScratchPool,
) -> Result<DrainOutcome> {
    let codec_ref: &dyn UpdateCodec = codec.as_ref();
    let (report, agg_shards, absorb_by_shard, lane_pool) =
        match (pipeline, resident_view.as_mut()) {
            (Some(pipe), Some(view)) => {
                let lanes_before = view.lane_pool_stats();
                let report = pipe.drain_round(&mut *transport, plan, codec, view)?;
                let lane_pool = view.lane_pool_stats().delta_since(lanes_before);
                server.sync_from_shards(view);
                (
                    report,
                    view.shard_count(),
                    view.absorb_secs_by_shard(),
                    lane_pool,
                )
            }
            (Some(pipe), None) => {
                let report = pipe.drain_round(&mut *transport, plan, codec, server)?;
                (report, 1, Vec::new(), PoolStats::default())
            }
            (None, _) if drain_cfg.resolved_shards() > 1 => {
                let mut view = shard_view_for(server, cfg, drain_cfg.resolved_shards())?;
                let report = drain_round(
                    &mut *transport,
                    plan,
                    codec_ref,
                    &mut view,
                    drain_cfg,
                    dec_pool,
                )?;
                let shards = view.shard_count();
                let absorb = view.absorb_secs_by_shard();
                let lane_pool = view.lane_pool_stats();
                server.adopt_shards(view);
                (report, shards, absorb, lane_pool)
            }
            (None, _) => {
                let report = drain_round(
                    &mut *transport,
                    plan,
                    codec_ref,
                    server,
                    drain_cfg,
                    dec_pool,
                )?;
                (report, 1, Vec::new(), PoolStats::default())
            }
        };
    Ok(DrainOutcome {
        report,
        agg_shards,
        absorb_by_shard,
        lane_pool,
    })
}

/// Reduce a drain outcome plus the round's wire accounting into the tally
/// the metrics layer consumes.
fn tally_from(out: DrainOutcome, wire: TransportStats) -> RoundTally {
    let report = out.report;
    // Reduce the report before moving its per-worker vector out (a struct
    // expression evaluates fields in order, so borrowing `report` after
    // the move would not compile).
    let pool = report.pool.merged(out.lane_pool);
    let enc_secs = report.total_enc_secs();
    let loss = report.total_loss();
    RoundTally {
        // Exact byte accounting from the transport (integer-valued, so
        // order-independent).
        bits: wire.sent_payload_bytes as f64 * 8.0,
        enc_secs,
        dec_secs: report.dec_secs,
        dec_by_worker: report.dec_by_worker,
        agg_shards: out.agg_shards,
        absorb_by_shard: out.absorb_by_shard,
        pool_hits: pool.hits,
        pool_misses: pool.misses,
        loss,
        faults: report.faults,
        quorum_met: report.quorum_met,
        degraded: report.degraded,
        wire,
    }
}

/// The client half of one round slot, shared by the in-process pool job
/// and the fleet process: train + encode (`client_round`), send with
/// bounded retry, escalate exhaustion as an in-band `Payload::Failed`
/// report.
#[allow(clippy::too_many_arguments)]
fn run_client_slot(
    backend: &dyn Backend,
    params: &ModelParams,
    shard: &ClientData,
    plan: &RoundPlan,
    local_epochs: usize,
    resync: bool,
    codec: &dyn UpdateCodec,
    sender: &dyn TransportSender,
    slot: usize,
    id: usize,
    sess: &mut ClientSession,
) -> Result<()> {
    match client_round(
        backend,
        params,
        shard,
        plan,
        local_epochs,
        resync,
        codec,
        slot,
        sess,
    ) {
        Ok(msg) => {
            // Bounded retry rides out transient send failures; on
            // exhaustion escalate with an in-band failure report so the
            // server hears about the loss instead of waiting on the slot.
            // If even that send fails, the server already ended the round
            // (receiver dropped) and its error is the root cause — no
            // client error is manufactured.
            if let Err(e) = send_with_retry(
                sender,
                msg,
                SEND_ATTEMPTS,
                std::time::Duration::from_millis(1),
            ) {
                let _ = sender.send(WireMessage {
                    round: plan.round,
                    client_id: id,
                    slot,
                    enc_secs: 0.0,
                    loss: 0.0,
                    payload: Payload::Failed(format!("client {id}: {e}")),
                });
            }
            Ok(())
        }
        Err(e) => {
            // Report in-band so the server never waits on us, then
            // surface the error through the pool result.
            let _ = sender.send(WireMessage {
                round: plan.round,
                client_id: id,
                slot,
                enc_secs: 0.0,
                loss: 0.0,
                payload: Payload::Failed(e.to_string()),
            });
            Err(e)
        }
    }
}

/// One client's work for one round, executed on a pool worker: local
/// stochastic-mask training against the broadcast plan, then update
/// encoding. Returns the wire message the transport will carry.
#[allow(clippy::too_many_arguments)]
fn client_round(
    backend: &dyn Backend,
    params: &ModelParams,
    shard: &ClientData,
    plan: &RoundPlan,
    local_epochs: usize,
    resync: bool,
    codec: &dyn UpdateCodec,
    slot: usize,
    sess: &mut ClientSession,
) -> Result<WireMessage> {
    let (theta_k, loss) = sess.local_train_opts(
        backend,
        params,
        shard,
        &plan.theta_g,
        local_epochs,
        plan.round,
        resync,
    )?;
    // Common-random-numbers sampling: m^{k,t} uses the SAME public
    // per-round uniforms as m^{g,t-1}, so Δ only contains coordinates whose
    // probability moved across u_i — the "inherent sparsity in consecutive
    // mask updates" (§3.2) that DeltaMask exploits.
    let mut mask_k = Vec::new();
    sample_mask_seeded(&theta_k, plan.seed, &mut mask_k);
    let ectx = plan.encode_ctx(slot, &theta_k, &mask_k, &sess.mask_state.s);
    let t = Stopwatch::new();
    // Selection buffers persist in the session, so steady-state encodes
    // allocate nothing for the Δ′ scan (bytes identical to plain encode).
    let enc = codec.encode_with(&ectx, &mut sess.enc_scratch)?;
    Ok(WireMessage {
        round: plan.round,
        client_id: plan.participants[slot],
        slot,
        enc_secs: t.elapsed_secs(),
        loss,
        payload: Payload::Update(enc),
    })
}

/// Evaluate arbitrary params (used by the LP baseline with a swapped head).
fn eval_with_params(
    backend: &dyn Backend,
    params: &ModelParams,
    data: &FederatedData,
    mask: &[f32],
) -> Result<f64> {
    let arch = params.cfg;
    let test = &data.test;
    let n = test.len();
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut pos = 0usize;
    let mut xbuf = vec![0.0f32; arch.b * arch.f];
    while pos < n {
        let take = (n - pos).min(arch.b);
        for row in 0..arch.b {
            let src = pos + (row % take);
            xbuf[row * arch.f..(row + 1) * arch.f]
                .copy_from_slice(&test.x[src * arch.f..(src + 1) * arch.f]);
        }
        let logits = backend.eval_logits(params, mask, &xbuf)?;
        let labels: Vec<u32> = (0..take).map(|r| test.y[pos + r]).collect();
        let (c, t) = accuracy(&logits, &labels, arch.c, take);
        correct += c;
        total += t;
        pos += take;
    }
    Ok(correct as f64 / total.max(1) as f64)
}

/// Native forward to the last hidden layer (FiT-LDA statistics).
fn native_features(
    params: &ModelParams,
    data: &super::data::ClientData,
    mask: &[f32],
) -> Result<Vec<f32>> {
    use crate::native::linalg::matmul_bt;
    let cfg = params.cfg;
    let f = cfg.f;
    let n = data.len();
    let mut h = data.x.clone();
    let mut mw = vec![0.0f32; f * f];
    let mut z = vec![0.0f32; n * f];
    for l in 0..cfg.l {
        let w = &params.w_blocks[l * f * f..(l + 1) * f * f];
        let m = &mask[l * f * f..(l + 1) * f * f];
        for i in 0..f * f {
            mw[i] = w[i] * m[i];
        }
        matmul_bt(&h, &mw, &mut z, n, f, f);
        for i in 0..n * f {
            h[i] += z[i].max(0.0);
        }
    }
    Ok(h)
}
