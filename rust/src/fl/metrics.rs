//! Per-round and per-experiment metrics; JSON emission for the benches.

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct RoundMetrics {
    pub round: usize,
    pub kappa: f64,
    /// Mean uplink bits per participating client.
    pub mean_bits: f64,
    /// Mean bits-per-parameter for this round.
    pub mean_bpp: f64,
    pub enc_ms_mean: f64,
    pub dec_ms_mean: f64,
    /// Total server-side decode compute for the round in ms — the Eq. 5
    /// reconstruction kernel cost the server actually paid, as opposed to
    /// `dec_ms_mean`'s per-client mean. Lets `--pipeline batch|streaming`
    /// A/Bs compare *compute* alongside the byte/latency accounting. With
    /// `decode_workers > 1` this is summed across workers (wall time is
    /// lower — that gap is the sharding speedup).
    pub dec_kernel_ms: f64,
    /// Server decode worker threads that drained this round (1 = serial).
    pub decode_workers: usize,
    /// Decode compute ms attributed to each worker, indexed by worker id
    /// (length = `decode_workers` for codec rounds; empty for the
    /// weight-space baselines, which have no server decode stage). A
    /// lopsided split flags shard imbalance.
    pub dec_worker_ms: Vec<f64>,
    /// Dimension shards the aggregation drained through (1 = single
    /// absorb lane, the reference path).
    pub agg_shards: usize,
    /// Absorb compute ms attributed to each dimension shard, indexed by
    /// shard (length = `agg_shards` when sharding is on; empty for the
    /// single-lane path and the weight-space baselines). Near-equal
    /// entries mean the contiguous `d`-split is balanced; a hot shard
    /// flags a dense coordinate range worth re-splitting.
    pub shard_absorb_ms: Vec<f64>,
    /// Decode/absorb buffer-pool leases served from the free lists this
    /// round (drain pool + shard-lane pools combined).
    pub pool_hits: u64,
    /// Buffer-pool leases that had to allocate this round. Under the
    /// round-resident pipeline (`--persistent-pipeline`) this drops to
    /// zero once the pools are warm — the cross-round zero-allocation
    /// property, reported instead of merely asserted. The per-round-spawn
    /// path re-allocates its shard-lane pools every round, so a nonzero
    /// steady state here is the cost that knob removes.
    pub pool_misses: u64,
    pub train_loss: f64,
    pub accuracy: Option<f64>,
    /// Which server pipeline produced this round: `"streaming"`
    /// (per-arrival decode→absorb) or `"batch"` (full-round barrier).
    pub pipeline: &'static str,
    /// Admission/fault accounting from the round's drain — received /
    /// accepted records plus every rejection class (duplicates, stale
    /// replays, bad slots, in-band failures, corrupt skips, late
    /// arrivals, missing slots). All zeros on a clean codec round and for
    /// the weight-space baselines (which don't drain a transport).
    pub faults: crate::coordinator::FaultCounters,
    /// Whether absorbed records met the round-completion quorum. Always
    /// `true` on an emitted round (a missed quorum aborts the run);
    /// carried so churn logs state it explicitly.
    pub quorum_met: bool,
    /// `true` when the round finished over fewer than the planned K
    /// records — degraded completion under `--quorum < 1.0`.
    pub degraded: bool,
    /// Uplink transport accounting for the round: messages/payload bytes
    /// handed to senders, messages drained server-side, total
    /// send→receive queue latency, and — on the socket transports —
    /// frames, framed bytes and backpressure stalls read off the wire
    /// (zeros on the in-process channel). Zeros for the weight-space
    /// baselines.
    pub wire: crate::coordinator::TransportStats,
}

#[derive(Clone, Debug)]
pub struct ExperimentResult {
    pub method: String,
    pub dataset: String,
    pub arch: String,
    pub n_clients: usize,
    pub rho: f64,
    pub dirichlet_alpha: f64,
    pub d: usize,
    pub rounds: Vec<RoundMetrics>,
    /// One-time §3.3 head-initialization uplink (bits/client), reported
    /// separately from the per-round update bpp exactly like the paper
    /// (its FedMask row is exactly 1.0 bpp).
    pub head_init_bits: f64,
    pub wall_secs: f64,
}

impl ExperimentResult {
    pub fn final_accuracy(&self) -> f64 {
        self.rounds
            .iter()
            .rev()
            .find_map(|r| r.accuracy)
            .unwrap_or(0.0)
    }

    pub fn peak_accuracy(&self) -> f64 {
        self.rounds
            .iter()
            .filter_map(|r| r.accuracy)
            .fold(0.0, f64::max)
    }

    /// Average uplink bpp over all rounds (the paper's "Avg. bpp" column).
    pub fn avg_bpp(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.rounds.iter().map(|r| r.mean_bpp).sum::<f64>() / self.rounds.len() as f64
    }

    /// Total uplink volume per client over the run, in MiB (head init
    /// included).
    pub fn total_uplink_mib(&self) -> f64 {
        (self.head_init_bits + self.rounds.iter().map(|r| r.mean_bits).sum::<f64>())
            / 8.0
            / (1024.0 * 1024.0)
    }

    /// Cumulative uplink MiB at the first eval where accuracy comes within
    /// `margin` (e.g. 0.01) of the run's peak — Fig. 7's data-volume metric.
    pub fn volume_to_within(&self, margin: f64) -> Option<f64> {
        let peak = self.peak_accuracy();
        if peak <= 0.0 {
            return None;
        }
        let mut cum_bits = self.head_init_bits;
        for r in &self.rounds {
            cum_bits += r.mean_bits;
            if let Some(acc) = r.accuracy {
                if acc >= peak - margin {
                    return Some(cum_bits / 8.0 / (1024.0 * 1024.0));
                }
            }
        }
        None
    }

    pub fn mean_enc_ms(&self) -> f64 {
        crate::util::stats::mean(&self.rounds.iter().map(|r| r.enc_ms_mean).collect::<Vec<_>>())
    }

    pub fn mean_dec_ms(&self) -> f64 {
        crate::util::stats::mean(&self.rounds.iter().map(|r| r.dec_ms_mean).collect::<Vec<_>>())
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("method", Json::from_str_(&self.method))
            .set("dataset", Json::from_str_(&self.dataset))
            .set("arch", Json::from_str_(&self.arch))
            .set("n_clients", Json::Num(self.n_clients as f64))
            .set("rho", Json::Num(self.rho))
            .set("dirichlet_alpha", Json::Num(self.dirichlet_alpha))
            .set("d", Json::Num(self.d as f64))
            .set("final_accuracy", Json::Num(self.final_accuracy()))
            .set("peak_accuracy", Json::Num(self.peak_accuracy()))
            .set("avg_bpp", Json::Num(self.avg_bpp()))
            .set("total_uplink_mib", Json::Num(self.total_uplink_mib()))
            .set("mean_enc_ms", Json::Num(self.mean_enc_ms()))
            .set("mean_dec_ms", Json::Num(self.mean_dec_ms()))
            .set("head_init_bits", Json::Num(self.head_init_bits))
            .set("wall_secs", Json::Num(self.wall_secs));
        let rounds: Vec<Json> = self
            .rounds
            .iter()
            .map(|r| {
                let mut o = Json::obj();
                o.set("round", Json::Num(r.round as f64))
                    .set("kappa", Json::Num(r.kappa))
                    .set("pipeline", Json::from_str_(r.pipeline))
                    .set("dec_kernel_ms", Json::Num(r.dec_kernel_ms))
                    .set("decode_workers", Json::Num(r.decode_workers as f64))
                    .set(
                        "dec_worker_ms",
                        Json::Arr(r.dec_worker_ms.iter().map(|&v| Json::Num(v)).collect()),
                    )
                    .set("agg_shards", Json::Num(r.agg_shards as f64))
                    .set(
                        "shard_absorb_ms",
                        Json::Arr(r.shard_absorb_ms.iter().map(|&v| Json::Num(v)).collect()),
                    )
                    .set("pool_hits", Json::Num(r.pool_hits as f64))
                    .set("pool_misses", Json::Num(r.pool_misses as f64))
                    .set("quorum_met", Json::Bool(r.quorum_met))
                    .set("degraded", Json::Bool(r.degraded))
                    .set("faults", {
                        let f = &r.faults;
                        let mut o = Json::obj();
                        o.set("received", Json::Num(f.received as f64))
                            .set("accepted", Json::Num(f.accepted as f64))
                            .set("duplicates", Json::Num(f.duplicates as f64))
                            .set("stale", Json::Num(f.stale as f64))
                            .set("bad_slot", Json::Num(f.bad_slot as f64))
                            .set("failed", Json::Num(f.failed as f64))
                            .set("corrupt", Json::Num(f.corrupt as f64))
                            .set("late", Json::Num(f.late as f64))
                            .set("missing", Json::Num(f.missing as f64));
                        o
                    })
                    .set("wire", {
                        let w = &r.wire;
                        let mut o = Json::obj();
                        o.set("sent_messages", Json::Num(w.sent_messages as f64))
                            .set(
                                "sent_payload_bytes",
                                Json::Num(w.sent_payload_bytes as f64),
                            )
                            .set(
                                "received_messages",
                                Json::Num(w.received_messages as f64),
                            )
                            .set("transit_secs", Json::Num(w.transit_secs))
                            .set("wire_frames", Json::Num(w.wire_frames as f64))
                            .set("wire_bytes", Json::Num(w.wire_bytes as f64))
                            .set(
                                "backpressure_stalls",
                                Json::Num(w.backpressure_stalls as f64),
                            );
                        o
                    })
                    .set("bpp", Json::Num(r.mean_bpp))
                    .set("loss", Json::Num(r.train_loss))
                    .set(
                        "acc",
                        r.accuracy.map(Json::Num).unwrap_or(Json::Null),
                    );
                o
            })
            .collect();
        j.set("rounds", Json::Arr(rounds));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(rounds: Vec<RoundMetrics>) -> ExperimentResult {
        ExperimentResult {
            method: "deltamask".into(),
            dataset: "cifar10".into(),
            arch: "vitb32".into(),
            n_clients: 10,
            rho: 1.0,
            dirichlet_alpha: 10.0,
            d: 1000,
            rounds,
            head_init_bits: 0.0,
            wall_secs: 1.0,
        }
    }

    fn round(n: usize, bpp: f64, acc: Option<f64>) -> RoundMetrics {
        RoundMetrics {
            round: n,
            kappa: 0.8,
            mean_bits: bpp * 1000.0,
            mean_bpp: bpp,
            enc_ms_mean: 1.0,
            dec_ms_mean: 2.0,
            dec_kernel_ms: 4.0,
            decode_workers: 2,
            dec_worker_ms: vec![2.5, 1.5],
            agg_shards: 4,
            shard_absorb_ms: vec![1.0, 1.25, 0.75, 1.0],
            pool_hits: 11,
            pool_misses: 3,
            train_loss: 0.5,
            accuracy: acc,
            pipeline: "streaming",
            faults: crate::coordinator::FaultCounters {
                received: 12,
                accepted: 10,
                duplicates: 1,
                stale: 1,
                bad_slot: 0,
                failed: 0,
                corrupt: 0,
                late: 0,
                missing: 2,
            },
            quorum_met: true,
            degraded: true,
            wire: crate::coordinator::TransportStats {
                sent_messages: 12,
                sent_payload_bytes: 4096,
                received_messages: 12,
                transit_secs: 0.25,
                wire_frames: 14,
                wire_bytes: 4300,
                backpressure_stalls: 2,
            },
        }
    }

    #[test]
    fn summary_stats() {
        let r = mk(vec![
            round(0, 0.2, Some(0.5)),
            round(1, 0.1, None),
            round(2, 0.1, Some(0.8)),
            round(3, 0.1, Some(0.79)),
        ]);
        assert!((r.avg_bpp() - 0.125).abs() < 1e-9);
        assert_eq!(r.peak_accuracy(), 0.8);
        assert_eq!(r.final_accuracy(), 0.79);
        // within 1% of peak (0.8): first hit at round 2.
        let v = r.volume_to_within(0.01).unwrap();
        let expect = (0.2 + 0.1 + 0.1) * 1000.0 / 8.0 / (1024.0 * 1024.0);
        assert!((v - expect).abs() < 1e-12);
    }

    #[test]
    fn json_emission_parses_back() {
        let r = mk(vec![round(0, 0.2, Some(0.5))]);
        let j = r.to_json().to_string_pretty();
        let back = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(back.get("method").unwrap().as_str().unwrap(), "deltamask");
        let rounds = back.get("rounds").unwrap().as_arr().unwrap();
        assert_eq!(rounds.len(), 1);
        assert_eq!(rounds[0].get("decode_workers").unwrap().as_usize().unwrap(), 2);
        let per_worker = rounds[0].get("dec_worker_ms").unwrap().as_arr().unwrap();
        assert_eq!(per_worker.len(), 2);
        assert_eq!(per_worker[0].as_f64().unwrap(), 2.5);
        assert_eq!(rounds[0].get("agg_shards").unwrap().as_usize().unwrap(), 4);
        let per_shard = rounds[0].get("shard_absorb_ms").unwrap().as_arr().unwrap();
        assert_eq!(per_shard.len(), 4);
        assert_eq!(per_shard[1].as_f64().unwrap(), 1.25);
        assert_eq!(rounds[0].get("pool_hits").unwrap().as_usize().unwrap(), 11);
        assert_eq!(rounds[0].get("pool_misses").unwrap().as_usize().unwrap(), 3);
        assert_eq!(rounds[0].get("quorum_met").unwrap().as_bool().unwrap(), true);
        assert_eq!(rounds[0].get("degraded").unwrap().as_bool().unwrap(), true);
        let faults = rounds[0].get("faults").unwrap();
        assert_eq!(faults.get("received").unwrap().as_usize().unwrap(), 12);
        assert_eq!(faults.get("accepted").unwrap().as_usize().unwrap(), 10);
        assert_eq!(faults.get("duplicates").unwrap().as_usize().unwrap(), 1);
        assert_eq!(faults.get("missing").unwrap().as_usize().unwrap(), 2);
        let wire = rounds[0].get("wire").unwrap();
        assert_eq!(wire.get("sent_messages").unwrap().as_usize().unwrap(), 12);
        assert_eq!(
            wire.get("sent_payload_bytes").unwrap().as_usize().unwrap(),
            4096
        );
        assert_eq!(wire.get("transit_secs").unwrap().as_f64().unwrap(), 0.25);
        assert_eq!(wire.get("wire_frames").unwrap().as_usize().unwrap(), 14);
        assert_eq!(wire.get("wire_bytes").unwrap().as_usize().unwrap(), 4300);
        assert_eq!(
            wire.get("backpressure_stalls").unwrap().as_usize().unwrap(),
            2
        );
    }
}
