//! Uplink transport abstraction: clients hand `Encoded` payloads to a
//! [`TransportSender`]; the server drains a [`Transport`] in arrival order.
//!
//! Every message carries its own byte and timing accounting so the round
//! loop measures honest wire costs without threading bookkeeping through
//! client code. The in-process [`ChannelTransport`] backs simulations; a
//! networked implementation only has to provide the same two traits.
//!
//! For fault-tolerance work the module also ships a deterministic fault
//! injector: [`ChaosTransport`] wraps any [`Transport`] and perturbs the
//! delivery stream (drop, duplicate, reorder, straggle, bit-flip
//! corruption, mid-round client death) according to a seeded
//! [`FaultPlan`]. Every decision is a pure hash of
//! `(seed, round, client, fault kind)` — no RNG state, no wall clock — so
//! a chaos run is bit-reproducible in CI regardless of thread schedule or
//! arrival order. [`send_with_retry`] gives the client send path bounded
//! retry-with-backoff against transient failures (injectable via
//! [`FaultPlan::flaky`] + [`FaultPlan::wrap_sender`]).
//!
//! The networked implementation lives in [`socket`]: a length-prefixed
//! framed transport over TCP / Unix-domain sockets with bounded inbound
//! admission (real backpressure) and session-multiplexed connections.
//! Because [`ChaosTransport`] wraps any [`Transport`], the whole fault
//! model composes onto the socket unchanged.

pub mod socket;

use crate::compress::Encoded;
use anyhow::{anyhow, bail, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// What a client produced for the round: an encoded update, or a terminal
/// failure (reported in-band so the server never waits on a dead client).
#[derive(Clone, Debug)]
pub enum Payload {
    Update(Encoded),
    Failed(String),
}

/// One uplink message.
#[derive(Clone, Debug)]
pub struct WireMessage {
    pub round: usize,
    pub client_id: usize,
    /// Participant index within the round (position in
    /// `RoundPlan::participants`) — the server's aggregation slot.
    pub slot: usize,
    pub payload: Payload,
    /// Client-side encode wall time.
    pub enc_secs: f64,
    /// Mean local training loss this round.
    pub loss: f32,
}

impl WireMessage {
    pub fn payload_bytes(&self) -> usize {
        match &self.payload {
            Payload::Update(enc) => enc.bytes.len(),
            Payload::Failed(_) => 0,
        }
    }
}

/// Aggregate transport accounting.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TransportStats {
    /// Messages handed to the sender side (for socket transports: data
    /// messages that arrived intact at the coordinator's reader).
    pub sent_messages: u64,
    /// Sum of payload bytes handed to the sender side.
    pub sent_payload_bytes: u64,
    /// Messages the server end has drained.
    pub received_messages: u64,
    /// Total send→receive queue latency over drained messages.
    pub transit_secs: f64,
    /// Frames read off socket connections, control frames included.
    /// Zero for the in-process channel (it has no frames).
    pub wire_frames: u64,
    /// Framed bytes (headers + payloads) read off socket connections.
    pub wire_bytes: u64,
    /// Times a connection reader blocked on admission because the global
    /// inbound budget or its per-connection budget was full — each stall
    /// propagates flow control to the sender through the kernel socket
    /// buffer instead of buffering unboundedly.
    pub backpressure_stalls: u64,
}

impl TransportStats {
    /// Counter difference `self − before`, for per-round accounting over a
    /// transport that persists across rounds (the per-round channel
    /// transport starts from zero; a long-lived socket does not).
    pub fn delta_since(&self, before: &TransportStats) -> TransportStats {
        TransportStats {
            sent_messages: self.sent_messages - before.sent_messages,
            sent_payload_bytes: self.sent_payload_bytes - before.sent_payload_bytes,
            received_messages: self.received_messages - before.received_messages,
            transit_secs: self.transit_secs - before.transit_secs,
            wire_frames: self.wire_frames - before.wire_frames,
            wire_bytes: self.wire_bytes - before.wire_bytes,
            backpressure_stalls: self.backpressure_stalls - before.backpressure_stalls,
        }
    }
}

/// Client-side handle. Cheap to clone; every worker thread owns one.
pub trait TransportSender: Send {
    fn send(&self, msg: WireMessage) -> Result<()>;
    fn clone_sender(&self) -> Box<dyn TransportSender>;
}

impl Clone for Box<dyn TransportSender> {
    fn clone(&self) -> Self {
        self.clone_sender()
    }
}

/// Outcome of a deadline-bounded receive.
#[derive(Debug)]
pub enum RecvOutcome {
    /// A message arrived before the deadline.
    Msg(WireMessage),
    /// The deadline passed with messages potentially still in flight.
    TimedOut,
    /// Every sender handle dropped and the queue is drained — nothing can
    /// arrive anymore.
    Closed,
}

/// Server-side end of an uplink.
pub trait Transport {
    /// Next message in arrival order; `None` once every sender handle has
    /// been dropped and the queue is drained.
    fn recv(&mut self) -> Option<WireMessage>;

    /// Next message, abandoning the wait at `deadline`.
    ///
    /// Outcome precedence is part of the trait contract and must be
    /// transport-independent, or `DrainPolicy`'s deadline sweep would
    /// classify the same scenario differently per transport:
    /// `Msg` > `Closed` > `TimedOut`. Concretely, when the deadline
    /// expires in the same instant the last sender drops, a buffered
    /// message is still delivered, and an empty closed uplink reports
    /// `Closed` — never `TimedOut` — so the gate counts the shortfall as
    /// `missing` senders rather than waiting on a wire that can no longer
    /// speak.
    ///
    /// The default implementation has infinite patience (it ignores the
    /// deadline and blocks until a message arrives or the uplink closes);
    /// transports that can time out should override it.
    fn recv_deadline(&mut self, deadline: Instant) -> RecvOutcome {
        let _ = deadline;
        match self.recv() {
            Some(msg) => RecvOutcome::Msg(msg),
            None => RecvOutcome::Closed,
        }
    }

    /// Non-blocking poll: a message if one is already buffered. Backs the
    /// post-deadline late sweep, which counts stragglers without waiting
    /// on them. The default has nothing buffered.
    fn try_recv(&mut self) -> Option<WireMessage> {
        None
    }

    /// Drop any undelivered in-flight state (chaos holds, straggler
    /// queues) without counting it received. Round-persistent transports
    /// call this between rounds so leftover duplicates from round `r`
    /// can't surface as `stale` in round `r+1` — the per-round channel
    /// transport gets the same effect by being dropped. Default: no-op.
    fn discard_inflight(&mut self) {}

    fn stats(&self) -> TransportStats;
}

/// Forwarding impl so a type-erased uplink (channel or socket, chosen at
/// runtime) can still be wrapped by generic adapters like
/// [`ChaosTransport`].
impl Transport for Box<dyn Transport> {
    fn recv(&mut self) -> Option<WireMessage> {
        (**self).recv()
    }

    fn recv_deadline(&mut self, deadline: Instant) -> RecvOutcome {
        (**self).recv_deadline(deadline)
    }

    fn try_recv(&mut self) -> Option<WireMessage> {
        (**self).try_recv()
    }

    fn discard_inflight(&mut self) {
        (**self).discard_inflight()
    }

    fn stats(&self) -> TransportStats {
        (**self).stats()
    }
}

struct Stamped {
    msg: WireMessage,
    sent_at: Instant,
}

#[derive(Default)]
struct Counters {
    messages: AtomicU64,
    payload_bytes: AtomicU64,
}

/// In-process MPSC transport for simulations.
pub struct ChannelTransport {
    rx: mpsc::Receiver<Stamped>,
    counters: Arc<Counters>,
    received: u64,
    transit_secs: f64,
}

struct ChannelSender {
    tx: mpsc::Sender<Stamped>,
    counters: Arc<Counters>,
}

impl ChannelTransport {
    /// Create the server end plus the root sender handle. Dropping the root
    /// handle and all its clones closes the channel, which is how `recv`
    /// learns that no more updates can arrive.
    pub fn new() -> (Self, Box<dyn TransportSender>) {
        let (tx, rx) = mpsc::channel();
        let counters = Arc::new(Counters::default());
        let server = Self {
            rx,
            counters: counters.clone(),
            received: 0,
            transit_secs: 0.0,
        };
        (server, Box::new(ChannelSender { tx, counters }))
    }

    fn absorb(&mut self, stamped: Stamped) -> WireMessage {
        self.received += 1;
        self.transit_secs += stamped.sent_at.elapsed().as_secs_f64();
        stamped.msg
    }
}

impl TransportSender for ChannelSender {
    fn send(&self, msg: WireMessage) -> Result<()> {
        self.counters
            .payload_bytes
            .fetch_add(msg.payload_bytes() as u64, Ordering::Relaxed);
        self.counters.messages.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Stamped {
                msg,
                sent_at: Instant::now(),
            })
            .map_err(|_| anyhow!("uplink closed: server end dropped"))
    }

    fn clone_sender(&self) -> Box<dyn TransportSender> {
        Box::new(ChannelSender {
            tx: self.tx.clone(),
            counters: self.counters.clone(),
        })
    }
}

impl Transport for ChannelTransport {
    fn recv(&mut self) -> Option<WireMessage> {
        match self.rx.recv() {
            Ok(stamped) => Some(self.absorb(stamped)),
            Err(_) => None,
        }
    }

    fn recv_deadline(&mut self, deadline: Instant) -> RecvOutcome {
        let wait = deadline.saturating_duration_since(Instant::now());
        match self.rx.recv_timeout(wait) {
            Ok(stamped) => RecvOutcome::Msg(self.absorb(stamped)),
            // `recv_timeout` reports Timeout even when the senders are
            // already gone (it only notices the disconnect while waiting).
            // Re-poll so a sender dropping exactly at the deadline yields
            // `Closed`, upholding the trait's Msg > Closed > TimedOut
            // ordering that the socket transport also implements.
            Err(mpsc::RecvTimeoutError::Timeout) => match self.rx.try_recv() {
                Ok(stamped) => RecvOutcome::Msg(self.absorb(stamped)),
                Err(mpsc::TryRecvError::Disconnected) => RecvOutcome::Closed,
                Err(mpsc::TryRecvError::Empty) => RecvOutcome::TimedOut,
            },
            Err(mpsc::RecvTimeoutError::Disconnected) => RecvOutcome::Closed,
        }
    }

    fn try_recv(&mut self) -> Option<WireMessage> {
        match self.rx.try_recv() {
            Ok(stamped) => Some(self.absorb(stamped)),
            Err(_) => None,
        }
    }

    fn stats(&self) -> TransportStats {
        TransportStats {
            sent_messages: self.counters.messages.load(Ordering::Relaxed),
            sent_payload_bytes: self.counters.payload_bytes.load(Ordering::Relaxed),
            received_messages: self.received,
            transit_secs: self.transit_secs,
            // The channel has no frames and never blocks admission.
            ..TransportStats::default()
        }
    }
}

// ---------------------------------------------------------------------------
// Deterministic fault injection.
// ---------------------------------------------------------------------------

/// splitmix64 finalizer — the avalanche behind every chaos decision.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

const KIND_DROP: u64 = 1;
const KIND_DUP: u64 = 2;
const KIND_REORDER: u64 = 3;
const KIND_CORRUPT: u64 = 4;
const KIND_STRAGGLE: u64 = 5;
const KIND_DIE: u64 = 6;
const KIND_FLAKY: u64 = 7;

/// What the chaos layer ultimately does to one `(round, client)` record.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultVerdict {
    /// Delivered intact (possibly duplicated or reordered on top — the
    /// first copy is still accepted).
    Deliver,
    /// Never arrives.
    Drop,
    /// Arrives later than every on-time sender: after the uplink closes
    /// under an infinite-patience drain, or only in the post-deadline late
    /// sweep when the drain runs a deadline.
    Straggle,
    /// Arrives as an in-band `Payload::Failed` (client death mid-round).
    Die,
    /// Arrives with an undecodable payload (bit flips + truncation).
    Corrupt,
}

/// Seeded description of every fault [`ChaosTransport`] may inject.
///
/// Rates are probabilities in `[0, 1]`, evaluated independently per
/// `(round, client)` pair by hashing — two runs with the same plan fault
/// exactly the same records, which is what makes churn scenarios
/// reproducible in CI. Parse one from a spec string like
/// `"seed=7,drop=0.1,dup=0.05,straggle=0.2"`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    /// Record never arrives.
    pub drop: f64,
    /// Record arrives twice.
    pub duplicate: f64,
    /// Record swaps places with the next delivery.
    pub reorder: f64,
    /// Record arrives undecodable (seeded bit flips + truncation —
    /// destructive on purpose, so it reliably fails the codecs'
    /// bounds-checked decode instead of sneaking through as a
    /// different-but-valid record).
    pub corrupt: f64,
    /// Record arrives later than every on-time sender (see
    /// [`FaultVerdict::Straggle`]).
    pub straggle: f64,
    /// Client dies mid-round: its slot reports `Payload::Failed` in-band.
    pub die: f64,
    /// Fraction of `(round, client)` pairs whose first `flaky_sends` send
    /// attempts fail, exercising the retry path ([`FaultPlan::wrap_sender`]).
    pub flaky: f64,
    /// How many leading send attempts fail for a flaky pair.
    pub flaky_sends: u32,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self {
            seed: 0,
            drop: 0.0,
            duplicate: 0.0,
            reorder: 0.0,
            corrupt: 0.0,
            straggle: 0.0,
            die: 0.0,
            flaky: 0.0,
            flaky_sends: 2,
        }
    }
}

impl FaultPlan {
    /// Parse a comma-separated `key=value` spec. Keys: `seed`, `drop`,
    /// `dup`/`duplicate`, `reorder`, `corrupt`, `straggle`/`delay`, `die`,
    /// `flaky`, `flaky_sends`. Rates must be in `[0, 1]`; unknown keys are
    /// an error (the config layer fails loudly rather than silently
    /// running a different scenario than asked).
    pub fn parse(spec: &str) -> Result<Self> {
        fn rate(key: &str, value: &str) -> Result<f64> {
            let r: f64 = value
                .parse()
                .map_err(|_| anyhow!("chaos spec: `{key}={value}` is not a number"))?;
            if !(0.0..=1.0).contains(&r) {
                bail!("chaos spec: rate `{key}={value}` outside [0, 1]");
            }
            Ok(r)
        }
        let mut plan = FaultPlan::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| anyhow!("chaos spec: entry `{part}` is not key=value"))?;
            match key.trim() {
                "seed" => {
                    plan.seed = value
                        .parse()
                        .map_err(|_| anyhow!("chaos spec: `seed={value}` is not a u64"))?
                }
                "drop" => plan.drop = rate(key, value)?,
                "dup" | "duplicate" => plan.duplicate = rate(key, value)?,
                "reorder" => plan.reorder = rate(key, value)?,
                "corrupt" => plan.corrupt = rate(key, value)?,
                "straggle" | "delay" => plan.straggle = rate(key, value)?,
                "die" => plan.die = rate(key, value)?,
                "flaky" => plan.flaky = rate(key, value)?,
                "flaky_sends" => {
                    plan.flaky_sends = value
                        .parse()
                        .map_err(|_| anyhow!("chaos spec: `flaky_sends={value}` is not a u32"))?
                }
                other => bail!(
                    "chaos spec: unknown key `{other}` (expected seed, drop, dup, \
                     reorder, corrupt, straggle, die, flaky, flaky_sends)"
                ),
            }
        }
        Ok(plan)
    }

    /// Whether any fault can fire at all.
    pub fn is_active(&self) -> bool {
        self.drop > 0.0
            || self.duplicate > 0.0
            || self.reorder > 0.0
            || self.corrupt > 0.0
            || self.straggle > 0.0
            || self.die > 0.0
            || self.flaky > 0.0
    }

    /// Deterministic uniform draw in `[0, 1)` for one decision.
    fn unit(&self, round: usize, client: usize, kind: u64) -> f64 {
        let h = mix(self.seed ^ mix((round as u64) ^ mix((client as u64) ^ (kind << 56))));
        (h >> 11) as f64 / (1u64 << 53) as f64
    }

    fn hit(&self, rate: f64, round: usize, client: usize, kind: u64) -> bool {
        rate > 0.0 && self.unit(round, client, kind) < rate
    }

    /// The terminal fate of one `(round, client)` record under this plan.
    /// Precedence: die > drop > straggle > corrupt > deliver — so tests can
    /// compute the surviving cohort of any round without replaying the
    /// transport.
    pub fn verdict(&self, round: usize, client: usize) -> FaultVerdict {
        if self.hit(self.die, round, client, KIND_DIE) {
            FaultVerdict::Die
        } else if self.hit(self.drop, round, client, KIND_DROP) {
            FaultVerdict::Drop
        } else if self.hit(self.straggle, round, client, KIND_STRAGGLE) {
            FaultVerdict::Straggle
        } else if self.hit(self.corrupt, round, client, KIND_CORRUPT) {
            FaultVerdict::Corrupt
        } else {
            FaultVerdict::Deliver
        }
    }

    /// Whether every one of `attempts` retried sends fails for this pair
    /// (i.e. the runner will escalate to an in-band `Payload::Failed`).
    pub fn exhausts_retries(&self, round: usize, client: usize, attempts: u32) -> bool {
        self.hit(self.flaky, round, client, KIND_FLAKY) && self.flaky_sends >= attempts
    }

    /// Wrap a sender so flaky `(round, client)` pairs fail their first
    /// `flaky_sends` attempts. A no-op (returns the sender unchanged) when
    /// `flaky` is zero.
    pub fn wrap_sender(&self, inner: Box<dyn TransportSender>) -> Box<dyn TransportSender> {
        if self.flaky <= 0.0 {
            return inner;
        }
        Box::new(ChaosSender {
            inner,
            plan: *self,
            attempts: Arc::new(Mutex::new(HashMap::new())),
        })
    }
}

fn corrupt_message(mut msg: WireMessage, seed: u64) -> WireMessage {
    if let Payload::Update(Encoded { bytes }) = &mut msg.payload {
        for (i, b) in bytes.iter_mut().take(8).enumerate() {
            *b ^= 1 << (mix(seed ^ (i as u64) ^ 0xC0_22) % 8);
        }
        let half = bytes.len() / 2;
        bytes.truncate(half);
        if bytes.is_empty() {
            bytes.push(0xFF);
        }
    }
    msg
}

/// Deterministic fault injector over any [`Transport`].
///
/// Pull-driven: each inner message is assigned its fate by
/// [`FaultPlan::verdict`] the moment it is pulled, so the fault pattern
/// depends only on `(seed, round, client)` — never on timing. Straggled
/// messages are withheld until the inner uplink closes (an
/// infinite-patience `recv` then drains them last) or, under
/// `recv_deadline`, forever — the drain sees `TimedOut` and collects them
/// in its `try_recv` late sweep. That simulates "still in flight past any
/// deadline" without a single real sleep, keeping churn tests fast and
/// deterministic.
pub struct ChaosTransport<T: Transport> {
    inner: T,
    plan: FaultPlan,
    /// Ready for delivery ahead of the inner stream (duplicates, resolved
    /// reorder swaps).
    pending: VecDeque<WireMessage>,
    /// Held back by a reorder fault; delivered after the next message.
    held: Option<WireMessage>,
    /// Withheld stragglers (see the type docs).
    straggled: VecDeque<WireMessage>,
}

impl<T: Transport> ChaosTransport<T> {
    pub fn new(inner: T, plan: FaultPlan) -> Self {
        Self {
            inner,
            plan,
            pending: VecDeque::new(),
            held: None,
            straggled: VecDeque::new(),
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    fn release_held(&mut self) {
        if let Some(h) = self.held.take() {
            self.pending.push_back(h);
        }
    }

    /// Apply this message's fate, queueing whatever should be delivered.
    fn admit(&mut self, msg: WireMessage) {
        let (round, client) = (msg.round, msg.client_id);
        let msg = match self.plan.verdict(round, client) {
            FaultVerdict::Drop => return,
            FaultVerdict::Straggle => {
                self.straggled.push_back(msg);
                return;
            }
            FaultVerdict::Die => WireMessage {
                payload: Payload::Failed(format!("chaos: client {client} died mid-round")),
                ..msg
            },
            FaultVerdict::Corrupt => corrupt_message(msg, self.plan.seed),
            FaultVerdict::Deliver => msg,
        };
        let dup = self.plan.hit(self.plan.duplicate, round, client, KIND_DUP);
        if self.plan.hit(self.plan.reorder, round, client, KIND_REORDER) && self.held.is_none() {
            if dup {
                self.pending.push_back(msg.clone());
            }
            self.held = Some(msg);
            return;
        }
        self.pending.push_back(msg.clone());
        if dup {
            self.pending.push_back(msg);
        }
        self.release_held();
    }

    /// Flush the reorder hold, then report whether anything is deliverable.
    fn drain_tail(&mut self) -> Option<WireMessage> {
        self.release_held();
        self.pending.pop_front()
    }
}

impl<T: Transport> Transport for ChaosTransport<T> {
    fn recv(&mut self) -> Option<WireMessage> {
        loop {
            if let Some(m) = self.pending.pop_front() {
                return Some(m);
            }
            match self.inner.recv() {
                Some(msg) => self.admit(msg),
                // Infinite patience: stragglers arrive after everyone else.
                None => return self.drain_tail().or_else(|| self.straggled.pop_front()),
            }
        }
    }

    fn recv_deadline(&mut self, deadline: Instant) -> RecvOutcome {
        loop {
            if let Some(m) = self.pending.pop_front() {
                return RecvOutcome::Msg(m);
            }
            match self.inner.recv_deadline(deadline) {
                RecvOutcome::Msg(msg) => self.admit(msg),
                RecvOutcome::TimedOut => return RecvOutcome::TimedOut,
                RecvOutcome::Closed => {
                    if let Some(m) = self.drain_tail() {
                        return RecvOutcome::Msg(m);
                    }
                    // Only stragglers remain: under a deadline they are
                    // "still in flight", however long the caller waits —
                    // surface as a timeout so the late sweep finds them
                    // and no test ever sleeps out a real deadline.
                    return if self.straggled.is_empty() {
                        RecvOutcome::Closed
                    } else {
                        RecvOutcome::TimedOut
                    };
                }
            }
        }
    }

    fn try_recv(&mut self) -> Option<WireMessage> {
        loop {
            if let Some(m) = self.pending.pop_front() {
                return Some(m);
            }
            match self.inner.try_recv() {
                Some(msg) => self.admit(msg),
                None => return self.drain_tail().or_else(|| self.straggled.pop_front()),
            }
        }
    }

    fn discard_inflight(&mut self) {
        self.pending.clear();
        self.held = None;
        self.straggled.clear();
        self.inner.discard_inflight();
    }

    fn stats(&self) -> TransportStats {
        self.inner.stats()
    }
}

struct ChaosSender {
    inner: Box<dyn TransportSender>,
    plan: FaultPlan,
    attempts: Arc<Mutex<HashMap<(usize, usize), u32>>>,
}

impl TransportSender for ChaosSender {
    fn send(&self, msg: WireMessage) -> Result<()> {
        if self.plan.hit(self.plan.flaky, msg.round, msg.client_id, KIND_FLAKY) {
            let mut seen = self.attempts.lock().unwrap();
            let n = seen.entry((msg.round, msg.client_id)).or_insert(0);
            if *n < self.plan.flaky_sends {
                *n += 1;
                bail!(
                    "chaos: transient send failure {}/{} for client {}",
                    *n,
                    self.plan.flaky_sends,
                    msg.client_id
                );
            }
        }
        self.inner.send(msg)
    }

    fn clone_sender(&self) -> Box<dyn TransportSender> {
        Box::new(ChaosSender {
            inner: self.inner.clone_sender(),
            plan: self.plan,
            attempts: self.attempts.clone(),
        })
    }
}

/// Send with bounded retry: up to `attempts` tries, sleeping `backoff`
/// (doubling each time) between failures. Returns the last error once
/// exhausted — callers escalate by reporting `Payload::Failed` in-band so
/// the server hears about the loss instead of waiting on it.
pub fn send_with_retry(
    sender: &dyn TransportSender,
    msg: WireMessage,
    attempts: u32,
    backoff: std::time::Duration,
) -> Result<()> {
    let attempts = attempts.max(1);
    let mut wait = backoff;
    let mut last = None;
    for attempt in 0..attempts {
        match sender.send(msg.clone()) {
            Ok(()) => return Ok(()),
            Err(e) => last = Some(e),
        }
        if attempt + 1 < attempts && !wait.is_zero() {
            std::thread::sleep(wait);
            wait *= 2;
        }
    }
    Err(anyhow!(
        "send failed after {attempts} attempts: {}",
        last.expect("attempts >= 1")
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(slot: usize, n_bytes: usize) -> WireMessage {
        WireMessage {
            round: 0,
            client_id: slot,
            slot,
            payload: Payload::Update(Encoded {
                bytes: vec![0xAB; n_bytes],
            }),
            enc_secs: 0.001,
            loss: 0.5,
        }
    }

    #[test]
    fn delivers_in_order_and_accounts_bytes() {
        let (mut server, sender) = ChannelTransport::new();
        let s2 = sender.clone();
        sender.send(msg(0, 10)).unwrap();
        s2.send(msg(1, 30)).unwrap();
        drop(sender);
        drop(s2);
        let a = server.recv().unwrap();
        let b = server.recv().unwrap();
        assert_eq!((a.slot, b.slot), (0, 1));
        assert!(server.recv().is_none(), "closed after all senders drop");
        let st = server.stats();
        assert_eq!(st.sent_messages, 2);
        assert_eq!(st.sent_payload_bytes, 40);
        assert_eq!(st.received_messages, 2);
        assert!(st.transit_secs >= 0.0);
    }

    #[test]
    fn failure_payloads_count_zero_bytes() {
        let (mut server, sender) = ChannelTransport::new();
        sender
            .send(WireMessage {
                round: 3,
                client_id: 9,
                slot: 0,
                payload: Payload::Failed("oom".into()),
                enc_secs: 0.0,
                loss: 0.0,
            })
            .unwrap();
        drop(sender);
        let m = server.recv().unwrap();
        assert_eq!(m.payload_bytes(), 0);
        assert!(matches!(m.payload, Payload::Failed(ref e) if e == "oom"));
    }

    #[test]
    fn send_after_server_drop_errors() {
        let (server, sender) = ChannelTransport::new();
        drop(server);
        assert!(sender.send(msg(0, 1)).is_err());
    }

    #[test]
    fn senders_work_across_threads() {
        let (mut server, sender) = ChannelTransport::new();
        std::thread::scope(|scope| {
            for t in 0..4 {
                let s = sender.clone();
                scope.spawn(move || s.send(msg(t, t + 1)).unwrap());
            }
        });
        drop(sender);
        let mut slots: Vec<usize> = std::iter::from_fn(|| server.recv().map(|m| m.slot)).collect();
        slots.sort_unstable();
        assert_eq!(slots, vec![0, 1, 2, 3]);
        assert_eq!(server.stats().sent_payload_bytes, 1 + 2 + 3 + 4);
    }

    #[test]
    fn recv_deadline_times_out_then_sees_close() {
        let (mut server, sender) = ChannelTransport::new();
        // Sender alive, nothing queued: the deadline fires.
        let soon = Instant::now() + std::time::Duration::from_millis(5);
        assert!(matches!(server.recv_deadline(soon), RecvOutcome::TimedOut));
        sender.send(msg(0, 4)).unwrap();
        drop(sender);
        let far = Instant::now() + std::time::Duration::from_secs(30);
        assert!(matches!(server.recv_deadline(far), RecvOutcome::Msg(_)));
        assert!(matches!(server.recv_deadline(far), RecvOutcome::Closed));
    }

    /// The trait's Msg > Closed > TimedOut contract at the razor's edge:
    /// senders gone and the deadline already expired must read as Closed
    /// (nothing can ever arrive), and a buffered message beats both.
    #[test]
    fn recv_deadline_prefers_msg_then_closed_over_timeout() {
        // Expired deadline + closed empty uplink ⇒ Closed, not TimedOut.
        let (mut server, sender) = ChannelTransport::new();
        drop(sender);
        let past = Instant::now() - std::time::Duration::from_millis(1);
        assert!(matches!(server.recv_deadline(past), RecvOutcome::Closed));

        // Expired deadline + buffered message ⇒ the message still lands.
        let (mut server, sender) = ChannelTransport::new();
        sender.send(msg(4, 8)).unwrap();
        drop(sender);
        let past = Instant::now() - std::time::Duration::from_millis(1);
        match server.recv_deadline(past) {
            RecvOutcome::Msg(m) => assert_eq!(m.slot, 4),
            other => panic!("expected Msg, got {other:?}"),
        }
        assert!(matches!(server.recv_deadline(past), RecvOutcome::Closed));

        // Expired deadline + live sender, nothing queued ⇒ TimedOut.
        let (mut server, _sender) = ChannelTransport::new();
        let past = Instant::now() - std::time::Duration::from_millis(1);
        assert!(matches!(server.recv_deadline(past), RecvOutcome::TimedOut));
    }

    #[test]
    fn try_recv_polls_without_blocking() {
        let (mut server, sender) = ChannelTransport::new();
        assert!(server.try_recv().is_none());
        sender.send(msg(2, 4)).unwrap();
        assert_eq!(server.try_recv().unwrap().slot, 2);
        assert!(server.try_recv().is_none());
    }

    #[test]
    fn fault_plan_parses_and_rejects_bad_specs() {
        let plan = FaultPlan::parse("seed=7, drop=0.25,dup=0.5,straggle=1,flaky_sends=3").unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.drop, 0.25);
        assert_eq!(plan.duplicate, 0.5);
        assert_eq!(plan.straggle, 1.0);
        assert_eq!(plan.flaky_sends, 3);
        assert!(plan.is_active());
        assert!(!FaultPlan::parse("").unwrap().is_active());
        assert!(FaultPlan::parse("drop=1.5").is_err(), "rate outside [0,1]");
        assert!(FaultPlan::parse("warp=0.1").is_err(), "unknown key");
        assert!(FaultPlan::parse("drop").is_err(), "missing value");
    }

    /// Chaos delivery is a pure function of (plan, message stream): two
    /// runs over the same stream produce byte-identical delivery
    /// sequences, and every delivered/absent record matches its verdict.
    #[test]
    fn chaos_faults_are_deterministic_and_match_verdicts() {
        let plan = FaultPlan::parse("seed=11,drop=0.3,dup=0.3,reorder=0.3,die=0.2").unwrap();
        let run = || -> Vec<(usize, usize, bool)> {
            let (server, sender) = ChannelTransport::new();
            for round in 0..3 {
                for client in 0..8 {
                    let mut m = msg(client, 16);
                    m.round = round;
                    sender.send(m).unwrap();
                }
            }
            drop(sender);
            let mut chaos = ChaosTransport::new(server, plan);
            std::iter::from_fn(|| chaos.recv())
                .map(|m| {
                    (
                        m.round,
                        m.client_id,
                        matches!(m.payload, Payload::Failed(_)),
                    )
                })
                .collect()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same plan, same stream ⇒ same deliveries");
        for round in 0..3usize {
            for client in 0..8usize {
                let copies = a
                    .iter()
                    .filter(|&&(r, c, _)| r == round && c == client)
                    .count();
                match plan.verdict(round, client) {
                    FaultVerdict::Drop => assert_eq!(copies, 0, "dropped r{round} c{client}"),
                    FaultVerdict::Die => {
                        assert!(copies >= 1);
                        assert!(a
                            .iter()
                            .any(|&(r, c, failed)| r == round && c == client && failed));
                    }
                    _ => assert!(copies >= 1, "delivered r{round} c{client}"),
                }
            }
        }
    }

    /// Stragglers arrive last under infinite patience, and only via the
    /// late sweep under a deadline — with no real sleeping either way.
    #[test]
    fn stragglers_arrive_after_close_or_in_the_late_sweep() {
        let plan = FaultPlan::parse("seed=5,straggle=1").unwrap();
        let (server, sender) = ChannelTransport::new();
        for c in 0..3 {
            sender.send(msg(c, 8)).unwrap();
        }
        drop(sender);
        let mut chaos = ChaosTransport::new(server, plan);
        let far = Instant::now() + std::time::Duration::from_secs(30);
        // Everything straggled ⇒ a deadline drain times out instantly …
        assert!(matches!(chaos.recv_deadline(far), RecvOutcome::TimedOut));
        // … and the late sweep yields all three without blocking.
        let late: Vec<usize> = std::iter::from_fn(|| chaos.try_recv())
            .map(|m| m.client_id)
            .collect();
        assert_eq!(late, vec![0, 1, 2]);

        // Infinite patience: same stream, stragglers delivered at the end.
        let (server, sender) = ChannelTransport::new();
        for c in 0..3 {
            sender.send(msg(c, 8)).unwrap();
        }
        drop(sender);
        let mut chaos = ChaosTransport::new(server, plan);
        let got: Vec<usize> = std::iter::from_fn(|| chaos.recv())
            .map(|m| m.client_id)
            .collect();
        assert_eq!(got, vec![0, 1, 2]);
    }

    /// Between-rounds hygiene for round-persistent transports: discarding
    /// in-flight chaos state drops undelivered stragglers/holds without
    /// counting them, exactly like dropping a per-round channel would.
    #[test]
    fn discard_inflight_clears_chaos_holds() {
        let plan = FaultPlan::parse("seed=5,straggle=1").unwrap();
        let (server, sender) = ChannelTransport::new();
        for c in 0..3 {
            sender.send(msg(c, 8)).unwrap();
        }
        drop(sender);
        let mut chaos = ChaosTransport::new(server, plan);
        let far = Instant::now() + std::time::Duration::from_secs(30);
        assert!(matches!(chaos.recv_deadline(far), RecvOutcome::TimedOut));
        chaos.discard_inflight();
        assert!(chaos.try_recv().is_none(), "stragglers discarded");
        assert!(chaos.recv().is_none(), "uplink reads closed afterwards");
    }

    #[test]
    fn corruption_is_destructive_and_deterministic() {
        let plan = FaultPlan::parse("seed=3,corrupt=1").unwrap();
        let deliver = || {
            let (server, sender) = ChannelTransport::new();
            sender.send(msg(0, 32)).unwrap();
            drop(sender);
            ChaosTransport::new(server, plan).recv().unwrap()
        };
        let a = deliver();
        let b = deliver();
        let bytes = |m: &WireMessage| match &m.payload {
            Payload::Update(enc) => enc.bytes.clone(),
            Payload::Failed(_) => panic!("corrupt keeps the Update shape"),
        };
        assert_eq!(bytes(&a), bytes(&b), "same seed ⇒ same corruption");
        assert_eq!(bytes(&a).len(), 16, "truncated to half");
        assert_ne!(bytes(&a), vec![0xAB; 16], "bits actually flipped");
    }

    #[test]
    fn flaky_sender_fails_then_recovers_under_retry() {
        let plan = FaultPlan::parse("seed=9,flaky=1,flaky_sends=2").unwrap();
        let (mut server, sender) = ChannelTransport::new();
        let flaky = plan.wrap_sender(sender);
        // Two raw sends fail, the third lands.
        assert!(flaky.send(msg(0, 4)).is_err());
        assert!(flaky.send(msg(0, 4)).is_err());
        assert!(flaky.send(msg(0, 4)).is_ok());
        // Retry helper rides out the transient window for a fresh client.
        let m = WireMessage {
            client_id: 1,
            ..msg(1, 4)
        };
        send_with_retry(flaky.as_ref(), m, 3, std::time::Duration::ZERO).unwrap();
        // A different pair with too few attempts exhausts and errors.
        let m = WireMessage {
            client_id: 2,
            ..msg(2, 4)
        };
        let err = send_with_retry(flaky.as_ref(), m, 2, std::time::Duration::ZERO).unwrap_err();
        assert!(err.to_string().contains("after 2 attempts"), "{err}");
        assert!(plan.exhausts_retries(0, 2, 2));
        assert!(!plan.exhausts_retries(0, 2, 3));
        drop(flaky);
        let delivered: Vec<usize> = std::iter::from_fn(|| server.recv())
            .map(|m| m.client_id)
            .collect();
        assert_eq!(delivered, vec![0, 1]);
    }
}
