//! **QSGD** (Alistarh et al. 2017) — stochastic uniform quantization with
//! `levels` quantization levels per coordinate plus a per-vector norm, with
//! the (level, sign) stream entropy-coded (we use the adaptive arithmetic
//! coder; QSGD's Elias coding achieves comparable rates for the sparse
//! low-level regime).

use super::{wire, DecodeCtx, EncodeCtx, Encoded, Family, Update, UpdateCodec};
use crate::codec::arith;
use crate::util::rng::Xoshiro256pp;
use anyhow::{ensure, Result};

pub struct QsgdCodec {
    /// Number of positive quantization levels s (QSGD's tuning knob);
    /// s=1 ⇒ ternary {-1, 0, +1}·‖v‖.
    pub levels: u32,
}

impl Default for QsgdCodec {
    fn default() -> Self {
        Self { levels: 1 }
    }
}

impl UpdateCodec for QsgdCodec {
    fn name(&self) -> &'static str {
        "qsgd"
    }

    fn family(&self) -> Family {
        Family::Delta
    }

    fn encode(&self, ctx: &EncodeCtx) -> Result<Encoded> {
        let d = ctx.d;
        let s = self.levels as f32;
        let mut rng = Xoshiro256pp::new(ctx.seed ^ 0x45_47_53_44);
        let norm = (0..d)
            .map(|i| {
                let x = ctx.s_k[i] - ctx.s_g[i];
                (x * x) as f64
            })
            .sum::<f64>()
            .sqrt() as f32;

        // Stochastic quantization: level_i = floor(|x|/norm * s + u).
        // Stream layout: per coordinate, unary-ish bit encoding via the
        // adaptive coder: [nonzero?][sign][level-1 in unary capped at s].
        let mut bits: Vec<bool> = Vec::with_capacity(d * 2);
        for i in 0..d {
            let x = ctx.s_k[i] - ctx.s_g[i];
            if norm == 0.0 {
                bits.push(false);
                continue;
            }
            let r = x.abs() / norm * s;
            let mut level = r.floor();
            if rng.next_f32() < r - level {
                level += 1.0;
            }
            let level = level as u32;
            if level == 0 {
                bits.push(false);
            } else {
                bits.push(true);
                bits.push(x >= 0.0);
                // level in unary: (level-1) ones then a zero (cap at s).
                for _ in 0..(level - 1).min(self.levels - 1) {
                    bits.push(true);
                }
                if level < self.levels {
                    bits.push(false);
                }
            }
        }
        let coded = arith::encode_bits(&bits);
        let mut bytes = Vec::with_capacity(coded.len() + 16);
        wire::put_u32(&mut bytes, d as u32);
        wire::put_f32(&mut bytes, norm);
        wire::put_u32(&mut bytes, bits.len() as u32);
        wire::put_u32(&mut bytes, coded.len() as u32);
        bytes.extend_from_slice(&coded);
        Ok(Encoded { bytes })
    }

    fn decode(&self, bytes: &[u8], ctx: &DecodeCtx) -> Result<Update> {
        let mut r = wire::Reader::new(bytes);
        let d = r.u32()? as usize;
        ensure!(d == ctx.d, "dimension mismatch");
        let norm = r.f32()?;
        let nbits = r.u32()? as usize;
        let clen = r.u32()? as usize;
        let coded = r.bytes(clen)?;
        let bits = arith::decode_bits(coded, nbits);
        let s = self.levels as f32;
        let mut out = vec![0.0f32; d];
        let mut pos = 0usize;
        for item in out.iter_mut() {
            ensure!(pos < bits.len(), "bit stream underrun");
            let nonzero = bits[pos];
            pos += 1;
            if !nonzero {
                continue;
            }
            let sign = if bits[pos] { 1.0 } else { -1.0 };
            pos += 1;
            let mut level = 1u32;
            while level < self.levels && pos < bits.len() && bits[pos] {
                level += 1;
                pos += 1;
            }
            if level < self.levels {
                pos += 1; // terminating zero
            }
            *item = sign * norm * level as f32 / s;
        }
        Ok(Update::ScoreDelta(out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn roundtrip_unbiased_and_sub_one_bpp() {
        let d = 50_000;
        let mut rng = Xoshiro256pp::new(9);
        let s_g = vec![0.0f32; d];
        let s_k: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32 * 0.01).collect();
        let ctx = EncodeCtx {
            d,
            theta_k: &[],
            theta_g: &[],
            mask_k: &[],
            mask_g: &[],
            s_k: &s_k,
            s_g: &s_g,
            kappa: 1.0,
            seed: 11,
        };
        let codec = QsgdCodec::default();
        let enc = codec.encode(&ctx).unwrap();
        // s=1 ternary: most coords quantize to zero (E[level] = |x|·s/‖x‖
        // ≈ 1/√d per coord) ⇒ rate well under 1 bpp.
        assert!(enc.bpp(d) < 1.0, "bpp={}", enc.bpp(d));
        let dctx = DecodeCtx {
            d,
            mask_g: &[],
            s_g: &s_g,
            seed: 11,
        };
        let Update::ScoreDelta(rec) = codec.decode(&enc.bytes, &dctx).unwrap() else {
            panic!()
        };
        // Unbiasedness: E[rec] = x ⇒ mean of (rec - x) ≈ 0 in aggregate.
        let bias: f64 = rec
            .iter()
            .zip(&s_k)
            .map(|(a, b)| (a - b) as f64)
            .sum::<f64>()
            / d as f64;
        let scale: f64 =
            s_k.iter().map(|x| x.abs() as f64).sum::<f64>() / d as f64;
        assert!(bias.abs() < scale, "bias={bias} scale={scale}");
        // Direction preserved.
        let dot: f64 = rec.iter().zip(&s_k).map(|(a, b)| (a * b) as f64).sum();
        assert!(dot > 0.0);
    }

    #[test]
    fn zero_vector_roundtrip() {
        let d = 100;
        let z = vec![0.0f32; d];
        let ctx = EncodeCtx {
            d,
            theta_k: &[],
            theta_g: &[],
            mask_k: &[],
            mask_g: &[],
            s_k: &z,
            s_g: &z,
            kappa: 1.0,
            seed: 1,
        };
        let codec = QsgdCodec::default();
        let enc = codec.encode(&ctx).unwrap();
        let dctx = DecodeCtx {
            d,
            mask_g: &[],
            s_g: &z,
            seed: 1,
        };
        let Update::ScoreDelta(rec) = codec.decode(&enc.bytes, &dctx).unwrap() else {
            panic!()
        };
        assert_eq!(rec, z);
    }
}
