//! XOR filters (Graf & Lemire, *ACM JEA* 2020) — predecessor of binary fuse
//! filters; used in the paper's filter ablation (Fig. 9, Table 4). Same XOR
//! membership identity as BFuse but with three *independent thirds* instead
//! of fused segments, costing ≈1.23·n cells (≈9.84 bits/entry at 8-bit
//! fingerprints).

use super::{Fingerprint, MembershipFilter, BATCH_BLOCK};
use crate::hash::{mix64, mix_split};

#[derive(Clone, Debug)]
pub struct XorFilter<F: Fingerprint> {
    seed: u64,
    block_length: u32,
    fingerprints: Vec<F>,
    num_keys: usize,
}

const MAX_ITERATIONS: usize = 128;

impl<F: Fingerprint> XorFilter<F> {
    pub fn build(keys: &[u64]) -> Option<Self> {
        let mut keys = keys.to_vec();
        keys.sort_unstable();
        keys.dedup();
        let size = keys.len();

        let capacity = if size == 0 {
            3 // one cell per block, empty-but-valid layout
        } else {
            let c = (1.23 * size as f64).floor() as usize + 32;
            c - (c % 3) + 3 // round up to a multiple of 3
        };
        let block_length = (capacity / 3) as u32;

        let mut filter = Self {
            seed: 0,
            block_length,
            fingerprints: vec![F::default(); capacity],
            num_keys: size,
        };
        if size == 0 {
            return Some(filter);
        }

        let mut t2count = vec![0u8; capacity];
        let mut t2hash = vec![0u64; capacity];
        let mut alone = vec![0u32; capacity];
        let mut stack_hash = vec![0u64; size];
        let mut stack_found = vec![0u8; size];
        let mut seed_rng = 0x9e3779b97f4a7c15u64;

        'outer: for _ in 0..MAX_ITERATIONS {
            seed_rng = seed_rng.wrapping_add(0xbf58476d1ce4e5b9);
            filter.seed = mix64(seed_rng);
            t2count.iter_mut().for_each(|c| *c = 0);
            t2hash.iter_mut().for_each(|h| *h = 0);

            for &key in &keys {
                let hash = mix_split(key, filter.seed);
                for (j, p) in filter.positions(hash).into_iter().enumerate() {
                    let c = &mut t2count[p as usize];
                    *c = c.wrapping_add(4);
                    *c ^= j as u8;
                    t2hash[p as usize] ^= hash;
                    if *c < 4 {
                        continue 'outer;
                    }
                }
            }

            let mut q = 0usize;
            for (i, &c) in t2count.iter().enumerate() {
                if c >> 2 == 1 {
                    alone[q] = i as u32;
                    q += 1;
                }
            }
            let mut stack = 0usize;
            while q > 0 {
                q -= 1;
                let cell = alone[q] as usize;
                if t2count[cell] >> 2 != 1 {
                    continue;
                }
                let hash = t2hash[cell];
                let found = (t2count[cell] & 3) as usize;
                stack_hash[stack] = hash;
                stack_found[stack] = found as u8;
                stack += 1;
                for (j, p) in filter.positions(hash).into_iter().enumerate() {
                    if j == found {
                        continue;
                    }
                    let c = &mut t2count[p as usize];
                    *c = c.wrapping_sub(4);
                    *c ^= j as u8;
                    t2hash[p as usize] ^= hash;
                    if *c >> 2 == 1 {
                        alone[q] = p;
                        q += 1;
                    }
                }
            }

            if stack == size {
                for i in (0..stack).rev() {
                    let hash = stack_hash[i];
                    let found = stack_found[i] as usize;
                    let positions = self_positions(filter.block_length, hash);
                    let mut fp = F::from_hash(hash);
                    for (j, &p) in positions.iter().enumerate() {
                        if j != found {
                            fp = fp.xor(filter.fingerprints[p as usize]);
                        }
                    }
                    filter.fingerprints[positions[found] as usize] = fp;
                }
                return Some(filter);
            }
        }
        None
    }

    #[inline]
    fn positions(&self, hash: u64) -> [u32; 3] {
        self_positions(self.block_length, hash)
    }

    /// Membership probe for an already-mixed hash — shared by `contains`
    /// and the batched kernels so both agree bitwise by construction.
    #[inline(always)]
    fn probe_hash(&self, hash: u64) -> bool {
        let mut fp = F::from_hash(hash);
        for p in self_positions(self.block_length, hash) {
            fp = fp.xor(self.fingerprints[p as usize]);
        }
        fp == F::default()
    }

    pub fn num_keys(&self) -> usize {
        self.num_keys
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn payload(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.fingerprints.len() * (F::BITS as usize / 8));
        for &fp in &self.fingerprints {
            fp.to_bytes_push(&mut out);
        }
        out
    }

    pub fn from_parts(seed: u64, block_length: u32, payload: &[u8], num_keys: usize) -> Self {
        let w = F::BITS as usize / 8;
        assert_eq!(payload.len() % w, 0);
        let n = payload.len() / w;
        Self {
            seed,
            block_length,
            fingerprints: (0..n).map(|i| F::read_bytes(payload, i)).collect(),
            num_keys,
        }
    }

    pub fn block_length(&self) -> u32 {
        self.block_length
    }
}

#[inline]
fn self_positions(block_length: u32, hash: u64) -> [u32; 3] {
    // Three independent 32-bit windows of the hash, each fast-range reduced
    // into its own third of the array (Lemire reduction: (r * b) >> 32).
    let r0 = hash as u32;
    let r1 = hash.rotate_left(21) as u32;
    let r2 = hash.rotate_left(42) as u32;
    let b = block_length as u64;
    [
        ((r0 as u64 * b) >> 32) as u32,
        ((r1 as u64 * b) >> 32) as u32 + block_length,
        ((r2 as u64 * b) >> 32) as u32 + 2 * block_length,
    ]
}

impl<F: Fingerprint> MembershipFilter for XorFilter<F> {
    #[inline]
    fn contains(&self, key: u64) -> bool {
        if self.num_keys == 0 {
            return false;
        }
        self.probe_hash(mix_split(key, self.seed))
    }

    /// Blocked monomorphic kernel: hash a whole block in a flat loop, then
    /// probe with the block-length register hoisted.
    fn contains_batch(&self, keys: &[u64], out: &mut [bool]) {
        assert_eq!(keys.len(), out.len());
        if self.num_keys == 0 {
            out.fill(false);
            return;
        }
        let seed = self.seed;
        let mut hashes = [0u64; BATCH_BLOCK];
        let mut base = 0usize;
        while base < keys.len() {
            let len = BATCH_BLOCK.min(keys.len() - base);
            for (h, &k) in hashes[..len].iter_mut().zip(&keys[base..base + len]) {
                *h = mix_split(k, seed);
            }
            for (o, &h) in out[base..base + len].iter_mut().zip(&hashes[..len]) {
                *o = self.probe_hash(h);
            }
            base += len;
        }
    }

    /// Batched Eq. 5 kernel over one contiguous index range (see
    /// [`MembershipFilter::decode_mask_into_range`]; `start == 0` is the
    /// full-`d` `decode_mask_into` sweep).
    fn decode_mask_into_range(&self, mask: &mut [f32], start: usize) {
        if self.num_keys == 0 {
            return;
        }
        let seed = self.seed;
        let mut hashes = [0u64; BATCH_BLOCK];
        let d = mask.len();
        let mut base = 0usize;
        while base < d {
            let len = BATCH_BLOCK.min(d - base);
            for (j, h) in hashes[..len].iter_mut().enumerate() {
                *h = mix_split((start + base + j) as u64, seed);
            }
            for (j, m) in mask[base..base + len].iter_mut().enumerate() {
                if self.probe_hash(hashes[j]) {
                    *m = 1.0 - *m;
                }
            }
            base += len;
        }
    }

    fn payload_bytes(&self) -> usize {
        self.fingerprints.len() * (F::BITS as usize / 8)
    }

    fn bits_per_entry(&self) -> f64 {
        if self.num_keys == 0 {
            return 0.0;
        }
        (self.payload_bytes() * 8) as f64 / self.num_keys as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filters::testutil::{random_indexes, random_keys};

    #[test]
    fn no_false_negatives() {
        for n in [0usize, 1, 2, 5, 100, 10_000] {
            let keys = random_keys(n, 100 + n as u64);
            let f = XorFilter::<u8>::build(&keys).unwrap();
            for &k in &keys {
                assert!(f.contains(k));
            }
            let f16 = XorFilter::<u16>::build(&keys).unwrap();
            for &k in &keys {
                assert!(f16.contains(k));
            }
        }
    }

    #[test]
    fn xor_larger_than_bfuse() {
        // The paper's Fig. 9 claim: BFuse beats XOR on space.
        let keys = random_keys(50_000, 5);
        let xf = XorFilter::<u8>::build(&keys).unwrap();
        let bf = crate::filters::BinaryFuse::<u8, 4>::build(&keys).unwrap();
        assert!(
            xf.bits_per_entry() > bf.bits_per_entry(),
            "xor={} bfuse={}",
            xf.bits_per_entry(),
            bf.bits_per_entry()
        );
        assert!(xf.bits_per_entry() < 10.5, "xor bpe={}", xf.bits_per_entry());
    }

    #[test]
    fn fp_rate() {
        let keys = random_indexes(5_000, 1u64 << 40, 6);
        let keyset: std::collections::HashSet<u64> = keys.iter().cloned().collect();
        let f = XorFilter::<u8>::build(&keys).unwrap();
        let mut rng = crate::util::rng::Xoshiro256pp::new(77);
        let mut fp = 0usize;
        let trials = 100_000;
        for _ in 0..trials {
            let k = rng.next_u64();
            if !keyset.contains(&k) && f.contains(k) {
                fp += 1;
            }
        }
        let rate = fp as f64 / trials as f64;
        assert!(rate < 0.008, "rate={rate}");
    }

    #[test]
    fn batched_kernels_match_scalar_oracle() {
        for (n, d) in [(0usize, 1_000u64), (1, 257), (400, 10_001), (4_000, 100_003)] {
            let keys = random_indexes(n, d, 31 + n as u64);
            let f8 = XorFilter::<u8>::build(&keys).unwrap();
            let f32f = XorFilter::<u32>::build(&keys).unwrap();
            // Scalar Eq. 5 oracle vs the blocked kernel, bitwise.
            let mut mask: Vec<f32> = (0..d).map(|i| (i % 2 == 0) as u32 as f32).collect();
            let mut expect = mask.clone();
            for (i, m) in expect.iter_mut().enumerate() {
                if f8.contains(i as u64) {
                    *m = 1.0 - *m;
                }
            }
            f8.decode_mask_into(&mut mask);
            assert_eq!(mask, expect);
            // Range tiling reproduces the full sweep bitwise.
            let mut tiled: Vec<f32> = (0..d).map(|i| (i % 2 == 0) as u32 as f32).collect();
            let mid = (d / 2 + 3).min(d) as usize;
            f8.decode_mask_into_range(&mut tiled[..mid], 0);
            f8.decode_mask_into_range(&mut tiled[mid..], mid);
            assert_eq!(tiled, expect, "range tiling diverged");
            // contains_batch parity across widths.
            let mut rng = crate::util::rng::Xoshiro256pp::new(n as u64 + 7);
            let probes: Vec<u64> = (0..3_000).map(|_| rng.below(2 * d)).collect();
            let mut got = vec![false; probes.len()];
            f32f.contains_batch(&probes, &mut got);
            for (j, &k) in probes.iter().enumerate() {
                assert_eq!(got[j], f32f.contains(k));
            }
        }
    }

    #[test]
    fn roundtrip() {
        let keys = random_indexes(3_000, 100_000, 8);
        let f = XorFilter::<u16>::build(&keys).unwrap();
        let g = XorFilter::<u16>::from_parts(f.seed(), f.block_length(), &f.payload(), f.num_keys());
        for &k in &keys {
            assert!(g.contains(k));
        }
        for k in 0..5_000u64 {
            assert_eq!(f.contains(k), g.contains(k));
        }
    }
}
