//! The transport-agnostic federated round engine — the paper's L3
//! coordination contribution (Alg. 1/2) as a reusable subsystem.
//!
//! The seed grew this logic inside a monolithic `fl::Runner`; it now lives
//! here in four parts so the round loop composes instead of hard-wiring:
//!
//! * [`round`] — [`RoundPlan`] / [`RoundEngine`]: participant sampling, the
//!   cosine κ schedule, per-round seeds and the shared-seed global binary
//!   mask m^{g,t-1}. A plan is an immutable snapshot of everything a round
//!   broadcasts (θ_g, s_g, mask_g), which is what decode contexts borrow —
//!   never live server state, so streaming aggregation can mutate the
//!   server while late updates are still being decoded.
//! * [`transport`] — the [`Transport`] / [`TransportSender`] traits and the
//!   in-process [`ChannelTransport`] used by simulations. Messages carry
//!   [`Encoded`](crate::compress::Encoded) payloads plus per-message byte
//!   and queue-latency accounting, replacing the old ad-hoc
//!   `ClientRoundOutput` plumbing. The networked implementation is
//!   [`transport::socket`]: a length-prefixed framed transport over
//!   TCP / Unix-domain sockets with bounded-admission backpressure and
//!   session-multiplexed connections — [`SocketHub`] wires it loopback
//!   in-process (`--transport tcp|uds`), [`FleetServer`]/[`FleetLink`]
//!   run coordinator and client fleet as separate OS processes
//!   (`deltamask serve` / `deltamask client-fleet`).
//! * [`aggregate`] — the server-side drain loop ([`drain_round`]) over an
//!   [`Aggregator`] sink: per-arrival decode→absorb in streaming mode, the
//!   old full-round barrier in batch mode, with deterministic per-slot
//!   accounting either way. A [`DrainConfig`] additionally shards the
//!   decode stage across N worker threads (each leasing buffers from the
//!   shared [`ScratchPool`]) while the absorb stage merges completions on
//!   the draining thread — bitwise identical to the serial path at any
//!   worker count, wired to the CLI as `--decode-workers N`.
//! * [`shard`] — the dimension-sharded [`ShardedAggregator`]: the
//!   parameter space `0..d` is partitioned into S contiguous shards, each
//!   with its own aggregation-state slice, participation counters and
//!   [`ScratchPool`], absorbed on S parallel lane threads fed through a
//!   clonable [`ShardRouter`]. With `DrainConfig::shards > 1` the decode
//!   workers hand each decoded record's shard splits to the lanes
//!   directly, so even a single huge record's absorb sweep parallelizes.
//!   Bitwise identical to the single-lane path at any shard count, wired
//!   to the CLI as `--agg-shards N`. The operator's guide to how the
//!   three knobs compose is `docs/SCALING.md`. Lanes sit behind the
//!   [`ShardLane`] trait: a [`ThreadLane`] runs in-process, a
//!   [`RemoteShardLane`] ships its shard's splits over the DMW1 wire to a
//!   `deltamask shard-worker` process ([`ShardPlacement`] /
//!   `--shard-place` choose per shard) — same router, same drains, same
//!   bitwise trajectories, with socket faults surfaced through
//!   [`Aggregator::lane_fault`] as clean round aborts.
//! * [`pipeline`] — the round-resident [`DrainPipeline`]: decode workers
//!   spawned **once per experiment** and parked on an epoch barrier
//!   between rounds, reusing one decode-buffer [`ScratchPool`] across the
//!   whole trajectory. Paired with a resident [`ShardedAggregator`]
//!   (whose absorb lanes are resident threads too), per-round setup cost
//!   drops from O(threads + pool warm-up) to zero and steady-state rounds
//!   allocate no decode buffers — observable via the pool's hit/miss
//!   counters in [`DrainReport`] / `RoundMetrics`. Wired to the CLI as
//!   `--persistent-pipeline` (env `DELTAMASK_PERSISTENT_PIPELINE=1`);
//!   bitwise identical to the per-round-spawn drain.
//! * **Fault tolerance** — every drain path (per-round-spawn and
//!   resident) admits wire traffic through one shared gate: first record
//!   per `(round, slot)` wins; duplicates, stale-round replays, bad slots
//!   and in-band `Payload::Failed` reports are counted
//!   ([`FaultCounters`]) and dropped. A [`DrainPolicy`]
//!   (`--quorum`/`--round-deadline-ms`/`--on-decode-error`) lets rounds
//!   finish **degraded** over whoever showed up instead of aborting on
//!   the first straggler. The deterministic chaos harness —
//!   [`ChaosTransport`] over a seeded [`FaultPlan`] (drop, duplicate,
//!   reorder, corrupt, straggle, die, flaky sends) plus
//!   [`send_with_retry`] on the client path — makes every failure mode
//!   reproducible in CI (`rust/tests/churn.rs`).
//! * [`pool`] — a self-scheduling (work-stealing) [`ClientPool`]: workers
//!   pull the next client job from a shared queue instead of being handed a
//!   fixed round-robin chunk, so stragglers no longer idle whole threads,
//!   and sessions live in `Option` slots rather than being swapped out for
//!   zero-dimension placeholders.
//! * [`PipelineMode`] — batch (decode + aggregate after a full-round
//!   barrier, the seed behaviour) vs streaming (decode→absorb per arrival,
//!   O(d) server memory instead of O(K·d)); both are exposed so benches can
//!   A/B them. Streaming is the default.
//!
//! The server-side counterpart is
//! [`MaskServer::{begin_round, absorb, finish_round}`](crate::fl::server::MaskServer),
//! whose mask-family pseudo-count arithmetic is exactly order-invariant
//! (integer-valued f32 adds) and whose delta-family FedAvg is applied in
//! participant order through a reorder window, so a streaming round is
//! bitwise identical to the batch barrier regardless of arrival order —
//! and, for the same reason, regardless of how many decode workers race
//! to produce those arrivals.
//!
//! The full layer map, the round lifecycle and the wire-format invariants
//! each layer guarantees are documented in `docs/ARCHITECTURE.md`.

pub mod aggregate;
pub mod pipeline;
pub mod pool;
pub mod round;
pub mod shard;
pub mod transport;

pub use aggregate::{
    drain_round, Aggregator, DrainConfig, DrainPolicy, DrainReport, FaultCounters, OnDecodeError,
};
pub use pipeline::DrainPipeline;
pub use shard::{
    shard_bounds, LaneSite, RemoteShardLane, ShardLane, ShardPlacement, ShardRouter,
    ShardedAggregator, ThreadLane, WireSlice,
};
// Re-exported so coordinator users thread the decode buffer pool without
// reaching into `compress` (the pool type lives beside the codecs because
// `decode_pooled` is a codec method).
pub use crate::compress::{PoolStats, ScratchPool};
pub use pool::ClientPool;
pub use round::{RoundEngine, RoundPlan};
pub use transport::socket::{
    serve_shard_worker, ConfigFingerprint, ControlMsg, FleetLink, FleetServer, Listener, PlanWire,
    ShardLink, SocketAddrSpec, SocketConfig, SocketHub, SocketTransport, TransportKind,
};
pub use transport::{
    send_with_retry, ChannelTransport, ChaosTransport, FaultPlan, FaultVerdict, Payload,
    RecvOutcome, Transport, TransportSender, TransportStats, WireMessage,
};

/// Server-side decode→aggregate scheduling policy for one experiment.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PipelineMode {
    /// Seed behaviour: wait for the whole round, then decode and aggregate
    /// every update behind the barrier (O(K·d) server memory).
    Batch,
    /// Decode and absorb each update as it arrives; the server holds only
    /// the Beta posterior / score vector (O(d)).
    #[default]
    Streaming,
}

impl PipelineMode {
    pub fn as_str(self) -> &'static str {
        match self {
            PipelineMode::Batch => "batch",
            PipelineMode::Streaming => "streaming",
        }
    }

    /// Parse a CLI value (`--pipeline {batch,streaming}`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "batch" => Some(PipelineMode::Batch),
            "streaming" => Some(PipelineMode::Streaming),
            _ => None,
        }
    }

    /// The shared `--pipeline {batch,streaming}` CLI option (panics with
    /// the allowed values on anything else; defaults to streaming).
    pub fn from_args(args: &crate::util::cli::Args) -> Self {
        let v = args.choice("pipeline", &["batch", "streaming"], "streaming");
        Self::parse(v).expect("choice() already validated the value")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_mode_round_trips() {
        for m in [PipelineMode::Batch, PipelineMode::Streaming] {
            assert_eq!(PipelineMode::parse(m.as_str()), Some(m));
        }
        assert_eq!(PipelineMode::parse("turbo"), None);
        assert_eq!(PipelineMode::default(), PipelineMode::Streaming);
    }
}
