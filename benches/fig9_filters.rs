//! **Figure 9** — probabilistic-filter ablation inside the full FL loop:
//! binary fuse vs XOR filters at 8/16/32 bits-per-entry (accuracy + bpp),
//! CIFAR-100-sim, N=10, ρ=1.
//!
//!     cargo bench --bench fig9_filters [-- --full]
//!
//! Shape claims: BFuse beats XOR on bitrate at equal bpe with no accuracy
//! loss; bpe is the bitrate↔fidelity knob (lower bpe ⇒ lower bpp, more
//! false-positive mask noise).

use deltamask::bench::{BenchScale, Table};
use deltamask::fl::run_experiment;
use deltamask::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let scale = BenchScale::from_args(&args);

    let variants = [
        ("BFuse8", "deltamask"),
        ("BFuse16", "deltamask-bfuse16"),
        ("BFuse32", "deltamask-bfuse32"),
        ("Xor8", "deltamask-xor8"),
        ("Xor16", "deltamask-xor16"),
        ("Xor32", "deltamask-xor32"),
    ];
    let mut table = Table::new(
        "Figure 9: filter choice & bits-per-entry",
        &["filter", "acc", "avg bpp"],
    );
    for (label, method) in variants {
        let cfg = scale.config("cifar100", method);
        let res = run_experiment(&cfg)?;
        eprintln!("  {label}: acc={:.4} bpp={:.4}", res.final_accuracy(), res.avg_bpp());
        table.row(vec![
            label.to_string(),
            format!("{:.4}", res.final_accuracy()),
            format!("{:.4}", res.avg_bpp()),
        ]);
    }
    table.print();
    table.save("fig9_filters");
    Ok(())
}
