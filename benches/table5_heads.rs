//! **Table 5 / App. C.5** — classifier-head initialization ablation:
//! He (random frozen) vs FiT-LDA (data statistics) vs LP (one federated
//! linear-probing round).
//!
//!     cargo bench --bench table5_heads [-- --full]
//!
//! Shape claims: LP > FiT > He in accuracy at essentially the same bpp
//! (the head-init uplink is amortized into round 0).

use deltamask::bench::{bench_datasets, BenchScale, Table};
use deltamask::fl::{run_experiment, HeadInit};
use deltamask::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let scale = BenchScale::from_args(&args);
    let datasets = bench_datasets(&args);

    let mut table = Table::new(
        "Table 5: classifier-head initialization (DeltaMask)",
        &["variant", "dataset", "acc", "avg bpp"],
    );
    let mut summary = Table::new(
        "Table 5 summary",
        &["variant", "avg acc", "avg bpp"],
    );
    for (label, init) in [
        ("DeltaMask_He", HeadInit::He),
        ("DeltaMask_FiT", HeadInit::Fit),
        ("DeltaMask_LP", HeadInit::Lp),
    ] {
        let mut accs = Vec::new();
        let mut bpps = Vec::new();
        for dataset in &datasets {
            let mut cfg = scale.config(dataset, "deltamask");
            cfg.head_init = init;
            let res = run_experiment(&cfg)?;
            table.row(vec![
                label.to_string(),
                dataset.to_string(),
                format!("{:.4}", res.final_accuracy()),
                format!("{:.4}", res.avg_bpp()),
            ]);
            accs.push(res.final_accuracy());
            bpps.push(res.avg_bpp());
            eprintln!("  {label}/{dataset}: acc={:.4}", res.final_accuracy());
        }
        summary.row(vec![
            label.to_string(),
            format!("{:.4}", deltamask::util::stats::mean(&accs)),
            format!("{:.4}", deltamask::util::stats::mean(&bpps)),
        ]);
    }
    table.print();
    summary.print();
    table.save("table5_heads");
    summary.save("table5_heads_summary");
    Ok(())
}
