//! Multi-process experiment entry points: `deltamask serve` hosts the
//! coordinator half of an experiment on a TCP or Unix-domain socket,
//! `deltamask client-fleet` connects the training half to it, and
//! `deltamask shard-worker` hosts remote absorb lanes that a coordinator's
//! `--shard-place` routes dimension shards to.
//!
//! Both processes are launched with the **same** `ExperimentConfig`
//! (dataset, seed, rounds, knobs): data generation, parameter init and
//! head initialization are deterministic in the config, so the two
//! processes reconstruct identical state without ever shipping weights —
//! only plans (θ_g, s_g, participants) and encoded mask updates cross the
//! wire. A [`ConfigFingerprint`] in the fleet's `Hello` frames catches
//! mismatched launches at connect time instead of as a silently divergent
//! trajectory.
//!
//! The round loop itself is [`Runner::serve_codec`] /
//! [`Runner::fleet_loop`]; this module only owns address parsing, backend
//! construction and the socket handshake.

use super::{ExperimentConfig, ExperimentResult, Runner};
use crate::compress::UpdateCodec;
use crate::coordinator::{
    serve_shard_worker, ConfigFingerprint, FleetLink, FleetServer, Listener, SocketAddrSpec,
    SocketConfig, TransportKind,
};
use crate::fl::server::MaskServer;
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;
use std::time::Duration;

/// How long `client-fleet` keeps retrying its first connection, covering
/// the serve process still binding its listener.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(30);

/// The config facts every process must agree on for lockstep trajectories
/// (checked at the fleet and shard-hello handshakes; everything else
/// diverges loudly later via the plan/update frames themselves).
fn fingerprint(cfg: &ExperimentConfig) -> ConfigFingerprint {
    cfg.fingerprint()
}

/// Resolve the experiment's update codec. The weight-space baselines
/// (`fine_tuning` / `linear_probing`) never touch a transport, so serving
/// them remotely is a config error, not a silent in-process fallback.
fn codec_for(cfg: &ExperimentConfig) -> Result<Arc<dyn UpdateCodec>> {
    match cfg.method.as_str() {
        "fine_tuning" | "linear_probing" => {
            bail!(
                "method '{}' is a weight-space baseline and runs in-process only",
                cfg.method
            )
        }
        name => Ok(Arc::from(
            crate::compress::by_name(name).ok_or_else(|| anyhow!("unknown method '{name}'"))?,
        )),
    }
}

/// The socket address for a remote run; `--transport channel` has none.
fn addr_spec(cfg: &ExperimentConfig, addr: &str) -> Result<SocketAddrSpec> {
    if cfg.transport == TransportKind::Channel {
        bail!("serve/client-fleet need --transport tcp or --transport uds");
    }
    SocketAddrSpec::parse(cfg.transport, addr)
}

/// Host the coordinator half of an experiment: bind `listen`, wait for a
/// client fleet whose config fingerprint matches, then run every round —
/// plan broadcast, socket drain, aggregation, metrics — exactly as the
/// in-process path would, and return the same [`ExperimentResult`].
pub fn serve_experiment(cfg: &ExperimentConfig, listen: &str) -> Result<ExperimentResult> {
    let spec = addr_spec(cfg, listen)?;
    let codec = codec_for(cfg)?;
    let scfg = SocketConfig::from_env();
    let listener = Listener::bind(&spec)?;
    // The bound spec, not the requested one: `tcp://127.0.0.1:0` resolves
    // to a real port here.
    eprintln!("[serve] listening on {}", listener.local_spec()?);
    let mut fleet = FleetServer::accept_fleet(&listener, scfg, fingerprint(cfg))?;
    eprintln!("[serve] fleet connected, running {} rounds", cfg.rounds);

    let result = super::with_backend(cfg, |backend| {
        let mut runner = Runner::new(cfg, backend)?;
        runner.serve_codec(codec, &mut fleet)
    });
    // A UDS listener leaves its socket file behind; reclaim it so reruns
    // bind cleanly even after an error.
    if let SocketAddrSpec::Uds(path) = &spec {
        let _ = std::fs::remove_file(path);
    }
    result
}

/// Host remote absorb lanes: bind `listen` and serve shard-worker
/// sessions against [`MaskServer`] slices. Each session begins with a
/// shard-hello carrying the coordinator's config fingerprint (rejected on
/// mismatch) plus the shard's dimension bounds and serialized aggregation
/// slice; the worker then drains record splits into it round by round and
/// returns the refreshed slice at every finish/abort. With `linger` the
/// worker accepts further sessions after a coordinator shuts down instead
/// of exiting — how the CI matrix shares one worker pair across suites.
pub fn run_shard_worker(cfg: &ExperimentConfig, listen: &str, linger: bool) -> Result<()> {
    let spec = addr_spec(cfg, listen)?;
    let scfg = SocketConfig::from_env();
    let listener = Listener::bind(&spec)?;
    eprintln!("[shard-worker] listening on {}", listener.local_spec()?);
    let result = serve_shard_worker::<MaskServer>(&listener, scfg, fingerprint(cfg), linger);
    // A UDS listener leaves its socket file behind; reclaim it so reruns
    // bind cleanly even after an error.
    if let SocketAddrSpec::Uds(path) = &spec {
        let _ = std::fs::remove_file(path);
    }
    result
}

/// Run the training half of an experiment: dial the coordinator at
/// `connect` over `conns` multiplexed OS connections (retrying until it
/// binds), then follow its control stream until shutdown.
pub fn run_client_fleet(cfg: &ExperimentConfig, connect: &str, conns: usize) -> Result<()> {
    let spec = addr_spec(cfg, connect)?;
    let codec = codec_for(cfg)?;
    let scfg = SocketConfig::from_env();
    let mut link = FleetLink::connect(&spec, conns, fingerprint(cfg), scfg, CONNECT_TIMEOUT)?;
    eprintln!(
        "[fleet] connected to {spec} with {} connection(s), {} clients",
        conns.max(1),
        cfg.n_clients
    );
    super::with_backend(cfg, |backend| {
        let mut runner = Runner::new(cfg, backend)?;
        runner.fleet_loop(codec, &mut link)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baselines_are_refused_a_socket() {
        let cfg = ExperimentConfig {
            method: "fine_tuning".into(),
            ..Default::default()
        };
        assert!(codec_for(&cfg).is_err());
    }

    #[test]
    fn channel_transport_has_no_address() {
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.transport, TransportKind::Channel);
        assert!(addr_spec(&cfg, "127.0.0.1:0").is_err());
    }

    #[test]
    fn fingerprint_tracks_the_config() {
        let a = ExperimentConfig::default();
        let mut b = ExperimentConfig::default();
        assert_eq!(fingerprint(&a), fingerprint(&b));
        b.seed ^= 1;
        assert_ne!(fingerprint(&a), fingerprint(&b));
    }
}
