//! Federated comparison scenario: the paper's core story on one dataset —
//! DeltaMask matches FedPM's accuracy at a fraction of the bitrate, with
//! Linear Probing / Fine-tuning as the anchor baselines (Fig. 3 slice).
//!
//!     cargo run --release --example federated_sim -- [--dataset svhn]
//!         [--rounds 30] [--clients 8] [--noniid] [--backend xla]

use deltamask::bench::Table;
use deltamask::fl::{knobs, run_experiment, BackendKind, ExperimentConfig, HeadInit};
use deltamask::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let dataset = args.get_or("dataset", "cifar10").to_string();
    let noniid = args.flag("noniid");
    // Env-resolved tuning/transport defaults (the fl::knobs table), the
    // scenario's experiment shape on top, then any CLI knob spellings.
    let mut base = ExperimentConfig {
        dataset: dataset.clone(),
        arch: "test".into(),
        method: String::new(),
        n_clients: args.usize("clients", 8),
        rounds: args.usize("rounds", 30),
        rho: if noniid { 0.5 } else { 1.0 },
        local_epochs: 1,
        samples_per_client: args.usize("samples", 48),
        test_samples: 400,
        dirichlet_alpha: if noniid { 0.1 } else { 10.0 },
        kappa0: 0.8,
        kappa_floor: 0.25,
        seed: args.u64("seed", 7),
        eval_every: 5,
        backend: if args.get_or("backend", "native") == "xla" {
            BackendKind::Xla
        } else {
            BackendKind::Native
        },
        head_init: HeadInit::Lp,
        lp_rounds: 1,
        theta0: 0.85,
        arch_override: None,
        ..ExperimentConfig::default()
    };
    knobs::apply_cli(&mut base, &args);

    let split = if noniid { "non-IID Dir(0.1)" } else { "IID Dir(10)" };
    println!("dataset={dataset} split={split} N={} R={}", base.n_clients, base.rounds);

    let mut table = Table::new(
        &format!("{dataset} ({split})"),
        &["method", "final acc", "peak acc", "avg bpp", "uplink MiB", "enc ms", "dec ms"],
    );
    for method in [
        "linear_probing",
        "fine_tuning",
        "fedpm",
        "deltamask",
        "fedmask",
        "deepreduce",
        "eden",
    ] {
        let mut cfg = base.clone();
        cfg.method = method.into();
        let res = run_experiment(&cfg)?;
        table.row(vec![
            method.to_string(),
            format!("{:.3}", res.final_accuracy()),
            format!("{:.3}", res.peak_accuracy()),
            format!("{:.3}", res.avg_bpp()),
            format!("{:.2}", res.total_uplink_mib()),
            format!("{:.2}", res.mean_enc_ms()),
            format!("{:.2}", res.mean_dec_ms()),
        ]);
        eprintln!("  done: {method}");
    }
    table.print();
    Ok(())
}
