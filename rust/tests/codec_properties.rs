//! Property-style integration tests over the coding substrates: randomized
//! roundtrips across codec layers (DEFLATE ↔ flate2, PNG, arithmetic coder,
//! filters, update codecs) with seed sweeps — the "fuzz-lite" suite.

use deltamask::codec::{arith, deflate, png};
use deltamask::compress::{self, DecodeCtx, EncodeCtx, Update, UpdateCodec};
use deltamask::filters::{BinaryFuse, MembershipFilter};
use deltamask::model::sample_mask_seeded;
use deltamask::util::rng::Xoshiro256pp;

/// Generator for adversarial byte distributions (this is what shook out the
/// Huffman length-limit repair bug).
fn gen_payload(rng: &mut Xoshiro256pp, mode: u64, n: usize) -> Vec<u8> {
    (0..n)
        .map(|i| match mode % 6 {
            0 => rng.next_u64() as u8,                         // uniform
            1 => (rng.next_u64() % 3) as u8,                   // tiny alphabet
            2 => {
                // geometric-ish skew
                let u = rng.next_f32();
                (-(1.0 - u).ln() * 6.0) as u8
            }
            3 => (i % 251) as u8,                              // periodic
            4 => {
                if rng.next_f32() < 0.95 { 0 } else { rng.next_u64() as u8 }
            }
            _ => ((i / 64) % 256) as u8,                       // long runs
        })
        .collect()
}

#[test]
fn deflate_roundtrip_seed_sweep() {
    let mut rng = Xoshiro256pp::new(0xd3f1a7e);
    for trial in 0..120 {
        let n = (rng.next_u64() % 60_000) as usize;
        let data = gen_payload(&mut rng, trial, n);
        let z = deflate::zlib_compress(&data);
        let back = deflate::zlib_decompress(&z)
            .unwrap_or_else(|e| panic!("trial {trial} n={n}: {e}"));
        assert_eq!(back, data, "trial {trial}");
        // flate2 must also accept our stream (RFC conformance). The
        // cross-check needs the optional `flate2` feature; offline default
        // builds still run the self-roundtrip above.
        #[cfg(feature = "flate2")]
        {
            use std::io::Read;
            let mut dec = flate2::read::ZlibDecoder::new(&z[..]);
            let mut back2 = Vec::new();
            dec.read_to_end(&mut back2)
                .unwrap_or_else(|e| panic!("trial {trial}: flate2 rejected: {e}"));
            assert_eq!(back2, data);
        }
    }
}

#[test]
fn png_roundtrip_seed_sweep() {
    let mut rng = Xoshiro256pp::new(0x9b6);
    for trial in 0..60 {
        let n = 1 + (rng.next_u64() % 50_000) as usize;
        let payload = gen_payload(&mut rng, trial, n);
        let img = png::GrayImage::from_payload(&payload);
        let back = png::decode(&png::encode(&img)).unwrap();
        assert_eq!(back.payload(n), &payload[..], "trial {trial}");
    }
}

#[test]
fn arith_roundtrip_seed_sweep() {
    let mut rng = Xoshiro256pp::new(0xa417);
    for trial in 0..40 {
        let n = (rng.next_u64() % 30_000) as usize;
        let p = rng.next_f32();
        let bits: Vec<bool> = (0..n).map(|_| rng.next_f32() < p).collect();
        let enc = arith::encode_bits(&bits);
        assert_eq!(arith::decode_bits(&enc, n), bits, "trial {trial} p={p}");
    }
}

#[test]
fn every_codec_roundtrips_through_full_pipeline() {
    let d = 20_000usize;
    let mut rng = Xoshiro256pp::new(0xc0dec);
    let theta_g: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
    let theta_k: Vec<f32> = theta_g
        .iter()
        .map(|&p| (p + 0.1 * (rng.next_f32() - 0.5)).clamp(0.01, 0.99))
        .collect();
    let s_g: Vec<f32> = theta_g.iter().map(|&p| (p / (1.0 - p)).ln()).collect();
    let s_k: Vec<f32> = theta_k.iter().map(|&p| (p / (1.0 - p)).ln()).collect();
    let round_seed = 1234u64;
    let mut mask_g = Vec::new();
    sample_mask_seeded(&theta_g, round_seed, &mut mask_g);
    let mut mask_k = Vec::new();
    sample_mask_seeded(&theta_k, round_seed, &mut mask_k);

    for name in compress::all_names() {
        let codec = compress::by_name(name).unwrap();
        let ctx = EncodeCtx {
            d,
            theta_k: &theta_k,
            theta_g: &theta_g,
            mask_k: &mask_k,
            mask_g: &mask_g,
            s_k: &s_k,
            s_g: &s_g,
            kappa: 0.8,
            seed: 42,
        };
        let enc = codec.encode(&ctx).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(enc.bpp(d) > 0.0, "{name}");
        let dctx = DecodeCtx {
            d,
            mask_g: &mask_g,
            s_g: &s_g,
            seed: 42,
        };
        let upd = codec
            .decode(&enc.bytes, &dctx)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        match upd {
            Update::Mask(m) => {
                assert_eq!(m.len(), d, "{name}");
                assert!(m.iter().all(|&v| v == 0.0 || v == 1.0), "{name}");
            }
            Update::ScoreDelta(ds) => {
                assert_eq!(ds.len(), d, "{name}");
                assert!(ds.iter().all(|v| v.is_finite()), "{name}");
                // Decoded delta must correlate positively with the truth.
                let truth: Vec<f32> = (0..d).map(|i| s_k[i] - s_g[i]).collect();
                let dot: f64 = ds.iter().zip(&truth).map(|(a, b)| (a * b) as f64).sum();
                assert!(dot > 0.0, "{name}: decoded delta anti-correlated");
            }
        }
    }
}

#[test]
fn corrupted_records_error_not_panic() {
    let d = 5_000usize;
    let mut rng = Xoshiro256pp::new(3);
    let theta: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
    let s: Vec<f32> = theta.iter().map(|&p| (p / (1.0 - p)).ln()).collect();
    let mut mask = Vec::new();
    sample_mask_seeded(&theta, 7, &mut mask);
    let mut mask_k = mask.clone();
    for i in 0..100 {
        mask_k[i * 7 % d] = 1.0 - mask_k[i * 7 % d];
    }
    for name in compress::all_names() {
        let codec = compress::by_name(name).unwrap();
        let ctx = EncodeCtx {
            d,
            theta_k: &theta,
            theta_g: &theta,
            mask_k: &mask_k,
            mask_g: &mask,
            s_k: &s,
            s_g: &s,
            kappa: 0.8,
            seed: 9,
        };
        let enc = codec.encode(&ctx).unwrap();
        let dctx = DecodeCtx {
            d,
            mask_g: &mask,
            s_g: &s,
            seed: 9,
        };
        // Truncations must produce Err, never panic.
        for cut in [0usize, 1, 5, enc.bytes.len() / 2] {
            let truncated = &enc.bytes[..cut.min(enc.bytes.len().saturating_sub(1))];
            let _ = codec.decode(truncated, &dctx);
        }
        // Bit-flipped body: either errors or yields a well-formed update.
        let mut corrupt = enc.bytes.clone();
        if corrupt.len() > 40 {
            let n = corrupt.len();
            corrupt[n - 10] ^= 0xff;
            match codec.decode(&corrupt, &dctx) {
                Err(_) => {}
                Ok(Update::Mask(m)) => assert_eq!(m.len(), d),
                Ok(Update::ScoreDelta(v)) => assert_eq!(v.len(), d),
            }
        }
    }
}

#[test]
fn decode_is_total_for_every_codec() {
    // Property: `decode` is a *total* function over byte strings — for every
    // registered codec it returns `Ok` (a well-formed d-length update) or `Err`, and
    // never panics or over-reads, on (a) every truncation prefix of a valid
    // record, (b) single-bit corruptions throughout the record, and (c)
    // entirely random byte strings. A panic anywhere aborts this test, so
    // completing it *is* the property.
    let d = 2_000usize;
    let mut rng = Xoshiro256pp::new(0x70741);
    let theta_g: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
    let theta_k: Vec<f32> = theta_g
        .iter()
        .map(|&p| (p + 0.2 * (rng.next_f32() - 0.5)).clamp(0.01, 0.99))
        .collect();
    let s_g: Vec<f32> = theta_g.iter().map(|&p| (p / (1.0 - p)).ln()).collect();
    let s_k: Vec<f32> = theta_k.iter().map(|&p| (p / (1.0 - p)).ln()).collect();
    let mut mask_g = Vec::new();
    sample_mask_seeded(&theta_g, 5, &mut mask_g);
    let mut mask_k = Vec::new();
    sample_mask_seeded(&theta_k, 5, &mut mask_k);

    let check = |codec: &dyn deltamask::compress::UpdateCodec, bytes: &[u8], what: &str| {
        let dctx = DecodeCtx {
            d,
            mask_g: &mask_g,
            s_g: &s_g,
            seed: 21,
        };
        match codec.decode(bytes, &dctx) {
            Err(_) => {}
            Ok(Update::Mask(m)) => {
                assert_eq!(m.len(), d, "{}: {what}", codec.name());
                assert!(
                    m.iter().all(|&v| v == 0.0 || v == 1.0),
                    "{}: {what}",
                    codec.name()
                );
            }
            Ok(Update::ScoreDelta(v)) => assert_eq!(v.len(), d, "{}: {what}", codec.name()),
        }
    };

    for name in compress::all_names() {
        let codec = compress::by_name(name).unwrap();
        let ctx = EncodeCtx {
            d,
            theta_k: &theta_k,
            theta_g: &theta_g,
            mask_k: &mask_k,
            mask_g: &mask_g,
            s_k: &s_k,
            s_g: &s_g,
            kappa: 0.7,
            seed: 21,
        };
        let enc = codec.encode(&ctx).unwrap();
        let len = enc.bytes.len();

        // (a) Every truncation prefix (strided once records get long).
        let stride = (len / 64).max(1);
        for cut in (0..len).step_by(stride) {
            check(codec.as_ref(), &enc.bytes[..cut], "truncation");
        }
        // (b) Single-bit flips: every bit of the header region, then strided
        // positions through the payload.
        for pos in 0..len.min(34) {
            for bit in 0..8 {
                let mut bad = enc.bytes.clone();
                bad[pos] ^= 1 << bit;
                check(codec.as_ref(), &bad, "bit flip");
            }
        }
        for pos in (34..len).step_by(stride) {
            let mut bad = enc.bytes.clone();
            bad[pos] ^= 0x80;
            check(codec.as_ref(), &bad, "payload flip");
        }
        // (c) Random byte strings, including ones that spoof the real
        // header prefix.
        for trial in 0..30 {
            let rlen = (rng.next_u64() % (len as u64 + 64)) as usize;
            let mut junk: Vec<u8> = (0..rlen).map(|_| rng.next_u64() as u8).collect();
            if trial % 2 == 0 {
                let keep = junk.len().min(enc.bytes.len()).min(12);
                junk[..keep].copy_from_slice(&enc.bytes[..keep]);
            }
            check(codec.as_ref(), &junk, "random bytes");
        }
    }
}

#[test]
fn pco_stream_roundtrips_and_decode_is_total() {
    // The codec-9 numeric-latent substrate: every u32 sequence roundtrips
    // bit-exactly, and `decompress_u32s` is total — truncations, bit flips,
    // and random byte strings return `Err` (or a within-limit `Ok`), never
    // panic or over-allocate past `max_count`.
    use deltamask::codec::pco;

    let mut rng = Xoshiro256pp::new(0x9c05);
    for trial in 0..60u64 {
        let n = (rng.next_u64() % 2_500) as usize;
        let vals: Vec<u32> = match trial % 5 {
            0 => (0..n).map(|_| rng.next_u32()).collect(), // incompressible
            1 => {
                // sorted index sets — the deltamask-pco payload shape
                let mut v: Vec<u32> =
                    (0..n).map(|_| (rng.next_u64() % 200_000) as u32).collect();
                v.sort_unstable();
                v.dedup();
                v
            }
            2 => (0..n as u32).map(|i| 17 + 3 * i).collect(), // pure ramp
            3 => vec![123_456; n],                            // constant
            _ => {
                let step = 1 + (rng.next_u64() % 997) as u32;
                (0..n as u32)
                    .map(|i| i.wrapping_mul(step) ^ (rng.next_u32() & 7))
                    .collect() // jittered ramp
            }
        };
        let z = pco::compress_u32s(&vals);
        let back = pco::decompress_u32s(&z, vals.len())
            .unwrap_or_else(|e| panic!("trial {trial}: {e}"));
        assert_eq!(back, vals, "trial {trial}");
        if !vals.is_empty() {
            assert!(
                pco::decompress_u32s(&z, vals.len() - 1).is_err(),
                "trial {trial}: max_count must be enforced"
            );
        }

        let total = |bytes: &[u8], what: &str| match pco::decompress_u32s(bytes, vals.len()) {
            Ok(v) => assert!(v.len() <= vals.len(), "trial {trial}: {what}"),
            Err(_) => {}
        };
        let stride = (z.len() / 32).max(1);
        for cut in (0..z.len()).step_by(stride) {
            total(&z[..cut], "truncation");
        }
        for pos in (0..z.len()).step_by(stride) {
            for bit in [0u8, 3, 7] {
                let mut bad = z.clone();
                bad[pos] ^= 1 << bit;
                total(&bad, "bit flip");
            }
        }
    }
    for _ in 0..200 {
        let n = (rng.next_u64() % 300) as usize;
        let junk: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        match pco::decompress_u32s(&junk, 10_000) {
            Ok(v) => assert!(v.len() <= 10_000),
            Err(_) => {}
        }
    }
}

#[test]
fn wire_tags_pin_codec_9_and_payload_backends() {
    // Wire identity: the v1 record layout must stay byte-stable (byte 0 =
    // filter tag, byte 1 = payload backend tag where PNG==1 matches the old
    // `use_png` boolean), and the codec-9 record must announce itself with
    // tag 7 — one past the v1 filter-tag space — so old decoders bail with
    // an error instead of misreading it.
    use deltamask::compress::{DeltaMaskCodec, DeltaMaskPcoCodec, PayloadBackend, UpdateCodec};

    let d = 4_000usize;
    let mut rng = Xoshiro256pp::new(0x7a95);
    let theta: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
    let mut mask_g = Vec::new();
    sample_mask_seeded(&theta, 3, &mut mask_g);
    let mut mask_k = mask_g.clone();
    for i in 0..80 {
        mask_k[(i * 31) % d] = 1.0 - mask_k[(i * 31) % d];
    }
    let ctx = EncodeCtx {
        d,
        theta_k: &theta,
        theta_g: &theta,
        mask_k: &mask_k,
        mask_g: &mask_g,
        s_k: &[],
        s_g: &[],
        kappa: 0.8,
        seed: 11,
    };
    let dctx = DecodeCtx {
        d,
        mask_g: &mask_g,
        s_g: &[],
        seed: 11,
    };

    let png_rec = DeltaMaskCodec::default().encode(&ctx).unwrap().bytes;
    assert_eq!(png_rec[0], 0, "default filter tag (bfuse8)");
    assert_eq!(png_rec[1], 1, "PNG backend keeps v1's use_png=true byte");
    let raw_rec = DeltaMaskCodec { payload: PayloadBackend::Raw, ..Default::default() }
        .encode(&ctx)
        .unwrap()
        .bytes;
    assert_eq!(raw_rec[1], 0, "raw backend keeps v1's use_png=false byte");
    let fast_rec = DeltaMaskCodec { payload: PayloadBackend::PngFast, ..Default::default() }
        .encode(&ctx)
        .unwrap()
        .bytes;
    assert_eq!(fast_rec[1], 2, "fast backend claims the first new tag");

    let pco_rec = DeltaMaskPcoCodec::default().encode(&ctx).unwrap().bytes;
    assert_eq!(pco_rec[0], 7, "codec-9 record tag");
    assert_eq!(pco_rec[1], 1, "pco stream version");
    assert!(
        DeltaMaskCodec::default().decode(&pco_rec, &dctx).is_err(),
        "a v1 filter decoder must reject the codec-9 record"
    );

    // All three backends and the pco record describe the same mask.
    let want = match DeltaMaskCodec::default().decode(&png_rec, &dctx).unwrap() {
        Update::Mask(m) => m,
        _ => panic!(),
    };
    for bytes in [&raw_rec, &fast_rec] {
        match DeltaMaskCodec::default().decode(bytes, &dctx).unwrap() {
            Update::Mask(m) => assert_eq!(m, want),
            _ => panic!(),
        }
    }
}

#[test]
fn registry_count_is_pinned() {
    // The single place the codec count lives. Every suite iterates
    // `all_names()`, so a new codec enters the whole property matrix by
    // registry growth alone — only this assertion changes when one lands.
    assert_eq!(compress::all_names().len(), 11);
}

#[test]
fn sibling_wire_tags_are_pinned_and_disjoint() {
    // Wire identity for the sibling-paper codecs: maskrn announces tag 8
    // and sparse-rsn tag 9 — both outside the v1 filter-tag space (0..=6)
    // and distinct from the codec-9 pco tag (7) — so every earlier decoder
    // rejects the new records with an error instead of misreading them,
    // and vice versa. These bytes are the compatibility contract; changing
    // them orphans recorded wire traffic.
    use deltamask::compress::{
        deltamask_pco, maskrn, sparse_rsn, DeltaMaskCodec, DeltaMaskPcoCodec, UpdateCodec,
    };

    assert_eq!(maskrn::RECORD_TAG, 8);
    assert_eq!(maskrn::RECORD_VERSION, 1);
    assert_eq!(sparse_rsn::RECORD_TAG, 9);
    assert_eq!(sparse_rsn::RECORD_VERSION, 1);
    let v1_filter_tags = 0u8..=6;
    let taken = [deltamask_pco::RECORD_TAG, maskrn::RECORD_TAG, sparse_rsn::RECORD_TAG];
    for tag in taken {
        assert!(!v1_filter_tags.contains(&tag), "tag {tag} collides with v1");
    }
    assert_eq!(taken.iter().collect::<std::collections::HashSet<_>>().len(), 3);

    let d = 4_000usize;
    let mut rng = Xoshiro256pp::new(0x51b);
    let theta: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
    let mut mask_g = Vec::new();
    sample_mask_seeded(&theta, 3, &mut mask_g);
    let mut mask_k = mask_g.clone();
    for i in 0..80 {
        mask_k[(i * 31) % d] = 1.0 - mask_k[(i * 31) % d];
    }
    let ctx = EncodeCtx {
        d,
        theta_k: &theta,
        theta_g: &theta,
        mask_k: &mask_k,
        mask_g: &mask_g,
        s_k: &[],
        s_g: &[],
        kappa: 0.8,
        seed: 11,
    };
    let dctx = DecodeCtx {
        d,
        mask_g: &mask_g,
        s_g: &[],
        seed: 11,
    };

    let mrn_rec = compress::by_name("maskrn").unwrap().encode(&ctx).unwrap().bytes;
    assert_eq!(mrn_rec[0], 8, "codec-10 record tag");
    assert_eq!(mrn_rec[1], 1, "maskrn record version");
    let rsn_rec = compress::by_name("sparse-rsn").unwrap().encode(&ctx).unwrap().bytes;
    assert_eq!(rsn_rec[0], 9, "codec-11 record tag");
    assert_eq!(rsn_rec[1], 1, "sparse-rsn record version");
    assert!(rsn_rec[2] <= 1, "polarity byte");

    // Cross-rejection: every decoder bails on the other codecs' records.
    let v1 = DeltaMaskCodec::default();
    let pco = DeltaMaskPcoCodec::default();
    let mrn = compress::by_name("maskrn").unwrap();
    let rsn = compress::by_name("sparse-rsn").unwrap();
    for rec in [&mrn_rec, &rsn_rec] {
        assert!(v1.decode(rec, &dctx).is_err(), "v1 must reject sibling records");
        assert!(pco.decode(rec, &dctx).is_err(), "codec 9 must reject sibling records");
    }
    assert!(mrn.decode(&rsn_rec, &dctx).is_err());
    assert!(rsn.decode(&mrn_rec, &dctx).is_err());
    let pco_rec = pco.encode(&ctx).unwrap().bytes;
    assert!(mrn.decode(&pco_rec, &dctx).is_err());
    assert!(rsn.decode(&pco_rec, &dctx).is_err());
}

#[test]
fn bfuse_payload_survives_png_stage_bit_exact() {
    // The exact DeltaMask §3.2 path at ViT-B/32 scale.
    let d = 327_680u64;
    let mut rng = Xoshiro256pp::new(0xf00d);
    let keys: Vec<u64> = (0..6_000).map(|_| rng.below(d)).collect();
    let f = BinaryFuse::<u8, 4>::build(&keys).unwrap();
    let payload = f.payload();
    let img = png::GrayImage::from_payload(&payload);
    let back = png::decode(&png::encode(&img)).unwrap();
    assert_eq!(back.payload(payload.len()), &payload[..]);
    let g = BinaryFuse::<u8, 4>::from_parts(
        f.seed(),
        f.segment_length_pub(),
        f.segment_count_length_pub(),
        back.payload(payload.len()),
        f.num_keys(),
    );
    for &k in &keys {
        assert!(g.contains(k));
    }
}
