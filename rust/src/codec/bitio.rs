//! LSB-first bit readers/writers as used by DEFLATE (RFC 1951 §3.1.1):
//! data elements are packed starting from the least-significant bit of each
//! byte; Huffman codes are packed most-significant-code-bit first, which the
//! caller handles by reversing code bits.
//!
//! Both directions move whole words instead of bytes on the hot path: the
//! writer flushes 32 bits at a time out of a 64-bit accumulator and the
//! reader refills its 64-bit buffer with a single unaligned `u64` load
//! (the branchless refill keeps ≥ 56 valid bits while input remains). The
//! byte stream produced/consumed is bit-for-bit identical to the scalar
//! byte-loop formulation, which the tests keep as an oracle.

/// Maximum width `peek_bits` is guaranteed to return correctly. The refill
/// keeps at least 56 valid buffered bits while input remains, but the `u32`
/// return narrows the reliable contract to 32 bits; wider peeks used to
/// silently truncate, now they trip a `debug_assert`.
pub const MAX_PEEK_BITS: u32 = 32;

#[derive(Debug, Default)]
pub struct BitWriter {
    out: Vec<u8>,
    bitbuf: u64,
    bitcount: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the low `n` bits of `value`, LSB-first. Flushes the
    /// accumulator a word (4 bytes) at a time; the invariant is
    /// `bitcount < 32` between calls, so `value` always fits.
    #[inline]
    pub fn write_bits(&mut self, value: u32, n: u32) {
        debug_assert!(n <= 32);
        debug_assert!(n == 32 || value < (1u32 << n));
        self.bitbuf |= (value as u64) << self.bitcount;
        self.bitcount += n;
        if self.bitcount >= 32 {
            self.out.extend_from_slice(&(self.bitbuf as u32).to_le_bytes());
            self.bitbuf >>= 32;
            self.bitcount -= 32;
        }
    }

    /// Pad to a byte boundary with zero bits.
    pub fn align_byte(&mut self) {
        while self.bitcount > 0 {
            self.out.push(self.bitbuf as u8);
            self.bitbuf >>= 8;
            self.bitcount = self.bitcount.saturating_sub(8);
        }
        self.bitbuf = 0;
    }

    pub fn write_bytes(&mut self, bytes: &[u8]) {
        // The 32-bit accumulator can legitimately hold whole byte-aligned
        // bytes (the old byte-loop writer never did) — drain them first so
        // "byte-aligned" keeps meaning what callers expect.
        debug_assert_eq!(self.bitcount % 8, 0, "write_bytes requires byte alignment");
        while self.bitcount >= 8 {
            self.out.push(self.bitbuf as u8);
            self.bitbuf >>= 8;
            self.bitcount -= 8;
        }
        self.out.extend_from_slice(bytes);
    }

    pub fn finish(mut self) -> Vec<u8> {
        self.align_byte();
        self.out
    }

    pub fn bit_len(&self) -> usize {
        self.out.len() * 8 + self.bitcount as usize
    }
}

#[derive(Debug)]
pub struct BitReader<'a> {
    data: &'a [u8],
    pos: usize,
    bitbuf: u64,
    bitcount: u32,
}

impl<'a> BitReader<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        Self {
            data,
            pos: 0,
            bitbuf: 0,
            bitcount: 0,
        }
    }

    /// Branchless word refill (one unaligned `u64` load per call on the hot
    /// path): after it returns, at least 56 bits are buffered while input
    /// remains. Bits beyond `bitcount` already hold the correct upcoming
    /// stream bytes, so re-OR-ing them on the next refill is idempotent.
    #[inline]
    fn refill(&mut self) {
        if self.bitcount < 57 && self.pos + 8 <= self.data.len() {
            let w = u64::from_le_bytes(self.data[self.pos..self.pos + 8].try_into().unwrap());
            self.bitbuf |= w << self.bitcount;
            let consumed = (63 - self.bitcount) >> 3;
            self.pos += consumed as usize;
            self.bitcount += consumed * 8;
        } else {
            // Tail: byte-at-a-time once fewer than 8 input bytes remain.
            while self.bitcount <= 56 && self.pos < self.data.len() {
                self.bitbuf |= (self.data[self.pos] as u64) << self.bitcount;
                self.pos += 1;
                self.bitcount += 8;
            }
        }
    }

    /// Read `n` bits LSB-first. Reading past the end returns zero bits
    /// (callers detect truncation at a higher level).
    #[inline]
    pub fn read_bits(&mut self, n: u32) -> u32 {
        debug_assert!(n <= 32);
        if n == 0 {
            return 0;
        }
        self.refill();
        let v = (self.bitbuf & ((1u64 << n) - 1)) as u32;
        self.bitbuf >>= n;
        self.bitcount = self.bitcount.saturating_sub(n);
        v
    }

    /// Peek up to [`MAX_PEEK_BITS`] bits without consuming.
    #[inline]
    pub fn peek_bits(&mut self, n: u32) -> u32 {
        debug_assert!(
            n <= MAX_PEEK_BITS,
            "peek width {n} exceeds MAX_PEEK_BITS ({MAX_PEEK_BITS})"
        );
        self.refill();
        (self.bitbuf & ((1u64 << n) - 1)) as u32
    }

    #[inline]
    pub fn consume(&mut self, n: u32) {
        self.bitbuf >>= n;
        self.bitcount = self.bitcount.saturating_sub(n);
    }

    pub fn align_byte(&mut self) {
        let drop = self.bitcount % 8;
        self.consume(drop);
    }

    /// Copy `n` bytes after byte alignment: drains whole bytes buffered in
    /// the accumulator, then bulk-copies the rest straight from the input.
    pub fn read_bytes(&mut self, n: usize) -> Option<Vec<u8>> {
        debug_assert_eq!(self.bitcount % 8, 0);
        let mut out = Vec::with_capacity(n);
        while out.len() < n && self.bitcount >= 8 {
            out.push(self.bitbuf as u8);
            self.bitbuf >>= 8;
            self.bitcount -= 8;
        }
        let rest = n - out.len();
        if rest > 0 {
            if self.pos + rest > self.data.len() {
                return None;
            }
            // The word refill leaves replica bytes above `bitcount` (they
            // normally get re-OR-ed idempotently). Bulk-copying advances
            // `pos` past their source bytes, so zero them or the next
            // refill would OR fresh input over stale data. `bitcount < 8`
            // here (the drain loop ran dry), so the shift is in range.
            self.bitbuf &= (1u64 << self.bitcount) - 1;
            out.extend_from_slice(&self.data[self.pos..self.pos + rest]);
            self.pos += rest;
        }
        Some(out)
    }

    /// True if all input has been consumed (ignoring sub-byte padding).
    pub fn exhausted(&mut self) -> bool {
        self.pos >= self.data.len() && self.bitcount < 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    /// Scalar byte-loop reader — the seed's refill, kept as the parity
    /// oracle for the word-at-a-time fast path.
    struct OracleReader<'a> {
        data: &'a [u8],
        pos: usize,
        bitbuf: u64,
        bitcount: u32,
    }

    impl<'a> OracleReader<'a> {
        fn new(data: &'a [u8]) -> Self {
            Self {
                data,
                pos: 0,
                bitbuf: 0,
                bitcount: 0,
            }
        }

        fn read_bits(&mut self, n: u32) -> u32 {
            if n == 0 {
                return 0;
            }
            while self.bitcount <= 56 && self.pos < self.data.len() {
                self.bitbuf |= (self.data[self.pos] as u64) << self.bitcount;
                self.pos += 1;
                self.bitcount += 8;
            }
            let v = (self.bitbuf & ((1u64 << n) - 1)) as u32;
            self.bitbuf >>= n;
            self.bitcount = self.bitcount.saturating_sub(n);
            v
        }
    }

    #[test]
    fn roundtrip_mixed_widths() {
        let mut w = BitWriter::new();
        let pattern: Vec<(u32, u32)> = vec![
            (0b1, 1),
            (0b101, 3),
            (0xff, 8),
            (0x1234, 13),
            (0, 2),
            (0xabcd, 16),
            (1, 1),
        ];
        for &(v, n) in &pattern {
            w.write_bits(v & ((1 << n) - 1), n);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &(v, n) in &pattern {
            assert_eq!(r.read_bits(n), v & ((1 << n) - 1), "width {n}");
        }
    }

    #[test]
    fn word_reader_matches_scalar_oracle() {
        let mut rng = Xoshiro256pp::new(0xb170);
        for trial in 0..50 {
            let len = (rng.next_u64() % 200) as usize + trial;
            let data: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let mut fast = BitReader::new(&data);
            let mut oracle = OracleReader::new(&data);
            // Random widths, reading well past the end (both must agree on
            // the zero-padded tail too).
            let mut remaining = len * 8 + 64;
            while remaining > 0 {
                let n = 1 + (rng.next_u64() % 32) as u32;
                assert_eq!(fast.read_bits(n), oracle.read_bits(n), "trial {trial}");
                remaining = remaining.saturating_sub(n as usize);
            }
        }
    }

    #[test]
    fn word_writer_matches_scalar_packing() {
        // The scalar LSB-first packing oracle, inline: bytes appear in the
        // exact order bits were written, 8 at a time.
        let mut rng = Xoshiro256pp::new(0x3717e);
        for _ in 0..30 {
            let writes: Vec<(u32, u32)> = (0..(rng.next_u64() % 300))
                .map(|_| {
                    let n = 1 + (rng.next_u64() % 32) as u32;
                    let v = if n == 32 {
                        rng.next_u64() as u32
                    } else {
                        (rng.next_u64() as u32) & ((1u32 << n) - 1)
                    };
                    (v, n)
                })
                .collect();
            let mut w = BitWriter::new();
            let mut bit_len = 0usize;
            for &(v, n) in &writes {
                w.write_bits(v, n);
                bit_len += n as usize;
                assert_eq!(w.bit_len(), bit_len);
            }
            let bytes = w.finish();
            assert_eq!(bytes.len(), bit_len.div_ceil(8));
            // Oracle: pack the same bits one by one.
            let mut oracle = vec![0u8; bit_len.div_ceil(8)];
            let mut at = 0usize;
            for &(v, n) in &writes {
                for b in 0..n {
                    if (v >> b) & 1 == 1 {
                        oracle[at / 8] |= 1 << (at % 8);
                    }
                    at += 1;
                }
            }
            assert_eq!(bytes, oracle);
        }
    }

    #[test]
    fn byte_alignment_and_raw_bytes() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        w.align_byte();
        w.write_bytes(&[0xde, 0xad]);
        let bytes = w.finish();
        assert_eq!(bytes, vec![0b101, 0xde, 0xad]);
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(3), 0b101);
        r.align_byte();
        assert_eq!(r.read_bytes(2).unwrap(), vec![0xde, 0xad]);
        assert!(r.exhausted());
    }

    #[test]
    fn read_bytes_drains_buffered_words_first() {
        // Provoke the case where refill has buffered several whole bytes
        // before a byte-aligned bulk copy is requested.
        let data: Vec<u8> = (0..64u8).collect();
        let mut r = BitReader::new(&data);
        assert_eq!(r.read_bits(8), 0);
        r.align_byte();
        assert_eq!(r.read_bytes(40).unwrap(), (1..41u8).collect::<Vec<_>>());
        assert_eq!(r.read_bits(8), 41);
        assert_eq!(r.read_bytes(22).unwrap(), (42..64u8).collect::<Vec<_>>());
        assert!(r.exhausted());
        assert!(r.read_bytes(1).is_none());
    }

    #[test]
    fn peek_consume_equivalence() {
        let mut w = BitWriter::new();
        for i in 0..64u32 {
            w.write_bits(i % 16, 4);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for i in 0..64u32 {
            let p = r.peek_bits(4);
            r.consume(4);
            assert_eq!(p, i % 16);
        }
    }

    #[test]
    fn peek_reliable_up_to_max_width() {
        // Pins MAX_PEEK_BITS: a full-width peek must agree with read_bits
        // at every bit offset, including across word-refill boundaries.
        let mut rng = Xoshiro256pp::new(0x9ee);
        let data: Vec<u8> = (0..64).map(|_| rng.next_u64() as u8).collect();
        for skew in 0..8u32 {
            let mut peeker = BitReader::new(&data);
            let mut reader = BitReader::new(&data);
            if skew > 0 {
                assert_eq!(peeker.read_bits(skew), reader.read_bits(skew));
            }
            for _ in 0..((data.len() * 8) as u32 - skew) / MAX_PEEK_BITS {
                let p = peeker.peek_bits(MAX_PEEK_BITS);
                peeker.consume(MAX_PEEK_BITS);
                assert_eq!(p, reader.read_bits(MAX_PEEK_BITS));
            }
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "exceeds MAX_PEEK_BITS")]
    fn over_wide_peek_is_rejected() {
        let mut r = BitReader::new(&[0xff; 16]);
        r.peek_bits(MAX_PEEK_BITS + 1);
    }

    #[test]
    fn reading_past_end_returns_zeros() {
        let mut r = BitReader::new(&[0xff]);
        assert_eq!(r.read_bits(8), 0xff);
        assert_eq!(r.read_bits(8), 0);
        assert!(r.exhausted());
    }
}
