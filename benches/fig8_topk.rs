//! **Figure 8** — top-κ ablation: entropy-based (KL) ranking vs naive
//! random subsampling across κ ∈ {0.2 … 1.0}, CIFAR-100-sim, N=10, ρ=1.
//!
//!     cargo bench --bench fig8_topk [-- --full]
//!
//! Shape claims: KL ranking consistently beats random; accuracy peaks near
//! κ=0.8 (more is noisier, not better) while bpp grows with κ.

use deltamask::bench::{BenchScale, Table};
use deltamask::fl::run_experiment;
use deltamask::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let scale = BenchScale::from_args(&args);

    let mut table = Table::new(
        "Figure 8: top-κ mechanism",
        &["kappa", "ranking", "acc", "avg bpp"],
    );
    for kappa in [0.2f64, 0.4, 0.6, 0.8, 1.0] {
        for (ranking, method) in [("kl", "deltamask"), ("random", "deltamask-random")] {
            let mut cfg = scale.config("cifar100", method);
            cfg.kappa0 = kappa;
            cfg.kappa_floor = 1.0; // constant κ for the ablation
            let res = run_experiment(&cfg)?;
            eprintln!(
                "  κ={kappa} {ranking}: acc={:.4} bpp={:.4}",
                res.final_accuracy(),
                res.avg_bpp()
            );
            table.row(vec![
                format!("{kappa}"),
                ranking.to_string(),
                format!("{:.4}", res.final_accuracy()),
                format!("{:.4}", res.avg_bpp()),
            ]);
        }
    }
    table.print();
    table.save("fig8_topk");
    Ok(())
}
