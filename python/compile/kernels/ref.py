"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
contract. Every Pallas kernel in :mod:`masked_linear` must match these
references to float tolerance across the full (shape, tile) sweep in
``python/tests/test_kernel.py``; the rust native backend mirrors the same
math for the L3-side cross-check.
"""

import jax.numpy as jnp


def masked_matmul_ref(x, w, m):
    """y = x @ (m ⊙ w)ᵀ."""
    return x @ (w * m).T


def masked_matmul_rhs_ref(dy, w, m):
    """dx = dy @ (m ⊙ w)."""
    return dy @ (w * m)


def masked_outer_ref(dy, x, w):
    """dm = (dyᵀ @ x) ⊙ w."""
    return (dy.T @ x) * w


def masked_linear_vjp_ref(x, w, m, dy):
    """Full reference VJP of y = x @ (m ⊙ w)ᵀ → (dx, dm)."""
    return masked_matmul_rhs_ref(dy, w, m), masked_outer_ref(dy, x, w)


def forward_ref(x, w_blocks, masks, head_w, head_b):
    """Reference masked-residual-MLP forward (mirrors model.make_forward)."""
    h = x
    for w, m in zip(w_blocks, masks):
        h = h + jnp.maximum(masked_matmul_ref(h, w, m), 0.0)
    return h @ head_w.T + head_b
