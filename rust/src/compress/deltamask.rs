//! **DeltaMask** — the paper's update codec (§3.2, Alg. 1 lines 9–11 and
//! 14–16).
//!
//! Encode (client k, round t):
//! 1. Δ = { i : m_i^{g,t-1} ≠ m_i^{k,t} } — mask-difference index set against
//!    the shared-seed global binary mask.
//! 2. top-κ selection (Eq. 4): keep the K = ⌈κ·|Δ|⌉ indexes with the largest
//!    KL(θ^{k,t}_i ‖ θ^{g,t-1}_i) — importance sampling of the most certain
//!    updates (O(d) quickselect, no full sort).
//! 3. Fingerprint Δ′ into a probabilistic filter (default: 4-wise binary
//!    fuse, 8-bit entries — "BFuse8").
//! 4. Pack the fingerprint array into a grayscale image and compress
//!    losslessly (PNG = filtering + DEFLATE) → `A_{k,t}`.
//!
//! Decode (server): unpack the PNG, rebuild the filter, run the membership
//! query over *all* d indexes (Eq. 5), and bit-flip m^{g,t-1} at the hits —
//! false positives (rate ≈ 2^-bpe) surface as mask noise, which Appendix B
//! bounds.

use super::{wire, DecodeCtx, EncodeCtx, Encoded, Family, Update, UpdateCodec};
use crate::codec::png::{self, GrayImage};
use crate::filters::{BinaryFuse, MembershipFilter, XorFilter};
use crate::model::kl_bernoulli;
use crate::util::rng::Xoshiro256pp;
use crate::util::top_k_indices;
use anyhow::{bail, ensure, Result};

/// Probabilistic filter selection (§5.4 ablation, Fig. 9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FilterKind {
    BFuse8,
    BFuse16,
    BFuse32,
    /// 3-wise binary fuse (slightly larger, same API).
    BFuse8Arity3,
    Xor8,
    Xor16,
    Xor32,
}

impl FilterKind {
    pub fn label(&self) -> &'static str {
        match self {
            FilterKind::BFuse8 => "bfuse8",
            FilterKind::BFuse16 => "bfuse16",
            FilterKind::BFuse32 => "bfuse32",
            FilterKind::BFuse8Arity3 => "bfuse8-3w",
            FilterKind::Xor8 => "xor8",
            FilterKind::Xor16 => "xor16",
            FilterKind::Xor32 => "xor32",
        }
    }

    fn tag(&self) -> u8 {
        match self {
            FilterKind::BFuse8 => 0,
            FilterKind::BFuse16 => 1,
            FilterKind::BFuse32 => 2,
            FilterKind::BFuse8Arity3 => 3,
            FilterKind::Xor8 => 4,
            FilterKind::Xor16 => 5,
            FilterKind::Xor32 => 6,
        }
    }

    fn from_tag(tag: u8) -> Result<Self> {
        Ok(match tag {
            0 => FilterKind::BFuse8,
            1 => FilterKind::BFuse16,
            2 => FilterKind::BFuse32,
            3 => FilterKind::BFuse8Arity3,
            4 => FilterKind::Xor8,
            5 => FilterKind::Xor16,
            6 => FilterKind::Xor32,
            _ => bail!("unknown filter tag {tag}"),
        })
    }
}

/// Update-ranking mechanism (Fig. 8 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ranking {
    /// Relative entropy between server and client probabilities (Eq. 4).
    Kl,
    /// Naive uniform subsampling baseline.
    Random,
}

#[derive(Clone, Debug)]
pub struct DeltaMaskCodec {
    pub filter: FilterKind,
    pub ranking: Ranking,
    /// Pack through the grayscale-PNG stage (§3.2). Disabled only by the
    /// ablation that isolates the filter's contribution.
    pub use_png: bool,
}

impl Default for DeltaMaskCodec {
    fn default() -> Self {
        Self {
            filter: FilterKind::BFuse8,
            ranking: Ranking::Kl,
            use_png: true,
        }
    }
}

impl DeltaMaskCodec {
    pub fn with_filter(filter: FilterKind) -> Self {
        Self {
            filter,
            ..Self::default()
        }
    }

    pub fn with_ranking(ranking: Ranking) -> Self {
        Self {
            ranking,
            ..Self::default()
        }
    }

    /// Steps 1–2: the ranked, truncated difference set Δ′ (Eq. 4).
    pub fn select_updates(&self, ctx: &EncodeCtx) -> Vec<u64> {
        let mut delta: Vec<u32> = Vec::new();
        for i in 0..ctx.d {
            if ctx.mask_g[i] != ctx.mask_k[i] {
                delta.push(i as u32);
            }
        }
        let k = ((ctx.kappa * delta.len() as f64).ceil() as usize).min(delta.len());
        if k == delta.len() {
            return delta.into_iter().map(u64::from).collect();
        }
        match self.ranking {
            Ranking::Kl => {
                let scores: Vec<f32> = delta
                    .iter()
                    .map(|&i| kl_bernoulli(ctx.theta_k[i as usize], ctx.theta_g[i as usize]))
                    .collect();
                top_k_indices(&scores, k)
                    .into_iter()
                    .map(|pos| delta[pos as usize] as u64)
                    .collect()
            }
            Ranking::Random => {
                let mut rng = Xoshiro256pp::new(ctx.seed ^ 0xdead_beef);
                rng.shuffle(&mut delta);
                delta.truncate(k);
                delta.into_iter().map(u64::from).collect()
            }
        }
    }
}

enum BuiltFilter {
    B8(BinaryFuse<u8, 4>),
    B16(BinaryFuse<u16, 4>),
    B32(BinaryFuse<u32, 4>),
    B8A3(BinaryFuse<u8, 3>),
    X8(XorFilter<u8>),
    X16(XorFilter<u16>),
    X32(XorFilter<u32>),
}

impl BuiltFilter {
    fn build(kind: FilterKind, keys: &[u64]) -> Result<Self> {
        let err = || anyhow::anyhow!("filter construction failed");
        Ok(match kind {
            FilterKind::BFuse8 => BuiltFilter::B8(BinaryFuse::build(keys).ok_or_else(err)?),
            FilterKind::BFuse16 => BuiltFilter::B16(BinaryFuse::build(keys).ok_or_else(err)?),
            FilterKind::BFuse32 => BuiltFilter::B32(BinaryFuse::build(keys).ok_or_else(err)?),
            FilterKind::BFuse8Arity3 => {
                BuiltFilter::B8A3(BinaryFuse::build(keys).ok_or_else(err)?)
            }
            FilterKind::Xor8 => BuiltFilter::X8(XorFilter::build(keys).ok_or_else(err)?),
            FilterKind::Xor16 => BuiltFilter::X16(XorFilter::build(keys).ok_or_else(err)?),
            FilterKind::Xor32 => BuiltFilter::X32(XorFilter::build(keys).ok_or_else(err)?),
        })
    }

    /// (seed, layout_a, layout_b, payload, num_keys) — layout params differ
    /// between bfuse (segment_length, segment_count_length) and xor
    /// (block_length, unused).
    fn parts(&self) -> (u64, u32, u64, Vec<u8>, usize) {
        match self {
            BuiltFilter::B8(f) => (f.seed(), f.segment_length_pub(), f.segment_count_length_pub(), f.payload(), f.num_keys()),
            BuiltFilter::B16(f) => (f.seed(), f.segment_length_pub(), f.segment_count_length_pub(), f.payload(), f.num_keys()),
            BuiltFilter::B32(f) => (f.seed(), f.segment_length_pub(), f.segment_count_length_pub(), f.payload(), f.num_keys()),
            BuiltFilter::B8A3(f) => (f.seed(), f.segment_length_pub(), f.segment_count_length_pub(), f.payload(), f.num_keys()),
            BuiltFilter::X8(f) => (f.seed(), f.block_length(), 0, f.payload(), f.num_keys()),
            BuiltFilter::X16(f) => (f.seed(), f.block_length(), 0, f.payload(), f.num_keys()),
            BuiltFilter::X32(f) => (f.seed(), f.block_length(), 0, f.payload(), f.num_keys()),
        }
    }

    fn restore(
        kind: FilterKind,
        seed: u64,
        layout_a: u32,
        layout_b: u64,
        payload: &[u8],
        num_keys: usize,
    ) -> Self {
        match kind {
            FilterKind::BFuse8 => {
                BuiltFilter::B8(BinaryFuse::from_parts(seed, layout_a, layout_b, payload, num_keys))
            }
            FilterKind::BFuse16 => {
                BuiltFilter::B16(BinaryFuse::from_parts(seed, layout_a, layout_b, payload, num_keys))
            }
            FilterKind::BFuse32 => {
                BuiltFilter::B32(BinaryFuse::from_parts(seed, layout_a, layout_b, payload, num_keys))
            }
            FilterKind::BFuse8Arity3 => {
                BuiltFilter::B8A3(BinaryFuse::from_parts(seed, layout_a, layout_b, payload, num_keys))
            }
            FilterKind::Xor8 => BuiltFilter::X8(XorFilter::from_parts(seed, layout_a, payload, num_keys)),
            FilterKind::Xor16 => BuiltFilter::X16(XorFilter::from_parts(seed, layout_a, payload, num_keys)),
            FilterKind::Xor32 => BuiltFilter::X32(XorFilter::from_parts(seed, layout_a, payload, num_keys)),
        }
    }

    fn contains(&self, key: u64) -> bool {
        match self {
            BuiltFilter::B8(f) => f.contains(key),
            BuiltFilter::B16(f) => f.contains(key),
            BuiltFilter::B32(f) => f.contains(key),
            BuiltFilter::B8A3(f) => f.contains(key),
            BuiltFilter::X8(f) => f.contains(key),
            BuiltFilter::X16(f) => f.contains(key),
            BuiltFilter::X32(f) => f.contains(key),
        }
    }
}

impl UpdateCodec for DeltaMaskCodec {
    fn name(&self) -> &'static str {
        "deltamask"
    }

    fn family(&self) -> Family {
        Family::Mask
    }

    fn encode(&self, ctx: &EncodeCtx) -> Result<Encoded> {
        let delta = self.select_updates(ctx);
        let filter = BuiltFilter::build(self.filter, &delta)?;
        let (seed, layout_a, layout_b, payload, num_keys) = filter.parts();

        // Wire format: tag(1) png_flag(1) seed(8) layout_a(4) layout_b(8)
        //              num_keys(4) payload_len(4) payload(PNG or raw)
        let mut bytes = Vec::with_capacity(payload.len() + 32);
        bytes.push(self.filter.tag());
        bytes.push(self.use_png as u8);
        wire::put_u64(&mut bytes, seed);
        wire::put_u32(&mut bytes, layout_a);
        wire::put_u64(&mut bytes, layout_b);
        wire::put_u32(&mut bytes, num_keys as u32);
        wire::put_u32(&mut bytes, payload.len() as u32);
        if self.use_png {
            let img = GrayImage::from_payload(&payload);
            bytes.extend_from_slice(&png::encode(&img));
        } else {
            bytes.extend_from_slice(&payload);
        }
        Ok(Encoded { bytes })
    }

    fn decode(&self, bytes: &[u8], ctx: &DecodeCtx) -> Result<Update> {
        ensure!(bytes.len() >= 30, "deltamask record too short");
        let kind = FilterKind::from_tag(bytes[0])?;
        let is_png = bytes[1] != 0;
        let mut r = wire::Reader::new(&bytes[2..]);
        let seed = r.u64()?;
        let layout_a = r.u32()?;
        let layout_b = r.u64()?;
        let num_keys = r.u32()? as usize;
        let payload_len = r.u32()? as usize;
        let rest = &bytes[2 + r.pos..];
        let payload = if is_png {
            let img = png::decode(rest).map_err(|e| anyhow::anyhow!("png: {e}"))?;
            ensure!(
                (img.width as usize * img.height as usize) >= payload_len,
                "png smaller than payload"
            );
            img.pixels[..payload_len].to_vec()
        } else {
            ensure!(rest.len() == payload_len, "payload length mismatch");
            rest.to_vec()
        };
        let filter = BuiltFilter::restore(kind, seed, layout_a, layout_b, &payload, num_keys);

        // Eq. 5: membership query across all d positions, then bit-flip.
        let mut mask = ctx.mask_g.to_vec();
        if num_keys > 0 {
            for (i, m) in mask.iter_mut().enumerate() {
                if filter.contains(i as u64) {
                    *m = 1.0 - *m;
                }
            }
        }
        Ok(Update::Mask(mask))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::sample_mask_seeded;

    fn make_ctx<'a>(
        d: usize,
        theta_k: &'a [f32],
        theta_g: &'a [f32],
        mask_k: &'a [f32],
        mask_g: &'a [f32],
        kappa: f64,
    ) -> EncodeCtx<'a> {
        EncodeCtx {
            d,
            theta_k,
            theta_g,
            mask_k,
            mask_g,
            s_k: &[],
            s_g: &[],
            kappa,
            seed: 99,
        }
    }

    fn setup(d: usize, drift: f32, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Xoshiro256pp::new(seed);
        let theta_g: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
        let theta_k: Vec<f32> = theta_g
            .iter()
            .map(|&p| (p + drift * (rng.next_f32() - 0.5)).clamp(0.01, 0.99))
            .collect();
        let mut mask_g = Vec::new();
        sample_mask_seeded(&theta_g, 7, &mut mask_g);
        let mut mask_k = Vec::new();
        sample_mask_seeded(&theta_k, 8, &mut mask_k);
        (theta_k, theta_g, mask_k, mask_g)
    }

    #[test]
    fn roundtrip_reconstructs_selected_updates_exactly() {
        let d = 50_000;
        let (tk, tg, mk, mg) = setup(d, 0.1, 1);
        // κ=1 + 32-bit fingerprints ⇒ essentially exact reconstruction.
        let codec = DeltaMaskCodec::with_filter(FilterKind::BFuse32);
        let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 1.0);
        let enc = codec.encode(&ctx).unwrap();
        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mg,
            s_g: &[],
            seed: 99,
        };
        match codec.decode(&enc.bytes, &dec_ctx).unwrap() {
            Update::Mask(m) => {
                let wrong = m
                    .iter()
                    .zip(&mk)
                    .filter(|(a, b)| a != b)
                    .count();
                // 2^-32 fp rate over 50k queries: expect exactly 0.
                assert_eq!(wrong, 0, "reconstruction errors: {wrong}");
            }
            _ => panic!("wrong family"),
        }
    }

    #[test]
    fn bfuse8_reconstruction_error_is_bounded_by_fp_rate() {
        let d = 100_000;
        let (tk, tg, mk, mg) = setup(d, 0.05, 2);
        let codec = DeltaMaskCodec::default();
        let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 1.0);
        let enc = codec.encode(&ctx).unwrap();
        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mg,
            s_g: &[],
            seed: 99,
        };
        let Update::Mask(m) = codec.decode(&enc.bytes, &dec_ctx).unwrap() else {
            panic!()
        };
        // All true updates applied (no false negatives) ...
        let missed = (0..d)
            .filter(|&i| mk[i] != mg[i] && m[i] != mk[i])
            .count();
        assert_eq!(missed, 0);
        // ... and false flips bounded by ~d·2^-8 with slack.
        let extra = (0..d)
            .filter(|&i| mk[i] == mg[i] && m[i] != mk[i])
            .count();
        assert!(extra < (d as f64 * 0.008) as usize, "extra flips: {extra}");
    }

    #[test]
    fn kappa_truncates_and_prefers_high_kl() {
        let d = 10_000;
        let (tk, tg, mk, mg) = setup(d, 0.5, 3);
        let codec = DeltaMaskCodec::default();
        let full = codec.select_updates(&make_ctx(d, &tk, &tg, &mk, &mg, 1.0));
        let half = codec.select_updates(&make_ctx(d, &tk, &tg, &mk, &mg, 0.5));
        assert!(half.len() <= full.len() / 2 + 1);
        // Every selected index is a true difference.
        for &i in &half {
            assert_ne!(mk[i as usize], mg[i as usize]);
        }
        // Selected KL floor ≥ max unselected KL (selection property).
        let sel: std::collections::HashSet<u64> = half.iter().cloned().collect();
        let min_sel = half
            .iter()
            .map(|&i| kl_bernoulli(tk[i as usize], tg[i as usize]))
            .fold(f32::INFINITY, f32::min);
        let max_unsel = full
            .iter()
            .filter(|i| !sel.contains(i))
            .map(|&i| kl_bernoulli(tk[i as usize], tg[i as usize]))
            .fold(f32::NEG_INFINITY, f32::max);
        assert!(min_sel >= max_unsel - 1e-5, "{min_sel} < {max_unsel}");
    }

    #[test]
    fn bpp_well_below_one_for_sparse_updates() {
        // Late-training regime: ~2% mask drift ⇒ bpp must land deep below
        // 1 bpp (the paper's headline).
        let d = 327_680;
        let mut rng = Xoshiro256pp::new(4);
        let theta_g: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
        let mut mask_g = Vec::new();
        sample_mask_seeded(&theta_g, 5, &mut mask_g);
        let mut mask_k = mask_g.clone();
        let mut flipped = 0;
        while flipped < d / 50 {
            let i = rng.below(d as u64) as usize;
            mask_k[i] = 1.0 - mask_k[i];
            flipped += 1;
        }
        let codec = DeltaMaskCodec::default();
        let ctx = make_ctx(d, &theta_g, &theta_g, &mask_k, &mask_g, 0.8);
        let enc = codec.encode(&ctx).unwrap();
        let bpp = enc.bpp(d);
        assert!(bpp < 0.25, "bpp={bpp}");
        assert!(bpp > 0.01, "bpp={bpp} suspiciously low");
    }

    #[test]
    fn empty_delta_roundtrip() {
        let d = 1000;
        let theta = vec![0.5f32; d];
        let mut mask = Vec::new();
        sample_mask_seeded(&theta, 1, &mut mask);
        let codec = DeltaMaskCodec::default();
        let ctx = make_ctx(d, &theta, &theta, &mask, &mask, 0.8);
        let enc = codec.encode(&ctx).unwrap();
        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mask,
            s_g: &[],
            seed: 99,
        };
        let Update::Mask(m) = codec.decode(&enc.bytes, &dec_ctx).unwrap() else {
            panic!()
        };
        assert_eq!(m, mask);
    }

    #[test]
    fn all_filter_kinds_roundtrip() {
        let d = 20_000;
        let (tk, tg, mk, mg) = setup(d, 0.1, 6);
        for kind in [
            FilterKind::BFuse8,
            FilterKind::BFuse16,
            FilterKind::BFuse32,
            FilterKind::BFuse8Arity3,
            FilterKind::Xor8,
            FilterKind::Xor16,
            FilterKind::Xor32,
        ] {
            let codec = DeltaMaskCodec::with_filter(kind);
            let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 1.0);
            let enc = codec.encode(&ctx).unwrap();
            let dec_ctx = DecodeCtx {
                d,
                mask_g: &mg,
                s_g: &[],
                seed: 99,
            };
            let Update::Mask(m) = codec.decode(&enc.bytes, &dec_ctx).unwrap() else {
                panic!()
            };
            let missed = (0..d)
                .filter(|&i| mk[i] != mg[i] && m[i] != mk[i])
                .count();
            assert_eq!(missed, 0, "{kind:?} missed true updates");
        }
    }

    #[test]
    fn png_stage_reduces_or_matches_raw_bytes() {
        let d = 100_000;
        let (tk, tg, mk, mg) = setup(d, 0.05, 8);
        let with_png = DeltaMaskCodec::default();
        let without = DeltaMaskCodec {
            use_png: false,
            ..Default::default()
        };
        let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 0.8);
        let a = with_png.encode(&ctx).unwrap().bytes.len();
        let b = without.encode(&ctx).unwrap().bytes.len();
        // Fingerprints are near-uniform, so PNG gains are small — but the
        // overhead must stay tiny (≤ ~2% + fixed header).
        assert!(a <= b + b / 50 + 128, "png={a} raw={b}");
    }
}
