//! Decode-worker determinism suite: the sharded server drain
//! (`DrainConfig::workers > 1`) must be **bitwise identical** to the serial
//! reference path for every codec, both pipeline modes and any worker
//! count — and a malformed record surfaced by a worker must abort the
//! round cleanly (no hang, no panic, every worker joined).

use deltamask::compress::{self, Encoded, ScratchPool};
use deltamask::coordinator::{
    drain_round, ChannelTransport, DrainConfig, DrainReport, Payload, PipelineMode, RoundEngine,
    RoundPlan, WireMessage,
};
use deltamask::fl::server::MaskServer;
use deltamask::model::sample_mask_seeded;
use deltamask::util::rng::Xoshiro256pp;

fn logit(p: f32) -> f32 {
    let p = p.clamp(1e-6, 1.0 - 1e-6);
    (p / (1.0 - p)).ln()
}

/// A plausible round for `codec`: global state, a plan, and one realistic
/// encoded update per slot (drifted posteriors, shared-seed masks, score
/// mirrors — the same recipe as the fl_integration property tests).
fn round_fixture(name: &str, d: usize, k: usize, trial: u64) -> (RoundPlan, Vec<Encoded>) {
    let codec = compress::by_name(name).unwrap();
    let mut rng = Xoshiro256pp::new(0xD0_0D ^ trial.wrapping_mul(0x9e37_79b9));
    let theta_g: Vec<f32> = (0..d).map(|_| 0.05 + 0.9 * rng.next_f32()).collect();
    let s_g: Vec<f32> = theta_g.iter().map(|&p| logit(p)).collect();
    let mut engine = RoundEngine::new(trial, k, 1.0, 0.8, 0.25, 3);
    let plan = engine.plan(0, &theta_g, &s_g);
    let mut encs = Vec::new();
    for slot in 0..plan.expected() {
        let theta_k: Vec<f32> = theta_g
            .iter()
            .map(|&p| (p + 0.3 * (rng.next_f32() - 0.5)).clamp(0.01, 0.99))
            .collect();
        let s_k: Vec<f32> = theta_k.iter().map(|&p| logit(p)).collect();
        let mut mask_k = Vec::new();
        sample_mask_seeded(&theta_k, plan.seed, &mut mask_k);
        let ectx = plan.encode_ctx(slot, &theta_k, &mask_k, &s_k);
        encs.push(codec.encode(&ectx).unwrap_or_else(|e| panic!("{name}: {e}")));
    }
    (plan, encs)
}

/// Send `encs` through a fresh channel in `order`, then drain into a fresh
/// server under `cfg`.
fn drain_into(
    name: &str,
    plan: &RoundPlan,
    encs: &[Encoded],
    order: &[usize],
    cfg: DrainConfig,
) -> (MaskServer, DrainReport) {
    let codec = compress::by_name(name).unwrap();
    let (mut channel, sender) = ChannelTransport::new();
    for &slot in order {
        sender
            .send(WireMessage {
                round: plan.round,
                client_id: plan.participants[slot],
                slot,
                payload: Payload::Update(encs[slot].clone()),
                enc_secs: 0.125 * (slot as f64 + 1.0),
                loss: 0.5 + slot as f32,
            })
            .unwrap();
    }
    drop(sender);
    let mut server = MaskServer::with_theta0(plan.d(), 1.0, 0.85);
    let pool = ScratchPool::new();
    let report = drain_round(&mut channel, plan, codec.as_ref(), &mut server, cfg, &pool)
        .unwrap_or_else(|e| panic!("{name} {cfg:?}: {e}"));
    (server, report)
}

/// The tentpole property: sharded drain ≡ serial drain, bitwise, across
/// all 11 codecs (both update families) × both pipeline modes × worker
/// counts 1/2/3/8, with varying client counts and adversarial arrival
/// orders.
#[test]
fn sharded_drain_is_bitwise_identical_to_serial_for_all_codecs() {
    let d = 2048;
    for (trial, name) in compress::all_names().iter().enumerate() {
        let k = 2 + (trial % 5); // client counts 2..=6 across the roster
        let (plan, encs) = round_fixture(name, d, k, trial as u64 + 1);
        // Adversarial arrival order: reversed with a mid-list swap.
        let mut order: Vec<usize> = (0..plan.expected()).rev().collect();
        if order.len() > 2 {
            let mid = order.len() / 2;
            order.swap(0, mid);
        }
        for mode in [PipelineMode::Batch, PipelineMode::Streaming] {
            let (reference, ref_report) =
                drain_into(name, &plan, &encs, &order, DrainConfig::serial(mode));
            for workers in [1usize, 2, 3, 8] {
                let (sharded, report) =
                    drain_into(name, &plan, &encs, &order, DrainConfig::new(mode, workers));
                let tag = format!("{name} {mode:?} workers={workers}");
                assert_eq!(reference.theta_g, sharded.theta_g, "{tag}: theta_g diverged");
                assert_eq!(reference.s_g, sharded.s_g, "{tag}: s_g diverged");
                assert_eq!(reference.round, sharded.round, "{tag}");
                // Per-slot accounting is deterministic regardless of which
                // worker decoded what…
                assert_eq!(ref_report.loss_by_slot, report.loss_by_slot, "{tag}");
                assert_eq!(ref_report.enc_by_slot, report.enc_by_slot, "{tag}");
                // …and the per-worker decode split covers the whole round.
                assert_eq!(report.dec_by_worker.len(), workers, "{tag}");
                let split: f64 = report.dec_by_worker.iter().sum();
                assert!(
                    (split - report.dec_secs).abs() < 1e-9,
                    "{tag}: worker split {split} != total {}",
                    report.dec_secs
                );
            }
        }
    }
}

/// Error path: a malformed record decoded *on a worker thread* must abort
/// the round with a clean error — pending jobs dropped, all workers
/// joined, no deadlock on the bounded results channel — in both modes.
#[test]
fn malformed_record_from_a_worker_aborts_the_round_cleanly() {
    let (plan, mut encs) = round_fixture("deltamask", 512, 4, 9);
    encs[2] = Encoded {
        bytes: vec![0u8; 8], // fails DeltaMask's record-length validation
    };
    let order: Vec<usize> = (0..plan.expected()).collect();
    for mode in [PipelineMode::Batch, PipelineMode::Streaming] {
        for workers in [2usize, 3] {
            let codec = compress::by_name("deltamask").unwrap();
            let (mut channel, sender) = ChannelTransport::new();
            for &slot in &order {
                sender
                    .send(WireMessage {
                        round: plan.round,
                        client_id: plan.participants[slot],
                        slot,
                        payload: Payload::Update(encs[slot].clone()),
                        enc_secs: 0.0,
                        loss: 0.0,
                    })
                    .unwrap();
            }
            drop(sender);
            let mut server = MaskServer::with_theta0(plan.d(), 1.0, 0.85);
            let err = drain_round(
                &mut channel,
                &plan,
                codec.as_ref(),
                &mut server,
                DrainConfig::new(mode, workers),
                &ScratchPool::new(),
            )
            .unwrap_err();
            let msg = err.to_string();
            assert!(
                msg.contains("decode failed for slot 2"),
                "{mode:?} workers={workers}: unexpected error: {msg}"
            );
        }
    }
}

/// `workers = 0` resolves to the machine's parallelism and worker counts
/// far beyond the record count are harmless — both still bitwise-match the
/// serial reference.
#[test]
fn auto_and_oversized_worker_counts_match_serial() {
    let (plan, encs) = round_fixture("fedpm", 1024, 2, 31);
    let order: Vec<usize> = (0..plan.expected()).collect();
    let (reference, _) = drain_into(
        "fedpm",
        &plan,
        &encs,
        &order,
        DrainConfig::serial(PipelineMode::Streaming),
    );
    for workers in [0usize, 16] {
        let (sharded, report) = drain_into(
            "fedpm",
            &plan,
            &encs,
            &order,
            DrainConfig::new(PipelineMode::Streaming, workers),
        );
        assert_eq!(reference.theta_g, sharded.theta_g, "workers={workers}");
        assert_eq!(reference.s_g, sharded.s_g, "workers={workers}");
        assert!(!report.dec_by_worker.is_empty(), "workers={workers}");
        assert_eq!(
            report.dec_by_worker.len(),
            DrainConfig::new(PipelineMode::Streaming, workers).resolved_workers(),
            "workers={workers}"
        );
    }
}
