//! Binary fuse filters (Graf & Lemire, *ACM JEA* 2022) — the paper's
//! probabilistic filter of choice (§3.1): ~8.62 bits/entry at 8-bit
//! fingerprints with false-positive rate ≈ 2^-bits and zero false negatives.
//!
//! Construction follows the reference segmented layout: keys hash to `ARITY`
//! cells in consecutive segments of a fingerprint array; a peeling pass
//! (hypergraph 1-core elimination) orders keys so each can be assigned a
//! cell whose XOR equation is then satisfiable, exactly like XOR filters but
//! with the fused-segment locality that buys the smaller size factor
//! (≈1.125 for 3-wise, ≈1.075 for 4-wise).

use super::{Fingerprint, MembershipFilter, BATCH_BLOCK};
use crate::hash::{mix64, mix_split, mulhi};

/// A binary fuse filter over `u64` keys with `ARITY` ∈ {3, 4} hash
/// functions and fingerprint type `F` (u8/u16/u32 ⇒ BFuse8/16/32).
#[derive(Clone, Debug)]
pub struct BinaryFuse<F: Fingerprint, const ARITY: usize = 4> {
    seed: u64,
    segment_length: u32,
    segment_length_mask: u32,
    segment_count_length: u64,
    fingerprints: Vec<F>,
    num_keys: usize,
}

const MAX_ITERATIONS: usize = 128;

fn segment_length(arity: usize, size: u32) -> u32 {
    if size == 0 {
        return 4;
    }
    let l = match arity {
        3 => ((size as f64).ln() / 3.33f64.ln() + 2.25).floor(),
        4 => ((size as f64).ln() / 2.91f64.ln() - 0.5).floor(),
        _ => unreachable!("arity must be 3 or 4"),
    };
    let l = l.clamp(0.0, 18.0) as u32;
    (1u32 << l).min(262_144)
}

fn size_factor(arity: usize, size: u32) -> f64 {
    let size = size.max(2) as f64;
    match arity {
        3 => (0.875 + 0.25 * 1_000_000f64.ln() / size.ln()).max(1.125),
        4 => (0.77 + 0.305 * 600_000f64.ln() / size.ln()).max(1.075),
        _ => unreachable!(),
    }
}

impl<F: Fingerprint, const ARITY: usize> BinaryFuse<F, ARITY> {
    /// Build a filter over `keys`. Keys must be distinct (the DeltaMask
    /// index sets are); duplicates are removed defensively.
    ///
    /// Returns `None` only if construction fails `MAX_ITERATIONS` times,
    /// which for distinct keys has vanishing probability.
    pub fn build(keys: &[u64]) -> Option<Self> {
        assert!(ARITY == 3 || ARITY == 4, "arity must be 3 or 4");
        let mut keys = keys.to_vec();
        keys.sort_unstable();
        keys.dedup();
        let size = keys.len() as u32;

        // Sizing follows the reference implementation exactly (fuse8.c):
        // array_length ≈ size·sizefactor rounded to whole segments, with
        // ARITY-1 "spill" segments appended so position j can reach
        // `segment_count + j` segments in.
        let seg_len = segment_length(ARITY, size);
        let capacity = if size <= 1 {
            0i64
        } else {
            ((size as f64) * size_factor(ARITY, size)).round() as i64
        };
        let init_segment_count =
            ((capacity + seg_len as i64 - 1) / seg_len as i64 - (ARITY as i64 - 1)).max(1);
        let array_length = ((init_segment_count + ARITY as i64 - 1) * seg_len as i64) as u32;
        let segment_count = {
            let sc = (array_length + seg_len - 1) / seg_len;
            if sc <= ARITY as u32 - 1 {
                1
            } else {
                sc - (ARITY as u32 - 1)
            }
        };
        let array_length = (segment_count + ARITY as u32 - 1) * seg_len;
        let segment_count_length = (segment_count as u64) * (seg_len as u64);

        let mut filter = Self {
            seed: 0,
            segment_length: seg_len,
            segment_length_mask: seg_len - 1,
            segment_count_length,
            fingerprints: vec![F::default(); array_length as usize],
            num_keys: keys.len(),
        };

        if keys.is_empty() {
            filter.seed = 0x1234_5678_9abc_def0;
            return Some(filter);
        }

        let cap = array_length as usize;
        let mut t2count = vec![0u8; cap];
        let mut t2hash = vec![0u64; cap];
        let mut alone = vec![0u32; cap];
        let mut reverse_order = vec![0u64; keys.len()];
        let mut reverse_h = vec![0u8; keys.len()];

        let mut seed_rng = 0x726b_2b9d_438b_9d4du64;

        'outer: for _ in 0..MAX_ITERATIONS {
            // splitmix step for a fresh seed
            seed_rng = seed_rng.wrapping_add(0x9e37_79b9_7f4a_7c15);
            filter.seed = mix64(seed_rng);

            t2count.iter_mut().for_each(|c| *c = 0);
            t2hash.iter_mut().for_each(|h| *h = 0);

            // Accumulate per-cell counts and xor-of-hashes; tag the count's
            // low 2 bits with the hash-function index parity trick so a
            // singleton cell reveals *which* of the ARITY positions it is.
            for &key in &keys {
                let hash = mix_split(key, filter.seed);
                let mut positions = [0u32; ARITY];
                filter.positions(hash, &mut positions);
                let mut overflow = false;
                for (j, &p) in positions.iter().enumerate() {
                    let c = &mut t2count[p as usize];
                    *c = c.wrapping_add(4);
                    *c ^= (j as u8) & 3;
                    t2hash[p as usize] ^= hash;
                    if *c < 4 {
                        overflow = true; // count overflowed u8
                    }
                }
                if overflow {
                    continue 'outer;
                }
            }

            // Seed the peeling queue with singleton cells.
            let mut q = 0usize;
            for (i, &c) in t2count.iter().enumerate() {
                if c >> 2 == 1 {
                    alone[q] = i as u32;
                    q += 1;
                }
            }

            let mut stack = 0usize;
            while q > 0 {
                q -= 1;
                let cell = alone[q] as usize;
                if t2count[cell] >> 2 != 1 {
                    continue;
                }
                let hash = t2hash[cell];
                let found = (t2count[cell] & 3) as usize;
                reverse_order[stack] = hash;
                reverse_h[stack] = found as u8;
                stack += 1;

                let mut positions = [0u32; ARITY];
                filter.positions(hash, &mut positions);
                for (j, &p) in positions.iter().enumerate() {
                    if j == found {
                        continue;
                    }
                    let c = &mut t2count[p as usize];
                    *c = c.wrapping_sub(4);
                    *c ^= (j as u8) & 3;
                    t2hash[p as usize] ^= hash;
                    if *c >> 2 == 1 {
                        alone[q] = p;
                        q += 1;
                    }
                }
            }

            if stack == keys.len() {
                // Assignment pass, in reverse peel order.
                for i in (0..stack).rev() {
                    let hash = reverse_order[i];
                    let found = reverse_h[i] as usize;
                    let mut positions = [0u32; ARITY];
                    filter.positions(hash, &mut positions);
                    let mut fp = F::from_hash(hash);
                    for (j, &p) in positions.iter().enumerate() {
                        if j != found {
                            fp = fp.xor(filter.fingerprints[p as usize]);
                        }
                    }
                    filter.fingerprints[positions[found] as usize] = fp;
                }
                return Some(filter);
            }
            // else: cyclic hypergraph — retry with a new seed.
        }
        None
    }

    /// The ARITY cell positions for a hashed key: a start segment from the
    /// high bits (fast-range), then one cell per consecutive segment with a
    /// within-segment offset drawn from disjoint windows of the hash.
    #[inline]
    fn positions(&self, hash: u64, out: &mut [u32; ARITY]) {
        let base = mulhi(hash, self.segment_count_length);
        match ARITY {
            3 => {
                // Reference layout: lower 36 bits, windows at shifts 36/18/0.
                let hh = hash & ((1u64 << 36) - 1);
                for (j, o) in out.iter_mut().enumerate() {
                    let h = base + (j as u64) * (self.segment_length as u64);
                    let perturb =
                        ((hh >> (36 - 18 * j)) as u32) & self.segment_length_mask;
                    *o = h as u32 ^ perturb;
                }
            }
            4 => {
                // Lower 48 bits, four 16-bit windows.
                let hh = hash & ((1u64 << 48) - 1);
                for (j, o) in out.iter_mut().enumerate() {
                    let h = base + (j as u64) * (self.segment_length as u64);
                    let perturb =
                        ((hh >> (48 - 16 * j)) as u32) & self.segment_length_mask;
                    *o = h as u32 ^ perturb;
                }
            }
            _ => unreachable!(),
        }
    }

    pub fn len_fingerprints(&self) -> usize {
        self.fingerprints.len()
    }

    pub fn num_keys(&self) -> usize {
        self.num_keys
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Layout parameters needed to reassemble a filter on the receiving
    /// side (travel in the DeltaMask record header).
    pub fn segment_length_pub(&self) -> u32 {
        self.segment_length
    }

    pub fn segment_count_length_pub(&self) -> u64 {
        self.segment_count_length
    }

    /// Serialize the fingerprint array (little-endian) — this is the payload
    /// DeltaMask packs into the grayscale image. Layout params travel in the
    /// image header sidecar (see `compress::deltamask`).
    pub fn payload(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.fingerprints.len() * (F::BITS as usize / 8));
        for &fp in &self.fingerprints {
            fp.to_bytes_push(&mut out);
        }
        out
    }

    /// Membership probe for an already-mixed hash — the one code path
    /// shared by `contains` and the batched kernels, so scalar and blocked
    /// queries agree bitwise by construction.
    #[inline(always)]
    fn probe_hash(&self, hash: u64) -> bool {
        let mut fp = F::from_hash(hash);
        let mut positions = [0u32; ARITY];
        self.positions(hash, &mut positions);
        for &p in positions.iter() {
            fp = fp.xor(self.fingerprints[p as usize]);
        }
        fp == F::default()
    }

    /// Reassemble a filter from its transmitted parts.
    pub fn from_parts(seed: u64, segment_length: u32, segment_count_length: u64, payload: &[u8], num_keys: usize) -> Self {
        let w = F::BITS as usize / 8;
        assert_eq!(payload.len() % w, 0, "payload not a multiple of fingerprint width");
        let n = payload.len() / w;
        let fingerprints = (0..n).map(|i| F::read_bytes(payload, i)).collect();
        Self {
            seed,
            segment_length,
            segment_length_mask: segment_length - 1,
            segment_count_length,
            fingerprints,
            num_keys,
        }
    }
}

impl<F: Fingerprint, const ARITY: usize> MembershipFilter for BinaryFuse<F, ARITY> {
    #[inline]
    fn contains(&self, key: u64) -> bool {
        if self.num_keys == 0 {
            return false;
        }
        self.probe_hash(mix_split(key, self.seed))
    }

    /// Blocked monomorphic kernel: hash a whole block (flat loop, no
    /// gathers), then probe with the segment-layout registers hoisted.
    fn contains_batch(&self, keys: &[u64], out: &mut [bool]) {
        assert_eq!(keys.len(), out.len());
        if self.num_keys == 0 {
            out.fill(false);
            return;
        }
        let seed = self.seed;
        let mut hashes = [0u64; BATCH_BLOCK];
        let mut base = 0usize;
        while base < keys.len() {
            let len = BATCH_BLOCK.min(keys.len() - base);
            for (h, &k) in hashes[..len].iter_mut().zip(&keys[base..base + len]) {
                *h = mix_split(k, seed);
            }
            for (o, &h) in out[base..base + len].iter_mut().zip(&hashes[..len]) {
                *o = self.probe_hash(h);
            }
            base += len;
        }
    }

    /// Batched Eq. 5 kernel over one contiguous index range (`start..start
    /// + mask.len()`): the hash phase runs over a fixed-size index block,
    /// then the probe phase flips members in place — one virtual dispatch
    /// per round instead of one per key. `start == 0` is the full-`d`
    /// sweep (`decode_mask_into`); nonzero starts are the per-shard
    /// sub-sweeps of the dimension-sharded drain.
    fn decode_mask_into_range(&self, mask: &mut [f32], start: usize) {
        if self.num_keys == 0 {
            return;
        }
        let seed = self.seed;
        let mut hashes = [0u64; BATCH_BLOCK];
        let d = mask.len();
        let mut base = 0usize;
        while base < d {
            let len = BATCH_BLOCK.min(d - base);
            for (j, h) in hashes[..len].iter_mut().enumerate() {
                *h = mix_split((start + base + j) as u64, seed);
            }
            for (j, m) in mask[base..base + len].iter_mut().enumerate() {
                if self.probe_hash(hashes[j]) {
                    *m = 1.0 - *m;
                }
            }
            base += len;
        }
    }

    fn payload_bytes(&self) -> usize {
        self.fingerprints.len() * (F::BITS as usize / 8)
    }

    fn bits_per_entry(&self) -> f64 {
        if self.num_keys == 0 {
            return 0.0;
        }
        (self.payload_bytes() * 8) as f64 / self.num_keys as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filters::testutil::{random_indexes, random_keys};

    fn check_no_false_negatives<F: Fingerprint, const A: usize>(keys: &[u64]) {
        let f = BinaryFuse::<F, A>::build(keys).expect("construction failed");
        for &k in keys {
            assert!(f.contains(k), "false negative for key {k}");
        }
    }

    #[test]
    fn no_false_negatives_all_widths_and_arities() {
        for n in [0usize, 1, 2, 3, 10, 100, 1000, 20_000] {
            let keys = random_keys(n, 42 + n as u64);
            check_no_false_negatives::<u8, 3>(&keys);
            check_no_false_negatives::<u8, 4>(&keys);
            check_no_false_negatives::<u16, 4>(&keys);
            check_no_false_negatives::<u32, 4>(&keys);
        }
    }

    #[test]
    fn false_positive_rate_matches_fingerprint_width() {
        let keys = random_indexes(10_000, 1u64 << 40, 7);
        let keyset: std::collections::HashSet<u64> = keys.iter().cloned().collect();
        let f8 = BinaryFuse::<u8, 4>::build(&keys).unwrap();
        let f16 = BinaryFuse::<u16, 4>::build(&keys).unwrap();
        let mut rng = crate::util::rng::Xoshiro256pp::new(99);
        let trials = 200_000;
        let mut fp8 = 0usize;
        let mut fp16 = 0usize;
        for _ in 0..trials {
            let k = rng.next_u64();
            if keyset.contains(&k) {
                continue;
            }
            if f8.contains(k) {
                fp8 += 1;
            }
            if f16.contains(k) {
                fp16 += 1;
            }
        }
        let rate8 = fp8 as f64 / trials as f64;
        let rate16 = fp16 as f64 / trials as f64;
        // ~2^-8 ≈ 0.0039 and ~2^-16 ≈ 1.5e-5
        assert!(rate8 < 0.008, "fp8 rate={rate8}");
        assert!(rate8 > 0.001, "fp8 rate={rate8} suspiciously low");
        assert!(rate16 < 2e-4, "fp16 rate={rate16}");
    }

    #[test]
    fn space_efficiency_near_paper_figure() {
        // Paper: "space efficiency of 8.62 bits per entry" for BFuse8.
        let keys = random_keys(100_000, 3);
        let f = BinaryFuse::<u8, 4>::build(&keys).unwrap();
        let bpe = f.bits_per_entry();
        assert!(bpe < 9.6, "bpe={bpe}");
        assert!(bpe >= 8.0, "bpe={bpe}");
        // 3-wise is a bit larger but still ≤ ~9.9.
        let f3 = BinaryFuse::<u8, 3>::build(&keys).unwrap();
        assert!(f3.bits_per_entry() < 10.0, "3-wise bpe={}", f3.bits_per_entry());
    }

    #[test]
    fn serialization_roundtrip() {
        let keys = random_indexes(5_000, 327_680, 11);
        let f = BinaryFuse::<u8, 4>::build(&keys).unwrap();
        let payload = f.payload();
        assert_eq!(payload.len(), f.payload_bytes());
        let g = BinaryFuse::<u8, 4>::from_parts(
            f.seed(),
            f.segment_length,
            f.segment_count_length,
            &payload,
            f.num_keys(),
        );
        // Identical answers on members and a random probe set.
        for &k in &keys {
            assert!(g.contains(k));
        }
        let mut rng = crate::util::rng::Xoshiro256pp::new(1);
        for _ in 0..10_000 {
            let k = rng.below(327_680);
            assert_eq!(f.contains(k), g.contains(k));
        }
    }

    #[test]
    fn exhaustive_membership_reconstruction() {
        // The exact server-side DeltaMask operation: query *every* index in
        // [0, d) and recover Δ′ (allowing ~2^-8·d false positives).
        let d = 100_000u64;
        let truth = random_indexes(2_000, d, 13);
        let f = BinaryFuse::<u8, 4>::build(&truth).unwrap();
        let truthset: std::collections::HashSet<u64> = truth.iter().cloned().collect();
        let mut recovered = 0usize;
        let mut false_pos = 0usize;
        for i in 0..d {
            if f.contains(i) {
                if truthset.contains(&i) {
                    recovered += 1;
                } else {
                    false_pos += 1;
                }
            }
        }
        assert_eq!(recovered, truth.len(), "zero false negatives required");
        // E[fp] ≈ d * 2^-8 ≈ 390; allow generous slack.
        assert!(false_pos < 800, "false_pos={false_pos}");
    }

    /// Scalar Eq. 5 oracle: the reference per-key membership sweep the
    /// batched kernels must reproduce bitwise.
    fn scalar_decode_oracle<M: MembershipFilter>(f: &M, mask: &mut [f32]) {
        for (i, m) in mask.iter_mut().enumerate() {
            if f.contains(i as u64) {
                *m = 1.0 - *m;
            }
        }
    }

    fn check_batch_parity<F: Fingerprint, const A: usize>(n: usize, d: u64, seed: u64) {
        let keys = random_indexes(n, d, seed);
        let f = BinaryFuse::<F, A>::build(&keys).unwrap();
        // decode_mask_into vs the scalar oracle, bitwise.
        let mut mask: Vec<f32> = (0..d).map(|i| (i % 3 == 0) as u32 as f32).collect();
        let mut expect = mask.clone();
        scalar_decode_oracle(&f, &mut expect);
        f.decode_mask_into(&mut mask);
        assert_eq!(mask, expect, "decode_mask_into diverged from scalar oracle");
        // Range-restricted kernel: tiling [0, d) with uneven ranges must
        // reproduce the full sweep bitwise (the dimension-sharded drain's
        // per-shard decode contract).
        let mut tiled: Vec<f32> = (0..d).map(|i| (i % 3 == 0) as u32 as f32).collect();
        let cuts = [0, (d / 3) as usize, (d / 3 + d / 7 + 1) as usize, d as usize];
        for w in cuts.windows(2) {
            let (lo, hi) = (w[0], w[1].max(w[0]));
            f.decode_mask_into_range(&mut tiled[lo..hi], lo);
        }
        assert_eq!(tiled, expect, "range tiling diverged from full decode");
        // contains_batch vs contains on a mixed member/non-member probe set.
        let mut rng = crate::util::rng::Xoshiro256pp::new(seed ^ 0xbb);
        let probes: Vec<u64> = (0..4_000).map(|_| rng.below(2 * d)).collect();
        let mut got = vec![false; probes.len()];
        f.contains_batch(&probes, &mut got);
        for (j, &k) in probes.iter().enumerate() {
            assert_eq!(got[j], f.contains(k), "key {k}");
        }
    }

    #[test]
    fn batched_kernels_match_scalar_oracle() {
        // Odd d exercises the partial tail block; n=0 the empty filter.
        for (n, d) in [(0usize, 1_000u64), (1, 257), (300, 10_001), (5_000, 100_003)] {
            check_batch_parity::<u8, 4>(n, d, 21 + n as u64);
            check_batch_parity::<u8, 3>(n, d, 22 + n as u64);
            check_batch_parity::<u16, 4>(n, d, 23 + n as u64);
            check_batch_parity::<u32, 4>(n, d, 24 + n as u64);
        }
    }

    #[test]
    fn empty_filter_contains_nothing() {
        let f = BinaryFuse::<u8, 4>::build(&[]).unwrap();
        for k in 0..1000u64 {
            assert!(!f.contains(k));
        }
        assert_eq!(f.bits_per_entry(), 0.0);
    }

    #[test]
    fn duplicate_keys_deduped() {
        let keys = vec![5u64, 5, 5, 9, 9, 1];
        let f = BinaryFuse::<u8, 4>::build(&keys).unwrap();
        assert_eq!(f.num_keys(), 3);
        assert!(f.contains(5) && f.contains(9) && f.contains(1));
    }
}
