//! CRC-32 (IEEE 802.3, as used by PNG) and Adler-32 (zlib), from scratch.
//! Cross-validated against the `crc32fast` crate in tests only.

/// CRC-32 lookup table (reflected polynomial 0xEDB88320), built at first use.
struct Crc32Table([u32; 256]);

impl Crc32Table {
    const fn build() -> Self {
        let mut table = [0u32; 256];
        let mut i = 0;
        while i < 256 {
            let mut c = i as u32;
            let mut k = 0;
            while k < 8 {
                c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
                k += 1;
            }
            table[i] = c;
            i += 1;
        }
        Self(table)
    }
}

static CRC_TABLE: Crc32Table = Crc32Table::build();

/// Streaming CRC-32.
#[derive(Clone, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Self { state: 0xffff_ffff }
    }

    #[inline]
    pub fn update(&mut self, data: &[u8]) {
        let mut c = self.state;
        for &b in data {
            c = CRC_TABLE.0[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    pub fn finalize(&self) -> u32 {
        self.state ^ 0xffff_ffff
    }
}

pub fn crc32(data: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(data);
    c.finalize()
}

/// Adler-32 (RFC 1950 §8.2).
pub fn adler32(data: &[u8]) -> u32 {
    const MOD: u32 = 65_521;
    let mut a: u32 = 1;
    let mut b: u32 = 0;
    // Process in chunks small enough to defer the modulo.
    for chunk in data.chunks(5552) {
        for &byte in chunk {
            a += byte as u32;
            b += a;
        }
        a %= MOD;
        b %= MOD;
    }
    (b << 16) | a
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414f_a339);
    }

    #[test]
    fn crc32_matches_crc32fast() {
        let mut rng = crate::util::rng::Xoshiro256pp::new(1);
        for len in [0usize, 1, 7, 255, 4096, 70_001] {
            let data: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let mut h = crc32fast::Hasher::new();
            h.update(&data);
            assert_eq!(crc32(&data), h.finalize(), "len={len}");
        }
    }

    #[test]
    fn crc32_streaming_equals_oneshot() {
        let data: Vec<u8> = (0..10_000u32).map(|i| (i * 7) as u8).collect();
        let mut c = Crc32::new();
        for chunk in data.chunks(97) {
            c.update(chunk);
        }
        assert_eq!(c.finalize(), crc32(&data));
    }

    #[test]
    fn adler32_known_vectors() {
        assert_eq!(adler32(b""), 1);
        assert_eq!(adler32(b"Wikipedia"), 0x11e6_0398);
    }
}
