//! Uplink transport abstraction: clients hand `Encoded` payloads to a
//! [`TransportSender`]; the server drains a [`Transport`] in arrival order.
//!
//! Every message carries its own byte and timing accounting so the round
//! loop measures honest wire costs without threading bookkeeping through
//! client code. The in-process [`ChannelTransport`] backs simulations; a
//! networked implementation only has to provide the same two traits.

use crate::compress::Encoded;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// What a client produced for the round: an encoded update, or a terminal
/// failure (reported in-band so the server never waits on a dead client).
#[derive(Clone, Debug)]
pub enum Payload {
    Update(Encoded),
    Failed(String),
}

/// One uplink message.
#[derive(Clone, Debug)]
pub struct WireMessage {
    pub round: usize,
    pub client_id: usize,
    /// Participant index within the round (position in
    /// `RoundPlan::participants`) — the server's aggregation slot.
    pub slot: usize,
    pub payload: Payload,
    /// Client-side encode wall time.
    pub enc_secs: f64,
    /// Mean local training loss this round.
    pub loss: f32,
}

impl WireMessage {
    pub fn payload_bytes(&self) -> usize {
        match &self.payload {
            Payload::Update(enc) => enc.bytes.len(),
            Payload::Failed(_) => 0,
        }
    }
}

/// Aggregate transport accounting.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TransportStats {
    /// Messages handed to the sender side.
    pub sent_messages: u64,
    /// Sum of payload bytes handed to the sender side.
    pub sent_payload_bytes: u64,
    /// Messages the server end has drained.
    pub received_messages: u64,
    /// Total send→receive queue latency over drained messages.
    pub transit_secs: f64,
}

/// Client-side handle. Cheap to clone; every worker thread owns one.
pub trait TransportSender: Send {
    fn send(&self, msg: WireMessage) -> Result<()>;
    fn clone_sender(&self) -> Box<dyn TransportSender>;
}

impl Clone for Box<dyn TransportSender> {
    fn clone(&self) -> Self {
        self.clone_sender()
    }
}

/// Server-side end of an uplink.
pub trait Transport {
    /// Next message in arrival order; `None` once every sender handle has
    /// been dropped and the queue is drained.
    fn recv(&mut self) -> Option<WireMessage>;
    fn stats(&self) -> TransportStats;
}

struct Stamped {
    msg: WireMessage,
    sent_at: Instant,
}

#[derive(Default)]
struct Counters {
    messages: AtomicU64,
    payload_bytes: AtomicU64,
}

/// In-process MPSC transport for simulations.
pub struct ChannelTransport {
    rx: mpsc::Receiver<Stamped>,
    counters: Arc<Counters>,
    received: u64,
    transit_secs: f64,
}

struct ChannelSender {
    tx: mpsc::Sender<Stamped>,
    counters: Arc<Counters>,
}

impl ChannelTransport {
    /// Create the server end plus the root sender handle. Dropping the root
    /// handle and all its clones closes the channel, which is how `recv`
    /// learns that no more updates can arrive.
    pub fn new() -> (Self, Box<dyn TransportSender>) {
        let (tx, rx) = mpsc::channel();
        let counters = Arc::new(Counters::default());
        let server = Self {
            rx,
            counters: counters.clone(),
            received: 0,
            transit_secs: 0.0,
        };
        (server, Box::new(ChannelSender { tx, counters }))
    }
}

impl TransportSender for ChannelSender {
    fn send(&self, msg: WireMessage) -> Result<()> {
        self.counters
            .payload_bytes
            .fetch_add(msg.payload_bytes() as u64, Ordering::Relaxed);
        self.counters.messages.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Stamped {
                msg,
                sent_at: Instant::now(),
            })
            .map_err(|_| anyhow!("uplink closed: server end dropped"))
    }

    fn clone_sender(&self) -> Box<dyn TransportSender> {
        Box::new(ChannelSender {
            tx: self.tx.clone(),
            counters: self.counters.clone(),
        })
    }
}

impl Transport for ChannelTransport {
    fn recv(&mut self) -> Option<WireMessage> {
        match self.rx.recv() {
            Ok(stamped) => {
                self.received += 1;
                self.transit_secs += stamped.sent_at.elapsed().as_secs_f64();
                Some(stamped.msg)
            }
            Err(_) => None,
        }
    }

    fn stats(&self) -> TransportStats {
        TransportStats {
            sent_messages: self.counters.messages.load(Ordering::Relaxed),
            sent_payload_bytes: self.counters.payload_bytes.load(Ordering::Relaxed),
            received_messages: self.received,
            transit_secs: self.transit_secs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(slot: usize, n_bytes: usize) -> WireMessage {
        WireMessage {
            round: 0,
            client_id: slot,
            slot,
            payload: Payload::Update(Encoded {
                bytes: vec![0xAB; n_bytes],
            }),
            enc_secs: 0.001,
            loss: 0.5,
        }
    }

    #[test]
    fn delivers_in_order_and_accounts_bytes() {
        let (mut server, sender) = ChannelTransport::new();
        let s2 = sender.clone();
        sender.send(msg(0, 10)).unwrap();
        s2.send(msg(1, 30)).unwrap();
        drop(sender);
        drop(s2);
        let a = server.recv().unwrap();
        let b = server.recv().unwrap();
        assert_eq!((a.slot, b.slot), (0, 1));
        assert!(server.recv().is_none(), "closed after all senders drop");
        let st = server.stats();
        assert_eq!(st.sent_messages, 2);
        assert_eq!(st.sent_payload_bytes, 40);
        assert_eq!(st.received_messages, 2);
        assert!(st.transit_secs >= 0.0);
    }

    #[test]
    fn failure_payloads_count_zero_bytes() {
        let (mut server, sender) = ChannelTransport::new();
        sender
            .send(WireMessage {
                round: 3,
                client_id: 9,
                slot: 0,
                payload: Payload::Failed("oom".into()),
                enc_secs: 0.0,
                loss: 0.0,
            })
            .unwrap();
        drop(sender);
        let m = server.recv().unwrap();
        assert_eq!(m.payload_bytes(), 0);
        assert!(matches!(m.payload, Payload::Failed(ref e) if e == "oom"));
    }

    #[test]
    fn send_after_server_drop_errors() {
        let (server, sender) = ChannelTransport::new();
        drop(server);
        assert!(sender.send(msg(0, 1)).is_err());
    }

    #[test]
    fn senders_work_across_threads() {
        let (mut server, sender) = ChannelTransport::new();
        std::thread::scope(|scope| {
            for t in 0..4 {
                let s = sender.clone();
                scope.spawn(move || s.send(msg(t, t + 1)).unwrap());
            }
        });
        drop(sender);
        let mut slots: Vec<usize> = std::iter::from_fn(|| server.recv().map(|m| m.slot)).collect();
        slots.sort_unstable();
        assert_eq!(slots, vec![0, 1, 2, 3]);
        assert_eq!(server.stats().sent_payload_bytes, 1 + 2 + 3 + 4);
    }
}
