//! **MaskRN** (codec 10) — federated masking over a frozen common-random-noise
//! dictionary, after *Masked Random Noise* (arxiv 2408.03220).
//!
//! MRN's client never ships weights: the server broadcasts a seed, every
//! party expands it into a frozen random-noise dictionary added to the
//! global model, and the client uplink is a learned Bernoulli mask selecting
//! which noise entries to keep. Mapped onto this repo's shared-seed CRN
//! machinery, the per-(round, client) seed already known to both ends
//! derives a **noise gate** per coordinate (a seeded hash bit at the codec's
//! fixed dictionary density): coordinate `i` carries a noise entry this
//! round iff the gate opens. The client runs DeltaMask's own Δ′ selection
//! (same KL ranking, same κ truncation — the selection kernel is shared,
//! not reimplemented) and then ships only the selected flips whose
//! coordinate is **in the dictionary**; flips outside it are, by
//! construction, not expressible as a noise-entry choice and are dropped on
//! the client, never on the wire.
//!
//! The index set reuses the codec-9 pco wire stage verbatim (sorted u32
//! indexes, delta-coded quantile-bin stream):
//!
//! ```text
//! tag(1)=8  version(1)=1  payload_len(4)  payload = pco stream of gated Δ′
//! ```
//!
//! Decode totality: header fields are validated, the pco decoder is total
//! and `d`-bounded, indexes must be strictly increasing and `< d`, and —
//! the MRN-specific clause — **every index must pass the receiver's own
//! seed-derived noise gate**. A record claiming a flip outside the round's
//! dictionary cannot have come from an honest encoder with the same seed,
//! so it is rejected as corrupt (`Err`, never a panic or a silent
//! mask-noise write). The gate is a pure per-index hash, so range decoding
//! needs no dictionary materialization: a parsed record is its own
//! [`MaskRangeDecoder`], exactly like codec 9.

use super::deltamask::DeltaMaskCodec;
use super::{
    wire, DecodeCtx, EncodeCtx, EncodeScratch, Encoded, Family, Ranking, ScratchPool, Update,
    UpdateCodec,
};
use crate::codec::pco;
use crate::hash::mix_split;
use anyhow::{ensure, Result};

/// Record tag: next free tag after the v1 filter-tag space (0..=6) and the
/// codec-9 pco record (7).
pub const RECORD_TAG: u8 = 8;
/// Record format version.
pub const RECORD_VERSION: u8 = 1;

/// Salt folded into the shared per-(round, client) seed before deriving the
/// noise gate, so the dictionary stream is independent of every other
/// codec-internal use of the seed (mask sampling, rotations, dithers).
const NOISE_SALT: u64 = 0x6d61_736b_5f72_6e01; // "mask_rn" ‖ 0x01

/// Fraction of coordinates carrying a noise entry each round. Codec-fixed
/// (changing it is a wire-format change: both ends gate with it).
pub const NOISE_DENSITY: f64 = 0.5;

/// Does coordinate `i` carry a noise-dictionary entry under `seed`?
/// Pure per-index hash — O(1) random access, no materialized dictionary —
/// which is what makes range decoding and sharded drains free.
#[inline]
pub fn in_noise_dictionary(i: u32, seed: u64) -> bool {
    let threshold = (NOISE_DENSITY * 4_294_967_296.0) as u64; // density · 2^32
    (mix_split(i as u64, seed ^ NOISE_SALT) >> 32) < threshold
}

#[derive(Clone, Debug)]
pub struct MaskRnCodec {
    pub ranking: Ranking,
}

impl Default for MaskRnCodec {
    fn default() -> Self {
        Self {
            ranking: Ranking::Kl,
        }
    }
}

impl MaskRnCodec {
    /// Parse + validate a record into the sorted gated-flip index set.
    /// Shared by every decode path so malformed-record rejection is uniform;
    /// the noise gate is checked here, making the dictionary load-bearing at
    /// decode (not just an encoder-side filter).
    fn parse_indexes(&self, bytes: &[u8], ctx: &DecodeCtx) -> Result<Vec<u32>> {
        ensure!(bytes.len() >= 6, "maskrn record too short");
        ensure!(
            bytes[0] == RECORD_TAG,
            "not a maskrn record (tag {})",
            bytes[0]
        );
        ensure!(
            bytes[1] == RECORD_VERSION,
            "unknown maskrn record version {}",
            bytes[1]
        );
        let mut r = wire::Reader::new(&bytes[2..]);
        let payload_len = r.u32()? as usize;
        let rest = &bytes[2 + r.pos..];
        ensure!(rest.len() == payload_len, "payload length mismatch");
        let idx = pco::decompress_u32s(rest, ctx.d).map_err(|e| anyhow::anyhow!("pco: {e}"))?;
        let mut prev = None;
        for &i in &idx {
            ensure!((i as usize) < ctx.d, "index {i} out of range (d={})", ctx.d);
            if let Some(p) = prev {
                ensure!(i > p, "indexes not strictly increasing");
            }
            prev = Some(i);
            ensure!(
                in_noise_dictionary(i, ctx.seed),
                "index {i} outside the round's noise dictionary"
            );
        }
        Ok(idx)
    }
}

/// A parsed record is its own range decoder (the gate was already verified
/// at parse): two binary searches per range over the sorted index set.
struct GatedIndexFlips {
    idx: Vec<u32>,
}

impl super::MaskRangeDecoder for GatedIndexFlips {
    fn decode_range(&self, range: std::ops::Range<usize>, mask: &mut [f32]) {
        debug_assert_eq!(mask.len(), range.len());
        let lo = self.idx.partition_point(|&i| (i as usize) < range.start);
        let hi = self.idx.partition_point(|&i| (i as usize) < range.end);
        for &i in &self.idx[lo..hi] {
            let j = i as usize - range.start;
            mask[j] = 1.0 - mask[j];
        }
    }
}

impl UpdateCodec for MaskRnCodec {
    fn name(&self) -> &'static str {
        "maskrn"
    }

    fn family(&self) -> Family {
        Family::Mask
    }

    fn encode(&self, ctx: &EncodeCtx) -> Result<Encoded> {
        self.encode_with(ctx, &mut EncodeScratch::default())
    }

    /// Encode reusing the caller's scratch: Δ′ selection is DeltaMask's
    /// fused kernel, the gate filter is a streaming pass over the selected
    /// key set, and the quickselect index buffer is recycled as the u32
    /// sort buffer — steady-state encodes allocate only the output bytes.
    fn encode_with(&self, ctx: &EncodeCtx, scratch: &mut EncodeScratch) -> Result<Encoded> {
        let selector = DeltaMaskCodec {
            ranking: self.ranking,
            ..Default::default()
        };
        selector.select_updates_into(ctx, scratch);
        scratch.rank.clear();
        scratch.rank.extend(
            scratch
                .keys
                .iter()
                .map(|&k| k as u32)
                .filter(|&i| in_noise_dictionary(i, ctx.seed)),
        );
        scratch.rank.sort_unstable();
        let payload = pco::compress_u32s(&scratch.rank);

        let mut bytes = Vec::with_capacity(payload.len() + 6);
        bytes.push(RECORD_TAG);
        bytes.push(RECORD_VERSION);
        wire::put_u32(&mut bytes, payload.len() as u32);
        bytes.extend_from_slice(&payload);
        Ok(Encoded { bytes })
    }

    fn decode(&self, bytes: &[u8], ctx: &DecodeCtx) -> Result<Update> {
        let idx = self.parse_indexes(bytes, ctx)?;
        let mut mask = ctx.mask_g.to_vec();
        for &i in &idx {
            mask[i as usize] = 1.0 - mask[i as usize];
        }
        Ok(Update::Mask(mask))
    }

    fn decode_pooled(&self, bytes: &[u8], ctx: &DecodeCtx, pool: &ScratchPool) -> Result<Update> {
        // Parse before leasing, so malformed records never touch the pool.
        let idx = self.parse_indexes(bytes, ctx)?;
        let mut mask = pool.take_copy(ctx.mask_g);
        for &i in &idx {
            mask[i as usize] = 1.0 - mask[i as usize];
        }
        Ok(Update::Mask(mask))
    }

    fn range_decoder(
        &self,
        bytes: &[u8],
        ctx: &DecodeCtx,
    ) -> Result<Option<Box<dyn super::MaskRangeDecoder>>> {
        let idx = self.parse_indexes(bytes, ctx)?;
        Ok(Some(Box::new(GatedIndexFlips { idx })))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::sample_mask_seeded;
    use crate::util::rng::Xoshiro256pp;

    fn make_ctx<'a>(
        d: usize,
        theta_k: &'a [f32],
        theta_g: &'a [f32],
        mask_k: &'a [f32],
        mask_g: &'a [f32],
        kappa: f64,
        seed: u64,
    ) -> EncodeCtx<'a> {
        EncodeCtx {
            d,
            theta_k,
            theta_g,
            mask_k,
            mask_g,
            s_k: &[],
            s_g: &[],
            kappa,
            seed,
        }
    }

    fn setup(d: usize, drift: f32, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Xoshiro256pp::new(seed);
        let theta_g: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
        let theta_k: Vec<f32> = theta_g
            .iter()
            .map(|&p| (p + drift * (rng.next_f32() - 0.5)).clamp(0.01, 0.99))
            .collect();
        let mut mask_g = Vec::new();
        sample_mask_seeded(&theta_g, 7, &mut mask_g);
        let mut mask_k = Vec::new();
        sample_mask_seeded(&theta_k, 8, &mut mask_k);
        (theta_k, theta_g, mask_k, mask_g)
    }

    #[test]
    fn dictionary_density_is_near_nominal_and_seed_dependent() {
        let d = 100_000u32;
        let hits = (0..d).filter(|&i| in_noise_dictionary(i, 11)).count();
        let frac = hits as f64 / d as f64;
        assert!(
            (frac - NOISE_DENSITY).abs() < 0.01,
            "density {frac} vs nominal {NOISE_DENSITY}"
        );
        // A different round/client seed opens a different dictionary.
        let differs = (0..d)
            .filter(|&i| in_noise_dictionary(i, 11) != in_noise_dictionary(i, 12))
            .count();
        assert!(differs > (d as usize) / 4, "gates barely differ: {differs}");
    }

    #[test]
    fn decode_flips_exactly_the_gated_selected_set() {
        let d = 50_000;
        let (tk, tg, mk, mg) = setup(d, 0.2, 42);
        let codec = MaskRnCodec::default();
        let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 0.6, 99);
        let selected = DeltaMaskCodec::default().select_updates(&ctx);
        let enc = codec.encode(&ctx).unwrap();
        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mg,
            s_g: &[],
            seed: 99,
        };
        let Update::Mask(m) = codec.decode(&enc.bytes, &dec_ctx).unwrap() else {
            panic!()
        };
        let mut expect = mg.clone();
        let mut gated = 0usize;
        for &k in &selected {
            let i = k as u32;
            if in_noise_dictionary(i, 99) {
                expect[i as usize] = 1.0 - expect[i as usize];
                gated += 1;
            }
        }
        assert_eq!(m, expect, "decode must flip exactly the gated Δ′ set");
        // At density 0.5 roughly half the selection must survive the gate —
        // if nothing (or everything) did, the gate is not wired in.
        assert!(gated > selected.len() / 4 && gated < selected.len() * 3 / 4);
    }

    #[test]
    fn scratch_pooled_and_range_paths_are_identical() {
        let d = 30_000;
        let (tk, tg, mk, mg) = setup(d, 0.1, 43);
        let codec = MaskRnCodec::default();
        let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 0.8, 7);
        let plain = codec.encode(&ctx).unwrap();
        let mut scratch = EncodeScratch::default();
        let scratched = codec.encode_with(&ctx, &mut scratch).unwrap();
        assert_eq!(plain.bytes, scratched.bytes);
        let again = codec.encode_with(&ctx, &mut scratch).unwrap();
        assert_eq!(plain.bytes, again.bytes);

        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mg,
            s_g: &[],
            seed: 7,
        };
        let Update::Mask(want) = codec.decode(&plain.bytes, &dec_ctx).unwrap() else {
            panic!()
        };
        let pool = ScratchPool::new();
        let Update::Mask(got) = codec.decode_pooled(&plain.bytes, &dec_ctx, &pool).unwrap()
        else {
            panic!()
        };
        assert_eq!(got, want);
        pool.put(got);
        let Update::Mask(got2) = codec.decode_pooled(&plain.bytes, &dec_ctx, &pool).unwrap()
        else {
            panic!()
        };
        assert_eq!(got2, want);
        assert_eq!(pool.spares(), 0, "pooled decode must draw from the pool");

        // Range tiling reproduces the full decode bitwise.
        let rd = codec
            .range_decoder(&plain.bytes, &dec_ctx)
            .unwrap()
            .expect("maskrn records support range decoding");
        let mut tiled = mg.clone();
        let cuts = [0usize, 1, 2, 2, d / 3, d / 2 + 7, d];
        for w in cuts.windows(2) {
            let (lo, hi) = (w[0], w[1]);
            rd.decode_range(lo..hi, &mut tiled[lo..hi]);
        }
        assert_eq!(tiled, want);
    }

    #[test]
    fn wrong_seed_rejects_out_of_dictionary_flips() {
        // An honest record decoded under a different per-(round, client)
        // seed claims flips outside *that* seed's dictionary — at density
        // 0.5 the survival probability per index is 1/2, so any non-trivial
        // record must be rejected.
        let d = 20_000;
        let (tk, tg, mk, mg) = setup(d, 0.2, 45);
        let codec = MaskRnCodec::default();
        let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 1.0, 99);
        let enc = codec.encode(&ctx).unwrap();
        assert!(enc.bytes.len() > 8, "fixture must carry a non-empty index set");
        let wrong_seed = DecodeCtx {
            d,
            mask_g: &mg,
            s_g: &[],
            seed: 100,
        };
        assert!(codec.decode(&enc.bytes, &wrong_seed).is_err());
        assert!(codec.range_decoder(&enc.bytes, &wrong_seed).is_err());
    }

    #[test]
    fn gated_record_is_smaller_than_the_ungated_pco_record() {
        // The dictionary drops ~half the selected flips, so the maskrn
        // record must undercut codec 9's full index stream on the same ctx.
        let d = 100_000;
        let (tk, tg, mk, mg) = setup(d, 0.3, 46);
        let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 0.8, 99);
        let full = super::super::DeltaMaskPcoCodec::default()
            .encode(&ctx)
            .unwrap()
            .bytes
            .len();
        let gated = MaskRnCodec::default().encode(&ctx).unwrap().bytes.len();
        assert!(
            gated < full,
            "gated={gated} must be smaller than ungated pco={full}"
        );
    }

    #[test]
    fn empty_delta_roundtrip() {
        let d = 1000;
        let theta = vec![0.5f32; d];
        let mut mask = Vec::new();
        sample_mask_seeded(&theta, 1, &mut mask);
        let codec = MaskRnCodec::default();
        let ctx = make_ctx(d, &theta, &theta, &mask, &mask, 0.8, 5);
        let enc = codec.encode(&ctx).unwrap();
        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mask,
            s_g: &[],
            seed: 5,
        };
        let Update::Mask(m) = codec.decode(&enc.bytes, &dec_ctx).unwrap() else {
            panic!()
        };
        assert_eq!(m, mask);
    }

    #[test]
    fn malformed_records_error_instead_of_panicking() {
        let d = 10_000;
        let (tk, tg, mk, mg) = setup(d, 0.1, 44);
        let codec = MaskRnCodec::default();
        let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 1.0, 99);
        let enc = codec.encode(&ctx).unwrap();
        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mg,
            s_g: &[],
            seed: 99,
        };
        // Wrong record tag (a v1 filter record, then codec 9) and version.
        let mut bad = enc.bytes.clone();
        bad[0] = 0;
        assert!(codec.decode(&bad, &dec_ctx).is_err());
        let mut bad = enc.bytes.clone();
        bad[0] = super::super::deltamask_pco::RECORD_TAG;
        assert!(codec.decode(&bad, &dec_ctx).is_err());
        let mut bad = enc.bytes.clone();
        bad[1] = RECORD_VERSION + 1;
        assert!(codec.decode(&bad, &dec_ctx).is_err());
        // Truncations.
        for cut in [0, 3, 6, enc.bytes.len() - 1] {
            assert!(codec.decode(&enc.bytes[..cut], &dec_ctx).is_err(), "cut={cut}");
        }
        // A v1 decoder must reject tag-8 records rather than misread them.
        assert!(DeltaMaskCodec::default().decode(&enc.bytes, &dec_ctx).is_err());
        // And d bounds the index range.
        let small_mg = vec![0.0f32; 4];
        let small_ctx = DecodeCtx {
            d: 4,
            mask_g: &small_mg,
            s_g: &[],
            seed: 99,
        };
        assert!(codec.decode(&enc.bytes, &small_ctx).is_err());
    }
}
