//! **Table 2 / Figure 3** — IID Dir(10) evaluation of DeltaMask vs all
//! baselines across datasets at ρ ∈ {0.2, 1.0}.
//!
//!     cargo bench --bench table2_iid            # reduced scale
//!     cargo bench --bench table2_iid -- --full  # paper scale (slow)
//!
//! Reduced scale shrinks F/N/R (DESIGN.md §4); the claims checked are the
//! paper's *shape*: DeltaMask ≈ FedPM accuracy at several-fold lower bpp,
//! FedPM the best compressed baseline, FT the accuracy ceiling at 32 bpp.

use deltamask::bench::{bench_datasets, paper_methods, BenchScale, Table};
use deltamask::fl::run_experiment;
use deltamask::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let scale = BenchScale::from_args(&args);
    let datasets = bench_datasets(&args);

    for rho in [0.2f64, 1.0] {
        let mut table = Table::new(
            &format!("Table 2 (IID Dir(10), rho={rho})"),
            &["method", "dataset", "acc", "avg bpp"],
        );
        let mut summary = Table::new(
            &format!("Table 2 summary (rho={rho})"),
            &["method", "avg acc", "avg bpp"],
        );
        for method in paper_methods() {
            let mut accs = Vec::new();
            let mut bpps = Vec::new();
            for dataset in &datasets {
                let mut cfg = scale.config(dataset, method);
                cfg.rho = rho;
                if rho < 1.0 {
                    cfg.rounds = (cfg.rounds * 2).max(cfg.rounds + 10);
                }
                let res = run_experiment(&cfg)?;
                let acc = res.final_accuracy();
                let bpp = res.avg_bpp();
                table.row(vec![
                    method.to_string(),
                    dataset.to_string(),
                    format!("{:.4}", acc),
                    format!("{:.4}", bpp),
                ]);
                accs.push(acc);
                bpps.push(bpp);
                eprintln!("  [rho={rho}] {method}/{dataset}: acc={acc:.4} bpp={bpp:.4}");
            }
            summary.row(vec![
                method.to_string(),
                format!("{:.4}", deltamask::util::stats::mean(&accs)),
                format!("{:.4}", deltamask::util::stats::mean(&bpps)),
            ]);
        }
        table.print();
        summary.print();
        table.save(&format!("table2_iid_rho{}", (rho * 10.0) as u32));
        summary.save(&format!("table2_iid_summary_rho{}", (rho * 10.0) as u32));
    }
    Ok(())
}
