//! **Pco-style numeric latent compressor** — a self-contained,
//! pcodec-inspired codec for the numeric sequences the wire path actually
//! carries (sorted mask-index sets, score quantization levels), instead of
//! treating them as opaque bytes for PNG + DEFLATE.
//!
//! Pipeline (mirroring pcodec's architecture at a deliberately small scale):
//!
//! 1. **Delta coding** — three modes, chosen per stream by exact bit-cost:
//!    `Direct` (values as-is), `Delta` (first differences — sorted index
//!    sets become small gaps), `DoubleDelta` (second differences —
//!    arithmetic-ish ramps collapse to near-zero latents). Signed
//!    differences are zigzag-mapped to unsigned latents.
//! 2. **GCD extraction** — a common divisor of all latents is factored out
//!    and stored once (quantized grids pay bits for their step size once,
//!    not per value).
//! 3. **Bin-based latent histogram** — the latents are split into
//!    `2^k` equal-count quantile bins (k ≤ [`MAX_BIN_BITS`], chosen by
//!    exact cost); each bin stores its lower bound and an offset width, and
//!    each latent is coded as `k` bin-index bits plus `offset_bits[bin]`
//!    offset bits. This is adaptive-bit packing: dense regions of the value
//!    distribution get narrow offsets, outliers ride in their own bins.
//! 4. **Word-aligned batch decode** — when `k + offset_bits ≤ 32` for every
//!    bin (the common case), the decoder reads each latent with a single
//!    32-bit peek and one consume, in the style of the repo's other blocked
//!    kernels; a two-phase scalar path (kept as the tests' parity oracle)
//!    handles wide latents.
//!
//! Floats ride through an order-preserving bijection to `u32`
//! ([`f32_to_ord_u32`]) so the integer delta/bin machinery applies
//! unchanged — the "float-to-int latent split".
//!
//! Decode is **total**: truncated, bit-flipped or random bytes return
//! `Err`, never panic — the body is decoded against an explicit bit budget
//! (the underlying [`BitReader`] zero-pads past the end, so truncation must
//! be detected by accounting, not by read failures), every header field is
//! bounds-checked, and all arithmetic on untrusted latents is checked.

use super::bitio::{BitReader, BitWriter};

/// Stream format version byte (first byte of every stream).
pub const VERSION: u8 = 1;
/// Maximum bin-index width: up to `2^7 = 128` quantile bins.
pub const MAX_BIN_BITS: u32 = 7;

const MODE_DIRECT: u8 = 0;
const MODE_DELTA: u8 = 1;
const MODE_DOUBLE_DELTA: u8 = 2;

#[inline]
fn zigzag(v: i64) -> u64 {
    (v.wrapping_shl(1) ^ (v >> 63)) as u64
}

#[inline]
fn unzigzag(u: u64) -> i64 {
    ((u >> 1) as i64) ^ -((u & 1) as i64)
}

#[inline]
fn bits_for(w: u64) -> u32 {
    64 - w.leading_zeros()
}

fn gcd_u64(mut a: u64, mut b: u64) -> u64 {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

/// Order-preserving bijection `f32 → u32`: negative floats map below
/// positive ones, and within each sign the integer order matches the float
/// order. Total (NaNs and infinities round-trip bit-exactly).
#[inline]
pub fn f32_to_ord_u32(v: f32) -> u32 {
    let b = v.to_bits();
    if b & 0x8000_0000 != 0 {
        !b
    } else {
        b ^ 0x8000_0000
    }
}

/// Inverse of [`f32_to_ord_u32`].
#[inline]
pub fn ord_u32_to_f32(u: u32) -> f32 {
    let b = if u & 0x8000_0000 != 0 {
        u ^ 0x8000_0000
    } else {
        !u
    };
    f32::from_bits(b)
}

#[inline]
fn write_bits64(w: &mut BitWriter, v: u64, n: u32) {
    debug_assert!(n <= 64);
    if n <= 32 {
        let mask = if n == 32 { u32::MAX } else { (1u32 << n) - 1 };
        w.write_bits(v as u32 & mask, n);
    } else {
        w.write_bits(v as u32, 32);
        let hi = (v >> 32) as u32 & ((1u32 << (n - 32)) - 1);
        w.write_bits(hi, n - 32);
    }
}

#[inline]
fn read_bits64(r: &mut BitReader, n: u32) -> u64 {
    debug_assert!(n <= 64);
    if n <= 32 {
        r.read_bits(n) as u64
    } else {
        let lo = r.read_bits(32) as u64;
        let hi = r.read_bits(n - 32) as u64;
        lo | (hi << 32)
    }
}

/// Latent sequences for each delta mode. `None` when the mode is not
/// applicable at this length (DoubleDelta needs two anchors).
fn latents_for_mode(values: &[u32], mode: u8) -> Option<Vec<u64>> {
    match mode {
        MODE_DIRECT => Some(values.iter().map(|&v| v as u64).collect()),
        MODE_DELTA => {
            if values.is_empty() {
                return None;
            }
            Some(
                values
                    .windows(2)
                    .map(|w| zigzag(w[1] as i64 - w[0] as i64))
                    .collect(),
            )
        }
        MODE_DOUBLE_DELTA => {
            if values.len() < 2 {
                return None;
            }
            let mut prev_d = values[1] as i64 - values[0] as i64;
            Some(
                values
                    .windows(2)
                    .skip(1)
                    .map(|w| {
                        let d = w[1] as i64 - w[0] as i64;
                        let out = zigzag(d - prev_d);
                        prev_d = d;
                        out
                    })
                    .collect(),
            )
        }
        _ => unreachable!(),
    }
}

/// Equal-count quantile bin table over `sorted` (non-empty): per-bin
/// (lower bound, offset width). Chunk `c` of the sorted latents covers
/// `[c·n/bins, (c+1)·n/bins)`; its lower is the chunk minimum and its
/// offset width spans the chunk. Encoding assigns each latent to the
/// **rightmost** bin whose lower is ≤ the latent, which always fits its
/// offset budget: if that bin is later than the latent's own chunk, the
/// latent equals the later bin's lower (offset 0).
fn bin_table(sorted: &[u64], k: u32) -> (Vec<u64>, Vec<u32>) {
    let n = sorted.len();
    let bins = 1usize << k;
    debug_assert!(bins <= n);
    let mut lowers = Vec::with_capacity(bins);
    let mut obs = Vec::with_capacity(bins);
    for c in 0..bins {
        let start = c * n / bins;
        let end = (c + 1) * n / bins;
        let lo = sorted[start];
        let width = sorted[end - 1] - lo;
        lowers.push(lo);
        obs.push(if width == 0 { 0 } else { bits_for(width) });
    }
    (lowers, obs)
}

/// Exact coded size in bits of body + bin table for this `k` (the mode/k
/// search objective; header/anchor bytes are added by the caller).
fn table_cost_bits(sorted: &[u64], k: u32) -> u64 {
    let n = sorted.len();
    let bins = 1usize << k;
    let mut bits = bins as u64 * (64 + 8); // lower u64 + offset-width u8 per bin
    bits += n as u64 * k as u64;
    for c in 0..bins {
        let start = c * n / bins;
        let end = (c + 1) * n / bins;
        let width = sorted[end - 1] - sorted[start];
        let ob = if width == 0 { 0 } else { bits_for(width) } as u64;
        bits += (end - start) as u64 * ob;
    }
    bits
}

/// Compress a `u32` sequence. Always succeeds; an incompressible stream
/// costs at most a small constant over `Direct` mode with one wide bin.
pub fn compress_u32s(values: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(values.len() * 2 + 32);
    out.push(VERSION);
    out.extend_from_slice(&(values.len() as u32).to_le_bytes());
    if values.is_empty() {
        return out;
    }

    // Search (mode, k) by exact coded size; anchors charge 4 bytes each.
    let mut best: Option<(u8, u32, Vec<u64>)> = None; // (mode, k, latents)
    let mut best_cost = u64::MAX;
    for mode in [MODE_DIRECT, MODE_DELTA, MODE_DOUBLE_DELTA] {
        let Some(latents) = latents_for_mode(values, mode) else {
            continue;
        };
        let anchor_bits = 32 * mode as u64;
        if latents.is_empty() {
            // Anchors alone carry the whole stream (n ≤ mode).
            if anchor_bits < best_cost {
                best_cost = anchor_bits;
                best = Some((mode, 0, latents));
            }
            continue;
        }
        let g = latents.iter().fold(0u64, |acc, &l| gcd_u64(acc, l)).max(1);
        let reduced: Vec<u64> = latents.iter().map(|&l| l / g).collect();
        let mut sorted = reduced.clone();
        sorted.sort_unstable();
        let mut k = 0u32;
        while k <= MAX_BIN_BITS && (1usize << k) <= sorted.len() {
            let cost = anchor_bits + table_cost_bits(&sorted, k);
            if cost < best_cost {
                best_cost = cost;
                best = Some((mode, k, reduced.clone()));
            }
            k += 1;
        }
    }
    let (mode, k, reduced) = best.expect("direct mode is always applicable");

    out.push(mode);
    if mode >= MODE_DELTA {
        out.extend_from_slice(&values[0].to_le_bytes());
    }
    if mode >= MODE_DOUBLE_DELTA {
        out.extend_from_slice(&values[1].to_le_bytes());
    }
    if reduced.is_empty() {
        return out;
    }

    // Recompute the gcd/table for the winning mode (the search kept only
    // the reduced latents to avoid storing a table per candidate).
    let latents = latents_for_mode(values, mode).unwrap();
    let g = latents.iter().fold(0u64, |acc, &l| gcd_u64(acc, l)).max(1);
    let mut sorted = reduced.clone();
    sorted.sort_unstable();
    let (lowers, obs) = bin_table(&sorted, k);

    out.push(k as u8);
    out.extend_from_slice(&g.to_le_bytes());
    for (lo, ob) in lowers.iter().zip(&obs) {
        out.extend_from_slice(&lo.to_le_bytes());
        out.push(*ob as u8);
    }

    let mut w = BitWriter::new();
    for &l in &reduced {
        // Rightmost bin with lower ≤ l (lowers are non-decreasing).
        let bin = lowers.partition_point(|&lo| lo <= l) - 1;
        if k > 0 {
            w.write_bits(bin as u32, k);
        }
        write_bits64(&mut w, l - lowers[bin], obs[bin]);
    }
    out.extend_from_slice(&w.finish());
    out
}

/// Header cursor over untrusted bytes (every read is bounds-checked).
struct Cursor<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn u8(&mut self) -> Result<u8, String> {
        let v = *self.data.get(self.pos).ok_or("pco: truncated header")?;
        self.pos += 1;
        Ok(v)
    }

    fn u32(&mut self) -> Result<u32, String> {
        if self.pos + 4 > self.data.len() {
            return Err("pco: truncated header".into());
        }
        let v = u32::from_le_bytes(self.data[self.pos..self.pos + 4].try_into().unwrap());
        self.pos += 4;
        Ok(v)
    }

    fn u64(&mut self) -> Result<u64, String> {
        if self.pos + 8 > self.data.len() {
            return Err("pco: truncated header".into());
        }
        let v = u64::from_le_bytes(self.data[self.pos..self.pos + 8].try_into().unwrap());
        self.pos += 8;
        Ok(v)
    }
}

/// Decode the bit-packed latent body against an explicit bit budget.
/// `force_scalar` pins the two-phase reference path (the fused single-peek
/// fast path must be bit-identical to it; the tests assert so).
fn decode_latents(
    body: &[u8],
    n_lat: usize,
    k: u32,
    lowers: &[u64],
    obs: &[u32],
    force_scalar: bool,
) -> Result<Vec<u64>, String> {
    let avail = body.len() as u64 * 8;
    let mut used = 0u64;
    let mut r = BitReader::new(body);
    let mut out = Vec::with_capacity(n_lat);
    let fused = !force_scalar && obs.iter().all(|&ob| k + ob <= 32);
    if fused {
        // Word-aligned batch path: one 32-bit peek yields bin index AND
        // offset, one consume per latent.
        let idx_mask = (1u64 << k) - 1;
        for _ in 0..n_lat {
            let word = r.peek_bits(32) as u64;
            let bin = (word & idx_mask) as usize;
            let ob = obs[bin];
            used += (k + ob) as u64;
            if used > avail {
                return Err("pco: truncated body".into());
            }
            let off = (word >> k) & ((1u64 << ob) - 1);
            r.consume(k + ob);
            let l = lowers[bin]
                .checked_add(off)
                .ok_or("pco: latent overflow")?;
            out.push(l);
        }
    } else {
        for _ in 0..n_lat {
            used += k as u64;
            if used > avail {
                return Err("pco: truncated body".into());
            }
            let bin = if k > 0 { r.read_bits(k) as usize } else { 0 };
            let ob = obs[bin];
            used += ob as u64;
            if used > avail {
                return Err("pco: truncated body".into());
            }
            let off = read_bits64(&mut r, ob);
            let l = lowers[bin]
                .checked_add(off)
                .ok_or("pco: latent overflow")?;
            out.push(l);
        }
    }
    // Encoder pads the last byte only: more than 7 slack bits means the
    // stream length is inconsistent with its own header.
    if avail - used >= 8 {
        return Err("pco: trailing bytes after body".into());
    }
    Ok(out)
}

fn decompress_u32s_inner(
    bytes: &[u8],
    max_count: usize,
    force_scalar: bool,
) -> Result<Vec<u32>, String> {
    let mut c = Cursor {
        data: bytes,
        pos: 0,
    };
    let version = c.u8()?;
    if version != VERSION {
        return Err(format!("pco: unknown stream version {version}"));
    }
    let count = c.u32()? as usize;
    if count > max_count {
        return Err(format!("pco: count {count} exceeds limit {max_count}"));
    }
    if count == 0 {
        if c.pos != bytes.len() {
            return Err("pco: trailing bytes after empty stream".into());
        }
        return Ok(Vec::new());
    }
    let mode = c.u8()?;
    if mode > MODE_DOUBLE_DELTA {
        return Err(format!("pco: unknown delta mode {mode}"));
    }
    let a0 = if mode >= MODE_DELTA { Some(c.u32()?) } else { None };
    let a1 = if mode >= MODE_DOUBLE_DELTA {
        if count < 2 {
            return Err("pco: double-delta needs two anchors".into());
        }
        Some(c.u32()?)
    } else {
        None
    };
    let n_lat = count - mode as usize;
    if n_lat == 0 {
        if c.pos != bytes.len() {
            return Err("pco: trailing bytes after anchors".into());
        }
        let mut out = Vec::with_capacity(count);
        if let Some(a) = a0 {
            out.push(a);
        }
        if let Some(a) = a1 {
            out.push(a);
        }
        if mode == MODE_DIRECT {
            // count > 0 with no latents is impossible in Direct mode.
            return Err("pco: direct mode with empty body".into());
        }
        return Ok(out);
    }

    let k = c.u8()? as u32;
    if k > MAX_BIN_BITS {
        return Err(format!("pco: bin-index width {k} exceeds {MAX_BIN_BITS}"));
    }
    let gcd = c.u64()?;
    if gcd == 0 {
        return Err("pco: zero gcd".into());
    }
    let bins = 1usize << k;
    let mut lowers = Vec::with_capacity(bins);
    let mut obs = Vec::with_capacity(bins);
    for _ in 0..bins {
        lowers.push(c.u64()?);
        let ob = c.u8()? as u32;
        if ob > 64 {
            return Err(format!("pco: offset width {ob} exceeds 64"));
        }
        obs.push(ob);
    }
    let body = &bytes[c.pos..];
    let latents = decode_latents(body, n_lat, k, &lowers, &obs, force_scalar)?;

    // Undo gcd + delta coding with checked arithmetic throughout: corrupt
    // tables can put latents anywhere in u64, and nothing reconstructed
    // from them may wrap or escape u32.
    let mut out: Vec<u32> = Vec::with_capacity(count);
    let to_u32 = |v: i64| -> Result<u32, String> {
        u32::try_from(v).map_err(|_| "pco: reconstructed value out of u32 range".into())
    };
    match mode {
        MODE_DIRECT => {
            for l in latents {
                let v = l.checked_mul(gcd).ok_or("pco: gcd overflow")?;
                out.push(u32::try_from(v).map_err(|_| "pco: value out of u32 range")?);
            }
        }
        MODE_DELTA => {
            let mut prev = a0.unwrap() as i64;
            out.push(a0.unwrap());
            for l in latents {
                let z = l.checked_mul(gcd).ok_or("pco: gcd overflow")?;
                let d = unzigzag(z);
                prev = prev.checked_add(d).ok_or("pco: delta overflow")?;
                out.push(to_u32(prev)?);
            }
        }
        MODE_DOUBLE_DELTA => {
            let (v0, v1) = (a0.unwrap(), a1.unwrap());
            out.push(v0);
            out.push(v1);
            let mut prev = v1 as i64;
            let mut d_prev = v1 as i64 - v0 as i64;
            for l in latents {
                let z = l.checked_mul(gcd).ok_or("pco: gcd overflow")?;
                let dd = unzigzag(z);
                d_prev = d_prev.checked_add(dd).ok_or("pco: delta overflow")?;
                prev = prev.checked_add(d_prev).ok_or("pco: delta overflow")?;
                out.push(to_u32(prev)?);
            }
        }
        _ => unreachable!(),
    }
    debug_assert_eq!(out.len(), count);
    Ok(out)
}

/// Decompress a stream produced by [`compress_u32s`]. `max_count` bounds
/// the decoded length (callers pass the model dimension `d`), so a corrupt
/// count field cannot force an unbounded allocation.
pub fn decompress_u32s(bytes: &[u8], max_count: usize) -> Result<Vec<u32>, String> {
    decompress_u32s_inner(bytes, max_count, false)
}

/// Compress an `f32` sequence via the order-preserving integer bijection.
pub fn compress_f32s(values: &[f32]) -> Vec<u8> {
    let ints: Vec<u32> = values.iter().map(|&v| f32_to_ord_u32(v)).collect();
    compress_u32s(&ints)
}

/// Decompress a stream produced by [`compress_f32s`] (bit-exact, including
/// NaNs, infinities and signed zeros).
pub fn decompress_f32s(bytes: &[u8], max_count: usize) -> Result<Vec<f32>, String> {
    Ok(decompress_u32s(bytes, max_count)?
        .into_iter()
        .map(ord_u32_to_f32)
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn sample_sequences() -> Vec<Vec<u32>> {
        let mut rng = Xoshiro256pp::new(0x9c0);
        let mut out: Vec<Vec<u32>> = vec![
            vec![],
            vec![0],
            vec![u32::MAX],
            vec![5, 5],
            vec![0, u32::MAX],
            vec![7; 1000],
            (0..1000u32).collect(),                     // perfect ramp
            (0..1000u32).map(|i| i * 24).collect(),     // ramp with gcd
            (0..500u32).map(|i| i * i).collect(),       // quadratic (double-delta-friendly)
        ];
        // Sorted index gaps — the Δ′ shape the wire path carries.
        let mut idx: Vec<u32> = (0..4_000).map(|_| rng.below(200_000) as u32).collect();
        idx.sort_unstable();
        idx.dedup();
        out.push(idx);
        // Uniform random (incompressible).
        out.push((0..2_000).map(|_| rng.next_u64() as u32).collect());
        // Clustered: two value populations (bins should split them).
        out.push(
            (0..3_000)
                .map(|_| {
                    if rng.next_f32() < 0.9 {
                        rng.below(100) as u32
                    } else {
                        1_000_000 + rng.below(1_000_000) as u32
                    }
                })
                .collect(),
        );
        out
    }

    #[test]
    fn roundtrip_all_sample_sequences() {
        for (i, vals) in sample_sequences().iter().enumerate() {
            let z = compress_u32s(vals);
            let back = decompress_u32s(&z, vals.len())
                .unwrap_or_else(|e| panic!("case {i}: {e}"));
            assert_eq!(&back, vals, "case {i}");
        }
    }

    #[test]
    fn fused_and_scalar_body_decoders_agree() {
        for (i, vals) in sample_sequences().iter().enumerate() {
            let z = compress_u32s(vals);
            let fast = decompress_u32s_inner(&z, vals.len(), false).unwrap();
            let slow = decompress_u32s_inner(&z, vals.len(), true).unwrap();
            assert_eq!(fast, slow, "case {i}: fused path diverged from scalar oracle");
        }
    }

    #[test]
    fn sorted_gap_streams_beat_raw_and_ramp_collapses() {
        let mut rng = Xoshiro256pp::new(0x6a9);
        let mut idx: Vec<u32> = (0..5_000).map(|_| rng.below(327_680) as u32).collect();
        idx.sort_unstable();
        idx.dedup();
        let z = compress_u32s(&idx);
        // Gap coding a sorted 1.5%-dense index set costs ≈ log2(d/n)+2 bits
        // per index — far below 32-bit raw.
        assert!(
            z.len() * 8 < idx.len() * 12,
            "gaps: {} bytes for {} indexes",
            z.len(),
            idx.len()
        );
        // A perfect arithmetic ramp double-deltas to all-zero latents.
        let ramp: Vec<u32> = (0..10_000u32).map(|i| 17 + i * 3).collect();
        let z = compress_u32s(&ramp);
        assert!(z.len() < 200, "ramp should collapse, got {} bytes", z.len());
        // Constant sequences delta to zero.
        let constant = vec![123_456u32; 10_000];
        let z = compress_u32s(&constant);
        assert!(z.len() < 200, "constant should collapse, got {} bytes", z.len());
    }

    #[test]
    fn incompressible_overhead_is_bounded() {
        let mut rng = Xoshiro256pp::new(0xbad);
        let vals: Vec<u32> = (0..10_000).map(|_| rng.next_u64() as u32).collect();
        let z = compress_u32s(&vals);
        // ≤ 32 latent bits + ~2 bin-index bits per value + table/header.
        assert!(z.len() <= vals.len() * 5 + 1_400, "blowup: {} bytes", z.len());
    }

    #[test]
    fn max_count_limit_is_enforced() {
        let vals: Vec<u32> = (0..100u32).collect();
        let z = compress_u32s(&vals);
        assert!(decompress_u32s(&z, 100).is_ok());
        assert!(decompress_u32s(&z, 99).is_err());
    }

    #[test]
    fn decode_is_total_under_corruption() {
        let mut rng = Xoshiro256pp::new(0xf02);
        for vals in sample_sequences() {
            let z = compress_u32s(&vals);
            // (a) Every truncation prefix.
            let stride = (z.len() / 48).max(1);
            for cut in (0..z.len()).step_by(stride) {
                match decompress_u32s(&z[..cut], vals.len()) {
                    Err(_) => {}
                    Ok(v) => assert!(v.len() <= vals.len()),
                }
            }
            // (b) Single-bit flips across the whole stream.
            for pos in (0..z.len()).step_by(stride) {
                for bit in [0, 3, 7] {
                    let mut bad = z.clone();
                    bad[pos] ^= 1 << bit;
                    match decompress_u32s(&bad, vals.len()) {
                        Err(_) => {}
                        Ok(v) => assert!(v.len() <= vals.len()),
                    }
                }
            }
            // (c) Random byte strings.
            for _ in 0..20 {
                let n = (rng.next_u64() % 200) as usize;
                let junk: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
                match decompress_u32s(&junk, 10_000) {
                    Err(_) => {}
                    Ok(v) => assert!(v.len() <= 10_000),
                }
            }
        }
    }

    #[test]
    fn version_gate_rejects_future_streams() {
        let mut z = compress_u32s(&[1, 2, 3]);
        z[0] = VERSION + 1;
        assert!(decompress_u32s(&z, 3).is_err());
    }

    #[test]
    fn float_bijection_preserves_order_and_roundtrips() {
        let mut rng = Xoshiro256pp::new(0xf10a7);
        let mut vals: Vec<f32> = (0..2_000)
            .map(|_| (rng.next_f32() - 0.5) * 1e6)
            .collect();
        vals.extend_from_slice(&[0.0, -0.0, f32::INFINITY, f32::NEG_INFINITY, f32::NAN, 1e-38]);
        // Order preservation on the comparable subset.
        let mut finite: Vec<f32> = vals.iter().cloned().filter(|v| !v.is_nan()).collect();
        finite.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mapped: Vec<u32> = finite.iter().map(|&v| f32_to_ord_u32(v)).collect();
        let mut sorted_mapped = mapped.clone();
        sorted_mapped.sort_unstable();
        assert_eq!(mapped, sorted_mapped, "bijection must be monotone");
        // Bit-exact roundtrip including NaN.
        let z = compress_f32s(&vals);
        let back = decompress_f32s(&z, vals.len()).unwrap();
        assert_eq!(back.len(), vals.len());
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn empty_stream_is_minimal_and_strict() {
        let z = compress_u32s(&[]);
        assert_eq!(z.len(), 5);
        assert_eq!(decompress_u32s(&z, 0).unwrap(), Vec::<u32>::new());
        let mut padded = z.clone();
        padded.push(0);
        assert!(decompress_u32s(&padded, 0).is_err(), "trailing bytes must be rejected");
    }
}
