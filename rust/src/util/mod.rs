//! Small self-contained utilities: deterministic RNG, bit I/O, CLI parsing,
//! JSON/CSV emission, summary statistics and wall-clock timers.
//!
//! Everything here is written from scratch because the offline vendor set
//! ships no general-purpose crates (no `rand`, no `serde`, no `clap`).

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod timer;

/// Argsort-free partial selection: returns the indexes of the `k` largest
/// values of `score` (unordered within the selection) in O(n) expected time.
///
/// Used for the paper's `top_κ` KL-ranked update selection (Eq. 4), where a
/// full `sort` would be the asymptotic bottleneck of the encode path at
/// d ≈ 10⁵–10⁷ mask parameters.
pub fn top_k_indices(score: &[f32], k: usize) -> Vec<u32> {
    let mut idx = Vec::new();
    top_k_indices_into(score, k, &mut idx);
    idx
}

/// [`top_k_indices`] writing into a caller-owned buffer, so hot encode
/// paths reuse the quickselect index array across rounds (it lives in
/// `compress::EncodeScratch::rank`, per `ClientSession`) instead of
/// reallocating an `n`-length `Vec` per selection. Leaves exactly the
/// selected indexes in `idx`, element-for-element identical to
/// [`top_k_indices`] — same fill order, same introselect, same comparator
/// — so every byte downstream of the selection is unchanged.
pub fn top_k_indices_into(score: &[f32], k: usize, idx: &mut Vec<u32>) {
    let n = score.len();
    idx.clear();
    if k == 0 {
        return;
    }
    idx.extend(0..n as u32);
    if k >= n {
        return;
    }
    // Introselect (std's pattern-defeating quickselect): O(n) expected AND
    // robust to heavily-tied scores — KL scores tie massively when θ values
    // come from a few levels, which degraded a naive two-way quickselect to
    // O(n²) here (see EXPERIMENTS.md §Perf).
    idx.select_nth_unstable_by(k - 1, |&a, &b| {
        score[b as usize]
            .partial_cmp(&score[a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    idx.truncate(k);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_matches_full_sort() {
        let mut rng = rng::Xoshiro256pp::new(7);
        for n in [1usize, 2, 3, 17, 100, 1031] {
            let scores: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            for k in [0usize, 1, n / 3, n - 1, n, n + 5] {
                let got = top_k_indices(&scores, k);
                let mut expect: Vec<u32> = (0..n as u32).collect();
                expect.sort_by(|&a, &b| {
                    scores[b as usize].partial_cmp(&scores[a as usize]).unwrap()
                });
                expect.truncate(k.min(n));
                let mut g = got.clone();
                g.sort_unstable();
                let mut e = expect.clone();
                e.sort_unstable();
                assert_eq!(g.len(), k.min(n));
                // Selection must contain exactly the k largest (ties: same values).
                let min_sel = got
                    .iter()
                    .map(|&i| scores[i as usize])
                    .fold(f32::INFINITY, f32::min);
                let max_rest: f32 = (0..n as u32)
                    .filter(|i| !g.binary_search(i).is_ok())
                    .map(|i| scores[i as usize])
                    .fold(f32::NEG_INFINITY, f32::max);
                if k > 0 && k < n {
                    assert!(min_sel >= max_rest, "n={n} k={k}");
                }
            }
        }
    }

    #[test]
    fn top_k_with_duplicate_scores() {
        let scores = vec![1.0f32; 64];
        let got = top_k_indices(&scores, 10);
        assert_eq!(got.len(), 10);
    }

    /// Parity oracle for the scratch-reusing variant: the buffer version
    /// must be element-for-element identical to the allocating one, with
    /// the same buffer reused across calls of varying `n` and `k` (the
    /// cross-round usage pattern in `EncodeScratch`).
    #[test]
    fn top_k_into_matches_allocating_variant_across_reuses() {
        let mut rng = rng::Xoshiro256pp::new(9);
        let mut buf = Vec::new();
        for n in [1usize, 2, 5, 257, 1024, 64] {
            let scores: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            for k in [0usize, 1, n / 2, n - 1, n, n + 3] {
                let fresh = top_k_indices(&scores, k);
                top_k_indices_into(&scores, k, &mut buf);
                assert_eq!(fresh, buf, "n={n} k={k}");
            }
        }
        // Heavily-tied scores take the same path through both variants.
        let tied = vec![0.5f32; 97];
        let fresh = top_k_indices(&tied, 13);
        top_k_indices_into(&tied, 13, &mut buf);
        assert_eq!(fresh, buf);
    }
}
