//! Adaptive binary arithmetic coder (Rissanen & Langdon 1979, cited by the
//! paper as FedPM's sub-1bpp mask coder). 32-bit range coder with carry-free
//! renormalization and an adaptive order-0 bit model — exactly what encoding
//! a Bernoulli(θ) mask stream near its empirical entropy requires.
//!
//! For a mask with activation frequency p, the achieved rate approaches the
//! binary entropy H(p) bits per mask bit, which is how FedPM dips below
//! 1 bpp (and why its rate floats with mask sparsity, §2).

/// Adaptive probability model: 12-bit probability of the next bit being 0,
/// updated with an exponential moving average (shift = 5, as in LZMA-style
/// coders).
#[derive(Clone, Debug)]
pub struct BitModel {
    p0: u16, // P(bit = 0) in [1, 4095] / 4096
}

const PROB_BITS: u32 = 12;
const PROB_ONE: u32 = 1 << PROB_BITS;
const ADAPT_SHIFT: u32 = 5;

impl Default for BitModel {
    fn default() -> Self {
        Self {
            p0: (PROB_ONE / 2) as u16,
        }
    }
}

impl BitModel {
    #[inline]
    fn update(&mut self, bit: bool) {
        if bit {
            self.p0 -= self.p0 >> ADAPT_SHIFT;
        } else {
            self.p0 += ((PROB_ONE as u16) - self.p0) >> ADAPT_SHIFT;
        }
        self.p0 = self.p0.clamp(1, (PROB_ONE - 1) as u16);
    }
}

pub struct Encoder {
    low: u64,
    range: u32,
    out: Vec<u8>,
    cache: u8,
    cache_size: u64,
}

impl Default for Encoder {
    fn default() -> Self {
        Self::new()
    }
}

impl Encoder {
    pub fn new() -> Self {
        Self {
            low: 0,
            range: u32::MAX,
            out: Vec::new(),
            cache: 0,
            cache_size: 1,
        }
    }

    #[inline]
    fn shift_low(&mut self) {
        if self.low < 0xff00_0000u64 || self.low > 0xffff_ffffu64 {
            let carry = (self.low >> 32) as u8;
            // Flush cache + any pending 0xff bytes with carry propagation.
            loop {
                self.out.push(self.cache.wrapping_add(carry));
                self.cache = 0xff;
                self.cache_size -= 1;
                if self.cache_size == 0 {
                    break;
                }
            }
            self.cache = (self.low >> 24) as u8;
        }
        self.cache_size += 1;
        self.low = (self.low << 8) & 0xffff_ffff;
    }

    #[inline]
    pub fn encode(&mut self, model: &mut BitModel, bit: bool) {
        let bound = (self.range >> PROB_BITS) * model.p0 as u32;
        if !bit {
            self.range = bound;
        } else {
            self.low += bound as u64;
            self.range -= bound;
        }
        model.update(bit);
        while self.range < 0x0100_0000 {
            self.range <<= 8;
            self.shift_low();
        }
    }

    pub fn finish(mut self) -> Vec<u8> {
        for _ in 0..5 {
            self.shift_low();
        }
        // Drop the leading cache byte (it was the initial dummy).
        self.out.remove(0);
        self.out
    }
}

pub struct Decoder<'a> {
    code: u32,
    range: u32,
    data: &'a [u8],
    pos: usize,
}

impl<'a> Decoder<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        let mut d = Self {
            code: 0,
            range: u32::MAX,
            data,
            pos: 0,
        };
        for _ in 0..4 {
            d.code = (d.code << 8) | d.next_byte() as u32;
        }
        d
    }

    #[inline]
    fn next_byte(&mut self) -> u8 {
        let b = self.data.get(self.pos).copied().unwrap_or(0);
        self.pos += 1;
        b
    }

    #[inline]
    pub fn decode(&mut self, model: &mut BitModel) -> bool {
        let bound = (self.range >> PROB_BITS) * model.p0 as u32;
        let bit = if self.code < bound {
            self.range = bound;
            false
        } else {
            self.code -= bound;
            self.range -= bound;
            true
        };
        model.update(bit);
        while self.range < 0x0100_0000 {
            self.range <<= 8;
            self.code = (self.code << 8) | self.next_byte() as u32;
        }
        bit
    }
}

/// Encode a bit vector with a single adaptive order-0 model.
pub fn encode_bits(bits: &[bool]) -> Vec<u8> {
    let mut enc = Encoder::new();
    let mut model = BitModel::default();
    for &b in bits {
        enc.encode(&mut model, b);
    }
    enc.finish()
}

/// Decode `n` bits previously encoded with [`encode_bits`].
pub fn decode_bits(data: &[u8], n: usize) -> Vec<bool> {
    let mut dec = Decoder::new(data);
    let mut model = BitModel::default();
    (0..n).map(|_| dec.decode(&mut model)).collect()
}

/// Binary entropy in bits: H(p) = -p·log2(p) - (1-p)·log2(1-p).
pub fn binary_entropy(p: f64) -> f64 {
    if p <= 0.0 || p >= 1.0 {
        return 0.0;
    }
    -p * p.log2() - (1.0 - p) * (1.0 - p).log2()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn roundtrip_various_biases() {
        let mut rng = Xoshiro256pp::new(11);
        for &p in &[0.0f32, 0.01, 0.1, 0.5, 0.9, 1.0] {
            for &n in &[0usize, 1, 100, 10_000] {
                let bits: Vec<bool> = (0..n).map(|_| rng.next_f32() < p).collect();
                let enc = encode_bits(&bits);
                let dec = decode_bits(&enc, n);
                assert_eq!(dec, bits, "p={p} n={n}");
            }
        }
    }

    #[test]
    fn rate_approaches_entropy() {
        // The FedPM claim: a Bern(p) mask codes at ≈ H(p) bits/bit.
        let mut rng = Xoshiro256pp::new(13);
        for &p in &[0.05f64, 0.2, 0.5] {
            let n = 200_000usize;
            let bits: Vec<bool> = (0..n).map(|_| rng.next_f64() < p).collect();
            let enc = encode_bits(&bits);
            let rate = enc.len() as f64 * 8.0 / n as f64;
            let h = binary_entropy(p);
            assert!(
                rate < h + 0.05 && rate > h * 0.8,
                "p={p}: rate={rate:.4} entropy={h:.4}"
            );
        }
    }

    #[test]
    fn adapts_to_nonstationary_stream() {
        // First half dense, second half sparse — adaptive model must track.
        let mut rng = Xoshiro256pp::new(17);
        let n = 100_000usize;
        let bits: Vec<bool> = (0..n)
            .map(|i| {
                let p = if i < n / 2 { 0.9 } else { 0.02 };
                rng.next_f64() < p
            })
            .collect();
        let enc = encode_bits(&bits);
        assert_eq!(decode_bits(&enc, n), bits);
        let rate = enc.len() as f64 * 8.0 / n as f64;
        let ideal = 0.5 * binary_entropy(0.9) + 0.5 * binary_entropy(0.02);
        assert!(rate < ideal + 0.1, "rate={rate:.4} ideal={ideal:.4}");
    }

    #[test]
    fn worst_case_overhead_bounded() {
        // Alternating bits (model hovers at 0.5): ≤ ~1.05 bits/bit.
        let bits: Vec<bool> = (0..50_000).map(|i| i % 2 == 0).collect();
        let enc = encode_bits(&bits);
        let rate = enc.len() as f64 * 8.0 / bits.len() as f64;
        assert!(rate < 1.1, "rate={rate}");
    }
}
