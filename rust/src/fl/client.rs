//! Client-side session: local stochastic-mask training (Alg. 1
//! ClientUpdate) over the client's shard, with persistent Adam moments
//! across rounds and deterministic per-(client, round) randomness.
//!
//! Sessions are owned by the runner's `Option` slots and travel by value
//! through the coordinator's work-stealing `ClientPool` for the duration of
//! a round — there are no placeholder sessions, and all round inputs arrive
//! via the immutable `RoundPlan` broadcast snapshot.

use super::data::ClientData;
use crate::compress::EncodeScratch;
use crate::model::backend::{Backend, FtState, LpState, ModelParams};
use crate::model::{theta_from_scores, MaskState};
use crate::util::rng::Xoshiro256pp;
use anyhow::Result;

pub struct ClientSession {
    pub id: usize,
    pub mask_state: MaskState,
    /// Local fine-tuning state (only allocated for the FT baseline).
    pub ft_state: Option<FtState>,
    /// Local linear-probe state (only for the LP baseline).
    pub lp_state: Option<LpState>,
    /// Reusable encode-path buffers (Δ scan / KL scores / key set): the
    /// session rides the pool across rounds, so steady-state encodes via
    /// `UpdateCodec::encode_with` allocate nothing for selection.
    pub enc_scratch: EncodeScratch,
    seed: u64,
}

/// A padded batch iterator: yields (x, y_onehot, n_valid) with fixed B rows,
/// wrapping the tail so every batch is full (the AOT graphs have static B).
pub struct Batches<'a> {
    data: &'a ClientData,
    order: Vec<usize>,
    pos: usize,
    f: usize,
    c: usize,
    b: usize,
}

impl<'a> Iterator for Batches<'a> {
    type Item = (Vec<f32>, Vec<f32>, usize);

    fn next(&mut self) -> Option<Self::Item> {
        if self.pos >= self.order.len() {
            return None;
        }
        let take = (self.order.len() - self.pos).min(self.b);
        let mut x = vec![0.0f32; self.b * self.f];
        let mut y1h = vec![0.0f32; self.b * self.c];
        for row in 0..self.b {
            // Wrap padding rows back onto real samples so batch statistics
            // stay sane; they still count as gradient weight, which matches
            // "repeat-to-fill" padding in FL frameworks.
            let src = self.order[self.pos + (row % take)];
            x[row * self.f..(row + 1) * self.f]
                .copy_from_slice(&self.data.x[src * self.f..(src + 1) * self.f]);
            y1h[row * self.c + self.data.y[src] as usize] = 1.0;
        }
        self.pos += take;
        Some((x, y1h, take))
    }
}

impl ClientSession {
    pub fn new(id: usize, d: usize, experiment_seed: u64) -> Self {
        Self {
            id,
            mask_state: MaskState::new(d),
            ft_state: None,
            lp_state: None,
            enc_scratch: EncodeScratch::default(),
            seed: experiment_seed
                ^ (id as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15),
        }
    }

    fn round_rng(&self, round: usize) -> Xoshiro256pp {
        Xoshiro256pp::new(
            self.seed ^ (round as u64).wrapping_mul(0xd134_2543_de82_ef95),
        )
    }

    pub fn batches<'a>(
        &self,
        data: &'a ClientData,
        f: usize,
        c: usize,
        b: usize,
        round: usize,
    ) -> Batches<'a> {
        let mut rng = self.round_rng(round);
        let mut order: Vec<usize> = (0..data.len()).collect();
        rng.shuffle(&mut order);
        Batches {
            data,
            order,
            pos: 0,
            f,
            c,
            b,
        }
    }

    /// Alg. 1 ClientUpdate: receive θ^{g,t-1}, train E epochs, return
    /// (θ^{k,t}, mean train loss). Scores are re-seeded from the broadcast
    /// probabilities; Adam moments persist locally.
    pub fn local_train(
        &mut self,
        backend: &dyn Backend,
        params: &ModelParams,
        data: &ClientData,
        theta_g: &[f32],
        epochs: usize,
        round: usize,
    ) -> Result<(Vec<f32>, f32)> {
        self.local_train_opts(backend, params, data, theta_g, epochs, round, true)
    }

    /// `resync` = false keeps the client's own scores (FedMask regime).
    #[allow(clippy::too_many_arguments)]
    pub fn local_train_opts(
        &mut self,
        backend: &dyn Backend,
        params: &ModelParams,
        data: &ClientData,
        theta_g: &[f32],
        epochs: usize,
        round: usize,
        resync: bool,
    ) -> Result<(Vec<f32>, f32)> {
        let cfg = params.cfg;
        let d = cfg.d();
        if resync {
            self.mask_state.set_theta(theta_g);
        }
        let mut rng = self.round_rng(round).fork(1);
        let mut u = vec![0.0f32; d];
        let mut loss_sum = 0.0f64;
        let mut steps = 0usize;
        for _epoch in 0..epochs {
            for (x, y1h, _valid) in self.batches(data, cfg.f, cfg.c, cfg.b, round) {
                rng.fill_f32_uniform(&mut u);
                let loss =
                    backend.train_step(params, &mut self.mask_state, &x, &y1h, &u)?;
                loss_sum += loss as f64;
                steps += 1;
            }
        }
        let mut theta_k = Vec::new();
        theta_from_scores(&self.mask_state.s, &mut theta_k);
        Ok((theta_k, (loss_sum / steps.max(1) as f64) as f32))
    }

    /// Sample the client's transmitted mask m^{k,t} (Alg. 1 line 8) with
    /// the round-deterministic client seed.
    pub fn sample_update_mask(&self, theta_k: &[f32], round: usize) -> Vec<f32> {
        let mut rng = self.round_rng(round).fork(2);
        let mut u = vec![0.0f32; theta_k.len()];
        rng.fill_f32_uniform(&mut u);
        theta_k
            .iter()
            .zip(&u)
            .map(|(&p, &uu)| if uu < p { 1.0f32 } else { 0.0 })
            .collect()
    }

    /// Local fine-tuning pass (FT baseline): start from the provided global
    /// weights, return the weight delta (wb, hw, hb concatenated order).
    pub fn local_finetune(
        &mut self,
        backend: &dyn Backend,
        params: &ModelParams,
        data: &ClientData,
        global: &FtState,
        epochs: usize,
        round: usize,
    ) -> Result<(FtState, f32)> {
        let cfg = params.cfg;
        let mut state = match self.ft_state.take() {
            Some(mut st) => {
                // Adopt global weights, keep local Adam moments.
                st.w_blocks.copy_from_slice(&global.w_blocks);
                st.head_w.copy_from_slice(&global.head_w);
                st.head_b.copy_from_slice(&global.head_b);
                st
            }
            None => global.clone(),
        };
        let mut loss_sum = 0.0f64;
        let mut steps = 0usize;
        for _ in 0..epochs {
            for (x, y1h, _valid) in self.batches(data, cfg.f, cfg.c, cfg.b, round) {
                loss_sum += backend.ft_step(params, &mut state, &x, &y1h)? as f64;
                steps += 1;
            }
        }
        let loss = (loss_sum / steps.max(1) as f64) as f32;
        self.ft_state = Some(state.clone());
        Ok((state, loss))
    }

    /// Local linear-probe pass (LP baseline and the §3.3 head-init round).
    pub fn local_probe(
        &mut self,
        backend: &dyn Backend,
        params: &ModelParams,
        data: &ClientData,
        global_head: &LpState,
        epochs: usize,
        round: usize,
    ) -> Result<(LpState, f32)> {
        let cfg = params.cfg;
        let mut state = match self.lp_state.take() {
            Some(mut st) => {
                st.head_w.copy_from_slice(&global_head.head_w);
                st.head_b.copy_from_slice(&global_head.head_b);
                st
            }
            None => global_head.clone(),
        };
        let mut loss_sum = 0.0f64;
        let mut steps = 0usize;
        for _ in 0..epochs {
            for (x, y1h, _valid) in self.batches(data, cfg.f, cfg.c, cfg.b, round) {
                loss_sum += backend.lp_step(params, &mut state, &x, &y1h)? as f64;
                steps += 1;
            }
        }
        let loss = (loss_sum / steps.max(1) as f64) as f32;
        self.lp_state = Some(state.clone());
        Ok((state, loss))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::data::{generate, profile};
    use crate::model::{init_params, ArchConfig};
    use crate::native::NativeBackend;

    #[test]
    fn batches_cover_all_samples_padded() {
        let p = profile("cifar10").unwrap();
        let arch = ArchConfig::new(32, 10, 8, 5);
        let data = generate(&p, arch, 1, 21, 0, 10.0, 1);
        let sess = ClientSession::new(0, arch.d(), 7);
        let batches: Vec<_> = sess.batches(&data.clients[0], 32, 10, 8, 0).collect();
        assert_eq!(batches.len(), 3); // ceil(21/8)
        assert!(batches.iter().all(|(x, y, _)| x.len() == 8 * 32 && y.len() == 8 * 10));
        let valid: usize = batches.iter().map(|(_, _, v)| v).sum();
        assert_eq!(valid, 21);
        // Every one-hot row sums to exactly 1 (padding rows are real samples).
        for (_, y1h, _) in &batches {
            for row in 0..8 {
                let s: f32 = y1h[row * 10..(row + 1) * 10].iter().sum();
                assert_eq!(s, 1.0);
            }
        }
    }

    #[test]
    fn local_train_deterministic_per_round() {
        let p = profile("cifar10").unwrap();
        let arch = ArchConfig::new(32, 10, 8, 5);
        let data = generate(&p, arch, 1, 32, 0, 10.0, 2);
        let params = init_params(arch, 3);
        let backend = NativeBackend;
        let theta_g = vec![0.5f32; arch.d()];
        let mut a = ClientSession::new(0, arch.d(), 9);
        let mut b = ClientSession::new(0, arch.d(), 9);
        let (ta, la) = a
            .local_train(&backend, &params, &data.clients[0], &theta_g, 1, 5)
            .unwrap();
        let (tb, lb) = b
            .local_train(&backend, &params, &data.clients[0], &theta_g, 1, 5)
            .unwrap();
        assert_eq!(ta, tb);
        assert_eq!(la, lb);
        // Different round ⇒ different batch order/uniforms ⇒ different θ.
        let (tc, _) = b
            .local_train(&backend, &params, &data.clients[0], &theta_g, 1, 6)
            .unwrap();
        assert_ne!(ta, tc);
    }

    #[test]
    fn update_mask_seeded_and_distinct_across_clients() {
        let d = 1000;
        let theta = vec![0.5f32; d];
        let a = ClientSession::new(0, d, 1);
        let b = ClientSession::new(1, d, 1);
        let ma1 = a.sample_update_mask(&theta, 3);
        let ma2 = a.sample_update_mask(&theta, 3);
        let mb = b.sample_update_mask(&theta, 3);
        assert_eq!(ma1, ma2);
        assert_ne!(ma1, mb);
    }
}
