//! Compile-only stub of the `xla` PJRT bindings.
//!
//! The real PJRT integration needs the external `xla` crate, which the
//! offline build cannot vendor. This shim mirrors exactly the API surface
//! `deltamask`'s `runtime::{executor, xla_backend}` modules use, so the
//! `xla` cargo feature **type-checks** (CI's `feature-matrix` job builds
//! and clippy-checks it) while every runtime entry point reports a clear
//! error: [`PjRtClient::cpu`] fails first, so nothing downstream is ever
//! reached. To actually execute the AOT artifacts, replace the
//! `rust/vendor/xla_stub` path dependency in the root `Cargo.toml` with
//! the real `xla` crate in a registry-connected environment.

/// Error type for every stub operation; `Debug`-formats into the message
/// the `deltamask` runtime surfaces (`anyhow!("...: {e:?}")`).
#[derive(Clone)]
pub struct XlaError(pub &'static str);

impl std::fmt::Debug for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.0)
    }
}

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.0)
    }
}

impl std::error::Error for XlaError {}

const STUB: &str = "xla stub build: PJRT is unavailable (this is the vendored compile-only \
                    shim at rust/vendor/xla_stub; swap in the real `xla` crate to execute \
                    AOT artifacts)";

type Result<T> = std::result::Result<T, XlaError>;

/// Element types a [`Literal`] can be read back as (only `f32` is used by
/// the deltamask graphs).
pub trait ArrayElement: Copy {}
impl ArrayElement for f32 {}

pub struct PjRtClient(());
pub struct PjRtDevice(());
pub struct PjRtLoadedExecutable(());
pub struct PjRtBuffer(());
pub struct HloModuleProto(());
pub struct XlaComputation(());
pub struct Literal(());

impl PjRtClient {
    /// Always fails in the stub — this is the first PJRT call every code
    /// path makes, so nothing below is reachable at runtime.
    pub fn cpu() -> Result<Self> {
        Err(XlaError(STUB))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError(STUB))
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(XlaError(STUB))
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(XlaError(STUB))
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self(())
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _inputs: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError(STUB))
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError(STUB))
    }
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(XlaError(STUB))
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        Err(XlaError(STUB))
    }
}
