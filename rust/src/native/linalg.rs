//! Minimal dense f32 matmul kernels for the native backend. Cache-friendly
//! loop orders (ikj for NN/BT-via-kj) — no external BLAS in the offline
//! vendor set, and the simulated-FM sizes (≤ 64×384×384) stay well inside
//! L2 cache.

/// C = A @ B with A:(m,k), B:(k,n), C:(m,n). (ikj order: streams B rows.)
pub fn matmul_nn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// C = A @ Bᵀ with A:(m,k), B:(n,k), C:(m,n). (Dot products of rows —
/// both operands stream contiguously.)
pub fn matmul_bt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += arow[kk] * brow[kk];
            }
            c[i * n + j] = acc;
        }
    }
}

/// C = Aᵀ @ B with A:(k,m), B:(k,n), C:(m,n). (Accumulates rank-1 updates;
/// ikj-style inner streaming.)
pub fn matmul_at(a: &[f32], b: &[f32], c: &mut [f32], k: usize, m: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for kk in 0..k {
        let arow = &a[kk * m..(kk + 1) * m];
        let brow = &b[kk * n..(kk + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn naive_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for kk in 0..k {
                    acc += a[i * k + kk] * b[kk * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn all_variants_match_naive() {
        let mut rng = Xoshiro256pp::new(1);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 7), (8, 32, 16), (17, 9, 23)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.next_f32() - 0.5).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.next_f32() - 0.5).collect();
            let want = naive_nn(&a, &b, m, k, n);

            let mut c = vec![0.0f32; m * n];
            matmul_nn(&a, &b, &mut c, m, k, n);
            assert_close(&c, &want);

            // A @ Bᵀ: feed B transposed.
            let mut bt = vec![0.0f32; n * k];
            for kk in 0..k {
                for j in 0..n {
                    bt[j * k + kk] = b[kk * n + j];
                }
            }
            matmul_bt(&a, &bt, &mut c, m, k, n);
            assert_close(&c, &want);

            // Aᵀ @ B: feed A transposed.
            let mut at = vec![0.0f32; k * m];
            for i in 0..m {
                for kk in 0..k {
                    at[kk * m + i] = a[i * k + kk];
                }
            }
            matmul_at(&at, &b, &mut c, k, m, n);
            assert_close(&c, &want);
        }
    }

    fn assert_close(got: &[f32], want: &[f32]) {
        for (g, w) in got.iter().zip(want) {
            assert!((g - w).abs() < 1e-4, "{g} vs {w}");
        }
    }
}
