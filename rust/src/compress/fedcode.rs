//! **FedCode** (Khalilian et al. 2023) — communication via codebook
//! transfer: the score delta is k-means-quantized; the client ships the
//! tiny codebook every round but the per-coordinate *assignments* only
//! every `assignment_period` rounds (the paper's mechanism for dipping far
//! below 1 bpp at an accuracy cost, matching Fig. 7's "most data-efficient,
//! lowest accuracy, slowest encode" characterization — k-means dominates
//! encode time).
//!
//! Between assignment rounds the server reuses the last assignments with
//! the fresh codebook.

use super::{wire, DecodeCtx, EncodeCtx, Encoded, Family, Update, UpdateCodec};
use crate::codec::deflate;
use crate::util::rng::Xoshiro256pp;
use anyhow::{ensure, Result};
use std::sync::Mutex;

pub struct FedCodeCodec {
    pub codebook_size: usize,
    pub assignment_period: usize,
    pub kmeans_iters: usize,
    /// Server-side memory of the last transmitted assignments per client
    /// stream (keyed by seed stream id = seed % slots for the simulation).
    last_assignments: Mutex<std::collections::HashMap<u64, Vec<u8>>>,
    round_counter: Mutex<std::collections::HashMap<u64, usize>>,
}

impl Default for FedCodeCodec {
    fn default() -> Self {
        Self {
            codebook_size: 16,
            assignment_period: 4,
            kmeans_iters: 8,
            last_assignments: Mutex::new(std::collections::HashMap::new()),
            round_counter: Mutex::new(std::collections::HashMap::new()),
        }
    }
}

/// 1-D k-means over `data` with `k` centroids (seeded init, Lloyd).
fn kmeans_1d(data: &[f32], k: usize, iters: usize, seed: u64) -> (Vec<f32>, Vec<u8>) {
    assert!(k <= 256);
    let mut rng = Xoshiro256pp::new(seed);
    let mut centroids: Vec<f32> = (0..k)
        .map(|_| data[rng.below(data.len() as u64) as usize])
        .collect();
    centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut assign = vec![0u8; data.len()];
    for _ in 0..iters {
        // Assign (centroids sorted ⇒ binary search).
        for (i, &x) in data.iter().enumerate() {
            let mut best = 0usize;
            let mut best_d = f32::INFINITY;
            // k ≤ 256: linear scan is fine and branch-predictable.
            for (c, &cv) in centroids.iter().enumerate() {
                let dd = (x - cv) * (x - cv);
                if dd < best_d {
                    best_d = dd;
                    best = c;
                }
            }
            assign[i] = best as u8;
        }
        // Update.
        let mut sums = vec![0.0f64; k];
        let mut counts = vec![0usize; k];
        for (i, &x) in data.iter().enumerate() {
            sums[assign[i] as usize] += x as f64;
            counts[assign[i] as usize] += 1;
        }
        for c in 0..k {
            if counts[c] > 0 {
                centroids[c] = (sums[c] / counts[c] as f64) as f32;
            }
        }
    }
    (centroids, assign)
}

impl UpdateCodec for FedCodeCodec {
    fn name(&self) -> &'static str {
        "fedcode"
    }

    fn family(&self) -> Family {
        Family::Delta
    }

    fn encode(&self, ctx: &EncodeCtx) -> Result<Encoded> {
        let d = ctx.d;
        let delta: Vec<f32> = (0..d).map(|i| ctx.s_k[i] - ctx.s_g[i]).collect();
        let (centroids, assign) =
            kmeans_1d(&delta, self.codebook_size, self.kmeans_iters, ctx.seed);

        let stream = ctx.seed & 0xff; // per-client stream id in the sim
        let mut counters = self.round_counter.lock().unwrap();
        let round = counters.entry(stream).or_insert(0);
        let send_assignments = *round % self.assignment_period == 0;
        *round += 1;
        drop(counters);

        let mut bytes = Vec::new();
        wire::put_u32(&mut bytes, d as u32);
        bytes.push(send_assignments as u8);
        bytes.push(self.codebook_size as u8);
        for &c in &centroids {
            wire::put_f32(&mut bytes, c);
        }
        if send_assignments {
            // Nibble-pack when k ≤ 16 (4 bits/assignment before DEFLATE).
            let packed: Vec<u8> = if self.codebook_size <= 16 {
                assign
                    .chunks(2)
                    .map(|c| c[0] | (c.get(1).copied().unwrap_or(0) << 4))
                    .collect()
            } else {
                assign.clone()
            };
            let z = deflate::zlib_compress(&packed);
            wire::put_u32(&mut bytes, z.len() as u32);
            bytes.extend_from_slice(&z);
            self.last_assignments
                .lock()
                .unwrap()
                .insert(stream, assign);
        }
        Ok(Encoded { bytes })
    }

    fn decode(&self, bytes: &[u8], ctx: &DecodeCtx) -> Result<Update> {
        let mut r = wire::Reader::new(bytes);
        let d = r.u32()? as usize;
        ensure!(d == ctx.d, "dimension mismatch");
        let has_assign = r.bytes(1)?[0] != 0;
        let k = r.bytes(1)?[0] as usize;
        let mut centroids = Vec::with_capacity(k);
        for _ in 0..k {
            centroids.push(r.f32()?);
        }
        let stream = ctx.seed & 0xff;
        let assign: Vec<u8> = if has_assign {
            let zlen = r.u32()? as usize;
            let z = r.bytes(zlen)?;
            let raw = deflate::zlib_decompress(z).map_err(|e| anyhow::anyhow!(e))?;
            let a: Vec<u8> = if k <= 16 {
                ensure!(raw.len() == d.div_ceil(2), "packed assignment length mismatch");
                let mut out = Vec::with_capacity(d);
                for &b in &raw {
                    out.push(b & 0x0f);
                    if out.len() < d {
                        out.push(b >> 4);
                    }
                }
                out
            } else {
                raw
            };
            ensure!(a.len() == d, "assignment length mismatch");
            a
        } else {
            match self.last_assignments.lock().unwrap().get(&stream) {
                Some(a) => a.clone(),
                None => vec![0u8; d], // cold start: all-zero codeword
            }
        };
        let delta = assign
            .iter()
            .map(|&a| centroids.get(a as usize).copied().unwrap_or(0.0))
            .collect();
        Ok(Update::ScoreDelta(delta))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn assignment_round_reconstructs_quantized_delta() {
        let d = 20_000;
        let mut rng = Xoshiro256pp::new(3);
        let s_g = vec![0.0f32; d];
        let s_k: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let codec = FedCodeCodec::default();
        let ctx = EncodeCtx {
            d,
            theta_k: &[],
            theta_g: &[],
            mask_k: &[],
            mask_g: &[],
            s_k: &s_k,
            s_g: &s_g,
            kappa: 1.0,
            seed: 17,
        };
        let enc = codec.encode(&ctx).unwrap(); // round 0 ⇒ assignments sent
        let dctx = DecodeCtx {
            d,
            mask_g: &[],
            s_g: &s_g,
            seed: 17,
        };
        let Update::ScoreDelta(rec) = codec.decode(&enc.bytes, &dctx).unwrap() else {
            panic!()
        };
        // Quantization error bounded by k-means distortion: high cosine.
        let dot: f64 = rec.iter().zip(&s_k).map(|(a, b)| (a * b) as f64).sum();
        let na = rec.iter().map(|a| (a * a) as f64).sum::<f64>().sqrt();
        let nb = s_k.iter().map(|a| (a * a) as f64).sum::<f64>().sqrt();
        assert!(dot / (na * nb) > 0.9, "cos={}", dot / (na * nb));
    }

    #[test]
    fn codebook_only_rounds_are_tiny() {
        let d = 50_000;
        let mut rng = Xoshiro256pp::new(4);
        let s_g = vec![0.0f32; d];
        let s_k: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let codec = FedCodeCodec::default();
        let mk_ctx = |seed| EncodeCtx {
            d,
            theta_k: &[],
            theta_g: &[],
            mask_k: &[],
            mask_g: &[],
            s_k: &s_k,
            s_g: &s_g,
            kappa: 1.0,
            seed,
        };
        let first = codec.encode(&mk_ctx(21)).unwrap();
        let second = codec.encode(&mk_ctx(21)).unwrap();
        assert!(
            second.bytes.len() * 20 < first.bytes.len(),
            "codebook-only ({}) should be ≪ assignment round ({})",
            second.bytes.len(),
            first.bytes.len()
        );
        // Amortized bpp dips below the 1-bit baselines.
        let total: usize = [&first, &second]
            .iter()
            .map(|e| e.bytes.len())
            .sum::<usize>()
            + 2 * second.bytes.len(); // two more codebook-only rounds
        let avg_bpp = total as f64 * 8.0 / (4.0 * d as f64);
        assert!(avg_bpp < 1.0, "avg bpp={avg_bpp}");
    }
}
