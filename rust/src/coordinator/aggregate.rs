//! The server-side round drain: pull encoded updates off a [`Transport`]
//! and feed an [`Aggregator`] — per-arrival (streaming) or behind the
//! full-round barrier (batch). This is the decode→aggregate pipeline the
//! runner used to hard-wire inline; it is generic over both the transport
//! and the aggregation rule.

use super::round::RoundPlan;
use super::transport::{Payload, Transport};
use super::PipelineMode;
use crate::compress::{Encoded, ScratchPool, Update, UpdateCodec};
use crate::util::timer::Stopwatch;
use anyhow::{bail, Result};

/// Streaming aggregation sink: a round is `begin_round(K)` → K×`absorb` →
/// `finish_round`. Implemented by `fl::server::MaskServer`; any other sink
/// (a sharded server, a test spy) plugs in the same way.
///
/// Contract (see `MaskServer` for the reference semantics): `absorb` must
/// accept participant slots in any arrival order and produce state
/// equivalent to slot-ordered application; `finish_round` publishes the new
/// global state.
pub trait Aggregator {
    fn begin_round(&mut self, expected: usize);
    fn absorb(&mut self, slot: usize, update: Update);
    fn finish_round(&mut self);

    /// Hand back an update buffer whose contents have been folded into the
    /// aggregator state (mask-family absorbs spend their buffer
    /// immediately; delta-family reorder windows release them in slot
    /// order). The drain loop feeds these into its [`ScratchPool`], closing
    /// the zero-allocation decode cycle. Default: nothing to reclaim.
    fn reclaim_buffer(&mut self) -> Option<Vec<f32>> {
        None
    }
}

/// Deterministic per-slot accounting from one drained round. Kept per-slot
/// (not running sums) so callers can reduce in slot order — f64 addition is
/// order-sensitive and arrival order is not deterministic.
#[derive(Clone, Debug)]
pub struct DrainReport {
    /// Mean local training loss, by participant slot.
    pub loss_by_slot: Vec<f64>,
    /// Client-side encode seconds, by participant slot.
    pub enc_by_slot: Vec<f64>,
    /// Total server-side decode seconds (wall time, arrival order).
    pub dec_secs: f64,
}

impl DrainReport {
    fn new(expected: usize) -> Self {
        Self {
            loss_by_slot: vec![0.0; expected],
            enc_by_slot: vec![0.0; expected],
            dec_secs: 0.0,
        }
    }

    pub fn total_loss(&self) -> f64 {
        self.loss_by_slot.iter().sum()
    }

    pub fn total_enc_secs(&self) -> f64 {
        self.enc_by_slot.iter().sum()
    }
}

/// Drain one round's `plan.expected()` updates from `transport`, decode
/// them against the plan's broadcast snapshot, and drive `agg` per `mode`.
///
/// Streaming: decode→absorb per arrival (the aggregator holds O(d) state).
/// Batch: buffer every payload, then decode + absorb in slot order behind
/// the barrier — the seed's reference behaviour. Both produce bitwise
/// identical aggregator state (see `fl::server` module docs).
///
/// Decoding draws its output buffers from `pool` and the aggregator's
/// spent buffers flow back into it after every absorb, so a pool that
/// outlives the round (the runner owns one per experiment) makes
/// steady-state decode allocation-free.
///
/// Errors if the uplink closes early, a client reports an in-band failure,
/// a slot arrives twice, or decoding fails.
pub fn drain_round(
    transport: &mut dyn Transport,
    plan: &RoundPlan,
    codec: &dyn UpdateCodec,
    agg: &mut dyn Aggregator,
    mode: PipelineMode,
    pool: &ScratchPool,
) -> Result<DrainReport> {
    let expected = plan.expected();
    let mut report = DrainReport::new(expected);
    let mut seen = vec![false; expected];
    let mut buffered: Vec<Option<Encoded>> = match mode {
        PipelineMode::Streaming => Vec::new(),
        PipelineMode::Batch => vec![None; expected],
    };

    if mode == PipelineMode::Streaming {
        agg.begin_round(expected);
    }
    for got in 0..expected {
        let msg = match transport.recv() {
            Some(msg) => msg,
            None => bail!("uplink closed after {got}/{expected} updates"),
        };
        let enc = match msg.payload {
            Payload::Update(enc) => enc,
            Payload::Failed(err) => bail!("client {} failed: {err}", msg.client_id),
        };
        // Transport data must never panic the server, so bad slots are a
        // recoverable error here; `MaskServer::absorb` re-checks the same
        // invariant with a panic to protect Aggregator drivers other than
        // this loop (the two layers are intentionally redundant).
        if msg.slot >= expected || seen[msg.slot] {
            bail!("bad or duplicate participant slot {}", msg.slot);
        }
        seen[msg.slot] = true;
        report.loss_by_slot[msg.slot] = msg.loss as f64;
        report.enc_by_slot[msg.slot] = msg.enc_secs;
        match mode {
            PipelineMode::Streaming => {
                let t = Stopwatch::new();
                let update = codec.decode_pooled(&enc.bytes, &plan.decode_ctx(msg.slot), pool)?;
                report.dec_secs += t.elapsed_secs();
                agg.absorb(msg.slot, update);
                while let Some(buf) = agg.reclaim_buffer() {
                    pool.put(buf);
                }
            }
            PipelineMode::Batch => buffered[msg.slot] = Some(enc),
        }
    }
    match mode {
        PipelineMode::Streaming => agg.finish_round(),
        PipelineMode::Batch => {
            // Barrier passed: one begin/absorb×K/finish sweep in slot order.
            agg.begin_round(expected);
            for (slot, enc) in buffered.iter().enumerate() {
                let enc = enc.as_ref().expect("all slots arrived");
                let t = Stopwatch::new();
                let update = codec.decode_pooled(&enc.bytes, &plan.decode_ctx(slot), pool)?;
                report.dec_secs += t.elapsed_secs();
                agg.absorb(slot, update);
                while let Some(buf) = agg.reclaim_buffer() {
                    pool.put(buf);
                }
            }
            agg.finish_round();
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress;
    use crate::coordinator::round::RoundEngine;
    use crate::coordinator::transport::{ChannelTransport, WireMessage};

    #[derive(Default)]
    struct Spy {
        begun: Option<usize>,
        absorbed: Vec<usize>,
        finished: bool,
    }

    impl Aggregator for Spy {
        fn begin_round(&mut self, expected: usize) {
            self.begun = Some(expected);
        }

        fn absorb(&mut self, slot: usize, _update: Update) {
            self.absorbed.push(slot);
        }

        fn finish_round(&mut self) {
            self.finished = true;
        }
    }

    fn plan_of(n: usize) -> RoundPlan {
        let theta = vec![0.5f32; 16];
        let s = vec![0.0f32; 16];
        RoundEngine::new(1, n, 1.0, 0.8, 0.25, 3).plan(0, &theta, &s)
    }

    fn msg(slot: usize, payload: Payload) -> WireMessage {
        WireMessage {
            round: 0,
            client_id: slot,
            slot,
            payload,
            enc_secs: 0.0,
            loss: 0.25,
        }
    }

    #[test]
    fn failed_client_surfaces_as_error() {
        let plan = plan_of(2);
        let codec = compress::by_name("fedpm").unwrap();
        let (mut transport, sender) = ChannelTransport::new();
        sender
            .send(msg(0, Payload::Failed("client oom".into())))
            .unwrap();
        drop(sender);
        let mut spy = Spy::default();
        let err = drain_round(
            &mut transport,
            &plan,
            codec.as_ref(),
            &mut spy,
            PipelineMode::Batch,
            &ScratchPool::new(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("client oom"), "{err}");
        assert!(!spy.finished);
    }

    #[test]
    fn duplicate_slot_rejected_before_decode() {
        let plan = plan_of(2);
        let codec = compress::by_name("fedpm").unwrap();
        let (mut transport, sender) = ChannelTransport::new();
        // Batch mode defers decoding, so garbage payloads are fine here.
        let junk = Payload::Update(Encoded { bytes: vec![0; 4] });
        sender.send(msg(1, junk.clone())).unwrap();
        sender.send(msg(1, junk)).unwrap();
        drop(sender);
        let mut spy = Spy::default();
        let err = drain_round(
            &mut transport,
            &plan,
            codec.as_ref(),
            &mut spy,
            PipelineMode::Batch,
            &ScratchPool::new(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
    }

    #[test]
    fn early_close_reports_progress() {
        let plan = plan_of(3);
        let codec = compress::by_name("fedpm").unwrap();
        let (mut transport, sender) = ChannelTransport::new();
        drop(sender); // no client ever reports
        let mut spy = Spy::default();
        let err = drain_round(
            &mut transport,
            &plan,
            codec.as_ref(),
            &mut spy,
            PipelineMode::Streaming,
            &ScratchPool::new(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("0/3"), "{err}");
        assert_eq!(spy.begun, Some(3), "streaming begins before the drain");
    }
}
