//! **Table 1** — DeltaMask across architectures / pre-training strategies on
//! CIFAR-100-sim (IID, N=10): Fine-tuning vs DeltaMask accuracy + avg bpp.
//!
//!     cargo bench --bench table1_archs [-- --full]
//!
//! Shape claims: DeltaMask lands near fine-tuning on every architecture at
//! ≈0.2 bpp; larger widths (ViT-L/14 sim) close the gap the most.

use deltamask::bench::{BenchScale, Table};
use deltamask::fl::{arch_width, run_experiment};
use deltamask::model::ArchConfig;
use deltamask::util::cli::Args;

const ARCHS: &[(&str, &str)] = &[
    ("vitb32", "CLIP ViT-B/32"),
    ("vitl14", "CLIP ViT-L/14"),
    ("dinov2b", "DINOv2-Base"),
    ("dinov2s", "DINOv2-Small"),
    ("convmixer", "ConvMixer-768/32"),
];

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let scale = BenchScale::from_args(&args);
    // Architecture identity = block width F; reduced scale divides widths by
    // 4 (keeping their ordering) so the native sweep stays fast.
    let divisor = if scale.full { 1 } else { args.usize("divisor", 4) };

    let mut table = Table::new(
        "Table 1 (architectures, CIFAR-100-sim, IID)",
        &["arch", "d", "fine-tuning acc", "deltamask acc", "deltamask avg bpp"],
    );
    for (arch, label) in ARCHS {
        let (f_full, b) = arch_width(arch).unwrap();
        let f = (f_full / divisor).max(16);
        let mk = |method: &str| {
            let mut cfg = scale.config("cifar100", method);
            cfg.arch = arch.to_string();
            cfg.arch_override = Some(ArchConfig::new(f, 100, if scale.full { b } else { scale.batch }, 5));
            cfg
        };
        let ft = run_experiment(&mk("fine_tuning"))?;
        let dm = run_experiment(&mk("deltamask"))?;
        eprintln!(
            "  {label}: ft={:.4} dm={:.4} bpp={:.4}",
            ft.final_accuracy(),
            dm.final_accuracy(),
            dm.avg_bpp()
        );
        table.row(vec![
            label.to_string(),
            format!("{}", 5 * f * f),
            format!("{:.4}", ft.final_accuracy()),
            format!("{:.4}", dm.final_accuracy()),
            format!("{:.4}", dm.avg_bpp()),
        ]);
    }
    table.print();
    table.save("table1_archs");
    Ok(())
}
