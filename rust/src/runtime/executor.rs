//! The PJRT execution engine: compile-once, execute-many.
//!
//! Perf-relevant design (DESIGN.md §8, L3 targets):
//! * **Device-resident constants** — frozen backbone weights / head tensors
//!   are uploaded once per client-session as `PjRtBuffer`s and passed by
//!   reference to `execute_b`, so the per-step host→device traffic is only
//!   the mutable state (scores, Adam moments, batch, uniforms).
//! * **Executable cache** — each `(hlo file)` is compiled exactly once per
//!   process; clients share the compiled artifact through `Arc`.

use super::manifest::{GraphSpec, Manifest};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// Process-wide executor over one PJRT CPU client.
pub struct Executor {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<PathBuf, Arc<xla::PjRtLoadedExecutable>>>,
}

// The PJRT client/executable wrappers are thread-compatible C++ objects the
// xla crate does not mark Send/Sync; we serialize compilation through the
// cache mutex and PJRT CPU execution itself is thread-safe.
unsafe impl Send for Executor {}
unsafe impl Sync for Executor {}

impl Executor {
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Load from the default artifacts location.
    pub fn from_artifacts() -> Result<Self> {
        let dir = super::artifacts_dir()
            .ok_or_else(|| anyhow!("artifacts/manifest.json not found — run `make artifacts`"))?;
        Self::new(Manifest::load(&dir)?)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) the executable for a graph.
    pub fn graph(&self, arch: &str, c: usize, name: &str) -> Result<GraphHandle> {
        let combo = self
            .manifest
            .find(arch, c)
            .ok_or_else(|| anyhow!("no combo {arch}/C={c} in manifest"))?;
        let spec = combo.graph(name)?.clone();
        let exe = self.compile_cached(&spec)?;
        Ok(GraphHandle {
            exe,
            spec,
            combo_d: combo.d,
        })
    }

    fn compile_cached(&self, spec: &GraphSpec) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(exe) = cache.get(&spec.file) {
            return Ok(exe.clone());
        }
        let path = spec
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {path}: {e:?}"))?;
        let exe = Arc::new(exe);
        cache.insert(spec.file.clone(), exe.clone());
        Ok(exe)
    }

    /// Upload a host tensor once; reuse the returned buffer across calls.
    pub fn upload(&self, data: &[f32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        let n: usize = shape.iter().product::<usize>().max(1);
        if data.len() != n {
            bail!("upload: data len {} != shape product {n}", data.len());
        }
        let dims: Vec<usize> = shape.to_vec();
        self.client
            .buffer_from_host_buffer(data, &dims, None)
            .map_err(|e| anyhow!("buffer_from_host_buffer: {e:?}"))
    }
}

/// A compiled graph plus its manifest spec. Cheap to clone-by-handle via the
/// inner `Arc`.
pub struct GraphHandle {
    exe: Arc<xla::PjRtLoadedExecutable>,
    pub spec: GraphSpec,
    pub combo_d: usize,
}

impl GraphHandle {
    /// Execute with device-resident buffers; outputs come back as host
    /// `Vec<f32>` per manifest output spec.
    pub fn execute(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "graph {:?}: got {} inputs, expected {}",
                self.spec.file.file_name().unwrap_or_default(),
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        let borrowed: Vec<&xla::PjRtBuffer> = inputs.to_vec();
        let result = self
            .exe
            .execute_b(&borrowed)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always a tuple literal.
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("to_tuple: {e:?}"))?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "graph returned {} outputs, manifest says {}",
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (part, spec) in parts.iter().zip(&self.spec.outputs) {
            let v = part
                .to_vec::<f32>()
                .map_err(|e| anyhow!("output {}: {e:?}", spec.name))?;
            if v.len() != spec.elements() {
                bail!(
                    "output {}: {} elements, expected {}",
                    spec.name,
                    v.len(),
                    spec.elements()
                );
            }
            out.push(v);
        }
        Ok(out)
    }

    /// Convenience: execute from host slices (uploads everything each call —
    /// fine for one-shots; hot paths should pre-upload via
    /// [`Executor::upload`] and call [`execute`]).
    pub fn execute_host(
        &self,
        exec: &Executor,
        inputs: &[&[f32]],
    ) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "execute_host: got {} inputs, expected {}",
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        let mut bufs = Vec::with_capacity(inputs.len());
        for (data, spec) in inputs.iter().zip(&self.spec.inputs) {
            bufs.push(
                exec.upload(data, &spec.shape)
                    .with_context(|| format!("uploading input {}", spec.name))?,
            );
        }
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        self.execute(&refs)
    }
}
