"""AOT compilation: lower every (architecture, class-count) graph family to
HLO **text** and write ``artifacts/manifest.json`` for the rust runtime.

HLO text — not ``.serialize()`` — is the interchange format: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids that the image's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts``; never on the request path.

    cd python && python -m compile.aot --out ../artifacts [--only test]
"""

import argparse
import json
import os
import time

import jax

from .model import ModelConfig, graph_specs, f32

# Simulated architectures (DESIGN.md §5): F = block width of the 5 maskable
# blocks; d = 5·F² mask parameters. The "test" config is a miniature used by
# rust integration tests and the quickstart example.
ARCHS = {
    "vitb32": dict(F=256, B=64),      # CLIP ViT-B/32 sim
    "vitl14": dict(F=384, B=64),      # CLIP ViT-L/14 sim
    "dinov2b": dict(F=320, B=64),     # DINOv2-Base sim
    "dinov2s": dict(F=160, B=64),     # DINOv2-Small sim
    "convmixer": dict(F=288, B=64),   # ConvMixer-768/32 sim
    "test": dict(F=32, B=8),          # miniature for tests/examples
}

# Paper's 8 datasets → class counts (§4).
DATASETS = {
    "cifar10": 10,
    "cifar100": 100,
    "svhn": 10,
    "emnist": 49,
    "fmnist": 10,
    "eurosat": 10,
    "food101": 101,
    "cars196": 196,
}

# (arch, C) combos actually lowered:
#  - vitb32 × every distinct class count (covers all 8 datasets: Tables 2/3,
#    Figs 1/3/4/7/8/9, Table 5),
#  - the other four archs × C=100 (Table 1),
#  - the miniature test combo.
def default_combos():
    combos = []
    for c in sorted(set(DATASETS.values())):
        combos.append(("vitb32", c))
    for arch in ("vitl14", "dinov2b", "dinov2s", "convmixer"):
        combos.append((arch, 100))
    combos.append(("test", 10))
    return combos


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_combo(arch: str, C: int, out_dir: str, verbose=True):
    a = ARCHS[arch]
    cfg = ModelConfig(name=arch, F=a["F"], C=C, B=a["B"])
    specs = graph_specs(cfg)
    entry = {
        "arch": arch,
        "F": cfg.F,
        "C": cfg.C,
        "B": cfg.B,
        "L": cfg.L,
        "d": cfg.d,
        "graphs": {},
    }
    for graph, spec in specs.items():
        t0 = time.time()
        args = [f32(shape) for _, shape in spec["inputs"]]
        lowered = jax.jit(spec["fn"]).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{arch}_c{C}_{graph}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entry["graphs"][graph] = {
            "file": fname,
            "inputs": [
                {"name": n, "shape": list(shape), "dtype": "f32"}
                for n, shape in spec["inputs"]
            ],
            "outputs": [
                {"name": n, "shape": list(shape), "dtype": "f32"}
                for n, shape in spec["outputs"]
            ],
        }
        if verbose:
            print(
                f"  {fname}: {len(text)/1024:.0f} KiB in {time.time()-t0:.1f}s",
                flush=True,
            )
    return entry


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--out", default="../artifacts")
    p.add_argument(
        "--only",
        default=None,
        help="comma-separated arch names to lower (e.g. 'test' or 'vitb32')",
    )
    args = p.parse_args()
    os.makedirs(args.out, exist_ok=True)

    combos = default_combos()
    if args.only:
        keep = set(args.only.split(","))
        combos = [(a, c) for a, c in combos if a in keep]

    manifest = {
        "version": 1,
        "datasets": DATASETS,
        "archs": {k: v["F"] for k, v in ARCHS.items()},
        "combos": [],
    }
    t0 = time.time()
    for arch, c in combos:
        print(f"lowering {arch} C={c} ...", flush=True)
        manifest["combos"].append(lower_combo(arch, c, args.out))
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"done: {len(combos)} combos in {time.time()-t0:.0f}s -> {args.out}")


if __name__ == "__main__":
    main()
