//! Server-side state: Bayesian aggregation of binary-mask updates (Alg. 2 /
//! Eq. 3) and FedAvg aggregation of score-delta updates.

use crate::compress::Update;
use crate::model::theta_from_scores;

/// The global probability mask and its Beta posterior.
#[derive(Clone, Debug)]
pub struct MaskServer {
    pub theta_g: Vec<f32>,
    /// Score mirror s_g = logit(θ_g) — the reference point for the
    /// delta-family codecs.
    pub s_g: Vec<f32>,
    alpha: Vec<f32>,
    beta: Vec<f32>,
    lambda0: f32,
    pub rho: f64,
    pub round: usize,
}

impl MaskServer {
    pub fn new(d: usize, rho: f64) -> Self {
        Self::with_theta0(d, rho, 0.5)
    }

    /// θ₀-initialized server (pre-trained-model regime starts near 1).
    pub fn with_theta0(d: usize, rho: f64, theta0: f32) -> Self {
        let theta0 = theta0.clamp(0.01, 0.99);
        let s0 = (theta0 / (1.0 - theta0)).ln();
        Self {
            theta_g: vec![theta0; d],
            s_g: vec![s0; d],
            alpha: vec![1.0; d],
            beta: vec![1.0; d],
            lambda0: 1.0,
            rho,
            round: 0,
        }
    }

    /// Alg. 2 lines 3–5: reset the Beta prior every ⌈1/ρ⌉ rounds.
    pub fn begin_round(&mut self) {
        let period = (1.0 / self.rho).ceil().max(1.0) as usize;
        if self.round % period == 0 {
            self.alpha.iter_mut().for_each(|a| *a = self.lambda0);
            self.beta.iter_mut().for_each(|b| *b = self.lambda0);
        }
    }

    /// Aggregate a round of updates (all same family), then refresh θ_g /
    /// s_g. Mask family → Bayesian (Eq. 3); delta family → FedAvg on scores.
    pub fn aggregate(&mut self, updates: &[Update]) {
        assert!(!updates.is_empty());
        let d = self.theta_g.len();
        match &updates[0] {
            Update::Mask(_) => {
                // α += Σ_k m_k ; β += K·1 − Σ_k m_k (Beta-Bernoulli
                // pseudo-counts over the K client observations).
                let k = updates.len() as f32;
                let mut sum = vec![0.0f32; d];
                for u in updates {
                    let Update::Mask(m) = u else {
                        panic!("mixed update families in one round")
                    };
                    assert_eq!(m.len(), d);
                    for i in 0..d {
                        sum[i] += m[i];
                    }
                }
                for i in 0..d {
                    self.alpha[i] += sum[i];
                    self.beta[i] += k - sum[i];
                    // Eq. 3 posterior-mode estimate; λ0=1 ⇒ running average
                    // of the observed mask bits since the last reset.
                    let denom = self.alpha[i] + self.beta[i] - 2.0;
                    self.theta_g[i] = if denom > 0.0 {
                        ((self.alpha[i] - 1.0) / denom).clamp(0.01, 0.99)
                    } else {
                        0.5
                    };
                }
                self.refresh_scores();
            }
            Update::ScoreDelta(_) => {
                let k = updates.len() as f32;
                for u in updates {
                    let Update::ScoreDelta(delta) = u else {
                        panic!("mixed update families in one round")
                    };
                    assert_eq!(delta.len(), d);
                    for i in 0..d {
                        self.s_g[i] += delta[i] / k;
                    }
                }
                theta_from_scores(&self.s_g, &mut self.theta_g);
            }
        }
        self.round += 1;
    }

    fn refresh_scores(&mut self) {
        for (s, &p) in self.s_g.iter_mut().zip(&self.theta_g) {
            let p = p.clamp(1e-6, 1.0 - 1e-6);
            *s = (p / (1.0 - p)).ln();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn bayes_agg_is_running_average_with_lambda1() {
        let d = 4;
        let mut srv = MaskServer::new(d, 1.0);
        srv.begin_round();
        srv.aggregate(&[
            Update::Mask(vec![1.0, 0.0, 1.0, 1.0]),
            Update::Mask(vec![1.0, 0.0, 0.0, 1.0]),
        ]);
        // θ = mean of observed bits = [1, 0, 0.5, 1] (clamped to [.01,.99]).
        assert_eq!(srv.theta_g, vec![0.99, 0.01, 0.5, 0.99]);
    }

    #[test]
    fn prior_reset_schedule() {
        let d = 2;
        let mut srv = MaskServer::new(d, 0.5); // reset every 2 rounds
        for round in 0..4 {
            srv.begin_round();
            srv.aggregate(&[Update::Mask(vec![1.0, 0.0])]);
            let expect_after_reset = round % 2 == 0;
            if expect_after_reset {
                // Fresh prior + one all-ones observation on coord 0.
                assert_eq!(srv.theta_g[0], 0.99, "round {round}");
            }
        }
    }

    #[test]
    fn unbiased_estimation_error_bound() {
        // Appendix B / Eq. 6: E‖θ̄ − θ̂‖² ≤ d/4K with θ̂ the mean of sampled
        // masks. Monte-Carlo over K clients.
        let d = 2_000;
        let k = 10;
        let mut rng = Xoshiro256pp::new(1);
        let thetas: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..d).map(|_| rng.next_f32()).collect())
            .collect();
        let mut theta_bar = vec![0.0f64; d];
        for t in &thetas {
            for i in 0..d {
                theta_bar[i] += t[i] as f64 / k as f64;
            }
        }
        let trials = 30;
        let mut mse = 0.0f64;
        for _ in 0..trials {
            let mut est = vec![0.0f64; d];
            for t in &thetas {
                for i in 0..d {
                    if rng.next_f32() < t[i] {
                        est[i] += 1.0 / k as f64;
                    }
                }
            }
            mse += (0..d)
                .map(|i| (est[i] - theta_bar[i]).powi(2))
                .sum::<f64>()
                / trials as f64;
        }
        let bound = d as f64 / (4.0 * k as f64);
        assert!(mse <= bound, "mse={mse} bound={bound}");
        assert!(mse > bound * 0.1, "bound should be within an order: {mse}");
    }

    #[test]
    fn delta_aggregation_moves_scores() {
        let d = 3;
        let mut srv = MaskServer::new(d, 1.0);
        srv.aggregate(&[
            Update::ScoreDelta(vec![1.0, -1.0, 0.0]),
            Update::ScoreDelta(vec![3.0, -1.0, 0.0]),
        ]);
        assert_eq!(srv.s_g, vec![2.0, -1.0, 0.0]);
        assert!((srv.theta_g[0] - crate::model::sigmoid(2.0)).abs() < 1e-6);
        assert!((srv.theta_g[2] - 0.5).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "mixed update families")]
    fn mixed_families_rejected() {
        let mut srv = MaskServer::new(2, 1.0);
        srv.aggregate(&[
            Update::Mask(vec![1.0, 0.0]),
            Update::ScoreDelta(vec![0.1, 0.2]),
        ]);
    }
}
