"""Layer-1 Pallas kernels: the masked-matmul hot-spot of DeltaMask.

The paper's compute core is the masked forward pass ``ŵ = m ⊙ w_init``
(§3.2) applied in every maskable block: ``y = x @ (m ⊙ W)ᵀ``. On a real TPU
these kernels tile W/m into VMEM blocks via ``BlockSpec``, fuse the mask
multiply into the block load (one VMEM pass — the mask never round-trips to
HBM) and feed the MXU with the masked block; the grid iterates K-innermost
so partial sums stay resident in the output block. See DESIGN.md §6 for the
GPU→TPU adaptation notes.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so the kernels lower to plain HLO (the structure —
tiling, fusion, memory schedule — is what carries to TPU, not the CPU
wallclock).

Three kernels cover fwd + bwd of ``masked_linear``:

* ``masked_matmul``        y  = x @ (m ⊙ W)ᵀ          (forward)
* ``masked_matmul_rhs``    dx = dy @ (m ⊙ W)           (input gradient)
* ``masked_outer``         dm = (dyᵀ @ x) ⊙ W          (mask gradient)

``masked_linear`` wires them into a ``jax.custom_vjp`` so the L2 model
differentiates straight through the Pallas calls. The frozen weights get a
zero cotangent (they are never updated — XLA dead-code-eliminates it).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile cap. 128 keeps MXU-shaped tiles on TPU and
# (bm·bk + 2·bn·bk + bm·bn)·4B well under a 16 MiB VMEM budget with room for
# the automatic double-buffering pipeline.
TILE_CAP = 128


def best_tile(dim: int, cap: int = TILE_CAP) -> int:
    """Largest divisor of ``dim`` not exceeding ``cap`` (grid dims must
    divide exactly; Pallas pads otherwise, which interpret mode dislikes)."""
    for t in range(min(dim, cap), 0, -1):
        if dim % t == 0:
            return t
    return 1


def _fwd_kernel(x_ref, w_ref, m_ref, o_ref):
    """o[i,j] += x[i,k] @ (w[j,k] * m[j,k])ᵀ — mask fused into the tile load."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...] @ (w_ref[...] * m_ref[...]).T


def masked_matmul(x, w, m, *, bm=None, bn=None, bk=None, interpret=True):
    """y = x @ (m ⊙ w)ᵀ with x:(B,Fin), w,m:(Fout,Fin) → y:(B,Fout)."""
    B, Fin = x.shape
    Fout, Fin2 = w.shape
    assert Fin == Fin2 and w.shape == m.shape
    bm = best_tile(B, bm or TILE_CAP)
    bn = best_tile(Fout, bn or TILE_CAP)
    bk = best_tile(Fin, bk or TILE_CAP)
    grid = (B // bm, Fout // bn, Fin // bk)
    return pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, Fout), x.dtype),
        interpret=interpret,
    )(x, w, m)


def _rhs_kernel(dy_ref, w_ref, m_ref, o_ref):
    """o[i,j] += dy[i,k] @ (w[k,j] * m[k,j]) — no transpose."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += dy_ref[...] @ (w_ref[...] * m_ref[...])


def masked_matmul_rhs(dy, w, m, *, bm=None, bn=None, bk=None, interpret=True):
    """dx = dy @ (m ⊙ w) with dy:(B,Fout), w,m:(Fout,Fin) → dx:(B,Fin)."""
    B, Fout = dy.shape
    Fout2, Fin = w.shape
    assert Fout == Fout2 and w.shape == m.shape
    bm = best_tile(B, bm or TILE_CAP)
    bn = best_tile(Fin, bn or TILE_CAP)
    bk = best_tile(Fout, bk or TILE_CAP)
    grid = (B // bm, Fin // bn, Fout // bk)
    return pl.pallas_call(
        _rhs_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, Fin), dy.dtype),
        interpret=interpret,
    )(dy, w, m)


def _outer_kernel(dy_ref, x_ref, w_ref, o_ref):
    """o[i,j] += (dy[k,i]ᵀ @ x[k,j]) * w[i,j].

    The ⊙w epilogue distributes over the K accumulation, so fusing it per
    partial product is exact."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += (dy_ref[...].T @ x_ref[...]) * w_ref[...]


def masked_outer(dy, x, w, *, bm=None, bn=None, bk=None, interpret=True):
    """dm = (dyᵀ @ x) ⊙ w with dy:(B,Fout), x:(B,Fin) → dm:(Fout,Fin)."""
    B, Fout = dy.shape
    B2, Fin = x.shape
    assert B == B2 and w.shape == (Fout, Fin)
    bm = best_tile(Fout, bm or TILE_CAP)
    bn = best_tile(Fin, bn or TILE_CAP)
    bk = best_tile(B, bk or TILE_CAP)
    grid = (Fout // bm, Fin // bn, B // bk)
    return pl.pallas_call(
        _outer_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Fout, Fin), dy.dtype),
        interpret=interpret,
    )(dy, x, w)


@jax.custom_vjp
def masked_linear(x, w, m):
    """Differentiable masked linear layer: y = x @ (m ⊙ w)ᵀ.

    Gradients flow to ``x`` and ``m`` (the mask probabilities, via the
    straight-through estimator wired in L2); ``w`` is the frozen
    foundation-model weight and receives a zero cotangent.
    """
    return masked_matmul(x, w, m)


def _ml_fwd(x, w, m):
    return masked_linear(x, w, m), (x, w, m)


def _ml_bwd(res, dy):
    x, w, m = res
    dx = masked_matmul_rhs(dy, w, m)
    dm = masked_outer(dy, x, w)
    return dx, jnp.zeros_like(w), dm


masked_linear.defvjp(_ml_fwd, _ml_bwd)


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set of one fwd grid step (x, w, m, o tiles).

    Used by DESIGN.md §8 and the pytest structural checks: must stay far
    below the ~16 MiB/core budget to leave room for double buffering.
    """
    return dtype_bytes * (bm * bk + 2 * (bn * bk) + bm * bn)


def mxu_utilization_estimate(bm: int, bn: int, bk: int, mxu: int = 128) -> float:
    """Fraction of MXU lanes a (bm × bk)·(bk × bn) tile keeps busy —
    1.0 when every tile dim is a multiple of the 128-wide systolic array."""

    def frac(d):
        return d / (((d + mxu - 1) // mxu) * mxu)

    return min(frac(bm), 1.0) * min(frac(bn), 1.0) * min(frac(bk), 1.0)
