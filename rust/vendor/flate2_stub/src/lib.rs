//! Compile-only stub of the `flate2` API surface the `flate2`-gated
//! DEFLATE cross-validation tests use.
//!
//! The point of those tests is to check the from-scratch DEFLATE codec
//! against an **independent** implementation, so a stub cannot honestly
//! stand in at run time: every stream operation returns
//! `io::ErrorKind::Unsupported` with an explanatory message, making the
//! gated tests fail loudly instead of passing vacuously. What the stub
//! does buy is **compile coverage**: CI's `feature-matrix` job builds and
//! clippy-checks `--features flate2`, so the gated test code can no
//! longer rot. To run the cross-checks for real, replace the
//! `rust/vendor/flate2_stub` path dependency in the root `Cargo.toml`
//! with the crates.io `flate2` in a registry-connected environment.

use std::io;

fn unsupported() -> io::Error {
    io::Error::new(
        io::ErrorKind::Unsupported,
        "flate2 stub build: this is the vendored compile-only shim at \
         rust/vendor/flate2_stub; swap in the real crates.io `flate2` to run the \
         DEFLATE cross-validation tests",
    )
}

/// Compression-level selector (accepted and ignored by the stub).
#[derive(Clone, Copy, Debug)]
pub struct Compression(pub u32);

impl Compression {
    pub fn new(level: u32) -> Self {
        Self(level)
    }

    pub fn best() -> Self {
        Self(9)
    }

    pub fn fast() -> Self {
        Self(1)
    }
}

pub mod read {
    use std::io;

    /// Stub zlib decoder: `read` always errors (see the crate docs).
    pub struct ZlibDecoder<R> {
        #[allow(dead_code)]
        inner: R,
    }

    impl<R: io::Read> ZlibDecoder<R> {
        pub fn new(inner: R) -> Self {
            Self { inner }
        }
    }

    impl<R: io::Read> io::Read for ZlibDecoder<R> {
        fn read(&mut self, _buf: &mut [u8]) -> io::Result<usize> {
            Err(super::unsupported())
        }
    }
}

pub mod write {
    use std::io;

    /// Stub zlib encoder: `write`/`finish` always error (see the crate
    /// docs).
    pub struct ZlibEncoder<W> {
        #[allow(dead_code)]
        inner: W,
    }

    impl<W: io::Write> ZlibEncoder<W> {
        pub fn new(inner: W, _level: crate::Compression) -> Self {
            Self { inner }
        }

        pub fn finish(self) -> io::Result<W> {
            Err(super::unsupported())
        }
    }

    impl<W: io::Write> io::Write for ZlibEncoder<W> {
        fn write(&mut self, _buf: &[u8]) -> io::Result<usize> {
            Err(super::unsupported())
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }
}
