//! **Table 4 / App. C.4** — per-entry CPU time of filter construction and
//! membership queries, Xor{8,16,32} vs BFuse{8,16,32}.
//!
//!     cargo bench --bench table4_edge                 # 1M entries
//!     cargo bench --bench table4_edge -- --full       # paper's 10M
//!
//! The paper measured Jetson Nano / RPi 4 / Coral with a power HAT; on this
//! testbed we report measured CPU ns/entry (energy ∝ time on fixed
//! hardware). The device-independent claims checked: BFuse faster than XOR
//! at every width; time grows only mildly with bits-per-entry.

use deltamask::bench::{summarize, time_fn, Table};
use deltamask::filters::{BinaryFuse, MembershipFilter, XorFilter};
use deltamask::util::cli::Args;
use deltamask::util::rng::Xoshiro256pp;

fn main() {
    let args = Args::from_env();
    let n = if args.flag("full") {
        10_000_000
    } else {
        args.usize("entries", 1_000_000)
    };
    let mut rng = Xoshiro256pp::new(3);
    let keys: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
    let probes: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
    let reps = if n >= 10_000_000 { 1 } else { 3 };

    println!("Table 4 over {n} entries ({reps} reps)");
    let mut table = Table::new(
        "Table 4: filter construct/query cost",
        &["filter", "bpe", "construct ns/entry", "query ns/entry"],
    );

    macro_rules! profile {
        ($label:expr, $ty:ty) => {{
            let c = summarize(&time_fn(0, reps, || <$ty>::build(&keys).unwrap()));
            let f = <$ty>::build(&keys).unwrap();
            let q = summarize(&time_fn(1, reps, || {
                probes.iter().filter(|&&k| f.contains(k)).count()
            }));
            eprintln!(
                "  {}: construct {:.1} ns/e, query {:.1} ns/e",
                $label,
                c.mean / n as f64 * 1e9,
                q.mean / n as f64 * 1e9
            );
            table.row(vec![
                $label.to_string(),
                format!("{:.2}", f.bits_per_entry()),
                format!("{:.1}", c.mean / n as f64 * 1e9),
                format!("{:.1}", q.mean / n as f64 * 1e9),
            ]);
        }};
    }

    profile!("Xor8", XorFilter<u8>);
    profile!("Xor16", XorFilter<u16>);
    profile!("Xor32", XorFilter<u32>);
    profile!("BFuse8", BinaryFuse<u8, 4>);
    profile!("BFuse16", BinaryFuse<u16, 4>);
    profile!("BFuse32", BinaryFuse<u32, 4>);
    table.print();
    table.save("table4_edge");
}
