//! **DeepReduce** (Kostopoulou et al. 2021) — Bloom-filter index
//! compression, P0 policy.
//!
//! Per App. C.1 the paper drops DeepReduce's value-compression stage (masks
//! are binary) and keeps only the Bloom-coded index set; masks are learned
//! with the same stochastic training as FedPM/DeltaMask. We transmit the
//! mask-difference index set (the same Δ as DeltaMask but *without* top-κ
//! ranking) through a Bloom filter at a bits-per-entry budget matching
//! BFuse8 — the §5.1 comparison point: "Bloom filters are prone to a higher
//! false positive rate for the same number of hash functions and bits per
//! entry".

use super::{wire, DecodeCtx, EncodeCtx, Encoded, Family, ScratchPool, Update, UpdateCodec};
use crate::codec::deflate;
use crate::filters::{BloomFilter, MembershipFilter};
use anyhow::{ensure, Result};

pub struct DeepReduceCodec {
    pub bits_per_entry: f64,
}

impl Default for DeepReduceCodec {
    fn default() -> Self {
        // Match BFuse8's ≈8.6 bpe so the comparison isolates the filter.
        Self {
            bits_per_entry: 8.62,
        }
    }
}

impl UpdateCodec for DeepReduceCodec {
    fn name(&self) -> &'static str {
        "deepreduce"
    }

    fn family(&self) -> Family {
        Family::Mask
    }

    fn encode(&self, ctx: &EncodeCtx) -> Result<Encoded> {
        let delta: Vec<u64> = (0..ctx.d)
            .filter(|&i| ctx.mask_g[i] != ctx.mask_k[i])
            .map(|i| i as u64)
            .collect();
        let bloom = BloomFilter::with_bits_per_entry(&delta, self.bits_per_entry);
        let payload = bloom.payload();
        // DeepReduce ships raw filter bytes (DEFLATE for parity with its
        // transport framing).
        let z = deflate::zlib_compress(&payload);
        let mut bytes = Vec::with_capacity(z.len() + 24);
        wire::put_u64(&mut bytes, bloom.num_bits());
        wire::put_u32(&mut bytes, bloom.num_hashes());
        wire::put_u32(&mut bytes, delta.len() as u32);
        wire::put_u32(&mut bytes, z.len() as u32);
        bytes.extend_from_slice(&z);
        Ok(Encoded { bytes })
    }

    fn decode(&self, bytes: &[u8], ctx: &DecodeCtx) -> Result<Update> {
        let mut mask = ctx.mask_g.to_vec();
        self.decode_mask_inplace(bytes, &mut mask)?;
        Ok(Update::Mask(mask))
    }

    /// Steady-state decode: output buffer drawn from the round's pool.
    fn decode_pooled(&self, bytes: &[u8], ctx: &DecodeCtx, pool: &ScratchPool) -> Result<Update> {
        let mut mask = pool.take_copy(ctx.mask_g);
        if let Err(e) = self.decode_mask_inplace(bytes, &mut mask) {
            pool.put(mask);
            return Err(e);
        }
        Ok(Update::Mask(mask))
    }

    /// Parse/validate (incl. the DEFLATE stage) once, then sweep the Bloom
    /// membership kernel per `d`-range — same rejections as `decode`.
    fn range_decoder(
        &self,
        bytes: &[u8],
        ctx: &DecodeCtx,
    ) -> Result<Option<Box<dyn super::MaskRangeDecoder>>> {
        let _ = ctx;
        Ok(Some(Box::new(self.parse_bloom(bytes)?)))
    }
}

/// A restored Bloom filter range-decodes exactly like the full sweep
/// restricted to the range (membership is a per-index property).
impl super::MaskRangeDecoder for BloomFilter {
    fn decode_range(&self, range: std::ops::Range<usize>, mask: &mut [f32]) {
        debug_assert_eq!(mask.len(), range.len());
        self.decode_mask_into_range(mask, range.start);
    }
}

impl DeepReduceCodec {
    /// The shared parse core: validate the record and rebuild the Bloom
    /// filter (owned bit array — nothing borrows the wire bytes).
    fn parse_bloom(&self, bytes: &[u8]) -> Result<BloomFilter> {
        let mut r = wire::Reader::new(bytes);
        let num_bits = r.u64()?;
        let num_hashes = r.u32()?;
        let num_keys = r.u32()? as usize;
        let zlen = r.u32()? as usize;
        let z = r.bytes(zlen)?;
        let payload = deflate::zlib_decompress(z).map_err(|e| anyhow::anyhow!(e))?;
        ensure!(payload.len() % 8 == 0, "bloom payload misaligned");
        // Guard the probe kernel against corrupted layout params: every bit
        // index must land inside the transmitted bit array, and a wild hash
        // count is a decode-time DoS, not a valid filter.
        ensure!(
            num_bits >= 1 && num_bits <= payload.len() as u64 * 8,
            "bloom num_bits outside payload"
        );
        ensure!((1..=64).contains(&num_hashes), "bad bloom hash count");
        Ok(BloomFilter::from_parts(
            &payload, num_bits, num_hashes, num_keys,
        ))
    }

    /// Parse + run the batched Bloom membership kernel directly over
    /// `mask` (pre-filled with m^{g,t-1}).
    fn decode_mask_inplace(&self, bytes: &[u8], mask: &mut [f32]) -> Result<()> {
        let bloom = self.parse_bloom(bytes)?;
        // The kernel no-ops on an empty key set.
        bloom.decode_mask_into(mask);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::deltamask::DeltaMaskCodec;
    use crate::model::sample_mask_seeded;
    use crate::util::rng::Xoshiro256pp;

    fn setup(d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Xoshiro256pp::new(seed);
        let theta: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
        let mut mg = Vec::new();
        sample_mask_seeded(&theta, 1, &mut mg);
        let mut mk = mg.clone();
        for _ in 0..d / 20 {
            let i = rng.below(d as u64) as usize;
            mk[i] = 1.0 - mk[i];
        }
        (theta, mk, mg)
    }

    #[test]
    fn roundtrip_no_false_negatives_but_noisier_than_bfuse() {
        let d = 100_000;
        let (theta, mk, mg) = setup(d, 3);
        let ctx = EncodeCtx {
            d,
            theta_k: &theta,
            theta_g: &theta,
            mask_k: &mk,
            mask_g: &mg,
            s_k: &[],
            s_g: &[],
            kappa: 1.0,
            seed: 0,
        };
        let dctx = DecodeCtx {
            d,
            mask_g: &mg,
            s_g: &[],
            seed: 0,
        };
        let dr = DeepReduceCodec::default();
        let enc = dr.encode(&ctx).unwrap();
        let Update::Mask(m) = dr.decode(&enc.bytes, &dctx).unwrap() else {
            panic!()
        };
        let missed = (0..d).filter(|&i| mk[i] != mg[i] && m[i] != mk[i]).count();
        assert_eq!(missed, 0, "bloom has zero false negatives");
        let extra_bloom = (0..d).filter(|&i| mk[i] == mg[i] && m[i] != mk[i]).count();

        let dm = DeltaMaskCodec::default();
        let enc2 = dm.encode(&ctx).unwrap();
        let Update::Mask(m2) = dm.decode(&enc2.bytes, &dctx).unwrap() else {
            panic!()
        };
        let extra_bfuse = (0..d).filter(|&i| mk[i] == mg[i] && m2[i] != mk[i]).count();
        assert!(
            extra_bloom > extra_bfuse,
            "paper §5.1: bloom fp ({extra_bloom}) must exceed bfuse fp ({extra_bfuse})"
        );
    }

    #[test]
    fn range_decoder_tiles_to_the_full_decode() {
        let d = 30_000;
        let (theta, mk, mg) = setup(d, 5);
        let ctx = EncodeCtx {
            d,
            theta_k: &theta,
            theta_g: &theta,
            mask_k: &mk,
            mask_g: &mg,
            s_k: &[],
            s_g: &[],
            kappa: 1.0,
            seed: 0,
        };
        let dctx = DecodeCtx {
            d,
            mask_g: &mg,
            s_g: &[],
            seed: 0,
        };
        let dr = DeepReduceCodec::default();
        let enc = dr.encode(&ctx).unwrap();
        let Update::Mask(want) = dr.decode(&enc.bytes, &dctx).unwrap() else {
            panic!()
        };
        let rd = dr
            .range_decoder(&enc.bytes, &dctx)
            .unwrap()
            .expect("deepreduce supports range decoding");
        let mut got = mg.clone();
        for w in [0usize, d / 4, d / 2 + 13, d].windows(2) {
            rd.decode_range(w[0]..w[1], &mut got[w[0]..w[1]]);
        }
        assert_eq!(got, want, "range tiling diverged from full decode");
        // Malformed records are rejected at parse time, like decode.
        assert!(dr.range_decoder(&enc.bytes[..6], &dctx).is_err());
    }
}
