//! Dimension-sharded aggregation: partition the parameter space `0..d`
//! into `S` contiguous shards, each owning its own slice of the
//! aggregation state, its own participation counters (inside the slice
//! sink) and its own [`ScratchPool`], behind the same
//! `begin_round`/`absorb`/`finish_round` streaming interface the
//! single-lane [`Aggregator`] exposes.
//!
//! This is the ROADMAP's million-client seam: the server-side cost of a
//! round is an O(d) sweep per client update (the Eq. 5 pseudo-count
//! accumulation), and a single absorb thread caps throughput at one
//! socket's memory bandwidth. Splitting `d` at shard boundaries makes the
//! absorb stage embarrassingly parallel in the dimension axis — the same
//! structure FedPM-style mask aggregation has on paper, where every
//! coordinate's pseudo-count is independent of every other's.
//!
//! ## Shape
//!
//! A [`ShardedAggregator`] owns `S` **absorb lanes** behind the
//! [`ShardLane`] trait. A [`ThreadLane`] is the in-process implementation:
//! a resident thread spawned once at construction and parked between
//! rounds on a per-lane control channel — round t+1 reuses the thread (and
//! the lane's sub-update [`ScratchPool`]) that round t warmed up, so a
//! view that outlives its rounds reaches a cross-round zero-allocation,
//! zero-spawn steady state (the round-resident drain pipeline keeps one
//! view per experiment). A [`RemoteShardLane`] keeps the same resident
//! shape but the absorb arithmetic runs in a `deltamask shard-worker`
//! process on the other end of a DMW1 socket (see the *Multi-host lanes*
//! section below).
//!
//! Between rounds each lane parks its `(range, sink, pool)` triple on the
//! coordinating thread; `begin_round` ships every sink to its lane
//! together with a fresh bounded job queue and hands out a clonable
//! [`ShardRouter`]. Routing a decoded record copies each shard's
//! sub-range into a buffer leased from that shard's pool (or range-decodes
//! straight into it, see [`ShardRouter::route_decoded_ranges`]) and
//! enqueues it on the lane's queue; the lane absorbs sub-updates in
//! arrival order and recycles spent buffers into its own pool.
//! `finish_round` sends each lane a `Finish` marker, collects the sinks
//! back and parks the lanes again — at which point
//! [`ShardedAggregator::into_shards`] (full decomposition) or
//! [`ShardedAggregator::shard_slices`] (borrowed peek, for the resident
//! path's per-round θ_g sync) expose the slices for stitching (see
//! `fl::server::MaskServer::{adopt_shards, sync_from_shards}`).
//!
//! Abort discipline is unchanged from the per-round-spawn design: an
//! aborted round drops every per-round job-queue sender, the lane drains
//! what was already queued, hands its (mid-round) sink back *unfinished*
//! and parks — ready for the superseding `begin_round`. Dropping the
//! whole view mid-round still joins every lane thread.
//!
//! ## Multi-host lanes
//!
//! [`ShardedAggregator::with_placement`] places each lane `local` or on a
//! remote `deltamask shard-worker` (`uds:<path>` / `tcp:<host:port>`, see
//! [`ShardPlacement`]). A remote lane's coordinator side is a resident
//! I/O thread holding a [`ShardLink`](super::transport::socket::ShardLink):
//! it ships each routed sub-update as a `ShardSplit` frame (range-decoding
//! [`LaneMsg::DecodeAbsorb`] jobs first — the parsed filter cannot cross
//! the process boundary, the decoded sub-mask can), and the worker absorbs
//! into a [`WireSlice`]-serializable slice sink seeded over the shard
//! hello. Every finish **and every abort** pulls the worker's post-absorb
//! slice state back into the coordinator's parked mirror, so the parked
//! state of a remote lane is byte-for-byte what a [`ThreadLane`] would
//! have parked — the stitch (`adopt_shards`/`sync_from_shards`) cannot
//! tell the difference. Socket errors never panic the lane: they trip a
//! per-lane fault flag (surfaced through `Aggregator::lane_fault`, checked
//! by every drain before settling), the I/O thread keeps draining jobs so
//! routed buffers keep recycling, and the next `begin_round` retries the
//! connection, re-seeding the worker from the parked mirror.
//!
//! ## Why sharding preserves bitwise identity
//!
//! Every conforming [`Aggregator`] update rule is **per-coordinate**
//! (pseudo-count adds, slot-ordered FedAvg on scores), so restricting it
//! to a contiguous range commutes with running it over all of `d`: lane
//! `s` performs exactly the arithmetic the single-lane path performs on
//! coordinates `range_s`, in an equivalent order (each lane sees every
//! slot, and the [`Aggregator`] contract already requires arrival-order
//! equivalence). A remote lane changes *where* that arithmetic runs, not
//! what it is: the worker absorbs the identical sub-updates in the
//! identical order on the identical slice state. Stitching the slices
//! back is a pure copy. The property suite in `rust/tests/agg_shards.rs`
//! checks bitwise identity across all 11 codecs × both pipeline modes ×
//! shard counts {1,2,3,8} under adversarial arrival orders — and, for the
//! resident path, across multi-round trajectories through the same view.

use super::aggregate::Aggregator;
use super::transport::socket::{ConfigFingerprint, ShardLink, SocketAddrSpec, SocketConfig};
use crate::compress::{MaskRangeDecoder, PoolStats, ScratchPool, Update};
use crate::util::timer::Stopwatch;
use anyhow::{bail, Result};
use std::ops::Range;
use std::path::PathBuf;
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Sub-updates a lane's bounded queue holds before routing backpressures.
/// Memory in the decode→absorb hand-off stays O(cap · d) across all lanes
/// combined (each lane buffers `cap` sub-ranges of length ~d/S).
const LANE_QUEUE_CAP: usize = 4;

/// How long a [`RemoteShardLane`] keeps retrying its first connection (the
/// worker may still be racing its bind when the coordinator starts).
const CONNECT_TIMEOUT: Duration = Duration::from_secs(30);

/// Per-round reconnect budget after a lane fault: long enough to ride out
/// a worker restart race, short enough that a genuinely dead worker fails
/// the round promptly instead of stalling the drain.
const RECONNECT_TIMEOUT: Duration = Duration::from_secs(1);

/// Partition `0..d` into `shards` contiguous, near-equal ranges (the
/// first `d % shards` ranges are one element longer). The shard count is
/// clamped to `[1, max(d, 1)]` so no lane ever owns an empty range.
///
/// ```
/// use deltamask::coordinator::shard_bounds;
/// assert_eq!(shard_bounds(7, 3), vec![0..3, 3..5, 5..7]);
/// assert_eq!(shard_bounds(6, 1), vec![0..6]);
/// assert_eq!(shard_bounds(2, 8).len(), 2); // clamped: never empty shards
/// ```
pub fn shard_bounds(d: usize, shards: usize) -> Vec<Range<usize>> {
    let s = shards.clamp(1, d.max(1));
    let base = d / s;
    let extra = d % s;
    let mut bounds = Vec::with_capacity(s);
    let mut start = 0;
    for i in 0..s {
        let len = base + usize::from(i < extra);
        bounds.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, d);
    bounds
}

/// A slice sink that can cross a process boundary: the shard hello seeds a
/// `deltamask shard-worker` with the encoded state, and every slice-return
/// frame carries it back. The encoding must be **bit-exact and total**:
/// `decode_slice(encode_slice(s)) == s` for every reachable state, and
/// `decode_slice` must reject (never panic on) arbitrary bytes — it sits
/// on the wire-input path of both processes.
pub trait WireSlice: Sized {
    /// Serialize the full slice state (little-endian, self-delimiting).
    fn encode_slice(&self) -> Vec<u8>;
    /// Rebuild a slice from its encoding; total on arbitrary input.
    fn decode_slice(bytes: &[u8]) -> Result<Self>;
    /// The dimensionality of this slice (must equal its lane's range
    /// length; checked on both ends of the wire).
    fn slice_dim(&self) -> usize;
}

/// What a lane hands back when its round ends (normally after `Finish`,
/// or unfinished when the round was aborted or the lane faulted).
struct LaneReturn<A> {
    sink: A,
    absorb_secs: f64,
    finished: bool,
}

/// One unit of lane work. Routed through the bounded per-round job queue
/// a [`ShardLane::begin_round`] hands out.
pub enum LaneMsg {
    /// A pre-split sub-update: absorb as-is.
    Absorb { slot: usize, update: Update },
    /// A range-decodable record: the lane runs this shard's slice of the
    /// Eq. 5 membership sweep itself (`base` is the m^{g,t-1} baseline for
    /// `range`, leased from the lane's pool; `decoder` is the record's
    /// parsed filter, shared across the S lanes), then absorbs the
    /// result. This is what makes a single huge record's *decode* sweep —
    /// not just its absorb — run on S threads.
    DecodeAbsorb {
        slot: usize,
        range: Range<usize>,
        base: Vec<f32>,
        decoder: Arc<dyn MaskRangeDecoder>,
    },
    /// Close the lane's round; `partial` finishes degraded (quorum) rounds
    /// through the slice sink's `finish_round_partial`.
    Finish { partial: bool },
}

/// One round's work package, shipped to a resident lane thread through its
/// control channel: the expected participant count, the slice sink (moved
/// onto the lane for the round's duration) and the round's bounded job
/// queue receiver.
struct LaneRound<A> {
    expected: usize,
    sink: A,
    jobs: Receiver<LaneMsg>,
}

// ---------------------------------------------------------------------------
// The lane interface and the shared resident-thread plumbing.
// ---------------------------------------------------------------------------

/// One absorb lane of a [`ShardedAggregator`]: a contiguous dimension
/// range, a slice sink (parked here between rounds, on the lane while a
/// round is in flight), a dedicated sub-update buffer pool, and a resident
/// execution context — an in-process thread ([`ThreadLane`]) or a socket
/// I/O thread fronting a `deltamask shard-worker` process
/// ([`RemoteShardLane`]). [`ShardRouter`], the drain pipelines and the
/// stitch compose against this trait and cannot tell the implementations
/// apart.
pub trait ShardLane<A>: Send {
    /// The contiguous dimension range this lane owns.
    fn range(&self) -> Range<usize>;
    /// The lane's sub-update buffer pool (routing leases from it; the
    /// lane recycles spent buffers back into it).
    fn pool(&self) -> &Arc<ScratchPool>;
    /// Activate the lane for one round; returns the round's bounded job
    /// queue sender. The parked sink moves onto the lane until the round
    /// is collected.
    fn begin_round(&mut self, expected: usize) -> SyncSender<LaneMsg>;
    /// Wait for the in-flight round to end and park the sink; returns
    /// whether the lane saw `Finish`. Propagates a lane panic.
    fn collect_round(&mut self) -> bool;
    /// [`collect_round`](Self::collect_round) for teardown paths: never
    /// panics, best-effort parking.
    fn collect_round_quiet(&mut self);
    /// Absorb compute seconds spent in the last collected round.
    fn absorb_secs(&self) -> f64;
    /// The lane's sticky fault, if any (remote lanes: first socket or
    /// protocol error since the last successful reconnect). A faulted
    /// lane cannot finish a round; drains check this before settling.
    fn fault(&self) -> Option<String>;
    /// Borrow the parked sink (`None` while a round is in flight).
    fn sink(&self) -> Option<&A>;
    /// Take the parked sink out (panics if a round is in flight).
    fn take_sink(&mut self) -> A;
    /// Quiesce and join the lane's resident thread; propagates a panic.
    /// Must not be called with a round in flight.
    fn shutdown(&mut self);
    /// [`shutdown`](Self::shutdown) for teardown paths: never panics.
    fn shutdown_quiet(&mut self);
}

/// The resident-thread plumbing both lane implementations share: parked
/// state, the control/return channel pair and the join handle. What runs
/// *on* the thread differs (absorb loop vs. socket I/O loop); how rounds
/// are shipped to it and collected from it does not.
struct LaneCore<A> {
    range: Range<usize>,
    sink: Option<A>,
    pool: Arc<ScratchPool>,
    /// Absorb compute seconds this lane spent in the last finished round.
    absorb_secs: f64,
    /// Control channel feeding round packages to the resident thread;
    /// dropping it shuts the thread down.
    ctrl: Option<Sender<LaneRound<A>>>,
    /// Sinks travel back here at round end (finish or abort).
    ret: Receiver<LaneReturn<A>>,
    handle: Option<JoinHandle<()>>,
}

impl<A> LaneCore<A> {
    fn begin_round(&mut self, expected: usize) -> SyncSender<LaneMsg> {
        let (tx, rx) = mpsc::sync_channel::<LaneMsg>(LANE_QUEUE_CAP);
        let sink = self.sink.take().expect("lane sink present between rounds");
        let round = LaneRound {
            expected,
            sink,
            jobs: rx,
        };
        if self.ctrl.as_ref().expect("lanes alive").send(round).is_err() {
            // The resident thread is gone — it can only have panicked.
            self.propagate_death();
        }
        tx
    }

    fn collect_round(&mut self) -> bool {
        match self.ret.recv() {
            Ok(ret) => {
                self.sink = Some(ret.sink);
                self.absorb_secs = ret.absorb_secs;
                ret.finished
            }
            Err(_) => self.propagate_death(),
        }
    }

    fn collect_round_quiet(&mut self) {
        if let Ok(ret) = self.ret.recv() {
            self.sink = Some(ret.sink);
            self.absorb_secs = ret.absorb_secs;
        }
    }

    fn take_sink(&mut self) -> A {
        self.sink.take().expect("lane sink present after abort/finish")
    }

    fn shutdown(&mut self) {
        self.ctrl = None;
        if let Some(handle) = self.handle.take() {
            if let Err(panic) = handle.join() {
                std::panic::resume_unwind(panic);
            }
        }
    }

    fn shutdown_quiet(&mut self) {
        self.ctrl = None;
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }

    /// The lane's channel disconnected outside shutdown: the resident
    /// thread died, which only a panic can cause — join it and re-raise.
    fn propagate_death(&mut self) -> ! {
        match self.handle.take() {
            Some(handle) => match handle.join() {
                Err(panic) => std::panic::resume_unwind(panic),
                Ok(()) => unreachable!("lane exited without panicking while in use"),
            },
            None => panic!("shard lane thread missing"),
        }
    }
}

impl<A> Drop for LaneCore<A> {
    /// Partial-construction safety net (e.g. `with_placement` failing on a
    /// later lane's connect): quiesce without re-raising — the in-use
    /// paths propagate panics themselves, after which this is a no-op.
    fn drop(&mut self) {
        self.shutdown_quiet();
    }
}

/// The in-process [`ShardLane`]: a resident absorb thread running the
/// slice sink directly. Spawned once, parked between rounds.
pub struct ThreadLane<A> {
    core: LaneCore<A>,
}

impl<A: Aggregator + Send + 'static> ThreadLane<A> {
    /// Spawn one resident lane thread: it loops over round packages from
    /// the control channel, absorbing each round's sub-updates and handing
    /// the sink back, until the control channel is dropped (shutdown).
    pub fn spawn(range: Range<usize>, sink: A) -> Self {
        let pool = Arc::new(ScratchPool::new());
        let (ctrl_tx, ctrl_rx) = mpsc::channel::<LaneRound<A>>();
        let (ret_tx, ret_rx) = mpsc::channel::<LaneReturn<A>>();
        let lane_pool = Arc::clone(&pool);
        let handle = std::thread::spawn(move || {
            while let Ok(LaneRound {
                expected,
                mut sink,
                jobs,
            }) = ctrl_rx.recv()
            {
                sink.begin_round(expected);
                let mut absorb_secs = 0.0;
                let mut finished = false;
                while let Ok(msg) = jobs.recv() {
                    match msg {
                        LaneMsg::Absorb { slot, update } => {
                            let t = Stopwatch::new();
                            sink.absorb(slot, update);
                            while let Some(buf) = sink.reclaim_buffer() {
                                lane_pool.put(buf);
                            }
                            absorb_secs += t.elapsed_secs();
                        }
                        LaneMsg::DecodeAbsorb {
                            slot,
                            range,
                            mut base,
                            decoder,
                        } => {
                            // This shard's slice of the record's Eq. 5
                            // sweep runs here, on the lane thread, in
                            // parallel with the other shards' slices.
                            let t = Stopwatch::new();
                            decoder.decode_range(range, &mut base);
                            sink.absorb(slot, Update::Mask(base));
                            while let Some(buf) = sink.reclaim_buffer() {
                                lane_pool.put(buf);
                            }
                            absorb_secs += t.elapsed_secs();
                        }
                        LaneMsg::Finish { partial } => {
                            if partial {
                                sink.finish_round_partial();
                            } else {
                                sink.finish_round();
                            }
                            finished = true;
                            break;
                        }
                    }
                }
                // Every round sender dropped without `Finish` means the
                // round was aborted: hand the (mid-round) sink back so the
                // next `begin_round` can supersede its state, exactly like
                // an aborted serial round — then park for the next round.
                if ret_tx
                    .send(LaneReturn {
                        sink,
                        absorb_secs,
                        finished,
                    })
                    .is_err()
                {
                    return; // aggregator gone mid-teardown
                }
            }
        });
        Self {
            core: LaneCore {
                range,
                sink: Some(sink),
                pool,
                absorb_secs: 0.0,
                ctrl: Some(ctrl_tx),
                ret: ret_rx,
                handle: Some(handle),
            },
        }
    }
}

impl<A: Send> ShardLane<A> for ThreadLane<A> {
    fn range(&self) -> Range<usize> {
        self.core.range.clone()
    }

    fn pool(&self) -> &Arc<ScratchPool> {
        &self.core.pool
    }

    fn begin_round(&mut self, expected: usize) -> SyncSender<LaneMsg> {
        self.core.begin_round(expected)
    }

    fn collect_round(&mut self) -> bool {
        self.core.collect_round()
    }

    fn collect_round_quiet(&mut self) {
        self.core.collect_round_quiet()
    }

    fn absorb_secs(&self) -> f64 {
        self.core.absorb_secs
    }

    fn fault(&self) -> Option<String> {
        None
    }

    fn sink(&self) -> Option<&A> {
        self.core.sink.as_ref()
    }

    fn take_sink(&mut self) -> A {
        self.core.take_sink()
    }

    fn shutdown(&mut self) {
        self.core.shutdown()
    }

    fn shutdown_quiet(&mut self) {
        self.core.shutdown_quiet()
    }
}

// ---------------------------------------------------------------------------
// Remote lanes: the absorb arithmetic runs in a shard-worker process.
// ---------------------------------------------------------------------------

/// A [`ShardLane`] whose slice sink lives in a `deltamask shard-worker`
/// process reached over the DMW1 wire (TCP or UDS). The coordinator side
/// is a resident I/O thread with the exact round lifecycle of a
/// [`ThreadLane`] — same control/return channels, same bounded job queue,
/// so [`ShardRouter`] and the drains are oblivious — that relays jobs as
/// `ShardSplit` frames and pulls the worker's slice state back into a
/// parked **mirror** on every finish *and* every abort. Socket errors trip
/// the lane's sticky fault flag instead of panicking; the next
/// `begin_round` reconnects and re-seeds the worker from the mirror.
pub struct RemoteShardLane<A> {
    core: LaneCore<A>,
    fault: Arc<Mutex<Option<String>>>,
}

impl<A: WireSlice + Send + 'static> RemoteShardLane<A> {
    /// Connect to the worker at `spec` (retrying up to 30 s — the worker
    /// may still be binding), seed it with `sink`'s encoded state over the
    /// shard hello, and spawn the resident I/O thread. Fails fast if the
    /// worker rejects the hello (config-fingerprint or bounds mismatch).
    pub fn connect(
        shard: u32,
        range: Range<usize>,
        sink: A,
        spec: SocketAddrSpec,
        fingerprint: ConfigFingerprint,
        cfg: SocketConfig,
    ) -> Result<Self> {
        let link = ShardLink::connect(
            &spec,
            cfg,
            shard,
            fingerprint,
            range.clone(),
            &sink.encode_slice(),
            CONNECT_TIMEOUT,
        )?;
        let pool = Arc::new(ScratchPool::new());
        let fault = Arc::new(Mutex::new(None));
        let (ctrl_tx, ctrl_rx) = mpsc::channel::<LaneRound<A>>();
        let (ret_tx, ret_rx) = mpsc::channel::<LaneReturn<A>>();
        let io = RemoteIo {
            ctrl: ctrl_rx,
            ret: ret_tx,
            link: Some(link),
            spec,
            cfg,
            shard,
            fingerprint,
            range: range.clone(),
            pool: Arc::clone(&pool),
            fault: Arc::clone(&fault),
            seq: 0,
        };
        let handle = std::thread::spawn(move || io.run());
        Ok(Self {
            core: LaneCore {
                range,
                sink: Some(sink),
                pool,
                absorb_secs: 0.0,
                ctrl: Some(ctrl_tx),
                ret: ret_rx,
                handle: Some(handle),
            },
            fault,
        })
    }
}

impl<A: Send> ShardLane<A> for RemoteShardLane<A> {
    fn range(&self) -> Range<usize> {
        self.core.range.clone()
    }

    fn pool(&self) -> &Arc<ScratchPool> {
        &self.core.pool
    }

    fn begin_round(&mut self, expected: usize) -> SyncSender<LaneMsg> {
        self.core.begin_round(expected)
    }

    fn collect_round(&mut self) -> bool {
        self.core.collect_round()
    }

    fn collect_round_quiet(&mut self) {
        self.core.collect_round_quiet()
    }

    fn absorb_secs(&self) -> f64 {
        self.core.absorb_secs
    }

    fn fault(&self) -> Option<String> {
        self.fault.lock().unwrap().clone()
    }

    fn sink(&self) -> Option<&A> {
        self.core.sink.as_ref()
    }

    fn take_sink(&mut self) -> A {
        self.core.take_sink()
    }

    fn shutdown(&mut self) {
        self.core.shutdown()
    }

    fn shutdown_quiet(&mut self) {
        self.core.shutdown_quiet()
    }
}

/// The remote lane's resident I/O loop. Owns the [`ShardLink`] (or `None`
/// after a fault) and the coordinator-side mirror for the round's
/// duration. Never panics on socket trouble: errors set the sticky fault
/// flag, the link is dropped, and the loop keeps draining jobs so routed
/// buffers keep flowing back into the lane pool (routing must never block
/// on a dead lane).
struct RemoteIo<A> {
    ctrl: Receiver<LaneRound<A>>,
    ret: Sender<LaneReturn<A>>,
    link: Option<ShardLink>,
    spec: SocketAddrSpec,
    cfg: SocketConfig,
    shard: u32,
    fingerprint: ConfigFingerprint,
    range: Range<usize>,
    pool: Arc<ScratchPool>,
    fault: Arc<Mutex<Option<String>>>,
    /// Strictly monotone round sequence; the worker rejects replays.
    seq: u64,
}

impl<A: WireSlice + Send> RemoteIo<A> {
    /// First error wins — it is the root cause; follow-on errors from the
    /// already-dead socket would only bury it.
    fn set_fault(&self, err: anyhow::Error) {
        let mut slot = self.fault.lock().unwrap();
        if slot.is_none() {
            *slot = Some(format!("{err:#}"));
        }
    }

    /// Decode a slice-return from the worker, rejecting a wrong-sized
    /// slice before it can replace the mirror.
    fn adopt(&self, state: &[u8]) -> Result<A> {
        let sink = A::decode_slice(state)?;
        if sink.slice_dim() != self.range.len() {
            bail!(
                "shard worker returned a {}-dim slice for range {:?}",
                sink.slice_dim(),
                self.range
            );
        }
        Ok(sink)
    }

    /// Ship one sub-update if the link is alive; a send error trips the
    /// fault and drops the link. `family`: 0 = mask, 1 = score-delta.
    fn ship(&mut self, slot: usize, family: u8, data: &[f32]) {
        if let Some(mut link) = self.link.take() {
            match link.split(slot, family, data) {
                Ok(()) => self.link = Some(link),
                Err(e) => self.set_fault(e),
            }
        }
    }

    fn run(mut self) {
        while let Ok(LaneRound {
            expected,
            mut sink,
            jobs,
        }) = self.ctrl.recv()
        {
            // Reconnect-on-begin: a faulted lane gets one bounded attempt
            // to re-seed a worker from the parked mirror before the round
            // opens — this is what makes the pipeline reusable on the
            // round after a worker death.
            if self.link.is_none() {
                match ShardLink::connect(
                    &self.spec,
                    self.cfg,
                    self.shard,
                    self.fingerprint,
                    self.range.clone(),
                    &sink.encode_slice(),
                    RECONNECT_TIMEOUT,
                ) {
                    Ok(link) => {
                        self.link = Some(link);
                        *self.fault.lock().unwrap() = None;
                    }
                    Err(e) => self.set_fault(e),
                }
            }
            if let Some(mut link) = self.link.take() {
                self.seq += 1;
                match link.begin(self.seq, expected) {
                    Ok(()) => self.link = Some(link),
                    Err(e) => self.set_fault(e),
                }
            }
            let mut absorb_secs = 0.0;
            let mut finished = false;
            while let Ok(msg) = jobs.recv() {
                match msg {
                    LaneMsg::Absorb { slot, update } => {
                        match &update {
                            Update::Mask(v) => self.ship(slot, 0, v),
                            Update::ScoreDelta(v) => self.ship(slot, 1, v),
                        }
                        self.pool.put(update.into_vec());
                    }
                    LaneMsg::DecodeAbsorb {
                        slot,
                        range,
                        mut base,
                        decoder,
                    } => {
                        // The parsed filter cannot cross the process
                        // boundary; this shard's slice of the Eq. 5 sweep
                        // runs here and the decoded sub-mask ships as a
                        // plain mask-family split — same arithmetic, same
                        // order, so trajectories stay bitwise identical.
                        if self.link.is_some() {
                            decoder.decode_range(range, &mut base);
                            self.ship(slot, 0, &base);
                        }
                        self.pool.put(base);
                    }
                    LaneMsg::Finish { partial } => {
                        if let Some(mut link) = self.link.take() {
                            let adopted = link
                                .finish(partial)
                                .and_then(|(secs, state)| Ok((secs, self.adopt(&state)?)));
                            match adopted {
                                Ok((secs, fresh)) => {
                                    // The worker's post-finish slice is
                                    // exactly what a local lane would have
                                    // parked; it becomes the new mirror.
                                    sink = fresh;
                                    absorb_secs = secs;
                                    finished = true;
                                    self.link = Some(link);
                                }
                                Err(e) => self.set_fault(e),
                            }
                        }
                        break;
                    }
                }
            }
            if !finished {
                // Aborted round (every job sender dropped without Finish),
                // or the finish exchange failed. If the link is still up,
                // pull the worker's *unfinished* post-absorb state back so
                // the mirror parks exactly what a local lane would park.
                if let Some(mut link) = self.link.take() {
                    let adopted = link
                        .abort()
                        .and_then(|(secs, state)| Ok((secs, self.adopt(&state)?)));
                    match adopted {
                        Ok((secs, fresh)) => {
                            sink = fresh;
                            absorb_secs = secs;
                            self.link = Some(link);
                        }
                        Err(e) => self.set_fault(e),
                    }
                }
            }
            if self
                .ret
                .send(LaneReturn {
                    sink,
                    absorb_secs,
                    finished,
                })
                .is_err()
            {
                return; // aggregator gone mid-teardown
            }
        }
        // Clean shutdown: tell a non-lingering worker the experiment is
        // over (best-effort — the worker also exits on EOF).
        if let Some(mut link) = self.link.take() {
            link.send_shutdown();
        }
    }
}

// ---------------------------------------------------------------------------
// Placement: which host each lane runs on.
// ---------------------------------------------------------------------------

/// Where one absorb lane runs.
#[derive(Clone, Debug)]
pub enum LaneSite {
    /// An in-process [`ThreadLane`].
    Local,
    /// A [`RemoteShardLane`] talking to the `deltamask shard-worker`
    /// listening at this address.
    Remote(SocketAddrSpec),
}

impl std::fmt::Display for LaneSite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Local => write!(f, "local"),
            Self::Remote(spec) => write!(f, "{spec}"),
        }
    }
}

/// Per-shard lane placement, parsed from the `--shard-place` /
/// `DELTAMASK_SHARD_PLACE` knob: a comma-separated list of `local`,
/// `uds:<path>` or `tcp:<host:port>` sites, one per shard in order. Empty
/// (the default) means every lane is local; a non-empty list must name
/// exactly one site per shard.
#[derive(Clone, Debug, Default)]
pub struct ShardPlacement {
    sites: Vec<LaneSite>,
}

impl ShardPlacement {
    /// Parse `"local,uds:/run/dm-shard1.sock,tcp:10.0.0.2:7000"`-style
    /// specs. Whitespace around entries is ignored; an empty spec parses
    /// to the all-local default.
    pub fn parse(spec: &str) -> Result<Self> {
        if spec.trim().is_empty() {
            return Ok(Self::default());
        }
        let mut sites = Vec::new();
        for entry in spec.split(',') {
            let entry = entry.trim();
            sites.push(if entry == "local" {
                LaneSite::Local
            } else if let Some(path) = entry.strip_prefix("uds:") {
                if path.is_empty() {
                    bail!("shard placement `uds:` needs a socket path");
                }
                LaneSite::Remote(SocketAddrSpec::Uds(PathBuf::from(path)))
            } else if let Some(addr) = entry.strip_prefix("tcp:") {
                if addr.is_empty() {
                    bail!("shard placement `tcp:` needs a host:port");
                }
                LaneSite::Remote(SocketAddrSpec::Tcp(addr.to_string()))
            } else {
                bail!(
                    "unknown shard placement site `{entry}` \
                     (expected `local`, `uds:<path>` or `tcp:<host:port>`)"
                )
            });
        }
        Ok(Self { sites })
    }

    /// No sites listed — every lane is local.
    pub fn is_empty(&self) -> bool {
        self.sites.is_empty()
    }

    /// Number of sites listed (0 for the all-local default).
    pub fn len(&self) -> usize {
        self.sites.len()
    }

    /// Whether no lane is remote (an empty list is all-local too).
    pub fn is_all_local(&self) -> bool {
        self.sites.iter().all(|s| matches!(s, LaneSite::Local))
    }

    /// The site for shard `i`; out-of-range shards default to local.
    pub fn site(&self, shard: usize) -> LaneSite {
        self.sites.get(shard).cloned().unwrap_or(LaneSite::Local)
    }

    /// This placement resolved to a view's actual lane count: missing
    /// sites pad with `local`, extra sites are dropped. An ambient spec
    /// (the `DELTAMASK_SHARD_PLACE` env knob) is written once per fleet
    /// while shard counts vary per run and clamp to `d`, so the runner
    /// resolves the spec here before
    /// [`ShardedAggregator::with_placement`]'s exact-length check. An
    /// empty placement stays empty (all-local).
    pub fn resolved(&self, lanes: usize) -> Self {
        if self.sites.is_empty() {
            return Self::default();
        }
        Self {
            sites: (0..lanes).map(|i| self.site(i)).collect(),
        }
    }

    /// The listed sites, in shard order.
    pub fn sites(&self) -> &[LaneSite] {
        &self.sites
    }
}

// ---------------------------------------------------------------------------
// The per-round router (unchanged above the lane trait).
// ---------------------------------------------------------------------------

/// The shareable per-round routing table: shard ranges, pools and lane
/// queue senders. Cloned into decode workers so they hand each decoded
/// record straight to the absorb lanes without serializing on the
/// draining thread.
#[derive(Clone)]
pub struct ShardRouter {
    lanes: Arc<[RouterLane]>,
}

struct RouterLane {
    range: Range<usize>,
    pool: Arc<ScratchPool>,
    tx: SyncSender<LaneMsg>,
}

impl ShardRouter {
    /// Split `update` at the shard boundaries and enqueue each sub-range
    /// on its shard's absorb lane (leasing the sub-buffer from that
    /// shard's pool). Blocks when a lane's bounded queue is full — that
    /// backpressure is what keeps decode from racing ahead of absorb.
    ///
    /// The caller keeps ownership of the full reconstruction buffer and
    /// should recycle it (`Update::into_vec` → the drain's `ScratchPool`)
    /// once this returns.
    pub fn route(&self, slot: usize, update: &Update) {
        for lane in self.lanes.iter() {
            let sub = match update {
                Update::Mask(v) => Update::Mask(lane.pool.take_copy(&v[lane.range.clone()])),
                Update::ScoreDelta(v) => {
                    Update::ScoreDelta(lane.pool.take_copy(&v[lane.range.clone()]))
                }
            };
            // A send can only fail if the lane exited early, which means
            // its sink panicked (a coordinator bug); the panic surfaces
            // when the lanes are joined, so it is not swallowed here.
            let _ = lane.tx.send(LaneMsg::Absorb { slot, update: sub });
        }
    }

    /// Range-restricted fan-out: hand each lane a buffer holding its
    /// slice of the m^{g,t-1} baseline (leased from that lane's pool)
    /// plus a shared handle to the record's parsed filter; **each lane
    /// thread then runs its own shard's slice of the Eq. 5 membership
    /// sweep** before absorbing it. The full `d`-length buffer is never
    /// materialized and no single thread sweeps the whole record — one
    /// huge record's decode, not just its absorb, runs on S threads.
    /// Bitwise identical to decoding fully and calling
    /// [`ShardRouter::route`] (the [`MaskRangeDecoder`] contract: range
    /// membership — false positives included — is a per-index property).
    /// (A remote lane runs its slice of the sweep on its coordinator-side
    /// I/O thread and ships the decoded sub-mask — the parsed filter
    /// cannot cross the process boundary.)
    pub fn route_decoded_ranges(
        &self,
        slot: usize,
        mask_g: &[f32],
        decoder: Arc<dyn MaskRangeDecoder>,
    ) {
        for lane in self.lanes.iter() {
            let base = lane.pool.take_copy(&mask_g[lane.range.clone()]);
            let _ = lane.tx.send(LaneMsg::DecodeAbsorb {
                slot,
                range: lane.range.clone(),
                base,
                decoder: Arc::clone(&decoder),
            });
        }
    }

    /// Number of shard lanes this router fans out to.
    pub fn shard_count(&self) -> usize {
        self.lanes.len()
    }
}

/// The routing table for one in-flight round (the resident lanes
/// themselves live in the aggregator for its lifetime).
struct RunningRound {
    router: ShardRouter,
}

// ---------------------------------------------------------------------------
// The sharded aggregator, composed over boxed lanes.
// ---------------------------------------------------------------------------

/// Dimension-sharded streaming aggregation sink: `S` contiguous shards of
/// the parameter space, each with its own slice sink, participation
/// counters and [`ScratchPool`], absorbed on `S` resident absorb lanes
/// (spawned once, parked between rounds) — in-process threads, remote
/// `shard-worker` processes, or any mix (see
/// [`with_placement`](Self::with_placement)).
///
/// Construct it from `(range, slice sink)` pairs tiling `0..d` — for the
/// Bayesian mask server, `fl::server::MaskServer::shard_view` builds the
/// slices and `adopt_shards` stitches them back after the round. Drive it
/// either as a plain [`Aggregator`] (inline `absorb` splits each record
/// and fans it out) or through [`drain_round`](super::drain_round) /
/// [`DrainPipeline`](super::DrainPipeline) with
/// [`DrainConfig::shards`](super::DrainConfig) > 1, where the decode
/// workers route records to the lanes directly via [`ShardRouter`].
///
/// ```
/// use deltamask::compress::Update;
/// use deltamask::coordinator::Aggregator;
/// use deltamask::fl::server::MaskServer;
///
/// // Two identical servers; one aggregates the round monolithically,
/// // the other through a 3-shard view — bitwise-identical results.
/// let mut mono = MaskServer::with_theta0(8, 1.0, 0.5);
/// let mut split = mono.clone();
/// let updates = vec![
///     Update::Mask(vec![1.0, 0.0, 1.0, 1.0, 0.0, 1.0, 0.0, 0.0]),
///     Update::Mask(vec![0.0, 0.0, 1.0, 0.0, 1.0, 1.0, 1.0, 0.0]),
/// ];
/// mono.aggregate(&updates);
///
/// let mut view = split.shard_view(3);
/// view.begin_round(2);
/// for (slot, u) in updates.iter().enumerate() {
///     view.absorb(slot, u.clone());
/// }
/// view.finish_round();
/// assert_eq!(view.absorb_secs_by_shard().len(), 3);
/// split.adopt_shards(view);
///
/// assert_eq!(mono.theta_g, split.theta_g); // bitwise
/// assert_eq!(mono.s_g, split.s_g);
/// ```
pub struct ShardedAggregator<A> {
    lanes: Vec<Box<dyn ShardLane<A>>>,
    running: Option<RunningRound>,
    /// A lane fault observed on a round that could not finish: the view's
    /// slices are no longer coherent with a completed round, so every
    /// subsequent drain through it fails loudly (via
    /// [`Aggregator::lane_fault`]) instead of silently shipping a
    /// half-settled round.
    poisoned: Option<String>,
    /// Full decoded buffers spent by the inline `absorb` path (their
    /// shard sub-ranges already copied out), awaiting reclamation by the
    /// drain loop via [`Aggregator::reclaim_buffer`].
    spent: Vec<Vec<f32>>,
}

/// Panic (coordinator bug) unless the ranges tile `0..d` contiguously.
fn check_tiling<A>(shards: &[(Range<usize>, A)]) {
    assert!(!shards.is_empty(), "at least one shard required");
    let mut expect = 0;
    for (range, _) in shards {
        assert_eq!(
            range.start, expect,
            "shard ranges must tile 0..d contiguously"
        );
        assert!(range.end >= range.start, "inverted shard range");
        expect = range.end;
    }
}

impl<A: Aggregator + Send + 'static> ShardedAggregator<A> {
    /// Build a sharded sink from `(range, slice sink)` pairs, every lane
    /// in-process. The ranges must tile `0..d` contiguously in order (see
    /// [`shard_bounds`]). Spawns one resident lane thread per shard; the
    /// threads park until the first `begin_round` and are reused by every
    /// subsequent round.
    pub fn new(shards: Vec<(Range<usize>, A)>) -> Self {
        check_tiling(&shards);
        Self {
            lanes: shards
                .into_iter()
                .map(|(range, sink)| {
                    Box::new(ThreadLane::spawn(range, sink)) as Box<dyn ShardLane<A>>
                })
                .collect(),
            running: None,
            poisoned: None,
            spent: Vec::new(),
        }
    }
}

impl<A: Aggregator + WireSlice + Send + 'static> ShardedAggregator<A> {
    /// [`new`](Self::new) with per-shard lane placement: `local` shards
    /// get a [`ThreadLane`], remote shards a [`RemoteShardLane`] connected
    /// (and seeded with the slice state) before this returns, so a missing
    /// or mismatched worker fails construction instead of the first round.
    /// An empty placement places every lane locally; a non-empty one must
    /// list exactly one site per shard, and no two remote lanes may share
    /// a worker (each worker serves one lane).
    pub fn with_placement(
        shards: Vec<(Range<usize>, A)>,
        placement: &ShardPlacement,
        fingerprint: ConfigFingerprint,
        cfg: SocketConfig,
    ) -> Result<Self> {
        check_tiling(&shards);
        if !placement.is_empty() && placement.len() != shards.len() {
            bail!(
                "shard placement lists {} sites for {} shards",
                placement.len(),
                shards.len()
            );
        }
        let mut seen = Vec::new();
        for site in placement.sites() {
            if let LaneSite::Remote(spec) = site {
                let key = spec.to_string();
                if seen.contains(&key) {
                    bail!("duplicate remote shard site {key} (each remote lane needs its own shard-worker)");
                }
                seen.push(key);
            }
        }
        let mut lanes: Vec<Box<dyn ShardLane<A>>> = Vec::with_capacity(shards.len());
        for (shard, (range, sink)) in shards.into_iter().enumerate() {
            let lane: Box<dyn ShardLane<A>> = match placement.site(shard) {
                LaneSite::Local => Box::new(ThreadLane::spawn(range, sink)),
                LaneSite::Remote(spec) => Box::new(RemoteShardLane::connect(
                    shard as u32,
                    range,
                    sink,
                    spec,
                    fingerprint,
                    cfg,
                )?),
            };
            lanes.push(lane);
        }
        Ok(Self {
            lanes,
            running: None,
            poisoned: None,
            spent: Vec::new(),
        })
    }
}

impl<A> ShardedAggregator<A> {
    /// Activate the resident lanes for one round and build the router.
    fn start_round(&mut self, expected: usize) {
        let mut router_lanes = Vec::with_capacity(self.lanes.len());
        for lane in &mut self.lanes {
            let tx = lane.begin_round(expected);
            router_lanes.push(RouterLane {
                range: lane.range(),
                pool: Arc::clone(lane.pool()),
                tx,
            });
        }
        self.running = Some(RunningRound {
            router: ShardRouter {
                lanes: router_lanes.into(),
            },
        });
    }

    /// Close the in-flight round on every lane — `partial` routes to the
    /// slice sinks' `finish_round_partial` (degraded quorum rounds).
    fn finish_lanes(&mut self, partial: bool) {
        let RunningRound { router } = self
            .running
            .take()
            .expect("ShardedAggregator::finish_round called before begin_round");
        // Lane queues are FIFO and every routed sub-update was enqueued
        // before its completion was acknowledged, so `Finish` lands after
        // the round's full absorb set on every lane.
        for lane in router.lanes.iter() {
            let _ = lane.tx.send(LaneMsg::Finish { partial });
        }
        drop(router);
        let finished = self.collect_round();
        if !finished {
            // A remote lane that faulted mid-round hands its mirror back
            // unfinished; the view's slices no longer reflect a completed
            // round, so poison it — every later drain fails loudly via
            // `lane_fault` instead of stitching half a round. A lane
            // exiting unfinished *without* a fault is still a bug.
            match self.lanes.iter().find_map(|l| l.fault()) {
                Some(fault) => self.poisoned = Some(fault),
                None => panic!("a shard lane exited before Finish"),
            }
        }
    }

    /// Number of shards (== absorb lanes).
    pub fn shard_count(&self) -> usize {
        self.lanes.len()
    }

    /// Total dimensionality the shards tile.
    pub fn d(&self) -> usize {
        self.lanes.last().map(|l| l.range().end).unwrap_or(0)
    }

    /// The shard ranges, in order.
    pub fn bounds(&self) -> Vec<Range<usize>> {
        self.lanes.iter().map(|l| l.range()).collect()
    }

    /// Absorb compute seconds each lane spent in the last finished round,
    /// indexed by shard (for a remote lane: the worker's own measurement,
    /// carried home on the slice-return frame). A lopsided split flags
    /// dimension imbalance (e.g. one shard owning all the dense payload
    /// coordinates).
    pub fn absorb_secs_by_shard(&self) -> Vec<f64> {
        self.lanes.iter().map(|l| l.absorb_secs()).collect()
    }

    /// Aggregate lease counters across every lane's sub-update pool. For a
    /// view that outlives its rounds, `misses` freezing after the warm-up
    /// round is the observable cross-round zero-allocation property.
    pub fn lane_pool_stats(&self) -> PoolStats {
        self.lanes
            .iter()
            .fold(PoolStats::default(), |acc, l| acc.merged(l.pool().stats()))
    }

    /// Borrow the parked `(range, slice sink)` pairs — `None` while a
    /// round is in flight (the sinks are on their lanes). The resident
    /// drain path uses this to refresh the global broadcast state between
    /// rounds without consuming the view. (A remote lane's parked sink is
    /// its coordinator-side mirror, refreshed from the worker at every
    /// finish/abort — identical to what a local lane parks.)
    pub fn shard_slices(&self) -> Option<Vec<(Range<usize>, &A)>> {
        if self.running.is_some() {
            return None;
        }
        self.lanes
            .iter()
            .map(|l| l.sink().map(|s| (l.range(), s)))
            .collect()
    }

    /// Tear down an in-flight round without finishing it: drop the lane
    /// job queues, wait for every lane to hand its (mid-round) sink back
    /// and park. Safe to call at any time; a no-op between rounds.
    ///
    /// Callers must ensure no external [`ShardRouter`] clone outlives this
    /// call (the drain paths join their decode workers first) — a live
    /// clone would keep a lane's job queue open and stall the hand-back.
    pub fn abort_round(&mut self) {
        let Some(RunningRound { router }) = self.running.take() else {
            return;
        };
        drop(router); // all round senders gone → lanes drain, return, park
        self.collect_round();
    }

    /// Decompose into `(range, slice sink)` pairs for stitching back into
    /// the global state. Aborts any round still in flight and shuts the
    /// lanes down first (remote lanes signal their worker to exit).
    pub fn into_shards(mut self) -> Vec<(Range<usize>, A)> {
        self.abort_round();
        for lane in &mut self.lanes {
            lane.shutdown();
        }
        std::mem::take(&mut self.lanes)
            .into_iter()
            .map(|mut lane| (lane.range(), lane.take_sink()))
            .collect()
    }

    /// Collect each lane's round return, parking the sinks; propagates
    /// lane panics. Returns whether every lane saw `Finish`.
    fn collect_round(&mut self) -> bool {
        let mut all_finished = true;
        for lane in &mut self.lanes {
            all_finished &= lane.collect_round();
        }
        all_finished
    }
}

impl<A> Aggregator for ShardedAggregator<A> {
    fn begin_round(&mut self, expected: usize) {
        // A round left in flight by an aborted drain is superseded, the
        // same tolerance the single-lane sinks give repeated begins.
        self.abort_round();
        self.spent.clear();
        self.start_round(expected);
    }

    /// Inline reference path: split the record at the shard boundaries on
    /// the calling thread and fan the pieces out to the absorb lanes. The
    /// routed drain (`DrainConfig::shards > 1`) bypasses this and calls
    /// [`ShardRouter::route`] from the decode workers instead.
    fn absorb(&mut self, slot: usize, update: Update) {
        assert_eq!(update.len(), self.d(), "update dimensionality mismatch");
        let running = self
            .running
            .as_ref()
            .expect("ShardedAggregator::absorb called before begin_round");
        running.router.route(slot, &update);
        // Sub-ranges are copied out; the full buffer is spent and flows
        // back to the drain's pool via `reclaim_buffer`.
        self.spent.push(update.into_vec());
    }

    fn finish_round(&mut self) {
        self.finish_lanes(false);
    }

    fn finish_round_partial(&mut self) {
        self.finish_lanes(true);
    }

    fn reclaim_buffer(&mut self) -> Option<Vec<f32>> {
        self.spent.pop()
    }

    fn shard_router(&self) -> Option<ShardRouter> {
        self.running.as_ref().map(|r| r.router.clone())
    }

    fn abort_round(&mut self) {
        ShardedAggregator::abort_round(self);
    }

    fn lane_fault(&self) -> Option<String> {
        self.poisoned
            .clone()
            .or_else(|| self.lanes.iter().find_map(|l| l.fault()))
    }
}

impl<A> Drop for ShardedAggregator<A> {
    /// Dropping mid-round (e.g. the drain bailed on a decode error and
    /// the caller discards the view) still quiesces and joins every
    /// resident lane thread. Lane panics are swallowed here (double
    /// panics abort); the in-use paths re-raise them instead.
    fn drop(&mut self) {
        if let Some(RunningRound { router }) = self.running.take() {
            drop(router);
            for lane in &mut self.lanes {
                lane.collect_round_quiet();
            }
        }
        for lane in &mut self.lanes {
            lane.shutdown_quiet();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::transport::socket::{serve_shard_worker, Listener};
    use super::*;

    /// Per-lane spy sink recording what it absorbed. It releases every
    /// spent sub-buffer through `reclaim_buffer` (like `MaskServer` does),
    /// so the lane pools can demonstrate cross-round reuse.
    #[derive(Default)]
    struct LaneSpy {
        d: usize,
        begun: Vec<usize>,
        absorbed: Vec<(usize, Vec<f32>)>,
        spent: Vec<Vec<f32>>,
        finished: usize,
        finished_partial: usize,
    }

    impl Aggregator for LaneSpy {
        fn begin_round(&mut self, expected: usize) {
            self.begun.push(expected);
        }

        fn absorb(&mut self, slot: usize, update: Update) {
            assert_eq!(update.len(), self.d);
            let v = update.into_vec();
            self.absorbed.push((slot, v.clone()));
            self.spent.push(v);
        }

        fn finish_round(&mut self) {
            self.finished += 1;
        }

        fn finish_round_partial(&mut self) {
            self.finished += 1;
            self.finished_partial += 1;
        }

        fn reclaim_buffer(&mut self) -> Option<Vec<f32>> {
            self.spent.pop()
        }
    }

    fn spy_shards(d: usize, shards: usize) -> ShardedAggregator<LaneSpy> {
        ShardedAggregator::new(
            shard_bounds(d, shards)
                .into_iter()
                .map(|r| {
                    let spy = LaneSpy {
                        d: r.len(),
                        ..Default::default()
                    };
                    (r, spy)
                })
                .collect(),
        )
    }

    #[test]
    fn bounds_tile_the_space() {
        assert_eq!(shard_bounds(10, 4), vec![0..3, 3..6, 6..8, 8..10]);
        assert_eq!(shard_bounds(3, 3), vec![0..1, 1..2, 2..3]);
        assert_eq!(shard_bounds(5, 1), vec![0..5]);
        // Clamping: more shards than dimensions never yields empty lanes.
        assert_eq!(shard_bounds(2, 5), vec![0..1, 1..2]);
        assert_eq!(shard_bounds(0, 3), vec![0..0]);
        for (d, s) in [(1031, 8), (64, 7), (100, 100)] {
            let bounds = shard_bounds(d, s);
            assert_eq!(bounds.first().unwrap().start, 0);
            assert_eq!(bounds.last().unwrap().end, d);
            for w in bounds.windows(2) {
                assert_eq!(w[0].end, w[1].start, "d={d} s={s}");
                assert!(!w[0].is_empty());
            }
        }
    }

    #[test]
    fn inline_absorb_splits_at_shard_boundaries() {
        let d = 10;
        let mut agg = spy_shards(d, 3); // ranges 0..4, 4..7, 7..10
        agg.begin_round(2);
        let u0: Vec<f32> = (0..d).map(|i| i as f32).collect();
        agg.absorb(0, Update::Mask(u0.clone()));
        agg.absorb(1, Update::ScoreDelta(u0.iter().map(|v| -v).collect()));
        // Spent full buffers flow back through reclaim.
        assert!(agg.reclaim_buffer().is_some());
        assert!(agg.reclaim_buffer().is_some());
        assert!(agg.reclaim_buffer().is_none());
        agg.finish_round();
        let timings = agg.absorb_secs_by_shard();
        assert_eq!(timings.len(), 3);
        let shards = agg.into_shards();
        assert_eq!(shards.len(), 3);
        for (range, spy) in shards {
            assert_eq!(spy.begun, vec![2]);
            assert_eq!(spy.finished, 1);
            assert_eq!(spy.absorbed.len(), 2);
            let (slot0, sub0) = &spy.absorbed[0];
            assert_eq!(*slot0, 0);
            assert_eq!(sub0, &u0[range.clone()].to_vec(), "{range:?}");
            let (slot1, sub1) = &spy.absorbed[1];
            assert_eq!(*slot1, 1);
            assert_eq!(sub1.len(), range.len());
        }
    }

    #[test]
    fn abort_round_parks_unfinished_lanes_for_reuse() {
        let mut agg = spy_shards(6, 2);
        agg.begin_round(3);
        agg.absorb(0, Update::Mask(vec![1.0; 6]));
        agg.abort_round(); // two updates never arrive
        assert!(agg.shard_router().is_none(), "no round in flight");
        assert!(agg.shard_slices().is_some(), "sinks parked after abort");
        // Lanes were recovered mid-round, unfinished — and can be reused.
        agg.begin_round(1);
        assert!(agg.shard_slices().is_none(), "sinks on lanes mid-round");
        agg.absorb(0, Update::Mask(vec![0.0; 6]));
        agg.finish_round();
        for (_, spy) in agg.into_shards() {
            assert_eq!(spy.finished, 1, "superseding round completed");
            assert_eq!(spy.absorbed.len(), 2, "one absorb per round attempt");
        }
    }

    #[test]
    fn resident_lanes_survive_many_rounds_and_reuse_pools() {
        // The persistence property the round-resident pipeline builds on:
        // the same S lane threads (and their pools) serve every round.
        let d = 8;
        let mut agg = spy_shards(d, 2);
        for round in 0..5 {
            agg.begin_round(2);
            for slot in 0..2 {
                agg.absorb(slot, Update::Mask(vec![round as f32; d]));
                while agg.reclaim_buffer().is_some() {}
            }
            agg.finish_round();
        }
        let stats = agg.lane_pool_stats();
        // 5 rounds × 2 slots × 2 lanes = 20 sub-leases total; only the
        // first round's in-flight peak can miss, every later lease is a
        // pool hit because the lane pools persist across rounds.
        assert_eq!(stats.hits + stats.misses, 20, "{stats:?}");
        assert!(
            stats.misses <= 2 * (LANE_QUEUE_CAP as u64 + 2),
            "lane pools must be reused across rounds: {stats:?}"
        );
        for (_, spy) in agg.into_shards() {
            assert_eq!(spy.begun.len(), 5);
            assert_eq!(spy.finished, 5);
            assert_eq!(spy.absorbed.len(), 10);
        }
    }

    #[test]
    fn router_fans_out_from_foreign_threads() {
        let d = 8;
        let mut agg = spy_shards(d, 2);
        agg.begin_round(4);
        let router = agg.shard_router().expect("round in flight");
        std::thread::scope(|scope| {
            for w in 0..2 {
                let router = router.clone();
                scope.spawn(move || {
                    for slot in [w, w + 2] {
                        let v: Vec<f32> = (0..d).map(|i| (slot * 10 + i) as f32).collect();
                        router.route(slot, &Update::Mask(v));
                    }
                });
            }
        });
        drop(router);
        agg.finish_round();
        for (range, spy) in agg.into_shards() {
            assert_eq!(spy.absorbed.len(), 4);
            for (slot, sub) in &spy.absorbed {
                let expect: Vec<f32> = range.clone().map(|i| (slot * 10 + i) as f32).collect();
                assert_eq!(sub, &expect, "slot {slot} range {range:?}");
            }
        }
    }

    #[test]
    fn route_decoded_ranges_matches_full_split() {
        // Range-restricted routing (the sweep runs on each lane thread)
        // ≡ full-decode-then-split, per lane.
        struct FlipAll;
        impl MaskRangeDecoder for FlipAll {
            fn decode_range(&self, range: Range<usize>, mask: &mut [f32]) {
                // "Member" at every even index.
                for (j, m) in mask.iter_mut().enumerate() {
                    if (range.start + j) % 2 == 0 {
                        *m = 1.0 - *m;
                    }
                }
            }
        }
        let d = 9;
        let mask_g: Vec<f32> = (0..d).map(|i| (i % 3 == 0) as u32 as f32).collect();
        let mut agg = spy_shards(d, 3);
        agg.begin_round(1);
        let router = agg.shard_router().unwrap();
        router.route_decoded_ranges(0, &mask_g, Arc::new(FlipAll));
        drop(router);
        agg.finish_round();
        // Oracle: full reconstruction then split at shard boundaries.
        let mut full = mask_g.clone();
        FlipAll.decode_range(0..d, &mut full);
        for (range, spy) in agg.into_shards() {
            assert_eq!(spy.absorbed.len(), 1);
            assert_eq!(spy.absorbed[0].1, full[range.clone()].to_vec(), "{range:?}");
        }
    }

    #[test]
    fn partial_finish_reaches_every_lane() {
        let mut agg = spy_shards(6, 3);
        agg.begin_round(3);
        agg.absorb(0, Update::Mask(vec![1.0; 6]));
        agg.absorb(2, Update::Mask(vec![0.0; 6]));
        // A quorum-degraded round: slot 1 never arrives.
        agg.finish_round_partial();
        // The view stays reusable after a degraded round.
        agg.begin_round(1);
        agg.absorb(0, Update::Mask(vec![1.0; 6]));
        agg.finish_round();
        for (_, spy) in agg.into_shards() {
            assert_eq!(spy.finished, 2);
            assert_eq!(spy.finished_partial, 1);
            assert_eq!(spy.absorbed.len(), 3);
        }
    }

    #[test]
    fn drop_mid_round_joins_lanes() {
        let mut agg = spy_shards(4, 2);
        agg.begin_round(2);
        agg.absorb(0, Update::Mask(vec![1.0; 4]));
        drop(agg); // must not hang or leak a blocked lane thread
    }

    /// Minimal wire-serializable slice sink: a slot-weighted coordinate
    /// sum plus round counters. It deliberately carries **no** transient
    /// mid-round state, so whole-struct equality is meaningful across the
    /// finish *and* abort parking paths — exactly the property the remote
    /// mirror adoption must preserve.
    #[derive(Clone, Debug, Default, PartialEq)]
    struct SumSink {
        acc: Vec<f32>,
        rounds: u64,
        partials: u64,
    }

    impl SumSink {
        fn new(d: usize) -> Self {
            Self {
                acc: vec![0.0; d],
                rounds: 0,
                partials: 0,
            }
        }
    }

    impl Aggregator for SumSink {
        fn begin_round(&mut self, _expected: usize) {}

        fn absorb(&mut self, slot: usize, update: Update) {
            let (sign, v) = match &update {
                Update::Mask(v) => (1.0f32, v),
                Update::ScoreDelta(v) => (-1.0f32, v),
            };
            assert_eq!(v.len(), self.acc.len());
            let w = sign * (slot as f32 + 1.0);
            for (a, x) in self.acc.iter_mut().zip(v) {
                *a += w * x;
            }
        }

        fn finish_round(&mut self) {
            self.rounds += 1;
        }

        fn finish_round_partial(&mut self) {
            self.rounds += 1;
            self.partials += 1;
        }
    }

    impl WireSlice for SumSink {
        fn encode_slice(&self) -> Vec<u8> {
            let mut out = Vec::with_capacity(24 + 4 * self.acc.len());
            out.extend_from_slice(&(self.acc.len() as u64).to_le_bytes());
            out.extend_from_slice(&self.rounds.to_le_bytes());
            out.extend_from_slice(&self.partials.to_le_bytes());
            for x in &self.acc {
                out.extend_from_slice(&x.to_le_bytes());
            }
            out
        }

        fn decode_slice(bytes: &[u8]) -> Result<Self> {
            if bytes.len() < 24 {
                bail!("sum-sink slice truncated: {} bytes", bytes.len());
            }
            let d = u64::from_le_bytes(bytes[0..8].try_into().unwrap()) as usize;
            if d.checked_mul(4).and_then(|n| n.checked_add(24)) != Some(bytes.len()) {
                bail!("sum-sink slice length {} does not match d={d}", bytes.len());
            }
            Ok(Self {
                acc: bytes[24..]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
                rounds: u64::from_le_bytes(bytes[8..16].try_into().unwrap()),
                partials: u64::from_le_bytes(bytes[16..24].try_into().unwrap()),
            })
        }

        fn slice_dim(&self) -> usize {
            self.acc.len()
        }
    }

    fn sum_shards(d: usize, shards: usize) -> Vec<(Range<usize>, SumSink)> {
        shard_bounds(d, shards)
            .into_iter()
            .map(|r| {
                let sink = SumSink::new(r.len());
                (r, sink)
            })
            .collect()
    }

    #[test]
    fn sum_sink_slice_codec_round_trips_and_rejects_garbage() {
        let mut s = SumSink::new(5);
        s.absorb(2, Update::Mask(vec![0.5, 1.0, 0.0, 1.0, 0.25]));
        s.finish_round();
        let bytes = s.encode_slice();
        assert_eq!(SumSink::decode_slice(&bytes).unwrap(), s);
        assert!(SumSink::decode_slice(&[]).is_err());
        assert!(SumSink::decode_slice(&bytes[..bytes.len() - 1]).is_err());
        let mut long = bytes.clone();
        long.push(0);
        assert!(SumSink::decode_slice(&long).is_err());
        let mut huge_d = bytes;
        huge_d[0..8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(SumSink::decode_slice(&huge_d).is_err());
    }

    #[test]
    fn placement_specs_parse_and_validate() {
        assert!(ShardPlacement::parse("").unwrap().is_empty());
        assert!(ShardPlacement::parse("   ").unwrap().is_all_local());
        let p = ShardPlacement::parse(" local, uds:/tmp/w1.sock ,tcp:10.0.0.2:7000").unwrap();
        assert_eq!(p.len(), 3);
        assert!(!p.is_all_local());
        assert_eq!(p.site(0).to_string(), "local");
        assert_eq!(p.site(1).to_string(), "uds:///tmp/w1.sock");
        assert_eq!(p.site(2).to_string(), "tcp://10.0.0.2:7000");
        assert_eq!(p.site(9).to_string(), "local", "out of range => local");
        for bad in ["bogus", "uds:", "tcp:", "local,remote", "local,,local"] {
            assert!(ShardPlacement::parse(bad).is_err(), "{bad}");
        }

        let fp = ConfigFingerprint {
            seed: 1,
            n_clients: 2,
            rounds: 3,
            d: 8,
        };
        let cfg = SocketConfig::default();
        // Site count must match the shard count when non-empty.
        let three = ShardPlacement::parse("local,local,local").unwrap();
        assert!(ShardedAggregator::with_placement(sum_shards(8, 2), &three, fp, cfg).is_err());
        // Two remote lanes may not share one worker.
        let dup = ShardPlacement::parse("uds:/tmp/same.sock,uds:/tmp/same.sock").unwrap();
        assert!(ShardedAggregator::with_placement(sum_shards(8, 2), &dup, fp, cfg).is_err());
        // All-local placements (explicit or empty) never touch a socket.
        let all_local = ShardPlacement::parse("local,local").unwrap();
        let agg =
            ShardedAggregator::with_placement(sum_shards(8, 2), &all_local, fp, cfg).unwrap();
        assert_eq!(agg.shard_count(), 2);
        let agg =
            ShardedAggregator::with_placement(sum_shards(8, 3), &ShardPlacement::default(), fp, cfg)
                .unwrap();
        assert_eq!(agg.shard_count(), 3);
    }

    #[test]
    fn placement_resolution_pads_and_truncates_to_the_lane_count() {
        // The ambient-spec contract `fl::shard_view_for` relies on: one
        // DELTAMASK_SHARD_PLACE composes with every shard count.
        let p = ShardPlacement::parse("local,uds:/tmp/a.sock,uds:/tmp/b.sock").unwrap();
        let padded = p.resolved(5);
        assert_eq!(padded.len(), 5);
        assert_eq!(padded.site(1).to_string(), "uds:///tmp/a.sock");
        assert_eq!(padded.site(3).to_string(), "local");
        assert_eq!(padded.site(4).to_string(), "local");
        let truncated = p.resolved(2);
        assert_eq!(truncated.len(), 2);
        assert_eq!(truncated.site(1).to_string(), "uds:///tmp/a.sock");
        assert!(truncated.resolved(1).is_all_local(), "remote site dropped");
        // Empty stays empty — the all-local fast path is preserved.
        assert!(ShardPlacement::default().resolved(4).is_empty());
        assert!(ShardPlacement::parse("").unwrap().resolved(3).is_all_local());
    }

    #[test]
    fn remote_lanes_match_local_lanes_bitwise_including_aborts() {
        let d = 9;
        let fp = ConfigFingerprint {
            seed: 3,
            n_clients: 4,
            rounds: 9,
            d: d as u64,
        };
        let cfg = SocketConfig::default();
        let path = std::env::temp_dir().join(format!("dm-lane-eqv-{}.sock", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let spec = SocketAddrSpec::Uds(path.clone());
        let listener = Listener::bind(&spec).unwrap();
        let worker =
            std::thread::spawn(move || serve_shard_worker::<SumSink>(&listener, cfg, fp, false));

        let mut local = ShardedAggregator::new(sum_shards(d, 2));
        let placement =
            ShardPlacement::parse(&format!("local,uds:{}", path.display())).unwrap();
        let mut placed =
            ShardedAggregator::with_placement(sum_shards(d, 2), &placement, fp, cfg).unwrap();

        let updates: Vec<Update> = (0..3)
            .map(|k| Update::Mask((0..d).map(|i| (i + k) as f32).collect()))
            .collect();
        for agg in [&mut local, &mut placed] {
            // Round 1: a clean finish over three updates.
            agg.begin_round(3);
            for (slot, u) in updates.iter().enumerate() {
                agg.absorb(slot, u.clone());
                while agg.reclaim_buffer().is_some() {}
            }
            agg.finish_round();
            // Round 2: one absorb, then the drain aborts the round — the
            // remote mirror must adopt the worker's post-absorb state.
            agg.begin_round(4);
            agg.absorb(2, Update::ScoreDelta(vec![0.5; d]));
            while agg.reclaim_buffer().is_some() {}
            agg.abort_round();
            // Round 3: a degraded (partial) finish.
            agg.begin_round(2);
            agg.absorb(1, Update::Mask(vec![1.0; d]));
            while agg.reclaim_buffer().is_some() {}
            agg.finish_round_partial();
        }
        assert!(placed.lane_fault().is_none(), "no fault expected");
        assert_eq!(local.absorb_secs_by_shard().len(), 2);
        let local_shards = local.into_shards();
        let placed_shards = placed.into_shards();
        assert_eq!(local_shards, placed_shards, "remote lane must be bitwise");
        // into_shards sent the worker a shutdown; the non-lingering serve
        // loop returns cleanly.
        worker.join().unwrap().unwrap();
        let _ = std::fs::remove_file(&path);
    }
}
