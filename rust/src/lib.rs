//! # DeltaMask
//!
//! Reproduction of *"Federated Fine-Tuning of Foundation Models via
//! Probabilistic Masking"* (Tsouvalas, Asano, Saeed — 2023) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the federated coordinator: round scheduling,
//!   client sampling, stochastic-mask bookkeeping, the DeltaMask update
//!   codec (binary fuse filters → grayscale PNG), Bayesian aggregation,
//!   and every baseline codec the paper compares against.
//! * **L2 (`python/compile/model.py`)** — the masked-model compute graph
//!   (fwd/bwd + Adam on mask scores), AOT-lowered once to HLO text.
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels for the masked
//!   matmul hot-spot, lowered into the same HLO.
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! pre-compiled artifacts through the PJRT C API and executes them natively.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index mapping every table/figure of the paper to a bench target.

pub mod bench;
pub mod codec;
pub mod compress;
pub mod filters;
pub mod fl;
pub mod hash;
pub mod model;
pub mod native;
pub mod runtime;
pub mod util;
