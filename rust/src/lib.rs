//! # DeltaMask
//!
//! Reproduction of *"Federated Fine-Tuning of Foundation Models via
//! Probabilistic Masking"* (Tsouvalas, Asano, Saeed — 2023) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the federated system, split into two layers:
//!   the [`coordinator`] subsystem (transport-agnostic round engine:
//!   `RoundPlan`/`RoundEngine` for sampling, κ scheduling and shared-seed
//!   mask derivation; a `Transport` carrying encoded updates with wire
//!   accounting; a work-stealing `ClientPool`; and the batch-vs-streaming
//!   `PipelineMode`), and the [`fl`] experiment layer on top of it
//!   (state ownership, the streaming Bayesian [`fl::server::MaskServer`],
//!   baselines, metrics). Updates are decoded and absorbed per-arrival —
//!   the server never materializes a round's O(K·d) update set — plus the
//!   DeltaMask codec (binary fuse filters → grayscale PNG) and every
//!   baseline codec the paper compares against, under [`compress`].
//! * **L2 (`python/compile/model.py`)** — the masked-model compute graph
//!   (fwd/bwd + Adam on mask scores), AOT-lowered once to HLO text.
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels for the masked
//!   matmul hot-spot, lowered into the same HLO.
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! pre-compiled artifacts through the PJRT C API and executes them natively
//! (behind the `xla` cargo feature; without it a stub reports the missing
//! integration and the pure-rust [`native`] backend drives everything).
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index mapping every table/figure of the paper to a bench target.

pub mod bench;
pub mod codec;
pub mod compress;
pub mod coordinator;
pub mod filters;
pub mod fl;
pub mod hash;
pub mod model;
pub mod native;
pub mod runtime;
pub mod util;
