//! **Table 3 / Figure 4** — non-IID Dir(0.1) evaluation at ρ ∈ {0.2, 1.0}
//! (the paper's "challenging and realistic" split, C_p ≈ 0.2).
//!
//!     cargo bench --bench table3_noniid [-- --full]
//!
//! Shape claims: the Bayesian aggregation keeps the stochastic-mask methods
//! (FedPM, DeltaMask, DeepReduce) ahead of FedMask under partial
//! participation; DeltaMask stays within a couple points of FedPM at a
//! fraction of the bitrate. The sibling codecs (maskrn, sparse-rsn) ride
//! below the paper roster: both learn under Dir(0.1), maskrn at roughly
//! half DeltaMask's bitrate, sparse-rsn at a flat polarity-bounded cost.

use deltamask::bench::{bench_datasets, paper_methods, sibling_methods, BenchScale, Table};
use deltamask::fl::run_experiment;
use deltamask::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let scale = BenchScale::from_args(&args);
    let datasets = bench_datasets(&args);

    for rho in [0.2f64, 1.0] {
        let mut table = Table::new(
            &format!("Table 3 (non-IID Dir(0.1), rho={rho})"),
            &["method", "dataset", "acc", "avg bpp"],
        );
        let mut summary = Table::new(
            &format!("Table 3 summary (rho={rho})"),
            &["method", "avg acc", "avg bpp"],
        );
        for method in paper_methods().iter().chain(sibling_methods()) {
            let mut accs = Vec::new();
            let mut bpps = Vec::new();
            for dataset in &datasets {
                let mut cfg = scale.config_noniid(dataset, method);
                cfg.rho = rho;
                let res = run_experiment(&cfg)?;
                let acc = res.final_accuracy();
                let bpp = res.avg_bpp();
                table.row(vec![
                    method.to_string(),
                    dataset.to_string(),
                    format!("{:.4}", acc),
                    format!("{:.4}", bpp),
                ]);
                accs.push(acc);
                bpps.push(bpp);
                eprintln!("  [rho={rho}] {method}/{dataset}: acc={acc:.4} bpp={bpp:.4}");
            }
            summary.row(vec![
                method.to_string(),
                format!("{:.4}", deltamask::util::stats::mean(&accs)),
                format!("{:.4}", deltamask::util::stats::mean(&bpps)),
            ]);
        }
        table.print();
        summary.print();
        table.save(&format!("table3_noniid_rho{}", (rho * 10.0) as u32));
        summary.save(&format!("table3_noniid_summary_rho{}", (rho * 10.0) as u32));
    }
    Ok(())
}
