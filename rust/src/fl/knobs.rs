//! The declarative operator-knob table: ONE entry per knob, pairing the
//! CLI flag spelling, the `DELTAMASK_*` environment spelling and the
//! [`ExperimentConfig`] field it writes.
//!
//! Before this table the same twelve-odd knobs were plumbed three times —
//! an `args.*` call in `main.rs`'s `parse_cfg`, a `*_from_env` reader in
//! `fl/mod.rs`, and the field default — and the three spellings could
//! (and once did) drift. Now:
//!
//! * [`apply_env`] resolves every environment spelling onto a config
//!   (called by `ExperimentConfig::default()`);
//! * [`apply_cli`] resolves every flag spelling on top (called by the CLI
//!   layer) — a flag that is absent leaves the env/default value alone;
//! * the legacy `fl::*_from_env` helpers delegate to [`env_only`], so
//!   parsing rules and panic messages exist in exactly one place.
//!
//! Resolution order is therefore: hard default → env → CLI, knob by knob.
//! Malformed values fail loudly (panic with the knob's spelling in the
//! message) — a typo'd knob silently falling back to its default would
//! let a CI matrix entry pass while exercising nothing.
//!
//! The parity tests at the bottom pin every pre-existing flag and env
//! spelling to the exact field and value semantics the triplicated code
//! had, so a table edit cannot silently retire an operator surface.

use super::ExperimentConfig;
use crate::coordinator::{FaultPlan, OnDecodeError, PipelineMode, ShardPlacement, TransportKind};
use crate::util::cli::Args;

/// One operator knob: its two outward spellings and the two resolvers
/// that write it into the config.
pub struct Knob {
    /// CLI spelling, without the leading `--`.
    pub flag: &'static str,
    /// Environment spelling; `None` for CLI-only knobs.
    pub env: Option<&'static str>,
    /// One-line operator help (shared by docs and usage text).
    pub help: &'static str,
    /// Apply a set environment value (may be empty — the CI matrix sets
    /// every key for every entry, `""` meaning "not exercised here").
    apply_env: fn(&mut ExperimentConfig, var: &str, value: &str),
    /// Apply the CLI spelling; must leave the config untouched when the
    /// flag is absent.
    apply_cli: fn(&mut ExperimentConfig, &Args),
}

/// The knob table. Order is the banner/usage order.
pub const KNOBS: &[Knob] = &[
    Knob {
        flag: "method",
        env: Some("DELTAMASK_METHOD"),
        help: "update codec (deltamask, fedpm, deltamask-pco, ...) or a weight-space baseline",
        apply_env: |cfg, _var, v| {
            if !v.is_empty() {
                cfg.method = v.to_string();
            }
        },
        apply_cli: |cfg, args| {
            if let Some(v) = args.get("method") {
                cfg.method = v.to_string();
            }
        },
    },
    Knob {
        flag: "pipeline",
        env: Some("DELTAMASK_PIPELINE"),
        help: "server decode->aggregate scheduling: streaming (default) or batch",
        apply_env: |cfg, var, v| {
            if !v.is_empty() {
                cfg.tuning.pipeline = PipelineMode::parse(v)
                    .unwrap_or_else(|| panic!("{var} must be batch/streaming, got '{v}'"));
            }
        },
        apply_cli: |cfg, args| {
            let v = args.choice(
                "pipeline",
                &["batch", "streaming"],
                cfg.tuning.pipeline.as_str(),
            );
            cfg.tuning.pipeline =
                PipelineMode::parse(v).expect("choice() already validated the value");
        },
    },
    Knob {
        flag: "decode-workers",
        env: Some("DELTAMASK_DECODE_WORKERS"),
        help: "server decode threads: 1 = serial, N = scoped workers, 0 = one per core",
        apply_env: |cfg, var, v| {
            cfg.tuning.decode_workers = parse_count(var, v);
        },
        apply_cli: |cfg, args| {
            cfg.tuning.decode_workers = args.usize("decode-workers", cfg.tuning.decode_workers);
        },
    },
    Knob {
        flag: "agg-shards",
        env: Some("DELTAMASK_AGG_SHARDS"),
        help: "dimension shards for the absorb stage: 1 = single lane, 0 = one per core",
        apply_env: |cfg, var, v| {
            cfg.tuning.agg_shards = parse_count(var, v);
        },
        apply_cli: |cfg, args| {
            cfg.tuning.agg_shards = args.usize("agg-shards", cfg.tuning.agg_shards);
        },
    },
    Knob {
        flag: "shard-place",
        env: Some("DELTAMASK_SHARD_PLACE"),
        help: "per-shard lane sites: comma list of local / uds:<path> / tcp:<host:port>",
        apply_env: |cfg, var, v| {
            if !v.is_empty() {
                if let Err(e) = ShardPlacement::parse(v) {
                    panic!("{var} is not a valid shard placement: {e}");
                }
                cfg.tuning.shard_place = v.to_string();
            }
        },
        apply_cli: |cfg, args| {
            if let Some(v) = args.get("shard-place") {
                if let Err(e) = ShardPlacement::parse(v) {
                    panic!("--shard-place spec invalid: {e}");
                }
                cfg.tuning.shard_place = v.to_string();
            }
        },
    },
    Knob {
        flag: "persistent-pipeline",
        env: Some("DELTAMASK_PERSISTENT_PIPELINE"),
        help: "spawn decode workers / absorb lanes once per experiment, park between rounds",
        apply_env: |cfg, var, v| {
            cfg.tuning.persistent_pipeline = match v {
                "1" | "true" => true,
                "0" | "false" => false,
                _ => panic!("{var} must be 0/1/true/false, got '{v}'"),
            };
        },
        apply_cli: |cfg, args| {
            // A flag, not an option: presence turns it on, absence leaves
            // the env/default verdict alone (flags cannot negate).
            cfg.tuning.persistent_pipeline =
                args.flag("persistent-pipeline") || cfg.tuning.persistent_pipeline;
        },
    },
    Knob {
        flag: "quorum",
        env: Some("DELTAMASK_QUORUM"),
        help: "fraction of the planned cohort that must report, in (0, 1]; 1.0 = strict",
        apply_env: |cfg, var, v| {
            if v.is_empty() {
                return;
            }
            let q: f64 = v
                .parse()
                .unwrap_or_else(|_| panic!("{var} must be a number, got '{v}'"));
            assert!(q > 0.0 && q <= 1.0, "{var} must be in (0, 1], got '{v}'");
            cfg.tuning.quorum = q;
        },
        apply_cli: |cfg, args| {
            cfg.tuning.quorum = args.f64("quorum", cfg.tuning.quorum);
            assert!(
                cfg.tuning.quorum > 0.0 && cfg.tuning.quorum <= 1.0,
                "--quorum must be in (0, 1], got {}",
                cfg.tuning.quorum
            );
        },
    },
    Knob {
        flag: "round-deadline-ms",
        env: Some("DELTAMASK_ROUND_DEADLINE_MS"),
        help: "per-round drain deadline in ms; 0 = wait forever",
        apply_env: |cfg, var, v| {
            if v.is_empty() {
                return;
            }
            cfg.tuning.round_deadline_ms = v
                .parse()
                .unwrap_or_else(|_| panic!("{var} must be a non-negative integer, got '{v}'"));
        },
        apply_cli: |cfg, args| {
            cfg.tuning.round_deadline_ms =
                args.u64("round-deadline-ms", cfg.tuning.round_deadline_ms);
        },
    },
    Knob {
        flag: "on-decode-error",
        env: Some("DELTAMASK_ON_DECODE_ERROR"),
        help: "undecodable-record handling: abort (default) or skip against quorum",
        apply_env: |cfg, var, v| {
            if v.is_empty() {
                return;
            }
            cfg.tuning.on_decode_error = OnDecodeError::parse(v)
                .unwrap_or_else(|_| panic!("{var} must be abort/skip, got '{v}'"));
        },
        apply_cli: |cfg, args| {
            let v = args.choice(
                "on-decode-error",
                &["abort", "skip"],
                cfg.tuning.on_decode_error.as_str(),
            );
            cfg.tuning.on_decode_error =
                OnDecodeError::parse(v).expect("choice() already validated the value");
        },
    },
    Knob {
        flag: "chaos",
        env: Some("DELTAMASK_CHAOS"),
        help: "deterministic fault-injection spec, e.g. seed=7,drop=0.1,straggle=0.2",
        apply_env: |cfg, var, v| {
            if v.is_empty() {
                return;
            }
            FaultPlan::parse(v)
                .unwrap_or_else(|e| panic!("{var} is not a valid fault spec: {e}"));
            cfg.chaos = v.to_string();
        },
        apply_cli: |cfg, args| {
            if let Some(v) = args.get("chaos") {
                cfg.chaos = v.to_string();
            }
            // Validate the final spelling (CLI or env) at startup — a
            // typo'd spec must fail loudly, not silently run a different
            // scenario than asked.
            if !cfg.chaos.is_empty() {
                if let Err(e) = FaultPlan::parse(&cfg.chaos) {
                    panic!("--chaos spec invalid: {e}");
                }
            }
        },
    },
    Knob {
        flag: "transport",
        env: Some("DELTAMASK_TRANSPORT"),
        help: "uplink: channel (in-process), tcp or uds (framed sockets)",
        apply_env: |cfg, var, v| {
            if v.is_empty() {
                return;
            }
            cfg.transport = TransportKind::parse(v)
                .unwrap_or_else(|| panic!("{var} must be channel/tcp/uds, got '{v}'"));
        },
        apply_cli: |cfg, args| {
            let v = args.choice(
                "transport",
                &["channel", "tcp", "uds"],
                cfg.transport.as_str(),
            );
            cfg.transport =
                TransportKind::parse(v).expect("choice() already validated the value");
        },
    },
];

/// Apply every set environment spelling to `cfg`, in table order.
pub fn apply_env(cfg: &mut ExperimentConfig) {
    apply_env_with(cfg, |var| std::env::var(var).ok());
}

/// [`apply_env`] against an arbitrary variable source — the parity tests
/// drive the table through this without mutating process environment
/// (env mutation is unsound under the parallel test harness).
pub fn apply_env_with(cfg: &mut ExperimentConfig, lookup: impl Fn(&str) -> Option<String>) {
    for k in KNOBS {
        if let Some(var) = k.env {
            if let Some(v) = lookup(var) {
                (k.apply_env)(cfg, var, &v);
            }
        }
    }
}

/// Apply every present CLI spelling to `cfg`, in table order. Absent
/// flags leave the env/default values alone.
pub fn apply_cli(cfg: &mut ExperimentConfig, args: &Args) {
    for k in KNOBS {
        (k.apply_cli)(cfg, args);
    }
}

/// A base config with exactly ONE env spelling resolved — the legacy
/// `fl::*_from_env` helpers read their field off this, so each keeps its
/// historical "just this variable" semantics while the parsing lives in
/// the table.
pub(crate) fn env_only(var: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::base();
    if let Some(k) = KNOBS.iter().find(|k| k.env == Some(var)) {
        if let Ok(v) = std::env::var(var) {
            (k.apply_env)(&mut cfg, var, &v);
        }
    } else {
        unreachable!("no knob reads {var}");
    }
    cfg
}

/// Shared parse-or-panic policy for the integer count knobs: a set but
/// malformed value must fail loudly, even when empty (these two gate CI's
/// sharded re-runs and predate the matrix's empty-means-unset convention).
fn parse_count(var: &str, v: &str) -> usize {
    v.parse()
        .unwrap_or_else(|_| panic!("{var} must be a non-negative integer, got '{v}'"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn cli(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    fn with_env(pairs: &[(&str, &str)]) -> ExperimentConfig {
        let map: BTreeMap<String, String> = pairs
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect();
        let mut cfg = ExperimentConfig::base();
        apply_env_with(&mut cfg, |var| map.get(var).cloned());
        cfg
    }

    /// Every spelling the pre-table code exposed must still exist, under
    /// the same name, resolving to the same field.
    #[test]
    fn table_pins_every_legacy_spelling() {
        let flags: Vec<&str> = KNOBS.iter().map(|k| k.flag).collect();
        for legacy in [
            "method",
            "pipeline",
            "decode-workers",
            "agg-shards",
            "persistent-pipeline",
            "quorum",
            "round-deadline-ms",
            "on-decode-error",
            "chaos",
            "transport",
        ] {
            assert!(flags.contains(&legacy), "flag --{legacy} retired");
        }
        let envs: Vec<&str> = KNOBS.iter().filter_map(|k| k.env).collect();
        for legacy in [
            "DELTAMASK_METHOD",
            "DELTAMASK_DECODE_WORKERS",
            "DELTAMASK_AGG_SHARDS",
            "DELTAMASK_PERSISTENT_PIPELINE",
            "DELTAMASK_QUORUM",
            "DELTAMASK_ROUND_DEADLINE_MS",
            "DELTAMASK_ON_DECODE_ERROR",
            "DELTAMASK_CHAOS",
            "DELTAMASK_TRANSPORT",
        ] {
            assert!(envs.contains(&legacy), "env {legacy} retired");
        }
        // The fabric addition rides the same table.
        assert!(flags.contains(&"shard-place"));
        assert!(envs.contains(&"DELTAMASK_SHARD_PLACE"));
        // No duplicate spellings.
        let mut f = flags.clone();
        f.sort_unstable();
        f.dedup();
        assert_eq!(f.len(), KNOBS.len(), "duplicate flag spelling");
        for k in KNOBS {
            assert!(!k.help.is_empty(), "--{} has no help line", k.flag);
        }
    }

    /// Env parity: each `DELTAMASK_*` value resolves to the exact field
    /// value the pre-table `*_from_env` readers produced.
    #[test]
    fn env_spellings_parse_to_the_legacy_values() {
        let cfg = with_env(&[
            ("DELTAMASK_METHOD", "deltamask-pco"),
            ("DELTAMASK_DECODE_WORKERS", "4"),
            ("DELTAMASK_AGG_SHARDS", "3"),
            ("DELTAMASK_PERSISTENT_PIPELINE", "1"),
            ("DELTAMASK_QUORUM", "0.6"),
            ("DELTAMASK_ROUND_DEADLINE_MS", "5000"),
            ("DELTAMASK_ON_DECODE_ERROR", "skip"),
            ("DELTAMASK_CHAOS", "seed=7,drop=0.1"),
            ("DELTAMASK_TRANSPORT", "uds"),
            ("DELTAMASK_SHARD_PLACE", "local,uds:/tmp/w1.sock"),
        ]);
        assert_eq!(cfg.method, "deltamask-pco");
        assert_eq!(cfg.tuning.decode_workers, 4);
        assert_eq!(cfg.tuning.agg_shards, 3);
        assert!(cfg.tuning.persistent_pipeline);
        assert_eq!(cfg.tuning.quorum, 0.6);
        assert_eq!(cfg.tuning.round_deadline_ms, 5000);
        assert_eq!(cfg.tuning.on_decode_error, OnDecodeError::Skip);
        assert_eq!(cfg.chaos, "seed=7,drop=0.1");
        assert_eq!(cfg.transport, TransportKind::Uds);
        assert_eq!(cfg.tuning.shard_place, "local,uds:/tmp/w1.sock");
    }

    /// The CI matrix convention: every key present, `""` meaning "not
    /// exercised here" — empty values leave the defaults untouched for
    /// every knob that predates the convention's adoption.
    #[test]
    fn empty_env_values_mean_unset() {
        let cfg = with_env(&[
            ("DELTAMASK_METHOD", ""),
            ("DELTAMASK_PIPELINE", ""),
            ("DELTAMASK_QUORUM", ""),
            ("DELTAMASK_ROUND_DEADLINE_MS", ""),
            ("DELTAMASK_ON_DECODE_ERROR", ""),
            ("DELTAMASK_CHAOS", ""),
            ("DELTAMASK_TRANSPORT", ""),
            ("DELTAMASK_SHARD_PLACE", ""),
        ]);
        assert_eq!(cfg.method, "deltamask");
        assert_eq!(cfg.tuning.pipeline, PipelineMode::Streaming);
        assert_eq!(cfg.tuning.quorum, 1.0);
        assert_eq!(cfg.tuning.round_deadline_ms, 0);
        assert_eq!(cfg.tuning.on_decode_error, OnDecodeError::Abort);
        assert_eq!(cfg.chaos, "");
        assert_eq!(cfg.transport, TransportKind::Channel);
        assert_eq!(cfg.tuning.shard_place, "");
    }

    /// Set-but-malformed env values fail loudly with the historical
    /// messages (spelling + offending value), never silently default.
    #[test]
    fn malformed_env_values_panic_with_the_legacy_messages() {
        let cases: &[(&str, &str, &str)] = &[
            (
                "DELTAMASK_DECODE_WORKERS",
                "two",
                "DELTAMASK_DECODE_WORKERS must be a non-negative integer, got 'two'",
            ),
            (
                "DELTAMASK_AGG_SHARDS",
                "",
                "DELTAMASK_AGG_SHARDS must be a non-negative integer, got ''",
            ),
            (
                "DELTAMASK_PERSISTENT_PIPELINE",
                "yes",
                "DELTAMASK_PERSISTENT_PIPELINE must be 0/1/true/false, got 'yes'",
            ),
            (
                "DELTAMASK_QUORUM",
                "1.5",
                "DELTAMASK_QUORUM must be in (0, 1], got '1.5'",
            ),
            (
                "DELTAMASK_QUORUM",
                "lots",
                "DELTAMASK_QUORUM must be a number, got 'lots'",
            ),
            (
                "DELTAMASK_ROUND_DEADLINE_MS",
                "-3",
                "DELTAMASK_ROUND_DEADLINE_MS must be a non-negative integer, got '-3'",
            ),
            (
                "DELTAMASK_ON_DECODE_ERROR",
                "retry",
                "DELTAMASK_ON_DECODE_ERROR must be abort/skip, got 'retry'",
            ),
            (
                "DELTAMASK_TRANSPORT",
                "carrier-pigeon",
                "DELTAMASK_TRANSPORT must be channel/tcp/uds, got 'carrier-pigeon'",
            ),
            (
                "DELTAMASK_PIPELINE",
                "turbo",
                "DELTAMASK_PIPELINE must be batch/streaming, got 'turbo'",
            ),
        ];
        for (var, val, want) in cases {
            let got = std::panic::catch_unwind(|| with_env(&[(var, val)]))
                .expect_err("malformed value must panic");
            let msg = got
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| got.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_default();
            assert!(msg.contains(want), "{var}='{val}': got panic '{msg}'");
        }
        // Structured specs validate eagerly too.
        assert!(std::panic::catch_unwind(|| with_env(&[("DELTAMASK_CHAOS", "drop=lots")]))
            .is_err());
        assert!(std::panic::catch_unwind(|| {
            with_env(&[("DELTAMASK_SHARD_PLACE", "bogus")])
        })
        .is_err());
    }

    /// CLI parity: each flag spelling resolves to the exact field value
    /// `parse_cfg`'s hand-rolled `args.*` calls produced, and absent
    /// flags leave env-resolved values alone.
    #[test]
    fn cli_spellings_parse_to_the_legacy_values() {
        let mut cfg = ExperimentConfig::base();
        apply_cli(
            &mut cfg,
            &cli(
                "--method fedpm --pipeline batch --decode-workers 8 --agg-shards 4 \
                 --persistent-pipeline --quorum 0.8 --round-deadline-ms 250 \
                 --on-decode-error skip --chaos seed=3,dup=0.2 --transport tcp \
                 --shard-place local,local",
            ),
        );
        assert_eq!(cfg.method, "fedpm");
        assert_eq!(cfg.tuning.pipeline, PipelineMode::Batch);
        assert_eq!(cfg.tuning.decode_workers, 8);
        assert_eq!(cfg.tuning.agg_shards, 4);
        assert!(cfg.tuning.persistent_pipeline);
        assert_eq!(cfg.tuning.quorum, 0.8);
        assert_eq!(cfg.tuning.round_deadline_ms, 250);
        assert_eq!(cfg.tuning.on_decode_error, OnDecodeError::Skip);
        assert_eq!(cfg.chaos, "seed=3,dup=0.2");
        assert_eq!(cfg.transport, TransportKind::Tcp);
        assert_eq!(cfg.tuning.shard_place, "local,local");

        // Absent flags: everything stays at the env/default layer.
        let mut cfg = with_env(&[("DELTAMASK_QUORUM", "0.7"), ("DELTAMASK_TRANSPORT", "uds")]);
        apply_cli(&mut cfg, &cli(""));
        assert_eq!(cfg.tuning.quorum, 0.7);
        assert_eq!(cfg.transport, TransportKind::Uds);
        assert_eq!(cfg.tuning.decode_workers, 1);
        assert_eq!(cfg.tuning.pipeline, PipelineMode::Streaming);
        assert!(!cfg.tuning.persistent_pipeline);

        // CLI wins over env, knob by knob (the legacy resolution order).
        let mut cfg = with_env(&[
            ("DELTAMASK_DECODE_WORKERS", "2"),
            ("DELTAMASK_AGG_SHARDS", "2"),
        ]);
        apply_cli(&mut cfg, &cli("--decode-workers 6"));
        assert_eq!(cfg.tuning.decode_workers, 6);
        assert_eq!(cfg.tuning.agg_shards, 2);
    }

    #[test]
    fn malformed_cli_values_panic_with_the_legacy_messages() {
        let cases: &[(&str, &str)] = &[
            ("--decode-workers two", "--decode-workers must be an integer"),
            ("--quorum 0", "--quorum must be in (0, 1]"),
            ("--pipeline turbo", "--pipeline must be one of"),
            ("--on-decode-error retry", "--on-decode-error must be one of"),
            ("--transport pigeon", "--transport must be one of"),
            ("--chaos drop=lots", "--chaos spec invalid"),
            ("--shard-place bogus", "--shard-place spec invalid"),
        ];
        for (argv, want) in cases {
            let args = cli(argv);
            let got = std::panic::catch_unwind(|| {
                let mut cfg = ExperimentConfig::base();
                apply_cli(&mut cfg, &args);
            })
            .expect_err("malformed value must panic");
            let msg = got
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| got.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_default();
            assert!(msg.contains(want), "{argv}: got panic '{msg}'");
        }
    }

    /// The `ServerTuning` group assembles the coordinator types the
    /// runner used to build by hand.
    #[test]
    fn server_tuning_assembles_drain_config_and_policy() {
        let mut cfg = ExperimentConfig::base();
        apply_cli(
            &mut cfg,
            &cli("--pipeline batch --decode-workers 3 --agg-shards 2 --quorum 0.5 --round-deadline-ms 100 --on-decode-error skip"),
        );
        let dc = cfg.tuning.to_drain_config();
        assert_eq!(dc.mode, PipelineMode::Batch);
        assert_eq!(dc.workers, 3);
        assert_eq!(dc.shards, 2);
        assert_eq!(dc.policy.quorum, 0.5);
        assert_eq!(dc.policy.deadline_ms, 100);
        assert_eq!(dc.policy.on_decode_error, OnDecodeError::Skip);
        let p = cfg.tuning.to_drain_policy();
        assert_eq!(p.quorum, 0.5);
        assert_eq!(p.deadline_ms, 100);

        cfg.tuning.shard_place = "local,uds:/tmp/w.sock".into();
        let placement = cfg.tuning.shard_placement().unwrap();
        assert_eq!(placement.len(), 2);
        assert!(!placement.is_all_local());
        assert!(ExperimentConfig::base()
            .tuning
            .shard_placement()
            .unwrap()
            .is_all_local());
    }
}
