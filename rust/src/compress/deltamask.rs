//! **DeltaMask** — the paper's update codec (§3.2, Alg. 1 lines 9–11 and
//! 14–16).
//!
//! Encode (client k, round t):
//! 1. Δ = { i : m_i^{g,t-1} ≠ m_i^{k,t} } — mask-difference index set against
//!    the shared-seed global binary mask.
//! 2. top-κ selection (Eq. 4): keep the K = ⌈κ·|Δ|⌉ indexes with the largest
//!    KL(θ^{k,t}_i ‖ θ^{g,t-1}_i) — importance sampling of the most certain
//!    updates (O(d) quickselect, no full sort).
//! 3. Fingerprint Δ′ into a probabilistic filter (default: 4-wise binary
//!    fuse, 8-bit entries — "BFuse8").
//! 4. Pack the fingerprint array into a grayscale image and compress
//!    losslessly (PNG = filtering + DEFLATE) → `A_{k,t}`.
//!
//! Decode (server): unpack the PNG, rebuild the filter, run the membership
//! query over *all* d indexes (Eq. 5), and bit-flip m^{g,t-1} at the hits —
//! false positives (rate ≈ 2^-bpe) surface as mask noise, which Appendix B
//! bounds.

use super::{
    wire, DecodeCtx, EncodeCtx, EncodeScratch, Encoded, Family, ScratchPool, Update, UpdateCodec,
};
use crate::codec::png::{self, GrayImage};
use crate::filters::{BinaryFuse, MembershipFilter, XorFilter};
use crate::model::kl_bernoulli;
use crate::util::rng::Xoshiro256pp;
use crate::util::top_k_indices_into;
use anyhow::{bail, ensure, Result};

/// Probabilistic filter selection (§5.4 ablation, Fig. 9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FilterKind {
    BFuse8,
    BFuse16,
    BFuse32,
    /// 3-wise binary fuse (slightly larger, same API).
    BFuse8Arity3,
    Xor8,
    Xor16,
    Xor32,
}

impl FilterKind {
    pub fn label(&self) -> &'static str {
        match self {
            FilterKind::BFuse8 => "bfuse8",
            FilterKind::BFuse16 => "bfuse16",
            FilterKind::BFuse32 => "bfuse32",
            FilterKind::BFuse8Arity3 => "bfuse8-3w",
            FilterKind::Xor8 => "xor8",
            FilterKind::Xor16 => "xor16",
            FilterKind::Xor32 => "xor32",
        }
    }

    fn tag(&self) -> u8 {
        match self {
            FilterKind::BFuse8 => 0,
            FilterKind::BFuse16 => 1,
            FilterKind::BFuse32 => 2,
            FilterKind::BFuse8Arity3 => 3,
            FilterKind::Xor8 => 4,
            FilterKind::Xor16 => 5,
            FilterKind::Xor32 => 6,
        }
    }

    fn from_tag(tag: u8) -> Result<Self> {
        Ok(match tag {
            0 => FilterKind::BFuse8,
            1 => FilterKind::BFuse16,
            2 => FilterKind::BFuse32,
            3 => FilterKind::BFuse8Arity3,
            4 => FilterKind::Xor8,
            5 => FilterKind::Xor16,
            6 => FilterKind::Xor32,
            _ => bail!("unknown filter tag {tag}"),
        })
    }
}

/// Update-ranking mechanism (Fig. 8 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ranking {
    /// Relative entropy between server and client probabilities (Eq. 4).
    Kl,
    /// Naive uniform subsampling baseline.
    Random,
}

/// Versioned payload stage for the fingerprint array. The tag travels in
/// byte 1 of every record, which the v1 wire format wrote as a boolean PNG
/// flag (`0` = raw, `1` = PNG) — so `Raw` and `Png` records are
/// byte-identical to v1, and `PngFast` (a standard PNG whose IDAT was
/// produced by the fast DEFLATE match finder) still decodes on v1 servers,
/// which treated any nonzero byte as "PNG". Tags ≥ 3 are reserved for
/// future payload formats and are rejected.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum PayloadBackend {
    /// Fingerprint bytes as-is (the ablation that isolates the filter).
    Raw,
    /// Grayscale-PNG + baseline DEFLATE (§3.2) — the v1 default.
    #[default]
    Png,
    /// Grayscale-PNG + fast match finder: same decoder, cheaper encode.
    PngFast,
}

impl PayloadBackend {
    fn tag(self) -> u8 {
        match self {
            PayloadBackend::Raw => 0,
            PayloadBackend::Png => 1,
            PayloadBackend::PngFast => 2,
        }
    }

    fn from_tag(tag: u8) -> Result<Self> {
        Ok(match tag {
            0 => PayloadBackend::Raw,
            1 => PayloadBackend::Png,
            2 => PayloadBackend::PngFast,
            _ => bail!("unknown payload backend tag {tag}"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct DeltaMaskCodec {
    pub filter: FilterKind,
    pub ranking: Ranking,
    /// Payload stage for the fingerprint array (§3.2 uses the PNG path).
    pub payload: PayloadBackend,
}

impl Default for DeltaMaskCodec {
    fn default() -> Self {
        Self {
            filter: FilterKind::BFuse8,
            ranking: Ranking::Kl,
            payload: PayloadBackend::Png,
        }
    }
}

impl DeltaMaskCodec {
    pub fn with_filter(filter: FilterKind) -> Self {
        Self {
            filter,
            ..Self::default()
        }
    }

    pub fn with_ranking(ranking: Ranking) -> Self {
        Self {
            ranking,
            ..Self::default()
        }
    }

    /// Steps 1–2: the ranked, truncated difference set Δ′ (Eq. 4).
    /// Allocating wrapper over [`Self::select_updates_into`] for callers
    /// without persistent scratch (tests, one-shot tools).
    pub fn select_updates(&self, ctx: &EncodeCtx) -> Vec<u64> {
        let mut scratch = EncodeScratch::default();
        self.select_updates_into(ctx, &mut scratch);
        std::mem::take(&mut scratch.keys)
    }

    /// Fused single-pass Δ′ selection: the Δ scan and the KL scoring run in
    /// one streaming sweep (the seed made two passes over `d`), writing into
    /// reusable scratch so steady-state encodes allocate nothing. The key
    /// set lands in `scratch.keys`, byte-for-byte identical to the two-pass
    /// path (same scan order, same `top_k_indices` input).
    pub fn select_updates_into(&self, ctx: &EncodeCtx, scratch: &mut EncodeScratch) {
        scratch.delta.clear();
        scratch.scores.clear();
        scratch.keys.clear();
        // Score inline only when truncation can actually happen: κ ≥ 1 ⇒
        // k == |Δ| ⇒ the scores would never be read.
        let score_kl = self.ranking == Ranking::Kl && ctx.kappa < 1.0;
        for i in 0..ctx.d {
            if ctx.mask_g[i] != ctx.mask_k[i] {
                scratch.delta.push(i as u32);
                if score_kl {
                    scratch.scores.push(kl_bernoulli(ctx.theta_k[i], ctx.theta_g[i]));
                }
            }
        }
        let k = ((ctx.kappa * scratch.delta.len() as f64).ceil() as usize)
            .min(scratch.delta.len());
        if k == scratch.delta.len() {
            scratch.keys.extend(scratch.delta.iter().map(|&i| i as u64));
            return;
        }
        match self.ranking {
            Ranking::Kl => {
                // The quickselect index array persists in the scratch, so
                // cross-round encodes reuse it (same selection output as
                // the allocating `top_k_indices`, element for element).
                top_k_indices_into(&scratch.scores, k, &mut scratch.rank);
                let delta = &scratch.delta;
                scratch
                    .keys
                    .extend(scratch.rank.iter().map(|&pos| delta[pos as usize] as u64));
            }
            Ranking::Random => {
                let mut rng = Xoshiro256pp::new(ctx.seed ^ 0xdead_beef);
                rng.shuffle(&mut scratch.delta);
                scratch
                    .keys
                    .extend(scratch.delta[..k].iter().map(|&i| i as u64));
            }
        }
    }
}

enum BuiltFilter {
    B8(BinaryFuse<u8, 4>),
    B16(BinaryFuse<u16, 4>),
    B32(BinaryFuse<u32, 4>),
    B8A3(BinaryFuse<u8, 3>),
    X8(XorFilter<u8>),
    X16(XorFilter<u16>),
    X32(XorFilter<u32>),
}

impl BuiltFilter {
    fn build(kind: FilterKind, keys: &[u64]) -> Result<Self> {
        let err = || anyhow::anyhow!("filter construction failed");
        Ok(match kind {
            FilterKind::BFuse8 => BuiltFilter::B8(BinaryFuse::build(keys).ok_or_else(err)?),
            FilterKind::BFuse16 => BuiltFilter::B16(BinaryFuse::build(keys).ok_or_else(err)?),
            FilterKind::BFuse32 => BuiltFilter::B32(BinaryFuse::build(keys).ok_or_else(err)?),
            FilterKind::BFuse8Arity3 => {
                BuiltFilter::B8A3(BinaryFuse::build(keys).ok_or_else(err)?)
            }
            FilterKind::Xor8 => BuiltFilter::X8(XorFilter::build(keys).ok_or_else(err)?),
            FilterKind::Xor16 => BuiltFilter::X16(XorFilter::build(keys).ok_or_else(err)?),
            FilterKind::Xor32 => BuiltFilter::X32(XorFilter::build(keys).ok_or_else(err)?),
        })
    }

    /// (seed, layout_a, layout_b, payload, num_keys) — layout params differ
    /// between bfuse (segment_length, segment_count_length) and xor
    /// (block_length, unused).
    fn parts(&self) -> (u64, u32, u64, Vec<u8>, usize) {
        match self {
            BuiltFilter::B8(f) => (f.seed(), f.segment_length_pub(), f.segment_count_length_pub(), f.payload(), f.num_keys()),
            BuiltFilter::B16(f) => (f.seed(), f.segment_length_pub(), f.segment_count_length_pub(), f.payload(), f.num_keys()),
            BuiltFilter::B32(f) => (f.seed(), f.segment_length_pub(), f.segment_count_length_pub(), f.payload(), f.num_keys()),
            BuiltFilter::B8A3(f) => (f.seed(), f.segment_length_pub(), f.segment_count_length_pub(), f.payload(), f.num_keys()),
            BuiltFilter::X8(f) => (f.seed(), f.block_length(), 0, f.payload(), f.num_keys()),
            BuiltFilter::X16(f) => (f.seed(), f.block_length(), 0, f.payload(), f.num_keys()),
            BuiltFilter::X32(f) => (f.seed(), f.block_length(), 0, f.payload(), f.num_keys()),
        }
    }

    fn restore(
        kind: FilterKind,
        seed: u64,
        layout_a: u32,
        layout_b: u64,
        payload: &[u8],
        num_keys: usize,
    ) -> Self {
        match kind {
            FilterKind::BFuse8 => {
                BuiltFilter::B8(BinaryFuse::from_parts(seed, layout_a, layout_b, payload, num_keys))
            }
            FilterKind::BFuse16 => {
                BuiltFilter::B16(BinaryFuse::from_parts(seed, layout_a, layout_b, payload, num_keys))
            }
            FilterKind::BFuse32 => {
                BuiltFilter::B32(BinaryFuse::from_parts(seed, layout_a, layout_b, payload, num_keys))
            }
            FilterKind::BFuse8Arity3 => {
                BuiltFilter::B8A3(BinaryFuse::from_parts(seed, layout_a, layout_b, payload, num_keys))
            }
            FilterKind::Xor8 => BuiltFilter::X8(XorFilter::from_parts(seed, layout_a, payload, num_keys)),
            FilterKind::Xor16 => BuiltFilter::X16(XorFilter::from_parts(seed, layout_a, payload, num_keys)),
            FilterKind::Xor32 => BuiltFilter::X32(XorFilter::from_parts(seed, layout_a, payload, num_keys)),
        }
    }

    /// Scalar per-key membership — retained as the parity oracle for the
    /// batched kernel (this enum dispatch per key *was* the decode hot
    /// path; production decoding goes through `decode_mask_into`).
    #[cfg(test)]
    fn contains(&self, key: u64) -> bool {
        match self {
            BuiltFilter::B8(f) => f.contains(key),
            BuiltFilter::B16(f) => f.contains(key),
            BuiltFilter::B32(f) => f.contains(key),
            BuiltFilter::B8A3(f) => f.contains(key),
            BuiltFilter::X8(f) => f.contains(key),
            BuiltFilter::X16(f) => f.contains(key),
            BuiltFilter::X32(f) => f.contains(key),
        }
    }

    /// Batched Eq. 5 kernel: one dispatch per round into the monomorphic
    /// per-filter block kernels, instead of one enum match per key.
    fn decode_mask_into(&self, mask: &mut [f32]) {
        self.decode_mask_into_range(mask, 0);
    }

    /// Range-restricted Eq. 5 kernel: sweep member indexes `start ..
    /// start + mask.len()` only. One dispatch per (record, range) — the
    /// dimension-sharded drain calls this once per shard lane.
    fn decode_mask_into_range(&self, mask: &mut [f32], start: usize) {
        match self {
            BuiltFilter::B8(f) => f.decode_mask_into_range(mask, start),
            BuiltFilter::B16(f) => f.decode_mask_into_range(mask, start),
            BuiltFilter::B32(f) => f.decode_mask_into_range(mask, start),
            BuiltFilter::B8A3(f) => f.decode_mask_into_range(mask, start),
            BuiltFilter::X8(f) => f.decode_mask_into_range(mask, start),
            BuiltFilter::X16(f) => f.decode_mask_into_range(mask, start),
            BuiltFilter::X32(f) => f.decode_mask_into_range(mask, start),
        }
    }
}

/// A restored filter is a [`MaskRangeDecoder`](super::MaskRangeDecoder):
/// membership — false positives included — is a per-index property, so a
/// range sweep is exactly the full sweep restricted to that range.
impl super::MaskRangeDecoder for BuiltFilter {
    fn decode_range(&self, range: std::ops::Range<usize>, mask: &mut [f32]) {
        debug_assert_eq!(mask.len(), range.len());
        self.decode_mask_into_range(mask, range.start);
    }
}

/// Fingerprint width in bytes for each filter kind.
fn fingerprint_width(kind: FilterKind) -> usize {
    match kind {
        FilterKind::BFuse8 | FilterKind::BFuse8Arity3 | FilterKind::Xor8 => 1,
        FilterKind::BFuse16 | FilterKind::Xor16 => 2,
        FilterKind::BFuse32 | FilterKind::Xor32 => 4,
    }
}

/// Validate transmitted filter layout parameters against the payload before
/// rebuilding the filter, so a malformed or corrupted record yields `Err`
/// instead of an out-of-bounds panic inside the membership kernels.
///
/// The checks mirror the construction invariants exactly:
/// * binary fuse — `segment_length` is a nonzero power of two,
///   `segment_count_length` is a whole number of segments, and the cell
///   count equals `segment_count_length + (ARITY−1)·segment_length`;
/// * xor — the cell count equals `3·block_length` with a nonzero block.
///
/// Together with those equalities, every position the probe kernels can
/// form (fast-range base + per-segment offset, xor-perturbed within a
/// power-of-two segment) stays strictly inside the fingerprint array.
fn validate_filter_parts(
    kind: FilterKind,
    layout_a: u32,
    layout_b: u64,
    payload_len: usize,
) -> Result<()> {
    let width = fingerprint_width(kind);
    ensure!(
        payload_len % width == 0,
        "payload not a whole number of {width}-byte fingerprints"
    );
    let cells = (payload_len / width) as u64;
    match kind {
        FilterKind::BFuse8 | FilterKind::BFuse16 | FilterKind::BFuse32
        | FilterKind::BFuse8Arity3 => {
            let arity = if kind == FilterKind::BFuse8Arity3 { 3u64 } else { 4 };
            let seg = layout_a as u64;
            ensure!(seg >= 1 && seg.is_power_of_two(), "bad segment length {seg}");
            // At least one whole segment: with layout_b == 0 the fast-range
            // base is pinned to 0 but the last hash window still reaches
            // (ARITY−1)·seg == cells, one past the array.
            ensure!(
                layout_b >= seg && layout_b % seg == 0,
                "segment count length not a positive whole number of segments"
            );
            let expect = layout_b
                .checked_add((arity - 1) * seg)
                .ok_or_else(|| anyhow::anyhow!("filter layout overflow"))?;
            ensure!(
                cells == expect,
                "fingerprint count {cells} inconsistent with layout {expect}"
            );
        }
        FilterKind::Xor8 | FilterKind::Xor16 | FilterKind::Xor32 => {
            let bl = layout_a as u64;
            ensure!(bl >= 1, "bad xor block length");
            ensure!(
                cells == 3 * bl,
                "fingerprint count {cells} inconsistent with 3×block {bl}"
            );
        }
    }
    Ok(())
}

impl UpdateCodec for DeltaMaskCodec {
    fn name(&self) -> &'static str {
        "deltamask"
    }

    fn family(&self) -> Family {
        Family::Mask
    }

    fn encode(&self, ctx: &EncodeCtx) -> Result<Encoded> {
        self.encode_with(ctx, &mut EncodeScratch::default())
    }

    /// Encode reusing the caller's scratch for the Δ′ selection (identical
    /// bytes to `encode` — the scratch only changes where buffers live).
    fn encode_with(&self, ctx: &EncodeCtx, scratch: &mut EncodeScratch) -> Result<Encoded> {
        self.select_updates_into(ctx, scratch);
        let filter = BuiltFilter::build(self.filter, &scratch.keys)?;
        let (seed, layout_a, layout_b, payload, num_keys) = filter.parts();

        // Wire format: tag(1) backend(1) seed(8) layout_a(4) layout_b(8)
        //              num_keys(4) payload_len(4) payload(PNG or raw).
        // Byte 1 was the v1 boolean PNG flag; see [`PayloadBackend`].
        let mut bytes = Vec::with_capacity(payload.len() + 32);
        bytes.push(self.filter.tag());
        bytes.push(self.payload.tag());
        wire::put_u64(&mut bytes, seed);
        wire::put_u32(&mut bytes, layout_a);
        wire::put_u64(&mut bytes, layout_b);
        wire::put_u32(&mut bytes, num_keys as u32);
        wire::put_u32(&mut bytes, payload.len() as u32);
        match self.payload {
            PayloadBackend::Raw => bytes.extend_from_slice(&payload),
            PayloadBackend::Png => {
                let img = GrayImage::from_payload(&payload);
                bytes.extend_from_slice(&png::encode(&img));
            }
            PayloadBackend::PngFast => {
                let img = GrayImage::from_payload(&payload);
                bytes.extend_from_slice(&png::encode_fast(&img));
            }
        }
        Ok(Encoded { bytes })
    }

    fn decode(&self, bytes: &[u8], ctx: &DecodeCtx) -> Result<Update> {
        let mut mask = ctx.mask_g.to_vec();
        self.decode_mask_inplace(bytes, ctx, &mut mask)?;
        Ok(Update::Mask(mask))
    }

    /// Steady-state decode path: the output buffer comes from (and its
    /// predecessors return to) the round's [`ScratchPool`].
    fn decode_pooled(&self, bytes: &[u8], ctx: &DecodeCtx, pool: &ScratchPool) -> Result<Update> {
        let mut mask = pool.take_copy(ctx.mask_g);
        if let Err(e) = self.decode_mask_inplace(bytes, ctx, &mut mask) {
            pool.put(mask);
            return Err(e);
        }
        Ok(Update::Mask(mask))
    }

    /// Parse/validate once, then sweep per `d`-range: the restored filter
    /// is the range decoder (its fingerprint array is owned, so nothing
    /// borrows the wire bytes). Same validation — and therefore the same
    /// malformed-record rejections — as the full decode.
    fn range_decoder(
        &self,
        bytes: &[u8],
        ctx: &DecodeCtx,
    ) -> Result<Option<Box<dyn super::MaskRangeDecoder>>> {
        let _ = ctx;
        Ok(Some(Box::new(self.parse_filter(bytes)?)))
    }
}

impl DeltaMaskCodec {
    /// The shared parse core: validate the record header and layout
    /// params, unpack the PNG stage, and rebuild the filter. The payload
    /// is borrowed from the wire bytes or the decoded image while the
    /// fingerprint array is reassembled — no intermediate copies — and the
    /// returned filter owns its state.
    fn parse_filter(&self, bytes: &[u8]) -> Result<BuiltFilter> {
        ensure!(bytes.len() >= 30, "deltamask record too short");
        let kind = FilterKind::from_tag(bytes[0])?;
        // Both PNG backends produce standard PNG streams; only the tag and
        // the IDAT bytes differ.
        let is_png = PayloadBackend::from_tag(bytes[1])? != PayloadBackend::Raw;
        let mut r = wire::Reader::new(&bytes[2..]);
        let seed = r.u64()?;
        let layout_a = r.u32()?;
        let layout_b = r.u64()?;
        let num_keys = r.u32()? as usize;
        let payload_len = r.u32()? as usize;
        let rest = &bytes[2 + r.pos..];
        let decoded_img;
        let payload: &[u8] = if is_png {
            decoded_img = png::decode(rest).map_err(|e| anyhow::anyhow!("png: {e}"))?;
            ensure!(
                (decoded_img.width as usize * decoded_img.height as usize) >= payload_len,
                "png smaller than payload"
            );
            &decoded_img.pixels[..payload_len]
        } else {
            ensure!(rest.len() == payload_len, "payload length mismatch");
            rest
        };
        validate_filter_parts(kind, layout_a, layout_b, payload.len())?;
        Ok(BuiltFilter::restore(
            kind, seed, layout_a, layout_b, payload, num_keys,
        ))
    }

    /// The shared decode core: [`Self::parse_filter`] + the batched Eq. 5
    /// kernel directly over `mask` (already initialized to m^{g,t-1}).
    fn decode_mask_inplace(&self, bytes: &[u8], ctx: &DecodeCtx, mask: &mut [f32]) -> Result<()> {
        debug_assert_eq!(mask.len(), ctx.d);
        let filter = self.parse_filter(bytes)?;
        // Eq. 5: batched membership query across all d positions, flipping
        // hits in place. (The kernels no-op on an empty key set.)
        filter.decode_mask_into(mask);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::sample_mask_seeded;

    fn make_ctx<'a>(
        d: usize,
        theta_k: &'a [f32],
        theta_g: &'a [f32],
        mask_k: &'a [f32],
        mask_g: &'a [f32],
        kappa: f64,
    ) -> EncodeCtx<'a> {
        EncodeCtx {
            d,
            theta_k,
            theta_g,
            mask_k,
            mask_g,
            s_k: &[],
            s_g: &[],
            kappa,
            seed: 99,
        }
    }

    fn setup(d: usize, drift: f32, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Xoshiro256pp::new(seed);
        let theta_g: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
        let theta_k: Vec<f32> = theta_g
            .iter()
            .map(|&p| (p + drift * (rng.next_f32() - 0.5)).clamp(0.01, 0.99))
            .collect();
        let mut mask_g = Vec::new();
        sample_mask_seeded(&theta_g, 7, &mut mask_g);
        let mut mask_k = Vec::new();
        sample_mask_seeded(&theta_k, 8, &mut mask_k);
        (theta_k, theta_g, mask_k, mask_g)
    }

    #[test]
    fn roundtrip_reconstructs_selected_updates_exactly() {
        let d = 50_000;
        let (tk, tg, mk, mg) = setup(d, 0.1, 1);
        // κ=1 + 32-bit fingerprints ⇒ essentially exact reconstruction.
        let codec = DeltaMaskCodec::with_filter(FilterKind::BFuse32);
        let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 1.0);
        let enc = codec.encode(&ctx).unwrap();
        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mg,
            s_g: &[],
            seed: 99,
        };
        match codec.decode(&enc.bytes, &dec_ctx).unwrap() {
            Update::Mask(m) => {
                let wrong = m
                    .iter()
                    .zip(&mk)
                    .filter(|(a, b)| a != b)
                    .count();
                // 2^-32 fp rate over 50k queries: expect exactly 0.
                assert_eq!(wrong, 0, "reconstruction errors: {wrong}");
            }
            _ => panic!("wrong family"),
        }
    }

    #[test]
    fn bfuse8_reconstruction_error_is_bounded_by_fp_rate() {
        let d = 100_000;
        let (tk, tg, mk, mg) = setup(d, 0.05, 2);
        let codec = DeltaMaskCodec::default();
        let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 1.0);
        let enc = codec.encode(&ctx).unwrap();
        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mg,
            s_g: &[],
            seed: 99,
        };
        let Update::Mask(m) = codec.decode(&enc.bytes, &dec_ctx).unwrap() else {
            panic!()
        };
        // All true updates applied (no false negatives) ...
        let missed = (0..d)
            .filter(|&i| mk[i] != mg[i] && m[i] != mk[i])
            .count();
        assert_eq!(missed, 0);
        // ... and false flips bounded by ~d·2^-8 with slack.
        let extra = (0..d)
            .filter(|&i| mk[i] == mg[i] && m[i] != mk[i])
            .count();
        assert!(extra < (d as f64 * 0.008) as usize, "extra flips: {extra}");
    }

    #[test]
    fn kappa_truncates_and_prefers_high_kl() {
        let d = 10_000;
        let (tk, tg, mk, mg) = setup(d, 0.5, 3);
        let codec = DeltaMaskCodec::default();
        let full = codec.select_updates(&make_ctx(d, &tk, &tg, &mk, &mg, 1.0));
        let half = codec.select_updates(&make_ctx(d, &tk, &tg, &mk, &mg, 0.5));
        assert!(half.len() <= full.len() / 2 + 1);
        // Every selected index is a true difference.
        for &i in &half {
            assert_ne!(mk[i as usize], mg[i as usize]);
        }
        // Selected KL floor ≥ max unselected KL (selection property).
        let sel: std::collections::HashSet<u64> = half.iter().cloned().collect();
        let min_sel = half
            .iter()
            .map(|&i| kl_bernoulli(tk[i as usize], tg[i as usize]))
            .fold(f32::INFINITY, f32::min);
        let max_unsel = full
            .iter()
            .filter(|i| !sel.contains(i))
            .map(|&i| kl_bernoulli(tk[i as usize], tg[i as usize]))
            .fold(f32::NEG_INFINITY, f32::max);
        assert!(min_sel >= max_unsel - 1e-5, "{min_sel} < {max_unsel}");
    }

    #[test]
    fn bpp_well_below_one_for_sparse_updates() {
        // Late-training regime: ~2% mask drift ⇒ bpp must land deep below
        // 1 bpp (the paper's headline).
        let d = 327_680;
        let mut rng = Xoshiro256pp::new(4);
        let theta_g: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
        let mut mask_g = Vec::new();
        sample_mask_seeded(&theta_g, 5, &mut mask_g);
        let mut mask_k = mask_g.clone();
        let mut flipped = 0;
        while flipped < d / 50 {
            let i = rng.below(d as u64) as usize;
            mask_k[i] = 1.0 - mask_k[i];
            flipped += 1;
        }
        let codec = DeltaMaskCodec::default();
        let ctx = make_ctx(d, &theta_g, &theta_g, &mask_k, &mask_g, 0.8);
        let enc = codec.encode(&ctx).unwrap();
        let bpp = enc.bpp(d);
        assert!(bpp < 0.25, "bpp={bpp}");
        assert!(bpp > 0.01, "bpp={bpp} suspiciously low");
    }

    #[test]
    fn empty_delta_roundtrip() {
        let d = 1000;
        let theta = vec![0.5f32; d];
        let mut mask = Vec::new();
        sample_mask_seeded(&theta, 1, &mut mask);
        let codec = DeltaMaskCodec::default();
        let ctx = make_ctx(d, &theta, &theta, &mask, &mask, 0.8);
        let enc = codec.encode(&ctx).unwrap();
        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mask,
            s_g: &[],
            seed: 99,
        };
        let Update::Mask(m) = codec.decode(&enc.bytes, &dec_ctx).unwrap() else {
            panic!()
        };
        assert_eq!(m, mask);
    }

    #[test]
    fn all_filter_kinds_roundtrip() {
        let d = 20_000;
        let (tk, tg, mk, mg) = setup(d, 0.1, 6);
        for kind in [
            FilterKind::BFuse8,
            FilterKind::BFuse16,
            FilterKind::BFuse32,
            FilterKind::BFuse8Arity3,
            FilterKind::Xor8,
            FilterKind::Xor16,
            FilterKind::Xor32,
        ] {
            let codec = DeltaMaskCodec::with_filter(kind);
            let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 1.0);
            let enc = codec.encode(&ctx).unwrap();
            let dec_ctx = DecodeCtx {
                d,
                mask_g: &mg,
                s_g: &[],
                seed: 99,
            };
            let Update::Mask(m) = codec.decode(&enc.bytes, &dec_ctx).unwrap() else {
                panic!()
            };
            let missed = (0..d)
                .filter(|&i| mk[i] != mg[i] && m[i] != mk[i])
                .count();
            assert_eq!(missed, 0, "{kind:?} missed true updates");
        }
    }

    /// Two-pass Δ′ selection exactly as the seed implemented it — the
    /// oracle for the fused single-pass `select_updates_into`.
    fn select_updates_two_pass_oracle(codec: &DeltaMaskCodec, ctx: &EncodeCtx) -> Vec<u64> {
        let mut delta: Vec<u32> = Vec::new();
        for i in 0..ctx.d {
            if ctx.mask_g[i] != ctx.mask_k[i] {
                delta.push(i as u32);
            }
        }
        let k = ((ctx.kappa * delta.len() as f64).ceil() as usize).min(delta.len());
        if k == delta.len() {
            return delta.into_iter().map(u64::from).collect();
        }
        match codec.ranking {
            Ranking::Kl => {
                let scores: Vec<f32> = delta
                    .iter()
                    .map(|&i| kl_bernoulli(ctx.theta_k[i as usize], ctx.theta_g[i as usize]))
                    .collect();
                crate::util::top_k_indices(&scores, k)
                    .into_iter()
                    .map(|pos| delta[pos as usize] as u64)
                    .collect()
            }
            Ranking::Random => {
                let mut rng = Xoshiro256pp::new(ctx.seed ^ 0xdead_beef);
                rng.shuffle(&mut delta);
                delta.truncate(k);
                delta.into_iter().map(u64::from).collect()
            }
        }
    }

    #[test]
    fn fused_selection_matches_two_pass_oracle() {
        let d = 30_000;
        let (tk, tg, mk, mg) = setup(d, 0.2, 12);
        for ranking in [Ranking::Kl, Ranking::Random] {
            for kappa in [1.0, 0.8, 0.33, 0.0] {
                let codec = DeltaMaskCodec::with_ranking(ranking);
                let ctx = make_ctx(d, &tk, &tg, &mk, &mg, kappa);
                let fused = codec.select_updates(&ctx);
                let oracle = select_updates_two_pass_oracle(&codec, &ctx);
                assert_eq!(fused, oracle, "{ranking:?} kappa={kappa}");
            }
        }
    }

    #[test]
    fn batched_decode_matches_scalar_oracle_all_kinds() {
        // The tentpole parity contract: the blocked kernels change *how*
        // membership is queried, never what is decoded. Compare the full
        // decode against a scalar per-key sweep over the restored filter.
        let d = 50_000;
        let (tk, tg, mk, mg) = setup(d, 0.1, 14);
        for kind in [
            FilterKind::BFuse8,
            FilterKind::BFuse16,
            FilterKind::BFuse32,
            FilterKind::BFuse8Arity3,
            FilterKind::Xor8,
            FilterKind::Xor16,
            FilterKind::Xor32,
        ] {
            let codec = DeltaMaskCodec::with_filter(kind);
            let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 0.7);
            let enc = codec.encode(&ctx).unwrap();
            let dec_ctx = DecodeCtx {
                d,
                mask_g: &mg,
                s_g: &[],
                seed: 99,
            };
            let Update::Mask(got) = codec.decode(&enc.bytes, &dec_ctx).unwrap() else {
                panic!()
            };
            // Scalar oracle: rebuild the filter and sweep with the retained
            // per-key enum dispatch path.
            let delta = codec.select_updates(&ctx);
            let filter = BuiltFilter::build(kind, &delta).unwrap();
            let mut expect = mg.clone();
            for (i, m) in expect.iter_mut().enumerate() {
                if filter.contains(i as u64) {
                    *m = 1.0 - *m;
                }
            }
            assert_eq!(got, expect, "{kind:?} batched decode diverged");
        }
    }

    #[test]
    fn scratch_and_pooled_paths_are_identical_and_reuse_buffers() {
        let d = 20_000;
        let (tk, tg, mk, mg) = setup(d, 0.1, 15);
        let codec = DeltaMaskCodec::default();
        let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 0.8);
        // encode_with must be byte-identical to encode.
        let plain = codec.encode(&ctx).unwrap();
        let mut scratch = EncodeScratch::default();
        let scratched = codec.encode_with(&ctx, &mut scratch).unwrap();
        assert_eq!(plain.bytes, scratched.bytes);
        // Scratch persists and a second encode reuses it, still identical.
        let again = codec.encode_with(&ctx, &mut scratch).unwrap();
        assert_eq!(plain.bytes, again.bytes);

        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mg,
            s_g: &[],
            seed: 99,
        };
        let Update::Mask(want) = codec.decode(&plain.bytes, &dec_ctx).unwrap() else {
            panic!()
        };
        let pool = ScratchPool::new();
        let Update::Mask(got) = codec.decode_pooled(&plain.bytes, &dec_ctx, &pool).unwrap()
        else {
            panic!()
        };
        assert_eq!(got, want);
        // Returning the buffer makes the next pooled decode allocation-free.
        pool.put(got);
        assert_eq!(pool.spares(), 1);
        let Update::Mask(got2) = codec.decode_pooled(&plain.bytes, &dec_ctx, &pool).unwrap()
        else {
            panic!()
        };
        assert_eq!(got2, want);
        assert_eq!(pool.spares(), 0, "pooled decode must draw from the pool");
    }

    #[test]
    fn range_decoder_tiles_to_the_full_decode_all_kinds() {
        // The dimension-sharded decode contract: parse once, sweep per
        // range — any tiling of [0, d) reproduces the full decode bitwise
        // (false-positive flips included).
        let d = 20_000;
        let (tk, tg, mk, mg) = setup(d, 0.1, 21);
        for kind in [
            FilterKind::BFuse8,
            FilterKind::BFuse16,
            FilterKind::BFuse8Arity3,
            FilterKind::Xor8,
            FilterKind::Xor16,
        ] {
            let codec = DeltaMaskCodec::with_filter(kind);
            let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 0.7);
            let enc = codec.encode(&ctx).unwrap();
            let dec_ctx = DecodeCtx {
                d,
                mask_g: &mg,
                s_g: &[],
                seed: 99,
            };
            let Update::Mask(want) = codec.decode(&enc.bytes, &dec_ctx).unwrap() else {
                panic!()
            };
            let rd = codec
                .range_decoder(&enc.bytes, &dec_ctx)
                .unwrap()
                .expect("deltamask supports range decoding");
            let mut got = mg.clone();
            // Uneven tiling incl. an empty range and single-element ranges.
            let cuts = [0usize, 1, 2, 2, d / 3, d / 2 + 7, d];
            for w in cuts.windows(2) {
                let (lo, hi) = (w[0], w[1]);
                rd.decode_range(lo..hi, &mut got[lo..hi]);
            }
            assert_eq!(got, want, "{kind:?} range tiling diverged");
        }
        // Empty-Δ records range-decode to the unchanged baseline.
        let theta = vec![0.5f32; 64];
        let mut mask = Vec::new();
        sample_mask_seeded(&theta, 1, &mut mask);
        let codec = DeltaMaskCodec::default();
        let ctx = make_ctx(64, &theta, &theta, &mask, &mask, 0.8);
        let enc = codec.encode(&ctx).unwrap();
        let dec_ctx = DecodeCtx {
            d: 64,
            mask_g: &mask,
            s_g: &[],
            seed: 99,
        };
        let rd = codec.range_decoder(&enc.bytes, &dec_ctx).unwrap().unwrap();
        let mut got = mask.clone();
        rd.decode_range(0..64, &mut got[..]);
        assert_eq!(got, mask);
    }

    #[test]
    fn range_decoder_rejects_what_decode_rejects() {
        let d = 1_000;
        let (tk, tg, mk, mg) = setup(d, 0.2, 22);
        let codec = DeltaMaskCodec {
            payload: PayloadBackend::Raw,
            ..Default::default()
        };
        let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 1.0);
        let enc = codec.encode(&ctx).unwrap();
        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mg,
            s_g: &[],
            seed: 99,
        };
        let mut bad = enc.bytes.clone();
        bad[10..14].copy_from_slice(&0u32.to_le_bytes()); // zero segment length
        assert!(codec.decode(&bad, &dec_ctx).is_err());
        assert!(codec.range_decoder(&bad, &dec_ctx).is_err());
        assert!(codec.range_decoder(&bad[..8], &dec_ctx).is_err(), "truncated");
    }

    #[test]
    fn malformed_layout_errors_instead_of_panicking() {
        // Hand-craft a raw (non-PNG) record with inconsistent layout params:
        // validation must reject it before the membership kernel runs.
        let d = 1_000;
        let (tk, tg, mk, mg) = setup(d, 0.2, 16);
        let codec = DeltaMaskCodec {
            payload: PayloadBackend::Raw,
            ..Default::default()
        };
        let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 1.0);
        let enc = codec.encode(&ctx).unwrap();
        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mg,
            s_g: &[],
            seed: 99,
        };
        // Wire layout: tag(1) png(1) seed(8) layout_a@10(4) layout_b@14(8).
        // Zero / non-power-of-two segment lengths and a wild segment count
        // must all be rejected before the membership kernel runs.
        for layout_a in [0u32, 3, 7] {
            let mut bad = enc.bytes.clone();
            bad[10..14].copy_from_slice(&layout_a.to_le_bytes());
            assert!(
                codec.decode(&bad, &dec_ctx).is_err(),
                "layout_a={layout_a} must error"
            );
        }
        let mut bad = enc.bytes.clone();
        bad[14..22].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(codec.decode(&bad, &dec_ctx).is_err(), "huge layout_b must error");
    }

    #[test]
    fn png_stage_reduces_or_matches_raw_bytes() {
        let d = 100_000;
        let (tk, tg, mk, mg) = setup(d, 0.05, 8);
        let with_png = DeltaMaskCodec::default();
        let without = DeltaMaskCodec {
            payload: PayloadBackend::Raw,
            ..Default::default()
        };
        let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 0.8);
        let a = with_png.encode(&ctx).unwrap().bytes.len();
        let b = without.encode(&ctx).unwrap().bytes.len();
        // Fingerprints are near-uniform, so PNG gains are small — but the
        // overhead must stay tiny (≤ ~2% + fixed header).
        assert!(a <= b + b / 50 + 128, "png={a} raw={b}");
    }

    #[test]
    fn all_payload_backends_roundtrip_and_keep_wire_tags() {
        let d = 50_000;
        let (tk, tg, mk, mg) = setup(d, 0.1, 31);
        let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 1.0);
        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mg,
            s_g: &[],
            seed: 99,
        };
        // The default (v1-identical) decoder must read every backend's
        // record, and each record must carry its backend tag in byte 1.
        let v1_decoder = DeltaMaskCodec::default();
        let mut decoded = Vec::new();
        for backend in [
            PayloadBackend::Raw,
            PayloadBackend::Png,
            PayloadBackend::PngFast,
        ] {
            let codec = DeltaMaskCodec {
                payload: backend,
                ..Default::default()
            };
            let enc = codec.encode(&ctx).unwrap();
            assert_eq!(enc.bytes[1], backend.tag(), "{backend:?}");
            let Update::Mask(m) = v1_decoder.decode(&enc.bytes, &dec_ctx).unwrap() else {
                panic!()
            };
            let missed = (0..d)
                .filter(|&i| mk[i] != mg[i] && m[i] != mk[i])
                .count();
            assert_eq!(missed, 0, "{backend:?} missed true updates");
            decoded.push(m);
        }
        // Same filter fingerprint underneath ⇒ identical decoded masks.
        assert_eq!(decoded[0], decoded[1]);
        assert_eq!(decoded[0], decoded[2]);
        // Reserved backend tags are rejected, not misread as PNG.
        let enc = v1_decoder.encode(&ctx).unwrap();
        let mut bad = enc.bytes.clone();
        bad[1] = 3;
        assert!(v1_decoder.decode(&bad, &dec_ctx).is_err());
    }
}
