//! Server-side state: Bayesian aggregation of binary-mask updates (Alg. 2 /
//! Eq. 3) and FedAvg aggregation of score-delta updates.
//!
//! Aggregation is **streaming**: a round is `begin_round(K)` → K×
//! [`MaskServer::absorb`] → [`MaskServer::finish_round`], so the server
//! holds O(d) state (the Beta pseudo-counts / the score vector) instead of
//! buffering the round's full `Vec<Update>` (O(K·d)). The coordinator feeds
//! `absorb` per-arrival as updates come off the transport.
//!
//! Determinism across arrival orders:
//! * **Mask family** — pseudo-count updates add 0.0/1.0 to small
//!   integer-valued f32 accumulators. Those additions are exact (no
//!   rounding below 2²⁴), hence commutative and associative, so absorbing
//!   in any arrival order is *bitwise* identical to the seed's batch sum.
//! * **Delta family** — FedAvg on f32 scores is order-sensitive, so
//!   `absorb` applies deltas strictly in participant-slot order through a
//!   reorder window (out-of-order arrivals wait, decoded, in a small
//!   buffer). The arithmetic sequence is then identical to the batch path.
//!
//! The legacy [`MaskServer::aggregate`] survives as a thin wrapper over the
//! streaming triplet and is what the `PipelineMode::Batch` A/B path uses.

use crate::compress::{Family, Update};
use crate::coordinator::{
    shard_bounds, ConfigFingerprint, ShardPlacement, ShardedAggregator, SocketConfig, WireSlice,
};
use crate::model::theta_from_scores;
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::ops::Range;

/// The global probability mask and its Beta posterior.
#[derive(Clone, Debug)]
pub struct MaskServer {
    pub theta_g: Vec<f32>,
    /// Score mirror s_g = logit(θ_g) — the reference point for the
    /// delta-family codecs.
    pub s_g: Vec<f32>,
    alpha: Vec<f32>,
    beta: Vec<f32>,
    lambda0: f32,
    pub rho: f64,
    pub round: usize,
    stream: Option<RoundStream>,
    /// Update buffers whose contents have been folded into the posterior /
    /// score state, awaiting reclamation by the drain loop's scratch pool
    /// (see [`crate::coordinator::Aggregator::reclaim_buffer`]).
    spent: Vec<Vec<f32>>,
}

/// In-flight accounting for one streaming round.
#[derive(Clone, Debug)]
struct RoundStream {
    expected: usize,
    absorbed: usize,
    family: Option<Family>,
    /// Which participant slots have been absorbed (duplicates are a
    /// coordinator bug and would silently corrupt both families).
    seen: Vec<bool>,
    /// Delta family only: next participant slot to apply…
    next_slot: usize,
    /// …and decoded deltas that arrived ahead of their slot.
    reorder: BTreeMap<usize, Vec<f32>>,
}

impl MaskServer {
    pub fn new(d: usize, rho: f64) -> Self {
        Self::with_theta0(d, rho, 0.5)
    }

    /// θ₀-initialized server (pre-trained-model regime starts near 1).
    pub fn with_theta0(d: usize, rho: f64, theta0: f32) -> Self {
        let theta0 = theta0.clamp(0.01, 0.99);
        let s0 = (theta0 / (1.0 - theta0)).ln();
        Self {
            theta_g: vec![theta0; d],
            s_g: vec![s0; d],
            alpha: vec![1.0; d],
            beta: vec![1.0; d],
            lambda0: 1.0,
            rho,
            round: 0,
            stream: None,
            spent: Vec::new(),
        }
    }

    /// Open a round expecting `expected` client updates, applying the
    /// Alg. 2 lines 3–5 prior reset every ⌈1/ρ⌉ rounds.
    pub fn begin_round(&mut self, expected: usize) {
        let period = (1.0 / self.rho).ceil().max(1.0) as usize;
        if self.round % period == 0 {
            self.alpha.iter_mut().for_each(|a| *a = self.lambda0);
            self.beta.iter_mut().for_each(|b| *b = self.lambda0);
        }
        // Drop buffers nobody reclaimed (e.g. the legacy `aggregate`
        // wrapper) so the stash never grows across rounds.
        self.spent.clear();
        self.stream = Some(RoundStream::new(expected));
    }

    /// Absorb one decoded update for participant `slot` (its index in the
    /// round's participant list). Mask-family updates fold into the Beta
    /// pseudo-counts immediately in any order; delta-family updates are
    /// applied in slot order (see module docs).
    ///
    /// Panics on a family mix within one round, on a duplicate or
    /// out-of-range slot, on absorbing more updates than `begin_round`
    /// announced, or if no round is open — all of these are coordinator
    /// bugs, not recoverable data errors.
    pub fn absorb(&mut self, slot: usize, update: Update) {
        let d = self.theta_g.len();
        assert_eq!(update.len(), d, "update dimensionality mismatch");
        let stream = self
            .stream
            .as_mut()
            .expect("MaskServer::absorb called before begin_round");
        match stream.family {
            None => stream.family = Some(update.family()),
            Some(f) => assert!(
                f == update.family(),
                "mixed update families in one round"
            ),
        }
        assert!(
            stream.absorbed < stream.expected,
            "absorbed more updates than begin_round({}) announced",
            stream.expected
        );
        assert!(slot < stream.expected, "slot {slot} out of range");
        assert!(!stream.seen[slot], "duplicate update for slot {slot}");
        stream.seen[slot] = true;
        stream.absorbed += 1;
        match update {
            Update::Mask(m) => {
                // α += m ; β += 1 − m (Beta-Bernoulli pseudo-counts). Exact
                // integer f32 arithmetic ⇒ arrival-order independent.
                for i in 0..d {
                    self.alpha[i] += m[i];
                    self.beta[i] += 1.0 - m[i];
                }
                self.spent.push(m);
            }
            Update::ScoreDelta(delta) => {
                let k = stream.expected as f32;
                stream.reorder.insert(slot, delta);
                while let Some(next) = stream.reorder.remove(&stream.next_slot) {
                    for i in 0..d {
                        self.s_g[i] += next[i] / k;
                    }
                    stream.next_slot += 1;
                    self.spent.push(next);
                }
            }
        }
    }

    /// Pop one spent update buffer for reuse by the decode path (drained by
    /// `coordinator::drain_round` after every absorb).
    pub fn take_spent(&mut self) -> Option<Vec<f32>> {
        self.spent.pop()
    }

    /// Close the round: refresh θ_g / s_g from the absorbed updates and
    /// advance the round counter. Panics if updates announced by
    /// `begin_round` never arrived — use
    /// [`MaskServer::finish_round_partial`] for a quorum-degraded round.
    pub fn finish_round(&mut self) {
        self.finish_stream(false);
    }

    /// Close a **degraded** round: refresh global state from however many
    /// updates were actually absorbed (a quorum of the planned K, enforced
    /// upstream by the drain's completion policy).
    ///
    /// * **Mask family** — the Eq. 3 posterior mode is computed from the
    ///   pseudo-counts of whoever reported; FedPM's Bayesian aggregation is
    ///   defined over the observed cohort, so nothing else changes.
    /// * **Delta family** — a missing participant contributes an implicit
    ///   zero delta: FedAvg keeps dividing by the *planned* K, and any
    ///   decoded deltas still held in the reorder window behind a missing
    ///   slot are flushed in ascending slot order (keeping the arithmetic
    ///   sequence deterministic and arrival-order invariant).
    pub fn finish_round_partial(&mut self) {
        self.finish_stream(true);
    }

    fn finish_stream(&mut self, allow_partial: bool) {
        let mut stream = self
            .stream
            .take()
            .expect("MaskServer::finish_round called before begin_round");
        if !allow_partial {
            assert_eq!(
                stream.absorbed, stream.expected,
                "finish_round with {}/{} updates absorbed",
                stream.absorbed, stream.expected
            );
            debug_assert!(stream.reorder.is_empty());
        }
        match stream.family {
            Some(Family::Mask) => {
                for i in 0..self.theta_g.len() {
                    // Eq. 3 posterior-mode estimate; λ0=1 ⇒ running average
                    // of the observed mask bits since the last reset.
                    let denom = self.alpha[i] + self.beta[i] - 2.0;
                    self.theta_g[i] = if denom > 0.0 {
                        ((self.alpha[i] - 1.0) / denom).clamp(0.01, 0.99)
                    } else {
                        0.5
                    };
                }
                self.refresh_scores();
            }
            Some(Family::Delta) => {
                // Flush deltas held behind slots that never arrived
                // (ascending slot order, /K with the planned K — the
                // missing slots' implicit zero deltas need no arithmetic).
                let k = stream.expected as f32;
                for (_, next) in std::mem::take(&mut stream.reorder) {
                    for i in 0..self.s_g.len() {
                        self.s_g[i] += next[i] / k;
                    }
                    self.spent.push(next);
                }
                theta_from_scores(&self.s_g, &mut self.theta_g);
            }
            // A zero-participant round leaves the global state untouched.
            None => {}
        }
        self.round += 1;
    }

    /// Batch compatibility wrapper (and the `PipelineMode::Batch` path):
    /// one full round over a pre-collected update slice, in slot order.
    pub fn aggregate(&mut self, updates: &[Update]) {
        assert!(!updates.is_empty());
        self.begin_round(updates.len());
        for (slot, u) in updates.iter().enumerate() {
            self.absorb(slot, u.clone());
        }
        self.finish_round();
    }

    fn refresh_scores(&mut self) {
        for (s, &p) in self.s_g.iter_mut().zip(&self.theta_g) {
            let p = p.clamp(1e-6, 1.0 - 1e-6);
            *s = (p / (1.0 - p)).ln();
        }
    }

    // -----------------------------------------------------------------
    // Dimension sharding (the million-client aggregation seam)
    // -----------------------------------------------------------------

    /// Carve the contiguous coordinate range `range` out into an
    /// independent slice server: same round counter, prior-reset schedule
    /// and aggregation rule, restricted to `range.len()` coordinates.
    /// Every update rule here is per-coordinate (pseudo-count adds,
    /// slot-ordered FedAvg on scores, the Eq. 3 posterior mode), so a
    /// slice server run over a round's sub-updates performs *exactly* the
    /// arithmetic the whole server performs on those coordinates.
    fn shard_slice(&self, range: Range<usize>) -> MaskServer {
        MaskServer {
            theta_g: self.theta_g[range.clone()].to_vec(),
            s_g: self.s_g[range.clone()].to_vec(),
            alpha: self.alpha[range.clone()].to_vec(),
            beta: self.beta[range.clone()].to_vec(),
            lambda0: self.lambda0,
            rho: self.rho,
            round: self.round,
            stream: None,
            spent: Vec::new(),
        }
    }

    /// Build a dimension-sharded aggregation view of this server: `S`
    /// contiguous shards (see [`shard_bounds`]; clamped so no shard is
    /// empty), each an independent slice server with its own pseudo-count
    /// slice, participation counters and scratch pool, absorbed on `S`
    /// parallel lanes. Drive the view through one round
    /// (`coordinator::drain_round` with `DrainConfig::shards > 1`, or the
    /// plain `Aggregator` interface), then stitch it back with
    /// [`MaskServer::adopt_shards`] — the result is **bitwise identical**
    /// to having aggregated the round unsharded.
    ///
    /// ```
    /// use deltamask::compress::Update;
    /// use deltamask::coordinator::Aggregator;
    /// use deltamask::fl::server::MaskServer;
    ///
    /// let mut mono = MaskServer::with_theta0(6, 1.0, 0.5);
    /// let mut split = mono.clone();
    /// let updates = vec![
    ///     Update::Mask(vec![1.0, 0.0, 1.0, 1.0, 0.0, 1.0]),
    ///     Update::Mask(vec![0.0, 0.0, 1.0, 0.0, 1.0, 1.0]),
    /// ];
    /// mono.aggregate(&updates);
    ///
    /// let mut view = split.shard_view(3);
    /// view.begin_round(2);
    /// for (slot, u) in updates.iter().enumerate() {
    ///     view.absorb(slot, u.clone());
    /// }
    /// view.finish_round();
    /// split.adopt_shards(view);
    ///
    /// assert_eq!(mono.theta_g, split.theta_g); // bitwise
    /// assert_eq!(mono.s_g, split.s_g);
    /// assert_eq!(mono.round, split.round);
    /// ```
    pub fn shard_view(&self, shards: usize) -> ShardedAggregator<MaskServer> {
        ShardedAggregator::new(
            shard_bounds(self.theta_g.len(), shards)
                .into_iter()
                .map(|range| (range.clone(), self.shard_slice(range)))
                .collect(),
        )
    }

    /// [`MaskServer::shard_view`] with per-shard lane placement: shards
    /// whose [`ShardPlacement`] site is `local` run on in-process
    /// [`ThreadLane`](crate::coordinator::ThreadLane)s exactly as
    /// `shard_view` builds them; `uds:`/`tcp:` sites ship their slice
    /// server to a `deltamask shard-worker` process over the DMW1 wire
    /// and absorb remotely. Trajectories are bitwise identical either way
    /// (the slice arithmetic is byte-exact across the [`WireSlice`]
    /// codec). Fails if a remote site is unreachable or the worker's
    /// config fingerprint disagrees.
    pub fn shard_view_placed(
        &self,
        shards: usize,
        placement: &ShardPlacement,
        fingerprint: ConfigFingerprint,
        cfg: SocketConfig,
    ) -> Result<ShardedAggregator<MaskServer>> {
        ShardedAggregator::with_placement(
            shard_bounds(self.theta_g.len(), shards)
                .into_iter()
                .map(|range| (range.clone(), self.shard_slice(range)))
                .collect(),
            placement,
            fingerprint,
            cfg,
        )
    }

    /// Refresh the broadcast state (θ_g, s_g) and the round counter from a
    /// **resident** shard view without consuming it — the round-resident
    /// drain pipeline keeps one view (lanes, pools, pseudo-count slices)
    /// alive for the whole experiment and calls this after every round so
    /// planning and evaluation see the advanced global state. The Beta
    /// pseudo-counts stay resident in the slices (nothing outside the
    /// slices' own `finish_round` reads them); retire the view with
    /// [`MaskServer::adopt_shards`] for the full stitch at experiment end.
    /// Bitwise identical to a per-round `adopt_shards` as far as θ_g/s_g
    /// are concerned (the copy is the same pure copy).
    ///
    /// Panics if the view's geometry does not match this server, a round
    /// is still in flight on the view, or the slices' round counters
    /// disagree (all coordinator bugs).
    pub fn sync_from_shards(&mut self, view: &ShardedAggregator<MaskServer>) {
        assert_eq!(view.d(), self.theta_g.len(), "shard view dimensionality");
        let slices = view
            .shard_slices()
            .expect("sync_from_shards called mid-round");
        let mut round = None;
        for (range, slice) in slices {
            assert_eq!(slice.theta_g.len(), range.len(), "slice/range mismatch");
            self.theta_g[range.clone()].copy_from_slice(&slice.theta_g);
            self.s_g[range.clone()].copy_from_slice(&slice.s_g);
            match round {
                None => round = Some(slice.round),
                Some(r) => assert_eq!(r, slice.round, "shard rounds diverged"),
            }
        }
        if let Some(r) = round {
            self.round = r;
        }
    }

    /// Stitch a drained shard view back into this server: copy every
    /// slice's posterior / score state into its coordinate range and
    /// adopt the advanced round counter. The stitched global state is
    /// bitwise identical to an unsharded round (see
    /// [`MaskServer::shard_view`]).
    ///
    /// Panics if the view's geometry does not match this server or the
    /// slices' round counters disagree (both are coordinator bugs).
    pub fn adopt_shards(&mut self, view: ShardedAggregator<MaskServer>) {
        assert_eq!(view.d(), self.theta_g.len(), "shard view dimensionality");
        let mut round = None;
        for (range, slice) in view.into_shards() {
            assert_eq!(slice.theta_g.len(), range.len(), "slice/range mismatch");
            self.theta_g[range.clone()].copy_from_slice(&slice.theta_g);
            self.s_g[range.clone()].copy_from_slice(&slice.s_g);
            self.alpha[range.clone()].copy_from_slice(&slice.alpha);
            self.beta[range.clone()].copy_from_slice(&slice.beta);
            match round {
                None => round = Some(slice.round),
                Some(r) => assert_eq!(r, slice.round, "shard rounds diverged"),
            }
        }
        if let Some(r) = round {
            self.round = r;
        }
        self.stream = None;
        self.spent.clear();
    }
}

/// The coordinator drives `MaskServer` through the generic sink trait; the
/// inherent methods above are the reference implementation.
impl crate::coordinator::Aggregator for MaskServer {
    fn begin_round(&mut self, expected: usize) {
        MaskServer::begin_round(self, expected);
    }

    fn absorb(&mut self, slot: usize, update: Update) {
        MaskServer::absorb(self, slot, update);
    }

    fn finish_round(&mut self) {
        MaskServer::finish_round(self);
    }

    fn finish_round_partial(&mut self) {
        MaskServer::finish_round_partial(self);
    }

    fn reclaim_buffer(&mut self) -> Option<Vec<f32>> {
        self.take_spent()
    }
}

/// Byte-exact slice-server codec for remote shard lanes: `[d:u64]`
/// `[round:u64]` `[rho:f64]` `[lambda0:f32]` then the four per-coordinate
/// f32 arrays (θ_g, s_g, α, β), all little-endian. f32/f64 bits round-trip
/// verbatim, so shipping a slice to a `shard-worker` and back changes no
/// arithmetic. In-flight round state never crosses the wire: encode is only
/// legal between rounds (enforced by the shard protocol's Finish/Abort
/// barriers), and decode rebuilds with `stream: None` and an empty spent
/// stash.
impl WireSlice for MaskServer {
    fn encode_slice(&self) -> Vec<u8> {
        let d = self.theta_g.len();
        let mut out = Vec::with_capacity(8 + 8 + 8 + 4 + 16 * d);
        out.extend_from_slice(&(d as u64).to_le_bytes());
        out.extend_from_slice(&(self.round as u64).to_le_bytes());
        out.extend_from_slice(&self.rho.to_le_bytes());
        out.extend_from_slice(&self.lambda0.to_le_bytes());
        for arr in [&self.theta_g, &self.s_g, &self.alpha, &self.beta] {
            for v in arr.iter() {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    fn decode_slice(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < 28 {
            bail!("shard slice truncated: {} bytes", bytes.len());
        }
        let d = u64::from_le_bytes(bytes[0..8].try_into().unwrap());
        let expect = (d as usize)
            .checked_mul(16)
            .and_then(|n| n.checked_add(28));
        if expect != Some(bytes.len()) {
            bail!(
                "shard slice length mismatch: {} bytes for d={d}",
                bytes.len()
            );
        }
        let d = d as usize;
        let round = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        let rho = f64::from_le_bytes(bytes[16..24].try_into().unwrap());
        let lambda0 = f32::from_le_bytes(bytes[24..28].try_into().unwrap());
        if !(rho.is_finite() && rho > 0.0) {
            bail!("shard slice rho {rho} out of range");
        }
        let f32s = |arr: usize| -> Vec<f32> {
            let base = 28 + arr * 4 * d;
            (0..d)
                .map(|i| {
                    f32::from_le_bytes(bytes[base + 4 * i..base + 4 * i + 4].try_into().unwrap())
                })
                .collect()
        };
        Ok(MaskServer {
            theta_g: f32s(0),
            s_g: f32s(1),
            alpha: f32s(2),
            beta: f32s(3),
            lambda0,
            rho,
            round,
            stream: None,
            spent: Vec::new(),
        })
    }

    fn slice_dim(&self) -> usize {
        self.theta_g.len()
    }
}

impl RoundStream {
    fn new(expected: usize) -> Self {
        Self {
            expected,
            absorbed: 0,
            family: None,
            seen: vec![false; expected],
            next_slot: 0,
            reorder: BTreeMap::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn bayes_agg_is_running_average_with_lambda1() {
        let d = 4;
        let mut srv = MaskServer::new(d, 1.0);
        srv.aggregate(&[
            Update::Mask(vec![1.0, 0.0, 1.0, 1.0]),
            Update::Mask(vec![1.0, 0.0, 0.0, 1.0]),
        ]);
        // θ = mean of observed bits = [1, 0, 0.5, 1] (clamped to [.01,.99]).
        assert_eq!(srv.theta_g, vec![0.99, 0.01, 0.5, 0.99]);
    }

    #[test]
    fn prior_reset_schedule() {
        let d = 2;
        let mut srv = MaskServer::new(d, 0.5); // reset every 2 rounds
        for round in 0..4 {
            srv.aggregate(&[Update::Mask(vec![1.0, 0.0])]);
            let expect_after_reset = round % 2 == 0;
            if expect_after_reset {
                // Fresh prior + one all-ones observation on coord 0.
                assert_eq!(srv.theta_g[0], 0.99, "round {round}");
            }
        }
    }

    #[test]
    fn streaming_mask_absorb_is_arrival_order_invariant() {
        let d = 512;
        let mut rng = Xoshiro256pp::new(11);
        let updates: Vec<Update> = (0..7)
            .map(|_| {
                Update::Mask(
                    (0..d)
                        .map(|_| if rng.next_f32() < 0.5 { 1.0 } else { 0.0 })
                        .collect(),
                )
            })
            .collect();
        let mut batch = MaskServer::new(d, 1.0);
        batch.aggregate(&updates);
        // Absorb in reverse arrival order — bitwise identical θ_g / s_g.
        let mut stream = MaskServer::new(d, 1.0);
        stream.begin_round(updates.len());
        for (slot, u) in updates.iter().enumerate().rev() {
            stream.absorb(slot, u.clone());
        }
        stream.finish_round();
        assert_eq!(batch.theta_g, stream.theta_g);
        assert_eq!(batch.s_g, stream.s_g);
        assert_eq!(batch.round, stream.round);
    }

    #[test]
    fn streaming_delta_reorder_window_preserves_slot_order() {
        let d = 256;
        let mut rng = Xoshiro256pp::new(12);
        let updates: Vec<Update> = (0..5)
            .map(|_| Update::ScoreDelta((0..d).map(|_| rng.next_f32() - 0.5).collect()))
            .collect();
        let mut batch = MaskServer::new(d, 1.0);
        batch.aggregate(&updates);
        // Adversarial arrival order: last slot first.
        let mut stream = MaskServer::new(d, 1.0);
        stream.begin_round(updates.len());
        for slot in [4usize, 2, 0, 3, 1] {
            stream.absorb(slot, updates[slot].clone());
        }
        stream.finish_round();
        assert_eq!(batch.s_g, stream.s_g);
        assert_eq!(batch.theta_g, stream.theta_g);
    }

    #[test]
    fn unbiased_estimation_error_bound() {
        // Appendix B / Eq. 6: E‖θ̄ − θ̂‖² ≤ d/4K with θ̂ the mean of sampled
        // masks. Monte-Carlo over K clients.
        let d = 2_000;
        let k = 10;
        let mut rng = Xoshiro256pp::new(1);
        let thetas: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..d).map(|_| rng.next_f32()).collect())
            .collect();
        let mut theta_bar = vec![0.0f64; d];
        for t in &thetas {
            for i in 0..d {
                theta_bar[i] += t[i] as f64 / k as f64;
            }
        }
        let trials = 30;
        let mut mse = 0.0f64;
        for _ in 0..trials {
            let mut est = vec![0.0f64; d];
            for t in &thetas {
                for i in 0..d {
                    if rng.next_f32() < t[i] {
                        est[i] += 1.0 / k as f64;
                    }
                }
            }
            mse += (0..d)
                .map(|i| (est[i] - theta_bar[i]).powi(2))
                .sum::<f64>()
                / trials as f64;
        }
        let bound = d as f64 / (4.0 * k as f64);
        assert!(mse <= bound, "mse={mse} bound={bound}");
        assert!(mse > bound * 0.1, "bound should be within an order: {mse}");
    }

    #[test]
    fn spent_buffers_flow_back_in_absorb_order() {
        let mut srv = MaskServer::new(4, 1.0);
        srv.begin_round(2);
        srv.absorb(0, Update::Mask(vec![1.0, 0.0, 1.0, 0.0]));
        assert_eq!(srv.take_spent(), Some(vec![1.0, 0.0, 1.0, 0.0]));
        assert!(srv.take_spent().is_none());
        srv.absorb(1, Update::Mask(vec![1.0; 4]));
        srv.finish_round();
        assert!(srv.take_spent().is_some());

        // Delta family: the reorder window releases buffers in slot order,
        // so an out-of-order arrival is held, not reclaimed.
        let mut srv = MaskServer::new(2, 1.0);
        srv.begin_round(2);
        srv.absorb(1, Update::ScoreDelta(vec![0.5, 0.5]));
        assert!(srv.take_spent().is_none(), "slot 1 must wait for slot 0");
        srv.absorb(0, Update::ScoreDelta(vec![0.25, 0.25]));
        assert!(srv.take_spent().is_some());
        assert!(srv.take_spent().is_some());
        assert!(srv.take_spent().is_none());
        srv.finish_round();
    }

    #[test]
    fn delta_aggregation_moves_scores() {
        let d = 3;
        let mut srv = MaskServer::new(d, 1.0);
        srv.aggregate(&[
            Update::ScoreDelta(vec![1.0, -1.0, 0.0]),
            Update::ScoreDelta(vec![3.0, -1.0, 0.0]),
        ]);
        assert_eq!(srv.s_g, vec![2.0, -1.0, 0.0]);
        assert!((srv.theta_g[0] - crate::model::sigmoid(2.0)).abs() < 1e-6);
        assert!((srv.theta_g[2] - 0.5).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "mixed update families")]
    fn mixed_families_rejected() {
        let mut srv = MaskServer::new(2, 1.0);
        srv.aggregate(&[
            Update::Mask(vec![1.0, 0.0]),
            Update::ScoreDelta(vec![0.1, 0.2]),
        ]);
    }

    #[test]
    #[should_panic(expected = "more updates than begin_round")]
    fn over_absorbing_rejected() {
        let mut srv = MaskServer::new(2, 1.0);
        srv.begin_round(1);
        srv.absorb(0, Update::Mask(vec![1.0, 0.0]));
        srv.absorb(1, Update::Mask(vec![1.0, 0.0]));
    }

    #[test]
    #[should_panic(expected = "duplicate update for slot")]
    fn duplicate_slot_rejected() {
        let mut srv = MaskServer::new(2, 1.0);
        srv.begin_round(2);
        srv.absorb(1, Update::ScoreDelta(vec![0.1, 0.2]));
        srv.absorb(1, Update::ScoreDelta(vec![0.3, 0.4]));
    }

    #[test]
    #[should_panic(expected = "updates absorbed")]
    fn short_round_rejected_at_finish() {
        let mut srv = MaskServer::new(2, 1.0);
        srv.begin_round(2);
        srv.absorb(0, Update::Mask(vec![1.0, 0.0]));
        srv.finish_round();
    }

    #[test]
    fn partial_finish_mask_family_aggregates_the_survivors() {
        let mut srv = MaskServer::new(2, 1.0);
        srv.begin_round(3);
        srv.absorb(0, Update::Mask(vec![1.0, 0.0]));
        srv.absorb(2, Update::Mask(vec![1.0, 1.0]));
        // Slot 1 never reports: the posterior mode is over who showed up.
        srv.finish_round_partial();
        assert_eq!(srv.theta_g, vec![0.99, 0.5]);
        assert_eq!(srv.round, 1);
    }

    #[test]
    fn partial_finish_flushes_delta_reorder_window_with_implicit_zeros() {
        let mut srv = MaskServer::new(2, 1.0);
        srv.begin_round(3);
        // Slot 0 never arrives, so both deltas are held by the reorder
        // window until the partial finish flushes them in slot order.
        srv.absorb(2, Update::ScoreDelta(vec![3.0, 0.0]));
        srv.absorb(1, Update::ScoreDelta(vec![0.0, 3.0]));
        assert!(srv.take_spent().is_none(), "held behind the missing slot");
        srv.finish_round_partial();
        // FedAvg over the planned K = 3: the missing slot is a zero delta.
        assert_eq!(srv.s_g, vec![1.0, 1.0]);
        // A degraded run matches a clean run over exactly that cohort.
        let mut clean = MaskServer::new(2, 1.0);
        clean.begin_round(3);
        clean.absorb(1, Update::ScoreDelta(vec![0.0, 3.0]));
        clean.absorb(2, Update::ScoreDelta(vec![3.0, 0.0]));
        clean.finish_round_partial();
        assert_eq!(srv.s_g, clean.s_g);
        assert_eq!(srv.theta_g, clean.theta_g);
    }

    /// Random rounds for `rounds` iterations of `family`, aggregated
    /// monolithically and through a shard view — must match bitwise after
    /// every stitch, including across a prior reset (ρ=0.5 ⇒ period 2).
    fn shard_trajectory_case(shards: usize, d: usize, mask_family: bool) {
        use crate::coordinator::Aggregator as _;
        let mut rng = Xoshiro256pp::new(31 + shards as u64);
        let mut mono = MaskServer::with_theta0(d, 0.5, 0.85);
        let mut split = mono.clone();
        for round in 0..4 {
            let k = 2 + round % 3;
            let updates: Vec<Update> = (0..k)
                .map(|_| {
                    if mask_family {
                        Update::Mask(
                            (0..d)
                                .map(|_| if rng.next_f32() < 0.5 { 1.0 } else { 0.0 })
                                .collect(),
                        )
                    } else {
                        Update::ScoreDelta((0..d).map(|_| rng.next_f32() - 0.5).collect())
                    }
                })
                .collect();
            mono.aggregate(&updates);
            let mut view = split.shard_view(shards);
            view.begin_round(k);
            // Adversarial arrival order: reversed.
            for slot in (0..k).rev() {
                view.absorb(slot, updates[slot].clone());
            }
            view.finish_round();
            split.adopt_shards(view);
            assert_eq!(mono.theta_g, split.theta_g, "round {round}");
            assert_eq!(mono.s_g, split.s_g, "round {round}");
            assert_eq!(mono.round, split.round, "round {round}");
        }
    }

    #[test]
    fn wire_slice_codec_round_trips_mask_server_bitwise() {
        let d = 37;
        let mut rng = Xoshiro256pp::new(7);
        let mut srv = MaskServer::with_theta0(d, 0.25, 0.85);
        let bit = |rng: &mut Xoshiro256pp| if rng.next_f32() < 0.5 { 1.0 } else { 0.0 };
        srv.aggregate(&[
            Update::Mask((0..d).map(|_| bit(&mut rng)).collect()),
            Update::Mask((0..d).map(|_| bit(&mut rng)).collect()),
        ]);
        let bytes = srv.encode_slice();
        assert_eq!(bytes.len(), 28 + 16 * d);
        let back = MaskServer::decode_slice(&bytes).unwrap();
        assert_eq!(back.slice_dim(), d);
        assert_eq!(back.theta_g, srv.theta_g);
        assert_eq!(back.s_g, srv.s_g);
        assert_eq!(back.alpha, srv.alpha);
        assert_eq!(back.beta, srv.beta);
        assert_eq!(back.round, srv.round);
        assert_eq!(back.rho, srv.rho);
        // Re-encode is byte-identical; the codec is a bijection on states.
        assert_eq!(back.encode_slice(), bytes);
        // Decoded servers aggregate bitwise-identically to the original.
        let next = vec![Update::Mask(vec![1.0; d]), Update::Mask(vec![0.0; d])];
        let mut a = srv.clone();
        let mut b = back;
        a.aggregate(&next);
        b.aggregate(&next);
        assert_eq!(a.theta_g, b.theta_g);
        assert_eq!(a.s_g, b.s_g);

        // Garbage is rejected, never panics.
        assert!(MaskServer::decode_slice(&[]).is_err());
        assert!(MaskServer::decode_slice(&bytes[..bytes.len() - 1]).is_err());
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(MaskServer::decode_slice(&extra).is_err());
        let mut huge = bytes.clone();
        huge[0..8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(MaskServer::decode_slice(&huge).is_err());
        let mut bad_rho = bytes;
        bad_rho[16..24].copy_from_slice(&0.0f64.to_le_bytes());
        assert!(MaskServer::decode_slice(&bad_rho).is_err());
    }

    #[test]
    fn shard_view_placed_all_local_matches_shard_view_bitwise() {
        use crate::coordinator::Aggregator as _;
        let d = 65;
        let mut rng = Xoshiro256pp::new(44);
        let base = MaskServer::with_theta0(d, 0.5, 0.85);
        let updates: Vec<Update> = (0..3)
            .map(|_| {
                Update::Mask(
                    (0..d)
                        .map(|_| if rng.next_f32() < 0.5 { 1.0 } else { 0.0 })
                        .collect(),
                )
            })
            .collect();
        let fp = ConfigFingerprint {
            seed: 1,
            n_clients: 3,
            rounds: 1,
            d: d as u64,
        };
        let mut plain = base.clone();
        let mut placed_srv = base;
        let mut view = plain.shard_view(2);
        let mut placed = placed_srv
            .shard_view_placed(2, &ShardPlacement::default(), fp, SocketConfig::default())
            .unwrap();
        for v in [&mut view, &mut placed] {
            v.begin_round(updates.len());
            for (slot, u) in updates.iter().enumerate() {
                v.absorb(slot, u.clone());
            }
            v.finish_round();
        }
        plain.adopt_shards(view);
        placed_srv.adopt_shards(placed);
        assert_eq!(plain.theta_g, placed_srv.theta_g);
        assert_eq!(plain.s_g, placed_srv.s_g);
        assert_eq!(plain.round, placed_srv.round);
    }

    #[test]
    fn shard_view_trajectories_match_monolithic_bitwise() {
        for shards in [1usize, 2, 3, 8] {
            shard_trajectory_case(shards, 257, true);
            shard_trajectory_case(shards, 257, false);
        }
        // More shards than coordinates: clamped, still exact.
        shard_trajectory_case(16, 5, true);
    }

    #[test]
    fn resident_view_with_per_round_sync_matches_monolithic_bitwise() {
        // The round-resident regime: ONE view (lanes + pseudo-count slices
        // resident), θ_g/s_g synced back per round, full stitch at the
        // end — across the ρ=0.5 prior reset (fires on rounds 0 and 2).
        use crate::coordinator::Aggregator as _;
        let d = 257;
        let mut rng = Xoshiro256pp::new(99);
        let mut mono = MaskServer::with_theta0(d, 0.5, 0.85);
        let mut split = mono.clone();
        let mut view = split.shard_view(3);
        for round in 0..4 {
            let k = 2 + round % 2;
            let updates: Vec<Update> = (0..k)
                .map(|_| {
                    Update::Mask(
                        (0..d)
                            .map(|_| if rng.next_f32() < 0.5 { 1.0 } else { 0.0 })
                            .collect(),
                    )
                })
                .collect();
            mono.aggregate(&updates);
            view.begin_round(k);
            for slot in (0..k).rev() {
                view.absorb(slot, updates[slot].clone());
            }
            view.finish_round();
            split.sync_from_shards(&view);
            assert_eq!(mono.theta_g, split.theta_g, "round {round}");
            assert_eq!(mono.s_g, split.s_g, "round {round}");
            assert_eq!(mono.round, split.round, "round {round}");
        }
        // Retiring the view stitches the pseudo-counts too; the next
        // unsharded round then continues bitwise-identically.
        split.adopt_shards(view);
        let next = vec![Update::Mask(vec![1.0; d])];
        mono.aggregate(&next);
        split.aggregate(&next);
        assert_eq!(mono.theta_g, split.theta_g);
        assert_eq!(mono.s_g, split.s_g);
    }
}
