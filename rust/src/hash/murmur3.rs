//! MurmurHash3 (Austin Appleby, public domain) — the hash family the paper
//! names for binary fuse filter fingerprinting (§3.1). From-scratch port of
//! the x86_32 and x64_128 variants, validated against the reference test
//! vectors.

/// MurmurHash3_x86_32.
pub fn murmur3_32(data: &[u8], seed: u32) -> u32 {
    const C1: u32 = 0xcc9e2d51;
    const C2: u32 = 0x1b873593;
    let mut h1 = seed;
    let nblocks = data.len() / 4;

    for i in 0..nblocks {
        let mut k1 = u32::from_le_bytes(data[i * 4..i * 4 + 4].try_into().unwrap());
        k1 = k1.wrapping_mul(C1).rotate_left(15).wrapping_mul(C2);
        h1 ^= k1;
        h1 = h1.rotate_left(13).wrapping_mul(5).wrapping_add(0xe6546b64);
    }

    let tail = &data[nblocks * 4..];
    let mut k1: u32 = 0;
    if tail.len() >= 3 {
        k1 ^= (tail[2] as u32) << 16;
    }
    if tail.len() >= 2 {
        k1 ^= (tail[1] as u32) << 8;
    }
    if !tail.is_empty() {
        k1 ^= tail[0] as u32;
        k1 = k1.wrapping_mul(C1).rotate_left(15).wrapping_mul(C2);
        h1 ^= k1;
    }

    h1 ^= data.len() as u32;
    fmix32(h1)
}

#[inline]
fn fmix32(mut h: u32) -> u32 {
    h ^= h >> 16;
    h = h.wrapping_mul(0x85ebca6b);
    h ^= h >> 13;
    h = h.wrapping_mul(0xc2b2ae35);
    h ^= h >> 16;
    h
}

/// MurmurHash3_x64_128; returns (h1, h2).
pub fn murmur3_x64_128(data: &[u8], seed: u64) -> (u64, u64) {
    const C1: u64 = 0x87c37b91114253d5;
    const C2: u64 = 0x4cf5ad432745937f;
    let mut h1 = seed;
    let mut h2 = seed;
    let nblocks = data.len() / 16;

    for i in 0..nblocks {
        let k1 = u64::from_le_bytes(data[i * 16..i * 16 + 8].try_into().unwrap());
        let k2 = u64::from_le_bytes(data[i * 16 + 8..i * 16 + 16].try_into().unwrap());

        let k1 = k1.wrapping_mul(C1).rotate_left(31).wrapping_mul(C2);
        h1 ^= k1;
        h1 = h1
            .rotate_left(27)
            .wrapping_add(h2)
            .wrapping_mul(5)
            .wrapping_add(0x52dce729);

        let k2 = k2.wrapping_mul(C2).rotate_left(33).wrapping_mul(C1);
        h2 ^= k2;
        h2 = h2
            .rotate_left(31)
            .wrapping_add(h1)
            .wrapping_mul(5)
            .wrapping_add(0x38495ab5);
    }

    let tail = &data[nblocks * 16..];
    let mut k1: u64 = 0;
    let mut k2: u64 = 0;
    let t = tail.len();
    // The reference switch falls through from 15 down to 1.
    for i in (8..t).rev() {
        k2 ^= (tail[i] as u64) << ((i - 8) * 8);
    }
    if t > 8 {
        k2 = k2.wrapping_mul(C2).rotate_left(33).wrapping_mul(C1);
        h2 ^= k2;
    }
    for i in (0..t.min(8)).rev() {
        k1 ^= (tail[i] as u64) << (i * 8);
    }
    if t > 0 {
        k1 = k1.wrapping_mul(C1).rotate_left(31).wrapping_mul(C2);
        h1 ^= k1;
    }

    h1 ^= data.len() as u64;
    h2 ^= data.len() as u64;
    h1 = h1.wrapping_add(h2);
    h2 = h2.wrapping_add(h1);
    h1 = super::mix64(h1);
    h2 = super::mix64(h2);
    h1 = h1.wrapping_add(h2);
    h2 = h2.wrapping_add(h1);
    (h1, h2)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Reference vectors from the canonical smhasher implementation.
    #[test]
    fn murmur3_32_vectors() {
        assert_eq!(murmur3_32(b"", 0), 0);
        assert_eq!(murmur3_32(b"", 1), 0x514e28b7);
        assert_eq!(murmur3_32(b"", 0xffffffff), 0x81f16f39);
        assert_eq!(murmur3_32(b"test", 0), 0xba6bd213);
        assert_eq!(murmur3_32(b"test", 0x9747b28c), 0x704b81dc);
        assert_eq!(murmur3_32(b"Hello, world!", 0), 0xc0363e43);
        assert_eq!(murmur3_32(b"Hello, world!", 0x9747b28c), 0x24884cba);
        assert_eq!(
            murmur3_32(b"The quick brown fox jumps over the lazy dog", 0x9747b28c),
            0x2fa826cd
        );
    }

    #[test]
    fn murmur3_128_empty_seed0() {
        assert_eq!(murmur3_x64_128(b"", 0), (0, 0));
    }

    #[test]
    fn murmur3_128_deterministic_and_length_sensitive() {
        let data: Vec<u8> = (0..64u8).collect();
        let mut outs = std::collections::HashSet::new();
        for len in 0..=64 {
            let h = murmur3_x64_128(&data[..len], 42);
            assert_eq!(h, murmur3_x64_128(&data[..len], 42));
            assert!(outs.insert(h), "collision at len={len}");
        }
    }

    #[test]
    fn murmur3_128_seed_sensitivity() {
        let a = murmur3_x64_128(b"deltamask", 1);
        let b = murmur3_x64_128(b"deltamask", 2);
        assert_ne!(a, b);
    }

    #[test]
    fn murmur3_128_distribution() {
        // Hash of consecutive integers should fill buckets uniformly.
        let mut counts = [0usize; 16];
        for i in 0..16_000u64 {
            let (h, _) = murmur3_x64_128(&i.to_le_bytes(), 0);
            counts[(h >> 60) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 1000.0).abs() < 200.0, "{counts:?}");
        }
    }
}
