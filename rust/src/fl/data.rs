//! Synthetic federated datasets — the DESIGN.md §2 substitution for the
//! paper's 8 image datasets.
//!
//! Each dataset profile emulates the *frozen-backbone feature distribution*
//! of one benchmark: class prototypes on a hypersphere, split into
//! `subclusters` modes per class (more modes ⇒ less linearly separable ⇒
//! larger gap between Linear Probing and adaptive methods, e.g. SVHN), plus
//! isotropic noise. Difficulty knobs are calibrated so the Linear-Probing
//! accuracy ordering matches the paper's Table 2 LP row.
//!
//! Client splits follow the paper §4: Dirichlet(a) over classes with a=10
//! (IID, C_p ≈ 1.0) or a=0.1 (non-IID, C_p ≈ 0.2).

use crate::model::ArchConfig;
use crate::util::rng::Xoshiro256pp;

/// Profile of one simulated dataset.
#[derive(Clone, Debug)]
pub struct DatasetProfile {
    pub name: &'static str,
    pub classes: usize,
    /// Sub-modes per class: drives the LP↔adaptive gap.
    pub subclusters: usize,
    /// Within-cluster noise std (relative to unit prototypes).
    pub noise: f32,
    /// Prototype spread: scale of cluster centers.
    pub radius: f32,
}

/// The paper's 8 datasets (§4) with difficulty calibrated to its LP row.
pub fn profiles() -> Vec<DatasetProfile> {
    // Calibrated against centralized-LP probes (see EXPERIMENTS.md §Data)
    // to land near the paper's Table 2 Linear-Probing row: cifar10 94,
    // cifar100 74, svhn 59 (multi-modal ⇒ LP weak / adaptation strong),
    // emnist 89, fmnist 89, eurosat 95, food101 77, cars196 62.
    vec![
        // name        classes  sub  noise  radius
        DatasetProfile { name: "cifar10",  classes: 10,  subclusters: 1, noise: 0.19, radius: 1.0 },
        DatasetProfile { name: "cifar100", classes: 100, subclusters: 1, noise: 0.17, radius: 1.0 },
        DatasetProfile { name: "svhn",     classes: 10,  subclusters: 4, noise: 0.18, radius: 1.0 },
        DatasetProfile { name: "emnist",   classes: 49,  subclusters: 1, noise: 0.16, radius: 1.0 },
        DatasetProfile { name: "fmnist",   classes: 10,  subclusters: 1, noise: 0.22, radius: 1.0 },
        DatasetProfile { name: "eurosat",  classes: 10,  subclusters: 1, noise: 0.18, radius: 1.0 },
        DatasetProfile { name: "food101",  classes: 101, subclusters: 2, noise: 0.14, radius: 1.0 },
        DatasetProfile { name: "cars196",  classes: 196, subclusters: 1, noise: 0.20, radius: 1.0 },
    ]
}

pub fn profile(name: &str) -> Option<DatasetProfile> {
    profiles().into_iter().find(|p| p.name == name)
}

/// One client's local shard.
#[derive(Clone, Debug)]
pub struct ClientData {
    pub x: Vec<f32>, // n·F
    pub y: Vec<u32>,
}

impl ClientData {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }
}

/// A federated dataset: per-client shards + a global balanced test set.
#[derive(Clone, Debug)]
pub struct FederatedData {
    pub f: usize,
    pub classes: usize,
    pub clients: Vec<ClientData>,
    pub test: ClientData,
}

struct FeatureGen {
    protos: Vec<f32>, // classes·subclusters·F
    f: usize,
    classes: usize,
    subclusters: usize,
    noise: f32,
}

impl FeatureGen {
    fn new(p: &DatasetProfile, f: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256pp::new(seed ^ 0xda7a_5e3d);
        let mut protos = vec![0.0f32; p.classes * p.subclusters * f];
        rng.fill_gaussian_f32(&mut protos, 0.0, 1.0);
        // Normalize each prototype to `radius` (hypersphere).
        for chunk in protos.chunks_mut(f) {
            let norm: f32 = chunk.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-6);
            for v in chunk.iter_mut() {
                *v *= p.radius / norm;
            }
        }
        Self {
            protos,
            f,
            classes: p.classes,
            subclusters: p.subclusters,
            noise: p.noise,
        }
    }

    fn sample(&self, class: usize, rng: &mut Xoshiro256pp, out: &mut [f32]) {
        debug_assert!(class < self.classes);
        let sub = rng.below(self.subclusters as u64) as usize;
        let base = (class * self.subclusters + sub) * self.f;
        for (j, o) in out.iter_mut().enumerate() {
            *o = self.protos[base + j] + self.noise * rng.next_gaussian() as f32;
        }
    }
}

/// Generate the full federated dataset.
///
/// Label distribution per client ~ Dirichlet(alpha·1_C) (paper §4); the test
/// set is balanced across classes.
pub fn generate(
    p: &DatasetProfile,
    arch: ArchConfig,
    n_clients: usize,
    samples_per_client: usize,
    test_samples: usize,
    dirichlet_alpha: f64,
    seed: u64,
) -> FederatedData {
    assert_eq!(arch.c, p.classes, "arch class count must match dataset");
    let gen = FeatureGen::new(p, arch.f, seed);
    let mut rng = Xoshiro256pp::new(seed);

    let mut clients = Vec::with_capacity(n_clients);
    for k in 0..n_clients {
        let mut crng = rng.fork(k as u64 + 1);
        let pk = crng.next_dirichlet(dirichlet_alpha, p.classes);
        // CDF sampling of labels.
        let mut cdf = vec![0.0f64; p.classes];
        let mut acc = 0.0;
        for (c, v) in pk.iter().enumerate() {
            acc += v;
            cdf[c] = acc;
        }
        let mut x = vec![0.0f32; samples_per_client * arch.f];
        let mut y = Vec::with_capacity(samples_per_client);
        for i in 0..samples_per_client {
            let u = crng.next_f64() * acc;
            let class = cdf.partition_point(|&c| c < u).min(p.classes - 1);
            y.push(class as u32);
            gen.sample(class, &mut crng, &mut x[i * arch.f..(i + 1) * arch.f]);
        }
        clients.push(ClientData { x, y });
    }

    // Balanced test set.
    let mut trng = rng.fork(0xdead);
    let mut tx = vec![0.0f32; test_samples * arch.f];
    let mut ty = Vec::with_capacity(test_samples);
    for i in 0..test_samples {
        let class = i % p.classes;
        ty.push(class as u32);
        gen.sample(class, &mut trng, &mut tx[i * arch.f..(i + 1) * arch.f]);
    }
    FederatedData {
        f: arch.f,
        classes: p.classes,
        clients,
        test: ClientData { x: tx, y: ty },
    }
}

/// Empirical class-distribution concentration C_p: mean over clients of the
/// fraction of classes present (paper: Dir(10) ⇒ ≈1.0, Dir(0.1) ⇒ ≈0.2).
pub fn class_presence(data: &FederatedData) -> f64 {
    let mut total = 0.0;
    for c in &data.clients {
        let mut seen = vec![false; data.classes];
        for &y in &c.y {
            seen[y as usize] = true;
        }
        total += seen.iter().filter(|&&s| s).count() as f64 / data.classes as f64;
    }
    total / data.clients.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arch(c: usize) -> ArchConfig {
        ArchConfig::new(32, c, 8, 5)
    }

    #[test]
    fn all_profiles_cover_paper_datasets() {
        let names: Vec<&str> = profiles().iter().map(|p| p.name).collect();
        assert_eq!(
            names,
            vec!["cifar10", "cifar100", "svhn", "emnist", "fmnist", "eurosat", "food101", "cars196"]
        );
        let classes: Vec<usize> = profiles().iter().map(|p| p.classes).collect();
        assert_eq!(classes, vec![10, 100, 10, 49, 10, 10, 101, 196]);
    }

    #[test]
    fn iid_vs_noniid_class_presence() {
        let p = profile("cifar10").unwrap();
        let iid = generate(&p, arch(10), 20, 200, 100, 10.0, 1);
        let noniid = generate(&p, arch(10), 20, 200, 100, 0.1, 1);
        let cp_iid = class_presence(&iid);
        let cp_non = class_presence(&noniid);
        assert!(cp_iid > 0.9, "C_p IID = {cp_iid}");
        assert!(cp_non < 0.5, "C_p non-IID = {cp_non}");
    }

    #[test]
    fn deterministic_generation() {
        let p = profile("eurosat").unwrap();
        let a = generate(&p, arch(10), 3, 50, 40, 10.0, 7);
        let b = generate(&p, arch(10), 3, 50, 40, 10.0, 7);
        assert_eq!(a.clients[0].x, b.clients[0].x);
        assert_eq!(a.test.y, b.test.y);
    }

    #[test]
    fn test_set_balanced() {
        let p = profile("cifar10").unwrap();
        let data = generate(&p, arch(10), 2, 10, 200, 10.0, 3);
        let mut counts = vec![0; 10];
        for &y in &data.test.y {
            counts[y as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 20), "{counts:?}");
    }

    #[test]
    fn features_are_class_informative() {
        // Nearest-prototype classification on clean features ≫ chance.
        let p = profile("cifar10").unwrap();
        let a = arch(10);
        let data = generate(&p, a, 1, 400, 0, 10.0, 5);
        let c = &data.clients[0];
        // Class means as prototypes.
        let mut means = vec![0.0f32; 10 * a.f];
        let mut counts = vec![0usize; 10];
        for (i, &y) in c.y.iter().enumerate() {
            counts[y as usize] += 1;
            for j in 0..a.f {
                means[y as usize * a.f + j] += c.x[i * a.f + j];
            }
        }
        for y in 0..10 {
            for j in 0..a.f {
                means[y * a.f + j] /= counts[y].max(1) as f32;
            }
        }
        let mut correct = 0;
        for (i, &y) in c.y.iter().enumerate() {
            let mut best = 0;
            let mut best_d = f32::INFINITY;
            for k in 0..10 {
                let mut dd = 0.0;
                for j in 0..a.f {
                    let diff = c.x[i * a.f + j] - means[k * a.f + j];
                    dd += diff * diff;
                }
                if dd < best_d {
                    best_d = dd;
                    best = k;
                }
            }
            if best == y as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / c.y.len() as f64;
        assert!(acc > 0.6, "nearest-mean acc = {acc}");
    }
}
