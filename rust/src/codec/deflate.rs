//! DEFLATE (RFC 1951) and zlib (RFC 1950), from scratch.
//!
//! This is the `Ψ(·)` lossless-compression stage of DeltaMask (§3.2): the
//! binary-fuse fingerprint array is packed into a grayscale image whose
//! pixel stream is DEFLATE-compressed, "taking advantage of possible
//! non-uniform distributions of entries across the fingerprint locations".
//!
//! Compressor: greedy LZ77 with one-step lazy matching over a 32 KiB window
//! (hash chains on 3-byte prefixes), then per-block choice between stored /
//! fixed-Huffman / dynamic-Huffman, picking the cheapest. Decompressor
//! handles all three block types with table-driven canonical Huffman
//! decoding. Round-trips and cross-checks against `flate2` live in the
//! tests.

use super::bitio::{BitReader, BitWriter};
use super::crc::adler32;

const WINDOW: usize = 32 * 1024;
const MIN_MATCH: usize = 3;
const MAX_MATCH: usize = 258;
const HASH_BITS: u32 = 15;
const HASH_SIZE: usize = 1 << HASH_BITS;
const MAX_CHAIN: usize = 128;
const BLOCK_MAX: usize = 128 * 1024; // tokens per block before flushing

// Fast match-finder tuning (`deflate_fast`): a 4-byte hash keeps 3-byte
// false positives out of the chains entirely, shorter chains and an
// early-exit "nice length" bound the search, and lazy evaluation is skipped
// once a match is already long. Streams differ from `deflate` but remain
// valid RFC 1951 — the fast path only ever runs behind the PngFast payload
// backend tag, so baseline wire bytes are untouched.
const MIN_MATCH_FAST: usize = 4; // 4-byte hash cannot see 3-byte matches
const MAX_CHAIN_FAST: usize = 32;
const NICE_LEN_FAST: usize = 64; // stop searching once a match is this long
const LAZY_MAX_FAST: usize = 32; // no lazy evaluation above this length
const INSERT_MAX_FAST: usize = 32; // cap hash insertions inside long matches

// Length code table (RFC 1951 §3.2.5): code, extra bits, base length.
const LEN_BASE: [u16; 29] = [
    3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51, 59, 67, 83, 99, 115,
    131, 163, 195, 227, 258,
];
const LEN_EXTRA: [u8; 29] = [
    0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4, 5, 5, 5, 5, 0,
];
const DIST_BASE: [u16; 30] = [
    1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385, 513, 769, 1025, 1537,
    2049, 3073, 4097, 6145, 8193, 12289, 16385, 24577,
];
const DIST_EXTRA: [u8; 30] = [
    0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10, 10, 11, 11, 12, 12,
    13, 13,
];
/// Order in which code-length code lengths are stored (RFC 1951 §3.2.7).
const CLC_ORDER: [usize; 19] = [
    16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15,
];

#[inline]
fn length_code(len: usize) -> usize {
    debug_assert!((MIN_MATCH..=MAX_MATCH).contains(&len));
    // Binary search over LEN_BASE (29 entries — a linear scan is fine too,
    // but this is on the encode hot path).
    let mut lo = 0usize;
    let mut hi = 28usize;
    while lo < hi {
        let mid = (lo + hi + 1) / 2;
        if LEN_BASE[mid] as usize <= len {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    // Length 255+3=258 belongs to code 285 (index 28), but lengths just
    // below the next base stay in the lower bucket automatically.
    if lo < 28 && (LEN_BASE[lo + 1] as usize) <= len {
        lo + 1
    } else {
        lo
    }
}

#[inline]
fn dist_code(dist: usize) -> usize {
    debug_assert!((1..=WINDOW).contains(&dist));
    let mut lo = 0usize;
    let mut hi = 29usize;
    while lo < hi {
        let mid = (lo + hi + 1) / 2;
        if DIST_BASE[mid] as usize <= dist {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    lo
}

/// Reverse the low `n` bits of `code` (Huffman codes are emitted MSB-first
/// into the LSB-first stream).
#[inline]
fn reverse_bits(code: u32, n: u32) -> u32 {
    let mut c = code;
    let mut out = 0u32;
    for _ in 0..n {
        out = (out << 1) | (c & 1);
        c >>= 1;
    }
    out
}

// ---------------------------------------------------------------------------
// Canonical Huffman construction (encode side)
// ---------------------------------------------------------------------------

/// Compute length-limited Huffman code lengths for `freq` (max length 15)
/// using the package-merge-free heuristic: build a true Huffman tree, and if
/// any length exceeds the limit, flatten by incrementing shallower codes
/// (the classic zlib `bl_count` adjustment).
fn huffman_code_lengths(freq: &[u64], max_len: u32) -> Vec<u8> {
    let n = freq.len();
    let mut lens = vec![0u8; n];
    let active: Vec<usize> = (0..n).filter(|&i| freq[i] > 0).collect();
    match active.len() {
        0 => return lens,
        1 => {
            lens[active[0]] = 1;
            return lens;
        }
        _ => {}
    }

    // Heap-based Huffman tree over (weight, node). Parent pointers give depths.
    #[derive(Eq, PartialEq)]
    struct Item(u64, usize); // (weight, node id)
    impl Ord for Item {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            other.0.cmp(&self.0).then(other.1.cmp(&self.1)) // min-heap
        }
    }
    impl PartialOrd for Item {
        fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }

    let mut parent = vec![usize::MAX; active.len() * 2];
    let mut heap = std::collections::BinaryHeap::new();
    for (node, &sym) in active.iter().enumerate() {
        heap.push(Item(freq[sym], node));
    }
    let mut next = active.len();
    while heap.len() > 1 {
        let a = heap.pop().unwrap();
        let b = heap.pop().unwrap();
        parent[a.1] = next;
        parent[b.1] = next;
        heap.push(Item(a.0 + b.0, next));
        next += 1;
    }

    // Depth of each leaf.
    let mut depth = vec![0u32; next];
    for node in (0..next - 1).rev() {
        depth[node] = depth[parent[node]] + 1;
    }
    for (node, &sym) in active.iter().enumerate() {
        lens[sym] = depth[node].max(1) as u8;
    }

    // Enforce the length limit with a Kraft repair: clamp, then while the
    // Kraft sum exceeds 1, deepen the deepest non-max symbol (each bump of
    // a symbol at depth l < max reduces the sum by 2^-(l+1)). Canonical
    // assignment tolerates the slight under-subscription this can leave.
    let max = max_len as u8;
    for l in lens.iter_mut() {
        if *l > max {
            *l = max;
        }
    }
    let unit = 1u64 << max_len; // Kraft budget scaled by 2^max
    loop {
        let kraft: u64 = lens
            .iter()
            .filter(|&&l| l > 0)
            .map(|&l| unit >> l)
            .sum();
        if kraft <= unit {
            break;
        }
        // Deepest symbol strictly below the limit (prefer high-frequency
        // preservation by scanning for the *least* frequent candidate).
        let mut pick: Option<usize> = None;
        for &sym in &active {
            if lens[sym] < max {
                pick = match pick {
                    Some(p)
                        if (lens[p], std::cmp::Reverse(freq[p]))
                            >= (lens[sym], std::cmp::Reverse(freq[sym])) =>
                    {
                        Some(p)
                    }
                    _ => Some(sym),
                };
            }
        }
        let Some(p) = pick else {
            unreachable!("length limit infeasible: more symbols than 2^max")
        };
        lens[p] += 1;
    }
    lens
}

/// Canonical code assignment from lengths (RFC 1951 §3.2.2). Returns
/// per-symbol (code, len) with code bits already reversed for LSB-first
/// emission.
fn canonical_codes(lens: &[u8]) -> Vec<(u32, u8)> {
    let max_len = lens.iter().cloned().max().unwrap_or(0) as usize;
    let mut bl_count = vec![0u32; max_len + 1];
    for &l in lens {
        if l > 0 {
            bl_count[l as usize] += 1;
        }
    }
    let mut next_code = vec![0u32; max_len + 2];
    let mut code = 0u32;
    for bits in 1..=max_len {
        code = (code + bl_count[bits - 1]) << 1;
        next_code[bits] = code;
    }
    lens.iter()
        .map(|&l| {
            if l == 0 {
                (0, 0)
            } else {
                let c = next_code[l as usize];
                next_code[l as usize] += 1;
                (reverse_bits(c, l as u32), l)
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Huffman decoding tables (decode side)
// ---------------------------------------------------------------------------

/// Flat single-level decode table: index by the next `max_len` bits
/// (LSB-first), yields (symbol, length). 15-bit max ⇒ ≤ 32768 entries.
struct DecodeTable {
    lookup: Vec<u16>, // (len << 12) | symbol  — symbols < 4096, len <= 15
    max_len: u32,
}

impl DecodeTable {
    fn build(lens: &[u8]) -> Result<Self, String> {
        let max_len = lens.iter().cloned().max().unwrap_or(0) as u32;
        if max_len == 0 {
            return Ok(Self {
                lookup: vec![0],
                max_len: 0,
            });
        }
        if max_len > 15 {
            return Err("code length > 15".into());
        }
        let codes = canonical_codes(lens);
        let mut lookup = vec![u16::MAX; 1usize << max_len];
        for (sym, &(code, len)) in codes.iter().enumerate() {
            if len == 0 {
                continue;
            }
            // `code` is already bit-reversed; fill every table slot whose low
            // `len` bits equal it.
            let step = 1usize << len;
            let mut idx = code as usize;
            while idx < lookup.len() {
                if lookup[idx] != u16::MAX {
                    return Err("over-subscribed Huffman code".into());
                }
                lookup[idx] = ((len as u16) << 12) | sym as u16;
                idx += step;
            }
        }
        Ok(Self { lookup, max_len })
    }

    #[inline]
    fn decode(&self, reader: &mut BitReader) -> Result<u16, String> {
        if self.max_len == 0 {
            return Err("decode from empty table".into());
        }
        let peek = reader.peek_bits(self.max_len);
        let entry = self.lookup[peek as usize];
        if entry == u16::MAX {
            return Err("invalid Huffman code".into());
        }
        let len = (entry >> 12) as u32;
        reader.consume(len);
        Ok(entry & 0x0fff)
    }
}

// ---------------------------------------------------------------------------
// LZ77 tokenization
// ---------------------------------------------------------------------------

enum Token {
    Literal(u8),
    Match { len: u16, dist: u16 },
}

struct Lz77 {
    head: Vec<i32>,
    prev: Vec<i32>,
}

impl Lz77 {
    fn new(n: usize) -> Self {
        Self {
            head: vec![-1; HASH_SIZE],
            prev: vec![-1; n],
        }
    }

    #[inline]
    fn hash(data: &[u8], i: usize) -> usize {
        let h = (data[i] as u32) | ((data[i + 1] as u32) << 8) | ((data[i + 2] as u32) << 16);
        ((h.wrapping_mul(0x9e37_79b1)) >> (32 - HASH_BITS)) as usize
    }

    #[inline]
    fn insert(&mut self, data: &[u8], i: usize) {
        if i + MIN_MATCH <= data.len() {
            let h = Self::hash(data, i);
            self.prev[i] = self.head[h];
            self.head[h] = i as i32;
        }
    }

    /// Longest match at `pos` within the window; returns (len, dist).
    fn best_match(&self, data: &[u8], pos: usize) -> (usize, usize) {
        if pos + MIN_MATCH > data.len() {
            return (0, 0);
        }
        let max_len = (data.len() - pos).min(MAX_MATCH);
        let mut best_len = MIN_MATCH - 1;
        let mut best_dist = 0usize;
        let mut cand = self.head[Self::hash(data, pos)];
        let min_pos = pos.saturating_sub(WINDOW) as i32;
        let mut chain = 0usize;
        while cand >= min_pos && cand >= 0 && chain < MAX_CHAIN {
            let c = cand as usize;
            if c < pos {
                // Quick reject on the byte that would extend the best match.
                if pos + best_len < data.len()
                    && data[c + best_len] == data[pos + best_len]
                {
                    let mut l = 0usize;
                    while l < max_len && data[c + l] == data[pos + l] {
                        l += 1;
                    }
                    if l > best_len {
                        best_len = l;
                        best_dist = pos - c;
                        if l >= max_len {
                            break;
                        }
                    }
                }
            }
            cand = self.prev[cand as usize];
            chain += 1;
        }
        if best_len >= MIN_MATCH {
            (best_len, best_dist)
        } else {
            (0, 0)
        }
    }
}

/// Fast hash-chain match finder: 4-byte hash, capped chains, early exit.
struct Lz77Fast {
    head: Vec<i32>,
    prev: Vec<i32>,
}

impl Lz77Fast {
    fn new(n: usize) -> Self {
        Self {
            head: vec![-1; HASH_SIZE],
            prev: vec![-1; n],
        }
    }

    #[inline]
    fn hash(data: &[u8], i: usize) -> usize {
        let h = u32::from_le_bytes([data[i], data[i + 1], data[i + 2], data[i + 3]]);
        ((h.wrapping_mul(0x9e37_79b1)) >> (32 - HASH_BITS)) as usize
    }

    #[inline]
    fn insert(&mut self, data: &[u8], i: usize) {
        if i + MIN_MATCH_FAST <= data.len() {
            let h = Self::hash(data, i);
            self.prev[i] = self.head[h];
            self.head[h] = i as i32;
        }
    }

    /// Longest match at `pos` within the window; returns (len, dist).
    /// Only finds matches of length ≥ [`MIN_MATCH_FAST`]; shorter tail
    /// matches are emitted as literals (the fast-level trade).
    fn best_match(&self, data: &[u8], pos: usize) -> (usize, usize) {
        if pos + MIN_MATCH_FAST > data.len() {
            return (0, 0);
        }
        let max_len = (data.len() - pos).min(MAX_MATCH);
        let mut best_len = MIN_MATCH_FAST - 1;
        let mut best_dist = 0usize;
        let mut cand = self.head[Self::hash(data, pos)];
        let min_pos = pos.saturating_sub(WINDOW) as i32;
        let mut chain = 0usize;
        while cand >= min_pos && cand >= 0 && chain < MAX_CHAIN_FAST {
            let c = cand as usize;
            if c < pos {
                if pos + best_len < data.len()
                    && data[c + best_len] == data[pos + best_len]
                {
                    let mut l = 0usize;
                    while l < max_len && data[c + l] == data[pos + l] {
                        l += 1;
                    }
                    if l > best_len {
                        best_len = l;
                        best_dist = pos - c;
                        if l >= max_len || l >= NICE_LEN_FAST {
                            break;
                        }
                    }
                }
            }
            cand = self.prev[cand as usize];
            chain += 1;
        }
        if best_len >= MIN_MATCH_FAST {
            (best_len, best_dist)
        } else {
            (0, 0)
        }
    }
}

// ---------------------------------------------------------------------------
// Block emission
// ---------------------------------------------------------------------------

fn fixed_litlen_lens() -> Vec<u8> {
    let mut lens = vec![0u8; 288];
    for (i, l) in lens.iter_mut().enumerate() {
        *l = match i {
            0..=143 => 8,
            144..=255 => 9,
            256..=279 => 7,
            _ => 8,
        };
    }
    lens
}

fn fixed_dist_lens() -> Vec<u8> {
    vec![5u8; 30]
}

struct BlockStats {
    lit_freq: [u64; 286],
    dist_freq: [u64; 30],
}

impl BlockStats {
    fn new() -> Self {
        Self {
            lit_freq: [0; 286],
            dist_freq: [0; 30],
        }
    }

    fn tally(&mut self, tok: &Token) {
        match tok {
            Token::Literal(b) => self.lit_freq[*b as usize] += 1,
            Token::Match { len, dist } => {
                self.lit_freq[257 + length_code(*len as usize)] += 1;
                self.dist_freq[dist_code(*dist as usize)] += 1;
            }
        }
    }
}

fn emit_tokens(
    w: &mut BitWriter,
    tokens: &[Token],
    lit_codes: &[(u32, u8)],
    dist_codes: &[(u32, u8)],
) {
    let mut i = 0usize;
    while i < tokens.len() {
        match &tokens[i] {
            Token::Literal(_) => {
                // Batched literal fast path: scan the literal run, then fuse
                // pairs of codes (each ≤ 15 bits) into single ≤ 30-bit
                // buffer insertions — half the accumulator traffic on
                // literal-heavy (fingerprint-like) payloads. LSB-first
                // concatenation is associative, so the bitstream is
                // identical to one-code-at-a-time emission.
                let mut end = i + 1;
                while end < tokens.len() && matches!(tokens[end], Token::Literal(_)) {
                    end += 1;
                }
                while i + 1 < end {
                    let (Token::Literal(a), Token::Literal(b)) = (&tokens[i], &tokens[i + 1])
                    else {
                        unreachable!()
                    };
                    let (c0, l0) = lit_codes[*a as usize];
                    let (c1, l1) = lit_codes[*b as usize];
                    w.write_bits(c0 | (c1 << l0), (l0 + l1) as u32);
                    i += 2;
                }
                if i < end {
                    let Token::Literal(b) = &tokens[i] else { unreachable!() };
                    let (c, l) = lit_codes[*b as usize];
                    w.write_bits(c, l as u32);
                    i = end;
                }
            }
            Token::Match { len, dist } => {
                let lc = length_code(*len as usize);
                let (c, l) = lit_codes[257 + lc];
                w.write_bits(c, l as u32);
                let extra = LEN_EXTRA[lc] as u32;
                if extra > 0 {
                    w.write_bits((*len as u32) - LEN_BASE[lc] as u32, extra);
                }
                let dc = dist_code(*dist as usize);
                let (c, l) = dist_codes[dc];
                w.write_bits(c, l as u32);
                let extra = DIST_EXTRA[dc] as u32;
                if extra > 0 {
                    w.write_bits((*dist as u32) - DIST_BASE[dc] as u32, extra);
                }
                i += 1;
            }
        }
    }
    // End-of-block.
    let (c, l) = lit_codes[256];
    w.write_bits(c, l as u32);
}

/// Cost in bits of coding `stats` under the given code lengths.
fn token_cost(stats: &BlockStats, lit_lens: &[u8], dist_lens: &[u8]) -> u64 {
    let mut bits = 0u64;
    for (sym, &f) in stats.lit_freq.iter().enumerate() {
        if f == 0 {
            continue;
        }
        bits += f * lit_lens[sym] as u64;
        if sym > 256 {
            bits += f * LEN_EXTRA[sym - 257] as u64;
        }
    }
    for (sym, &f) in stats.dist_freq.iter().enumerate() {
        if f > 0 {
            bits += f * (dist_lens[sym] as u64 + DIST_EXTRA[sym] as u64);
        }
    }
    bits + lit_lens[256] as u64 // EOB
}

/// RLE-encode the lit+dist code-length sequence with symbols 16/17/18
/// (RFC 1951 §3.2.7). Returns (symbols, extra bits) pairs.
fn encode_code_lengths(all_lens: &[u8]) -> Vec<(u8, u8, u8)> {
    // (symbol, extra_value, extra_bits)
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < all_lens.len() {
        let cur = all_lens[i];
        let mut run = 1usize;
        while i + run < all_lens.len() && all_lens[i + run] == cur {
            run += 1;
        }
        if cur == 0 {
            let mut r = run;
            while r >= 11 {
                let take = r.min(138);
                out.push((18, (take - 11) as u8, 7));
                r -= take;
            }
            if r >= 3 {
                out.push((17, (r - 3) as u8, 3));
                r = 0;
            }
            for _ in 0..r {
                out.push((0, 0, 0));
            }
        } else {
            out.push((cur, 0, 0));
            let mut r = run - 1;
            while r >= 3 {
                let take = r.min(6);
                out.push((16, (take - 3) as u8, 2));
                r -= take;
            }
            for _ in 0..r {
                out.push((cur, 0, 0));
            }
        }
        i += run;
    }
    out
}

fn write_dynamic_header(w: &mut BitWriter, lit_lens: &[u8], dist_lens: &[u8]) {
    // HLIT/HDIST trimming.
    let hlit = {
        let mut n = 286;
        while n > 257 && lit_lens[n - 1] == 0 {
            n -= 1;
        }
        n
    };
    let hdist = {
        let mut n = 30;
        while n > 1 && dist_lens[n - 1] == 0 {
            n -= 1;
        }
        n
    };
    let mut all = Vec::with_capacity(hlit + hdist);
    all.extend_from_slice(&lit_lens[..hlit]);
    all.extend_from_slice(&dist_lens[..hdist]);
    let rle = encode_code_lengths(&all);

    let mut clc_freq = [0u64; 19];
    for &(sym, _, _) in &rle {
        clc_freq[sym as usize] += 1;
    }
    let clc_lens = huffman_code_lengths(&clc_freq, 7);
    let clc_codes = canonical_codes(&clc_lens);

    let hclen = {
        let mut n = 19;
        while n > 4 && clc_lens[CLC_ORDER[n - 1]] == 0 {
            n -= 1;
        }
        n
    };

    w.write_bits((hlit - 257) as u32, 5);
    w.write_bits((hdist - 1) as u32, 5);
    w.write_bits((hclen - 4) as u32, 4);
    for &ord in CLC_ORDER.iter().take(hclen) {
        w.write_bits(clc_lens[ord] as u32, 3);
    }
    for &(sym, extra, ebits) in &rle {
        let (c, l) = clc_codes[sym as usize];
        w.write_bits(c, l as u32);
        if ebits > 0 {
            w.write_bits(extra as u32, ebits as u32);
        }
    }
}

/// Cost in bits of the dynamic header for these code lengths.
fn dynamic_header_cost(lit_lens: &[u8], dist_lens: &[u8]) -> u64 {
    let hlit = {
        let mut n = 286;
        while n > 257 && lit_lens[n - 1] == 0 {
            n -= 1;
        }
        n
    };
    let hdist = {
        let mut n = 30;
        while n > 1 && dist_lens[n - 1] == 0 {
            n -= 1;
        }
        n
    };
    let mut all = Vec::with_capacity(hlit + hdist);
    all.extend_from_slice(&lit_lens[..hlit]);
    all.extend_from_slice(&dist_lens[..hdist]);
    let rle = encode_code_lengths(&all);
    let mut clc_freq = [0u64; 19];
    for &(sym, _, _) in &rle {
        clc_freq[sym as usize] += 1;
    }
    let clc_lens = huffman_code_lengths(&clc_freq, 7);
    let hclen = {
        let mut n = 19;
        while n > 4 && clc_lens[CLC_ORDER[n - 1]] == 0 {
            n -= 1;
        }
        n
    };
    let mut bits = 5 + 5 + 4 + 3 * hclen as u64;
    for &(sym, _, ebits) in &rle {
        bits += clc_lens[sym as usize] as u64 + ebits as u64;
    }
    bits
}

// ---------------------------------------------------------------------------
// Public API
// ---------------------------------------------------------------------------

/// Raw DEFLATE compression.
pub fn deflate(data: &[u8]) -> Vec<u8> {
    let mut w = BitWriter::new();
    if data.is_empty() {
        // Single empty fixed-Huffman block: BFINAL=1, BTYPE=01, EOB.
        w.write_bits(1, 1);
        w.write_bits(1, 2);
        let codes = canonical_codes(&fixed_litlen_lens());
        let (c, l) = codes[256];
        w.write_bits(c, l as u32);
        return w.finish();
    }

    let mut lz = Lz77::new(data.len());
    let mut pos = 0usize;
    let mut tokens: Vec<Token> = Vec::with_capacity(BLOCK_MAX);
    let mut stats = BlockStats::new();
    let mut block_start = 0usize;

    while pos < data.len() {
        let (len, dist) = lz.best_match(data, pos);
        let tok = if len >= MIN_MATCH {
            // One-step lazy matching: prefer a longer match at pos+1.
            let (len2, _) = if pos + 1 < data.len() {
                lz.best_match(data, pos + 1)
            } else {
                (0, 0)
            };
            if len2 > len + 1 {
                lz.insert(data, pos);
                pos += 1;
                Token::Literal(data[pos - 1])
            } else {
                for i in 0..len {
                    lz.insert(data, pos + i);
                }
                pos += len;
                Token::Match {
                    len: len as u16,
                    dist: dist as u16,
                }
            }
        } else {
            lz.insert(data, pos);
            pos += 1;
            Token::Literal(data[pos - 1])
        };
        stats.tally(&tok);
        tokens.push(tok);

        if tokens.len() >= BLOCK_MAX || pos >= data.len() {
            let is_final = pos >= data.len();
            flush_block(
                &mut w,
                &tokens,
                &stats,
                &data[block_start..pos],
                is_final,
            );
            tokens.clear();
            stats = BlockStats::new();
            block_start = pos;
        }
    }
    w.finish()
}

/// Raw DEFLATE compression, fast profile: [`Lz77Fast`] match finder
/// (4-byte hash, short chains, early exit), lazy matching only for short
/// matches, and capped hash insertions inside long matches. Emits a valid
/// RFC 1951 stream that any inflater (including [`inflate`]) decodes, but
/// the bytes differ from [`deflate`] — callers must gate it behind a wire
/// version tag. Block-format selection (`flush_block`) is shared with the
/// baseline, so only the tokenization differs.
pub fn deflate_fast(data: &[u8]) -> Vec<u8> {
    let mut w = BitWriter::new();
    if data.is_empty() {
        w.write_bits(1, 1);
        w.write_bits(1, 2);
        let codes = canonical_codes(&fixed_litlen_lens());
        let (c, l) = codes[256];
        w.write_bits(c, l as u32);
        return w.finish();
    }

    let mut lz = Lz77Fast::new(data.len());
    let mut pos = 0usize;
    let mut tokens: Vec<Token> = Vec::with_capacity(BLOCK_MAX);
    let mut stats = BlockStats::new();
    let mut block_start = 0usize;

    while pos < data.len() {
        let (len, dist) = lz.best_match(data, pos);
        let tok = if len >= MIN_MATCH_FAST {
            // Lazy matching only pays when the current match is short; long
            // matches are taken greedily.
            let len2 = if len < LAZY_MAX_FAST && pos + 1 < data.len() {
                lz.best_match(data, pos + 1).0
            } else {
                0
            };
            if len2 > len + 1 {
                lz.insert(data, pos);
                pos += 1;
                Token::Literal(data[pos - 1])
            } else {
                // Inserting every covered position into the chains is most
                // of the cost of long matches; cap it — positions inside a
                // long match are poor future match starts anyway.
                for i in 0..len.min(INSERT_MAX_FAST) {
                    lz.insert(data, pos + i);
                }
                pos += len;
                Token::Match {
                    len: len as u16,
                    dist: dist as u16,
                }
            }
        } else {
            lz.insert(data, pos);
            pos += 1;
            Token::Literal(data[pos - 1])
        };
        stats.tally(&tok);
        tokens.push(tok);

        if tokens.len() >= BLOCK_MAX || pos >= data.len() {
            let is_final = pos >= data.len();
            flush_block(
                &mut w,
                &tokens,
                &stats,
                &data[block_start..pos],
                is_final,
            );
            tokens.clear();
            stats = BlockStats::new();
            block_start = pos;
        }
    }
    w.finish()
}

fn flush_block(
    w: &mut BitWriter,
    tokens: &[Token],
    stats: &BlockStats,
    raw: &[u8],
    is_final: bool,
) {
    // Candidate 1: dynamic Huffman.
    let mut lit_freq = stats.lit_freq;
    lit_freq[256] += 1; // EOB
    let lit_lens = huffman_code_lengths(&lit_freq, 15);
    let mut dist_freq_v = stats.dist_freq.to_vec();
    if dist_freq_v.iter().all(|&f| f == 0) {
        dist_freq_v[0] = 1; // at least one dist code must exist
    }
    let dist_lens = huffman_code_lengths(&dist_freq_v, 15);
    let dyn_cost = dynamic_header_cost(&lit_lens, &dist_lens)
        + token_cost(stats, &lit_lens, &dist_lens);

    // Candidate 2: fixed Huffman.
    let fixed_lit = fixed_litlen_lens();
    let fixed_dist = fixed_dist_lens();
    let fixed_cost = token_cost(stats, &fixed_lit, &fixed_dist);

    // Candidate 3: stored (only meaningful vs. both).
    let stored_cost = 8 * (raw.len() as u64 + 5) + 8; // + alignment slack

    let bfinal = if is_final { 1 } else { 0 };
    if stored_cost < dyn_cost.min(fixed_cost) {
        w.write_bits(bfinal, 1);
        w.write_bits(0, 2);
        w.align_byte();
        let len = raw.len() as u32;
        w.write_bits(len & 0xffff, 16);
        w.write_bits(!len & 0xffff, 16);
        w.write_bytes(raw);
    } else if fixed_cost <= dyn_cost {
        w.write_bits(bfinal, 1);
        w.write_bits(1, 2);
        let lit_codes = canonical_codes(&fixed_lit);
        let dist_codes = canonical_codes(&fixed_dist);
        emit_tokens(w, tokens, &lit_codes, &dist_codes);
    } else {
        w.write_bits(bfinal, 1);
        w.write_bits(2, 2);
        write_dynamic_header(w, &lit_lens, &dist_lens);
        let lit_codes = canonical_codes(&lit_lens);
        let dist_codes = canonical_codes(&dist_lens);
        emit_tokens(w, tokens, &lit_codes, &dist_codes);
    }
}

/// Raw DEFLATE decompression.
pub fn inflate(data: &[u8]) -> Result<Vec<u8>, String> {
    let mut r = BitReader::new(data);
    let mut out: Vec<u8> = Vec::with_capacity(data.len() * 4);
    loop {
        let bfinal = r.read_bits(1);
        let btype = r.read_bits(2);
        match btype {
            0 => {
                r.align_byte();
                let len = r.read_bits(16) as usize;
                let nlen = r.read_bits(16) as usize;
                if len != (!nlen & 0xffff) {
                    return Err("stored block LEN/NLEN mismatch".into());
                }
                let bytes = r.read_bytes(len).ok_or("truncated stored block")?;
                out.extend_from_slice(&bytes);
            }
            1 => {
                let lit = DecodeTable::build(&fixed_litlen_lens())?;
                let dist = DecodeTable::build(&fixed_dist_lens())?;
                inflate_block(&mut r, &lit, &dist, &mut out)?;
            }
            2 => {
                let hlit = r.read_bits(5) as usize + 257;
                let hdist = r.read_bits(5) as usize + 1;
                let hclen = r.read_bits(4) as usize + 4;
                let mut clc_lens = [0u8; 19];
                for &ord in CLC_ORDER.iter().take(hclen) {
                    clc_lens[ord] = r.read_bits(3) as u8;
                }
                let clc = DecodeTable::build(&clc_lens)?;
                let mut all = Vec::with_capacity(hlit + hdist);
                while all.len() < hlit + hdist {
                    let sym = clc.decode(&mut r)?;
                    match sym {
                        0..=15 => all.push(sym as u8),
                        16 => {
                            let prev = *all.last().ok_or("repeat with no previous length")?;
                            let n = 3 + r.read_bits(2) as usize;
                            for _ in 0..n {
                                all.push(prev);
                            }
                        }
                        17 => {
                            let n = 3 + r.read_bits(3) as usize;
                            for _ in 0..n {
                                all.push(0);
                            }
                        }
                        18 => {
                            let n = 11 + r.read_bits(7) as usize;
                            for _ in 0..n {
                                all.push(0);
                            }
                        }
                        _ => return Err("bad code-length symbol".into()),
                    }
                }
                if all.len() != hlit + hdist {
                    return Err("code-length overrun".into());
                }
                let lit = DecodeTable::build(&all[..hlit])?;
                let dist = DecodeTable::build(&all[hlit..])?;
                inflate_block(&mut r, &lit, &dist, &mut out)?;
            }
            _ => return Err("reserved block type".into()),
        }
        if bfinal == 1 {
            return Ok(out);
        }
    }
}

fn inflate_block(
    r: &mut BitReader,
    lit: &DecodeTable,
    dist: &DecodeTable,
    out: &mut Vec<u8>,
) -> Result<(), String> {
    loop {
        let sym = lit.decode(r)?;
        match sym {
            0..=255 => out.push(sym as u8),
            256 => return Ok(()),
            257..=285 => {
                let lc = (sym - 257) as usize;
                let len = LEN_BASE[lc] as usize + r.read_bits(LEN_EXTRA[lc] as u32) as usize;
                let dsym = dist.decode(r)? as usize;
                if dsym >= 30 {
                    return Err("bad distance symbol".into());
                }
                let d = DIST_BASE[dsym] as usize + r.read_bits(DIST_EXTRA[dsym] as u32) as usize;
                if d > out.len() {
                    return Err("distance beyond output".into());
                }
                let start = out.len() - d;
                for i in 0..len {
                    let b = out[start + i];
                    out.push(b);
                }
            }
            _ => return Err("bad literal/length symbol".into()),
        }
    }
}

/// zlib (RFC 1950) container around DEFLATE.
pub fn zlib_compress(data: &[u8]) -> Vec<u8> {
    let mut out = vec![0x78, 0x9c]; // CMF/FLG: 32K window, default level
    out.extend_from_slice(&deflate(data));
    out.extend_from_slice(&adler32(data).to_be_bytes());
    out
}

/// zlib container around [`deflate_fast`]. Same header/trailer as
/// [`zlib_compress`]; only the DEFLATE body bytes differ.
pub fn zlib_compress_fast(data: &[u8]) -> Vec<u8> {
    let mut out = vec![0x78, 0x9c];
    out.extend_from_slice(&deflate_fast(data));
    out.extend_from_slice(&adler32(data).to_be_bytes());
    out
}

pub fn zlib_decompress(data: &[u8]) -> Result<Vec<u8>, String> {
    if data.len() < 6 {
        return Err("zlib stream too short".into());
    }
    let cmf = data[0];
    let flg = data[1];
    if cmf & 0x0f != 8 {
        return Err("unsupported zlib method".into());
    }
    if ((cmf as u16) << 8 | flg as u16) % 31 != 0 {
        return Err("zlib header check failed".into());
    }
    if flg & 0x20 != 0 {
        return Err("preset dictionary unsupported".into());
    }
    let body = &data[2..data.len() - 4];
    let out = inflate(body)?;
    let expect = u32::from_be_bytes(data[data.len() - 4..].try_into().unwrap());
    if adler32(&out) != expect {
        return Err("adler32 mismatch".into());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;
    #[cfg(feature = "flate2")]
    use std::io::{Read, Write};

    fn sample_payloads() -> Vec<Vec<u8>> {
        let mut rng = Xoshiro256pp::new(42);
        let mut out: Vec<Vec<u8>> = vec![
            vec![],
            b"a".to_vec(),
            b"aaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaa".to_vec(),
            b"DeltaMask binary fuse fingerprints ".repeat(50),
            (0..=255u8).collect(),
        ];
        // Uniform random (incompressible — exercises stored blocks).
        out.push((0..10_000).map(|_| rng.next_u64() as u8).collect());
        // Skewed random (exercises dynamic Huffman): geometric-ish bytes.
        out.push(
            (0..50_000)
                .map(|_| {
                    let u = rng.next_f32();
                    (-(1.0 - u).ln() * 8.0) as u8
                })
                .collect(),
        );
        // Long runs + periodic structure (exercises LZ77 matches).
        let mut v = Vec::new();
        for i in 0..2_000u32 {
            v.extend_from_slice(&[(i % 7) as u8; 37]);
        }
        out.push(v);
        // A realistic BFuse8 payload: mostly non-uniform small bytes.
        let keys: Vec<u64> = (0..5_000u64).map(|_| rng.next_u64() % 327_680).collect();
        if let Some(f) = crate::filters::BinaryFuse::<u8, 4>::build(&keys) {
            out.push(f.payload());
        }
        out
    }

    #[test]
    fn roundtrip_own_inflate() {
        for (i, data) in sample_payloads().iter().enumerate() {
            let comp = deflate(data);
            let back = inflate(&comp).unwrap_or_else(|e| panic!("case {i}: {e}"));
            assert_eq!(&back, data, "case {i}");
        }
    }

    #[test]
    fn zlib_roundtrip() {
        for data in sample_payloads() {
            let z = zlib_compress(&data);
            assert_eq!(zlib_decompress(&z).unwrap(), data);
        }
    }

    #[test]
    fn deflate_fast_roundtrips_through_baseline_inflate() {
        // The baseline inflater is the parity oracle for the fast match
        // finder: any stream it reconstructs exactly is valid RFC 1951.
        for (i, data) in sample_payloads().iter().enumerate() {
            let comp = deflate_fast(data);
            let back = inflate(&comp).unwrap_or_else(|e| panic!("case {i}: {e}"));
            assert_eq!(&back, data, "case {i}");
            let z = zlib_compress_fast(data);
            assert_eq!(&zlib_decompress(&z).unwrap(), data, "case {i} (zlib)");
        }
    }

    #[test]
    fn deflate_fast_stays_bounded_and_still_compresses() {
        // Stored-block fallback bounds the worst case exactly like the
        // baseline...
        let mut rng = Xoshiro256pp::new(9);
        let data: Vec<u8> = (0..65_536).map(|_| rng.next_u64() as u8).collect();
        let comp = deflate_fast(&data);
        assert!(comp.len() <= data.len() + 64, "len={}", comp.len());
        // ...and the 4-byte finder still sees the matches that matter on
        // run-heavy data (within 1.5× of the baseline emitter there).
        let mut v = Vec::new();
        for i in 0..2_000u32 {
            v.extend_from_slice(&[(i % 7) as u8; 37]);
        }
        let fast = deflate_fast(&v);
        let base = deflate(&v);
        assert!(
            fast.len() <= base.len() * 3 / 2 + 64,
            "fast={} base={}",
            fast.len(),
            base.len()
        );
    }

    #[test]
    fn deflate_fast_multi_block_boundary() {
        let mut rng = Xoshiro256pp::new(17);
        let data: Vec<u8> = (0..300_000)
            .map(|_| (rng.next_f32() * 4.0) as u8)
            .collect();
        let comp = deflate_fast(&data);
        assert_eq!(inflate(&comp).unwrap(), data);
    }

    // Cross-validation against an independent DEFLATE implementation;
    // needs the optional `flate2` feature (offline default builds skip it).
    #[cfg(feature = "flate2")]
    #[test]
    fn our_deflate_readable_by_flate2() {
        for (i, data) in sample_payloads().iter().enumerate() {
            let z = zlib_compress(data);
            let mut dec = flate2::read::ZlibDecoder::new(&z[..]);
            let mut back = Vec::new();
            dec.read_to_end(&mut back)
                .unwrap_or_else(|e| panic!("case {i}: flate2 rejected our stream: {e}"));
            assert_eq!(&back, data, "case {i}");
        }
    }

    #[cfg(feature = "flate2")]
    #[test]
    fn our_inflate_reads_flate2_output() {
        for (i, data) in sample_payloads().iter().enumerate() {
            let mut enc =
                flate2::write::ZlibEncoder::new(Vec::new(), flate2::Compression::best());
            enc.write_all(data).unwrap();
            let z = enc.finish().unwrap();
            let back = zlib_decompress(&z).unwrap_or_else(|e| panic!("case {i}: {e}"));
            assert_eq!(&back, data, "case {i}");
        }
    }

    #[test]
    fn fused_literal_pairs_match_scalar_emission() {
        // The batched literal fast path must produce the exact bitstream of
        // one-code-at-a-time emission (the seed behaviour, inlined here as
        // the oracle).
        let lit_lens = fixed_litlen_lens();
        let dist_lens = fixed_dist_lens();
        let lit_codes = canonical_codes(&lit_lens);
        let dist_codes = canonical_codes(&dist_lens);
        let mut rng = Xoshiro256pp::new(77);
        let tokens: Vec<Token> = (0..999)
            .map(|i| {
                if i % 7 == 3 {
                    Token::Match {
                        len: 3 + (i % 20) as u16,
                        dist: 1 + (i % 30) as u16,
                    }
                } else {
                    Token::Literal(rng.next_u64() as u8)
                }
            })
            .collect();
        let mut fast = BitWriter::new();
        emit_tokens(&mut fast, &tokens, &lit_codes, &dist_codes);
        let mut slow = BitWriter::new();
        for tok in &tokens {
            match tok {
                Token::Literal(b) => {
                    let (c, l) = lit_codes[*b as usize];
                    slow.write_bits(c, l as u32);
                }
                Token::Match { len, dist } => {
                    let lc = length_code(*len as usize);
                    let (c, l) = lit_codes[257 + lc];
                    slow.write_bits(c, l as u32);
                    let extra = LEN_EXTRA[lc] as u32;
                    if extra > 0 {
                        slow.write_bits((*len as u32) - LEN_BASE[lc] as u32, extra);
                    }
                    let dc = dist_code(*dist as usize);
                    let (c, l) = dist_codes[dc];
                    slow.write_bits(c, l as u32);
                    let extra = DIST_EXTRA[dc] as u32;
                    if extra > 0 {
                        slow.write_bits((*dist as u32) - DIST_BASE[dc] as u32, extra);
                    }
                }
            }
        }
        let (c, l) = lit_codes[256];
        slow.write_bits(c, l as u32);
        assert_eq!(fast.finish(), slow.finish());
    }

    #[test]
    fn compresses_skewed_data() {
        // Entropy sanity: a heavily skewed stream must compress well below 1 byte/byte.
        let data: Vec<u8> = (0..100_000)
            .map(|i| if i % 10 == 0 { 1u8 } else { 0u8 })
            .collect();
        let comp = deflate(&data);
        assert!(
            comp.len() < data.len() / 10,
            "ratio {}",
            comp.len() as f64 / data.len() as f64
        );
    }

    #[test]
    fn stored_fallback_for_random_data() {
        let mut rng = Xoshiro256pp::new(9);
        let data: Vec<u8> = (0..65_536).map(|_| rng.next_u64() as u8).collect();
        let comp = deflate(&data);
        // Must not blow up: ≤ input + small block overhead.
        assert!(comp.len() <= data.len() + 64, "len={}", comp.len());
    }

    #[test]
    fn inflate_rejects_garbage() {
        assert!(inflate(&[0x07, 0xff, 0xff, 0x12]).is_err());
        assert!(zlib_decompress(&[0x78, 0x9c, 0, 0, 0, 0, 0]).is_err());
        // Valid header, corrupted adler.
        let mut z = zlib_compress(b"hello world hello world");
        let n = z.len();
        z[n - 1] ^= 0xff;
        assert!(zlib_decompress(&z).is_err());
    }

    #[test]
    fn multi_block_boundary() {
        // Force multiple blocks by exceeding BLOCK_MAX tokens.
        let mut rng = Xoshiro256pp::new(17);
        let data: Vec<u8> = (0..300_000)
            .map(|_| (rng.next_f32() * 4.0) as u8)
            .collect();
        let comp = deflate(&data);
        assert_eq!(inflate(&comp).unwrap(), data);
    }
}
