//! **Design-choice ablation** (DESIGN.md §4 extension) — isolates each
//! stage of the DeltaMask codec at ViT-B/32 scale (d = 327,680) across
//! mask-drift levels, answering "what does each §3.2 ingredient buy?":
//!
//! * shared-seed (common-random-numbers) m_k sampling vs independent —
//!   the source of delta sparsity,
//! * grayscale-PNG packing vs raw filter bytes vs the fast-DEFLATE payload
//!   backend (`PayloadBackend::PngFast`),
//! * 4-wise vs 3-wise binary fuse arity,
//! * the `deltamask-pco` numeric-latent index stream (codec 9) vs the
//!   filter + PNG record,
//! * the sibling-paper mask codecs: `maskrn` (codec 10, noise-dictionary
//!   gated flips) and `sparse-rsn` (codec 11, absolute λ-penalized
//!   supermask) on the same fixtures,
//! * top-κ truncation (κ=0.8) vs full Δ.
//!
//!     cargo bench --bench ablation_codec

use deltamask::bench::Table;
use deltamask::compress::{
    self, DeltaMaskCodec, EncodeCtx, FilterKind, PayloadBackend, UpdateCodec,
};
use deltamask::model::sample_mask_seeded;
use deltamask::util::rng::Xoshiro256pp;

fn make_masks(
    d: usize,
    drift: f32,
    shared_seed: bool,
    rng: &mut Xoshiro256pp,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let theta_g: Vec<f32> = (0..d)
        .map(|_| if rng.next_f32() < 0.5 { 0.95 } else { 0.05 })
        .collect();
    let mut theta_k = theta_g.clone();
    for t in theta_k.iter_mut() {
        if rng.next_f32() < drift {
            *t = 1.0 - *t;
        }
    }
    let mut mask_g = Vec::new();
    sample_mask_seeded(&theta_g, 1234, &mut mask_g);
    let mut mask_k = Vec::new();
    let seed_k = if shared_seed { 1234 } else { 777 };
    sample_mask_seeded(&theta_k, seed_k, &mut mask_k);
    (theta_g, theta_k, mask_g, mask_k)
}

fn main() -> anyhow::Result<()> {
    let d = 327_680usize;
    let mut rng = Xoshiro256pp::new(5);

    let mut table = Table::new(
        "DeltaMask codec ablation (d = 327,680)",
        &["drift", "variant", "bpp", "vs baseline"],
    );
    for drift in [0.01f32, 0.03, 0.10] {
        let variants: Vec<(&str, Box<dyn UpdateCodec>, bool, f64)> = vec![
            (
                "baseline (CRN+PNG+4w+κ.8)",
                Box::new(DeltaMaskCodec::default()),
                true,
                0.8,
            ),
            ("no shared seed", Box::new(DeltaMaskCodec::default()), false, 0.8),
            (
                "no PNG stage",
                Box::new(DeltaMaskCodec { payload: PayloadBackend::Raw, ..Default::default() }),
                true,
                0.8,
            ),
            (
                "fast-DEFLATE payload",
                Box::new(DeltaMaskCodec { payload: PayloadBackend::PngFast, ..Default::default() }),
                true,
                0.8,
            ),
            (
                "3-wise fuse",
                Box::new(DeltaMaskCodec::with_filter(FilterKind::BFuse8Arity3)),
                true,
                0.8,
            ),
            (
                "pco index stream (codec 9)",
                compress::by_name("deltamask-pco").expect("registry has deltamask-pco"),
                true,
                0.8,
            ),
            (
                "maskrn noise gate (codec 10)",
                compress::by_name("maskrn").expect("registry has maskrn"),
                true,
                0.8,
            ),
            (
                "sparse-rsn supermask (codec 11)",
                compress::by_name("sparse-rsn").expect("registry has sparse-rsn"),
                true,
                0.8,
            ),
            ("κ = 1.0 (no top-κ)", Box::new(DeltaMaskCodec::default()), true, 1.0),
        ];
        let mut baseline_bpp = 0.0f64;
        for (label, codec, shared, kappa) in variants {
            let (tg, tk, mg, mk) = make_masks(d, drift, shared, &mut rng);
            let ctx = EncodeCtx {
                d,
                theta_k: &tk,
                theta_g: &tg,
                mask_k: &mk,
                mask_g: &mg,
                s_k: &[],
                s_g: &[],
                kappa,
                seed: 42,
            };
            let enc = codec.encode(&ctx)?;
            let bpp = enc.bpp(d);
            if label.starts_with("baseline") {
                baseline_bpp = bpp;
            }
            eprintln!("  drift={drift} {label}: bpp={bpp:.4}");
            table.row(vec![
                format!("{drift}"),
                label.to_string(),
                format!("{:.4}", bpp),
                format!("{:+.1}%", (bpp / baseline_bpp - 1.0) * 100.0),
            ]);
        }
    }
    table.print();
    table.save("ablation_codec");
    println!(
        "\nexpected shape: dropping the shared seed explodes Δ (the CRN trick IS the\n\
         sparsity); no-PNG costs a few %; fast-DEFLATE matches PNG within ~1%;\n\
         3-wise costs ~5-15% space vs 4-wise at this |Δ| scale; the pco index\n\
         stream undercuts the filter record by 10-35% (more at higher drift);\n\
         maskrn halves the pco stream again (the noise gate drops ~50% of Δ′);\n\
         sparse-rsn is drift-insensitive (absolute supermask: cost tracks\n\
         min(|A|, d−|A|), not Δ); κ=1 adds ~25% bits."
    );
    Ok(())
}
