//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json`) and executes them from the L3 hot path.
//!
//! Python never runs here — the HLO text was produced once by
//! `python/compile/aot.py`; this module parses it with
//! `HloModuleProto::from_text_file`, compiles it on the PJRT CPU client and
//! executes with concrete buffers. One compiled executable per (combo,
//! graph), cached for the whole process lifetime.
//!
//! The PJRT path depends on the external `xla` crate and is gated behind
//! the `xla` cargo feature so the default build stays fully offline. With
//! the feature disabled the [`Executor`] / [`XlaBackend`] stubs below keep
//! every call site compiling; their constructors return a clear error and
//! the pure-rust `native` backend remains the execution substrate.
//! With the feature enabled, the default dependency is the vendored
//! compile-only shim at `rust/vendor/xla_stub` (CI's `feature-matrix` job
//! builds + clippy-checks this path); executing real artifacts requires
//! pointing the `xla` dependency at the real crate. Manifest parsing is
//! plain JSON and stays available either way.

#[cfg(feature = "xla")]
pub mod executor;
pub mod manifest;
#[cfg(feature = "xla")]
pub mod xla_backend;

#[cfg(feature = "xla")]
pub use executor::{Executor, GraphHandle};
pub use manifest::{ComboSpec, GraphSpec, Manifest, TensorSpec};
#[cfg(feature = "xla")]
pub use xla_backend::XlaBackend;

#[cfg(not(feature = "xla"))]
mod stub {
    //! Featureless stand-ins so `BackendKind::Xla` call sites compile; any
    //! attempt to construct them reports the missing integration.

    use crate::model::backend::{Backend, FtState, LpState, ModelParams};
    use crate::model::MaskState;
    use anyhow::{bail, Result};
    use std::sync::Arc;

    const MSG: &str = "built without the `xla` cargo feature: the PJRT/XLA path is unavailable \
                       (enable the feature — swapping the vendored xla_stub for the real `xla` \
                       crate to actually execute — or use the native backend)";

    /// Stub for the PJRT executor (see module docs).
    pub struct Executor;

    impl Executor {
        pub fn from_artifacts() -> Result<Self> {
            bail!(MSG)
        }
    }

    /// Stub for the PJRT-backed `Backend` (never constructible).
    pub struct XlaBackend;

    impl XlaBackend {
        pub fn new(_exec: Arc<Executor>, _arch: &str, _c: usize) -> Result<Self> {
            bail!(MSG)
        }
    }

    impl Backend for XlaBackend {
        fn train_step(
            &self,
            _params: &ModelParams,
            _state: &mut MaskState,
            _x: &[f32],
            _y_onehot: &[f32],
            _u: &[f32],
        ) -> Result<f32> {
            bail!(MSG)
        }

        fn eval_logits(
            &self,
            _params: &ModelParams,
            _mask: &[f32],
            _x: &[f32],
        ) -> Result<Vec<f32>> {
            bail!(MSG)
        }

        fn lp_step(
            &self,
            _params: &ModelParams,
            _state: &mut LpState,
            _x: &[f32],
            _y_onehot: &[f32],
        ) -> Result<f32> {
            bail!(MSG)
        }

        fn ft_step(
            &self,
            _params: &ModelParams,
            _state: &mut FtState,
            _x: &[f32],
            _y_onehot: &[f32],
        ) -> Result<f32> {
            bail!(MSG)
        }

        fn ft_eval_logits(
            &self,
            _params: &ModelParams,
            _state: &FtState,
            _x: &[f32],
        ) -> Result<Vec<f32>> {
            bail!(MSG)
        }

        fn name(&self) -> &'static str {
            "xla-stub"
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::{Executor, XlaBackend};

/// Locate the artifacts directory: `$DELTAMASK_ARTIFACTS`, else walk up
/// from the current directory looking for `artifacts/manifest.json` (so
/// `cargo test` / `cargo bench` work from any cwd).
pub fn artifacts_dir() -> Option<std::path::PathBuf> {
    if let Ok(p) = std::env::var("DELTAMASK_ARTIFACTS") {
        let p = std::path::PathBuf::from(p);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    let mut cur = std::env::current_dir().ok()?;
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return Some(cand);
        }
        if !cur.pop() {
            return None;
        }
    }
}
