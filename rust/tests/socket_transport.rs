//! Framed socket transport suite: frame-codec totality under mutation
//! (fleet *and* shard-fabric frame kinds), bounded-admission backpressure,
//! multiplexed-session integrity, torn-frame connection death, the
//! `recv_deadline` outcome ordering over a real wire, the shard-worker
//! hello rejection and mid-round death/recovery paths — and the
//! two-process `serve` / `client-fleet` and `train` / `shard-worker`
//! end-to-ends, asserted trajectory-identical to the in-process runs.
//!
//! The loopback tests build directly on the socket module's public surface
//! (`SocketHub`, `FleetServer`, the frame codec); the end-to-end test drives
//! the installed binary through `CARGO_BIN_EXE_deltamask`, so the whole CLI
//! path — config parsing, handshake fingerprint, plan broadcast, EOR
//! barrier, shutdown — is under test, not just the library.

use deltamask::compress::{Encoded, Update};
use deltamask::coordinator::transport::socket::{
    encode_eor, encode_hello, encode_message, encode_plan, encode_shard_abort,
    encode_shard_begin, encode_shard_finish, encode_shard_hello, encode_shard_slice,
    encode_shard_split, encode_shutdown, parse_frame, parse_header, Hello, Listener, ShardHello,
    Stream, HEADER_LEN, MAGIC, VERSION,
};
use deltamask::coordinator::{
    serve_shard_worker, Aggregator, ConfigFingerprint, FleetServer, Payload, RecvOutcome,
    RoundEngine, ShardLink, ShardPlacement, ShardedAggregator, SocketAddrSpec, SocketConfig,
    SocketHub, Transport, TransportKind, TransportSender, WireMessage, WireSlice,
};
use deltamask::fl::server::MaskServer;
use deltamask::util::json::Json;
use deltamask::util::rng::Xoshiro256pp;
use std::io::Write as _;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

/// Deterministic per-client payload bytes, so receivers can verify that a
/// frame's content belongs to the client its session field claims.
fn pattern(client: usize, n: usize) -> Vec<u8> {
    (0..n)
        .map(|i| (i.wrapping_mul(31) ^ client.wrapping_mul(7)) as u8)
        .collect()
}

fn update(round: usize, client: usize, slot: usize, n: usize) -> WireMessage {
    WireMessage {
        round,
        client_id: client,
        slot,
        payload: Payload::Update(Encoded {
            bytes: pattern(client, n),
        }),
        enc_secs: 0.25,
        loss: 2.0,
    }
}

fn fingerprint() -> ConfigFingerprint {
    ConfigFingerprint {
        seed: 5,
        n_clients: 4,
        rounds: 2,
        d: 64,
    }
}

// ---------------------------------------------------------------------
// Frame codec totality
// ---------------------------------------------------------------------

/// Hand-rolled header bytes (magic | version | kind | reserved | session |
/// len), for probing the parser with inputs the encoders would never emit.
fn raw_header(kind: u8, session: u32, len: u32) -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[..4].copy_from_slice(&MAGIC);
    h[4] = VERSION;
    h[5] = kind;
    // h[6..8] reserved, zero
    h[8..12].copy_from_slice(&session.to_le_bytes());
    h[12..16].copy_from_slice(&len.to_le_bytes());
    h
}

/// Every well-formed frame the encoders can produce, one of each kind.
fn corpus() -> Vec<Vec<u8>> {
    let d = 48;
    let theta: Vec<f32> = (0..d).map(|i| 0.1 + 0.8 * (i as f32) / d as f32).collect();
    let s: Vec<f32> = theta.iter().map(|&p| (p / (1.0 - p)).ln()).collect();
    let plan = RoundEngine::new(7, 6, 1.0, 0.8, 0.25, 3).plan(0, &theta, &s);
    vec![
        encode_message(&update(2, 11, 3, 96)),
        encode_message(&update(0, 0, 0, 0)),
        encode_message(&WireMessage {
            payload: Payload::Failed("client oom".into()),
            ..update(1, 5, 2, 0)
        }),
        encode_hello(&Hello {
            conn_index: 1,
            conns_total: 3,
            fingerprint: fingerprint(),
        }),
        encode_plan(&plan),
        encode_eor(9),
        encode_shutdown(),
        // The shard-fabric kinds (7–12): lane hello with a fingerprint,
        // bounds and an opaque slice-state seed, the round control frames,
        // a routed split and the worker's slice return.
        encode_shard_hello(
            0,
            &ShardHello {
                fingerprint: fingerprint(),
                range_start: 8,
                range_end: 24,
                state: (0..16u8).collect(),
            },
        ),
        encode_shard_begin(1, 4, 3),
        encode_shard_split(1, 2, 0, &[1.0, 0.0, 1.0, 0.5]),
        encode_shard_finish(1, true),
        encode_shard_abort(2),
        encode_shard_slice(2, 0.125, &[9, 8, 7]),
    ]
}

/// Shard hello (kind 7) and slice return (kind 12) end in an opaque
/// state blob that absorbs any tail — only a *structural* truncation is
/// detectable for them, so the exact-length assertions below skip both.
fn state_tailed(frame: &[u8]) -> bool {
    frame[5] == 7 || frame[5] == 12
}

fn split(frame: &[u8]) -> ([u8; HEADER_LEN], &[u8]) {
    let header: [u8; HEADER_LEN] = frame[..HEADER_LEN].try_into().unwrap();
    (header, &frame[HEADER_LEN..])
}

/// The decoder is total: random bit flips in headers and payloads, truncated
/// and extended payloads, and outright random bytes all come back as
/// `Ok`/`Err` — never a panic, never an out-of-bounds read. Untouched frames
/// keep round-tripping throughout.
#[test]
fn frame_decoding_is_total_under_mutation() {
    const MAX: usize = 1 << 20;
    let mut rng = Xoshiro256pp::new(0x50C4E7);
    let frames = corpus();

    for frame in &frames {
        let (header, payload) = split(frame);
        let h = parse_header(&header, MAX).expect("encoder output must parse");
        parse_frame(h, payload).expect("encoder output must decode");

        for _ in 0..500 {
            // Header mutation: up to 3 flipped bits. If the header still
            // parses, the (unmodified) payload is decoded against it — a
            // changed length or kind must surface as an error, not a panic.
            let mut mh = header;
            for _ in 0..1 + rng.below(3) {
                let bit = rng.below((HEADER_LEN * 8) as u64) as usize;
                mh[bit / 8] ^= 1 << (bit % 8);
            }
            if let Ok(h) = parse_header(&mh, MAX) {
                let _ = parse_frame(h, payload);
            }

            // Payload mutation: flipped bits under an intact header.
            if !payload.is_empty() {
                let mut mp = payload.to_vec();
                for _ in 0..1 + rng.below(4) {
                    let bit = rng.below((mp.len() * 8) as u64) as usize;
                    mp[bit / 8] ^= 1 << (bit % 8);
                }
                let _ = parse_frame(h, &mp);
            }
        }

        // Truncations and extensions: the length cross-check rejects every
        // payload that does not match the header exactly — except inside
        // the opaque state tail, where only structural cuts can surface.
        for cut in [0, 1, payload.len().saturating_sub(1)] {
            if cut < payload.len() && !(state_tailed(frame) && cut + 1 == payload.len()) {
                assert!(parse_frame(h, &payload[..cut]).is_err(), "truncated to {cut}");
            }
        }
        let mut extended = payload.to_vec();
        extended.push(0xAA);
        if state_tailed(frame) {
            assert!(parse_frame(h, &extended).is_ok(), "a state tail absorbs bytes");
        } else {
            assert!(parse_frame(h, &extended).is_err(), "extended payload");
        }
    }

    // Fully random headers.
    for _ in 0..2_000 {
        let mut h = [0u8; HEADER_LEN];
        for b in h.iter_mut() {
            *b = rng.below(256) as u8;
        }
        let _ = parse_header(&h, MAX);
    }

    // Valid headers of every kind — the fleet kinds 1–6 and the shard
    // fabric's 7–12 — over random payload bytes of the declared length:
    // this drives the body decoders (including the Plan vector counts and
    // the shard-hello bounds checks) through arbitrary garbage.
    for _ in 0..2_000 {
        let kind = 1 + rng.below(12) as u8;
        let len = rng.below(512) as usize;
        let session = rng.next_u32();
        let h = parse_header(&raw_header(kind, session, len as u32), MAX)
            .expect("well-formed header");
        let mut body = vec![0u8; len];
        for b in body.iter_mut() {
            *b = rng.below(256) as u8;
        }
        let _ = parse_frame(h, &body);
    }

    // A header announcing more than the cap is rejected before any
    // allocation happens.
    assert!(parse_header(&raw_header(1, 0, (MAX + 1) as u32), MAX).is_err());
}

// ---------------------------------------------------------------------
// Backpressure
// ---------------------------------------------------------------------

/// A slow consumer bounds the coordinator's queue memory without losing or
/// reordering anything: the reader parks once the byte budget is hit (the
/// stall counter proves it), the high-water mark never exceeds the budget,
/// and every frame still arrives exactly once, in order.
#[test]
fn backpressure_bounds_queue_memory_and_loses_nothing() {
    let cfg = SocketConfig {
        max_frame: 1 << 20,
        inbound_budget: 4096,
        conn_budget: 4096,
    };
    let hub = SocketHub::bind_loopback(TransportKind::Tcp, cfg, 1).unwrap();
    let (mut transport, sender) = hub.round_link(1).unwrap();
    let total = 300usize;
    let payload = 256usize; // frame cost 308 bytes — ~13 fit in the budget

    let tx = std::thread::spawn(move || {
        for slot in 0..total {
            sender.send(update(0, 0, slot, payload)).unwrap();
        }
        // Dropping the only sender closes the write side: the round ends.
    });

    let mut got = Vec::with_capacity(total);
    while let Some(m) = transport.recv() {
        if got.len() < 150 {
            // Slow consumer for the first half: the sender outruns us and
            // must hit the admission gate.
            std::thread::sleep(Duration::from_millis(1));
        }
        got.push(m.slot);
    }
    tx.join().unwrap();

    assert_eq!(got, (0..total).collect::<Vec<_>>(), "lossless and in order");
    assert!(
        transport.peak_inbound_bytes() <= 4096,
        "queue grew past the budget: {} bytes",
        transport.peak_inbound_bytes()
    );
    let st = transport.stats();
    assert_eq!(st.sent_messages, total as u64);
    assert_eq!(st.received_messages, total as u64);
    assert!(
        st.backpressure_stalls > 0,
        "the slow consumer never backpressured the reader"
    );
    assert_eq!(transport.frame_corruptions(), 0);
}

// ---------------------------------------------------------------------
// Session multiplexing
// ---------------------------------------------------------------------

/// Many logical clients over few connections, written from concurrent
/// threads: every message arrives exactly once with its own client's
/// payload bytes — frames from different sessions sharing a connection
/// never bleed into each other.
#[test]
fn multiplexed_sessions_interleave_without_crosstalk() {
    let clients = 32usize;
    let writers = 4usize;
    let hub = SocketHub::bind_loopback(TransportKind::Uds, SocketConfig::default(), writers).unwrap();
    let (mut transport, sender) = hub.round_link(clients).unwrap();

    let threads: Vec<_> = (0..writers)
        .map(|w| {
            let s = sender.clone_sender();
            std::thread::spawn(move || {
                for c in (w..clients).step_by(writers) {
                    s.send(update(1, c, c, 64 + c)).unwrap();
                }
            })
        })
        .collect();
    drop(sender);
    for t in threads {
        t.join().unwrap();
    }

    let mut seen = vec![false; clients];
    let mut wire_bytes = 0u64;
    while let Some(m) = transport.recv() {
        assert_eq!(m.round, 1);
        assert_eq!(m.slot, m.client_id);
        assert!(!seen[m.client_id], "client {} delivered twice", m.client_id);
        seen[m.client_id] = true;
        match &m.payload {
            Payload::Update(enc) => assert_eq!(
                enc.bytes,
                pattern(m.client_id, 64 + m.client_id),
                "crosstalk: client {} carries foreign bytes",
                m.client_id
            ),
            Payload::Failed(e) => panic!("unexpected failure payload: {e}"),
        }
        wire_bytes += (HEADER_LEN + 36 + 64 + m.client_id) as u64;
    }
    assert!(seen.iter().all(|&s| s), "a session went missing");

    let st = transport.stats();
    assert_eq!(st.sent_messages, clients as u64);
    assert_eq!(st.received_messages, clients as u64);
    assert_eq!(st.wire_frames, clients as u64);
    assert_eq!(st.wire_bytes, wire_bytes);
    assert_eq!(transport.frame_corruptions(), 0);
}

// ---------------------------------------------------------------------
// Handshake and connection death
// ---------------------------------------------------------------------

/// `serve` and `client-fleet` launched with different experiment configs is
/// the deadliest two-process operator error: the Hello fingerprint check
/// fails the handshake before a single round runs.
#[test]
fn fleet_handshake_rejects_a_config_mismatch() {
    let listener = Listener::bind(&SocketAddrSpec::Tcp("127.0.0.1:0".into())).unwrap();
    let spec = listener.local_spec().unwrap();
    let client = std::thread::spawn(move || {
        let mut s = Stream::connect(&spec).unwrap();
        let wrong = Hello {
            conn_index: 0,
            conns_total: 1,
            fingerprint: ConfigFingerprint {
                seed: 999, // everything else agrees; the seed does not
                ..fingerprint()
            },
        };
        s.write_all(&encode_hello(&wrong)).unwrap();
        s.flush().unwrap();
        s // keep the connection alive until the server has judged it
    });
    let err = FleetServer::accept_fleet(&listener, SocketConfig::default(), fingerprint())
        .unwrap_err()
        .to_string();
    assert!(err.contains("fingerprint"), "unexpected error: {err}");
    drop(client.join().unwrap());
}

/// The `recv_deadline` outcome ordering (Msg > Closed > TimedOut), pinned
/// over a real wire — plus torn-frame semantics: a connection dying
/// mid-frame is counted as a corruption and drops out of the round's
/// closure condition, so the drain sees `Closed`, never a hang.
#[test]
fn torn_frames_kill_the_connection_and_close_the_round() {
    let listener = Listener::bind(&SocketAddrSpec::Tcp("127.0.0.1:0".into())).unwrap();
    let spec = listener.local_spec().unwrap();
    let fp = fingerprint();
    let fleet_side = std::thread::spawn(move || {
        let mut a = Stream::connect(&spec).unwrap();
        let mut b = Stream::connect(&spec).unwrap();
        for (i, s) in [&mut a, &mut b].into_iter().enumerate() {
            s.write_all(&encode_hello(&Hello {
                conn_index: i as u32,
                conns_total: 2,
                fingerprint: fp,
            }))
            .unwrap();
            s.flush().unwrap();
        }
        (a, b)
    });
    let mut fleet = FleetServer::accept_fleet(&listener, SocketConfig::default(), fp).unwrap();
    let (mut a, mut b) = fleet_side.join().unwrap();
    let mut transport = fleet.take_transport();

    // Msg beats an already-expired deadline: once the frame lands, a
    // deadline in the past still yields the message, not TimedOut.
    a.write_all(&encode_message(&update(0, 0, 0, 40))).unwrap();
    a.flush().unwrap();
    let msg = loop {
        match transport.recv_deadline(Instant::now()) {
            RecvOutcome::Msg(m) => break m,
            RecvOutcome::TimedOut => std::thread::sleep(Duration::from_millis(1)),
            RecvOutcome::Closed => panic!("live connections must not read as closed"),
        }
    };
    assert_eq!(msg.slot, 0);

    // Live-but-idle wire: a short deadline is a timeout, not a closure.
    match transport.recv_deadline(Instant::now() + Duration::from_millis(20)) {
        RecvOutcome::TimedOut => {}
        other => panic!("expected TimedOut on an idle live wire, got {other:?}"),
    }

    // Connection 0 dies seven bytes into a header; connection 1 finishes
    // the round politely.
    let torn = encode_message(&update(0, 1, 1, 40));
    a.write_all(&torn[..7]).unwrap();
    a.flush().unwrap();
    drop(a);
    b.write_all(&encode_eor(0)).unwrap();
    b.flush().unwrap();

    // One dead connection + one EOR mark = the round is closed, well before
    // any deadline. Closed must win over TimedOut.
    let deadline = Instant::now() + Duration::from_secs(30);
    match transport.recv_deadline(deadline) {
        RecvOutcome::Closed => {}
        other => panic!("expected Closed after death + EOR, got {other:?}"),
    }
    assert!(
        Instant::now() < deadline,
        "closure must not sleep out the deadline"
    );
    assert_eq!(transport.frame_corruptions(), 1, "the torn frame is counted");
    assert_eq!(transport.stats().received_messages, 1);
    drop(b);
}

// ---------------------------------------------------------------------
// Shard-worker hello, lane death and recovery
// ---------------------------------------------------------------------

/// The shard hello is judged before any round state exists: a wrong
/// config fingerprint, bounds that disagree with the slice state, or an
/// undecodable state seed each close the connection — surfaced on the
/// lane side as a connect error — while the worker survives to re-accept,
/// so a correct hello on the very next connection still succeeds.
#[test]
fn shard_worker_rejects_fingerprint_and_bounds_mismatches() {
    let d = 24usize;
    let fp = fingerprint(); // d = 64 covers the 0..24 slice below
    let scfg = SocketConfig::default();
    let path = std::env::temp_dir().join(format!("dm-shard-hello-{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let spec = SocketAddrSpec::Uds(path.clone());
    let listener = Listener::bind(&spec).unwrap();
    let worker =
        std::thread::spawn(move || serve_shard_worker::<MaskServer>(&listener, scfg, fp, false));

    let state = MaskServer::with_theta0(d, 1.0, 0.85).encode_slice();
    let timeout = Duration::from_secs(10);

    // Wrong fingerprint: rejected at the hello, before any round frame.
    let wrong = ConfigFingerprint { seed: 999, ..fp };
    let err = ShardLink::connect(&spec, scfg, 0, wrong, 0..d, &state, timeout).unwrap_err();
    assert!(format!("{err:#}").contains("rejected the hello"), "{err:#}");

    // Bounds that disagree with the slice state's dimensionality.
    let err = ShardLink::connect(&spec, scfg, 0, fp, 0..d - 1, &state, timeout).unwrap_err();
    assert!(format!("{err:#}").contains("rejected the hello"), "{err:#}");

    // An undecodable state seed: rejected without killing the worker.
    let err = ShardLink::connect(&spec, scfg, 0, fp, 0..d, &[7u8; 11], timeout).unwrap_err();
    assert!(format!("{err:#}").contains("rejected the hello"), "{err:#}");

    // The worker re-accepted after every rejection: a correct hello now
    // completes, and a shutdown retires the non-lingering serve loop.
    let mut link = ShardLink::connect(&spec, scfg, 0, fp, 0..d, &state, timeout).unwrap();
    link.send_shutdown();
    drop(link);
    worker.join().unwrap().unwrap();
    let _ = std::fs::remove_file(&path);
}

/// Kill a real `shard-worker` process mid-round: the lane trips its
/// sticky fault instead of panicking, the drain-visible shortfall abort
/// leaves no trace on the aggregate state, and after the worker restarts
/// the SAME view reconnects on the next begin — re-seeding the fresh
/// worker from the parked mirror — and lands bitwise-identical to an
/// all-local twin that was driven through the same call sequence.
#[test]
fn remote_lane_death_is_a_clean_shortfall_and_the_view_recovers() {
    use deltamask::fl::ExperimentConfig;
    // The worker derives its expected fingerprint from EXPERIMENT_FLAGS;
    // this config replicates the shape facts those flags pin.
    let shape = ExperimentConfig {
        dataset: "cifar10".into(),
        arch: "test".into(),
        n_clients: 5,
        rounds: 3,
        seed: 42,
        ..ExperimentConfig::default()
    };
    let fp = shape.fingerprint();
    let d = shape.arch_config().d();

    let sock = std::env::temp_dir().join(format!("dm-lane-death-{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&sock);
    let spawn_worker = || {
        deltamask_cmd("shard-worker")
            .args(["--transport", "uds", "--listen"])
            .arg(&sock)
            .spawn()
            .unwrap()
    };
    let mut worker = spawn_worker();

    let server = MaskServer::with_theta0(d, 1.0, 0.85);
    let placement = ShardPlacement::parse(&format!("local,uds:{}", sock.display())).unwrap();
    let mut view = server
        .shard_view_placed(2, &placement, fp, SocketConfig::default())
        .unwrap();
    let mut oracle = server.shard_view(2);

    let masks = |round: u64, k: usize| -> Vec<Vec<f32>> {
        let mut rng = Xoshiro256pp::new(0xD1E ^ round);
        (0..k)
            .map(|_| {
                (0..d)
                    .map(|_| if rng.next_f32() < 0.5 { 1.0 } else { 0.0 })
                    .collect()
            })
            .collect()
    };
    let absorb_all = |agg: &mut ShardedAggregator<MaskServer>, round: u64, k: usize| {
        agg.begin_round(k);
        for (slot, m) in masks(round, k).into_iter().enumerate() {
            agg.absorb(slot, Update::Mask(m));
            while agg.reclaim_buffer().is_some() {}
        }
    };

    // Round 1, both lanes alive: a clean finish over the wire.
    for agg in [&mut view, &mut oracle] {
        absorb_all(agg, 1, 3);
        agg.finish_round();
    }
    assert!(view.lane_fault().is_none(), "clean round must not fault");

    // Round 2: the worker dies mid-round. The absorbs keep flowing (a
    // dead lane must never block routing); the I/O thread trips the
    // sticky fault asynchronously, which is what the drain observes via
    // `lane_fault` before settling — mimic its shortfall abort here.
    worker.kill().unwrap();
    worker.wait().unwrap();
    absorb_all(&mut view, 2, 5);
    let deadline = Instant::now() + Duration::from_secs(30);
    while view.lane_fault().is_none() {
        assert!(Instant::now() < deadline, "lane fault never surfaced");
        std::thread::sleep(Duration::from_millis(10));
    }
    view.abort_round();
    // The oracle runs the identical sequence; its abort is unconditional.
    absorb_all(&mut oracle, 2, 5);
    oracle.abort_round();

    // Restart the worker (the killed process left its socket file behind)
    // and wait for the fresh bind before opening the next round.
    let _ = std::fs::remove_file(&sock);
    let mut worker = spawn_worker();
    let deadline = Instant::now() + Duration::from_secs(60);
    while !sock.exists() {
        assert!(Instant::now() < deadline, "restarted worker never bound");
        std::thread::sleep(Duration::from_millis(10));
    }

    // Round 3: reconnect-on-begin re-seeds the fresh worker from the
    // parked mirror, clears the fault, and the round completes.
    for agg in [&mut view, &mut oracle] {
        absorb_all(agg, 3, 4);
        agg.finish_round();
    }
    assert!(view.lane_fault().is_none(), "reconnect must clear the fault");

    // Bitwise: the faulted round left no trace, the finished rounds did.
    let view_shards = view.into_shards();
    let oracle_shards = oracle.into_shards();
    assert_eq!(view_shards.len(), oracle_shards.len());
    for ((ra, a), (rb, b)) in view_shards.iter().zip(&oracle_shards) {
        assert_eq!(ra, rb, "shard bounds diverged");
        assert_eq!(a.encode_slice(), b.encode_slice(), "slice {ra:?} diverged");
    }
    // `into_shards` sent the worker a shutdown; it exits cleanly.
    let status = wait_or_kill(&mut worker, "restarted shard-worker");
    assert!(status.success(), "restarted shard-worker exited with {status}");
    let _ = std::fs::remove_file(&sock);
}

// ---------------------------------------------------------------------
// Two-process end-to-end
// ---------------------------------------------------------------------

/// The experiment flags shared by all three processes. Small enough for a
/// debug-profile CI run, identical to the churn suite's mini config.
const EXPERIMENT_FLAGS: &[&str] = &[
    "--method", "deltamask", "--dataset", "cifar10", "--arch", "test",
    "--backend", "native", "--head-init", "he", "--clients", "5",
    "--rounds", "3", "--samples", "24", "--test-samples", "100",
    "--alpha", "10", "--seed", "42", "--eval-every", "3", "--epochs", "1",
];

/// A `deltamask` subcommand invocation with the ambient `DELTAMASK_*` knob
/// environment scrubbed, so the test pins its own transport regardless of
/// what the CI matrix exports.
fn deltamask_cmd(sub: &str) -> Command {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_deltamask"));
    for (k, _) in std::env::vars() {
        if k.starts_with("DELTAMASK_") {
            cmd.env_remove(k);
        }
    }
    cmd.arg(sub).args(EXPERIMENT_FLAGS).stdout(Stdio::null());
    cmd
}

fn wait_or_kill(child: &mut Child, label: &str) -> std::process::ExitStatus {
    let deadline = Instant::now() + Duration::from_secs(240);
    loop {
        if let Some(status) = child.try_wait().unwrap() {
            return status;
        }
        if Instant::now() > deadline {
            let _ = child.kill();
            panic!("{label} did not finish within 240s");
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

fn load_json(path: &std::path::Path) -> Json {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    Json::parse(&text).unwrap_or_else(|e| panic!("parsing {}: {e}", path.display()))
}

fn field<'j>(j: &'j Json, key: &str) -> &'j Json {
    j.get(key).unwrap_or_else(|| panic!("missing key {key}"))
}

/// Coordinator and fleet as separate OS processes over a Unix-domain
/// socket, via the real CLI: the run must complete cleanly and its JSON
/// result must match an in-process channel run of the identical config on
/// every transport-invariant fact — losses, bitrates, accuracy, fault
/// counters, completion verdicts and send-time wire counts. The socket
/// frame counters additionally prove the traffic really crossed the wire.
#[test]
fn two_process_uds_run_matches_the_in_process_channel_run() {
    let tmp = std::env::temp_dir();
    let pid = std::process::id();
    let sock = tmp.join(format!("dm-e2e-{pid}.sock"));
    let ref_out = tmp.join(format!("dm-e2e-{pid}-channel.json"));
    let two_out = tmp.join(format!("dm-e2e-{pid}-uds.json"));
    let _ = std::fs::remove_file(&sock);

    // Reference: one process, in-process channel transport.
    let status = deltamask_cmd("train")
        .args(["--transport", "channel", "--out"])
        .arg(&ref_out)
        .status()
        .unwrap();
    assert!(status.success(), "channel reference run failed");

    // Two processes: `serve` owns the coordinator, `client-fleet` trains.
    let mut serve = deltamask_cmd("serve")
        .args(["--transport", "uds", "--listen"])
        .arg(&sock)
        .arg("--out")
        .arg(&two_out)
        .spawn()
        .unwrap();
    let mut fleet = deltamask_cmd("client-fleet")
        .args(["--transport", "uds", "--connections", "3", "--connect"])
        .arg(&sock)
        .spawn()
        .unwrap();
    let serve_status = wait_or_kill(&mut serve, "serve");
    let fleet_status = wait_or_kill(&mut fleet, "client-fleet");
    assert!(serve_status.success(), "serve exited with {serve_status}");
    assert!(fleet_status.success(), "client-fleet exited with {fleet_status}");

    let a = load_json(&ref_out);
    let b = load_json(&two_out);
    for key in ["final_accuracy", "peak_accuracy", "avg_bpp", "total_uplink_mib", "d"] {
        assert_eq!(field(&a, key), field(&b, key), "top-level {key} diverged");
    }
    let ra = field(&a, "rounds").as_arr().unwrap();
    let rb = field(&b, "rounds").as_arr().unwrap();
    assert_eq!(ra.len(), rb.len(), "round count");
    assert_eq!(ra.len(), 3);
    for (x, y) in ra.iter().zip(rb) {
        let r = field(x, "round").as_usize().unwrap();
        for key in ["round", "loss", "bpp", "acc", "quorum_met", "degraded", "faults"] {
            assert_eq!(field(x, key), field(y, key), "round {r}: {key} diverged");
        }
        for key in ["sent_messages", "sent_payload_bytes"] {
            assert_eq!(
                field(field(x, "wire"), key),
                field(field(y, "wire"), key),
                "round {r}: wire.{key} diverged"
            );
        }
        // The channel run never framed anything; the socket run framed at
        // least one frame per message (EOR marks add more).
        let sent = field(field(x, "wire"), "sent_messages").as_f64().unwrap();
        let chan_frames = field(field(x, "wire"), "wire_frames").as_f64().unwrap();
        let sock_frames = field(field(y, "wire"), "wire_frames").as_f64().unwrap();
        assert_eq!(chan_frames, 0.0, "round {r}: channel run framed traffic");
        assert!(
            sock_frames >= sent,
            "round {r}: {sock_frames} frames < {sent} messages over the socket"
        );
    }

    let _ = std::fs::remove_file(&ref_out);
    let _ = std::fs::remove_file(&two_out);
    let _ = std::fs::remove_file(&sock);
}

/// The shard-fabric acceptance: `train` with one absorb lane living in a
/// real `shard-worker` OS process over UDS must be bitwise-identical,
/// round by round, to the in-process `--agg-shards` run of the same seed
/// — losses, bitrates, accuracy, fault counters — both on a clean client
/// uplink and under a seeded `ChaosTransport` on that uplink (the chaos
/// wraps the client wire; the shard wire must not perturb anything).
#[test]
fn remote_shard_train_matches_in_process_sharded_train_bitwise() {
    let tmp = std::env::temp_dir();
    let pid = std::process::id();
    for (tag, chaos) in [
        ("clean", None),
        ("chaos", Some("seed=1702,drop=0.1,flaky=0.5")),
    ] {
        let sock = tmp.join(format!("dm-shard-e2e-{pid}-{tag}.sock"));
        let local_out = tmp.join(format!("dm-shard-e2e-{pid}-{tag}-local.json"));
        let remote_out = tmp.join(format!("dm-shard-e2e-{pid}-{tag}-remote.json"));
        let _ = std::fs::remove_file(&sock);

        // `--persistent-pipeline` keeps one resident view (one worker
        // session) for the whole run, so the non-lingering worker exits
        // cleanly on the end-of-experiment shutdown. Dropped updates under
        // chaos need the degraded-quorum gate to still settle rounds.
        let mut shared = vec!["--agg-shards", "2", "--decode-workers", "2", "--persistent-pipeline"];
        if let Some(spec) = chaos {
            shared.extend(["--chaos", spec, "--quorum", "0.6"]);
        }

        // Reference: both absorb lanes in-process.
        let status = deltamask_cmd("train")
            .args(&shared)
            .arg("--out")
            .arg(&local_out)
            .status()
            .unwrap();
        assert!(status.success(), "{tag}: local sharded run failed");

        // Same run, shard 1's lane in a worker process.
        let mut worker = deltamask_cmd("shard-worker")
            .args(["--transport", "uds", "--listen"])
            .arg(&sock)
            .spawn()
            .unwrap();
        let status = deltamask_cmd("train")
            .args(&shared)
            .arg("--shard-place")
            .arg(format!("local,uds:{}", sock.display()))
            .arg("--out")
            .arg(&remote_out)
            .status()
            .unwrap();
        assert!(status.success(), "{tag}: remote sharded run failed");
        let worker_status = wait_or_kill(&mut worker, "shard-worker");
        assert!(worker_status.success(), "{tag}: shard-worker exited with {worker_status}");

        let a = load_json(&local_out);
        let b = load_json(&remote_out);
        for key in ["final_accuracy", "peak_accuracy", "avg_bpp", "total_uplink_mib", "d"] {
            assert_eq!(field(&a, key), field(&b, key), "{tag}: top-level {key} diverged");
        }
        let ra = field(&a, "rounds").as_arr().unwrap();
        let rb = field(&b, "rounds").as_arr().unwrap();
        assert_eq!(ra.len(), rb.len(), "{tag}: round count");
        assert_eq!(ra.len(), 3);
        for (x, y) in ra.iter().zip(rb) {
            let r = field(x, "round").as_usize().unwrap();
            for key in ["round", "loss", "bpp", "acc", "quorum_met", "degraded", "faults"] {
                assert_eq!(field(x, key), field(y, key), "{tag} round {r}: {key} diverged");
            }
        }
        let _ = std::fs::remove_file(&local_out);
        let _ = std::fs::remove_file(&remote_out);
        let _ = std::fs::remove_file(&sock);
    }
}

// ---------------------------------------------------------------------
// Scale
// ---------------------------------------------------------------------

/// Ten thousand logical clients multiplexed over eight connections, written
/// from eight concurrent threads against the default budgets: exactly-once
/// delivery, zero corruption, send-time counters intact.
#[test]
fn ten_thousand_sessions_multiplex_over_a_loopback_socket() {
    let k = 10_000usize;
    let writers = 8usize;
    let payload = 24usize;
    let hub = SocketHub::bind_loopback(TransportKind::Uds, SocketConfig::default(), writers).unwrap();
    let (mut transport, sender) = hub.round_link(k).unwrap();

    let threads: Vec<_> = (0..writers)
        .map(|w| {
            let s = sender.clone_sender();
            std::thread::spawn(move || {
                for c in (w..k).step_by(writers) {
                    s.send(update(0, c, c, payload)).unwrap();
                }
            })
        })
        .collect();
    drop(sender);

    // Drain concurrently with the writers — at this volume the queue and
    // the OS socket buffers are both smaller than the traffic.
    let mut seen = vec![false; k];
    let mut n = 0usize;
    while let Some(m) = transport.recv() {
        assert!(!seen[m.slot], "slot {} delivered twice", m.slot);
        seen[m.slot] = true;
        n += 1;
    }
    for t in threads {
        t.join().unwrap();
    }
    assert_eq!(n, k, "every session's frame arrived exactly once");

    let st = transport.stats();
    assert_eq!(st.sent_messages, k as u64);
    assert_eq!(st.received_messages, k as u64);
    assert_eq!(st.sent_payload_bytes, (k * payload) as u64);
    assert_eq!(transport.frame_corruptions(), 0);
}

/// The acceptance-scale witness: a full multi-round experiment with 10^4
/// multiplexed clients over the UDS loopback, trajectory-identical to the
/// in-process channel run. Ignored by default — minutes of debug-profile
/// training — run with `cargo test --test socket_transport -- --ignored`.
#[test]
#[ignore = "10^4-client experiment: minutes in a debug profile"]
fn ten_thousand_client_experiment_is_transport_invariant() {
    use deltamask::coordinator::{OnDecodeError, PipelineMode};
    use deltamask::fl::{run_experiment, BackendKind, ExperimentConfig, HeadInit, ServerTuning};
    let base = ExperimentConfig {
        dataset: "cifar10".into(),
        arch: "test".into(),
        method: "deltamask".into(),
        n_clients: 10_000,
        rounds: 2,
        rho: 1.0,
        local_epochs: 1,
        samples_per_client: 8,
        test_samples: 50,
        dirichlet_alpha: 10.0,
        kappa0: 0.8,
        kappa_floor: 0.25,
        seed: 42,
        eval_every: 2,
        backend: BackendKind::Native,
        head_init: HeadInit::He,
        lp_rounds: 1,
        theta0: 0.85,
        arch_override: None,
        tuning: ServerTuning {
            pipeline: PipelineMode::Streaming,
            decode_workers: 2,
            agg_shards: 2,
            shard_place: String::new(),
            persistent_pipeline: true,
            quorum: 1.0,
            round_deadline_ms: 0,
            on_decode_error: OnDecodeError::Abort,
        },
        chaos: String::new(),
        transport: TransportKind::Channel,
    };
    let channel = run_experiment(&base).unwrap();
    let mut cfg = base;
    cfg.transport = TransportKind::Uds;
    let socket = run_experiment(&cfg).unwrap();
    assert_eq!(channel.rounds.len(), socket.rounds.len());
    for (x, y) in channel.rounds.iter().zip(&socket.rounds) {
        let r = x.round;
        assert_eq!(x.train_loss, y.train_loss, "round {r}: loss");
        assert_eq!(x.mean_bpp, y.mean_bpp, "round {r}: bpp");
        assert_eq!(x.accuracy, y.accuracy, "round {r}: accuracy");
        assert_eq!(x.faults, y.faults, "round {r}: fault counters");
        assert_eq!(x.wire.sent_messages, y.wire.sent_messages, "round {r}");
        assert_eq!(
            x.wire.sent_payload_bytes, y.wire.sent_payload_bytes,
            "round {r}"
        );
    }
    assert_eq!(channel.final_accuracy(), socket.final_accuracy());
}
