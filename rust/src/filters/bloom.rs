//! Classic Bloom filter — the index-compression substrate of the
//! **DeepReduce** baseline (Kostopoulou et al. 2021, P0 policy). Included so
//! the paper's Figures 3/4/7 comparison ("Bloom filters are prone to a
//! higher false positive rate for the same bits per entry", §5.1) can be
//! regenerated against our own from-scratch implementation.

//! Bit mapping: probes use Lemire multiply-shift range reduction
//! (`mulhi(h, num_bits)`) instead of `h % num_bits` — two fewer 64-bit
//! divisions per probe on the Eq. 5 hot path. The reduction is part of the
//! wire contract (`from_parts` rebuilds the same mapping), so encoder and
//! decoder stay consistent; it is simply a different, division-free hash →
//! bit map with the same uniformity.

use super::{MembershipFilter, BATCH_BLOCK};
use crate::hash::{mix_split, mulhi};

#[derive(Clone, Debug)]
pub struct BloomFilter {
    bits: Vec<u64>,
    num_bits: u64,
    num_hashes: u32,
    num_keys: usize,
}

impl BloomFilter {
    /// Build with an explicit bits-per-entry budget (to match a BFuse filter
    /// byte-for-byte in ablations). Optimal k = bpe·ln2.
    pub fn with_bits_per_entry(keys: &[u64], bpe: f64) -> Self {
        let n = keys.len().max(1);
        let num_bits = ((n as f64 * bpe).ceil() as u64).max(64);
        let k = ((bpe * std::f64::consts::LN_2).round() as u32).clamp(1, 16);
        let mut f = Self {
            bits: vec![0u64; num_bits.div_ceil(64) as usize],
            num_bits,
            num_hashes: k,
            num_keys: keys.len(),
        };
        for &key in keys {
            f.insert(key);
        }
        f
    }

    /// Build for a target false-positive rate: m = -n·ln(p)/ln²2.
    pub fn with_fp_rate(keys: &[u64], p: f64) -> Self {
        let bpe = -p.ln() / (std::f64::consts::LN_2 * std::f64::consts::LN_2);
        Self::with_bits_per_entry(keys, bpe)
    }

    fn insert(&mut self, key: u64) {
        let (h1, h2) = Self::double_hash(key);
        for i in 0..self.num_hashes as u64 {
            let bit = mulhi(h1.wrapping_add(i.wrapping_mul(h2)), self.num_bits);
            self.bits[(bit / 64) as usize] |= 1u64 << (bit % 64);
        }
    }

    /// The double-hashing pair (h1, h2|1) shared by insert and every query
    /// path.
    #[inline(always)]
    fn double_hash(key: u64) -> (u64, u64) {
        (
            mix_split(key, 0x51_7c_c1_b7_27_22_0a_95),
            mix_split(key, 0x96_97_9a_6e_0f_3e_1d_31) | 1,
        )
    }

    /// Membership probe from a precomputed hash pair — shared by `contains`
    /// and the batched kernels so both agree bitwise by construction.
    #[inline(always)]
    fn probe(&self, h1: u64, h2: u64) -> bool {
        for i in 0..self.num_hashes as u64 {
            let bit = mulhi(h1.wrapping_add(i.wrapping_mul(h2)), self.num_bits);
            if self.bits[(bit / 64) as usize] & (1u64 << (bit % 64)) == 0 {
                return false;
            }
        }
        true
    }

    pub fn num_keys(&self) -> usize {
        self.num_keys
    }

    pub fn num_hashes(&self) -> u32 {
        self.num_hashes
    }

    pub fn payload(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.bits.len() * 8);
        for w in &self.bits {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out
    }

    pub fn from_parts(payload: &[u8], num_bits: u64, num_hashes: u32, num_keys: usize) -> Self {
        assert_eq!(payload.len() % 8, 0);
        let bits = payload
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Self {
            bits,
            num_bits,
            num_hashes,
            num_keys,
        }
    }

    pub fn num_bits(&self) -> u64 {
        self.num_bits
    }
}

impl MembershipFilter for BloomFilter {
    #[inline]
    fn contains(&self, key: u64) -> bool {
        if self.num_keys == 0 {
            return false;
        }
        let (h1, h2) = Self::double_hash(key);
        self.probe(h1, h2)
    }

    /// Blocked kernel: both double-hash streams are computed for a whole
    /// block in flat loops before the bit-test phase runs.
    fn contains_batch(&self, keys: &[u64], out: &mut [bool]) {
        assert_eq!(keys.len(), out.len());
        if self.num_keys == 0 {
            out.fill(false);
            return;
        }
        let mut h1s = [0u64; BATCH_BLOCK];
        let mut h2s = [0u64; BATCH_BLOCK];
        let mut base = 0usize;
        while base < keys.len() {
            let len = BATCH_BLOCK.min(keys.len() - base);
            for (j, &k) in keys[base..base + len].iter().enumerate() {
                let (h1, h2) = Self::double_hash(k);
                h1s[j] = h1;
                h2s[j] = h2;
            }
            for (j, o) in out[base..base + len].iter_mut().enumerate() {
                *o = self.probe(h1s[j], h2s[j]);
            }
            base += len;
        }
    }

    /// Batched Eq. 5 kernel over one contiguous index range (see
    /// [`MembershipFilter::decode_mask_into_range`]; `start == 0` is the
    /// full-`d` `decode_mask_into` sweep).
    fn decode_mask_into_range(&self, mask: &mut [f32], start: usize) {
        if self.num_keys == 0 {
            return;
        }
        let mut h1s = [0u64; BATCH_BLOCK];
        let mut h2s = [0u64; BATCH_BLOCK];
        let d = mask.len();
        let mut base = 0usize;
        while base < d {
            let len = BATCH_BLOCK.min(d - base);
            for (j, h) in h1s[..len].iter_mut().enumerate() {
                let (h1, h2) = Self::double_hash((start + base + j) as u64);
                *h = h1;
                h2s[j] = h2;
            }
            for (j, m) in mask[base..base + len].iter_mut().enumerate() {
                if self.probe(h1s[j], h2s[j]) {
                    *m = 1.0 - *m;
                }
            }
            base += len;
        }
    }

    fn payload_bytes(&self) -> usize {
        self.bits.len() * 8
    }

    fn bits_per_entry(&self) -> f64 {
        if self.num_keys == 0 {
            return 0.0;
        }
        self.num_bits as f64 / self.num_keys as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filters::testutil::random_keys;
    use crate::filters::{BinaryFuse, MembershipFilter};

    #[test]
    fn no_false_negatives() {
        for n in [0usize, 1, 10, 5_000] {
            let keys = random_keys(n, n as u64 + 1);
            let f = BloomFilter::with_bits_per_entry(&keys, 8.6);
            for &k in &keys {
                assert!(f.contains(k));
            }
        }
    }

    #[test]
    fn bloom_worse_fp_than_bfuse_at_equal_budget() {
        // The §5.1 comparison: same bits per entry, Bloom has higher FP rate.
        let keys = random_keys(20_000, 2);
        let keyset: std::collections::HashSet<u64> = keys.iter().cloned().collect();
        let bf = BinaryFuse::<u8, 4>::build(&keys).unwrap();
        let bloom = BloomFilter::with_bits_per_entry(&keys, bf.bits_per_entry());
        let mut rng = crate::util::rng::Xoshiro256pp::new(3);
        let trials = 300_000;
        let (mut fp_bloom, mut fp_bfuse) = (0usize, 0usize);
        for _ in 0..trials {
            let k = rng.next_u64();
            if keyset.contains(&k) {
                continue;
            }
            if bloom.contains(k) {
                fp_bloom += 1;
            }
            if bf.contains(k) {
                fp_bfuse += 1;
            }
        }
        assert!(
            fp_bloom > fp_bfuse,
            "bloom fp={fp_bloom} bfuse fp={fp_bfuse} (paper §5.1 ordering)"
        );
    }

    #[test]
    fn fp_rate_target() {
        let keys = random_keys(10_000, 4);
        let keyset: std::collections::HashSet<u64> = keys.iter().cloned().collect();
        let f = BloomFilter::with_fp_rate(&keys, 0.01);
        let mut rng = crate::util::rng::Xoshiro256pp::new(5);
        let mut fp = 0usize;
        let trials = 100_000;
        for _ in 0..trials {
            let k = rng.next_u64();
            if !keyset.contains(&k) && f.contains(k) {
                fp += 1;
            }
        }
        let rate = fp as f64 / trials as f64;
        assert!(rate < 0.02, "rate={rate}");
    }

    #[test]
    fn roundtrip() {
        // from_parts must rebuild the exact Lemire-mapped bit array: same
        // answers (members and non-members) on both sides of the wire.
        let keys = random_keys(1_000, 6);
        let f = BloomFilter::with_bits_per_entry(&keys, 10.0);
        let g = BloomFilter::from_parts(&f.payload(), f.num_bits(), f.num_hashes(), f.num_keys());
        for &k in &keys {
            assert!(g.contains(k));
        }
        for k in 0..10_000u64 {
            assert_eq!(f.contains(k), g.contains(k));
        }
    }

    #[test]
    fn lemire_mapping_fills_whole_range() {
        // The multiply-shift reduction must use the full [0, num_bits) range
        // (a regression guard for the % → mulhi change): with enough keys,
        // both the first and last bit words see insertions.
        let keys = random_keys(20_000, 9);
        let f = BloomFilter::with_bits_per_entry(&keys, 9.0);
        let payload = f.payload();
        assert!(payload[..8].iter().any(|&b| b != 0), "low words never hit");
        let n = payload.len();
        assert!(payload[n - 8..].iter().any(|&b| b != 0), "high words never hit");
    }

    #[test]
    fn batched_kernels_match_scalar_oracle() {
        for n in [0usize, 1, 700, 20_000] {
            let keys = random_keys(n, 40 + n as u64);
            let f = BloomFilter::with_bits_per_entry(&keys, 8.62);
            let d = 30_001u64;
            let mut mask: Vec<f32> = (0..d).map(|i| (i % 5 == 0) as u32 as f32).collect();
            let mut expect = mask.clone();
            for (i, m) in expect.iter_mut().enumerate() {
                if f.contains(i as u64) {
                    *m = 1.0 - *m;
                }
            }
            f.decode_mask_into(&mut mask);
            assert_eq!(mask, expect);
            // Range tiling reproduces the full sweep bitwise.
            let mut tiled: Vec<f32> = (0..d).map(|i| (i % 5 == 0) as u32 as f32).collect();
            let mid = (d / 3 + 1) as usize;
            f.decode_mask_into_range(&mut tiled[..mid], 0);
            f.decode_mask_into_range(&mut tiled[mid..], mid);
            assert_eq!(tiled, expect, "range tiling diverged");
            let mut rng = crate::util::rng::Xoshiro256pp::new(n as u64 + 13);
            let probes: Vec<u64> = (0..3_000).map(|_| rng.next_u64()).collect();
            let mut got = vec![false; probes.len()];
            f.contains_batch(&probes, &mut got);
            for (j, &k) in probes.iter().enumerate() {
                assert_eq!(got[j], f.contains(k));
            }
        }
    }
}
