//! Probabilistic membership filters.
//!
//! The DeltaMask codec fingerprints the top-κ mask-update index set Δ′ into a
//! **binary fuse filter** (Graf & Lemire 2022) whose fingerprint array is then
//! packed into a grayscale PNG (§3.2, Eq. 1–2). The ablations additionally
//! need **XOR filters** (Graf & Lemire 2020, Fig. 9 / Table 4) and a **Bloom
//! filter** (the DeepReduce baseline). All three are implemented from
//! scratch here.

pub mod bfuse;
pub mod bloom;
pub mod xor;

pub use bfuse::BinaryFuse;
pub use bloom::BloomFilter;
pub use xor::XorFilter;

/// Fingerprint storage width. The paper's "bits-per-entry" knob (§5.4):
/// wider fingerprints lower the false-positive rate (≈ 2^-bits) at a linear
/// space cost.
pub trait Fingerprint: Copy + Eq + Default {
    const BITS: u32;
    fn from_hash(h: u64) -> Self;
    fn to_u32(self) -> u32;
    fn xor(self, other: Self) -> Self;
    fn to_bytes_push(self, out: &mut Vec<u8>);
    fn read_bytes(bytes: &[u8], idx: usize) -> Self;
}

macro_rules! impl_fingerprint {
    ($t:ty, $bits:expr) => {
        impl Fingerprint for $t {
            const BITS: u32 = $bits;
            #[inline]
            fn from_hash(h: u64) -> Self {
                // Fold the full 64-bit hash so every input bit matters.
                (h ^ (h >> 32)) as $t
            }
            #[inline]
            fn to_u32(self) -> u32 {
                self as u32
            }
            #[inline]
            fn xor(self, other: Self) -> Self {
                self ^ other
            }
            #[inline]
            fn to_bytes_push(self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }
            #[inline]
            fn read_bytes(bytes: &[u8], idx: usize) -> Self {
                const W: usize = ($bits / 8) as usize;
                let mut buf = [0u8; W];
                buf.copy_from_slice(&bytes[idx * W..idx * W + W]);
                <$t>::from_le_bytes(buf)
            }
        }
    };
}

impl_fingerprint!(u8, 8);
impl_fingerprint!(u16, 16);
impl_fingerprint!(u32, 32);

/// Block width of the batched query kernels: per-key hashes are computed
/// for a whole block in a flat, data-independent loop (which the compiler
/// can vectorize) before the gather-heavy probe phase runs.
pub(crate) const BATCH_BLOCK: usize = 128;

/// Common interface used by the codecs and the ablation benches.
pub trait MembershipFilter {
    /// Query a key (for DeltaMask: a mask-parameter index).
    fn contains(&self, key: u64) -> bool;
    /// Serialized size of the fingerprint payload in bytes (what goes into
    /// the grayscale image).
    fn payload_bytes(&self) -> usize;
    /// Achieved bits per entry for the construction set.
    fn bits_per_entry(&self) -> f64;

    /// Batched membership over a slice of keys, writing one answer per key
    /// into `out`. The default is the scalar per-key loop; the concrete
    /// filters override it with blocked monomorphic kernels that hash
    /// fixed-size blocks before probing. Overrides must agree bitwise with
    /// `contains` (the parity tests drive both paths).
    fn contains_batch(&self, keys: &[u64], out: &mut [bool]) {
        assert_eq!(keys.len(), out.len());
        for (o, &k) in out.iter_mut().zip(keys) {
            *o = self.contains(k);
        }
    }

    /// Batched Eq. 5 reconstruction kernel over the dense index range
    /// `[0, mask.len())`: flip `mask[i]` (0.0 ↔ 1.0) at every index the
    /// filter reports as a member. This is the server-side DeltaMask hot
    /// path; it is the `start == 0` case of the range-restricted kernel.
    fn decode_mask_into(&self, mask: &mut [f32]) {
        self.decode_mask_into_range(mask, 0);
    }

    /// Range-restricted Eq. 5 kernel: flip `mask[j]` at every member index
    /// `start + j` for `j` in `[0, mask.len())`. Restricting the sweep to
    /// a contiguous `d`-range is what lets the dimension-sharded drain
    /// split a single record's decode across shard lanes. The default is
    /// the scalar membership sweep and doubles as the parity oracle for
    /// the blocked overrides; overrides must agree with it bitwise, and
    /// tiling `0..d` with ranges must reproduce `decode_mask_into` exactly
    /// (membership is a per-index property, false positives included).
    fn decode_mask_into_range(&self, mask: &mut [f32], start: usize) {
        for (j, m) in mask.iter_mut().enumerate() {
            if self.contains((start + j) as u64) {
                *m = 1.0 - *m;
            }
        }
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::util::rng::Xoshiro256pp;

    /// Distinct random u64 keys.
    pub fn random_keys(n: usize, seed: u64) -> Vec<u64> {
        let mut rng = Xoshiro256pp::new(seed);
        let mut set = std::collections::HashSet::with_capacity(n);
        while set.len() < n {
            set.insert(rng.next_u64());
        }
        set.into_iter().collect()
    }

    /// Distinct keys drawn from a small universe [0, d) — the actual
    /// DeltaMask regime (mask indexes).
    pub fn random_indexes(n: usize, d: u64, seed: u64) -> Vec<u64> {
        assert!(n as u64 <= d);
        let mut rng = Xoshiro256pp::new(seed);
        let mut set = std::collections::HashSet::with_capacity(n);
        while set.len() < n {
            set.insert(rng.below(d));
        }
        set.into_iter().collect()
    }
}
