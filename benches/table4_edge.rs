//! **Table 4 / App. C.4** — per-entry CPU time of filter construction and
//! membership queries, Xor{8,16,32} vs BFuse{8,16,32}.
//!
//!     cargo bench --bench table4_edge                 # 1M entries
//!     cargo bench --bench table4_edge -- --full       # paper's 10M
//!
//! The paper measured Jetson Nano / RPi 4 / Coral with a power HAT; on this
//! testbed we report measured CPU ns/entry (energy ∝ time on fixed
//! hardware). The device-independent claims checked: BFuse faster than XOR
//! at every width; time grows only mildly with bits-per-entry.
//!
//! A second table reports the same per-entry cost view one layer up — the
//! full mask-codec encode/decode path (client encode cost is what an edge
//! device actually pays per round) for the filter record, the codec-9 pco
//! stream and the sibling codecs 10–11.

use deltamask::bench::{summarize, time_fn, Table};
use deltamask::compress::{self, DecodeCtx, EncodeCtx};
use deltamask::filters::{BinaryFuse, MembershipFilter, XorFilter};
use deltamask::model::sample_mask_seeded;
use deltamask::util::cli::Args;
use deltamask::util::rng::Xoshiro256pp;

fn main() {
    let args = Args::from_env();
    let n = if args.flag("full") {
        10_000_000
    } else {
        args.usize("entries", 1_000_000)
    };
    let mut rng = Xoshiro256pp::new(3);
    let keys: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
    let probes: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
    let reps = if n >= 10_000_000 { 1 } else { 3 };

    println!("Table 4 over {n} entries ({reps} reps)");
    let mut table = Table::new(
        "Table 4: filter construct/query cost",
        &["filter", "bpe", "construct ns/entry", "query ns/entry"],
    );

    macro_rules! profile {
        ($label:expr, $ty:ty) => {{
            let c = summarize(&time_fn(0, reps, || <$ty>::build(&keys).unwrap()));
            let f = <$ty>::build(&keys).unwrap();
            let q = summarize(&time_fn(1, reps, || {
                probes.iter().filter(|&&k| f.contains(k)).count()
            }));
            eprintln!(
                "  {}: construct {:.1} ns/e, query {:.1} ns/e",
                $label,
                c.mean / n as f64 * 1e9,
                q.mean / n as f64 * 1e9
            );
            table.row(vec![
                $label.to_string(),
                format!("{:.2}", f.bits_per_entry()),
                format!("{:.1}", c.mean / n as f64 * 1e9),
                format!("{:.1}", q.mean / n as f64 * 1e9),
            ]);
        }};
    }

    profile!("Xor8", XorFilter<u8>);
    profile!("Xor16", XorFilter<u16>);
    profile!("Xor32", XorFilter<u32>);
    profile!("BFuse8", BinaryFuse<u8, 4>);
    profile!("BFuse16", BinaryFuse<u16, 4>);
    profile!("BFuse32", BinaryFuse<u32, 4>);
    table.print();
    table.save("table4_edge");

    // -- Codec-level edge cost: encode/decode ns per model parameter -------
    // The client-side number an edge deployment budgets against, for the
    // filter record and each index-stream codec (9, 10, 11) on one fixture.
    let d = if args.flag("full") { 1_000_000 } else { 200_000 };
    let theta_g: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
    let theta_k: Vec<f32> = theta_g
        .iter()
        .map(|&p| (p + 0.1 * (rng.next_f32() - 0.5)).clamp(0.01, 0.99))
        .collect();
    let mut mask_g = Vec::new();
    sample_mask_seeded(&theta_g, 21, &mut mask_g);
    let mut mask_k = Vec::new();
    sample_mask_seeded(&theta_k, 22, &mut mask_k);
    let ctx = EncodeCtx {
        d,
        theta_k: &theta_k,
        theta_g: &theta_g,
        mask_k: &mask_k,
        mask_g: &mask_g,
        s_k: &[],
        s_g: &[],
        kappa: 0.8,
        seed: 17,
    };
    let dctx = DecodeCtx {
        d,
        mask_g: &mask_g,
        s_g: &[],
        seed: 17,
    };
    let mut codec_table = Table::new(
        "Table 4b: mask-codec edge cost",
        &["codec", "bpp", "encode ns/param", "decode ns/param"],
    );
    for name in ["deltamask", "deltamask-pco", "maskrn", "sparse-rsn"] {
        let codec = compress::by_name(name).expect("registered codec");
        let enc = codec.encode(&ctx).expect("encode");
        let e = summarize(&time_fn(1, reps, || codec.encode(&ctx).unwrap()));
        let q = summarize(&time_fn(1, reps, || codec.decode(&enc.bytes, &dctx).unwrap()));
        eprintln!(
            "  {name}: bpp {:.4}, encode {:.1} ns/p, decode {:.1} ns/p",
            enc.bpp(d),
            e.mean / d as f64 * 1e9,
            q.mean / d as f64 * 1e9
        );
        codec_table.row(vec![
            name.to_string(),
            format!("{:.4}", enc.bpp(d)),
            format!("{:.1}", e.mean / d as f64 * 1e9),
            format!("{:.1}", q.mean / d as f64 * 1e9),
        ]);
    }
    codec_table.print();
    codec_table.save("table4_edge_codecs");
}
